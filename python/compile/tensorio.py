"""FAT1: a tiny named-tensor binary format shared by python and rust.

Used for golden test vectors (python writes, rust reads and compares after
executing the same HLO artifact) and for initial checkpoint export.  numpy's
.npy was avoided only because the offline rust side has no npy crate; FAT1 is
~40 lines on each side.

Layout (little-endian):
  magic  b"FAT1"
  u32    n_tensors
  repeat n_tensors times:
    u32      name_len, name (utf-8)
    u8       dtype code (0=f32, 1=i32, 2=u32, 3=f64, 4=i64, 5=bf16 as u16)
    u32      ndim
    u64*ndim dims
    bytes    raw data (C order)
"""

from __future__ import annotations

import struct

import numpy as np

_DTYPE_CODES = {
    np.dtype(np.float32): 0,
    np.dtype(np.int32): 1,
    np.dtype(np.uint32): 2,
    np.dtype(np.float64): 3,
    np.dtype(np.int64): 4,
}
_CODE_DTYPES = {v: k for k, v in _DTYPE_CODES.items()}


def write_tensors(path: str, tensors: dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(b"FAT1")
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            # NB: not ascontiguousarray — it promotes 0-d arrays to 1-d.
            # tobytes() below always emits a C-order copy.
            arr = np.asarray(arr)
            if arr.dtype == np.bool_:
                arr = arr.astype(np.int32)
            if arr.dtype not in _DTYPE_CODES:
                raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", _DTYPE_CODES[arr.dtype]))
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<Q", d))
            f.write(arr.tobytes())


def read_tensors(path: str) -> dict[str, np.ndarray]:
    out: dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        if f.read(4) != b"FAT1":
            raise ValueError(f"{path}: bad magic")
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (nl,) = struct.unpack("<I", f.read(4))
            name = f.read(nl).decode("utf-8")
            (code,) = struct.unpack("<B", f.read(1))
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = [struct.unpack("<Q", f.read(8))[0] for _ in range(ndim)]
            dt = _CODE_DTYPES[code]
            count = int(np.prod(dims)) if dims else 1
            data = f.read(count * dt.itemsize)
            out[name] = np.frombuffer(data, dtype=dt).reshape(dims).copy()
    return out
