"""FlashAttention-1 style forward kernel — the non-matmul-FLOPs ablation.

Differences from ``flash2.py`` (each one is a paper section 3.1.1 tweak that
FlashAttention-2 *removes*):

1. **Per-iteration rescale**: after every KV block the output accumulator is
   brought back to the fully-normalized form ``diag(l)^-1 O`` — two extra
   rows of non-matmul work (a divide and a multiply over the whole ``Bq x d``
   accumulator) per iteration, versus FA2's single rescale after the loop.
2. **Both softmax statistics stored**: the kernel writes the row max ``m``
   AND the row sum-of-exponentials ``l`` to HBM (2N floats) instead of the
   single logsumexp ``L`` (N floats).

The final output is bit-wise *mathematically* identical to FA2 (the tests
assert allclose); only the FLOP mix and the saved statistics differ.  The
occupancy/loop-order differences of FA1 (grid over batch x heads only) are
modeled in the Rust `gpusim` substrate, where they belong — on the real GPU
they are scheduling properties, not arithmetic.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .flash2 import BlockSizes, NEG_INF, _pad_seq

__all__ = ["flash1_fwd"]


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *, scale, causal, block_k, n_k):
    block_q, d = q_ref.shape
    i = pl.program_id(2)
    n_k_pad = k_ref.shape[0]
    num_kv_blocks = n_k_pad // block_k

    q = q_ref[...].astype(jnp.float32) * scale

    if causal:
        hi = lax.min(
            lax.div((i + 1) * block_q + block_k - 1, block_k), num_kv_blocks
        )
    else:
        hi = num_kv_blocks

    def body(j, carry):
        o_scaled, m, l = carry
        k_blk = k_ref[pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        v_blk = v_ref[pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        s = jnp.dot(q, k_blk.T, preferred_element_type=jnp.float32)

        rows = i * block_q + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = j * block_k + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        if causal:
            keep = jnp.logical_and(cols <= rows, cols < n_k)
        else:
            keep = cols < n_k
        # FA1 applies the mask unconditionally (no diagonal-only tweak).
        s = jnp.where(keep, s, NEG_INF)

        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p_sum = jnp.sum(p, axis=-1)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + p_sum
        # FA1-style update: the accumulator is kept FULLY NORMALIZED at every
        # step — rescale the old value by l*alpha/l_new and the new
        # contribution by 1/l_new.  This is the extra non-matmul work FA2
        # deletes (one multiply + one divide over Bq x d per iteration).
        l_new_safe = jnp.where(l_new == 0.0, 1.0, l_new)
        o_scaled = (
            o_scaled * (l * alpha / l_new_safe)[:, None]
            + jnp.dot(p, v_blk, preferred_element_type=jnp.float32)
            / l_new_safe[:, None]
        )
        return o_scaled, m_new, l_new

    o0 = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    o_scaled, m, l = lax.fori_loop(0, hi, body, (o0, m0, l0))

    o_ref[...] = o_scaled.astype(o_ref.dtype)
    # FA1 stores BOTH statistics (2N floats of HBM traffic vs FA2's N).
    m_ref[...] = jnp.where(jnp.isfinite(m), m, 0.0)
    l_ref[...] = l


def flash1_fwd(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    scale: float | None = None,
    block_sizes: BlockSizes = BlockSizes(),
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """FlashAttention-1-style forward. Returns ``(O, m, l)``."""
    b, hq, n_q, d = q.shape
    _, hk, n_k, _ = k.shape
    if causal and n_q != n_k:
        raise ValueError("causal kernel requires square attention")
    group = hq // hk
    if scale is None:
        scale = 1.0 / math.sqrt(d)

    bq = min(block_sizes.block_q, n_q)
    bk = min(block_sizes.block_k, n_k)
    qp = _pad_seq(q, 2, bq)
    kp = _pad_seq(k, 2, bk)
    vp = _pad_seq(v, 2, bk)
    n_q_pad, n_k_pad = qp.shape[2], kp.shape[2]

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, block_k=bk, n_k=n_k
    )
    o, m, l = pl.pallas_call(
        kernel,
        grid=(b, hq, n_q_pad // bq),
        in_specs=[
            pl.BlockSpec((None, None, bq, d), lambda b_, h, i: (b_, h, i, 0)),
            pl.BlockSpec(
                (None, None, n_k_pad, d), lambda b_, h, i: (b_, h // group, 0, 0)
            ),
            pl.BlockSpec(
                (None, None, n_k_pad, d), lambda b_, h, i: (b_, h // group, 0, 0)
            ),
        ],
        out_specs=[
            pl.BlockSpec((None, None, bq, d), lambda b_, h, i: (b_, h, i, 0)),
            pl.BlockSpec((None, None, bq), lambda b_, h, i: (b_, h, i)),
            pl.BlockSpec((None, None, bq), lambda b_, h, i: (b_, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, n_q_pad, d), q.dtype),
            jax.ShapeDtypeStruct((b, hq, n_q_pad), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, n_q_pad), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return o[:, :, :n_q], m[:, :, :n_q], l[:, :, :n_q]
