"""Split-K attention forward — the work-partitioning ablation (paper §3.3).

FlashAttention-1 splits **K/V across warps** ("split-K"): every warp computes
a partial, differently-normalized output for the *same* rows, and the partials
must be exchanged through shared memory and combined.  FlashAttention-2 splits
**Q across warps** so each warp owns its rows outright (no exchange) — that is
what ``flash2.py`` does at grid level.

This module implements the split-K scheme in Pallas so the cost of the
exchange is real and measurable on our substrate:

* ``splitk_fwd_partial`` grids over ``(batch, head, Q-block, KV-chunk)``;
  each cell produces an *unscaled* partial output plus its local softmax
  statistics ``(O~, m, l)`` — the analogue of a warp's private accumulator.
* ``combine_partials`` is the "shared-memory exchange": a second pass that
  merges the per-chunk partials with the online-softmax algebra
  ``O = (sum_s e^{m_s - m} O~_s) / (sum_s e^{m_s - m} l_s)``.

The combine algebra is associative and commutative — the Rust `gpusim`
substrate property-tests the same merge operator (mirrored in
``rust/src/attn/combine.rs``).  This is also exactly the flash-decoding
decomposition, so the serving example reuses it for long-context decode.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .flash2 import BlockSizes, NEG_INF, _pad_seq

__all__ = ["splitk_fwd_partial", "combine_partials", "splitk_fwd"]


def _partial_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *, scale, causal, block_k, n_k, kv_chunk):
    """One (Q-block, KV-chunk) cell: local online softmax over the chunk."""
    block_q, d = q_ref.shape
    i = pl.program_id(2)  # Q block
    s_idx = pl.program_id(3)  # KV chunk ("warp")
    chunk_blocks = kv_chunk // block_k

    q = q_ref[...].astype(jnp.float32) * scale

    def body(jj, carry):
        o_acc, m, l = carry
        j = s_idx * chunk_blocks + jj  # global KV block index
        k_blk = k_ref[pl.ds(jj * block_k, block_k), :].astype(jnp.float32)
        v_blk = v_ref[pl.ds(jj * block_k, block_k), :].astype(jnp.float32)
        s = jnp.dot(q, k_blk.T, preferred_element_type=jnp.float32)

        rows = i * block_q + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = j * block_k + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        if causal:
            keep = jnp.logical_and(cols <= rows, cols < n_k)
        else:
            keep = cols < n_k
        s = jnp.where(keep, s, NEG_INF)

        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(
            jnp.isfinite(s), jnp.exp(s - m_safe[:, None]), 0.0
        )
        alpha = jnp.where(
            jnp.isfinite(m), jnp.exp(m - m_safe), 0.0
        )
        l_new = alpha * l + jnp.sum(p, axis=-1)
        o_acc = o_acc * alpha[:, None] + jnp.dot(
            p, v_blk, preferred_element_type=jnp.float32
        )
        return o_acc, m_new, l_new

    o0 = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    o_acc, m, l = lax.fori_loop(0, chunk_blocks, body, (o0, m0, l0))

    # Unscaled partials written out — this extra O(B*H*N*d*n_split) traffic is
    # the split-K exchange cost FA2 eliminates.
    o_ref[...] = o_acc
    m_ref[...] = m
    l_ref[...] = l


def splitk_fwd_partial(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    n_split: int,
    causal: bool = False,
    scale: float | None = None,
    block_sizes: BlockSizes = BlockSizes(),
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Compute per-chunk partials ``(O~, m, l)``.

    Returns arrays with a leading split axis: ``O~ (S,B,H,Nq,d)``,
    ``m, l (S,B,H,Nq)``.
    """
    b, hq, n_q, d = q.shape
    _, hk, n_k, _ = k.shape
    group = hq // hk
    if causal and n_q != n_k:
        raise ValueError("causal kernel requires square attention")
    if scale is None:
        scale = 1.0 / math.sqrt(d)

    bq = min(block_sizes.block_q, n_q)
    bk = min(block_sizes.block_k, n_k)
    qp = _pad_seq(q, 2, bq)
    kp = _pad_seq(k, 2, bk)
    vp = _pad_seq(v, 2, bk)
    n_q_pad, n_k_pad = qp.shape[2], kp.shape[2]

    # KV chunk per split, in whole blocks; pad KV so chunks divide evenly.
    blocks_total = n_k_pad // bk
    chunk_blocks = -(-blocks_total // n_split)
    kv_chunk = chunk_blocks * bk
    kp = _pad_seq(kp, 2, kv_chunk * n_split)
    vp = _pad_seq(vp, 2, kv_chunk * n_split)

    kernel = functools.partial(
        _partial_kernel, scale=scale, causal=causal, block_k=bk, n_k=n_k,
        kv_chunk=kv_chunk,
    )
    o, m, l = pl.pallas_call(
        kernel,
        grid=(b, hq, n_q_pad // bq, n_split),
        in_specs=[
            pl.BlockSpec((None, None, bq, d), lambda b_, h, i, s: (b_, h, i, 0)),
            pl.BlockSpec(
                (None, None, kv_chunk, d),
                lambda b_, h, i, s: (b_, h // group, s, 0),
            ),
            pl.BlockSpec(
                (None, None, kv_chunk, d),
                lambda b_, h, i, s: (b_, h // group, s, 0),
            ),
        ],
        out_specs=[
            pl.BlockSpec(
                (None, None, None, bq, d), lambda b_, h, i, s: (s, b_, h, i, 0)
            ),
            pl.BlockSpec(
                (None, None, None, bq), lambda b_, h, i, s: (s, b_, h, i)
            ),
            pl.BlockSpec(
                (None, None, None, bq), lambda b_, h, i, s: (s, b_, h, i)
            ),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_split, b, hq, n_q_pad, d), jnp.float32),
            jax.ShapeDtypeStruct((n_split, b, hq, n_q_pad), jnp.float32),
            jax.ShapeDtypeStruct((n_split, b, hq, n_q_pad), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return o[:, :, :, :n_q], m[:, :, :, :n_q], l[:, :, :, :n_q]


def combine_partials(
    o_parts: jax.Array, m_parts: jax.Array, l_parts: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Merge split-K partials: the "shared-memory exchange" pass.

    ``O = (sum_s e^{m_s - m} O~_s) / (sum_s e^{m_s - m} l_s)``,
    ``L = m + log(sum_s e^{m_s - m} l_s)``.
    """
    m = jnp.max(m_parts, axis=0)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    w = jnp.where(
        jnp.isfinite(m_parts), jnp.exp(m_parts - m_safe[None]), 0.0
    )
    l = jnp.sum(w * l_parts, axis=0)
    l_safe = jnp.where(l == 0.0, 1.0, l)
    o = jnp.sum(w[..., None] * o_parts, axis=0) / l_safe[..., None]
    lse = m_safe + jnp.log(l_safe)
    return o, lse


def splitk_fwd(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    n_split: int = 4,
    causal: bool = False,
    scale: float | None = None,
    block_sizes: BlockSizes = BlockSizes(),
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Full split-K forward: partials + combine. Returns ``(O, L)``."""
    o_p, m_p, l_p = splitk_fwd_partial(
        q, k, v, n_split=n_split, causal=causal, scale=scale,
        block_sizes=block_sizes, interpret=interpret,
    )
    o, lse = combine_partials(o_p, m_p, l_p)
    return o.astype(q.dtype), lse
