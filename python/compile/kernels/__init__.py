"""FlashAttention-2 Pallas kernels (L1) and their pure-jnp oracle."""

from .ref import attention_ref, attention_ref_bwd, attention_ref_vjp, expand_kv_heads
from .flash2 import BlockSizes, flash2_fwd, flash2_bwd, flash_attention
from .flash1 import flash1_fwd
from .splitk import splitk_fwd, splitk_fwd_partial, combine_partials

__all__ = [
    "attention_ref", "attention_ref_bwd", "attention_ref_vjp", "expand_kv_heads",
    "BlockSizes", "flash2_fwd", "flash2_bwd", "flash_attention",
    "flash1_fwd", "splitk_fwd", "splitk_fwd_partial", "combine_partials",
]
