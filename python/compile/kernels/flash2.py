"""FlashAttention-2 forward and backward Pallas kernels (paper Alg. 1 & 2).

Hardware adaptation (see DESIGN.md section "Hardware adaptation"): the paper's
CUDA concepts map onto Pallas as

* thread-block tile      -> ``pl.BlockSpec`` (the HBM<->VMEM schedule),
* grid over (batch, head, Q-block) -> the paper's *sequence-length
  parallelism* (section 3.2): every Q row-block is an independent grid cell,
* split-Q warp layout    -> each grid cell owns its output row-block outright
  and never exchanges partial sums (the analogue of avoiding "split-K";
  the split-K ablation lives in ``splitk.py``),
* tensor-core MXU        -> ``jnp.dot(..., preferred_element_type=f32)``.

Paper-faithful algorithmic details implemented here:

* **Deferred rescale** (section 3.1.1 tweak #1): the output accumulator is kept
  *unscaled*; ``diag(l)^-1`` is applied once after the KV loop, not per
  iteration (``flash1.py`` implements the per-iteration variant for the
  non-matmul-FLOPs ablation).
* **Logsumexp only** (tweak #2): the forward stores a single statistic
  ``L = m + log(l)`` per row; the backward recomputes ``P = exp(S - L)``.
* **Causal block skipping** (section 3.1.1 "Causal masking"): for causal
  attention the KV loop of row-block ``i`` runs only to
  ``ceil((i+1)*Bq / Bk)`` — blocks entirely above the diagonal are never
  computed (the ~1.7-1.8x claimed speedup), and the elementwise mask is
  applied *only* to blocks that straddle the diagonal (``lax.cond``).
* **Backward parallelism** (section 3.2): dK/dV are computed by a kernel
  gridded over KV column-blocks (each grid cell owns one dK_j/dV_j block);
  dQ is computed by a second kernel gridded over Q row-blocks.  CUDA FA2
  updates dQ with atomic adds across thread blocks; Pallas has no cross-cell
  atomics, so the dQ reduction is restructured as an independent row-parallel
  kernel — same arithmetic, same parallel width, no data races by
  construction.
* **GQA/MQA** (section 3.1.2): KV head indices are manipulated in the
  BlockSpec ``index_map`` (no duplication of K/V in memory); backward sums
  dK/dV over the query heads sharing a KV head.

All kernels run under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls) and lower to plain HLO, which is what ``aot.py`` exports for the
Rust runtime.
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = [
    "BlockSizes",
    "flash2_fwd",
    "flash2_bwd",
    "flash_attention",
    "DEFAULT_BLOCK_Q",
    "DEFAULT_BLOCK_K",
]

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128

NEG_INF = float("-inf")


class BlockSizes(NamedTuple):
    """Tile sizes: the Pallas analogue of the paper's {64,128}x{64,128} sweep."""

    block_q: int = DEFAULT_BLOCK_Q
    block_k: int = DEFAULT_BLOCK_K


def _pad_len(n: int, b: int) -> int:
    return (b - n % b) % b


def _pad_seq(x: jax.Array, axis: int, block: int, value: float = 0.0) -> jax.Array:
    pad = _pad_len(x.shape[axis], block)
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------
# Forward kernel (Algorithm 1)
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, scale, causal, block_k, n_k):
    """One grid cell = one (batch, head, Q row-block): Alg. 1 lines 4-15."""
    block_q, d = q_ref.shape
    i = pl.program_id(2)  # Q row-block index (seqlen parallelism)
    n_k_pad = k_ref.shape[0]
    num_kv_blocks = n_k_pad // block_k

    q = q_ref[...].astype(jnp.float32) * scale

    # Causal block skipping: only KV blocks with any column <= the last row
    # of this Q block are visited.  hi is dynamic (depends on program_id) —
    # this *is* the paper's "skip ~half the blocks".
    if causal:
        hi = lax.min(
            lax.div((i + 1) * block_q + block_k - 1, block_k), num_kv_blocks
        )
    else:
        hi = num_kv_blocks

    def body(j, carry):
        o_acc, m, l = carry
        k_blk = k_ref[pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        v_blk = v_ref[pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        s = jnp.dot(q, k_blk.T, preferred_element_type=jnp.float32)  # (Bq, Bk)

        # Elementwise mask is applied only when this block straddles the
        # causal diagonal or contains the padded KV tail (tweak: non-diagonal
        # blocks skip the mask entirely).
        rows = i * block_q + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = j * block_k + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        needs_tail = (j + 1) * block_k > n_k
        if causal:
            needs_diag = (j + 1) * block_k - 1 > i * block_q
            needs_mask = jnp.logical_or(needs_diag, needs_tail)
            keep = jnp.logical_and(cols <= rows, cols < n_k)
        else:
            needs_mask = needs_tail
            keep = cols < n_k
        s = lax.cond(
            needs_mask,
            lambda s_: jnp.where(keep, s_, NEG_INF),
            lambda s_: s_,
            s,
        )

        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])  # masked entries: exp(-inf)=0
        alpha = jnp.exp(m - m_new)  # exp(-inf - m_new) = 0 on first visit
        l_new = alpha * l + jnp.sum(p, axis=-1)
        # Deferred rescale: accumulator stays UNSCALED (no diag(l)^-1 here).
        o_acc = o_acc * alpha[:, None] + jnp.dot(
            p, v_blk, preferred_element_type=jnp.float32
        )
        return o_acc, m_new, l_new

    o_acc = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    o_acc, m, l = lax.fori_loop(0, hi, body, (o_acc, m0, l0))

    # Single final rescale (Alg. 1 line 12) + logsumexp (line 13).
    l_safe = jnp.where(l == 0.0, 1.0, l)
    o_ref[...] = (o_acc / l_safe[:, None]).astype(o_ref.dtype)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    lse_ref[...] = (m_safe + jnp.log(l_safe)).astype(jnp.float32)


def flash2_fwd(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    scale: float | None = None,
    block_sizes: BlockSizes = BlockSizes(),
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """FlashAttention-2 forward pass (paper Algorithm 1).

    Args/shape conventions match :func:`..kernels.ref.attention_ref`; returns
    ``(O, L)`` with ``L`` the row-wise logsumexp in f32.
    """
    b, hq, n_q, d = q.shape
    _, hk, n_k, _ = k.shape
    if causal and n_q != n_k:
        raise ValueError("causal kernel requires square attention (n_q == n_k)")
    if hq % hk != 0:
        raise ValueError(f"q heads {hq} not a multiple of kv heads {hk}")
    group = hq // hk
    if scale is None:
        scale = 1.0 / math.sqrt(d)

    bq = min(block_sizes.block_q, n_q)
    bk = min(block_sizes.block_k, n_k)
    qp = _pad_seq(q, 2, bq)
    kp = _pad_seq(k, 2, bk)
    vp = _pad_seq(v, 2, bk)
    n_q_pad, n_k_pad = qp.shape[2], kp.shape[2]
    grid = (b, hq, n_q_pad // bq)

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, block_k=bk, n_k=n_k
    )
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, bq, d), lambda b_, h, i: (b_, h, i, 0)),
            # GQA: the KV head index is derived from the Q head index here,
            # in the index_map — K/V are never duplicated in memory.
            pl.BlockSpec(
                (None, None, n_k_pad, d), lambda b_, h, i: (b_, h // group, 0, 0)
            ),
            pl.BlockSpec(
                (None, None, n_k_pad, d), lambda b_, h, i: (b_, h // group, 0, 0)
            ),
        ],
        out_specs=[
            pl.BlockSpec((None, None, bq, d), lambda b_, h, i: (b_, h, i, 0)),
            pl.BlockSpec((None, None, bq), lambda b_, h, i: (b_, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, n_q_pad, d), q.dtype),
            jax.ShapeDtypeStruct((b, hq, n_q_pad), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return o[:, :, :n_q], lse[:, :, :n_q]


# ---------------------------------------------------------------------------
# Backward kernels (Algorithm 2)
# ---------------------------------------------------------------------------


def _precompute_d_kernel(o_ref, do_ref, d_ref):
    """Alg. 2 line 4: D = rowsum(dO o O), written to HBM once per row."""
    o = o_ref[...].astype(jnp.float32)
    do = do_ref[...].astype(jnp.float32)
    d_ref[...] = jnp.sum(o * do, axis=-1)


def _bwd_dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, d_ref, dk_ref, dv_ref,
    *, scale, causal, block_q, n_q, n_k,
):
    """One grid cell = one KV column-block: Alg. 2 lines 6-18 (dK_j, dV_j).

    This is the paper's backward seqlen-parallelism: column blocks are
    independent workers (Fig. 2 right).
    """
    block_k, d = k_ref.shape
    j = pl.program_id(2)
    n_q_pad = q_ref.shape[0]
    num_q_blocks = n_q_pad // block_q

    k_blk = k_ref[...].astype(jnp.float32)
    v_blk = v_ref[...].astype(jnp.float32)

    # Causal block skipping, transposed: rows strictly above this column
    # block's start can be skipped (their P entries are all zero).
    if causal:
        lo = lax.div(j * block_k, block_q)
    else:
        lo = 0

    def body(i, carry):
        dk_acc, dv_acc = carry
        q_blk = q_ref[pl.ds(i * block_q, block_q), :].astype(jnp.float32)
        do_blk = do_ref[pl.ds(i * block_q, block_q), :].astype(jnp.float32)
        lse_blk = lse_ref[pl.ds(i * block_q, block_q)]
        d_blk = d_ref[pl.ds(i * block_q, block_q)]

        s = jnp.dot(q_blk, k_blk.T, preferred_element_type=jnp.float32) * scale
        p = jnp.exp(s - lse_blk[:, None])  # recompute P from L (no P stored)

        rows = i * block_q + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = j * block_k + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        needs_tail = jnp.logical_or((i + 1) * block_q > n_q, (j + 1) * block_k > n_k)
        if causal:
            needs_diag = (j + 1) * block_k - 1 > i * block_q
            needs_mask = jnp.logical_or(needs_diag, needs_tail)
            keep = jnp.logical_and(
                cols <= rows, jnp.logical_and(rows < n_q, cols < n_k)
            )
        else:
            needs_mask = needs_tail
            keep = jnp.logical_and(rows < n_q, cols < n_k)
        p = lax.cond(
            needs_mask,
            lambda p_: jnp.where(keep, p_, 0.0),
            lambda p_: p_,
            p,
        )

        dv_acc = dv_acc + jnp.dot(p.T, do_blk, preferred_element_type=jnp.float32)
        dp = jnp.dot(do_blk, v_blk.T, preferred_element_type=jnp.float32)
        ds = p * (dp - d_blk[:, None]) * scale
        dk_acc = dk_acc + jnp.dot(ds.T, q_blk, preferred_element_type=jnp.float32)
        return dk_acc, dv_acc

    dk0 = jnp.zeros((block_k, d), jnp.float32)
    dv0 = jnp.zeros((block_k, d), jnp.float32)
    dk, dv = lax.fori_loop(lo, num_q_blocks, body, (dk0, dv0))
    dk_ref[...] = dk
    dv_ref[...] = dv


def _bwd_dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, d_ref, dq_ref,
    *, scale, causal, block_k, n_k,
):
    """One grid cell = one Q row-block: the dQ half of Alg. 2.

    CUDA FA2 accumulates dQ_i across column-block workers with atomic adds;
    here dQ_i is owned by a single grid cell that loops over KV blocks —
    identical arithmetic, no atomics (Pallas/TPU adaptation).
    """
    block_q, d = q_ref.shape
    i = pl.program_id(2)
    n_k_pad = k_ref.shape[0]
    num_kv_blocks = n_k_pad // block_k

    q_blk = q_ref[...].astype(jnp.float32)
    do_blk = do_ref[...].astype(jnp.float32)
    lse_blk = lse_ref[...]
    d_blk = d_ref[...]

    if causal:
        hi = lax.min(
            lax.div((i + 1) * block_q + block_k - 1, block_k), num_kv_blocks
        )
    else:
        hi = num_kv_blocks

    def body(j, dq_acc):
        k_blk = k_ref[pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        v_blk = v_ref[pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        s = jnp.dot(q_blk, k_blk.T, preferred_element_type=jnp.float32) * scale
        p = jnp.exp(s - lse_blk[:, None])

        rows = i * block_q + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = j * block_k + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        needs_tail = (j + 1) * block_k > n_k
        if causal:
            needs_diag = (j + 1) * block_k - 1 > i * block_q
            needs_mask = jnp.logical_or(needs_diag, needs_tail)
            keep = jnp.logical_and(cols <= rows, cols < n_k)
        else:
            needs_mask = needs_tail
            keep = cols < n_k
        p = lax.cond(
            needs_mask,
            lambda p_: jnp.where(keep, p_, 0.0),
            lambda p_: p_,
            p,
        )

        dp = jnp.dot(do_blk, v_blk.T, preferred_element_type=jnp.float32)
        ds = p * (dp - d_blk[:, None]) * scale
        return dq_acc + jnp.dot(ds, k_blk, preferred_element_type=jnp.float32)

    dq = lax.fori_loop(0, hi, body, jnp.zeros((block_q, d), jnp.float32))
    dq_ref[...] = dq


def flash2_bwd(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    o: jax.Array,
    lse: jax.Array,
    do: jax.Array,
    *,
    causal: bool = False,
    scale: float | None = None,
    block_sizes: BlockSizes = BlockSizes(),
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """FlashAttention-2 backward pass (paper Algorithm 2)."""
    b, hq, n_q, d = q.shape
    _, hk, n_k, _ = k.shape
    group = hq // hk
    if scale is None:
        scale = 1.0 / math.sqrt(d)

    bq = min(block_sizes.block_q, n_q)
    bk = min(block_sizes.block_k, n_k)
    qp = _pad_seq(q, 2, bq)
    op = _pad_seq(o, 2, bq)
    dop = _pad_seq(do, 2, bq)
    # Padded rows get lse=+inf so their recomputed P is exactly zero and they
    # contribute nothing to dK/dV.
    lsep = _pad_seq(lse, 2, bq, value=float("inf"))
    kp = _pad_seq(k, 2, bk)
    vp = _pad_seq(v, 2, bk)
    n_q_pad, n_k_pad = qp.shape[2], kp.shape[2]

    # --- D = rowsum(dO o O) (Alg. 2 line 4), its own tiny kernel/grid ---
    d_vec = pl.pallas_call(
        _precompute_d_kernel,
        grid=(b, hq, n_q_pad // bq),
        in_specs=[
            pl.BlockSpec((None, None, bq, d), lambda b_, h, i: (b_, h, i, 0)),
            pl.BlockSpec((None, None, bq, d), lambda b_, h, i: (b_, h, i, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, bq), lambda b_, h, i: (b_, h, i)),
        out_shape=jax.ShapeDtypeStruct((b, hq, n_q_pad), jnp.float32),
        interpret=interpret,
    )(op, dop)

    # --- dK/dV: grid over KV column blocks (Fig. 2 right) ---
    dkv_kernel = functools.partial(
        _bwd_dkv_kernel, scale=scale, causal=causal, block_q=bq, n_q=n_q, n_k=n_k
    )
    dk_per_qhead, dv_per_qhead = pl.pallas_call(
        dkv_kernel,
        grid=(b, hq, n_k_pad // bk),
        in_specs=[
            pl.BlockSpec((None, None, n_q_pad, d), lambda b_, h, j: (b_, h, 0, 0)),
            pl.BlockSpec(
                (None, None, bk, d), lambda b_, h, j: (b_, h // group, j, 0)
            ),
            pl.BlockSpec(
                (None, None, bk, d), lambda b_, h, j: (b_, h // group, j, 0)
            ),
            pl.BlockSpec((None, None, n_q_pad, d), lambda b_, h, j: (b_, h, 0, 0)),
            pl.BlockSpec((None, None, n_q_pad), lambda b_, h, j: (b_, h, 0)),
            pl.BlockSpec((None, None, n_q_pad), lambda b_, h, j: (b_, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, bk, d), lambda b_, h, j: (b_, h, j, 0)),
            pl.BlockSpec((None, None, bk, d), lambda b_, h, j: (b_, h, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, n_k_pad, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, n_k_pad, d), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp, dop, lsep, d_vec)

    # GQA: sum dK/dV over the query heads that share each KV head.
    if group > 1:
        dk = dk_per_qhead.reshape(b, hk, group, n_k_pad, d).sum(axis=2)
        dv = dv_per_qhead.reshape(b, hk, group, n_k_pad, d).sum(axis=2)
    else:
        dk, dv = dk_per_qhead, dv_per_qhead

    # --- dQ: grid over Q row blocks (atomic-free restructuring) ---
    dq_kernel = functools.partial(
        _bwd_dq_kernel, scale=scale, causal=causal, block_k=bk, n_k=n_k
    )
    dq = pl.pallas_call(
        dq_kernel,
        grid=(b, hq, n_q_pad // bq),
        in_specs=[
            pl.BlockSpec((None, None, bq, d), lambda b_, h, i: (b_, h, i, 0)),
            pl.BlockSpec(
                (None, None, n_k_pad, d), lambda b_, h, i: (b_, h // group, 0, 0)
            ),
            pl.BlockSpec(
                (None, None, n_k_pad, d), lambda b_, h, i: (b_, h // group, 0, 0)
            ),
            pl.BlockSpec((None, None, bq, d), lambda b_, h, i: (b_, h, i, 0)),
            pl.BlockSpec((None, None, bq), lambda b_, h, i: (b_, h, i)),
            pl.BlockSpec((None, None, bq), lambda b_, h, i: (b_, h, i)),
        ],
        out_specs=pl.BlockSpec((None, None, bq, d), lambda b_, h, i: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, n_q_pad, d), jnp.float32),
        interpret=interpret,
    )(qp, kp, vp, dop, lsep, d_vec)

    return (
        dq[:, :, :n_q].astype(q.dtype),
        dk[:, :, :n_k].astype(k.dtype),
        dv[:, :, :n_k].astype(v.dtype),
    )


# ---------------------------------------------------------------------------
# custom_vjp wrapper: what the L2 model calls
# ---------------------------------------------------------------------------


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6)
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = False,
    scale: float | None = None,
    block_sizes: BlockSizes = BlockSizes(),
    interpret: bool = True,
) -> jax.Array:
    """Differentiable FlashAttention-2: fwd = Alg. 1, bwd = Alg. 2."""
    o, _ = flash2_fwd(
        q, k, v, causal=causal, scale=scale, block_sizes=block_sizes,
        interpret=interpret,
    )
    return o


def _fa_fwd(q, k, v, causal, scale, block_sizes, interpret):
    o, lse = flash2_fwd(
        q, k, v, causal=causal, scale=scale, block_sizes=block_sizes,
        interpret=interpret,
    )
    # Residuals: Q,K,V,O and the single logsumexp vector — exactly what the
    # paper stores (O(N) extra memory, section 3.1.1).
    return o, (q, k, v, o, lse)


def _fa_bwd(causal, scale, block_sizes, interpret, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = flash2_bwd(
        q, k, v, o, lse, do, causal=causal, scale=scale,
        block_sizes=block_sizes, interpret=interpret,
    )
    return dq, dk, dv


flash_attention.defvjp(_fa_fwd, _fa_bwd)
