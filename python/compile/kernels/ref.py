"""Pure-jnp correctness oracle for FlashAttention-2.

This module is the ground truth every Pallas kernel is tested against:

* :func:`attention_ref`        -- numerically-stable standard attention fwd,
                                  returning both the output ``O`` and the
                                  row-wise logsumexp ``L`` (the only softmax
                                  statistic FlashAttention-2 stores, paper
                                  section 3.1.1 tweak #2).
* :func:`attention_ref_bwd`    -- hand-derived backward pass following the
                                  chain rule in paper section 2.2, written
                                  with the same ``D = rowsum(dO o O)``
                                  simplification Algorithm 2 uses.
* :func:`attention_ref_vjp`    -- jax.vjp-based gradients, used as a second,
                                  independent oracle for the hand-derived
                                  backward.

All functions operate on ``(batch, heads, seqlen, head_dim)`` arrays and
support causal masking and grouped-query attention (KV heads fewer than Q
heads, paper section 3.1.2 "Multi-query attention and grouped-query
attention").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "attention_ref",
    "attention_ref_bwd",
    "attention_ref_vjp",
    "expand_kv_heads",
]


def expand_kv_heads(kv: jax.Array, n_q_heads: int) -> jax.Array:
    """Explicitly duplicate KV heads so K/V match the query head count.

    The paper implements GQA/MQA by *implicitly* manipulating head indices;
    the oracle does the explicit duplication instead (same math, simpler to
    audit).  ``n_q_heads`` must be a multiple of the KV head count.
    """
    n_kv = kv.shape[1]
    if n_kv == n_q_heads:
        return kv
    if n_q_heads % n_kv != 0:
        raise ValueError(f"q heads {n_q_heads} not a multiple of kv heads {n_kv}")
    reps = n_q_heads // n_kv
    return jnp.repeat(kv, reps, axis=1)


def _causal_mask(n_q: int, n_k: int, dtype) -> jax.Array:
    """Additive causal mask: 0 where j <= i, -inf where j > i.

    Supports rectangular S (n_q != n_k) by right-aligning the query block,
    matching the convention used for KV-cache decoding (query position i
    corresponds to absolute position n_k - n_q + i).
    """
    offset = n_k - n_q
    rows = jnp.arange(n_q)[:, None] + offset
    cols = jnp.arange(n_k)[None, :]
    return jnp.where(cols <= rows, 0.0, -jnp.inf).astype(dtype)


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    scale: float | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Standard attention with a numerically stable softmax.

    Args:
      q: ``(B, Hq, Nq, D)`` queries.
      k: ``(B, Hk, Nk, D)`` keys   (``Hk`` divides ``Hq`` for GQA/MQA).
      v: ``(B, Hk, Nk, D)`` values.
      causal: apply the autoregressive mask (entries with j > i set to -inf).
      scale: softmax temperature; defaults to ``1/sqrt(D)``.

    Returns:
      ``(O, L)`` where ``O`` is ``(B, Hq, Nq, D)`` and ``L`` is the row-wise
      logsumexp ``(B, Hq, Nq)`` of the *scaled, masked* scores -- exactly the
      statistic FlashAttention-2's backward pass consumes.
    """
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    hq = q.shape[1]
    k = expand_kv_heads(k, hq)
    v = expand_kv_heads(v, hq)

    # All softmax statistics in f32 regardless of input dtype (the kernels
    # accumulate in f32 on the MXU the same way).
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    if causal:
        s = s + _causal_mask(q.shape[2], k.shape[2], s.dtype)[None, None]
    m = jnp.max(s, axis=-1, keepdims=True)
    # Guard fully-masked rows (can only happen with empty KV): exp(-inf - -inf).
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe)
    ell = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    o = o / ell
    lse = (m_safe + jnp.log(ell))[..., 0]
    return o.astype(q.dtype), lse


def attention_ref_bwd(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    o: jax.Array,
    lse: jax.Array,
    do: jax.Array,
    *,
    causal: bool = False,
    scale: float | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Hand-derived attention backward using only the logsumexp statistic.

    Implements the math of Algorithm 2 without tiling:

      P  = exp(S - L)                (recomputed, not stored)
      dV = P^T dO
      dP = dO V^T
      D  = rowsum(dO o O)
      dS = P o (dP - D)
      dQ = dS K * scale
      dK = dS^T Q * scale

    For GQA the dK/dV of implicitly-duplicated heads are summed back into
    the shared KV head (paper section 3.1.2).
    """
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    hq, hk = q.shape[1], k.shape[1]
    kx = expand_kv_heads(k, hq)
    vx = expand_kv_heads(v, hq)

    s = jnp.einsum("bhqd,bhkd->bhqk", q, kx, preferred_element_type=jnp.float32)
    s = s * scale
    if causal:
        s = s + _causal_mask(q.shape[2], kx.shape[2], s.dtype)[None, None]
    p = jnp.exp(s - lse[..., None])  # (B,Hq,Nq,Nk); rows of P sum to 1

    do32 = do.astype(jnp.float32)
    o32 = o.astype(jnp.float32)
    dv = jnp.einsum("bhqk,bhqd->bhkd", p, do32)
    dp = jnp.einsum("bhqd,bhkd->bhqk", do32, vx.astype(jnp.float32))
    d_vec = jnp.sum(do32 * o32, axis=-1, keepdims=True)  # D = rowsum(dO o O)
    ds = p * (dp - d_vec)
    dq = jnp.einsum("bhqk,bhkd->bhqd", ds, kx.astype(jnp.float32)) * scale
    dk = jnp.einsum("bhqk,bhqd->bhkd", ds, q.astype(jnp.float32)) * scale

    if hk != hq:
        reps = hq // hk
        dk = dk.reshape(dk.shape[0], hk, reps, *dk.shape[2:]).sum(axis=2)
        dv = dv.reshape(dv.shape[0], hk, reps, *dv.shape[2:]).sum(axis=2)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def attention_ref_vjp(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    do: jax.Array,
    *,
    causal: bool = False,
    scale: float | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Independent oracle: gradients via jax autodiff of the reference fwd."""

    def f(q_, k_, v_):
        return attention_ref(q_, k_, v_, causal=causal, scale=scale)[0]

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(do)
