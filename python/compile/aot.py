"""AOT pipeline: lower every model/kernel entry point to HLO *text* plus a
JSON manifest + FAT1 golden test vectors, all consumed by the Rust runtime.

HLO text (NOT ``lowered.compiler_ir().serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which
xla_extension 0.5.1 (what the published ``xla`` rust crate links) rejects;
the text parser reassigns ids and round-trips cleanly.

Usage:
  python -m compile.aot --out-dir ../artifacts [--profile full|test]

Artifact inventory (profile=full):
  attn_fa2_{causal|full}_b{B}h{H}n{N}d{D}     FA2 fwd (Alg 1)   -> (O, L)
  attn_fa2grad_{...}                          FA2 fwd+bwd       -> (O,dQ,dK,dV)
  attn_std_{...}                              standard attention baseline
  attn_splitk{S}_{...}                        split-K ablation
  {model}_init                                seed -> initial params (flat)
  {model}_train_step                          params+opt+tokens -> updated
  {model}_prefill_b{B}                        params+tokens -> logits+cache
  {model}_decode_b{B}                         params+cache+token+pos -> logits
Every artifact gets input/output specs in manifest.json; most get a FAT1
golden file with concrete inputs/outputs for the rust integration tests.
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import BlockSizes, attention_ref, flash2_fwd, flash_attention, splitk_fwd
from .tensorio import write_tensors

# ---------------------------------------------------------------------------
# Model registry (mirrored by configs/*.toml on the rust side)
# ---------------------------------------------------------------------------

MODELS: dict[str, M.GPTConfig] = {
    "tiny": M.GPTConfig(
        vocab_size=512, n_layer=2, n_head=4, n_kv_head=4, d_model=64,
        max_seq=64, block_q=32, block_k=32,
    ),
    # ~13.7M params: the e2e CPU training target (single core).
    "small": M.GPTConfig(
        vocab_size=8192, n_layer=6, n_head=6, n_kv_head=6, d_model=384,
        max_seq=128, block_q=64, block_k=64,
    ),
}
TRAIN_BATCH = {"tiny": 4, "small": 4}
ADAM = M.AdamConfig(lr=1e-3)


# ---------------------------------------------------------------------------
# Lowering helpers
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


_DTYPE_NAMES = {
    np.dtype(np.float32): "f32",
    np.dtype(np.int32): "i32",
    np.dtype(np.uint32): "u32",
    np.dtype(np.float64): "f64",
    np.dtype(np.int64): "i64",
}


def _spec(name: str, x) -> dict:
    return {
        "name": name,
        "shape": list(np.shape(x)),
        "dtype": _DTYPE_NAMES[np.dtype(x.dtype)],
    }


class Exporter:
    """Accumulates artifacts + manifest in an output directory."""

    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries: list[dict] = []
        os.makedirs(out_dir, exist_ok=True)

    def add(
        self,
        name: str,
        fn,
        example_inputs: list[tuple[str, np.ndarray]],
        *,
        kind: str,
        meta: dict | None = None,
        golden: bool = True,
        donate_argnums: tuple = (),
    ) -> None:
        """Lower fn(*inputs) -> tuple of outputs; write hlo + golden + entry."""
        args = [jnp.asarray(v) for _, v in example_inputs]
        jitted = jax.jit(fn, donate_argnums=donate_argnums)
        lowered = jitted.lower(*args)
        hlo = to_hlo_text(lowered)
        hlo_file = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, hlo_file), "w") as f:
            f.write(hlo)

        outputs = fn(*args)
        if not isinstance(outputs, (tuple, list)):
            outputs = (outputs,)
        out_specs = [_spec(f"out{i}", o) for i, o in enumerate(outputs)]

        golden_file = None
        if golden:
            golden_file = f"{name}.golden.fat1"
            tensors = {f"in{i}": np.asarray(v) for i, (_, v) in enumerate(example_inputs)}
            tensors.update({f"out{i}": np.asarray(o) for i, o in enumerate(outputs)})
            write_tensors(os.path.join(self.out_dir, golden_file), tensors)

        self.entries.append(
            {
                "name": name,
                "kind": kind,
                "hlo": hlo_file,
                "golden": golden_file,
                "inputs": [
                    {**_spec(n, v), "name": n} for n, v in example_inputs
                ],
                "outputs": out_specs,
                "meta": meta or {},
            }
        )
        print(f"  [aot] {name}: {len(hlo)//1024} KiB hlo, "
              f"{len(example_inputs)} in / {len(out_specs)} out")

    def finish(self) -> None:
        manifest = {"version": 1, "artifacts": self.entries}
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        print(f"  [aot] manifest.json: {len(self.entries)} artifacts")


# ---------------------------------------------------------------------------
# Attention artifacts
# ---------------------------------------------------------------------------


def _attn_cases(profile: str):
    if profile == "test":
        return [(1, 2, 64, 32)]
    # Tiny case for fast integration tests, then B chosen so B*N = 2048
    # "tokens" (scaled-down paper setting: the paper fixes B*N = 16k on
    # A100; CPU gets 2k).
    return [
        (1, 2, 64, 32),
        (16, 4, 128, 64), (8, 4, 256, 64), (4, 4, 512, 64), (4, 4, 256, 128),
    ]


def export_attention(ex: Exporter, profile: str) -> None:
    rng = np.random.default_rng(42)
    for b, h, n, d in _attn_cases(profile):
        q = rng.normal(size=(b, h, n, d)).astype(np.float32)
        k = rng.normal(size=(b, h, n, d)).astype(np.float32)
        v = rng.normal(size=(b, h, n, d)).astype(np.float32)
        do = rng.normal(size=(b, h, n, d)).astype(np.float32)
        bs = BlockSizes(min(128, n), min(128, n))
        meta = {"batch": b, "heads": h, "seqlen": n, "head_dim": d}
        for causal in (False, True):
            tag = "causal" if causal else "full"
            sfx = f"{tag}_b{b}h{h}n{n}d{d}"

            ex.add(
                f"attn_fa2_{sfx}",
                functools.partial(flash2_fwd, causal=causal, block_sizes=bs),
                [("q", q), ("k", k), ("v", v)],
                kind="attn_fwd", meta={**meta, "causal": causal, "impl": "fa2"},
            )

            def grad_fn(q_, k_, v_, do_, _c=causal, _bs=bs):
                def f(a, b_, c):
                    return flash_attention(a, b_, c, _c, None, _bs, True)
                o, vjp = jax.vjp(f, q_, k_, v_)
                dq, dk, dv = vjp(do_)
                return o, dq, dk, dv

            ex.add(
                f"attn_fa2grad_{sfx}",
                grad_fn,
                [("q", q), ("k", k), ("v", v), ("do", do)],
                kind="attn_grad", meta={**meta, "causal": causal, "impl": "fa2"},
            )

            ex.add(
                f"attn_std_{sfx}",
                functools.partial(attention_ref, causal=causal),
                [("q", q), ("k", k), ("v", v)],
                kind="attn_fwd", meta={**meta, "causal": causal, "impl": "std"},
            )
        # split-K ablation: non-causal only (its natural decode use case)
        ex.add(
            f"attn_splitk4_full_b{b}h{h}n{n}d{d}",
            functools.partial(splitk_fwd, n_split=4, block_sizes=bs),
            [("q", q), ("k", k), ("v", v)],
            kind="attn_fwd", meta={**meta, "causal": False, "impl": "splitk4"},
        )


# ---------------------------------------------------------------------------
# Model artifacts (init / train_step / prefill / decode)
# ---------------------------------------------------------------------------


def _flatten_with_names(tree) -> tuple[list[str], list, object]:
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = [
        "/".join(
            p.key if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path
        )
        for path, _ in paths
    ]
    return names, [leaf for _, leaf in paths], treedef


def export_model(ex: Exporter, model_name: str, profile: str) -> None:
    cfg = MODELS[model_name]
    batch = TRAIN_BATCH[model_name]
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    opt = M.init_opt_state(params)
    p_names, p_leaves, p_tree = _flatten_with_names(params)
    o_names, o_leaves, o_tree = _flatten_with_names(opt)
    cfg_meta = {
        "model": model_name,
        "vocab_size": cfg.vocab_size, "n_layer": cfg.n_layer,
        "n_head": cfg.n_head, "n_kv_head": cfg.n_kv_head,
        "d_model": cfg.d_model, "max_seq": cfg.max_seq,
        "n_params": cfg.n_params, "train_batch": batch,
        "param_leaves": p_names, "opt_leaves": o_names,
    }

    # --- init: seed -> flat params (rust never constructs params itself) ---
    def init_fn(seed):
        p = M.init_params(jax.random.PRNGKey(seed), cfg)
        return tuple(_flatten_with_names(p)[1])

    ex.add(
        f"{model_name}_init", init_fn,
        [("seed", np.uint32(0))],
        kind="init", meta=cfg_meta, golden=(model_name == "tiny"),
    )

    # --- train_step: flat(params) + flat(opt) + tokens -> updated + loss ---
    n_p, n_o = len(p_leaves), len(o_leaves)

    def make_train_step(attention_impl):
        cfg_i = dataclasses.replace(cfg, attention_impl=attention_impl)

        def step_fn(*args):
            ps = jax.tree_util.tree_unflatten(p_tree, args[:n_p])
            os_ = jax.tree_util.tree_unflatten(o_tree, args[n_p:n_p + n_o])
            tokens = args[n_p + n_o]
            new_p, new_o, loss = M.train_step(cfg_i, ADAM, ps, os_, tokens)
            return tuple(
                jax.tree_util.tree_leaves(new_p)
                + jax.tree_util.tree_leaves(new_o)
                + [loss]
            )

        return step_fn

    rng = np.random.default_rng(7)
    tokens = rng.integers(0, cfg.vocab_size, size=(batch, cfg.max_seq)).astype(np.int32)
    inputs = (
        [(f"p_{n}", np.asarray(v)) for n, v in zip(p_names, p_leaves)]
        + [(f"o_{n}", np.asarray(v)) for n, v in zip(o_names, o_leaves)]
        + [("tokens", tokens)]
    )
    variants = [("flash2", "")] if profile == "test" else [
        ("flash2", ""), ("reference", "_refattn")
    ]
    for impl, suffix in variants:
        ex.add(
            f"{model_name}_train_step{suffix}",
            make_train_step(impl),
            inputs,
            kind="train_step",
            meta={**cfg_meta, "attention_impl": impl},
            golden=(model_name == "tiny" and impl == "flash2"),
            donate_argnums=tuple(range(n_p + n_o)),
        )

    # --- serving: prefill + decode (tiny model only; serving example) ---
    if model_name != "tiny":
        return
    for b in (1, 4):
        n_prompt = cfg.max_seq // 2

        def prefill_fn(*args):
            ps = jax.tree_util.tree_unflatten(p_tree, args[:n_p])
            toks = args[n_p]
            logits, cache = M.prefill(cfg, ps, toks)
            return logits, cache["k"], cache["v"]

        toks = rng.integers(0, cfg.vocab_size, size=(b, n_prompt)).astype(np.int32)
        ex.add(
            f"{model_name}_prefill_b{b}", prefill_fn,
            [(f"p_{n}", np.asarray(v)) for n, v in zip(p_names, p_leaves)]
            + [("tokens", toks)],
            kind="prefill",
            meta={**cfg_meta, "batch": b, "prompt_len": n_prompt},
        )

        def decode_fn(*args):
            ps = jax.tree_util.tree_unflatten(p_tree, args[:n_p])
            k_cache, v_cache, token, pos = args[n_p:]
            logits, cache = M.decode_step(
                cfg, ps, {"k": k_cache, "v": v_cache}, token, pos
            )
            return logits, cache["k"], cache["v"]

        cache_shape = (cfg.n_layer, b, cfg.n_kv_head, cfg.max_seq, cfg.d_head)
        k_cache = np.zeros(cache_shape, np.float32)
        v_cache = np.zeros(cache_shape, np.float32)
        token = rng.integers(0, cfg.vocab_size, size=(b,)).astype(np.int32)
        pos = np.full((b,), n_prompt, np.int32)
        ex.add(
            f"{model_name}_decode_b{b}", decode_fn,
            [(f"p_{n}", np.asarray(v)) for n, v in zip(p_names, p_leaves)]
            + [("k_cache", k_cache), ("v_cache", v_cache),
               ("token", token), ("pos", pos)],
            kind="decode",
            meta={**cfg_meta, "batch": b},
        )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--profile", choices=["full", "test"], default="full")
    args = ap.parse_args()

    ex = Exporter(args.out_dir)
    print(f"[aot] profile={args.profile} -> {args.out_dir}")
    export_attention(ex, args.profile)
    export_model(ex, "tiny", args.profile)
    if args.profile == "full":
        export_model(ex, "small", args.profile)
    ex.finish()


if __name__ == "__main__":
    main()
