"""L2: GPT-style transformer in JAX, attention via the L1 FlashAttention-2
kernels, plus the train/prefill/decode entry points that ``aot.py`` lowers to
HLO for the Rust runtime.

Everything here is build-time Python: the Rust coordinator only ever sees the
lowered HLO text.  The model is deliberately framework-free (no flax/optax —
neither is available offline, and inlining Adam keeps the *entire* training
step inside one donated-buffer HLO executable, which is what the Table-1
harness measures).

Architecture (GPT-2/3 style, pre-LN):
  token embedding + learned positional embedding
  n_layer x [ LN -> MHA/GQA (FlashAttention-2, causal) -> residual
              LN -> MLP (4x, GeLU)                     -> residual ]
  final LN -> tied LM head (embedding transpose)
Layers are stacked and scanned (``lax.scan``) so HLO size is O(1) in depth.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import BlockSizes, attention_ref, flash_attention

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class GPTConfig:
    """Model + kernel configuration (mirrored by rust/src/config)."""

    vocab_size: int = 8192
    n_layer: int = 4
    n_head: int = 8
    n_kv_head: int = 8          # < n_head enables GQA; == 1 is MQA
    d_model: int = 256
    max_seq: int = 256
    attention_impl: str = "flash2"  # "flash2" | "reference"
    block_q: int = 128
    block_k: int = 128
    param_dtype: Any = jnp.float32

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_head == 0
        return self.d_model // self.n_head

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    @property
    def n_params(self) -> int:
        """Exact parameter count (used by the MFU accounting)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        d_kv = self.n_kv_head * self.d_head
        per_layer = (
            2 * d * d          # W_q, W_o
            + 2 * d * d_kv     # W_k, W_v
            + 2 * d * f        # W_in, W_out
            + 3 * d + 2 * d_kv + f  # biases: bq, bo, b_out, bk, bv, b_in
            + 4 * d            # 2 LN scale+bias
        )
        embed = v * d + self.max_seq * d
        final_ln = 2 * d
        return self.n_layer * per_layer + embed + final_ln


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def init_params(key: jax.Array, cfg: GPTConfig) -> Params:
    """GPT-2 style init: N(0, 0.02), residual projections scaled by 1/sqrt(2L)."""
    k_emb, k_pos, k_blocks = jax.random.split(key, 3)
    std = 0.02
    resid_std = std / math.sqrt(2 * cfg.n_layer)
    d, dh = cfg.d_model, cfg.d_head
    d_kv = cfg.n_kv_head * dh
    L = cfg.n_layer
    dt = cfg.param_dtype

    def norm(key, shape, s):
        return (jax.random.normal(key, shape) * s).astype(dt)

    ks = jax.random.split(k_blocks, 8)
    blocks = {
        "ln1_g": jnp.ones((L, d), dt),
        "ln1_b": jnp.zeros((L, d), dt),
        "wq": norm(ks[0], (L, d, d), std),
        "bq": jnp.zeros((L, d), dt),
        "wk": norm(ks[1], (L, d, d_kv), std),
        "bk": jnp.zeros((L, d_kv), dt),
        "wv": norm(ks[2], (L, d, d_kv), std),
        "bv": jnp.zeros((L, d_kv), dt),
        "wo": norm(ks[3], (L, d, d), resid_std),
        "bo": jnp.zeros((L, d), dt),
        "ln2_g": jnp.ones((L, d), dt),
        "ln2_b": jnp.zeros((L, d), dt),
        "w_in": norm(ks[4], (L, d, cfg.d_ff), std),
        "b_in": jnp.zeros((L, cfg.d_ff), dt),
        "w_out": norm(ks[5], (L, cfg.d_ff, d), resid_std),
        "b_out": jnp.zeros((L, d), dt),
    }
    return {
        "wte": norm(k_emb, (cfg.vocab_size, d), std),
        "wpe": norm(k_pos, (cfg.max_seq, d), std),
        "ln_f_g": jnp.ones((d,), dt),
        "ln_f_b": jnp.zeros((d,), dt),
        "blocks": blocks,
    }


def count_params(params: Params) -> int:
    return sum(p.size for p in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * lax.rsqrt(var + eps) * g + b


def _split_heads(x, n_head, d_head):
    b, n, _ = x.shape
    return x.reshape(b, n, n_head, d_head).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, n, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, n, h * dh)


def _attention(cfg: GPTConfig, q, k, v, *, causal: bool):
    """Dispatch to the FlashAttention-2 kernel or the jnp reference."""
    if cfg.attention_impl == "flash2":
        return flash_attention(
            q, k, v, causal, None, BlockSizes(cfg.block_q, cfg.block_k), True
        )
    elif cfg.attention_impl == "reference":
        return attention_ref(q, k, v, causal=causal)[0]
    raise ValueError(f"unknown attention_impl {cfg.attention_impl!r}")


def _block(cfg: GPTConfig, x, p, *, causal: bool = True):
    """One pre-LN transformer block. x: (B, N, D)."""
    h = _layer_norm(x, p["ln1_g"], p["ln1_b"])
    q = _split_heads(h @ p["wq"] + p["bq"], cfg.n_head, cfg.d_head)
    k = _split_heads(h @ p["wk"] + p["bk"], cfg.n_kv_head, cfg.d_head)
    v = _split_heads(h @ p["wv"] + p["bv"], cfg.n_kv_head, cfg.d_head)
    o = _merge_heads(_attention(cfg, q, k, v, causal=causal))
    x = x + (o @ p["wo"] + p["bo"])
    h = _layer_norm(x, p["ln2_g"], p["ln2_b"])
    h = jax.nn.gelu(h @ p["w_in"] + p["b_in"])
    return x + (h @ p["w_out"] + p["b_out"])


def forward(cfg: GPTConfig, params: Params, tokens: jax.Array) -> jax.Array:
    """tokens (B, N) int32 -> logits (B, N, vocab)."""
    b, n = tokens.shape
    x = params["wte"][tokens] + params["wpe"][:n][None]

    def scan_body(x, layer_params):
        return _block(cfg, x, layer_params), None

    x, _ = lax.scan(scan_body, x, params["blocks"])
    x = _layer_norm(x, params["ln_f_g"], params["ln_f_b"])
    return x @ params["wte"].T  # tied head


def loss_fn(cfg: GPTConfig, params: Params, tokens: jax.Array) -> jax.Array:
    """Causal LM cross-entropy (next-token prediction), mean over tokens."""
    logits = forward(cfg, params, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


# ---------------------------------------------------------------------------
# Training step (inline Adam, donated state)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0


def init_opt_state(params: Params) -> dict:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": zeros,
        "v": jax.tree_util.tree_map(jnp.zeros_like, params),
    }


def train_step(
    cfg: GPTConfig,
    adam: AdamConfig,
    params: Params,
    opt_state: dict,
    tokens: jax.Array,
) -> tuple[Params, dict, jax.Array]:
    """One fused fwd+bwd+Adam update. AOT-lowered with donated params/state."""
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens))(params)

    # Global-norm gradient clip.
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    clip = jnp.minimum(1.0, adam.grad_clip / (gnorm + 1e-6))
    grads = jax.tree_util.tree_map(lambda g: g * clip, grads)

    step = opt_state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - adam.beta1**t
    bc2 = 1.0 - adam.beta2**t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = adam.beta1 * m + (1 - adam.beta1) * g32
        v_new = adam.beta2 * v + (1 - adam.beta2) * g32 * g32
        delta = adam.lr * (m_new / bc1) / (jnp.sqrt(v_new / bc2) + adam.eps)
        if adam.weight_decay:
            delta = delta + adam.lr * adam.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt_state["m"])
    flat_v = jax.tree_util.tree_leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, {"step": step, "m": new_m, "v": new_v}, loss


# ---------------------------------------------------------------------------
# Inference: prefill + single-token decode with a fixed-size KV cache
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: GPTConfig, batch: int) -> dict:
    shape = (cfg.n_layer, batch, cfg.n_kv_head, cfg.max_seq, cfg.d_head)
    return {
        "k": jnp.zeros(shape, jnp.float32),
        "v": jnp.zeros(shape, jnp.float32),
    }


def _cached_attention(cfg, q, k_cache, v_cache, pos):
    """Decode attention: one query row against cache[:pos+1].

    Decode is memory-bound (a (1 x d) @ (d x N) matvec — no MXU win), so it
    uses a masked dense softmax over the fixed-size cache; the causal
    structure is enforced with a position mask, which keeps the HLO static
    for AOT.  This is the flash-decoding regime; the split-K kernel covers
    the long-context variant and is exercised in the serving bench.
    """
    scale = 1.0 / math.sqrt(cfg.d_head)
    from .kernels.ref import expand_kv_heads

    k_cache = expand_kv_heads(k_cache, cfg.n_head)
    v_cache = expand_kv_heads(v_cache, cfg.n_head)
    s = jnp.einsum("bhd,bhnd->bhn", q, k_cache) * scale  # (B, H, max_seq)
    idx = jnp.arange(cfg.max_seq)[None, None]
    s = jnp.where(idx <= pos[:, None, None], s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhn,bhnd->bhd", p, v_cache)


def _block_decode(cfg, x, p, k_cache, v_cache, pos):
    """One block for a single new token. x: (B, D); caches (B, Hkv, S, dh)."""
    h = _layer_norm(x, p["ln1_g"], p["ln1_b"])
    q = (h @ p["wq"] + p["bq"]).reshape(-1, cfg.n_head, cfg.d_head)
    k = (h @ p["wk"] + p["bk"]).reshape(-1, cfg.n_kv_head, cfg.d_head)
    v = (h @ p["wv"] + p["bv"]).reshape(-1, cfg.n_kv_head, cfg.d_head)
    # Scatter this token's K/V into the cache at `pos` (per batch row).
    b_idx = jnp.arange(k.shape[0])
    k_cache = k_cache.at[b_idx, :, pos].set(k)
    v_cache = v_cache.at[b_idx, :, pos].set(v)
    o = _cached_attention(cfg, q, k_cache, v_cache, pos)
    x = x + (o.reshape(-1, cfg.d_model) @ p["wo"] + p["bo"])
    h = _layer_norm(x, p["ln2_g"], p["ln2_b"])
    h = jax.nn.gelu(h @ p["w_in"] + p["b_in"])
    return x + (h @ p["w_out"] + p["b_out"]), k_cache, v_cache


def prefill(
    cfg: GPTConfig, params: Params, tokens: jax.Array
) -> tuple[jax.Array, dict]:
    """Run the full prompt through the model, filling the KV cache.

    tokens: (B, N) with N <= max_seq.  Returns (logits for last position,
    cache dict).  Prefill attention uses the FA2 kernel (compute-bound).
    """
    b, n = tokens.shape
    x = params["wte"][tokens] + params["wpe"][:n][None]
    ks, vs = [], []

    def scan_body(x, p):
        h = _layer_norm(x, p["ln1_g"], p["ln1_b"])
        q = _split_heads(h @ p["wq"] + p["bq"], cfg.n_head, cfg.d_head)
        k = _split_heads(h @ p["wk"] + p["bk"], cfg.n_kv_head, cfg.d_head)
        v = _split_heads(h @ p["wv"] + p["bv"], cfg.n_kv_head, cfg.d_head)
        o = _merge_heads(_attention(cfg, q, k, v, causal=True))
        x = x + (o @ p["wo"] + p["bo"])
        h2 = _layer_norm(x, p["ln2_g"], p["ln2_b"])
        h2 = jax.nn.gelu(h2 @ p["w_in"] + p["b_in"])
        return x + (h2 @ p["w_out"] + p["b_out"]), (k, v)

    x, (k_all, v_all) = lax.scan(scan_body, x, params["blocks"])
    x = _layer_norm(x, params["ln_f_g"], params["ln_f_b"])
    logits = x[:, -1] @ params["wte"].T

    pad = cfg.max_seq - n
    cache = {
        "k": jnp.pad(k_all, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))),
        "v": jnp.pad(v_all, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))),
    }
    return logits, cache


def decode_step(
    cfg: GPTConfig,
    params: Params,
    cache: dict,
    token: jax.Array,  # (B,) int32
    pos: jax.Array,    # (B,) int32 — position to write / attend through
) -> tuple[jax.Array, dict]:
    """Append one token per sequence and return next-token logits (B, vocab)."""
    x = params["wte"][token] + params["wpe"][pos]

    def scan_body(x, inputs):
        p, k_cache, v_cache = inputs
        x, k_new, v_new = _block_decode(cfg, x, p, k_cache, v_cache, pos)
        return x, (k_new, v_new)

    x, (k_all, v_all) = lax.scan(
        scan_body, x, (params["blocks"], cache["k"], cache["v"])
    )
    x = _layer_norm(x, params["ln_f_g"], params["ln_f_b"])
    logits = x @ params["wte"].T
    return logits, {"k": k_all, "v": v_all}


# ---------------------------------------------------------------------------
# FLOPs accounting (paper section 4.2, the Megatron-LM formula)
# ---------------------------------------------------------------------------


def train_step_flops(cfg: GPTConfig, batch: int, seqlen: int) -> float:
    """6 * seqlen * n_params + 12 * n_layer * d_model * seqlen^2, times batch.

    This is the exact formula the paper uses for Table 1 (footnote: attention
    term NOT halved for causal, "for consistency with the literature").
    """
    per_seq = (
        6.0 * seqlen * cfg.n_params
        + 12.0 * cfg.n_layer * cfg.d_model * float(seqlen) ** 2
    )
    return batch * per_seq


def attention_flops(
    seqlen: int, head_dim: int, n_heads: int, *, causal: bool, mode: str = "fwd"
) -> float:
    """Paper section 4.1 benchmark formula: 4 * N^2 * d * heads [/2 causal].

    mode: "fwd" -> x1, "bwd" -> x2.5, "fwd_bwd" -> x3.5.
    """
    f = 4.0 * float(seqlen) ** 2 * head_dim * n_heads
    if causal:
        f /= 2
    return {"fwd": f, "bwd": 2.5 * f, "fwd_bwd": 3.5 * f}[mode]
