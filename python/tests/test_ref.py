"""Oracle self-consistency tests.

The reference implementation is itself tested three ways before it is
trusted as the kernel oracle:
  1. against a dead-simple dense softmax with no stability tricks,
  2. hand-derived backward vs jax autodiff of the forward,
  3. algebraic properties (row-stochastic P, LSE definition, GQA equivalence).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import (
    attention_ref,
    attention_ref_bwd,
    attention_ref_vjp,
    expand_kv_heads,
)
from tests.conftest import make_qkv


def naive_attention(q, k, v, causal=False, scale=None):
    """Textbook O = softmax(QK^T)V with zero cleverness."""
    if scale is None:
        scale = 1.0 / np.sqrt(q.shape[-1])
    s = np.einsum("bhqd,bhkd->bhqk", q, k).astype(np.float64) * scale
    if causal:
        nq, nk = s.shape[-2:]
        mask = np.triu(np.ones((nq, nk), bool), k=1 + nk - nq)
        s = np.where(mask, -np.inf, s)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return np.einsum("bhqk,bhkd->bhqd", p, v)


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("n,d", [(17, 8), (64, 32), (128, 16)])
def test_ref_matches_naive(rng, causal, n, d):
    q, k, v = make_qkv(rng, 2, 3, 3, n, n, d)
    o, _ = attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=causal)
    o_naive = naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), o_naive, atol=2e-5, rtol=2e-5)


def test_ref_lse_definition(rng):
    """L must equal log(sum(exp(scaled scores))) per row."""
    q, k, v = make_qkv(rng, 1, 2, 2, 48, 48, 16)
    scale = 1.0 / np.sqrt(16)
    _, lse = attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    s = np.einsum("bhqd,bhkd->bhqk", q, k) * scale
    expected = np.log(np.exp(s - s.max(-1, keepdims=True)).sum(-1)) + s.max(-1)
    np.testing.assert_allclose(np.asarray(lse), expected, atol=1e-5, rtol=1e-5)


def test_ref_rows_sum_to_one_via_ones_value(rng):
    """With V = all-ones, O must be exactly all-ones (P is row-stochastic)."""
    q, k, _ = make_qkv(rng, 1, 2, 2, 40, 40, 8)
    v = jnp.ones((1, 2, 40, 8), jnp.float32)
    o, _ = attention_ref(jnp.asarray(q), jnp.asarray(k), v, causal=True)
    np.testing.assert_allclose(np.asarray(o), 1.0, atol=1e-6)


@pytest.mark.parametrize("causal", [False, True])
def test_ref_bwd_matches_autodiff(rng, causal):
    q, k, v = make_qkv(rng, 2, 2, 2, 33, 33, 16)
    q, k, v = map(jnp.asarray, (q, k, v))
    o, lse = attention_ref(q, k, v, causal=causal)
    do = jnp.asarray(rng.normal(size=o.shape).astype(np.float32))
    dq, dk, dv = attention_ref_bwd(q, k, v, o, lse, do, causal=causal)
    dq2, dk2, dv2 = attention_ref_vjp(q, k, v, do, causal=causal)
    np.testing.assert_allclose(dq, dq2, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(dk, dk2, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(dv, dv2, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("hq,hk", [(4, 1), (8, 2), (6, 3)])
def test_ref_gqa_equals_explicit_duplication(rng, hq, hk):
    q, k, v = make_qkv(rng, 1, hq, hk, 32, 32, 8)
    q, k, v = map(jnp.asarray, (q, k, v))
    o_gqa, lse_gqa = attention_ref(q, k, v, causal=True)
    kx, vx = expand_kv_heads(k, hq), expand_kv_heads(v, hq)
    o_full, lse_full = attention_ref(q, kx, vx, causal=True)
    np.testing.assert_allclose(o_gqa, o_full, atol=1e-6)
    np.testing.assert_allclose(lse_gqa, lse_full, atol=1e-6)


def test_ref_gqa_bwd_sums_over_group(rng):
    """dK/dV for GQA must equal the sum over duplicated query-head grads."""
    hq, hk = 4, 2
    q, k, v = make_qkv(rng, 1, hq, hk, 24, 24, 8)
    q, k, v = map(jnp.asarray, (q, k, v))
    o, lse = attention_ref(q, k, v)
    do = jnp.asarray(rng.normal(size=o.shape).astype(np.float32))
    dq, dk, dv = attention_ref_bwd(q, k, v, o, lse, do)
    dq2, dk2, dv2 = attention_ref_vjp(q, k, v, do)
    np.testing.assert_allclose(dk, dk2, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(dv, dv2, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(dq, dq2, atol=2e-5, rtol=2e-5)


def test_ref_rectangular_causal_right_aligned(rng):
    """Decode convention: q block right-aligned against the KV sequence."""
    q, k, v = make_qkv(rng, 1, 1, 1, 4, 16, 8)
    q, k, v = map(jnp.asarray, (q, k, v))
    o, _ = attention_ref(q, k, v, causal=True)
    # Row r of the 4 queries may attend to keys 0..(12+r). Check against a
    # manual computation for the last row (full visibility).
    o_full, _ = attention_ref(q[:, :, 3:], k, v, causal=False)
    np.testing.assert_allclose(o[:, :, 3], o_full[:, :, 0], atol=1e-6)


def test_ref_scale_override(rng):
    q, k, v = make_qkv(rng, 1, 1, 1, 16, 16, 4)
    q, k, v = map(jnp.asarray, (q, k, v))
    o1, _ = attention_ref(q, k, v, scale=0.5)
    o2, _ = attention_ref(q * 0.5, k, v, scale=1.0)
    np.testing.assert_allclose(o1, o2, atol=1e-6)
