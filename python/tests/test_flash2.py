"""FlashAttention-2 Pallas kernels vs the pure-jnp oracle.

This is the CORE correctness signal for the whole repo: every artifact the
Rust runtime executes goes through these kernels.  Includes a hypothesis
sweep over shapes/blocks/flags (paper Algorithm 1 & 2 under every tiling).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    BlockSizes,
    attention_ref,
    attention_ref_bwd,
    attention_ref_vjp,
    flash2_bwd,
    flash2_fwd,
)
from tests.conftest import make_qkv

ATOL = 2e-5
BWD_ATOL = 5e-5


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("n,d,bq,bk", [
    (64, 32, 16, 16),
    (128, 64, 64, 32),
    (96, 16, 32, 64),   # block_k > block_q
    (256, 32, 128, 128),
    (80, 32, 32, 32),   # n not a multiple of block (tail masking)
    (100, 8, 64, 32),
])
def test_fwd_matches_ref(rng, causal, n, d, bq, bk):
    q, k, v = make_qkv(rng, 2, 2, 2, n, n, d)
    q, k, v = map(jnp.asarray, (q, k, v))
    o_ref, lse_ref = attention_ref(q, k, v, causal=causal)
    o, lse = flash2_fwd(q, k, v, causal=causal, block_sizes=BlockSizes(bq, bk))
    np.testing.assert_allclose(o, o_ref, atol=ATOL, rtol=ATOL)
    np.testing.assert_allclose(lse, lse_ref, atol=ATOL, rtol=ATOL)


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("n,d,bq,bk", [
    (64, 32, 16, 16),
    (128, 32, 64, 32),
    (96, 16, 32, 64),
    (80, 32, 32, 32),
])
def test_bwd_matches_ref(rng, causal, n, d, bq, bk):
    q, k, v = make_qkv(rng, 2, 2, 2, n, n, d)
    q, k, v = map(jnp.asarray, (q, k, v))
    o, lse = flash2_fwd(q, k, v, causal=causal, block_sizes=BlockSizes(bq, bk))
    do = jnp.asarray(rng.normal(size=o.shape).astype(np.float32))
    dq, dk, dv = flash2_bwd(
        q, k, v, o, lse, do, causal=causal, block_sizes=BlockSizes(bq, bk)
    )
    dq_r, dk_r, dv_r = attention_ref_vjp(q, k, v, do, causal=causal)
    np.testing.assert_allclose(dq, dq_r, atol=BWD_ATOL, rtol=BWD_ATOL)
    np.testing.assert_allclose(dk, dk_r, atol=BWD_ATOL, rtol=BWD_ATOL)
    np.testing.assert_allclose(dv, dv_r, atol=BWD_ATOL, rtol=BWD_ATOL)


@pytest.mark.parametrize("hq,hk", [(2, 1), (4, 2), (6, 2)])
@pytest.mark.parametrize("causal", [False, True])
def test_gqa_fwd_bwd(rng, hq, hk, causal):
    """GQA via BlockSpec index_map == explicit KV duplication (paper 3.1.2)."""
    q, k, v = make_qkv(rng, 1, hq, hk, 64, 64, 16)
    q, k, v = map(jnp.asarray, (q, k, v))
    bs = BlockSizes(32, 32)
    o_ref, lse_ref = attention_ref(q, k, v, causal=causal)
    o, lse = flash2_fwd(q, k, v, causal=causal, block_sizes=bs)
    np.testing.assert_allclose(o, o_ref, atol=ATOL, rtol=ATOL)
    np.testing.assert_allclose(lse, lse_ref, atol=ATOL, rtol=ATOL)

    do = jnp.asarray(rng.normal(size=o.shape).astype(np.float32))
    dq, dk, dv = flash2_bwd(q, k, v, o, lse, do, causal=causal, block_sizes=bs)
    dq_r, dk_r, dv_r = attention_ref_vjp(q, k, v, do, causal=causal)
    np.testing.assert_allclose(dq, dq_r, atol=BWD_ATOL, rtol=BWD_ATOL)
    np.testing.assert_allclose(dk, dk_r, atol=BWD_ATOL, rtol=BWD_ATOL)
    np.testing.assert_allclose(dv, dv_r, atol=BWD_ATOL, rtol=BWD_ATOL)


def test_cross_attention_rectangular(rng):
    """n_q != n_k (non-causal cross attention)."""
    q, k, v = make_qkv(rng, 1, 2, 2, 48, 112, 16)
    q, k, v = map(jnp.asarray, (q, k, v))
    o_ref, lse_ref = attention_ref(q, k, v)
    o, lse = flash2_fwd(q, k, v, block_sizes=BlockSizes(16, 32))
    np.testing.assert_allclose(o, o_ref, atol=ATOL, rtol=ATOL)
    np.testing.assert_allclose(lse, lse_ref, atol=ATOL, rtol=ATOL)


def test_bf16_inputs(rng):
    """bf16 inputs with f32 accumulation (the MXU configuration)."""
    q, k, v = make_qkv(rng, 1, 2, 2, 64, 64, 32)
    qb, kb, vb = (jnp.asarray(x, jnp.bfloat16) for x in (q, k, v))
    o_ref, _ = attention_ref(qb, kb, vb, causal=True)
    o, _ = flash2_fwd(qb, kb, vb, causal=True, block_sizes=BlockSizes(32, 32))
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref, np.float32), atol=2e-2, rtol=2e-2
    )


def test_scale_override(rng):
    q, k, v = make_qkv(rng, 1, 1, 1, 32, 32, 8)
    q, k, v = map(jnp.asarray, (q, k, v))
    o_ref, _ = attention_ref(q, k, v, scale=0.25)
    o, _ = flash2_fwd(q, k, v, scale=0.25, block_sizes=BlockSizes(16, 16))
    np.testing.assert_allclose(o, o_ref, atol=ATOL, rtol=ATOL)


def test_extreme_scores_stability():
    """Large score magnitudes: online softmax must not overflow."""
    b, h, n, d = 1, 1, 64, 16
    q = jnp.full((b, h, n, d), 30.0, jnp.float32)
    k = jnp.full((b, h, n, d), 30.0, jnp.float32)
    v = jnp.ones((b, h, n, d), jnp.float32)
    o, lse = flash2_fwd(q, k, v, block_sizes=BlockSizes(16, 16))
    assert np.isfinite(np.asarray(o)).all()
    assert np.isfinite(np.asarray(lse)).all()
    np.testing.assert_allclose(np.asarray(o), 1.0, atol=1e-5)


def test_single_block_degenerate(rng):
    """Whole problem fits one block: the online loop runs exactly once."""
    q, k, v = make_qkv(rng, 1, 1, 1, 8, 8, 4)
    q, k, v = map(jnp.asarray, (q, k, v))
    o_ref, lse_ref = attention_ref(q, k, v, causal=True)
    o, lse = flash2_fwd(q, k, v, causal=True, block_sizes=BlockSizes(128, 128))
    np.testing.assert_allclose(o, o_ref, atol=ATOL, rtol=ATOL)
    np.testing.assert_allclose(lse, lse_ref, atol=ATOL, rtol=ATOL)


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.integers(1, 2),
    h=st.integers(1, 3),
    n=st.integers(4, 96),
    d=st.sampled_from([4, 8, 16, 32]),
    bq=st.sampled_from([8, 16, 32, 128]),
    bk=st.sampled_from([8, 16, 32, 128]),
    causal=st.booleans(),
)
def test_fwd_hypothesis_sweep(seed, b, h, n, d, bq, bk, causal):
    """Property: for ANY shape/tiling, FA2 fwd == reference (Alg. 1 invariant)."""
    rng = np.random.default_rng(seed)
    q, k, v = make_qkv(rng, b, h, h, n, n, d)
    q, k, v = map(jnp.asarray, (q, k, v))
    o_ref, lse_ref = attention_ref(q, k, v, causal=causal)
    o, lse = flash2_fwd(q, k, v, causal=causal, block_sizes=BlockSizes(bq, bk))
    np.testing.assert_allclose(o, o_ref, atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(lse, lse_ref, atol=5e-5, rtol=5e-5)


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(8, 64),
    d=st.sampled_from([4, 8, 16]),
    bq=st.sampled_from([8, 16, 32]),
    bk=st.sampled_from([8, 16, 32]),
    causal=st.booleans(),
)
def test_bwd_hypothesis_sweep(seed, n, d, bq, bk, causal):
    """Property: for ANY shape/tiling, FA2 bwd == autodiff of the reference."""
    rng = np.random.default_rng(seed)
    q, k, v = make_qkv(rng, 1, 2, 2, n, n, d)
    q, k, v = map(jnp.asarray, (q, k, v))
    bs = BlockSizes(bq, bk)
    o, lse = flash2_fwd(q, k, v, causal=causal, block_sizes=bs)
    do = jnp.asarray(rng.normal(size=o.shape).astype(np.float32))
    dq, dk, dv = flash2_bwd(q, k, v, o, lse, do, causal=causal, block_sizes=bs)
    dq_r, dk_r, dv_r = attention_ref_vjp(q, k, v, do, causal=causal)
    np.testing.assert_allclose(dq, dq_r, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(dk, dk_r, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(dv, dv_r, atol=1e-4, rtol=1e-4)


def test_lse_is_the_only_residual_needed(rng):
    """Paper tweak #2: bwd from (Q,K,V,O,L) alone reproduces autodiff grads,
    proving m and l separately are redundant residuals."""
    q, k, v = make_qkv(rng, 1, 1, 1, 48, 48, 8)
    q, k, v = map(jnp.asarray, (q, k, v))
    o, lse = flash2_fwd(q, k, v, block_sizes=BlockSizes(16, 16))
    do = jnp.ones_like(o)
    dq, dk, dv = flash2_bwd(q, k, v, o, lse, do, block_sizes=BlockSizes(16, 16))
    dq_r, dk_r, dv_r = attention_ref_vjp(q, k, v, do)
    np.testing.assert_allclose(dq, dq_r, atol=BWD_ATOL, rtol=BWD_ATOL)
