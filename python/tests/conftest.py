"""Shared fixtures: deterministic RNG helpers for kernel tests."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(1234)


def make_qkv(rng, b, hq, hk, n_q, n_k, d, dtype=np.float32):
    """Gaussian Q/K/V with the given head layout."""
    q = rng.normal(size=(b, hq, n_q, d)).astype(dtype)
    k = rng.normal(size=(b, hk, n_k, d)).astype(dtype)
    v = rng.normal(size=(b, hk, n_k, d)).astype(dtype)
    return q, k, v
