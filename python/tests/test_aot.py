"""AOT pipeline tests: the test-profile export produces a well-formed
manifest, valid HLO text, and goldens that reproduce under re-execution."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from compile.tensorio import read_tensors, write_tensors

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    with open(path) as f:
        return json.load(f)


def test_manifest_schema(manifest):
    assert manifest["version"] == 1
    arts = manifest["artifacts"]
    assert len(arts) >= 30
    names = {a["name"] for a in arts}
    for required in [
        "attn_fa2_causal_b1h2n64d32",
        "attn_fa2grad_full_b1h2n64d32",
        "tiny_train_step",
        "tiny_init",
        "tiny_prefill_b1",
        "tiny_decode_b4",
        "small_train_step",
        "small_train_step_refattn",
    ]:
        assert required in names, f"missing {required}"
    for a in arts:
        assert os.path.exists(os.path.join(ART, a["hlo"])), a["name"]
        for spec in a["inputs"] + a["outputs"]:
            assert spec["dtype"] in ("f32", "i32", "u32", "f64", "i64")
            assert all(isinstance(d, int) and d >= 0 for d in spec["shape"])


def test_hlo_is_parseable_text(manifest):
    a = next(x for x in manifest["artifacts"] if x["name"] == "attn_fa2_causal_b1h2n64d32")
    with open(os.path.join(ART, a["hlo"])) as f:
        text = f.read()
    assert text.startswith("HloModule"), "expected HLO text, not proto"
    assert "ENTRY" in text


def test_goldens_reproduce_in_python(manifest):
    """Re-execute a golden's inputs through the jitted fn and compare."""
    import jax.numpy as jnp
    from compile.kernels import flash2_fwd, BlockSizes

    a = next(x for x in manifest["artifacts"] if x["name"] == "attn_fa2_causal_b1h2n64d32")
    g = read_tensors(os.path.join(ART, a["golden"]))
    o, lse = flash2_fwd(
        jnp.asarray(g["in0"]), jnp.asarray(g["in1"]), jnp.asarray(g["in2"]),
        causal=True, block_sizes=BlockSizes(64, 64),
    )
    np.testing.assert_allclose(np.asarray(o), g["out0"], atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(lse), g["out1"], atol=1e-5, rtol=1e-5)


def test_tensorio_preserves_scalars(tmp_path):
    p = str(tmp_path / "t.fat1")
    write_tensors(p, {"s": np.int32(7), "z": np.zeros((), np.float32)})
    back = read_tensors(p)
    assert back["s"].shape == ()
    assert back["z"].shape == ()
    assert back["s"] == 7


def test_aot_test_profile_runs_end_to_end(tmp_path):
    """The exporter itself: run the (fast) test profile into a tmp dir."""
    out = str(tmp_path / "arts")
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", out, "--profile", "test"],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    with open(os.path.join(out, "manifest.json")) as f:
        m = json.load(f)
    assert len(m["artifacts"]) == 13
    # golden self-consistency for one artifact
    a = next(x for x in m["artifacts"] if x["kind"] == "train_step")
    g = read_tensors(os.path.join(out, a["golden"]))
    assert f"in{len(a['inputs']) - 1}" in g
    assert f"out{len(a['outputs']) - 1}" in g
