"""L2 model tests: shapes, flash2-vs-reference equivalence, train step,
prefill/decode consistency, FLOPs accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG_TINY = M.GPTConfig(
    vocab_size=128, n_layer=2, n_head=4, n_kv_head=4, d_model=32,
    max_seq=32, block_q=16, block_k=16,
)


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(0), CFG_TINY)


def test_param_count_formula_matches_actual(params):
    assert M.count_params(params) == CFG_TINY.n_params


def test_forward_shapes(params):
    tokens = jnp.zeros((2, 16), jnp.int32)
    logits = M.forward(CFG_TINY, params, tokens)
    assert logits.shape == (2, 16, CFG_TINY.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


def test_flash2_and_reference_attention_agree(params):
    """The whole model, flash2 kernels vs jnp reference — must agree."""
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, 128)
    cfg_ref = M.GPTConfig(**{**CFG_TINY.__dict__, "attention_impl": "reference"})
    lf = M.forward(CFG_TINY, params, tokens)
    lr = M.forward(cfg_ref, params, tokens)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lr), atol=2e-4, rtol=2e-4)


def test_gqa_model_runs():
    cfg = M.GPTConfig(
        vocab_size=64, n_layer=2, n_head=4, n_kv_head=2, d_model=32,
        max_seq=16, block_q=8, block_k=8,
    )
    p = M.init_params(jax.random.PRNGKey(0), cfg)
    assert M.count_params(p) == cfg.n_params
    tokens = jnp.zeros((1, 16), jnp.int32)
    logits = M.forward(cfg, p, tokens)
    assert logits.shape == (1, 16, 64)


def test_gradients_flow_and_loss_decreases(params):
    """A few Adam steps on a fixed batch must reduce the loss (overfit test)
    and gradients must flow through the custom_vjp FA2 backward."""
    tokens = jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0, 128)
    adam = M.AdamConfig(lr=1e-2)
    step = jax.jit(lambda p, s, t: M.train_step(CFG_TINY, adam, p, s, t))
    p, s = params, M.init_opt_state(params)
    losses = []
    for _ in range(8):
        p, s, loss = step(p, s, tokens)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses
    assert all(np.isfinite(losses))


def test_train_step_grads_match_reference_attention(params):
    """Grad through the FA2 custom_vjp == grad through reference attention."""
    tokens = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0, 128)
    cfg_ref = M.GPTConfig(**{**CFG_TINY.__dict__, "attention_impl": "reference"})
    g_fa = jax.grad(lambda p: M.loss_fn(CFG_TINY, p, tokens))(params)
    g_ref = jax.grad(lambda p: M.loss_fn(cfg_ref, p, tokens))(params)
    flat_fa = jax.tree_util.tree_leaves(g_fa)
    flat_ref = jax.tree_util.tree_leaves(g_ref)
    for a, b in zip(flat_fa, flat_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3, rtol=1e-3)


def test_prefill_decode_matches_full_forward(params):
    """Decoding token-by-token with the KV cache must reproduce the logits of
    a single full forward pass (the serving-path correctness invariant)."""
    key = jax.random.PRNGKey(4)
    tokens = jax.random.randint(key, (2, 12), 0, 128)
    full = M.forward(CFG_TINY, params, tokens)

    n_prefill = 8
    logits_p, cache = M.prefill(CFG_TINY, params, tokens[:, :n_prefill])
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full[:, n_prefill - 1]), atol=2e-4, rtol=2e-4
    )
    logits = logits_p
    for t in range(n_prefill, 12):
        pos = jnp.full((2,), t, jnp.int32)
        logits, cache = M.decode_step(CFG_TINY, params, cache, tokens[:, t], pos)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full[:, t]), atol=5e-4, rtol=5e-4
        )


def test_loss_at_init_near_uniform(params):
    """Untrained model: x-ent ~ log(vocab)."""
    tokens = jax.random.randint(jax.random.PRNGKey(5), (4, 32), 0, 128)
    loss = float(M.loss_fn(CFG_TINY, params, tokens))
    assert abs(loss - np.log(128)) < 0.5, loss


def test_flops_formulas():
    cfg = M.GPTConfig(vocab_size=50257, n_layer=24, n_head=16, n_kv_head=16,
                      d_model=2048, max_seq=2048)
    # GPT3-1.3B-ish: ~1.3e9 params
    assert 1.2e9 < cfg.n_params < 1.5e9
    f = M.train_step_flops(cfg, batch=1, seqlen=2048)
    # 6 * 2048 * 1.3e9 ~ 1.6e13 plus attention term
    assert 1.5e13 < f < 2.5e13
    a = M.attention_flops(2048, 64, 32, causal=False, mode="fwd")
    assert a == 4 * 2048**2 * 64 * 32
    assert M.attention_flops(2048, 64, 32, causal=True, mode="fwd") == a / 2
    assert M.attention_flops(2048, 64, 32, causal=False, mode="bwd") == 2.5 * a
    assert M.attention_flops(2048, 64, 32, causal=False, mode="fwd_bwd") == 3.5 * a
