"""FA1-style kernel and split-K ablation kernels vs the oracle.

These kernels exist to make the paper's ablations *executable*:
  - flash1_fwd: per-iteration rescale + (m, l) stored  (section 3.1.1)
  - splitk_fwd: partial-per-KV-chunk + combine pass    (section 3.3)
Both must produce the same output as FA2/reference — the paper's point is
that they differ in *work*, not in *result*.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    BlockSizes,
    attention_ref,
    combine_partials,
    flash1_fwd,
    flash2_fwd,
    splitk_fwd,
    splitk_fwd_partial,
)
from tests.conftest import make_qkv

ATOL = 3e-5


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("n,d", [(64, 16), (96, 32), (80, 8)])
def test_flash1_matches_ref(rng, causal, n, d):
    q, k, v = make_qkv(rng, 2, 2, 2, n, n, d)
    q, k, v = map(jnp.asarray, (q, k, v))
    o_ref, lse_ref = attention_ref(q, k, v, causal=causal)
    o, m, l = flash1_fwd(q, k, v, causal=causal, block_sizes=BlockSizes(32, 32))
    np.testing.assert_allclose(o, o_ref, atol=ATOL, rtol=ATOL)
    # FA1's (m, l) pair must recombine to FA2's single statistic: L = m+log(l)
    np.testing.assert_allclose(
        np.asarray(m) + np.log(np.asarray(l)), lse_ref, atol=ATOL, rtol=ATOL
    )


@pytest.mark.parametrize("n_split", [1, 2, 3, 4])
@pytest.mark.parametrize("causal", [False, True])
def test_splitk_matches_ref(rng, n_split, causal):
    q, k, v = make_qkv(rng, 1, 2, 2, 96, 96, 16)
    q, k, v = map(jnp.asarray, (q, k, v))
    o_ref, lse_ref = attention_ref(q, k, v, causal=causal)
    o, lse = splitk_fwd(
        q, k, v, n_split=n_split, causal=causal, block_sizes=BlockSizes(32, 32)
    )
    np.testing.assert_allclose(o, o_ref, atol=ATOL, rtol=ATOL)
    np.testing.assert_allclose(lse, lse_ref, atol=ATOL, rtol=ATOL)


def test_splitk_gqa(rng):
    q, k, v = make_qkv(rng, 1, 4, 2, 64, 64, 16)
    q, k, v = map(jnp.asarray, (q, k, v))
    o_ref, _ = attention_ref(q, k, v)
    o, _ = splitk_fwd(q, k, v, n_split=2, block_sizes=BlockSizes(32, 32))
    np.testing.assert_allclose(o, o_ref, atol=ATOL, rtol=ATOL)


def test_splitk_partials_are_locally_normalized(rng):
    """Each partial must itself be a valid attention over its KV chunk."""
    q, k, v = make_qkv(rng, 1, 1, 1, 32, 64, 8)
    q, k, v = map(jnp.asarray, (q, k, v))
    o_p, m_p, l_p = splitk_fwd_partial(
        q, k, v, n_split=2, block_sizes=BlockSizes(32, 32)
    )
    # Chunk 0 covers keys [0, 32): compare against reference over that slice.
    o_ref, lse_ref = attention_ref(q, k[:, :, :32], v[:, :, :32])
    o0 = np.asarray(o_p[0]) / np.asarray(l_p[0])[..., None]
    np.testing.assert_allclose(o0, o_ref, atol=ATOL, rtol=ATOL)
    np.testing.assert_allclose(
        np.asarray(m_p[0]) + np.log(np.asarray(l_p[0])), lse_ref, atol=ATOL
    )


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_rows=st.integers(1, 8),
    n_split=st.integers(2, 5),
    d=st.sampled_from([2, 4, 8]),
)
def test_combine_is_order_invariant(seed, n_rows, n_split, d):
    """Property: combine_partials is permutation-invariant in the split axis
    (the merge operator is associative+commutative — same property the Rust
    gpusim mirror is proptested on)."""
    rng = np.random.default_rng(seed)
    o_p = jnp.asarray(rng.normal(size=(n_split, 1, 1, n_rows, d)), jnp.float32)
    m_p = jnp.asarray(rng.normal(size=(n_split, 1, 1, n_rows)), jnp.float32)
    l_p = jnp.asarray(rng.uniform(0.1, 5.0, size=(n_split, 1, 1, n_rows)), jnp.float32)
    o1, lse1 = combine_partials(o_p, m_p, l_p)
    perm = rng.permutation(n_split)
    o2, lse2 = combine_partials(o_p[perm], m_p[perm], l_p[perm])
    np.testing.assert_allclose(o1, o2, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(lse1, lse2, atol=1e-5, rtol=1e-5)


def test_combine_handles_empty_chunk():
    """A chunk whose rows saw only -inf scores (m=-inf, l=0) must be a no-op."""
    o_p = jnp.stack([jnp.ones((1, 1, 4, 2)), jnp.zeros((1, 1, 4, 2))])
    m_p = jnp.stack([jnp.zeros((1, 1, 4)), jnp.full((1, 1, 4), -jnp.inf)])
    l_p = jnp.stack([jnp.full((1, 1, 4), 2.0), jnp.zeros((1, 1, 4))])
    o, lse = combine_partials(o_p, m_p, l_p)
    np.testing.assert_allclose(np.asarray(o), 0.5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(lse), np.log(2.0), atol=1e-6)


def test_all_variants_agree(rng):
    """FA2, FA1 and split-K must agree pairwise to tight tolerance."""
    q, k, v = make_qkv(rng, 1, 2, 2, 64, 64, 16)
    q, k, v = map(jnp.asarray, (q, k, v))
    bs = BlockSizes(16, 16)
    o2, _ = flash2_fwd(q, k, v, causal=True, block_sizes=bs)
    o1, _, _ = flash1_fwd(q, k, v, causal=True, block_sizes=bs)
    os, _ = splitk_fwd(q, k, v, n_split=2, causal=True, block_sizes=bs)
    np.testing.assert_allclose(o1, o2, atol=ATOL, rtol=ATOL)
    np.testing.assert_allclose(os, o2, atol=ATOL, rtol=ATOL)
