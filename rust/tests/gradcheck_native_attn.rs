//! Gradient check: `flash_bwd`'s dQ/dK/dV against central finite
//! differences of the reference forward, on tiny problems (N ≤ 32).
//!
//! Loss is L = Σ O ⊙ W for a fixed random W, so dL/dO = W is the `dout`
//! fed to the backward.  Each input element x gets the two-sided probe
//! (L(x+h) − L(x−h)) / 2h with h = 1e-2; perturbed values are stored back
//! as f32 (exactly what the kernel sees).  Tolerance is 1e-3 relative —
//! FD truncation + f32 quantization noise sit well under that on these
//! sizes.

use fa2::attn::exec::{parallel, reference, AttnDims, FlashParams};
use fa2::util::rng::Rng;

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

/// L = Σ O ⊙ W under the reference forward.
fn loss(q: &[f32], k: &[f32], v: &[f32], w: &[f32], dims: AttnDims) -> f64 {
    let out = reference::forward(q, k, v, dims);
    out.o.iter().zip(w).map(|(&o, &wi)| o as f64 * wi as f64).sum()
}

fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

/// Check every element of `grad` against the FD probe of `which` (0=q,
/// 1=k, 2=v).
#[allow(clippy::too_many_arguments)]
fn check_grad(
    name: &str,
    which: usize,
    grad: &[f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    w: &[f32],
    dims: AttnDims,
) {
    let h = 1e-2f32;
    let mut bufs = [q.to_vec(), k.to_vec(), v.to_vec()];
    for e in 0..grad.len() {
        let orig = bufs[which][e];
        bufs[which][e] = orig + h;
        let up = loss(&bufs[0], &bufs[1], &bufs[2], w, dims);
        bufs[which][e] = orig - h;
        let dn = loss(&bufs[0], &bufs[1], &bufs[2], w, dims);
        bufs[which][e] = orig;
        let fd = (up - dn) / (2.0 * h as f64);
        assert!(
            close(grad[e] as f64, fd, 1e-3),
            "{name}[{e}]: analytic {} vs FD {fd} ({dims:?})",
            grad[e]
        );
    }
}

fn gradcheck(dims: AttnDims, seed: u64) {
    assert!(dims.seq <= 32, "gradcheck is O(elems²·N) — keep problems tiny");
    let mut rng = Rng::seed_from(seed);
    let n = dims.elems();
    let (q, k, v, w) = (
        rand_vec(&mut rng, n),
        rand_vec(&mut rng, n),
        rand_vec(&mut rng, n),
        rand_vec(&mut rng, n),
    );
    let p = FlashParams { block_q: 8, block_k: 8 };
    let fwd = parallel::forward_with(1, &q, &k, &v, dims, p);
    let g = parallel::backward_with(1, &q, &k, &v, &fwd, &w, dims, p);
    check_grad("dQ", 0, &g.dq, &q, &k, &v, &w, dims);
    check_grad("dK", 1, &g.dk, &q, &k, &v, &w, dims);
    check_grad("dV", 2, &g.dv, &q, &k, &v, &w, dims);
}

#[test]
fn gradcheck_full_attention() {
    gradcheck(
        AttnDims { batch: 1, heads: 1, seq: 6, head_dim: 4, causal: false },
        0xFD01,
    );
}

#[test]
fn gradcheck_causal_attention() {
    gradcheck(
        AttnDims { batch: 1, heads: 2, seq: 8, head_dim: 4, causal: true },
        0xFD02,
    );
}

#[test]
fn gradcheck_blocks_crossing_diagonal() {
    // seq spans multiple 8-blocks so masked, partial, and full K-blocks all
    // occur in the backward tiling
    gradcheck(
        AttnDims { batch: 1, heads: 1, seq: 18, head_dim: 3, causal: true },
        0xFD03,
    );
}
