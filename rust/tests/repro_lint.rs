//! Self-application gate for the in-tree static analysis pass (DESIGN.md
//! §12): the committed tree must lint clean, and the `--verify-lint`
//! injection must turn the pass red.  This is the same honesty contract
//! as the bench gate's FA2_BENCH_INJECT_SLOWDOWN check — a gate that
//! cannot fail is not a gate.

use fa2::analysis::{self, RULES};
use fa2::bench::summary;

/// The committed tree lints clean: every hot-path panic is either fixed
/// or carries a justified `fa2lint: allow`, no float-literal equality
/// outside tests, benches register their metrics, the dependency policy
/// holds.  A violation here means a rule regressed or new code needs a
/// fix/allow — read the rendered diagnostics in the panic message.
#[test]
fn committed_tree_is_lint_clean() {
    let root = summary::workspace_root();
    let report = analysis::lint_workspace(&root, false).expect("workspace is readable");
    let rendered: Vec<String> = report.violations.iter().map(|d| d.render()).collect();
    assert!(
        report.clean(),
        "repro lint found {} violation(s):\n{}",
        report.violations.len(),
        rendered.join("\n")
    );
}

/// No stale suppressions: every `fa2lint: allow` in the tree must still
/// be needed.  Unused allows are warnings, not violations — but letting
/// them rot would make the allowlist meaningless, so the suite pins the
/// tree to zero.
#[test]
fn committed_tree_has_no_unused_allows() {
    let root = summary::workspace_root();
    let report = analysis::lint_workspace(&root, false).expect("workspace is readable");
    let rendered: Vec<String> = report.warnings.iter().map(|d| d.render()).collect();
    assert!(
        report.warnings.is_empty(),
        "{} stale lint warning(s):\n{}",
        report.warnings.len(),
        rendered.join("\n")
    );
}

/// The gate can actually fail: injecting the synthetic hot-path unwrap()
/// fixture must produce a no-hotpath-panic violation (what
/// `./ci.sh --verify-lint` checks end to end through the binary).
#[test]
fn injected_violation_turns_the_gate_red() {
    let root = summary::workspace_root();
    let clean = analysis::lint_workspace(&root, false).expect("workspace is readable");
    let poisoned = analysis::lint_workspace(&root, true).expect("workspace is readable");
    assert!(clean.clean());
    assert!(!poisoned.clean());
    assert_eq!(
        poisoned.violations.len(),
        clean.violations.len() + 1,
        "injection must add exactly one violation"
    );
    assert!(poisoned.violations.iter().any(|d| {
        d.rule == "no-hotpath-panic" && d.path.contains("__lint_inject_fixture")
    }));
}

/// The tree actually exercises the allow grammar: suppression totals are
/// non-zero (the justified hot-path expects in runtime/kv.rs et al), so
/// the clean result above is not vacuous.
#[test]
fn allowlist_is_exercised_by_the_real_tree() {
    let root = summary::workspace_root();
    let report = analysis::lint_workspace(&root, false).expect("workspace is readable");
    assert!(
        !report.suppressed.is_empty(),
        "expected at least one fa2lint allow to be live in the tree"
    );
}

/// Rule registry sanity: ids are unique, kebab-case, and documented.
#[test]
fn rule_catalog_is_well_formed() {
    let mut seen = std::collections::HashSet::new();
    for rule in RULES {
        assert!(seen.insert(rule.id), "duplicate rule id {}", rule.id);
        assert!(!rule.summary.is_empty(), "{} has no summary", rule.id);
        assert!(
            rule.id
                .chars()
                .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-'),
            "{} is not kebab-case",
            rule.id
        );
    }
    assert!(seen.contains("no-hotpath-panic"));
    assert!(seen.contains("no-float-eq"));
    assert!(seen.contains("dep-policy"));
}
