//! Integration: the session-based serving engine (Engine/Session,
//! streamed TokenEvents, SamplingParams, KV arena) on the native backend —
//! runs on a fresh checkout with no artifacts on disk.

use std::path::PathBuf;

use fa2::coordinator::engine::{
    Engine, EngineError, FinishReason, SamplingParams, TokenEvent,
};
use fa2::runtime::BackendKind;

fn engine() -> Engine {
    // the directory is never read: the native backend synthesizes its
    // manifest in memory
    Engine::start(PathBuf::from("artifacts"), "tiny", BackendKind::Native)
        .expect("native engine must start with no artifacts on disk")
}

#[test]
fn streamed_events_arrive_in_order_and_match_done() {
    let e = engine();
    let session = e.submit((1..=8).collect(), SamplingParams::greedy(5)).unwrap();
    let mut events = Vec::new();
    loop {
        let ev = session.recv().expect("stream ended without Done");
        let done = matches!(ev, TokenEvent::Done { .. });
        events.push(ev);
        if done {
            break;
        }
    }
    // First (index 0), then deltas with strictly consecutive indices
    let TokenEvent::First { token: first, ttft_secs } = &events[0] else {
        panic!("first event was {:?}", events[0]);
    };
    assert!(*ttft_secs >= 0.0);
    let mut streamed = vec![*first];
    for (i, ev) in events[1..events.len() - 1].iter().enumerate() {
        let TokenEvent::Delta { index, token } = ev else {
            panic!("mid-stream event was {ev:?}");
        };
        assert_eq!(*index, i + 1, "delta indices must be monotone");
        assert_eq!(ev.index(), Some(i + 1));
        streamed.push(*token);
    }
    let TokenEvent::Done { finish, tokens, latency_secs, ttft_secs: done_ttft } =
        events.last().unwrap()
    else {
        panic!("missing Done");
    };
    assert_eq!(*finish, FinishReason::MaxTokens);
    assert_eq!(tokens, &streamed, "Done tokens must equal the streamed sequence");
    assert_eq!(tokens.len(), 5);
    assert!(*latency_secs >= *done_ttft);
    e.shutdown().unwrap();
}

#[test]
fn grouped_decode_matches_solo_for_2_3_and_5_sequences() {
    // Exercises pad-row handling and bucket selection: 2 and 3 active
    // sequences ride the bucket-4 executable with padding, 5 splits into
    // groups of 4 + 1.  Greedy output must match each prompt served alone.
    let prompts: Vec<Vec<i32>> = (0..5)
        .map(|j| {
            let mut p: Vec<i32> = (1..=8).collect();
            p[0] = 10 + j;
            p
        })
        .collect();
    let solo: Vec<Vec<i32>> = prompts
        .iter()
        .map(|p| {
            let e = engine();
            let c = e.submit(p.clone(), SamplingParams::greedy(6)).unwrap().wait().unwrap();
            e.shutdown().unwrap();
            c.tokens
        })
        .collect();
    for n in [2usize, 3, 5] {
        let e = engine();
        let sessions: Vec<_> = prompts[..n]
            .iter()
            .map(|p| e.submit(p.clone(), SamplingParams::greedy(6)).unwrap())
            .collect();
        for (i, s) in sessions.into_iter().enumerate() {
            let c = s.wait().unwrap();
            assert_eq!(c.finish, FinishReason::MaxTokens);
            assert_eq!(c.tokens, solo[i], "n={n} seq {i}: grouped decode diverged");
        }
        let metrics = e.shutdown().unwrap();
        assert_eq!(metrics.requests(), n);
    }
}

#[test]
fn native_decode_moves_zero_kv_bytes() {
    // The acceptance bar: a full multi-request serve on the native backend
    // performs ZERO per-token KV assemble/scatter copies.
    let e = engine();
    let sessions: Vec<_> = (0..5)
        .map(|i| e.submit(vec![i + 1; 8], SamplingParams::greedy(4)).unwrap())
        .collect();
    for s in sessions {
        s.wait().unwrap();
    }
    let m = e.shutdown().unwrap();
    assert!(m.decode_steps() > 0, "workload must have decoded");
    assert_eq!(m.kv_gather_bytes(), 0, "native path assembled KV bytes");
    assert_eq!(m.kv_scatter_bytes(), 0, "native path scattered KV bytes");
    assert_eq!(m.kv_bytes_per_step(), 0.0);
}

#[test]
fn prompt_too_long_is_a_typed_error_not_silent_truncation() {
    let e = engine();
    let max = e.shapes().prompt_len;
    let err = e.submit(vec![1; max + 1], SamplingParams::greedy(2)).unwrap_err();
    assert_eq!(err, EngineError::PromptTooLong { len: max + 1, max });
    // an exactly-window prompt and a short prompt still serve fine
    let full = e.submit(vec![1; max], SamplingParams::greedy(2)).unwrap();
    let short = e.submit(vec![1; 4], SamplingParams::greedy(2)).unwrap();
    assert_eq!(full.wait().unwrap().tokens.len(), 2);
    assert_eq!(short.wait().unwrap().tokens.len(), 2);
    e.shutdown().unwrap();
}

#[test]
fn out_of_vocab_tokens_are_rejected_at_submit_not_fatal() {
    // One bad request must not poison the shared worker: the range check
    // happens at submit (typed error), and the engine keeps serving.
    let e = engine();
    let vocab = e.shapes().vocab;
    let err = e.submit(vec![100_000], SamplingParams::greedy(2)).unwrap_err();
    assert_eq!(err, EngineError::TokenOutOfVocab { token: 100_000, vocab });
    let err = e.submit(vec![1, -3, 2], SamplingParams::greedy(2)).unwrap_err();
    assert_eq!(err, EngineError::TokenOutOfVocab { token: -3, vocab });
    // the engine is still healthy after the rejections
    let c = e.submit(vec![1, 2, 3], SamplingParams::greedy(2)).unwrap().wait().unwrap();
    assert_eq!(c.tokens.len(), 2);
    e.shutdown().unwrap();
}

#[test]
fn stop_tokens_finish_generation_early() {
    let prompt: Vec<i32> = (1..=8).collect();
    let e = engine();
    let full = e.submit(prompt.clone(), SamplingParams::greedy(8)).unwrap().wait().unwrap();
    assert_eq!(full.tokens.len(), 8);
    // stop on a token we know greedy decoding will emit
    let stop = full.tokens[2];
    let stopped = e
        .submit(
            prompt,
            SamplingParams { stop_tokens: vec![stop], ..SamplingParams::greedy(8) },
        )
        .unwrap()
        .wait()
        .unwrap();
    e.shutdown().unwrap();
    assert_eq!(stopped.finish, FinishReason::Stop);
    assert_eq!(*stopped.tokens.last().unwrap(), stop, "stop token is included");
    assert!(stopped.tokens.len() <= 3);
    assert_eq!(
        stopped.tokens[..],
        full.tokens[..stopped.tokens.len()],
        "greedy prefix must be preserved up to the stop"
    );
}

#[test]
fn temperature_sampling_is_deterministic_given_seed() {
    let run = |seed: u64| -> Vec<i32> {
        let e = engine();
        let c = e
            .submit(
                (1..=8).collect(),
                SamplingParams {
                    max_tokens: 6,
                    temperature: 0.8,
                    top_k: 40,
                    seed,
                    stop_tokens: vec![],
                },
            )
            .unwrap()
            .wait()
            .unwrap();
        e.shutdown().unwrap();
        c.tokens
    };
    let a = run(7);
    assert_eq!(a, run(7), "same seed must reproduce the sampled sequence");
    assert_eq!(a.len(), 6);
    assert!(a.iter().all(|&t| (0..512).contains(&t)), "tokens within vocab");
}

#[test]
fn cancellation_retires_the_session_with_cancelled() {
    let e = engine();
    // ballast sessions queue ahead of the target, so the worker must
    // prefill them before it can even admit the target — by then the
    // cancel flag below is long since set (no race on the flag landing)
    let ballast: Vec<_> = (0..3)
        .map(|i| e.submit(vec![i + 1; 8], SamplingParams::greedy(10_000)).unwrap())
        .collect();
    let target = e.submit(vec![42; 8], SamplingParams::greedy(10_000)).unwrap();
    target.cancel();
    // cancel lands either before prefill (empty tokens) or at a decode
    // step boundary (partial tokens); both retire as Cancelled
    let comp = target.wait().unwrap();
    assert_eq!(comp.finish, FinishReason::Cancelled);
    assert!(comp.tokens.len() < 10_000);
    // dropping un-detached sessions cancels them too, releasing the worker
    drop(ballast);
    let m = e.shutdown().unwrap();
    assert!(m.cancelled() >= 1, "at least the explicit cancel must be counted");
}
