//! Integration: the session-based serving engine (Engine/Session,
//! streamed TokenEvents, SamplingParams, KV arena) and its continuous
//! batching scheduler (chunked prefill, Saturated backpressure,
//! anti-starvation preemption) on the native backend — runs on a fresh
//! checkout with no artifacts on disk.

use std::path::PathBuf;

use fa2::coordinator::engine::{
    Engine, EngineError, FinishReason, SamplingParams, TokenEvent,
};
use fa2::coordinator::scheduler::SchedulerConfig;
use fa2::runtime::{BackendKind, RuntimeOptions};

fn engine() -> Engine {
    // the directory is never read: the native backend synthesizes its
    // manifest in memory
    Engine::start(PathBuf::from("artifacts"), "tiny", BackendKind::Native)
        .expect("native engine must start with no artifacts on disk")
}

fn engine_with(cfg: SchedulerConfig) -> Engine {
    Engine::start_with(PathBuf::from("artifacts"), "tiny", BackendKind::Native, cfg)
        .expect("native engine must start with no artifacts on disk")
}

/// Greedy tokens for one prompt served alone on a fresh engine — the
/// byte-identity reference for every scheduling scenario below.
fn solo_tokens(prompt: &[i32], max_tokens: usize) -> Vec<i32> {
    let e = engine();
    let c = e
        .submit(prompt.to_vec(), SamplingParams::greedy(max_tokens))
        .unwrap()
        .wait()
        .unwrap();
    e.shutdown().unwrap();
    c.tokens
}

#[test]
fn streamed_events_arrive_in_order_and_match_done() {
    let e = engine();
    let session = e.submit((1..=8).collect(), SamplingParams::greedy(5)).unwrap();
    let mut events = Vec::new();
    loop {
        let ev = session.recv().expect("stream ended without Done");
        let done = matches!(ev, TokenEvent::Done { .. });
        events.push(ev);
        if done {
            break;
        }
    }
    // First (index 0), then deltas with strictly consecutive indices
    let TokenEvent::First { token: first, ttft_secs } = &events[0] else {
        panic!("first event was {:?}", events[0]);
    };
    assert!(*ttft_secs >= 0.0);
    let mut streamed = vec![*first];
    for (i, ev) in events[1..events.len() - 1].iter().enumerate() {
        let TokenEvent::Delta { index, token } = ev else {
            panic!("mid-stream event was {ev:?}");
        };
        assert_eq!(*index, i + 1, "delta indices must be monotone");
        assert_eq!(ev.index(), Some(i + 1));
        streamed.push(*token);
    }
    let TokenEvent::Done { finish, tokens, latency_secs, ttft_secs: done_ttft, .. } =
        events.last().unwrap()
    else {
        panic!("missing Done");
    };
    assert_eq!(*finish, FinishReason::MaxTokens);
    assert_eq!(tokens, &streamed, "Done tokens must equal the streamed sequence");
    assert_eq!(tokens.len(), 5);
    assert!(*latency_secs >= *done_ttft);
    e.shutdown().unwrap();
}

#[test]
fn grouped_decode_matches_solo_for_2_3_and_5_sequences() {
    // Exercises pad-row handling and bucket selection: 2 and 3 active
    // sequences ride the bucket-4 executable with padding, 5 splits into
    // groups of 4 + 1.  Greedy output must match each prompt served alone.
    let prompts: Vec<Vec<i32>> = (0..5)
        .map(|j| {
            let mut p: Vec<i32> = (1..=8).collect();
            p[0] = 10 + j;
            p
        })
        .collect();
    let solo: Vec<Vec<i32>> = prompts
        .iter()
        .map(|p| {
            let e = engine();
            let c = e.submit(p.clone(), SamplingParams::greedy(6)).unwrap().wait().unwrap();
            e.shutdown().unwrap();
            c.tokens
        })
        .collect();
    for n in [2usize, 3, 5] {
        let e = engine();
        let sessions: Vec<_> = prompts[..n]
            .iter()
            .map(|p| e.submit(p.clone(), SamplingParams::greedy(6)).unwrap())
            .collect();
        for (i, s) in sessions.into_iter().enumerate() {
            let c = s.wait().unwrap();
            assert_eq!(c.finish, FinishReason::MaxTokens);
            assert_eq!(c.tokens, solo[i], "n={n} seq {i}: grouped decode diverged");
        }
        let metrics = e.shutdown().unwrap();
        assert_eq!(metrics.requests(), n);
    }
}

#[test]
fn native_decode_moves_zero_kv_bytes() {
    // The acceptance bar: a full multi-request serve on the native backend
    // performs ZERO per-token KV assemble/scatter copies.
    let e = engine();
    let sessions: Vec<_> = (0..5)
        .map(|i| e.submit(vec![i + 1; 8], SamplingParams::greedy(4)).unwrap())
        .collect();
    for s in sessions {
        s.wait().unwrap();
    }
    let m = e.shutdown().unwrap();
    assert!(m.decode_steps() > 0, "workload must have decoded");
    assert_eq!(m.kv_gather_bytes(), 0, "native path assembled KV bytes");
    assert_eq!(m.kv_scatter_bytes(), 0, "native path scattered KV bytes");
    assert_eq!(m.kv_bytes_per_step(), 0.0);
}

#[test]
fn prompt_too_long_is_a_typed_error_not_silent_truncation() {
    let e = engine();
    let max = e.shapes().prompt_len;
    let err = e.submit(vec![1; max + 1], SamplingParams::greedy(2)).unwrap_err();
    assert_eq!(err, EngineError::PromptTooLong { len: max + 1, max });
    // an exactly-window prompt and a short prompt still serve fine
    let full = e.submit(vec![1; max], SamplingParams::greedy(2)).unwrap();
    let short = e.submit(vec![1; 4], SamplingParams::greedy(2)).unwrap();
    assert_eq!(full.wait().unwrap().tokens.len(), 2);
    assert_eq!(short.wait().unwrap().tokens.len(), 2);
    e.shutdown().unwrap();
}

#[test]
fn out_of_vocab_tokens_are_rejected_at_submit_not_fatal() {
    // One bad request must not poison the shared worker: the range check
    // happens at submit (typed error), and the engine keeps serving.
    let e = engine();
    let vocab = e.shapes().vocab;
    let err = e.submit(vec![100_000], SamplingParams::greedy(2)).unwrap_err();
    assert_eq!(err, EngineError::TokenOutOfVocab { token: 100_000, vocab });
    let err = e.submit(vec![1, -3, 2], SamplingParams::greedy(2)).unwrap_err();
    assert_eq!(err, EngineError::TokenOutOfVocab { token: -3, vocab });
    // the engine is still healthy after the rejections
    let c = e.submit(vec![1, 2, 3], SamplingParams::greedy(2)).unwrap().wait().unwrap();
    assert_eq!(c.tokens.len(), 2);
    e.shutdown().unwrap();
}

#[test]
fn stop_tokens_finish_generation_early() {
    let prompt: Vec<i32> = (1..=8).collect();
    let e = engine();
    let full = e.submit(prompt.clone(), SamplingParams::greedy(8)).unwrap().wait().unwrap();
    assert_eq!(full.tokens.len(), 8);
    // stop on a token we know greedy decoding will emit
    let stop = full.tokens[2];
    let stopped = e
        .submit(
            prompt,
            SamplingParams { stop_tokens: vec![stop], ..SamplingParams::greedy(8) },
        )
        .unwrap()
        .wait()
        .unwrap();
    e.shutdown().unwrap();
    assert_eq!(stopped.finish, FinishReason::Stop);
    assert_eq!(*stopped.tokens.last().unwrap(), stop, "stop token is included");
    assert!(stopped.tokens.len() <= 3);
    assert_eq!(
        stopped.tokens[..],
        full.tokens[..stopped.tokens.len()],
        "greedy prefix must be preserved up to the stop"
    );
}

#[test]
fn continuous_mixed_arrivals_stay_byte_identical_to_solo() {
    // The tentpole acceptance bar: the continuous scheduler changes WHEN
    // work runs (stragglers admitted mid-flight, prefill chunked between
    // decode steps), never WHAT it computes — every session's greedy
    // tokens must equal its solo run.
    let prompts: Vec<Vec<i32>> = (0..4)
        .map(|j| {
            let mut p: Vec<i32> = (1..=8).collect();
            p[0] = 30 + j;
            p
        })
        .collect();
    let budgets = [12usize, 9, 7, 5];
    let solo: Vec<Vec<i32>> = prompts
        .iter()
        .zip(budgets)
        .map(|(p, n)| solo_tokens(p, n))
        .collect();

    let e = engine();
    // two sessions up front...
    let first: Vec<_> = (0..2)
        .map(|i| e.submit(prompts[i].clone(), SamplingParams::greedy(budgets[i])).unwrap())
        .collect();
    // ...and two stragglers submitted only once session 0 is demonstrably
    // decoding (its deltas are streaming), i.e. genuinely mid-flight
    loop {
        let ev = first[0].recv().expect("stream ended early");
        if ev.index().map_or(true, |i| i >= 2) {
            break;
        }
    }
    let late: Vec<_> = (2..4)
        .map(|i| e.submit(prompts[i].clone(), SamplingParams::greedy(budgets[i])).unwrap())
        .collect();
    for (i, s) in first.into_iter().chain(late).enumerate() {
        let c = s.wait().unwrap();
        assert_eq!(c.finish, FinishReason::MaxTokens);
        assert_eq!(c.tokens, solo[i], "session {i}: mixed-arrival decode diverged from solo");
    }
    let m = e.shutdown().unwrap();
    assert_eq!(m.requests(), 4);
    assert_eq!(m.kv_bytes_per_step(), 0.0, "chunked prefill must stay in-place");
}

#[test]
fn saturated_backpressure_is_typed_and_recovers() {
    // max_in_flight 1 pins the only KV slot on a long-running session;
    // max_queue 2 then bounds how many submissions may wait.  The huge
    // starvation bound keeps preemption out of this test's way.
    let e = engine_with(SchedulerConfig {
        max_in_flight: 1,
        max_queue: 2,
        starvation_bound: 1_000_000,
        ..SchedulerConfig::default()
    });
    let hog = e.submit(vec![9; 8], SamplingParams::greedy(10_000)).unwrap();
    // once the hog's first token streams it has been ADMITTED, so the
    // queue depth is exactly 0 before the fill-up below
    assert!(matches!(hog.recv(), Some(TokenEvent::First { .. })));
    let q1 = e.submit(vec![1; 8], SamplingParams::greedy(2)).unwrap();
    let q2 = e.submit(vec![2; 8], SamplingParams::greedy(2)).unwrap();
    let err = e.submit(vec![3; 8], SamplingParams::greedy(2)).unwrap_err();
    assert_eq!(err, EngineError::Saturated { max_queue: 2 });
    // backpressure is pressure, not failure: cancelling the hog frees the
    // slot, the queue drains FCFS, and new submissions are accepted again
    hog.cancel();
    assert_eq!(hog.wait().unwrap().finish, FinishReason::Cancelled);
    assert_eq!(q1.wait().unwrap().tokens.len(), 2);
    assert_eq!(q2.wait().unwrap().tokens.len(), 2);
    let q3 = e.submit(vec![3; 8], SamplingParams::greedy(2)).unwrap();
    assert_eq!(q3.wait().unwrap().tokens.len(), 2);
    let m = e.shutdown().unwrap();
    assert_eq!(m.requests(), 3);
    assert_eq!(m.cancelled(), 1);
}

#[test]
fn preemption_resumes_byte_identically_to_an_uninterrupted_run() {
    // One slot, a tight anti-starvation bound: the late short session must
    // evict the long one (recompute-style preemption), run, and hand the
    // slot back — and the long session's resumed stream must be
    // byte-identical to its solo run (the replay rebuilds the same cache
    // bit for bit).
    let long_prompt = vec![7; 8];
    let short_prompt = vec![11; 8];
    let long_solo = solo_tokens(&long_prompt, 48);
    let short_solo = solo_tokens(&short_prompt, 4);

    let e = engine_with(SchedulerConfig {
        max_in_flight: 1,
        starvation_bound: 6,
        prefill_chunk: 4,
        ..SchedulerConfig::default()
    });
    let long = e.submit(long_prompt, SamplingParams::greedy(48)).unwrap();
    // ensure the long session holds the slot before the starver arrives
    assert!(matches!(long.recv(), Some(TokenEvent::First { .. })));
    let short = e.submit(short_prompt, SamplingParams::greedy(4)).unwrap();
    let short_c = short.wait().unwrap();
    let long_c = long.wait().unwrap();
    let m = e.shutdown().unwrap();
    assert_eq!(short_c.tokens, short_solo, "preempting session diverged");
    assert_eq!(long_c.tokens, long_solo, "preempted session resumed differently");
    assert_eq!(long_c.tokens.len(), 48);
    assert!(
        m.preemptions() >= 1,
        "the starving session should have evicted the long one at the bound"
    );
    assert_eq!(m.requests(), 2);
}

#[test]
fn gqa_window_model_serves_with_zero_kv_copies() {
    // The AttnSpec axes reach serving end to end: a GQA (4 query / 2 KV
    // heads) sliding-window model decodes deterministically over the
    // paged arena with zero assemble/scatter bytes.
    let opts = RuntimeOptions { n_kv_heads: Some(2), window: Some(32) };
    let run = || -> Vec<Vec<i32>> {
        let e = Engine::start_full(
            PathBuf::from("artifacts"),
            "tiny",
            BackendKind::Native,
            SchedulerConfig::default(),
            opts,
        )
        .expect("GQA+window native engine must start");
        assert_eq!(e.shapes().n_kv_head, 2, "manifest reflects the GQA config");
        let sessions: Vec<_> = (0..3)
            .map(|i| e.submit(vec![i + 1; 8], SamplingParams::greedy(6)).unwrap())
            .collect();
        let tokens: Vec<Vec<i32>> =
            sessions.into_iter().map(|s| s.wait().unwrap().tokens).collect();
        let m = e.shutdown().unwrap();
        assert_eq!(m.kv_bytes_per_step(), 0.0, "paged GQA decode must stay in-place");
        tokens
    };
    let a = run();
    assert_eq!(a, run(), "GQA+window generation must be deterministic");
    assert_eq!(a.len(), 3);
    assert!(a.iter().all(|t| t.len() == 6));
    // MQA (1 KV head) must also serve
    let e = Engine::start_full(
        PathBuf::from("artifacts"),
        "tiny",
        BackendKind::Native,
        SchedulerConfig::default(),
        RuntimeOptions { n_kv_heads: Some(1), window: None },
    )
    .expect("MQA native engine must start");
    let c = e.submit(vec![3; 8], SamplingParams::greedy(4)).unwrap().wait().unwrap();
    assert_eq!(c.tokens.len(), 4);
    e.shutdown().unwrap();
    // a KV head count that does not divide n_head is a typed startup error
    assert!(Engine::start_full(
        PathBuf::from("artifacts"),
        "tiny",
        BackendKind::Native,
        SchedulerConfig::default(),
        RuntimeOptions { n_kv_heads: Some(3), window: None },
    )
    .is_err());
}

#[test]
fn block_reservation_packs_short_sessions_where_slabs_could_not() {
    // A 3-block arena cannot hold even ONE full 8-block window — under
    // the old slab-per-sequence design nothing could serve.  Block-level
    // reservation admits three short sessions concurrently (1 block each:
    // 8 prompt + 4 generated = 12 tokens < 16-token block), and rejects a
    // window-sized request with a typed error at submit.
    let e = engine_with(SchedulerConfig {
        max_in_flight: 4,
        kv_block: 16,
        kv_blocks: Some(3),
        ..SchedulerConfig::default()
    });
    let err = e.submit(vec![1; 8], SamplingParams::greedy(10_000)).unwrap_err();
    assert!(
        matches!(err, EngineError::ExceedsKvCapacity { need_blocks: 8, capacity_blocks: 3 }),
        "window-sized request must be rejected up front: {err:?}"
    );
    let sessions: Vec<_> = (0..3)
        .map(|i| e.submit(vec![i + 1; 8], SamplingParams::greedy(4)).unwrap())
        .collect();
    for s in sessions {
        let c = s.wait().unwrap();
        assert_eq!(c.finish, FinishReason::MaxTokens);
        assert_eq!(c.tokens.len(), 4);
    }
    let m = e.shutdown().unwrap();
    assert_eq!(m.requests(), 3);
    assert_eq!(m.kv_bytes_per_step(), 0.0);
}

#[test]
fn temperature_sampling_is_deterministic_given_seed() {
    let run = |seed: u64| -> Vec<i32> {
        let e = engine();
        let c = e
            .submit(
                (1..=8).collect(),
                SamplingParams {
                    max_tokens: 6,
                    temperature: 0.8,
                    top_k: 40,
                    seed,
                    stop_tokens: vec![],
                },
            )
            .unwrap()
            .wait()
            .unwrap();
        e.shutdown().unwrap();
        c.tokens
    };
    let a = run(7);
    assert_eq!(a, run(7), "same seed must reproduce the sampled sequence");
    assert_eq!(a.len(), 6);
    assert!(a.iter().all(|&t| (0..512).contains(&t)), "tokens within vocab");
}

#[test]
fn cancellation_retires_the_session_with_cancelled() {
    let e = engine();
    // ballast sessions keep the worker busy; whether the cancel flag lands
    // while the target is still pending, mid-prefill, or decoding, the
    // session must retire as Cancelled at the next step boundary
    let ballast: Vec<_> = (0..3)
        .map(|i| e.submit(vec![i + 1; 8], SamplingParams::greedy(10_000)).unwrap())
        .collect();
    let target = e.submit(vec![42; 8], SamplingParams::greedy(10_000)).unwrap();
    target.cancel();
    // cancel lands either before prefill (empty tokens) or at a decode
    // step boundary (partial tokens); both retire as Cancelled
    let comp = target.wait().unwrap();
    assert_eq!(comp.finish, FinishReason::Cancelled);
    assert!(comp.tokens.len() < 10_000);
    // dropping un-detached sessions cancels them too, releasing the worker
    drop(ballast);
    let m = e.shutdown().unwrap();
    assert!(m.cancelled() >= 1, "at least the explicit cancel must be counted");
}
