//! Observability integration suite (DESIGN.md §13): golden deterministic
//! trace, the unclosed-span validator failure path, Prometheus snapshot
//! round-trips, and the scheduler audit-log replay.
//!
//! These tests flip the PROCESS-GLOBAL trace gate (`set_enabled`), which
//! is exactly why they live in their own integration binary instead of
//! the lib test runner: here a static mutex serializes them, and no lib
//! unit test can observe the gate mid-flip.

use std::path::PathBuf;
use std::sync::Mutex;

use fa2::coordinator::engine::{Engine, SamplingParams};
use fa2::obs::counters::Counters;
use fa2::obs::{expo, trace};
use fa2::runtime::BackendKind;
use fa2::util::json::Json;
use fa2::util::rng::Rng;

/// Serializes every test in this binary: they all mutate the global
/// trace recorder.  Poison recovery keeps one failed test from wedging
/// the rest into opaque `PoisonError` noise.
static GATE: Mutex<()> = Mutex::new(());

fn serialized() -> std::sync::MutexGuard<'static, ()> {
    match GATE.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// A fixed single-threaded recording: an outer span, four inner spans,
/// one event per inner with rng-derived args.  Under the logical clock
/// this must serialize to the exact same bytes on every run.
fn record_fixture(seed: u64) -> String {
    trace::reset();
    trace::set_logical(true);
    trace::set_enabled(true);
    let mut rng = Rng::seed_from(seed);
    {
        let _outer = fa2::obs_span!("test_span_outer");
        for i in 0..4u64 {
            let _inner = fa2::obs_span!("test_span_inner");
            fa2::obs_event!("test_event", "i" => i, "draw" => rng.below(1000));
        }
    }
    let doc = trace::export_json().expect("fixture trace must export");
    trace::set_enabled(false);
    trace::set_logical(false);
    trace::reset();
    doc
}

#[test]
fn golden_trace_is_byte_deterministic() {
    let _g = serialized();
    let a = record_fixture(7);
    let b = record_fixture(7);
    assert_eq!(a, b, "logical-clock recordings must be byte-identical");
    // different rng stream changes args, nothing else structural
    let c = record_fixture(8);
    assert_ne!(a, c, "the rng args must actually land in the trace");

    let j = Json::parse(&a).expect("exporter emits valid JSON");
    let evs = j.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents array");
    // 4 instants + 4 inner completes + 1 outer complete
    assert_eq!(evs.len(), 9);
    let field = |e: &Json, k: &str| e.get(k).and_then(|v| v.as_str().map(str::to_string));
    let n = |e: &Json, k: &str| e.get(k).and_then(|v| v.as_f64());
    for e in evs {
        assert_eq!(n(e, "pid"), Some(1.0));
        assert_eq!(n(e, "tid"), Some(0.0), "logical mode pins tids to 0");
        let name = field(e, "name").expect("name");
        let ph = field(e, "ph").expect("ph");
        match name.as_str() {
            "test_event" => {
                assert_eq!(ph, "i");
                assert!(e.get("args").and_then(|a| a.get("draw")).is_some());
            }
            "test_span_inner" | "test_span_outer" => {
                assert_eq!(ph, "X");
                assert!(n(e, "dur").expect("complete events carry dur") > 0.0);
            }
            other => panic!("unexpected event {other}"),
        }
        assert_eq!(field(e, "cat").as_deref(), Some("test"));
    }
    // exporter sorts by ts: the outer span (opened at tick 0) comes first
    assert_eq!(field(&evs[0], "name").as_deref(), Some("test_span_outer"));
}

#[test]
fn unclosed_span_turns_the_validator_red() {
    let _g = serialized();
    trace::reset();
    trace::set_enabled(true);
    trace::inject_unclosed();
    let err = trace::export_json().expect_err("a leaked span guard must fail export");
    assert!(format!("{err:#}").contains("never closed"), "{err:#}");
    trace::set_enabled(false);
    trace::reset();
    assert!(trace::export_json().is_ok(), "reset must re-arm the validator");
}

#[test]
fn prometheus_snapshot_roundtrips_through_a_file() {
    let _g = serialized();
    let c = Counters::new();
    c.add("engine_steps_total", 42);
    c.add("flash_fwd_flops_total", 3_000);
    c.add("flash_fwd_ns_total", 1_500);
    c.set("kv_blocks_in_use", 7);
    let text = expo::prometheus(&c);
    assert_eq!(text, expo::prometheus(&c), "rendering must be deterministic");
    assert!(text.contains("\nfa2_engine_steps_total 42\n"), "{text}");
    assert!(text.contains("\nfa2_flash_fwd_gflops 2\n"), "derived gauge:\n{text}");

    let dir = std::env::temp_dir().join("fa2_obs_trace_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("metrics.prom");
    expo::write_prometheus(&path, &c).unwrap();
    assert_eq!(std::fs::read_to_string(&path).unwrap(), text);

    // every exposed sample agrees with the JSON snapshot
    let snap = expo::json_snapshot(&c);
    for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
        let (name, value) = line.split_once(' ').expect("sample line");
        assert!(name.starts_with("fa2_"), "unprefixed series {name}");
        let from_json = snap
            .get(name)
            .and_then(|v| v.as_f64())
            .unwrap_or_else(|| panic!("{name} missing from the JSON snapshot"));
        let want: f64 = value.parse().unwrap();
        assert!((from_json - want).abs() < 1e-9, "{name}: {from_json} != {want}");
    }
}

#[test]
fn audit_log_replays_fcfs_admission_order() {
    let _g = serialized();
    trace::reset();
    trace::set_logical(true);
    trace::set_enabled(true);

    let engine = Engine::start(PathBuf::from("artifacts"), "tiny", BackendKind::Native)
        .expect("native engine needs no artifacts");
    let sessions: Vec<_> = (0..5)
        .map(|j| {
            let mut prompt: Vec<i32> = (1..=6).collect();
            prompt[0] = 10 + j;
            engine.submit(prompt, SamplingParams::greedy(4)).expect("submit")
        })
        .collect();
    for s in sessions {
        s.wait().expect("session completes");
    }
    engine.shutdown().expect("shutdown joins the worker, spilling its ring");

    let doc = trace::export_json().expect("engine run must leave no open spans");
    trace::set_enabled(false);
    trace::set_logical(false);
    trace::reset();

    let j = Json::parse(&doc).expect("valid trace JSON");
    let evs = j.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents");
    let names: Vec<&str> = evs
        .iter()
        .filter_map(|e| e.get("name").and_then(|n| n.as_str()))
        .collect();
    for required in ["engine_step", "sched_plan", "attn_decode_step", "sched_admit"] {
        assert!(names.contains(&required), "trace is missing {required}: {names:?}");
    }

    // Replay the admission audit log: traceEvents are ts-sorted, so the
    // FIRST sched_admit per session id must appear in submit order —
    // exactly the FCFS contract the scheduler property test promises.
    let mut first_admissions = Vec::new();
    for e in evs {
        if e.get("name").and_then(|n| n.as_str()) != Some("sched_admit") {
            continue;
        }
        let id = e
            .get("args")
            .and_then(|a| a.get("session"))
            .and_then(|v| v.as_i64())
            .expect("sched_admit carries the session id");
        if !first_admissions.contains(&id) {
            first_admissions.push(id);
        }
    }
    assert_eq!(first_admissions.len(), 5, "every session admits exactly once");
    let mut sorted = first_admissions.clone();
    sorted.sort_unstable();
    assert_eq!(
        first_admissions, sorted,
        "admission order diverged from FCFS submit order"
    );
}
