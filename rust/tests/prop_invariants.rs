//! Property tests over the pure substrates (no artifacts needed):
//! split-K combine algebra, gpusim monotonicity, schedule accounting,
//! batcher/ordering (complementing the in-module proptests).

use fa2::attn::combine::{merge_all, Partial};
use fa2::attn::{kernels_for, AttnProblem, Method, Pass};
use fa2::gpusim::{occupancy, simulate, waves, BlockResources, Device};
use fa2::prop_assert;
use fa2::util::prop::{check, close, PropConfig};
use fa2::util::rng::Rng;

fn random_partial(rng: &mut Rng, d: usize) -> Partial {
    let n = rng.range_usize(1, 6);
    let scores: Vec<f64> = (0..n).map(|_| rng.normal() * 3.0).collect();
    let values: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..d).map(|_| rng.normal()).collect())
        .collect();
    Partial::from_scores(&scores, &values)
}

#[test]
fn prop_combine_is_associative() {
    check("combine-associative", PropConfig::default(), |rng| {
        let d = rng.range_usize(1, 5);
        let (a, b, c) = (
            random_partial(rng, d),
            random_partial(rng, d),
            random_partial(rng, d),
        );
        let left = a.merge(&b).merge(&c).finalize();
        let right = a.merge(&b.merge(&c)).finalize();
        for (x, y) in left.0.iter().zip(&right.0) {
            prop_assert!(close(*x, *y, 1e-9), "O mismatch {x} vs {y}");
        }
        prop_assert!(close(left.1, right.1, 1e-9), "LSE mismatch");
        Ok(())
    });
}

#[test]
fn prop_combine_split_equals_whole() {
    // Splitting a score/value stream at ANY point and merging the partials
    // must equal the monolithic softmax — the correctness core of both
    // split-K (section 3.3) and flash-decoding.
    check("combine-split-invariance", PropConfig::default(), |rng| {
        let d = rng.range_usize(1, 4);
        let n = rng.range_usize(2, 12);
        let scores: Vec<f64> = (0..n).map(|_| rng.normal() * 5.0).collect();
        let values: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..d).map(|_| rng.normal()).collect())
            .collect();
        let whole = Partial::from_scores(&scores, &values).finalize();
        // random partition into up to 4 chunks
        let mut cuts: Vec<usize> = (0..rng.range_usize(0, 3))
            .map(|_| rng.range_usize(0, n + 1))
            .collect();
        cuts.push(0);
        cuts.push(n);
        cuts.sort();
        let parts: Vec<Partial> = cuts
            .windows(2)
            .map(|w| Partial::from_scores(&scores[w[0]..w[1]], &values[w[0]..w[1]]))
            .collect();
        let merged = merge_all(&parts).finalize();
        for (x, y) in whole.0.iter().zip(&merged.0) {
            prop_assert!(close(*x, *y, 1e-9), "{x} vs {y} (cuts {cuts:?})");
        }
        prop_assert!(close(whole.1, merged.1, 1e-9), "LSE (cuts {cuts:?})");
        Ok(())
    });
}

#[test]
fn prop_gpusim_more_work_never_faster() {
    check("gpusim-monotone-work", PropConfig::default(), |rng| {
        let dev = Device::a100();
        let base = AttnProblem {
            batch: rng.range_i64(1, 8) as u64,
            heads: rng.range_i64(1, 32) as u64,
            seqlen: 256 << rng.range_i64(0, 5),
            head_dim: *rng.choice(&[64u64, 128]),
            causal: rng.next_f64() < 0.5,
            dtype_bytes: 2,
        };
        let bigger = AttnProblem { seqlen: base.seqlen * 2, ..base };
        for m in Method::all() {
            let t1 = fa2::attn::simulate_time(&dev, &base, m, Pass::Fwd);
            let t2 = fa2::attn::simulate_time(&dev, &bigger, m, Pass::Fwd);
            prop_assert!(
                t2 >= t1 * 0.99,
                "{m:?}: doubling seqlen got faster ({t1} -> {t2}) for {base:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_gpusim_faster_device_never_slower() {
    check("gpusim-monotone-device", PropConfig::default(), |rng| {
        let p = AttnProblem {
            batch: rng.range_i64(1, 16) as u64,
            heads: rng.range_i64(1, 32) as u64,
            seqlen: 128 << rng.range_i64(0, 6),
            head_dim: *rng.choice(&[64u64, 128]),
            causal: rng.next_f64() < 0.5,
            dtype_bytes: 2,
        };
        for m in Method::all() {
            let ta = fa2::attn::simulate_time(&Device::a100(), &p, m, Pass::FwdBwd);
            let th = fa2::attn::simulate_time(&Device::h100(), &p, m, Pass::FwdBwd);
            prop_assert!(th <= ta * 1.01, "{m:?}: H100 slower than A100 for {p:?}");
        }
        Ok(())
    });
}

#[test]
fn prop_causal_never_more_expensive() {
    check("causal-cheaper", PropConfig::default(), |rng| {
        let dev = Device::a100();
        let full = AttnProblem {
            batch: rng.range_i64(1, 8) as u64,
            heads: rng.range_i64(2, 16) as u64,
            seqlen: 512 << rng.range_i64(0, 4),
            head_dim: *rng.choice(&[64u64, 128]),
            causal: false,
            dtype_bytes: 2,
        };
        let causal = AttnProblem { causal: true, ..full };
        for m in [Method::Flash1, Method::Flash2, Method::Triton] {
            let tf = fa2::attn::simulate_time(&dev, &full, m, Pass::Fwd);
            let tc = fa2::attn::simulate_time(&dev, &causal, m, Pass::Fwd);
            prop_assert!(tc <= tf * 1.01, "{m:?}: causal slower for {full:?}");
        }
        Ok(())
    });
}

#[test]
fn prop_kernels_have_finite_positive_work() {
    check("kernels-sane", PropConfig::default(), |rng| {
        let p = AttnProblem {
            batch: rng.range_i64(1, 8) as u64,
            heads: rng.range_i64(1, 16) as u64,
            seqlen: 128 << rng.range_i64(0, 5),
            head_dim: *rng.choice(&[64u64, 128]),
            causal: rng.next_f64() < 0.5,
            dtype_bytes: 2,
        };
        for m in Method::all() {
            for pass in [Pass::Fwd, Pass::Bwd, Pass::FwdBwd] {
                for k in kernels_for(&p, m, pass) {
                    prop_assert!(k.grid > 0, "{m:?} zero grid");
                    prop_assert!(
                        k.matmul_flops >= 0.0 && k.matmul_flops.is_finite(),
                        "{m:?} bad matmul flops"
                    );
                    prop_assert!(k.hbm_bytes > 0.0, "{m:?} no traffic");
                    let cost = simulate(&Device::a100(), &k);
                    prop_assert!(
                        cost.time.is_finite() && cost.time > 0.0,
                        "{m:?}/{pass:?} infinite time: {:?}", k.label
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_occupancy_bounds() {
    check("occupancy-bounds", PropConfig::default(), |rng| {
        let dev = Device::a100();
        let res = BlockResources {
            threads: 32 * rng.range_i64(1, 16) as u32,
            regs_per_thread: rng.range_i64(16, 256) as u32,
            smem_bytes: rng.range_usize(0, 200 * 1024),
        };
        let occ = occupancy(&dev, res);
        prop_assert!(
            occ.blocks_per_sm <= dev.max_blocks_per_sm,
            "blocks/SM over cap"
        );
        let grid = rng.range_i64(1, 100_000) as u64;
        let w = waves(&dev, &occ, grid);
        prop_assert!(w.sm_fill >= 0.0 && w.sm_fill <= 1.0, "fill {}", w.sm_fill);
        prop_assert!(
            w.efficiency >= 0.0 && w.efficiency <= 1.0 + 1e-12,
            "eff {}", w.efficiency
        );
        if occ.concurrent_blocks > 0 {
            prop_assert!(
                w.waves == grid.div_ceil(occ.concurrent_blocks),
                "wave count"
            );
        }
        Ok(())
    });
}
