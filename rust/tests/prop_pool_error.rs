//! Property tests for the std-only substrates added with the workspace
//! resurrection: `util::pool` (parallel results identical to serial
//! execution, ordering preserved, no deadlock on degenerate workloads) and
//! `util::error` (context chaining).

use fa2::prop_assert;
use fa2::util::error::{Context, Error, Result as FaResult};
use fa2::util::pool;
use fa2::util::prop::{check, PropConfig};

#[test]
fn prop_par_map_matches_serial() {
    check("pool-parallel-equals-serial", PropConfig::default(), |rng| {
        let n = rng.range_usize(0, 65);
        let workers = rng.range_usize(1, 9);
        let items: Vec<u64> = (0..n).map(|_| rng.next_u64() >> 32).collect();
        let f = |x: u64| x.wrapping_mul(2654435761).rotate_left(13) ^ 0xFA2;
        let serial: Vec<u64> = items.iter().map(|&x| f(x)).collect();
        let parallel = pool::par_map_with(workers, items, f);
        prop_assert!(
            serial == parallel,
            "parallel != serial with {workers} workers over {n} items"
        );
        Ok(())
    });
}

#[test]
fn pool_degenerate_workloads_terminate() {
    // empty, single-item, and oversubscribed (workers >> items) must all
    // complete without deadlock.
    assert_eq!(pool::par_map_with(8, Vec::<u32>::new(), |x| x), Vec::<u32>::new());
    assert_eq!(pool::par_map_with(8, vec![7u32], |x| x * 3), vec![21]);
    assert_eq!(pool::par_map_with(64, vec![1u32, 2, 3], |x| x), vec![1, 2, 3]);
    // and many more items than workers
    let out = pool::par_map_with(4, (0..10_000usize).collect(), |x| x + 1);
    assert_eq!(out.len(), 10_000);
    assert!(out.iter().enumerate().all(|(i, &v)| v == i + 1));
}

#[test]
fn pool_keeps_order_under_skewed_work() {
    // Wildly uneven per-item cost: work stealing must rebalance without
    // reordering the result vector.
    let out = pool::par_map_with(8, (0..200usize).collect(), |i| {
        if i % 17 == 0 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        i * i
    });
    assert!(out.iter().enumerate().all(|(i, &v)| v == i * i));
}

#[test]
fn pool_default_api_is_deterministic() {
    // The env-driven entry point used by the sweeps: repeated runs agree.
    let a = pool::par_map((0..500usize).collect::<Vec<_>>(), |i| i * 3 + 1);
    let b = pool::par_map((0..500usize).collect::<Vec<_>>(), |i| i * 3 + 1);
    assert_eq!(a, b);
    assert!(pool::threads() >= 1);
}

#[test]
fn prop_error_context_chains_in_order() {
    check("error-context-chain", PropConfig::default(), |rng| {
        let depth = rng.range_usize(1, 6);
        let mut res: FaResult<()> = Err(Error::msg("root"));
        let mut expect = vec!["root".to_string()];
        for i in 0..depth {
            let layer = format!("layer{i}");
            res = res.with_context(|| layer.clone());
            expect.insert(0, layer);
        }
        let err = res.unwrap_err();
        prop_assert!(
            format!("{err}") == expect[0],
            "Display must show the outermost context, got {err}"
        );
        let full = format!("{err:#}");
        let want = expect.join(": ");
        prop_assert!(full == want, "chain {full:?} != {want:?}");
        prop_assert!(err.root_cause() == "root", "root cause lost");
        Ok(())
    });
}

#[test]
fn error_interops_with_std_option_and_bail() {
    let io: FaResult<()> = Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
        .context("reading manifest");
    let e = io.unwrap_err();
    assert_eq!(format!("{e}"), "reading manifest");
    assert!(format!("{e:#}").contains("gone"));

    let none: FaResult<u32> = None.context("missing key");
    assert_eq!(format!("{}", none.unwrap_err()), "missing key");

    fn bails(x: u32) -> FaResult<u32> {
        if x == 0 {
            fa2::bail!("x must be nonzero (got {x})");
        }
        Ok(x)
    }
    assert_eq!(bails(5).unwrap(), 5);
    assert!(format!("{}", bails(0).unwrap_err()).contains("nonzero"));
}
