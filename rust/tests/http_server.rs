//! Integration: the srv HTTP/1.1 + SSE front-end against a live native
//! engine over real TCP sockets — byte-identical tokens vs. in-process
//! sessions, the wire error-mapping matrix, injected saturation, budget
//! shedding, and graceful shutdown.  Runs on a fresh checkout with no
//! artifacts on disk (native backend).

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::path::PathBuf;

use fa2::coordinator::engine::{Engine, SamplingParams, TokenEvent};
use fa2::runtime::BackendKind;
use fa2::srv::admission::AdmissionConfig;
use fa2::srv::{HttpServer, HttpServerConfig};

fn engine() -> Engine {
    // the directory is never read: the native backend synthesizes its
    // manifest in memory
    Engine::start(PathBuf::from("artifacts"), "tiny", BackendKind::Native)
        .expect("native engine must start with no artifacts on disk")
}

fn server_with(cfg: HttpServerConfig) -> (Engine, HttpServer, SocketAddr) {
    let e = engine();
    let s = HttpServer::start("127.0.0.1:0", e.handle(), cfg).expect("bind ephemeral port");
    let addr = s.local_addr();
    (e, s, addr)
}

fn server() -> (Engine, HttpServer, SocketAddr) {
    server_with(HttpServerConfig::default())
}

/// Send raw bytes, read the full response (Connection: close semantics).
fn raw_request(addr: SocketAddr, raw: &[u8]) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(raw).expect("send");
    let _ = s.shutdown(Shutdown::Write);
    let mut buf = String::new();
    s.read_to_string(&mut buf).expect("read response");
    buf
}

fn post(addr: SocketAddr, path: &str, body: &str) -> String {
    let raw = format!(
        "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    raw_request(addr, raw.as_bytes())
}

fn get(addr: SocketAddr, path: &str) -> String {
    raw_request(addr, format!("GET {path} HTTP/1.1\r\nHost: t\r\n\r\n").as_bytes())
}

fn status_of(resp: &str) -> u16 {
    resp.split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("unparseable response: {resp:?}"))
}

fn body_of(resp: &str) -> &str {
    resp.split_once("\r\n\r\n").map(|(_, b)| b).unwrap_or("")
}

/// The canonical wire form of a token list (matches `Json::Num`
/// integer serialization), for byte-level comparison inside bodies.
fn tokens_json(tokens: &[i32]) -> String {
    let items: Vec<String> = tokens.iter().map(|t| t.to_string()).collect();
    format!("\"tokens\":[{}]", items.join(","))
}

/// Greedy tokens for one prompt served alone on a fresh in-process
/// engine — the byte-identity reference.
fn solo_tokens(prompt: &[i32], max_tokens: usize) -> Vec<i32> {
    let e = engine();
    let c = e
        .submit(prompt.to_vec(), SamplingParams::greedy(max_tokens))
        .unwrap()
        .wait()
        .unwrap();
    e.shutdown().unwrap();
    c.tokens
}

#[test]
fn health_and_metrics_answer_over_tcp() {
    let (e, s, addr) = server();
    let health = get(addr, "/health");
    assert_eq!(status_of(&health), 200, "{health}");
    assert!(body_of(&health).contains("\"status\":\"ok\""), "{health}");
    assert!(body_of(&health).contains("\"queue_depth\""), "{health}");

    let metrics = get(addr, "/metrics");
    assert_eq!(status_of(&metrics), 200);
    // the Prometheus text includes the http counter series
    assert!(body_of(&metrics).contains("http_requests_total"), "{metrics}");
    assert!(body_of(&metrics).contains("# HELP"), "{metrics}");

    s.shutdown();
    e.shutdown().unwrap();
}

#[test]
fn generate_tokens_are_byte_identical_to_in_process_session() {
    let prompt: Vec<i32> = (1..=8).collect();
    let expected = solo_tokens(&prompt, 6);

    let (e, s, addr) = server();
    let resp = post(addr, "/generate", r#"{"prompt":[1,2,3,4,5,6,7,8],"max_tokens":6}"#);
    assert_eq!(status_of(&resp), 200, "{resp}");
    let body = body_of(&resp);
    assert!(body.contains(&tokens_json(&expected)), "want {expected:?} in {body}");
    assert!(body.contains("\"finish\":\"max_tokens\""), "{body}");
    assert!(body.contains("\"n_tokens\":6"), "{body}");

    s.shutdown();
    e.shutdown().unwrap();
}

#[test]
fn sse_stream_is_byte_identical_to_in_process_events() {
    let prompt: Vec<i32> = (3..=10).collect();
    // in-process reference: the exact event sequence for the same request
    let e = engine();
    let session = e.submit(prompt.clone(), SamplingParams::greedy(5)).unwrap();
    let mut ref_tokens = Vec::new();
    let ref_done = loop {
        match session.recv().expect("in-process stream ended early") {
            TokenEvent::First { token, .. } => ref_tokens.push(token),
            TokenEvent::Delta { token, .. } => ref_tokens.push(token),
            TokenEvent::Done { tokens, .. } => break tokens,
        }
    };
    assert_eq!(ref_tokens, ref_done, "streamed vs final tokens must agree");
    e.shutdown().unwrap();

    let (e, s, addr) = server();
    let resp = post(addr, "/generate_stream", r#"{"prompt":[3,4,5,6,7,8,9,10],"max_tokens":5}"#);
    assert_eq!(status_of(&resp), 200, "{resp}");
    assert!(resp.contains("Content-Type: text/event-stream"), "{resp}");

    // parse the SSE frames: first, then deltas, then exactly one done
    let body = body_of(&resp);
    let frames: Vec<&str> = body.split("\n\n").filter(|f| !f.trim().is_empty()).collect();
    let mut wire_tokens: Vec<i32> = Vec::new();
    for (i, frame) in frames.iter().enumerate() {
        let mut event = "";
        let mut data = "";
        for line in frame.lines() {
            if let Some(v) = line.strip_prefix("event: ") {
                event = v;
            } else if let Some(v) = line.strip_prefix("data: ") {
                data = v;
            }
        }
        match (i, event) {
            (0, "first") => {
                assert!(data.contains("\"index\":0"), "{data}");
                assert!(data.contains("\"ttft_ms\""), "{data}");
            }
            (_, "delta") => assert!(data.contains(&format!("\"index\":{i}")), "{data}"),
            (_, "done") => {
                assert_eq!(i, frames.len() - 1, "done must be the final frame");
                // the done frame carries the full token list, byte-equal
                // to the in-process completion
                assert!(data.contains(&tokens_json(&ref_done)), "want {ref_done:?} in {data}");
                assert!(data.contains("\"finish\":\"max_tokens\""), "{data}");
                continue;
            }
            other => panic!("unexpected frame {other:?}: {frame}"),
        }
        // extract "token":N
        let tok = data
            .split("\"token\":")
            .nth(1)
            .and_then(|rest| rest.split([',', '}']).next())
            .and_then(|s| s.trim().parse::<i32>().ok())
            .unwrap_or_else(|| panic!("no token in {data}"));
        wire_tokens.push(tok);
    }
    assert_eq!(wire_tokens, ref_tokens, "SSE token stream must match in-process events");

    s.shutdown();
    e.shutdown().unwrap();
}

#[test]
fn wire_error_matrix_maps_statuses() {
    let (e, s, addr) = server();

    // unparseable HTTP -> 400
    let resp = raw_request(addr, b"NONSENSE\r\n\r\n");
    assert_eq!(status_of(&resp), 400, "{resp}");
    // body not JSON -> 400
    let resp = post(addr, "/generate", "not json");
    assert_eq!(status_of(&resp), 400, "{resp}");
    assert!(body_of(&resp).contains("body_not_json"), "{resp}");
    // missing prompt -> 422
    let resp = post(addr, "/generate", "{}");
    assert_eq!(status_of(&resp), 422, "{resp}");
    assert!(body_of(&resp).contains("missing_prompt"), "{resp}");
    // empty prompt -> 422
    let resp = post(addr, "/generate", r#"{"prompt":[]}"#);
    assert_eq!(status_of(&resp), 422, "{resp}");
    // token out of vocab -> 422
    let resp = post(addr, "/generate", r#"{"prompt":[99999]}"#);
    assert_eq!(status_of(&resp), 422, "{resp}");
    assert!(body_of(&resp).contains("token_out_of_vocab"), "{resp}");
    // over-long prompt -> 422 (prompt window is 16 on the tiny model)
    let long: Vec<String> = (0..64).map(|i| (i % 100).to_string()).collect();
    let resp = post(addr, "/generate", &format!(r#"{{"prompt":[{}]}}"#, long.join(",")));
    assert_eq!(status_of(&resp), 422, "{resp}");
    assert!(body_of(&resp).contains("prompt_too_long"), "{resp}");
    // bad sampling field -> 422; unknown field -> 422
    let resp = post(addr, "/generate", r#"{"prompt":[1],"max_tokens":0}"#);
    assert_eq!(status_of(&resp), 422, "{resp}");
    let resp = post(addr, "/generate", r#"{"prompt":[1],"max_token":4}"#);
    assert_eq!(status_of(&resp), 422, "{resp}");
    assert!(body_of(&resp).contains("unknown_field"), "{resp}");
    // unknown route -> 404; wrong method -> 405 with Allow
    let resp = get(addr, "/nope");
    assert_eq!(status_of(&resp), 404, "{resp}");
    let resp = get(addr, "/generate");
    assert_eq!(status_of(&resp), 405, "{resp}");
    assert!(resp.contains("Allow: POST"), "{resp}");
    let resp = post(addr, "/health", "{}");
    assert_eq!(status_of(&resp), 405, "{resp}");

    // the engine survived the whole gauntlet
    let health = get(addr, "/health");
    assert_eq!(status_of(&health), 200);
    s.shutdown();
    e.shutdown().unwrap();
}

#[test]
fn injected_saturation_sheds_429_without_wedging_the_engine() {
    let cfg = HttpServerConfig { inject_saturate: true, ..HttpServerConfig::default() };
    let (e, s, addr) = server_with(cfg);

    let resp = post(addr, "/generate", r#"{"prompt":[1,2,3],"max_tokens":4}"#);
    assert_eq!(status_of(&resp), 429, "{resp}");
    assert!(resp.contains("Retry-After: 1"), "{resp}");
    assert!(body_of(&resp).contains("saturated"), "{resp}");
    let resp = post(addr, "/generate_stream", r#"{"prompt":[1,2,3],"max_tokens":4}"#);
    assert_eq!(status_of(&resp), 429, "{resp}");

    // health still answers, and the engine still serves in-process
    assert_eq!(status_of(&get(addr, "/health")), 200);
    let c = e
        .submit(vec![1, 2, 3], SamplingParams::greedy(2))
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(c.tokens.len(), 2);

    s.shutdown();
    e.shutdown().unwrap();
}

#[test]
fn token_budget_sheds_a_second_request_with_429() {
    // total budget fits one stream (8 + 112 = 120 <= 128) but not a
    // second request while the first is still generating
    let cfg = HttpServerConfig {
        admission: AdmissionConfig {
            max_batch_prefill_tokens: 0,
            max_batch_total_tokens: 128,
            waiting_served_ratio: 0.0,
            max_in_flight: 8,
        },
        ..HttpServerConfig::default()
    };
    let (e, s, addr) = server_with(cfg);

    // hold a long stream open: read only the first SSE frame, then keep
    // the connection (and its budget reservation) alive
    let mut stream = TcpStream::connect(addr).expect("connect");
    let body = r#"{"prompt":[1,2,3,4,5,6,7,8],"max_tokens":112}"#;
    stream
        .write_all(
            format!(
                "POST /generate_stream HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        )
        .expect("send");
    let mut first = [0u8; 64];
    let n = stream.read(&mut first).expect("first sse bytes");
    assert!(n > 0, "stream produced no bytes");

    // while ~112 tokens are still decoding, a second request must shed
    let resp = post(addr, "/generate", r#"{"prompt":[1,2],"max_tokens":16}"#);
    assert_eq!(status_of(&resp), 429, "{resp}");
    assert!(body_of(&resp).contains("total_budget"), "{resp}");
    assert!(resp.contains("Retry-After: 1"), "{resp}");

    // drain the held stream; after it completes the budget frees up
    let mut rest = String::new();
    stream.read_to_string(&mut rest).expect("drain stream");
    assert!(rest.contains("event: done"), "{rest}");
    let resp = post(addr, "/generate", r#"{"prompt":[1,2],"max_tokens":16}"#);
    assert_eq!(status_of(&resp), 200, "{resp}");

    s.shutdown();
    e.shutdown().unwrap();
}

#[test]
fn graceful_shutdown_drains_an_in_flight_stream() {
    let (e, s, addr) = server();

    // open a long-running stream and read its first frame so we know the
    // session is live before shutdown starts
    let mut stream = TcpStream::connect(addr).expect("connect");
    let body = r#"{"prompt":[1,2,3,4,5,6,7,8],"max_tokens":112}"#;
    stream
        .write_all(
            format!(
                "POST /generate_stream HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        )
        .expect("send");
    let mut first = [0u8; 32];
    assert!(stream.read(&mut first).expect("first sse bytes") > 0);

    // shutdown drains: the handler cancels the session, the engine sends
    // Done{Cancelled}, and the client still gets a terminal done frame
    let reader = std::thread::spawn(move || {
        let mut rest = String::new();
        stream.read_to_string(&mut rest).expect("drain stream");
        rest
    });
    s.shutdown();
    let rest = reader.join().expect("reader thread");
    assert!(rest.contains("event: done"), "no terminal frame after shutdown: {rest}");

    // every server-held EngineHandle was released: shutdown completes
    e.shutdown().unwrap();
}

#[test]
fn admin_shutdown_raises_the_drain_latch() {
    let (e, s, addr) = server();
    assert!(!s.shutdown_requested());
    let resp = post(addr, "/admin/shutdown", "");
    assert_eq!(status_of(&resp), 200, "{resp}");
    assert!(body_of(&resp).contains("draining"), "{resp}");
    // the latch is up: wait returns immediately instead of blocking
    s.wait_shutdown_requested();
    assert!(s.shutdown_requested());
    // health reports draining once the latch is raised
    let health = get(addr, "/health");
    assert!(body_of(&health).contains("draining"), "{health}");
    s.shutdown();
    e.shutdown().unwrap();
}
