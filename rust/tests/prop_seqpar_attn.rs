//! Properties of the sequence-parallel ring executor (DESIGN.md §16):
//!
//! - oracle parity: ring forward/backward within 1e-4 of the O(N²)
//!   reference at seq 512 — 4× the single-slab window of 128 — across
//!   causal, GQA, and sliding-window masks;
//! - determinism: outputs are **byte-identical** at every worker count,
//!   including counts that do not divide the chunk count (the merge order
//!   is keyed by absolute K-chunk index, never arrival order).  ci.sh runs
//!   this test under FA2_SEQPAR_INJECT_SKEW=1 and requires it to FAIL —
//!   proving the invariant is load-bearing, not vacuous;
//! - gradcheck: the ring backward's dQ/dK/dV match central finite
//!   differences of the reference forward on tiny problems;
//! - shard skipping: sliding-window shards nobody attends are never
//!   shipped, and measured ring bytes always equal the plan's prediction
//!   (the gpusim calibration contract).

use fa2::attn::exec::reference;
use fa2::attn::exec::seqpar::{backward_spec, forward_spec, SeqParParams, SeqParPlan};
use fa2::attn::spec::{AttnSpec, HeadMap, Mask};
use fa2::util::rng::Rng;

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn max_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

fn draws(spec: AttnSpec, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::seed_from(seed);
    let q = rand_vec(&mut rng, spec.q_elems());
    let k = rand_vec(&mut rng, spec.kv_elems());
    let v = rand_vec(&mut rng, spec.kv_elems());
    let dout = rand_vec(&mut rng, spec.q_elems());
    (q, k, v, dout)
}

#[test]
fn oracle_parity_at_4x_the_single_slab_window() {
    // seq 512 = 4 × the 128-token sliding window: shards expire
    // mid-ring, GQA groups share KV rows, and the causal diagonal crosses
    // many chunk boundaries.  Every variant must still match the O(N²)
    // oracle to 1e-4 in both passes.
    let cases = [
        (HeadMap::mha(2), Mask::Causal, 4usize),
        (HeadMap { n_q_heads: 4, n_kv_heads: 2 }, Mask::Causal, 5),
        (HeadMap { n_q_heads: 4, n_kv_heads: 1 }, Mask::SlidingWindow(128), 8),
        (HeadMap::mha(2), Mask::Full, 3),
    ];
    for (i, &(heads, mask, workers)) in cases.iter().enumerate() {
        let spec = AttnSpec { batch: 1, heads, seq: 512, head_dim: 16, mask };
        spec.validate().unwrap();
        let (q, k, v, dout) = draws(spec, 0x5EED + i as u64);
        let prm = SeqParParams { workers, chunk: 64, striped: true };

        let (out, _) = forward_spec(&q, &k, &v, spec, prm).expect("seqpar fwd");
        let rf = reference::forward_spec(&q, &k, &v, spec);
        assert!(
            max_diff(&out.o, &rf.o) < 1e-4,
            "fwd O diverged from oracle ({mask:?}, W={workers}): {}",
            max_diff(&out.o, &rf.o)
        );
        assert!(max_diff(&out.lse, &rf.lse) < 1e-4, "fwd LSE diverged ({mask:?})");

        let (g, _) = backward_spec(&q, &k, &v, &out, &dout, spec, prm).expect("seqpar bwd");
        let rg = reference::backward_spec(&q, &k, &v, &dout, spec);
        for (name, got, want) in
            [("dQ", &g.dq, &rg.dq), ("dK", &g.dk, &rg.dk), ("dV", &g.dv, &rg.dv)]
        {
            assert!(
                max_diff(got, want) < 1e-4,
                "bwd {name} diverged from oracle ({mask:?}, W={workers}): {}",
                max_diff(got, want)
            );
        }
    }
}

#[test]
fn byte_identical_across_worker_counts() {
    // The tentpole invariant: W is an execution detail, not a numeric
    // input.  seq 193 / chunk 16 gives 13 chunks — indivisible by every
    // tested W, so shards are ragged and stripes wrap unevenly.
    // FA2_SEQPAR_INJECT_SKEW=1 disables the deterministic merge sort and
    // MUST make this test fail (ci.sh --verify-seqpar proves it does).
    let spec = AttnSpec {
        batch: 2,
        heads: HeadMap { n_q_heads: 4, n_kv_heads: 2 },
        seq: 193,
        head_dim: 8,
        mask: Mask::Causal,
    };
    let (q, k, v, dout) = draws(spec, 0xB17E);
    let solo = SeqParParams { workers: 1, chunk: 16, striped: true };
    let (base, _) = forward_spec(&q, &k, &v, spec, solo).expect("W=1 fwd");
    let (bg, _) = backward_spec(&q, &k, &v, &base, &dout, spec, solo).expect("W=1 bwd");
    for workers in [2usize, 3, 5, 8] {
        for striped in [true, false] {
            let prm = SeqParParams { workers, chunk: 16, striped };
            let (out, _) = forward_spec(&q, &k, &v, spec, prm).expect("fwd");
            assert_eq!(out.o, base.o, "O not byte-identical at W={workers} striped={striped}");
            assert_eq!(out.lse, base.lse, "LSE not byte-identical at W={workers}");
            let (g, _) = backward_spec(&q, &k, &v, &base, &dout, spec, prm).expect("bwd");
            assert_eq!(g.dq, bg.dq, "dQ not byte-identical at W={workers} striped={striped}");
            assert_eq!(g.dk, bg.dk, "dK not byte-identical at W={workers} striped={striped}");
            assert_eq!(g.dv, bg.dv, "dV not byte-identical at W={workers} striped={striped}");
        }
    }
}

fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

/// L = Σ O ⊙ W under the reference forward (dL/dO = W is the `dout`).
fn loss(q: &[f32], k: &[f32], v: &[f32], w: &[f32], spec: AttnSpec) -> f64 {
    let out = reference::forward_spec(q, k, v, spec);
    out.o.iter().zip(w).map(|(&o, &wi)| o as f64 * wi as f64).sum()
}

#[test]
fn gradcheck_ring_backward() {
    // Central finite differences against the reference forward, h = 1e-2,
    // 1e-3 relative tolerance (same recipe as gradcheck_native_attn) —
    // but the analytic gradients come from the W=3 ring backward, so the
    // dK/dV contribution shuttle and exclusive-owner accumulation are on
    // the checked path.  Tiny problem: FD is O(elems² · N).
    let spec = AttnSpec {
        batch: 1,
        heads: HeadMap { n_q_heads: 2, n_kv_heads: 1 },
        seq: 24,
        head_dim: 4,
        mask: Mask::Causal,
    };
    let (q, k, v, w) = draws(spec, 0xFD5E);
    let prm = SeqParParams { workers: 3, chunk: 4, striped: true };
    let (fwd, _) = forward_spec(&q, &k, &v, spec, prm).expect("fwd");
    let (g, _) = backward_spec(&q, &k, &v, &fwd, &w, spec, prm).expect("bwd");

    let h = 1e-2f32;
    let mut bufs = [q.clone(), k.clone(), v.clone()];
    for (name, which, grad) in [("dQ", 0usize, &g.dq), ("dK", 1, &g.dk), ("dV", 2, &g.dv)] {
        for e in 0..grad.len() {
            let orig = bufs[which][e];
            bufs[which][e] = orig + h;
            let up = loss(&bufs[0], &bufs[1], &bufs[2], &w, spec);
            bufs[which][e] = orig - h;
            let dn = loss(&bufs[0], &bufs[1], &bufs[2], &w, spec);
            bufs[which][e] = orig;
            let fd = (up - dn) / (2.0 * h as f64);
            assert!(
                close(grad[e] as f64, fd, 1e-3),
                "{name}[{e}]: ring analytic {} vs FD {fd}",
                grad[e]
            );
        }
    }
}

#[test]
fn window_shards_skip_and_bytes_match_plan_on_two_shapes() {
    // Calibration contract + shard skipping, on the executing layer's
    // side: measured ring traffic equals the plan's closed-form byte
    // count, and a tight sliding window leaves provably-dead shards
    // unshipped.  The window shape uses contiguous Q ownership: striping
    // spreads a shard's neighbor Q-chunks across ranks, so only the
    // contiguous layout can prove a shard fully dead.
    let shapes = [
        (
            AttnSpec {
                batch: 1,
                heads: HeadMap::mha(2),
                seq: 512,
                head_dim: 16,
                mask: Mask::SlidingWindow(64),
            },
            8usize,
            false,
        ),
        (
            AttnSpec {
                batch: 2,
                heads: HeadMap { n_q_heads: 4, n_kv_heads: 2 },
                seq: 320,
                head_dim: 8,
                mask: Mask::Causal,
            },
            4,
            true,
        ),
    ];
    for &(spec, workers, striped) in &shapes {
        let (q, k, v, _) = draws(spec, 0xCA1B);
        let prm = SeqParParams { workers, chunk: 32, striped };
        let plan = SeqParPlan::build(&spec, &prm);
        let (_, st) = forward_spec(&q, &k, &v, spec, prm).expect("fwd");
        assert_eq!(st.comm_bytes, plan.fwd_comm_bytes(&spec), "bytes diverge ({spec:?})");
        assert_eq!(st.comm_msgs, plan.fwd_comm_msgs(), "msgs diverge ({spec:?})");
        assert_eq!(st.steps, workers);
        if matches!(spec.mask, Mask::SlidingWindow(_)) {
            assert!(
                st.shards_unshipped > 0,
                "a 64-token window over 512 tokens at W=8 must strand shards"
            );
        }
    }
    // and the window must ship strictly less than a Full mask would
    let (w_spec, workers, _) = shapes[0];
    let full = AttnSpec { mask: Mask::Full, ..w_spec };
    let prm = SeqParParams { workers, chunk: 32, striped: true };
    let windowed = SeqParPlan::build(&w_spec, &prm).fwd_comm_bytes(&w_spec);
    let shipped_full = SeqParPlan::build(&full, &prm).fwd_comm_bytes(&full);
    assert!(
        windowed < shipped_full,
        "window {windowed} B should undercut full {shipped_full} B"
    );
}
