//! Property tests for the unified `AttnSpec` API (DESIGN.md §11): the
//! flash kernels must match the O(N²) reference oracle on EVERY axis
//! combination — head maps (MHA / GQA / MQA) × masks (full / causal /
//! sliding-window) × block geometries — and the paged KV layout must
//! decode **bit-identically** to the contiguous one.
//!
//! - forward parity ≤ 1e-4 over random `n_q_heads/n_kv_heads` ratios
//!   (incl. MQA `n_kv = 1`) and window sizes;
//! - backward parity ≤ 1e-4 on the same axes, plus a central
//!   finite-difference gradcheck ≤ 1e-3 on tiny GQA/window problems;
//! - `decode_splitkv_spec` over a `Paged` block table is bitwise equal to
//!   the `Contiguous` run (same chunk boundaries), for any block size,
//!   history length, and window clip;
//! - parallel execution stays byte-identical to serial on the spec paths.
//!
//! Replay failures with FA2_PROP_SEED / FA2_PROP_CASES (see util::prop).

use fa2::attn::exec::{parallel, reference, FlashParams};
use fa2::attn::spec::{AttnSpec, BlockTable, HeadMap, KvLayout, Mask};
use fa2::prop_assert;
use fa2::util::prop::{check, PropConfig};
use fa2::util::rng::Rng;

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn max_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// A random spec: every head-ratio in {1, 2, 4, MQA}, every mask, awkward
/// seqlens.
fn rand_spec(rng: &mut Rng, max_seq: usize) -> AttnSpec {
    let n_kv_heads = *rng.choice(&[1usize, 2, 4]);
    let group = *rng.choice(&[1usize, 2, 4]);
    let seq = rng.range_usize(1, max_seq + 1);
    let mask = match rng.range_usize(0, 3) {
        0 => Mask::Full,
        1 => Mask::Causal,
        _ => Mask::SlidingWindow(rng.range_usize(1, seq + 4)),
    };
    AttnSpec {
        batch: rng.range_usize(1, 3),
        heads: HeadMap { n_q_heads: n_kv_heads * group, n_kv_heads },
        seq,
        head_dim: *rng.choice(&[8usize, 16, 64]),
        mask,
    }
}

fn rand_params(rng: &mut Rng) -> FlashParams {
    FlashParams {
        block_q: *rng.choice(&[4usize, 8, 16, 33, 64]),
        block_k: *rng.choice(&[4usize, 8, 16, 33, 64]),
    }
}

#[test]
fn prop_spec_forward_matches_reference() {
    let cfg = PropConfig { cases: 40, ..PropConfig::default() };
    check("spec-fwd-parity", cfg, |rng| {
        let spec = rand_spec(rng, 48);
        let p = rand_params(rng);
        let q = rand_vec(rng, spec.q_elems());
        let k = rand_vec(rng, spec.kv_elems());
        let v = rand_vec(rng, spec.kv_elems());
        let fl = parallel::forward_spec_with(1, &q, &k, &v, spec, p);
        let rf = reference::forward_spec(&q, &k, &v, spec);
        let od = max_diff(&fl.o, &rf.o);
        prop_assert!(od < 1e-4, "O diff {od} for {spec:?} {p:?}");
        let ld = max_diff(&fl.lse, &rf.lse);
        prop_assert!(ld < 1e-4, "LSE diff {ld} for {spec:?} {p:?}");
        Ok(())
    });
}

#[test]
fn prop_spec_backward_matches_reference() {
    let cfg = PropConfig { cases: 24, ..PropConfig::default() };
    check("spec-bwd-parity", cfg, |rng| {
        let mut spec = rand_spec(rng, 25);
        spec.head_dim = *rng.choice(&[8usize, 16]);
        let p = rand_params(rng);
        let q = rand_vec(rng, spec.q_elems());
        let k = rand_vec(rng, spec.kv_elems());
        let v = rand_vec(rng, spec.kv_elems());
        let dout = rand_vec(rng, spec.q_elems());
        let fwd = parallel::forward_spec_with(1, &q, &k, &v, spec, p);
        let g = parallel::backward_spec_with(1, &q, &k, &v, &fwd, &dout, spec, p);
        let r = reference::backward_spec(&q, &k, &v, &dout, spec);
        for (name, got, want) in
            [("dQ", &g.dq, &r.dq), ("dK", &g.dk, &r.dk), ("dV", &g.dv, &r.dv)]
        {
            let d = max_diff(got, want);
            prop_assert!(d < 1e-4, "{name} diff {d} for {spec:?} {p:?}");
        }
        prop_assert!(g.dk.len() == spec.kv_elems(), "dK must be KV-shaped");
        Ok(())
    });
}

#[test]
fn prop_spec_parallel_equals_serial_bitwise() {
    let cfg = PropConfig { cases: 24, ..PropConfig::default() };
    check("spec-parallel-serial-identical", cfg, |rng| {
        let spec = rand_spec(rng, 40);
        let p = rand_params(rng);
        let workers = rng.range_usize(2, 9);
        let q = rand_vec(rng, spec.q_elems());
        let k = rand_vec(rng, spec.kv_elems());
        let v = rand_vec(rng, spec.kv_elems());
        let dout = rand_vec(rng, spec.q_elems());
        let serial = parallel::forward_spec_with(1, &q, &k, &v, spec, p);
        let par = parallel::forward_spec_with(workers, &q, &k, &v, spec, p);
        prop_assert!(serial.o == par.o, "forward O diverged at {workers} workers");
        prop_assert!(serial.lse == par.lse, "forward LSE diverged");
        let gs = parallel::backward_spec_with(1, &q, &k, &v, &serial, &dout, spec, p);
        let gp = parallel::backward_spec_with(workers, &q, &k, &v, &serial, &dout, spec, p);
        prop_assert!(gs.dq == gp.dq, "dQ diverged at {workers} workers");
        prop_assert!(gs.dk == gp.dk, "dK diverged");
        prop_assert!(gs.dv == gp.dv, "dV diverged");
        Ok(())
    });
}

/// Build a paged copy of `n` contiguous rows: blocks of `bt` rows at
/// shuffled physical positions (plus a decoy plane to prove the plane
/// offset is honored), returning the pools + table.
fn paginate(
    rng: &mut Rng,
    flat_k: &[f32],
    flat_v: &[f32],
    n: usize,
    d: usize,
    bt: usize,
) -> (Vec<f32>, Vec<f32>, Vec<u32>, usize, usize) {
    let n_blocks = (n + bt - 1) / bt;
    let planes = 2; // plane 0 is a decoy filled with garbage
    let block_elems = planes * bt * d;
    let plane = bt * d; // our rows live in plane 1
    let mut phys: Vec<u32> = (0..n_blocks as u32).collect();
    rng.shuffle(&mut phys);
    let mut k_pool = vec![f32::NAN; n_blocks * block_elems];
    let mut v_pool = vec![f32::NAN; n_blocks * block_elems];
    for (logical, &pb) in phys.iter().enumerate() {
        let t0 = logical * bt;
        let rows = bt.min(n - t0);
        let dst = pb as usize * block_elems + plane;
        k_pool[dst..dst + rows * d].copy_from_slice(&flat_k[t0 * d..(t0 + rows) * d]);
        v_pool[dst..dst + rows * d].copy_from_slice(&flat_v[t0 * d..(t0 + rows) * d]);
    }
    (k_pool, v_pool, phys, block_elems, plane)
}

#[test]
fn prop_paged_decode_is_bitwise_identical_to_contiguous() {
    check("paged-vs-contiguous-decode", PropConfig::default(), |rng| {
        let d = *rng.choice(&[8usize, 16, 64]);
        let n = rng.range_usize(1, 160);
        let bt = *rng.choice(&[1usize, 4, 16, 32]);
        let q = rand_vec(rng, d);
        let k = rand_vec(rng, n * d);
        let v = rand_vec(rng, n * d);
        let scale = 1.0 / (d as f32).sqrt();
        // random window clip [lo, hi): hi is the current position + 1
        let hi = rng.range_usize(1, n + 1);
        let lo = rng.range_usize(0, hi);

        let contig = KvLayout::Contiguous { k: &k, v: &v };
        // the contiguous run chunked at the SAME block size...
        let (oc, lc) = parallel::decode_splitkv_spec(&q, &contig, lo, hi, scale, bt);
        // ...must be bit-identical to the paged run over a shuffled pool
        let (k_pool, v_pool, table, block_elems, plane) =
            paginate(rng, &k, &v, n, d, bt);
        let paged = KvLayout::Paged(BlockTable {
            k_pool: &k_pool,
            v_pool: &v_pool,
            blocks: &table,
            block_elems,
            plane,
            block_tokens: bt,
        });
        let (op, lp) = parallel::decode_splitkv_spec(&q, &paged, lo, hi, scale, bt);
        prop_assert!(
            oc.iter().zip(&op).all(|(a, b)| a.to_bits() == b.to_bits()),
            "paged decode not bitwise equal (n={n} bt={bt} lo={lo} hi={hi})"
        );
        prop_assert!(lc.to_bits() == lp.to_bits(), "LSE not bitwise equal");
        // and the full-range contiguous decode matches the legacy entry
        let (ol, ll) = parallel::decode_splitkv(&q, &k, &v, n, scale, bt);
        let (of, lf) = parallel::decode_splitkv_spec(&q, &contig, 0, n, scale, bt);
        prop_assert!(
            ol.iter().zip(&of).all(|(a, b)| a.to_bits() == b.to_bits())
                && ll.to_bits() == lf.to_bits(),
            "legacy decode_splitkv diverged from the spec entry point"
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// gradcheck on the new axes (tiny problems; FD is O(elems²·N))

/// L = Σ O ⊙ W under the reference forward.
fn loss(q: &[f32], k: &[f32], v: &[f32], w: &[f32], spec: AttnSpec) -> f64 {
    let out = reference::forward_spec(q, k, v, spec);
    out.o.iter().zip(w).map(|(&o, &wi)| o as f64 * wi as f64).sum()
}

fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

fn gradcheck_spec(spec: AttnSpec, seed: u64) {
    assert!(spec.seq <= 16, "gradcheck is O(elems²·N) — keep problems tiny");
    let mut rng = Rng::seed_from(seed);
    let q = rand_vec(&mut rng, spec.q_elems());
    let k = rand_vec(&mut rng, spec.kv_elems());
    let v = rand_vec(&mut rng, spec.kv_elems());
    let w = rand_vec(&mut rng, spec.q_elems());
    let p = FlashParams { block_q: 8, block_k: 8 };
    let fwd = parallel::forward_spec_with(1, &q, &k, &v, spec, p);
    let g = parallel::backward_spec_with(1, &q, &k, &v, &fwd, &w, spec, p);
    let h = 1e-2f32;
    let mut bufs = [q.clone(), k.clone(), v.clone()];
    for (name, which, grad) in [("dQ", 0usize, &g.dq), ("dK", 1, &g.dk), ("dV", 2, &g.dv)] {
        for e in 0..grad.len() {
            let orig = bufs[which][e];
            bufs[which][e] = orig + h;
            let up = loss(&bufs[0], &bufs[1], &bufs[2], &w, spec);
            bufs[which][e] = orig - h;
            let dn = loss(&bufs[0], &bufs[1], &bufs[2], &w, spec);
            bufs[which][e] = orig;
            let fd = (up - dn) / (2.0 * h as f64);
            assert!(
                close(grad[e] as f64, fd, 1e-3),
                "{name}[{e}]: analytic {} vs FD {fd} ({spec:?})",
                grad[e]
            );
        }
    }
}

#[test]
fn gradcheck_gqa_causal() {
    gradcheck_spec(
        AttnSpec {
            batch: 1,
            heads: HeadMap { n_q_heads: 4, n_kv_heads: 2 },
            seq: 7,
            head_dim: 3,
            mask: Mask::Causal,
        },
        0xFD11,
    );
}

#[test]
fn gradcheck_mqa_sliding_window() {
    gradcheck_spec(
        AttnSpec {
            batch: 1,
            heads: HeadMap { n_q_heads: 2, n_kv_heads: 1 },
            seq: 9,
            head_dim: 3,
            mask: Mask::SlidingWindow(4),
        },
        0xFD12,
    );
}

#[test]
fn gradcheck_window_crossing_blocks() {
    // window boundary crosses the 8-wide K block so Skip, Partial and
    // Full covers all occur in the backward tiling
    gradcheck_spec(
        AttnSpec {
            batch: 1,
            heads: HeadMap::mha(1),
            seq: 14,
            head_dim: 2,
            mask: Mask::SlidingWindow(5),
        },
        0xFD13,
    );
}
