//! Integration: copy-on-write prefix caching over the paged KV arena
//! (DESIGN.md §15).  The correctness bar is the PR 4/5 identity pattern:
//! a session whose prompt shares a prefix with a prior session must
//! produce byte-identical greedy tokens versus a cold run, while
//! allocating strictly fewer fresh KV blocks and reporting
//! `cached_tokens > 0`.  Arena-level tests drive copy-on-write,
//! eviction ordering, and the refcount sanitizer directly.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use fa2::coordinator::engine::{Engine, SamplingParams};
use fa2::coordinator::scheduler::SchedulerConfig;
use fa2::runtime::{BackendKind, KvArena, KvGeometry, KvSlot, PrefixIndex, RuntimeOptions};

/// Small blocks so a 16-token prompt window spans several of them — the
/// tiny model's prompts can then actually share full blocks.
const BLOCK: usize = 4;

fn cfg(prefix_cache: bool) -> SchedulerConfig {
    SchedulerConfig { kv_block: BLOCK, prefix_cache, ..Default::default() }
}

fn engine(cfg: SchedulerConfig, opts: RuntimeOptions) -> Engine {
    Engine::start_full(PathBuf::from("artifacts"), "tiny", BackendKind::Native, cfg, opts)
        .expect("native engine must start with no artifacts on disk")
}

/// Two 12-token prompts sharing an 8-token (two-block) prefix.
fn shared_prompts() -> (Vec<i32>, Vec<i32>) {
    let prefix: Vec<i32> = (1..=8).collect();
    let mut a = prefix.clone();
    a.extend([21, 22, 23, 24]);
    let mut b = prefix;
    b.extend([31, 32, 33, 34]);
    (a, b)
}

/// The tentpole acceptance test (MHA): warm sessions are byte-identical
/// to a cache-off engine and report `cached_tokens > 0`.
#[test]
fn shared_prefix_sessions_are_byte_identical_and_report_cached_tokens() {
    let (a, b) = shared_prompts();
    // Cold reference: the SAME scheduler shape with caching off — the
    // cache must change scheduling cost, never bytes.
    let cold = engine(cfg(false), RuntimeOptions::default());
    let cold_a = cold.submit(a.clone(), SamplingParams::greedy(6)).unwrap().wait().unwrap();
    let cold_b = cold.submit(b.clone(), SamplingParams::greedy(6)).unwrap().wait().unwrap();
    cold.shutdown().unwrap();
    assert_eq!(cold_a.cached_tokens, 0, "cache off never reports cached tokens");
    assert_eq!(cold_b.cached_tokens, 0);

    let warm = engine(cfg(true), RuntimeOptions::default());
    let first = warm.submit(a.clone(), SamplingParams::greedy(6)).unwrap().wait().unwrap();
    assert_eq!(first.tokens, cold_a.tokens, "cold path under caching is unchanged");
    assert_eq!(first.cached_tokens, 0, "nothing published yet");
    // Identical prompt: adopts both full prefix blocks (the third block
    // holds the final prompt token and is never adopted — the model
    // still needs to produce first-token logits from a real replay row).
    let again = warm.submit(a.clone(), SamplingParams::greedy(6)).unwrap().wait().unwrap();
    assert_eq!(again.tokens, cold_a.tokens, "warm tokens must be byte-identical");
    assert_eq!(again.cached_tokens, 2 * BLOCK);
    // Divergent tail, shared two-block prefix.
    let cousin = warm.submit(b.clone(), SamplingParams::greedy(6)).unwrap().wait().unwrap();
    assert_eq!(cousin.tokens, cold_b.tokens, "shared-prefix tokens must be byte-identical");
    assert_eq!(cousin.cached_tokens, 2 * BLOCK);
    let metrics = warm.shutdown().unwrap();
    assert_eq!(metrics.prefix_cached_tokens(), (4 * BLOCK) as u64);
}

/// Same identity bar under GQA (2 KV heads) + sliding window: the cache
/// key is token content, not head layout, so the guarantee must hold on
/// every attention configuration the native model supports.
#[test]
fn shared_prefix_identity_holds_under_gqa_and_window() {
    let opts = RuntimeOptions { n_kv_heads: Some(2), window: Some(32) };
    let (a, b) = shared_prompts();
    let cold = engine(cfg(false), opts);
    let cold_a = cold.submit(a.clone(), SamplingParams::greedy(6)).unwrap().wait().unwrap();
    let cold_b = cold.submit(b.clone(), SamplingParams::greedy(6)).unwrap().wait().unwrap();
    cold.shutdown().unwrap();

    let warm = engine(cfg(true), opts);
    let warm_a = warm.submit(a, SamplingParams::greedy(6)).unwrap().wait().unwrap();
    let warm_b = warm.submit(b, SamplingParams::greedy(6)).unwrap().wait().unwrap();
    assert_eq!(warm_a.tokens, cold_a.tokens);
    assert_eq!(warm_b.tokens, cold_b.tokens);
    assert_eq!(warm_a.cached_tokens, 0);
    assert_eq!(warm_b.cached_tokens, 2 * BLOCK, "two shared blocks adopted under GQA");
    warm.shutdown().unwrap();
}

// ---------------------------------------------------------------------------
// arena-level: block accounting, copy-on-write, eviction, sanitizer

fn geo() -> KvGeometry {
    KvGeometry { n_layer: 1, n_kv_head: 1, max_seq: 16, d_head: 2, block_tokens: 4 }
}

fn cached_arena(cap_blocks: usize) -> KvArena {
    let mut a = KvArena::with_block_capacity(geo(), cap_blocks);
    a.attach_prefix_index(Arc::new(Mutex::new(PrefixIndex::new(4, 0))));
    a
}

/// Write one distinguishable row at every position a `n_blocks`
/// reservation covers.
fn fill(a: &mut KvArena, slot: KvSlot, n_blocks: usize, base: f32) {
    let mut p = a.paged_mut(slot);
    for pos in 0..(n_blocks * 4) {
        let x = base + pos as f32;
        p.write_row(0, 0, pos, &[x, x + 0.5], &[x + 1.0, x + 1.5]);
    }
}

/// The acceptance criterion "strictly fewer new KV blocks", stated in
/// arena arithmetic: a 3-block prompt whose first 2 blocks are cached
/// allocates exactly 1 fresh block instead of 3.
#[test]
fn adoption_allocates_strictly_fewer_fresh_blocks() {
    let mut a = cached_arena(8);
    let prompt: Vec<i32> = (1..=12).collect(); // 3 full 4-token blocks
    let (adopted, cached) = a.acquire_prefix(&prompt);
    assert!(adopted.is_empty() && cached == 0, "cold: nothing to adopt");
    let cold = a.try_alloc_seq(3).expect("8-block arena fits 3");
    let cold_fresh = a.blocks_in_use();
    assert_eq!(cold_fresh, 3);
    fill(&mut a, cold, 3, 0.0);
    assert_eq!(a.publish_prefix(cold, &prompt), 3);
    a.free(cold);
    assert_eq!(a.blocks_in_use(), 0, "published blocks live in the cache bucket");

    // Warm path: adoption is capped at (len-1)/block = 2 of the 3
    // published blocks, so need shrinks from 3 fresh to 1 fresh.
    let (adopted, cached) = a.acquire_prefix(&prompt);
    assert_eq!(adopted.len(), 2);
    assert_eq!(cached, 2 * 4);
    let warm = a.try_alloc_seq_shared(&adopted, 3 - adopted.len()).unwrap();
    assert_eq!(a.table(warm).len(), 3, "full table: 2 adopted + 1 fresh");
    assert_eq!(&a.table(warm)[..2], &adopted[..], "adopted blocks head the table");
    assert!(
        a.blocks_in_use() < cold_fresh,
        "warm session must allocate strictly fewer fresh blocks ({} vs {cold_fresh})",
        a.blocks_in_use()
    );
    assert_eq!(a.blocks_in_use(), 1);
    a.free(warm);
}

/// A divergent write into an adopted block takes a private copy (COW)
/// and drops the pin; the cached original stays byte-intact for the
/// next reader.
#[test]
fn cow_on_divergence_copies_and_preserves_the_cached_block() {
    let mut a = cached_arena(8);
    let prompt: Vec<i32> = (1..=8).collect();
    let s0 = a.try_alloc_seq(2).unwrap();
    fill(&mut a, s0, 2, 0.0);
    assert_eq!(a.publish_prefix(s0, &prompt), 2);
    a.free(s0);

    let (adopted, _) = a.acquire_prefix(&prompt);
    assert_eq!(adopted.len(), 1, "adoption cap leaves the final block unshared");
    let s1 = a.try_alloc_seq_shared(&adopted, 1).unwrap();
    let before = a.table(s1).to_vec();
    // Divergence: write into position 0, which lives in the adopted
    // shared block — ensure_writable must swap in a private copy first.
    assert!(a.ensure_writable(s1, 0), "write into a shared block takes a copy");
    let after = a.table(s1).to_vec();
    assert_ne!(before[0], after[0], "table now points at the private copy");
    assert!(!a.ensure_writable(s1, 0), "second write is already private");
    // The write goes through cleanly (the sanitizer would abort on a
    // shared-block write in debug builds).
    a.paged_mut(s1).write_row(0, 0, 0, &[9.0, 9.0], &[9.0, 9.0]);
    a.free(s1);

    // The cached original is still adoptable afterwards.
    let (readopted, _) = a.acquire_prefix(&prompt);
    assert_eq!(readopted, vec![before[0]], "original cached block survived the COW");
    a.release_prefix_blocks(&readopted);
}

/// Under allocation pressure the arena reclaims only zero-ref cached
/// blocks; blocks pinned by a live adopter are never evicted.
#[test]
fn eviction_under_pressure_never_takes_pinned_blocks() {
    let mut a = cached_arena(4);
    let prompt: Vec<i32> = (1..=8).collect();
    let s0 = a.try_alloc_seq(2).unwrap();
    fill(&mut a, s0, 2, 0.0);
    a.publish_prefix(s0, &prompt);
    a.free(s0);
    // Cache holds 2 zero-ref blocks; pin one through adoption.
    let (adopted, _) = a.acquire_prefix(&prompt);
    assert_eq!(adopted.len(), 1);
    let s1 = a.try_alloc_seq_shared(&adopted, 1).unwrap();
    // 4-block arena: 1 adopted (pinned) + 1 fresh + 1 zero-ref cached +
    // 1 free.  A 2-block demand forces reclaim of the zero-ref block.
    let s2 = a.try_alloc_seq(2).expect("pressure reclaims the unpinned cache block");
    assert_eq!(a.blocks_in_use(), 3);
    assert_eq!(a.available(), 0);
    // The pinned block survived: its table entry still backs s1 and the
    // cache still resolves the prefix to it.
    assert_eq!(a.table(s1)[0], adopted[0]);
    let (still, _) = a.acquire_prefix(&prompt);
    assert_eq!(still, adopted, "pinned block was not evicted under pressure");
    a.release_prefix_blocks(&still);
    a.free(s1);
    a.free(s2);
}

/// The kv-sanitizer (ShadowArena refcounts) catches injected refcount
/// corruption: zeroing a pinned block's refs and then evicting it must
/// abort with a premature-evict violation instead of silently handing
/// shared KV back to the free list.
#[cfg(debug_assertions)]
#[test]
fn sanitizer_catches_injected_refcount_corruption() {
    let outcome = std::panic::catch_unwind(|| {
        let mut a = cached_arena(8);
        let prompt: Vec<i32> = (1..=8).collect();
        let s0 = a.try_alloc_seq(2).unwrap();
        fill(&mut a, s0, 2, 0.0);
        a.publish_prefix(s0, &prompt);
        a.free(s0);
        let (adopted, _) = a.acquire_prefix(&prompt);
        assert_eq!(adopted.len(), 1, "one live pin to corrupt");
        // Inject the corruption the sanitizer exists for: the index
        // forgets the live pin, then eviction tries to reclaim the block.
        assert!(a.corrupt_prefix_refs_for_test(adopted[0]));
        a.evict_cached_for_test(8);
    });
    let payload = outcome.expect_err("sanitizer must abort the corrupted eviction");
    let msg = payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_default();
    assert!(msg.contains("premature evict"), "unexpected panic message: {msg}");
}
