//! Integration: every golden-bearing artifact loads, compiles, executes and
//! reproduces the Python-side outputs through the PJRT runtime.
//!
//! AOT artifacts are produced by `python/compile/aot.py` (the `make
//! artifacts` step) and are not checked in.  Without them — or without the
//! `xla` execution backend — each test SKIPS (prints a note and returns)
//! instead of panicking, so a fresh offline checkout is green.  The
//! synthesized-fixture test at the bottom exercises the manifest/runtime
//! plumbing with no artifacts at all.

mod common;

use fa2::runtime::{ArtifactKind, Runtime};

/// The runtime over real AOT artifacts, or `None` (with a note) to skip.
fn runtime() -> Option<Runtime> {
    let dir = common::artifact_dir_or_skip()?;
    Some(Runtime::new(&dir).expect("manifest exists but failed to load"))
}

/// Executing (not just inspecting) artifacts also needs the real backend.
fn exec_runtime() -> Option<Runtime> {
    let dir = common::exec_artifact_dir_or_skip()?;
    Some(Runtime::new(&dir).expect("manifest exists but failed to load"))
}

#[test]
fn manifest_is_complete() {
    let Some(rt) = runtime() else { return };
    assert!(rt.manifest.artifacts.len() >= 30, "expected full artifact set");
    // every kind is represented
    for kind in [
        ArtifactKind::AttnFwd,
        ArtifactKind::AttnGrad,
        ArtifactKind::Init,
        ArtifactKind::TrainStep,
        ArtifactKind::Prefill,
        ArtifactKind::Decode,
    ] {
        assert!(!rt.manifest.by_kind(kind).is_empty(), "missing kind {kind:?}");
    }
}

#[test]
fn specs_are_internally_consistent() {
    let Some(rt) = runtime() else { return };
    for a in rt.manifest.artifacts.values() {
        assert!(a.hlo_path.exists(), "{}: missing hlo file", a.name);
        assert!(!a.inputs.is_empty(), "{}: no inputs", a.name);
        assert!(!a.outputs.is_empty(), "{}: no outputs", a.name);
        if let Some(g) = &a.golden_path {
            assert!(g.exists(), "{}: missing golden file", a.name);
        }
        // attention artifacts: q/k/v agree on shape
        if a.kind == ArtifactKind::AttnFwd {
            assert_eq!(a.inputs[0].dims, a.inputs[1].dims, "{}", a.name);
            assert_eq!(a.inputs[0].dims.len(), 4, "{}", a.name);
            let n = a.meta_i64("seqlen").unwrap() as usize;
            assert_eq!(a.inputs[0].dims[2], n, "{}", a.name);
        }
    }
}

#[test]
fn all_goldens_verify() {
    let Some(rt) = exec_runtime() else { return };
    let names: Vec<String> = rt
        .manifest
        .artifacts
        .values()
        .filter(|a| a.golden_path.is_some())
        .map(|a| a.name.clone())
        .collect();
    assert!(!names.is_empty());
    for name in names {
        let diffs = rt.verify_golden(&name).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        let worst = diffs.iter().cloned().fold(0.0f32, f32::max);
        assert!(worst < 2e-4, "{name}: max diff {worst}");
    }
}

#[test]
fn fa2_and_standard_artifacts_agree_on_fresh_inputs() {
    // Beyond goldens: generate NEW inputs in rust and check the two
    // schedules compute the same attention.
    use fa2::util::rng::Rng;
    use fa2::util::tensorio::HostTensor;
    let Some(rt) = exec_runtime() else { return };
    let fa2 = rt.load("attn_fa2_causal_b1h2n64d32").unwrap();
    let std_ = rt.load("attn_std_causal_b1h2n64d32").unwrap();
    let dims = fa2.spec.inputs[0].dims.clone();
    let n: usize = dims.iter().product();
    let mut rng = Rng::seed_from(123);
    let mk = |rng: &mut Rng| {
        HostTensor::from_f32(&dims, &(0..n).map(|_| rng.normal() as f32).collect::<Vec<_>>())
    };
    let (q, k, v) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
    let a = fa2.run(&[q.clone(), k.clone(), v.clone()]).unwrap();
    let b = std_.run(&[q, k, v]).unwrap();
    assert!(a[0].max_abs_diff(&b[0]) < 1e-4);
    assert!(a[1].max_abs_diff(&b[1]) < 1e-4, "logsumexp mismatch");
}

#[test]
fn splitk_artifact_matches_fa2() {
    let Some(rt) = exec_runtime() else { return };
    let fa2 = rt.load("attn_fa2_full_b1h2n64d32").unwrap();
    let splitk = rt.load("attn_splitk4_full_b1h2n64d32").unwrap();
    // run both on the fa2 golden inputs
    let tensors =
        fa2::util::tensorio::read_tensors(fa2.spec.golden_path.as_ref().unwrap()).unwrap();
    let inputs = vec![tensors["in0"].clone(), tensors["in1"].clone(), tensors["in2"].clone()];
    let a = fa2.run(&inputs).unwrap();
    let b = splitk.run(&inputs).unwrap();
    assert!(a[0].max_abs_diff(&b[0]) < 1e-4);
}

#[test]
fn grad_artifact_outputs_have_input_shapes() {
    let Some(rt) = exec_runtime() else { return };
    let g = rt.load("attn_fa2grad_causal_b1h2n64d32").unwrap();
    let tensors =
        fa2::util::tensorio::read_tensors(g.spec.golden_path.as_ref().unwrap()).unwrap();
    let inputs: Vec<_> = (0..4).map(|i| tensors[&format!("in{i}")].clone()).collect();
    let out = g.run(&inputs).unwrap();
    // (o, dq, dk, dv) all shaped like q
    assert_eq!(out.len(), 4);
    for t in &out {
        assert_eq!(t.dims, g.spec.inputs[0].dims);
    }
}

#[test]
fn exec_stats_accumulate() {
    let Some(rt) = exec_runtime() else { return };
    let exe = rt.load("attn_fa2_full_b1h2n64d32").unwrap();
    let before = exe.stats().executions;
    rt.verify_golden("attn_fa2_full_b1h2n64d32").unwrap();
    assert_eq!(exe.stats().executions, before + 1);
    assert!(exe.stats().total_exec_secs > 0.0);
}

#[test]
fn input_validation_rejects_bad_shapes() {
    use fa2::util::tensorio::HostTensor;
    let Some(rt) = exec_runtime() else { return };
    let exe = rt.load("attn_fa2_full_b1h2n64d32").unwrap();
    let bad = HostTensor::from_f32(&[1, 2, 3], &[0.0; 6]);
    let err = exe.run(&[bad.clone(), bad.clone(), bad]).unwrap_err();
    assert!(format!("{err}").contains("expects"));
    let err = exe.run(&[]).unwrap_err();
    assert!(format!("{err}").contains("expected 3 inputs"));
}

#[test]
fn runtime_loads_synthesized_manifest_fixture() {
    // No AOT artifacts needed: synthesize a minimal manifest and check the
    // runtime's manifest plumbing under any backend — and that loading a
    // missing/uncompilable artifact is a clean error, never a panic.
    let dir = std::env::temp_dir()
        .join(format!("fa2_runtime_fixture_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"version": 1, "artifacts": [
            {"name": "toy", "kind": "attn_fwd", "hlo": "toy.hlo.txt",
             "inputs": [{"name": "q", "shape": [1, 1, 8, 4], "dtype": "f32"},
                        {"name": "k", "shape": [1, 1, 8, 4], "dtype": "f32"},
                        {"name": "v", "shape": [1, 1, 8, 4], "dtype": "f32"}],
             "outputs": [{"name": "o", "shape": [1, 1, 8, 4], "dtype": "f32"}],
             "meta": {"seqlen": 8, "causal": false}}
        ]}"#,
    )
    .unwrap();
    let rt = Runtime::new(&dir).unwrap();
    assert_eq!(rt.manifest.artifacts.len(), 1);
    assert_eq!(rt.manifest.by_kind(ArtifactKind::AttnFwd).len(), 1);
    let spec = rt.manifest.get("toy").unwrap();
    assert_eq!(spec.inputs[0].dims, vec![1, 1, 8, 4]);
    assert_eq!(spec.meta_i64("seqlen"), Some(8));
    assert!(rt.load("not-in-manifest").is_err());
    // "toy" is in the manifest but its .hlo.txt does not exist (and the
    // stub backend cannot compile at all): load must error, not panic.
    assert!(rt.load("toy").is_err());
    let _ = std::fs::remove_dir_all(&dir);
}
