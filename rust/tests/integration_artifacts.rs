//! Integration: every golden-bearing artifact loads, compiles, executes and
//! reproduces the Python-side outputs through the PJRT runtime.
//! Requires `make artifacts` to have run.

use std::path::Path;

use fa2::runtime::{ArtifactKind, Runtime};

fn runtime() -> Runtime {
    Runtime::new(Path::new("artifacts")).expect("run `make artifacts` first")
}

#[test]
fn manifest_is_complete() {
    let rt = runtime();
    assert!(rt.manifest.artifacts.len() >= 30, "expected full artifact set");
    // every kind is represented
    for kind in [
        ArtifactKind::AttnFwd,
        ArtifactKind::AttnGrad,
        ArtifactKind::Init,
        ArtifactKind::TrainStep,
        ArtifactKind::Prefill,
        ArtifactKind::Decode,
    ] {
        assert!(!rt.manifest.by_kind(kind).is_empty(), "missing kind {kind:?}");
    }
}

#[test]
fn specs_are_internally_consistent() {
    let rt = runtime();
    for a in rt.manifest.artifacts.values() {
        assert!(a.hlo_path.exists(), "{}: missing hlo file", a.name);
        assert!(!a.inputs.is_empty(), "{}: no inputs", a.name);
        assert!(!a.outputs.is_empty(), "{}: no outputs", a.name);
        if let Some(g) = &a.golden_path {
            assert!(g.exists(), "{}: missing golden file", a.name);
        }
        // attention artifacts: q/k/v agree on shape
        if a.kind == ArtifactKind::AttnFwd {
            assert_eq!(a.inputs[0].dims, a.inputs[1].dims, "{}", a.name);
            assert_eq!(a.inputs[0].dims.len(), 4, "{}", a.name);
            let n = a.meta_i64("seqlen").unwrap() as usize;
            assert_eq!(a.inputs[0].dims[2], n, "{}", a.name);
        }
    }
}

#[test]
fn all_goldens_verify() {
    let rt = runtime();
    let names: Vec<String> = rt
        .manifest
        .artifacts
        .values()
        .filter(|a| a.golden_path.is_some())
        .map(|a| a.name.clone())
        .collect();
    assert!(!names.is_empty());
    for name in names {
        let diffs = rt.verify_golden(&name).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        let worst = diffs.iter().cloned().fold(0.0f32, f32::max);
        assert!(worst < 2e-4, "{name}: max diff {worst}");
    }
}

#[test]
fn fa2_and_standard_artifacts_agree_on_fresh_inputs() {
    // Beyond goldens: generate NEW inputs in rust and check the two
    // schedules compute the same attention.
    use fa2::util::rng::Rng;
    use fa2::util::tensorio::HostTensor;
    let rt = runtime();
    let fa2 = rt.load("attn_fa2_causal_b1h2n64d32").unwrap();
    let std_ = rt.load("attn_std_causal_b1h2n64d32").unwrap();
    let dims = fa2.spec.inputs[0].dims.clone();
    let n: usize = dims.iter().product();
    let mut rng = Rng::seed_from(123);
    let mk = |rng: &mut Rng| {
        HostTensor::from_f32(&dims, &(0..n).map(|_| rng.normal() as f32).collect::<Vec<_>>())
    };
    let (q, k, v) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
    let a = fa2.run(&[q.clone(), k.clone(), v.clone()]).unwrap();
    let b = std_.run(&[q, k, v]).unwrap();
    assert!(a[0].max_abs_diff(&b[0]) < 1e-4);
    assert!(a[1].max_abs_diff(&b[1]) < 1e-4, "logsumexp mismatch");
}

#[test]
fn splitk_artifact_matches_fa2() {
    let rt = runtime();
    let fa2 = rt.load("attn_fa2_full_b1h2n64d32").unwrap();
    let splitk = rt.load("attn_splitk4_full_b1h2n64d32").unwrap();
    // run both on the fa2 golden inputs
    let tensors =
        fa2::util::tensorio::read_tensors(fa2.spec.golden_path.as_ref().unwrap()).unwrap();
    let inputs = vec![tensors["in0"].clone(), tensors["in1"].clone(), tensors["in2"].clone()];
    let a = fa2.run(&inputs).unwrap();
    let b = splitk.run(&inputs).unwrap();
    assert!(a[0].max_abs_diff(&b[0]) < 1e-4);
}

#[test]
fn grad_artifact_outputs_have_input_shapes() {
    let rt = runtime();
    let g = rt.load("attn_fa2grad_causal_b1h2n64d32").unwrap();
    let tensors =
        fa2::util::tensorio::read_tensors(g.spec.golden_path.as_ref().unwrap()).unwrap();
    let inputs: Vec<_> = (0..4).map(|i| tensors[&format!("in{i}")].clone()).collect();
    let out = g.run(&inputs).unwrap();
    // (o, dq, dk, dv) all shaped like q
    assert_eq!(out.len(), 4);
    for t in &out {
        assert_eq!(t.dims, g.spec.inputs[0].dims);
    }
}

#[test]
fn exec_stats_accumulate() {
    let rt = runtime();
    let exe = rt.load("attn_fa2_full_b1h2n64d32").unwrap();
    let before = exe.stats().executions;
    rt.verify_golden("attn_fa2_full_b1h2n64d32").unwrap();
    assert_eq!(exe.stats().executions, before + 1);
    assert!(exe.stats().total_exec_secs > 0.0);
}

#[test]
fn input_validation_rejects_bad_shapes() {
    use fa2::util::tensorio::HostTensor;
    let rt = runtime();
    let exe = rt.load("attn_fa2_full_b1h2n64d32").unwrap();
    let bad = HostTensor::from_f32(&[1, 2, 3], &[0.0; 6]);
    let err = exe.run(&[bad.clone(), bad.clone(), bad]).unwrap_err();
    assert!(format!("{err}").contains("expects"));
    let err = exe.run(&[]).unwrap_err();
    assert!(format!("{err}").contains("expected 3 inputs"));
}
