//! Shared skip/discovery helpers for the artifact-dependent integration
//! tests.  (Files under tests/common/ are not compiled as test crates;
//! each test file pulls this in with `mod common;`.)
//!
//! Skips are REGISTERED, not just printed: `cargo test` swallows stderr of
//! passing tests, so a green run used to hide which suites never actually
//! exercised anything.  When `CI_SKIP_LOG` is set (ci.sh exports it), each
//! skip appends a `<test>: <reason>` line there and ci.sh prints a
//! `SKIPPED:` summary at the end of the run.

#![allow(dead_code)] // not every test crate uses every helper

use std::io::Write;
use std::path::PathBuf;

/// Record that the calling test skipped (with the reason), both to stderr
/// (visible under `cargo test -- --nocapture`) and to the `CI_SKIP_LOG`
/// file when ci.sh is driving.  The test name comes from the test thread's
/// name, which the harness sets to the test path.
pub fn register_skip(reason: &str) {
    let test = std::thread::current()
        .name()
        .map(|s| s.to_string())
        .unwrap_or_else(|| "unknown-test".to_string());
    eprintln!("skipping {test}: {reason}");
    let Ok(path) = std::env::var("CI_SKIP_LOG") else { return };
    if path.is_empty() {
        return;
    }
    // appends are line-buffered and tiny; concurrent test processes
    // interleave whole lines, which is all the summary needs
    if let Ok(mut f) =
        std::fs::OpenOptions::new().create(true).append(true).open(&path)
    {
        let _ = writeln!(f, "{test}: {reason}");
    }
}

/// artifacts/ relative to the test cwd (the package root, rust/) or the
/// workspace root.
pub fn artifact_dir() -> Option<PathBuf> {
    ["artifacts", "../artifacts"]
        .iter()
        .map(PathBuf::from)
        .find(|d| d.join("manifest.json").exists())
}

/// Like [`artifact_dir`], but registers a skip when absent.
pub fn artifact_dir_or_skip() -> Option<PathBuf> {
    let found = artifact_dir();
    if found.is_none() {
        register_skip("no artifacts/manifest.json (run `make artifacts`)");
    }
    found
}

/// [`artifact_dir_or_skip`] plus the execution-backend gate: running (not
/// just inspecting) artifacts needs the real `xla` backend.
pub fn exec_artifact_dir_or_skip() -> Option<PathBuf> {
    if cfg!(not(feature = "xla")) {
        register_skip("built without the `xla` execution backend");
        return None;
    }
    artifact_dir_or_skip()
}
