//! Shared skip/discovery helpers for the artifact-dependent integration
//! tests.  (Files under tests/common/ are not compiled as test crates;
//! each test file pulls this in with `mod common;`.)

#![allow(dead_code)] // not every test crate uses every helper

use std::path::PathBuf;

/// artifacts/ relative to the test cwd (the package root, rust/) or the
/// workspace root.
pub fn artifact_dir() -> Option<PathBuf> {
    ["artifacts", "../artifacts"]
        .iter()
        .map(PathBuf::from)
        .find(|d| d.join("manifest.json").exists())
}

/// Like [`artifact_dir`], but prints a skip note when absent.
pub fn artifact_dir_or_skip() -> Option<PathBuf> {
    let found = artifact_dir();
    if found.is_none() {
        eprintln!("skipping: no artifacts/manifest.json (run `make artifacts`)");
    }
    found
}

/// [`artifact_dir_or_skip`] plus the execution-backend gate: running (not
/// just inspecting) artifacts needs the real `xla` backend.
pub fn exec_artifact_dir_or_skip() -> Option<PathBuf> {
    if cfg!(not(feature = "xla")) {
        eprintln!("skipping: built without the `xla` execution backend");
        return None;
    }
    artifact_dir_or_skip()
}
