//! Integration: the training driver and the serving engine over real
//! compiled artifacts, plus the native (`attn::exec`) serving path.
//!
//! These suites used to pin the deprecated `Server` shim's behavior; the
//! shim is gone (it shipped its one release of back-compat), so the same
//! serving contracts — completion in order, batch-invariant greedy
//! decode, fire-and-forget submissions, determinism — are now asserted
//! directly against `Engine`/`Session`.  `tests/native_engine.rs` covers
//! the streaming/scheduling surface in depth.
//!
//! The artifact-backed tests require `make artifacts`
//! (python/compile/aot.py) AND the `xla` execution backend; without
//! either, they SKIP with a note instead of panicking, so a fresh offline
//! checkout is green.  The `native_*` tests at the bottom run the same
//! engine on `BackendKind::Native` and never skip — serving works on a
//! fresh checkout with no artifacts at all.

mod common;

use std::path::PathBuf;
use std::sync::Arc;

use fa2::coordinator::engine::{Engine, SamplingParams};
use fa2::runtime::{BackendKind, Runtime};
use fa2::train::trainer::{TrainConfig, Trainer};

/// artifacts/ with everything needed to EXECUTE artifacts, or `None` (with
/// a note) to skip.
fn artifact_dir() -> Option<PathBuf> {
    common::exec_artifact_dir_or_skip()
}

fn runtime() -> Option<Arc<Runtime>> {
    let dir = artifact_dir()?;
    Some(Arc::new(Runtime::new(&dir).expect("manifest exists but failed to load")))
}

#[test]
fn tiny_training_reduces_loss() {
    let Some(rt) = runtime() else { return };
    let cfg = TrainConfig { model: "tiny".into(), steps: 15, log_every: 0, ..Default::default() };
    let report = Trainer::new(rt).run(&cfg).unwrap();
    assert_eq!(report.logs.len(), 15);
    // untrained x-ent ~ ln(512) ~ 6.24; must drop measurably in 15 steps
    assert!(report.first_loss() > 5.5, "{}", report.first_loss());
    assert!(
        report.last_loss() < report.first_loss() - 0.1,
        "loss {} -> {}",
        report.first_loss(),
        report.last_loss()
    );
    assert!(report.logs.iter().all(|l| l.loss.is_finite()));
}

#[test]
fn training_is_deterministic_given_seed() {
    let Some(rt) = runtime() else { return };
    let cfg = TrainConfig { model: "tiny".into(), steps: 4, log_every: 0, ..Default::default() };
    let a = Trainer::new(rt.clone()).run(&cfg).unwrap();
    let b = Trainer::new(rt).run(&cfg).unwrap();
    for (x, y) in a.logs.iter().zip(&b.logs) {
        assert_eq!(x.loss, y.loss, "step {}", x.step);
    }
}

#[test]
fn training_checkpoint_is_written_and_readable() {
    let Some(rt) = runtime() else { return };
    let dir = std::env::temp_dir().join("fa2_ckpt_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ckpt.fat1");
    let cfg = TrainConfig {
        model: "tiny".into(),
        steps: 2,
        log_every: 0,
        checkpoint: Some(path.to_str().unwrap().to_string()),
        ..Default::default()
    };
    Trainer::new(rt).run(&cfg).unwrap();
    let tensors = fa2::util::tensorio::read_tensors(&path).unwrap();
    assert!(tensors.len() >= 20, "expected all param leaves, got {}", tensors.len());
    assert!(tensors.keys().any(|k| k.contains("wte")));
}

#[test]
fn engine_completes_all_requests_in_order() {
    let Some(dir) = artifact_dir() else { return };
    let engine = Engine::start(dir, "tiny", BackendKind::Auto).unwrap();
    let mut sessions = Vec::new();
    for i in 0..5 {
        sessions.push(
            engine.submit(vec![i as i32 + 1; 8], SamplingParams::greedy(4)).unwrap(),
        );
    }
    for s in sessions {
        let c = s.wait().expect("completion");
        assert_eq!(c.tokens.len(), 4);
        assert!(c.latency >= c.ttft);
        assert!(c.tokens.iter().all(|&t| (0..512).contains(&t)));
    }
    let metrics = engine.shutdown().unwrap();
    assert_eq!(metrics.requests(), 5);
    assert_eq!(metrics.tokens(), 20);
}

#[test]
fn greedy_decode_is_batch_invariant() {
    // The same prompt must produce the same tokens whether it is served
    // alone (decode_b1) or batched with others (decode_b4, with padding) —
    // the KV-cache handling must not leak state across rows.
    let Some(dir) = artifact_dir() else { return };
    let engine = Engine::start(dir, "tiny", BackendKind::Auto).unwrap();
    let prompt: Vec<i32> = (1..=8).collect();
    let solo = engine
        .submit(prompt.clone(), SamplingParams::greedy(6))
        .unwrap()
        .wait()
        .unwrap();
    // now submit 4 at once so they decode as a batch
    let sessions: Vec<_> = (0..4)
        .map(|j| {
            let mut p = prompt.clone();
            if j > 0 {
                p[0] = 100 + j; // make the other requests different
            }
            engine.submit(p, SamplingParams::greedy(6)).unwrap()
        })
        .collect();
    let batched: Vec<_> = sessions.into_iter().map(|s| s.wait().unwrap()).collect();
    engine.shutdown().unwrap();
    assert_eq!(
        solo.tokens, batched[0].tokens,
        "batching changed greedy decode output"
    );
}

fn native_engine() -> Engine {
    // the directory is never read: the native backend synthesizes its
    // manifest in memory
    Engine::start(PathBuf::from("artifacts"), "tiny", BackendKind::Native)
        .expect("native engine must start with no artifacts on disk")
}

#[test]
fn native_engine_answers_generate_requests() {
    let engine = native_engine();
    let mut sessions = Vec::new();
    for i in 0..5 {
        sessions.push(
            engine.submit(vec![i as i32 + 1; 8], SamplingParams::greedy(4)).unwrap(),
        );
    }
    for s in sessions {
        let c = s.wait().expect("completion");
        assert_eq!(c.tokens.len(), 4);
        assert!(c.latency >= c.ttft);
        assert!(c.tokens.iter().all(|&t| (0..512).contains(&t)));
    }
    let metrics = engine.shutdown().unwrap();
    assert_eq!(metrics.requests(), 5);
    assert_eq!(metrics.tokens(), 20);
}

#[test]
fn native_greedy_decode_is_batch_invariant() {
    // same contract as the artifact-backed test: batching with padding must
    // not change a sequence's greedy tokens
    let engine = native_engine();
    let prompt: Vec<i32> = (1..=8).collect();
    let solo = engine
        .submit(prompt.clone(), SamplingParams::greedy(6))
        .unwrap()
        .wait()
        .unwrap();
    let sessions: Vec<_> = (0..4)
        .map(|j| {
            let mut p = prompt.clone();
            if j > 0 {
                p[0] = 100 + j;
            }
            engine.submit(p, SamplingParams::greedy(6)).unwrap()
        })
        .collect();
    let batched: Vec<_> = sessions.into_iter().map(|s| s.wait().unwrap()).collect();
    engine.shutdown().unwrap();
    assert_eq!(
        solo.tokens, batched[0].tokens,
        "batching changed native greedy decode output"
    );
}

#[test]
fn native_detached_fire_and_forget_submissions_still_complete() {
    // The old `Server` completed (and counted) fire-and-forget
    // submissions; with the shim gone, `Session::detach` is the explicit
    // spelling: a detached session keeps decoding after its handle drops.
    let engine = native_engine();
    let mut dropped = engine.submit(vec![5; 8], SamplingParams::greedy(3)).unwrap();
    dropped.detach();
    drop(dropped);
    let kept = engine.submit(vec![6; 8], SamplingParams::greedy(3)).unwrap();
    assert_eq!(kept.wait().unwrap().tokens.len(), 3);
    let metrics = engine.shutdown().unwrap();
    assert_eq!(metrics.requests(), 2, "dropped detached handle must not cancel its request");
}

#[test]
fn native_generation_is_deterministic() {
    let run = || {
        let engine = native_engine();
        let c = engine
            .submit((10..26).collect(), SamplingParams::greedy(5))
            .unwrap()
            .wait()
            .unwrap();
        engine.shutdown().unwrap();
        c.tokens
    };
    assert_eq!(run(), run(), "same prompt + seed 0 weights must repeat exactly");
}

#[test]
fn native_runtime_verifies_flash_against_reference() {
    // `repro verify --backend native` in test form: golden vectors are
    // synthesized from attn::exec::reference, executed through the
    // runtime — now covering GQA, MQA and sliding-window kernels too.
    let rt = Runtime::with_backend(&PathBuf::from("artifacts"), BackendKind::Native).unwrap();
    let names = rt.golden_names();
    assert!(names.len() >= 6, "native manifest should self-verify every spec axis");
    for name in names {
        let diffs = rt.verify_golden(&name).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        let worst = diffs.iter().cloned().fold(0.0f32, f32::max);
        assert!(worst < 2e-4, "{name}: max diff {worst}");
    }
}

#[test]
fn refattn_and_flash2_train_variants_agree() {
    // Same seed, same data: the no-FA baseline and the FA2 kernel path must
    // produce (numerically) the same loss trajectory — they are the same
    // math, which is the paper's core claim.
    let Some(rt) = runtime() else { return };
    let fa2_cfg = TrainConfig { model: "small".into(), steps: 2, log_every: 0, ..Default::default() };
    let ref_cfg = TrainConfig { variant: "_refattn".into(), ..fa2_cfg.clone() };
    let a = Trainer::new(rt.clone()).run(&fa2_cfg).unwrap();
    let b = Trainer::new(rt).run(&ref_cfg).unwrap();
    for (x, y) in a.logs.iter().zip(&b.logs) {
        assert!(
            (x.loss - y.loss).abs() < 1e-3,
            "step {}: fa2 {} vs ref {}",
            x.step, x.loss, y.loss
        );
    }
}
