//! Property tests for the native executing kernels (`attn::exec`):
//!
//! - flash forward matches the O(N²) reference within 1e-4 over random
//!   shapes — causal and full, seqlens not divisible by the block sizes,
//!   head_dim ∈ {16, 64, 128};
//! - flash backward matches the reference gradients within 1e-4;
//! - parallel execution is byte-identical to serial at any worker count
//!   (the same order-preserving fan-out contract as the sweeps);
//! - split-KV decode matches monolithic decode for any chunking, streamed
//!   (`merge_from`) or fanned (`merge_all`).
//!
//! Replay failures with FA2_PROP_SEED / FA2_PROP_CASES (see util::prop).

use fa2::attn::exec::{parallel, reference, AttnDims, FlashParams};
use fa2::prop_assert;
use fa2::util::prop::{check, PropConfig};
use fa2::util::rng::Rng;

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn max_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// A random problem: small batch/heads, awkward seqlens, the paper's head
/// dims, random masking.
fn rand_dims(rng: &mut Rng, max_seq: usize) -> AttnDims {
    AttnDims {
        batch: rng.range_usize(1, 3),
        heads: rng.range_usize(1, 3),
        seq: rng.range_usize(1, max_seq + 1),
        head_dim: *rng.choice(&[16usize, 64, 128]),
        causal: rng.next_f64() < 0.5,
    }
}

fn rand_params(rng: &mut Rng) -> FlashParams {
    FlashParams {
        block_q: *rng.choice(&[4usize, 8, 16, 33, 64]),
        block_k: *rng.choice(&[4usize, 8, 16, 33, 64]),
    }
}

#[test]
fn prop_flash_forward_matches_reference() {
    let cfg = PropConfig { cases: 32, ..PropConfig::default() };
    check("flash-fwd-parity", cfg, |rng| {
        let dims = rand_dims(rng, 48);
        let p = rand_params(rng);
        let n = dims.elems();
        let (q, k, v) = (rand_vec(rng, n), rand_vec(rng, n), rand_vec(rng, n));
        let fl = parallel::forward_with(1, &q, &k, &v, dims, p);
        let rf = reference::forward(&q, &k, &v, dims);
        let od = max_diff(&fl.o, &rf.o);
        prop_assert!(od < 1e-4, "O diff {od} for {dims:?} {p:?}");
        let ld = max_diff(&fl.lse, &rf.lse);
        prop_assert!(ld < 1e-4, "LSE diff {ld} for {dims:?} {p:?}");
        Ok(())
    });
}

#[test]
fn prop_flash_backward_matches_reference() {
    let cfg = PropConfig { cases: 24, ..PropConfig::default() };
    check("flash-bwd-parity", cfg, |rng| {
        let dims = AttnDims {
            batch: rng.range_usize(1, 3),
            heads: rng.range_usize(1, 3),
            seq: rng.range_usize(1, 25),
            head_dim: *rng.choice(&[16usize, 64]),
            causal: rng.next_f64() < 0.5,
        };
        let p = rand_params(rng);
        let n = dims.elems();
        let (q, k, v, dout) = (
            rand_vec(rng, n),
            rand_vec(rng, n),
            rand_vec(rng, n),
            rand_vec(rng, n),
        );
        let fwd = parallel::forward_with(1, &q, &k, &v, dims, p);
        let g = parallel::backward_with(1, &q, &k, &v, &fwd, &dout, dims, p);
        let r = reference::backward(&q, &k, &v, &dout, dims);
        for (name, got, want) in
            [("dQ", &g.dq, &r.dq), ("dK", &g.dk, &r.dk), ("dV", &g.dv, &r.dv)]
        {
            let d = max_diff(got, want);
            prop_assert!(d < 1e-4, "{name} diff {d} for {dims:?} {p:?}");
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_equals_serial_bitwise() {
    let cfg = PropConfig { cases: 24, ..PropConfig::default() };
    check("parallel-serial-identical", cfg, |rng| {
        let dims = rand_dims(rng, 40);
        let p = rand_params(rng);
        let workers = rng.range_usize(2, 9);
        let n = dims.elems();
        let (q, k, v, dout) = (
            rand_vec(rng, n),
            rand_vec(rng, n),
            rand_vec(rng, n),
            rand_vec(rng, n),
        );
        let serial = parallel::forward_with(1, &q, &k, &v, dims, p);
        let par = parallel::forward_with(workers, &q, &k, &v, dims, p);
        prop_assert!(serial.o == par.o, "forward O diverged at {workers} workers");
        prop_assert!(serial.lse == par.lse, "forward LSE diverged");
        let gs = parallel::backward_with(1, &q, &k, &v, &serial, &dout, dims, p);
        let gp = parallel::backward_with(workers, &q, &k, &v, &serial, &dout, dims, p);
        prop_assert!(gs.dq == gp.dq, "dQ diverged at {workers} workers");
        prop_assert!(gs.dk == gp.dk, "dK diverged");
        prop_assert!(gs.dv == gp.dv, "dV diverged");
        Ok(())
    });
}

#[test]
fn prop_splitkv_decode_matches_monolithic_for_any_chunking() {
    check("splitkv-chunk-invariance", PropConfig::default(), |rng| {
        let d = *rng.choice(&[16usize, 64, 128]);
        let n = rng.range_usize(1, 160);
        let chunk = rng.range_usize(1, n + 1);
        let q = rand_vec(rng, d);
        let k = rand_vec(rng, n * d);
        let v = rand_vec(rng, n * d);
        let scale = 1.0 / (d as f32).sqrt();
        let mono = parallel::decode_splitkv(&q, &k, &v, n, scale, n);
        let split = parallel::decode_splitkv(&q, &k, &v, n, scale, chunk);
        let fanned = parallel::decode_splitkv_fanned(4, &q, &k, &v, n, scale, chunk);
        let ds = max_diff(&mono.0, &split.0);
        prop_assert!(ds < 1e-5, "streamed split diff {ds} (n={n} chunk={chunk})");
        prop_assert!((mono.1 - split.1).abs() < 1e-5, "LSE diff (n={n} chunk={chunk})");
        let df = max_diff(&split.0, &fanned.0);
        prop_assert!(df < 1e-5, "fanned split diff {df} (n={n} chunk={chunk})");
        prop_assert!((split.1 - fanned.1).abs() < 1e-5, "fanned LSE diff");
        Ok(())
    });
}

#[test]
fn prop_decode_agrees_with_flash_last_row() {
    // The decode path and the full flash forward must agree on the last
    // causal row (which attends to the whole history) — ties the serving
    // decode path to the prefill kernel.
    check("decode-vs-flash-row", PropConfig { cases: 24, ..PropConfig::default() }, |rng| {
        let dims = AttnDims {
            batch: 1,
            heads: 1,
            seq: rng.range_usize(1, 65),
            head_dim: *rng.choice(&[16usize, 64]),
            causal: true,
        };
        let n = dims.elems();
        let (q, k, v) = (rand_vec(rng, n), rand_vec(rng, n), rand_vec(rng, n));
        let fwd = parallel::forward_with(1, &q, &k, &v, dims, FlashParams::default());
        let last = dims.seq - 1;
        let d = dims.head_dim;
        let (orow, lse) = parallel::decode_splitkv(
            &q[last * d..(last + 1) * d],
            &k,
            &v,
            dims.seq,
            dims.scale(),
            rng.range_usize(1, dims.seq + 1),
        );
        let got = &fwd.o[last * d..(last + 1) * d];
        let diff = max_diff(got, &orow);
        prop_assert!(diff < 1e-5, "decode vs flash last row diff {diff} ({dims:?})");
        let flse = fwd.lse[last];
        prop_assert!((flse - lse).abs() < 1e-5, "LSE {flse} vs {lse}");
        Ok(())
    });
}
