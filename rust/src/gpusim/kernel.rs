//! Kernel cost model: time a simulated kernel launch on a `Device`.
//!
//! Model (deliberately simple, every term auditable):
//!
//!   t_matmul    = matmul_flops    / (matmul_peak  * mm_eff * fill)
//!   t_nonmatmul = nonmatmul_flops / (nonmatmul_pk * fill)
//!   t_compute   = t_matmul + t_nonmatmul          (serialized in-SM: the
//!                 softmax sits on the critical path between the two GEMMs)
//!   t_hbm       = hbm_bytes  / hbm_bw
//!   t_smem      = smem_bytes / smem_bw
//!   time        = max(t_compute, t_hbm, t_smem) / wave_efficiency
//!
//! where `fill` is the fraction of SMs occupied in the first wave (section
//! 3.2's occupancy effect: a grid of batch*heads = 16 blocks on 108 SMs can
//! use at most 15% of the compute no matter what), `wave_efficiency`
//! captures the partial-last-wave tail, and `mm_eff` derates the tensor-core
//! peak for tile geometry (head_dim 64 tiles utilize the MXU/tensor-core
//! pipeline less than 128-wide tiles; GEMM itself tops out at 80-90%).

use super::device::Device;
use super::occupancy::{occupancy, waves, BlockResources, Limiter};

/// A simulated kernel launch: grid + per-block resources + aggregate work.
#[derive(Debug, Clone)]
pub struct KernelLaunch {
    pub label: &'static str,
    pub grid: u64,
    pub block: BlockResources,
    /// Total tensor-core FLOPs over the whole kernel.
    pub matmul_flops: f64,
    /// Total CUDA-core (non-matmul) FLOPs: softmax exp/max/sum, rescales,
    /// masking — the currency of paper section 3.1.
    pub nonmatmul_flops: f64,
    /// Total HBM traffic in bytes (both directions).
    pub hbm_bytes: f64,
    /// Total shared-memory traffic in bytes, *excluding* what stays in
    /// registers.  Split-K partial exchanges land here (section 3.3).
    pub smem_bytes: f64,
    /// Tensor-core efficiency for this kernel's tile geometry (0..1].
    pub mm_eff: f64,
}

/// Cost breakdown for one kernel.
#[derive(Debug, Clone, Copy)]
pub struct KernelCost {
    pub time: f64,
    pub t_matmul: f64,
    pub t_nonmatmul: f64,
    pub t_hbm: f64,
    pub t_smem: f64,
    pub sm_fill: f64,
    pub wave_efficiency: f64,
    pub waves: u64,
    pub limiter: Limiter,
}

impl KernelCost {
    pub fn bound(&self) -> &'static str {
        let compute = self.t_matmul + self.t_nonmatmul;
        if compute >= self.t_hbm && compute >= self.t_smem {
            "compute"
        } else if self.t_hbm >= self.t_smem {
            "hbm"
        } else {
            "smem"
        }
    }
}

/// Fixed launch overhead per kernel (host->device, ~3-5us on real GPUs);
/// matters only for the standard-attention multi-kernel pipeline at tiny N.
const LAUNCH_OVERHEAD: f64 = 4e-6;

pub fn simulate(dev: &Device, k: &KernelLaunch) -> KernelCost {
    let occ = occupancy(dev, k.block);
    let w = waves(dev, &occ, k.grid);
    if occ.concurrent_blocks == 0 || k.grid == 0 {
        return KernelCost {
            time: f64::INFINITY,
            t_matmul: 0.0,
            t_nonmatmul: 0.0,
            t_hbm: 0.0,
            t_smem: 0.0,
            sm_fill: 0.0,
            wave_efficiency: 0.0,
            waves: 0,
            limiter: occ.limiter,
        };
    }
    let fill = w.sm_fill;
    let t_matmul = k.matmul_flops / (dev.matmul_flops * k.mm_eff * fill);
    let t_nonmatmul = k.nonmatmul_flops / (dev.nonmatmul_flops * fill);
    let t_compute = t_matmul + t_nonmatmul;
    let t_hbm = k.hbm_bytes / dev.hbm_bw;
    // smem bandwidth scales with the SMs actually in use.
    let t_smem = k.smem_bytes / (dev.smem_bw * fill);
    let time = t_compute.max(t_hbm).max(t_smem) / w.efficiency + LAUNCH_OVERHEAD;
    KernelCost {
        time,
        t_matmul,
        t_nonmatmul,
        t_hbm,
        t_smem,
        sm_fill: fill,
        wave_efficiency: w.efficiency,
        waves: w.waves,
        limiter: occ.limiter,
    }
}

/// Total time of a multi-kernel pipeline (standard attention = 3 kernels,
/// split-K = partial + combine, backward = D + dKdV + dQ).
pub fn simulate_pipeline(dev: &Device, kernels: &[KernelLaunch]) -> f64 {
    kernels.iter().map(|k| simulate(dev, k).time).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flash_like(grid: u64, matmul: f64, nonmatmul: f64) -> KernelLaunch {
        KernelLaunch {
            label: "test",
            grid,
            block: BlockResources::flash_block(4, 64 * 1024),
            matmul_flops: matmul,
            nonmatmul_flops: nonmatmul,
            hbm_bytes: 1e6,
            smem_bytes: 0.0,
            mm_eff: 0.9,
        }
    }

    #[test]
    fn compute_bound_kernel_hits_derated_peak() {
        let dev = Device::a100();
        let k = flash_like(10_000, 1e12, 0.0);
        let c = simulate(&dev, &k);
        assert_eq!(c.bound(), "compute");
        let achieved = 1e12 / c.time;
        // ~0.9 * 312T derated by wave efficiency; must be in (200, 290) TFLOPs.
        assert!(achieved > 200e12 && achieved < 290e12, "{achieved:e}");
    }

    #[test]
    fn nonmatmul_flops_are_16x_more_expensive() {
        let dev = Device::a100();
        let only_mm = simulate(&dev, &flash_like(10_000, 1e12, 0.0));
        let only_nm = simulate(&dev, &flash_like(10_000, 0.0, 1e12));
        let ratio = only_nm.t_nonmatmul / only_mm.t_matmul;
        // 16x raw penalty, scaled by the 0.9 mm_eff on the matmul side.
        assert!((ratio - 16.0 * 0.9).abs() < 0.2, "{ratio}");
    }

    #[test]
    fn hbm_bound_when_traffic_dominates() {
        let dev = Device::a100();
        let mut k = flash_like(10_000, 1e9, 0.0);
        k.hbm_bytes = 1e12; // 0.5s of HBM vs ~4us of compute
        let c = simulate(&dev, &k);
        assert_eq!(c.bound(), "hbm");
        assert!((c.time - 0.5).abs() / 0.5 < 0.1, "{}", c.time);
    }

    #[test]
    fn small_grid_is_slower_per_flop() {
        // Section 3.2: grid = 16 (batch*heads, long-seq regime) vs 4096.
        let dev = Device::a100();
        let small = simulate(&dev, &flash_like(16, 1e12, 0.0));
        let large = simulate(&dev, &flash_like(4096, 1e12, 0.0));
        assert!(
            small.time > 5.0 * large.time,
            "small {} vs large {}",
            small.time,
            large.time
        );
    }

    #[test]
    fn smem_traffic_adds_cost() {
        let dev = Device::a100();
        let mut with_exchange = flash_like(4096, 1e12, 0.0);
        with_exchange.smem_bytes = 1e11; // split-K style exchange
        let base = simulate(&dev, &flash_like(4096, 1e12, 0.0));
        let loaded = simulate(&dev, &with_exchange);
        assert!(loaded.time > base.time);
        assert_eq!(loaded.bound(), "smem");
    }

    #[test]
    fn pipeline_sums_kernels() {
        let dev = Device::a100();
        let k = flash_like(4096, 1e12, 0.0);
        let one = simulate(&dev, &k).time;
        let three = simulate_pipeline(&dev, &[k.clone(), k.clone(), k]);
        assert!((three - 3.0 * one).abs() / three < 1e-9);
    }

    #[test]
    fn oversized_kernel_is_infinite() {
        let dev = Device::a100();
        let mut k = flash_like(100, 1e12, 0.0);
        k.block.smem_bytes = 300 * 1024;
        assert!(simulate(&dev, &k).time.is_infinite());
    }
}
