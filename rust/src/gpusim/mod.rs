//! GPU cost model: devices, occupancy, wave scheduling, kernel timing.
//! This is the performance substrate (DESIGN.md section 1) that regenerates
//! the paper's A100/H100 figures on a machine that has neither.

pub mod comm;
pub mod device;
pub mod kernel;
pub mod occupancy;

pub use comm::RingLink;
pub use device::Device;
pub use kernel::{simulate, simulate_pipeline, KernelCost, KernelLaunch};
pub use occupancy::{occupancy, waves, BlockResources, Limiter, Occupancy};
