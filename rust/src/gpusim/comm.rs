//! Ring-interconnect cost term for sequence-parallel attention (DESIGN.md
//! §16).
//!
//! The executing seqpar layer (`attn::exec::seqpar`) rotates KV shards
//! around an in-process ring; on real hardware the same schedule rides a
//! device-to-device interconnect (NVLink-class for intra-node rings).
//! This module prices that transport with the standard α–β model:
//!
//!   t_exchange = msgs · latency + total_bytes / bandwidth
//!
//! The **calibration contract**: the byte count fed to this model is
//! [`SeqParPlan::fwd_comm_bytes`] — the exact same formula the executing
//! transport meters into `seqpar_comm_bytes_total` (asserted equal in
//! both layers' tests).  Because bytes-moved is the shared currency, the
//! simulated and executing layers rank shard counts the same way: more
//! shards always means more exchanged bytes (each shard visits more
//! peers), while per-worker compute shrinks — the crossover the
//! `attn::autotune::seqpar_cost` search exposes.
//!
//! [`SeqParPlan::fwd_comm_bytes`]: crate::attn::exec::seqpar::SeqParPlan::fwd_comm_bytes

/// One directed ring link in the α–β (latency–bandwidth) model.
#[derive(Debug, Clone, Copy)]
pub struct RingLink {
    /// Sustained payload bandwidth in bytes/second.
    pub bandwidth: f64,
    /// Per-message latency in seconds (α term).
    pub latency: f64,
}

impl RingLink {
    /// NVLink-class intra-node link: ~250 GB/s per direction, ~1.5 µs
    /// per-message launch+sync latency.
    pub fn nvlink() -> RingLink {
        RingLink { bandwidth: 250e9, latency: 1.5e-6 }
    }

    /// PCIe-class fallback link: ~25 GB/s, ~5 µs latency — an order of
    /// magnitude slower, shifting the compute/comm crossover toward
    /// fewer shards.
    pub fn pcie() -> RingLink {
        RingLink { bandwidth: 25e9, latency: 5e-6 }
    }

    /// Time for one message of `bytes` payload over this link.
    pub fn hop_time(&self, bytes: f64) -> f64 {
        self.latency + bytes / self.bandwidth
    }

    /// Time for a whole exchange of `msgs` messages totalling
    /// `total_bytes` — the α–β cost of one seqpar pass's transport.
    /// Strictly monotone in both arguments (any positive latency and
    /// finite bandwidth), which is what keeps the shard-count ranking
    /// honest.
    pub fn exchange_time(&self, msgs: u64, total_bytes: f64) -> f64 {
        msgs as f64 * self.latency + total_bytes / self.bandwidth
    }
}

impl Default for RingLink {
    fn default() -> Self {
        RingLink::nvlink()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::exec::seqpar::{forward_spec, SeqParParams, SeqParPlan};
    use crate::attn::spec::{AttnSpec, HeadMap, Mask};
    use crate::util::rng::Rng;

    fn spec(seq: usize) -> AttnSpec {
        AttnSpec {
            batch: 1,
            heads: HeadMap::mha(2),
            seq,
            head_dim: 16,
            mask: Mask::Full,
        }
    }

    fn sim_cost(sp: &AttnSpec, workers: usize) -> (u64, f64) {
        let prm = SeqParParams { workers, chunk: 32, striped: true };
        let plan = SeqParPlan::build(sp, &prm);
        let bytes = plan.fwd_comm_bytes(sp);
        (bytes, RingLink::nvlink().exchange_time(plan.fwd_comm_msgs(), bytes as f64))
    }

    #[test]
    fn alpha_beta_terms_price_as_declared() {
        let l = RingLink { bandwidth: 100e9, latency: 2e-6 };
        assert!((l.hop_time(100e9) - (1.0 + 2e-6)).abs() < 1e-9);
        let t = l.exchange_time(10, 200e9);
        assert!((t - (10.0 * 2e-6 + 2.0)).abs() < 1e-9);
        // zero-byte exchange still pays latency per message
        assert!((l.exchange_time(4, 0.0) - 8e-6).abs() < 1e-12);
        assert!(RingLink::nvlink().bandwidth > RingLink::pcie().bandwidth);
    }

    #[test]
    fn simulated_ring_cost_is_monotone_in_shards_and_seq() {
        // Satellite bugfix pin: under a Full mask, every extra shard adds
        // ring traffic ((W-1)/W of total KV per rotation grows with W),
        // and longer sequences ship more bytes at every W.
        for seq in [256usize, 512, 1024] {
            let sp = spec(seq);
            let mut prev = sim_cost(&sp, 1);
            assert_eq!(prev.0, 0, "W=1 must ship zero bytes");
            for w in [2usize, 4, 8] {
                let cur = sim_cost(&sp, w);
                assert!(
                    cur.0 > prev.0 && cur.1 > prev.1,
                    "cost not monotone in shard count at seq {seq}: W={w} {cur:?} vs {prev:?}"
                );
                prev = cur;
            }
        }
        for w in [2usize, 4, 8] {
            let mut prev = sim_cost(&spec(256), w);
            for seq in [512usize, 1024] {
                let cur = sim_cost(&spec(seq), w);
                assert!(
                    cur.0 > prev.0 && cur.1 > prev.1,
                    "cost not monotone in seq at W={w}"
                );
                prev = cur;
            }
        }
    }

    #[test]
    fn simulated_bytes_agree_with_executing_counter_on_two_shapes() {
        // The calibration contract: the byte count the cost model prices
        // is the byte count the executing transport actually meters.
        let mut rng = Rng::seed_from(0xC0DE);
        for (sp, workers) in [
            (spec(256), 4usize),
            (
                AttnSpec {
                    batch: 2,
                    heads: HeadMap { n_q_heads: 4, n_kv_heads: 2 },
                    seq: 320,
                    head_dim: 8,
                    mask: Mask::Causal,
                },
                5,
            ),
        ] {
            let gen = |rng: &mut Rng, n: usize| -> Vec<f32> {
                (0..n).map(|_| rng.normal() as f32).collect()
            };
            let q = gen(&mut rng, sp.q_elems());
            let k = gen(&mut rng, sp.kv_elems());
            let v = gen(&mut rng, sp.kv_elems());
            let prm = SeqParParams { workers, chunk: 32, striped: true };
            let (_, stats) = forward_spec(&q, &k, &v, sp, prm).expect("seqpar fwd");
            let plan = SeqParPlan::build(&sp, &prm);
            assert_eq!(
                stats.comm_bytes,
                plan.fwd_comm_bytes(&sp),
                "measured ring bytes diverge from the simulated model's input ({sp:?})"
            );
            assert_eq!(stats.comm_msgs, plan.fwd_comm_msgs());
            // identical inputs → identical simulated price for the
            // executing run and the planned run
            let link = RingLink::default();
            let sim = link.exchange_time(plan.fwd_comm_msgs(), plan.fwd_comm_bytes(&sp) as f64);
            let exec = link.exchange_time(stats.comm_msgs, stats.comm_bytes as f64);
            assert!((sim - exec).abs() < 1e-15, "{sim} vs {exec}");
        }
    }
}
