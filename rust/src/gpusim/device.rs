//! GPU device models.
//!
//! The paper's performance claims are functions of a handful of hardware
//! ratios (section 2.1 / 3.1): matmul vs non-matmul throughput (16x on
//! A100), HBM vs SRAM bandwidth (~10x), and the SM count that the
//! parallelism section (3.2) plays against.  This module pins those numbers
//! for the two devices the paper evaluates (A100 80GB SXM, H100 SXM) from
//! the paper text and the Jia et al. microbenchmark reports it cites.

/// Static description of a GPU for the cost model.
#[derive(Debug, Clone, PartialEq)]
pub struct Device {
    pub name: &'static str,
    pub num_sms: u32,
    /// Dense FP16/BF16 tensor-core peak, FLOP/s (A100: 312e12).
    pub matmul_flops: f64,
    /// FP32 CUDA-core peak, FLOP/s (A100: 19.5e12) — the paper's "16x more
    /// expensive per non-matmul FLOP".
    pub nonmatmul_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// Aggregate shared-memory bandwidth across all SMs, bytes/s
    /// (A100: ~19 TB/s, Jia & Van Sandt).
    pub smem_bw: f64,
    /// Shared memory usable per thread block, bytes (A100: 163 KiB of the
    /// 192 KiB SRAM per SM is available to a single block).
    pub smem_per_block_max: usize,
    /// Shared memory per SM available for occupancy, bytes.
    pub smem_per_sm: usize,
    /// 32-bit registers per SM.
    pub regs_per_sm: u32,
    pub max_threads_per_sm: u32,
    /// Hardware cap on threads in a single block (1024 on every CUDA GPU);
    /// a wider block cannot launch regardless of SM-level resources.
    pub max_threads_per_block: u32,
    pub max_blocks_per_sm: u32,
    pub threads_per_warp: u32,
}

impl Device {
    /// NVIDIA A100 SXM4 80GB — the paper's primary testbed (section 4.1).
    pub fn a100() -> Device {
        Device {
            name: "A100-SXM4-80GB",
            num_sms: 108,
            matmul_flops: 312e12,
            nonmatmul_flops: 19.5e12,
            hbm_bw: 2.0e12,
            smem_bw: 19e12,
            smem_per_block_max: 163 * 1024,
            smem_per_sm: 164 * 1024,
            regs_per_sm: 65536,
            max_threads_per_sm: 2048,
            max_threads_per_block: 1024,
            max_blocks_per_sm: 32,
            threads_per_warp: 32,
        }
    }

    /// NVIDIA H100 SXM5 — figure 7 ("no special instructions", i.e. the same
    /// kernels running on Hopper without TMA/WGMMA, which caps the achieved
    /// fraction well below Hopper's wgmma peak).  The paper reports up to
    /// 335 TFLOPs/s; Ampere-style mma.sync on H100 reaches roughly half of
    /// the 989 TFLOPs/s wgmma peak, which is what `matmul_flops` models.
    pub fn h100() -> Device {
        Device {
            name: "H100-SXM5",
            num_sms: 132,
            // Ampere-path (mma.sync) effective tensor-core peak on Hopper:
            // ~0.48x of the 989e12 wgmma peak (no TMA / 4th-gen cores, as
            // the paper's figure 7 caption states) — calibrated so the same
            // kernels land at the paper's ~335 TFLOPs/s fwd+bwd.
            matmul_flops: 470e12,
            nonmatmul_flops: 60e12,
            hbm_bw: 3.35e12,
            smem_bw: 33e12,
            smem_per_block_max: 227 * 1024,
            smem_per_sm: 228 * 1024,
            regs_per_sm: 65536,
            max_threads_per_sm: 2048,
            max_threads_per_block: 1024,
            max_blocks_per_sm: 32,
            threads_per_warp: 32,
        }
    }

    pub fn by_name(name: &str) -> Option<Device> {
        match name.to_ascii_lowercase().as_str() {
            "a100" => Some(Device::a100()),
            "h100" => Some(Device::h100()),
            _ => None,
        }
    }

    /// The paper's headline ratio: non-matmul FLOPs are this many times more
    /// expensive than matmul FLOPs (16x on A100).
    pub fn nonmatmul_penalty(&self) -> f64 {
        self.matmul_flops / self.nonmatmul_flops
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_matches_paper_numbers() {
        let d = Device::a100();
        assert_eq!(d.num_sms, 108);
        assert_eq!(d.matmul_flops, 312e12);
        assert_eq!(d.nonmatmul_flops, 19.5e12);
        // "each non-matmul FLOP is 16x more expensive" (section 3.1)
        assert_eq!(d.nonmatmul_penalty(), 16.0);
    }

    #[test]
    fn h100_is_faster_everywhere() {
        let a = Device::a100();
        let h = Device::h100();
        assert!(h.matmul_flops > a.matmul_flops);
        assert!(h.hbm_bw > a.hbm_bw);
        assert!(h.num_sms > a.num_sms);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(Device::by_name("A100").unwrap().name, "A100-SXM4-80GB");
        assert_eq!(Device::by_name("h100").unwrap().num_sms, 132);
        assert!(Device::by_name("v100").is_none());
    }
}
