//! Occupancy model: how many thread blocks fit per SM, and how a grid maps
//! onto waves.  This is the machinery behind the paper's section 3.2 claim:
//! parallelizing over the sequence dimension raises occupancy exactly when
//! `batch x heads` alone cannot fill the SMs.

use super::device::Device;

/// Per-block resource demands of a simulated kernel.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BlockResources {
    pub threads: u32,
    pub regs_per_thread: u32,
    pub smem_bytes: usize,
}

impl BlockResources {
    /// Typical FlashAttention-style block: `warps` warps, full register use,
    /// smem holding the K/V (+Q) tiles.
    pub fn flash_block(warps: u32, smem_bytes: usize) -> BlockResources {
        BlockResources { threads: warps * 32, regs_per_thread: 128, smem_bytes }
    }
}

/// Result of the occupancy calculation.
#[derive(Debug, Clone, Copy)]
pub struct Occupancy {
    /// Blocks that can be resident on one SM simultaneously.
    pub blocks_per_sm: u32,
    /// Blocks resident across the whole device.
    pub concurrent_blocks: u64,
    /// What limited it (for ablation reports).
    pub limiter: Limiter,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Limiter {
    SharedMemory,
    Registers,
    Threads,
    BlockSlots,
    KernelDoesNotFit,
}

/// Compute device occupancy for a block shape.
///
/// A block that cannot launch at all — zero threads, wider than the
/// hardware block limit, shared memory beyond the per-block budget, or a
/// register file larger than the SM's — reports zero occupancy with
/// `Limiter::KernelDoesNotFit`.  It must never divide by zero here or hand
/// a bogus `blocks_per_sm` to the wave analysis downstream (`waves` and
/// `kernel::simulate` both treat `concurrent_blocks == 0` as unlaunchable).
pub fn occupancy(dev: &Device, res: BlockResources) -> Occupancy {
    const DOES_NOT_FIT: Occupancy = Occupancy {
        blocks_per_sm: 0,
        concurrent_blocks: 0,
        limiter: Limiter::KernelDoesNotFit,
    };
    if res.threads == 0 || res.threads > dev.max_threads_per_block {
        return DOES_NOT_FIT;
    }
    if res.smem_bytes > dev.smem_per_block_max {
        return DOES_NOT_FIT;
    }
    // Register arithmetic in u64: 2^20 regs/thread x 1024 threads would
    // overflow u32 before the comparison rejects it.
    let regs_per_block = res.regs_per_thread as u64 * res.threads as u64;
    if regs_per_block > dev.regs_per_sm as u64 {
        return DOES_NOT_FIT;
    }
    let by_smem = if res.smem_bytes == 0 {
        u32::MAX
    } else {
        (dev.smem_per_sm / res.smem_bytes) as u32
    };
    let by_regs = if regs_per_block == 0 {
        u32::MAX
    } else {
        (dev.regs_per_sm as u64 / regs_per_block) as u32
    };
    let by_threads = dev.max_threads_per_sm / res.threads;
    let by_slots = dev.max_blocks_per_sm;

    let (blocks, limiter) = [
        (by_smem, Limiter::SharedMemory),
        (by_regs, Limiter::Registers),
        (by_threads, Limiter::Threads),
        (by_slots, Limiter::BlockSlots),
    ]
    .into_iter()
    .min_by_key(|(b, _)| *b)
    .unwrap();

    if blocks == 0 {
        return DOES_NOT_FIT;
    }
    Occupancy {
        blocks_per_sm: blocks,
        concurrent_blocks: blocks as u64 * dev.num_sms as u64,
        limiter,
    }
}

/// Wave analysis for a grid of `grid` blocks at a given occupancy.
#[derive(Debug, Clone, Copy)]
pub struct Waves {
    pub waves: u64,
    /// Fraction of resident-block slots doing useful work, averaged over
    /// waves (the "tail effect": a grid of 110 blocks on 108 concurrent
    /// slots runs 2 waves at ~51% average utilization).
    pub efficiency: f64,
    /// Fraction of SMs with at least one block in the FIRST wave — the
    /// "occupancy" the paper's section 3.2 is about (grid 16 on 108 SMs
    /// leaves 85% of the chip idle regardless of waves).
    pub sm_fill: f64,
}

pub fn waves(dev: &Device, occ: &Occupancy, grid: u64) -> Waves {
    if occ.concurrent_blocks == 0 || grid == 0 {
        return Waves { waves: 0, efficiency: 0.0, sm_fill: 0.0 };
    }
    let w = grid.div_ceil(occ.concurrent_blocks);
    // Tail effect across waves: only meaningful when there IS more than one
    // wave (a single partial wave is already captured by sm_fill below —
    // penalizing both would double-count idle SMs).  Real schedulers
    // backfill the last wave as blocks of earlier waves retire (block
    // durations are not uniform), so the quantized tail is softened halfway
    // toward the continuous ideal.
    let efficiency = if w > 1 {
        let w_cont = grid as f64 / occ.concurrent_blocks as f64;
        let w_eff = 0.5 * w_cont + 0.5 * w as f64;
        w_cont / w_eff
    } else {
        1.0
    };
    // The hardware scheduler spreads blocks across SMs before stacking them:
    // a grid of 32 blocks occupies 32 SMs (one each), not 8 SMs of 4.
    let active_sms = (grid.min(occ.concurrent_blocks) as f64).min(dev.num_sms as f64);
    // Latency-hiding penalty: an SM with a single resident block cannot
    // overlap softmax with the next tile's loads as well as 2+ blocks can.
    let resident = (grid as f64 / active_sms).min(occ.blocks_per_sm as f64);
    let lat_pen = 0.8 + 0.2 * (resident / 2.0).min(1.0);
    Waves { waves: w, efficiency, sm_fill: active_sms / dev.num_sms as f64 * lat_pen }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> Device {
        Device::a100()
    }

    #[test]
    fn smem_limited_block() {
        // 48 KiB smem per block -> 3 blocks per SM on A100 (164 KiB budget).
        let occ = occupancy(&dev(), BlockResources { threads: 128, regs_per_thread: 64, smem_bytes: 48 * 1024 });
        assert_eq!(occ.blocks_per_sm, 3);
        assert_eq!(occ.limiter, Limiter::SharedMemory);
        assert_eq!(occ.concurrent_blocks, 3 * 108);
    }

    #[test]
    fn register_limited_block() {
        // 256 threads x 255 regs = 65280 regs -> 1 block/SM.
        let occ = occupancy(&dev(), BlockResources { threads: 256, regs_per_thread: 255, smem_bytes: 1024 });
        assert_eq!(occ.blocks_per_sm, 1);
        assert_eq!(occ.limiter, Limiter::Registers);
    }

    #[test]
    fn kernel_too_large_does_not_fit() {
        // Paper section 3.3: "the amount of shared memory required is larger
        // than what the GPU has available, and the kernel cannot run at all".
        let occ = occupancy(&dev(), BlockResources { threads: 128, regs_per_thread: 64, smem_bytes: 200 * 1024 });
        assert_eq!(occ.limiter, Limiter::KernelDoesNotFit);
        assert_eq!(occ.concurrent_blocks, 0);
    }

    #[test]
    fn small_grid_leaves_sms_idle() {
        // The FA1 long-sequence pathology: grid = batch*heads = 16 blocks.
        let occ = occupancy(&dev(), BlockResources::flash_block(4, 64 * 1024));
        let w = waves(&dev(), &occ, 16);
        assert_eq!(w.waves, 1);
        assert!(w.sm_fill < 0.16, "sm_fill={}", w.sm_fill);
    }

    #[test]
    fn large_grid_fills_device() {
        let occ = occupancy(&dev(), BlockResources::flash_block(4, 64 * 1024));
        let w = waves(&dev(), &occ, 4096);
        assert!(w.sm_fill > 0.99);
        assert!(w.efficiency > 0.9);
    }

    #[test]
    fn wave_tail_effect() {
        let occ = occupancy(&dev(), BlockResources { threads: 128, regs_per_thread: 64, smem_bytes: dev().smem_per_block_max });
        // 1 block/SM -> 108 concurrent; grid 110 -> 2 waves.  Backfill
        // softening: efficiency = w_cont / (0.5*w_cont + 0.5*2) ~ 0.675,
        // between the harsh quantized 0.51 and the continuous ideal 1.0.
        assert_eq!(occ.blocks_per_sm, 1);
        let w = waves(&dev(), &occ, 110);
        assert_eq!(w.waves, 2);
        let w_cont = 110.0 / 108.0;
        assert!((w.efficiency - w_cont / (0.5 * w_cont + 1.0)).abs() < 1e-9);
        assert!(w.efficiency > 0.5 && w.efficiency < 1.0);
    }

    #[test]
    fn zero_thread_block_cannot_launch() {
        let occ = occupancy(&dev(), BlockResources { threads: 0, regs_per_thread: 64, smem_bytes: 1024 });
        assert_eq!(occ.limiter, Limiter::KernelDoesNotFit);
        assert_eq!(occ.blocks_per_sm, 0);
        assert_eq!(occ.concurrent_blocks, 0);
    }

    #[test]
    fn block_wider_than_hw_limit_cannot_launch() {
        // 2048 threads fit an SM's thread budget but not a single block's.
        let occ = occupancy(&dev(), BlockResources { threads: 2048, regs_per_thread: 16, smem_bytes: 1024 });
        assert_eq!(occ.limiter, Limiter::KernelDoesNotFit);
        assert_eq!(occ.concurrent_blocks, 0);
    }

    #[test]
    fn register_file_overflow_is_does_not_fit() {
        // 1024 threads x 255 regs = 261120 > 65536 regs/SM: the block can
        // never be resident, which is KernelDoesNotFit, not Registers with
        // a fabricated blocks_per_sm.
        let occ = occupancy(&dev(), BlockResources { threads: 1024, regs_per_thread: 255, smem_bytes: 0 });
        assert_eq!(occ.limiter, Limiter::KernelDoesNotFit);
        // And absurd per-thread counts must not overflow the arithmetic.
        let occ = occupancy(&dev(), BlockResources { threads: 1024, regs_per_thread: u32::MAX, smem_bytes: 0 });
        assert_eq!(occ.limiter, Limiter::KernelDoesNotFit);
    }

    #[test]
    fn unlaunchable_block_yields_zero_waves_downstream() {
        let occ = occupancy(&dev(), BlockResources { threads: 0, regs_per_thread: 0, smem_bytes: 0 });
        let w = waves(&dev(), &occ, 4096);
        assert_eq!(w.waves, 0);
        assert_eq!(w.efficiency, 0.0);
        assert_eq!(w.sm_fill, 0.0);
    }

    #[test]
    fn more_sms_never_fewer_concurrent_blocks() {
        // gpusim monotonicity property from DESIGN.md section 5.
        let res = BlockResources::flash_block(8, 100 * 1024);
        let a = occupancy(&Device::a100(), res);
        let h = occupancy(&Device::h100(), res);
        assert!(h.concurrent_blocks >= a.concurrent_blocks);
    }
}
