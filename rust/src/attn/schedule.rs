//! Attention schedules: how standard attention, FlashAttention-1, the
//! Triton implementation, and FlashAttention-2 map the same math onto GPU
//! kernels.  Each schedule builds `KernelLaunch`es for the `gpusim` cost
//! model; the *differences between schedules are exactly the paper's three
//! contributions*:
//!
//!   1. non-matmul FLOP counts  (`per_iter_rescale`, `mask_all_blocks`,
//!      `stores_m_and_l`)                                    — section 3.1
//!   2. grid shape (`seqlen_parallel`)                       — section 3.2
//!   3. warp partitioning (`split_k_warps` -> smem exchange) — section 3.3
//!
//! Counting conventions (all auditable in `fwd_kernels`/`bwd_kernels`):
//! exp = 4 FLOPs, div = 4 FLOPs, everything else 1 FLOP.

use crate::gpusim::device::Device;
use crate::gpusim::kernel::{simulate_pipeline, KernelLaunch};
use crate::gpusim::occupancy::BlockResources;

use super::problem::{AttnProblem, Pass};

const EXP: f64 = 4.0;
const DIV: f64 = 4.0;
/// Effective smem read traffic per staged tile, in tile-sizes: warps share
/// tiles through ldmatrix broadcasts, so reads do not scale with warp count.
const SMEM_READ_FACTOR: f64 = 2.0;

/// Which implementation a schedule models.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    /// PyTorch-style standard attention: 3 kernels, materializes S and P.
    Standard,
    /// FlashAttention (original): batch*heads grid, split-K warps,
    /// per-iteration output rescale, stores (m, l).
    Flash1,
    /// The Triton implementation: FA2-style loop order and seqlen
    /// parallelism, but weaker codegen (calibrated `mm_eff`) and
    /// unconditional masking.
    Triton,
    /// FlashAttention-2.
    Flash2,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Standard => "standard",
            Method::Flash1 => "flashattention",
            Method::Triton => "triton",
            Method::Flash2 => "flashattention-2",
        }
    }

    pub fn all() -> [Method; 4] {
        [Method::Standard, Method::Flash1, Method::Triton, Method::Flash2]
    }
}

/// Tiling + work-partitioning knobs for the flash-style schedules.
#[derive(Debug, Clone, Copy)]
pub struct ScheduleSpec {
    pub method: Method,
    pub block_q: u64,
    pub block_k: u64,
    pub warps: u32,
    /// Grid includes the sequence dimension (section 3.2).  Without it the
    /// grid is batch*heads only.
    pub seqlen_parallel: bool,
    /// Warps split K/V and exchange partial outputs through shared memory
    /// (section 3.3's "split-K scheme").
    pub split_k_warps: bool,
    /// Output accumulator rescaled by diag(l)^-1 every iteration
    /// (section 3.1 tweak #1 removes this).
    pub per_iter_rescale: bool,
    /// Stores both m and l instead of the single logsumexp
    /// (section 3.1 tweak #2 removes this).
    pub stores_m_and_l: bool,
    /// Causal masking applied to every visited block, not only diagonal
    /// blocks (section 3.1 "causal masking" tweak #2 removes this).
    pub mask_all_blocks: bool,
    /// Tensor-core efficiency of the generated code (tile geometry and
    /// pipelining quality; calibration knob, see DESIGN.md section 1).
    pub mm_eff_fwd: f64,
    pub mm_eff_bwd: f64,
}

impl ScheduleSpec {
    pub fn for_method(method: Method, head_dim: u64) -> ScheduleSpec {
        // Block sizes per the paper section 3.3: {64,128} x {64,128},
        // chosen per head_dim so the tiles fit shared memory.
        let (bq, bk) = if head_dim <= 64 { (128, 128) } else { (128, 64) };
        match method {
            Method::Flash2 => ScheduleSpec {
                method,
                block_q: bq,
                block_k: bk,
                warps: 4,
                seqlen_parallel: true,
                split_k_warps: false,
                per_iter_rescale: false,
                stores_m_and_l: false,
                mask_all_blocks: false,
                mm_eff_fwd: 0.90,
                mm_eff_bwd: 0.82,
            },
            Method::Triton => ScheduleSpec {
                method,
                block_q: bq,
                block_k: bk,
                warps: 4,
                seqlen_parallel: true,
                split_k_warps: false,
                per_iter_rescale: false,
                stores_m_and_l: false,
                mask_all_blocks: true,
                // Calibrated to the paper's measured 1.3-1.5x fwd and ~2x
                // bwd gaps vs FA2 (section 4.1).
                mm_eff_fwd: 0.65,
                mm_eff_bwd: 0.42,
            },
            Method::Flash1 => ScheduleSpec {
                method,
                block_q: bq.min(64),
                block_k: bk,
                warps: 4,
                seqlen_parallel: false,
                split_k_warps: true,
                per_iter_rescale: true,
                stores_m_and_l: true,
                mask_all_blocks: true,
                // FA1's CUTLASS 2.x codegen + split-K epilogue kept it at
                // 30-50% of peak fwd / 25-35% bwd (paper section 1).
                mm_eff_fwd: 0.72,
                mm_eff_bwd: 0.55,
            },
            Method::Standard => panic!("standard attention uses standard_kernels()"),
        }
    }
}

/// Exact count of visited (q-block, kv-block) pairs per (batch, head),
/// honouring causal block skipping.
pub fn visited_pairs(p: &AttnProblem, bq: u64, bk: u64) -> u64 {
    let tr = p.seqlen.div_ceil(bq);
    let tc = p.seqlen.div_ceil(bk);
    if !p.causal {
        return tr * tc;
    }
    (0..tr)
        .map(|i| (((i + 1) * bq).div_ceil(bk)).min(tc))
        .sum()
}

/// Number of diagonal (mask-straddling) pairs per (batch, head).
fn diagonal_pairs(p: &AttnProblem, bq: u64, bk: u64) -> u64 {
    if !p.causal {
        return 0;
    }
    let tr = p.seqlen.div_ceil(bq);
    // blocks j with j*bk < (i+1)*bq and (j+1)*bk - 1 > i*bq
    (0..tr)
        .map(|i| {
            let lo = (i * bq) / bk;
            let hi = ((i + 1) * bq).div_ceil(bk);
            hi - lo
        })
        .sum()
}

/// Shared-memory footprint of a forward flash block: Q tile + K,V tiles
/// (double-buffered K/V, as real implementations pipeline the loads).
fn fwd_smem(spec: &ScheduleSpec, d: u64, bytes: u64) -> usize {
    ((spec.block_q * d + 2 * spec.block_k * d) * bytes) as usize
}

/// Backward needs Q, dO, K, V tiles plus dS staging (5-matmul working set,
/// paper section 2.3.2 "more values to be kept in SRAM").
fn bwd_smem(spec: &ScheduleSpec, d: u64, bytes: u64) -> usize {
    ((2 * spec.block_q * d + 2 * spec.block_k * d + spec.block_q * spec.block_k)
        * bytes) as usize
}

/// Build forward kernels for a flash-style schedule.
pub fn fwd_kernels(p: &AttnProblem, spec: &ScheduleSpec) -> Vec<KernelLaunch> {
    let bh = p.batch * p.heads;
    let (bq, bk, d) = (spec.block_q, spec.block_k, p.head_dim);
    let pairs = visited_pairs(p, bq, bk) as f64 * bh as f64;
    let diag = diagonal_pairs(p, bq, bk) as f64 * bh as f64;
    let rows = (p.seqlen * bh) as f64;
    let tile = (bq * bk) as f64;

    // -- matmul: QK^T + PV per visited pair --
    let matmul = pairs * 4.0 * tile as f64 * d as f64 / 2.0 * 2.0; // 2*2*Bq*Bk*d
    // -- non-matmul: online softmax per pair --
    let mut nonmatmul = pairs * tile * (1.0 + EXP + 1.0 + 1.0); // max, exp, sum, scale
    nonmatmul += pairs * (bq as f64) * (EXP + 2.0); // alpha + l update per row
    // accumulator rescale by alpha each iteration (FA2 keeps this; it is the
    // diag(l)^-1 *division* that is deferred)
    nonmatmul += pairs * (bq * d) as f64;
    if spec.per_iter_rescale {
        // FA1: full diag(l)^-1 normalization every iteration: ratio (div) +
        // acc multiply + new-term divide over Bq x d.
        nonmatmul += pairs * ((bq as f64) * DIV + 2.0 * (bq * d) as f64 + (bq * d) as f64 * DIV);
    } else {
        // FA2: single final rescale + logsumexp.
        nonmatmul += rows * (d as f64 * DIV + EXP + 1.0);
    }
    // masking
    let masked_pairs = if spec.mask_all_blocks { pairs } else { diag };
    nonmatmul += masked_pairs * tile * 2.0;

    // -- HBM traffic --
    // Fraction of the full Tr x Tc square actually visited (causal block
    // skipping also skips the corresponding K/V tile loads).
    let tr = p.seqlen.div_ceil(bq) as f64;
    let tc = p.seqlen.div_ceil(bk) as f64;
    let visit_frac = pairs / (tr * tc * bh as f64);
    let stats = if spec.stores_m_and_l { 2.0 } else { 1.0 };
    let mut hbm = p.qkv_bytes() + p.o_bytes() + rows * 4.0 * stats;
    if !spec.seqlen_parallel {
        // FA1 loop order: K/V resident, Q and O streamed per KV block —
        // O is read+written every outer iteration (the rewrite FA2 removes
        // by swapping the loops).
        hbm += bh as f64 * tc * visit_frac
            * (p.seqlen * d) as f64 * p.dtype_bytes as f64 * 2.0;
    } else {
        // seqlen-parallel: every Q block re-reads its visited share of K,V.
        hbm += (tr - 1.0).max(0.0) * visit_frac * 2.0 / 3.0 * p.qkv_bytes();
    }

    // -- shared-memory traffic --
    // Baseline: K/V tiles staged through smem; warp reads amortized by
    // ldmatrix-style broadcast (~2 read-equivalents per tile).
    let kv_tile_bytes = (2 * bk * d * p.dtype_bytes) as f64;
    let mut smem = pairs * kv_tile_bytes * (1.0 + SMEM_READ_FACTOR);
    if spec.split_k_warps {
        // Section 3.3 split-K: every warp writes its partial O (f32) +
        // (m,l) to shared memory once and the reduction reads each once.
        let partial = (bq * d) as f64 * 4.0 + (2 * bq) as f64 * 4.0;
        smem += pairs * spec.warps as f64 * partial;
    }

    let grid = if spec.seqlen_parallel {
        bh * p.seqlen.div_ceil(bq)
    } else {
        bh
    };
    vec![KernelLaunch {
        label: "attn_fwd",
        grid,
        block: BlockResources {
            threads: spec.warps * 32,
            regs_per_thread: 128,
            smem_bytes: fwd_smem(spec, d, p.dtype_bytes),
        },
        matmul_flops: matmul,
        nonmatmul_flops: nonmatmul,
        hbm_bytes: hbm,
        smem_bytes: smem,
        mm_eff: spec.mm_eff_fwd,
    }]
}

/// Build backward kernels for a flash-style schedule (paper Algorithm 2:
/// 5 matmuls per visited pair, P recomputed from the saved statistic).
pub fn bwd_kernels(p: &AttnProblem, spec: &ScheduleSpec) -> Vec<KernelLaunch> {
    let bh = p.batch * p.heads;
    let (bq, bk, d) = (spec.block_q, spec.block_k, p.head_dim);
    let pairs = visited_pairs(p, bq, bk) as f64 * bh as f64;
    let diag = diagonal_pairs(p, bq, bk) as f64 * bh as f64;
    let rows = (p.seqlen * bh) as f64;
    let tile = (bq * bk) as f64;

    // 5 matmuls: S=QK^T, dV+=P^T dO, dP=dO V^T, dQ+=dS K, dK+=dS^T Q.
    let matmul = pairs * 5.0 * 2.0 * tile * d as f64;
    // recompute P = exp(S - L), dS = P o (dP - D), masking, D precompute.
    let mut nonmatmul = pairs * tile * (EXP + 1.0 + 2.0);
    nonmatmul += rows * (2.0 * d as f64); // D = rowsum(dO o O)
    let masked_pairs = if spec.mask_all_blocks { pairs } else { diag };
    nonmatmul += masked_pairs * tile * 2.0;
    if spec.stores_m_and_l {
        nonmatmul += pairs * tile; // extra subtract path using separate m, l
    }

    // HBM: Q,K,V,O,dO read; dQ,dK,dV written; dQ via atomic adds in the
    // seqlen-parallel scheme (each column-block worker adds its dQ_i
    // contribution, section 3.2 backward).
    let tr = p.seqlen.div_ceil(bq) as f64;
    let tc = p.seqlen.div_ceil(bk) as f64;
    let visit_frac = pairs / (tr * tc * bh as f64);
    let stats = if spec.stores_m_and_l { 2.0 } else { 1.0 };
    let mut hbm = p.qkv_bytes() * 2.0 + p.o_bytes() * 3.0 + rows * 4.0 * (stats + 1.0);
    if spec.seqlen_parallel {
        // dQ atomic traffic: one f32 add per row element per visited column
        // block (section 3.2: "atomic adds to communicate between different
        // thread blocks to update dQ").
        hbm += tc * visit_frac * rows * d as f64 * 4.0;
        // every column block re-reads its visited share of Q and dO
        hbm += (tc - 1.0).max(0.0) * visit_frac
            * 2.0 * (rows * d as f64) * p.dtype_bytes as f64;
    } else {
        // FA1 bwd loop order: dQ read+modify+write per column block.
        hbm += tc * visit_frac * rows * d as f64 * 4.0 * 2.0;
        hbm += (tc - 1.0).max(0.0) * visit_frac
            * 2.0 * (rows * d as f64) * p.dtype_bytes as f64;
    }

    let kv_tile_bytes = (2 * bk * d * p.dtype_bytes) as f64;
    let mut smem = pairs * kv_tile_bytes * (1.0 + SMEM_READ_FACTOR);
    // dS staging between the matmuls goes through smem in all schemes.
    smem += pairs * tile * p.dtype_bytes as f64 * 2.0;
    if spec.split_k_warps {
        let partial = (bk * d) as f64 * 4.0 * 2.0; // dK, dV partials (f32)
        smem += pairs * spec.warps as f64 * partial;
    }

    let grid = if spec.seqlen_parallel {
        bh * p.seqlen.div_ceil(bk) // column-block parallel (Fig. 2 right)
    } else {
        bh
    };
    vec![KernelLaunch {
        label: "attn_bwd",
        grid,
        block: BlockResources {
            threads: spec.warps * 32,
            regs_per_thread: 160,
            smem_bytes: bwd_smem(spec, d, p.dtype_bytes),
        },
        matmul_flops: matmul,
        nonmatmul_flops: nonmatmul,
        hbm_bytes: hbm,
        smem_bytes: smem,
        mm_eff: spec.mm_eff_bwd,
    }]
}

/// Standard (PyTorch) attention: three memory-bound kernels that
/// materialize S and P in HBM (paper section 2.2).  Executes the full
/// square even under a causal mask; PyTorch's softmax path upcasts the
/// score matrix to fp32 and the causal mask is its own elementwise kernel.
pub fn standard_kernels(p: &AttnProblem, pass: Pass) -> Vec<KernelLaunch> {
    let bh = (p.batch * p.heads) as f64;
    let n = p.seqlen as f64;
    let d = p.head_dim as f64;
    // fp32 S/P materialization (softmax upcast): 4 bytes per score.
    let score = (p.batch * p.heads * p.seqlen * p.seqlen * 4) as f64;
    let nd_bytes = (p.seqlen * p.head_dim * p.dtype_bytes) as f64 * bh;
    let gemm_block = BlockResources { threads: 256, regs_per_thread: 128, smem_bytes: 96 * 1024 };
    let gemm_grid = ((bh * n * n) / (128.0 * 128.0)).ceil() as u64;
    let eltwise_block = BlockResources { threads: 256, regs_per_thread: 40, smem_bytes: 0 };
    let eltwise_grid = ((bh * n * n) / (256.0 * 8.0)).ceil() as u64;

    let gemm = |label, flops, hbm| KernelLaunch {
        label,
        grid: gemm_grid.max(1),
        block: gemm_block,
        matmul_flops: flops,
        nonmatmul_flops: 0.0,
        hbm_bytes: hbm,
        smem_bytes: 0.0,
        mm_eff: 0.85,
    };

    let mut kernels = vec![
        // S = QK^T: read Q,K; write S.
        gemm("std_qk", bh * 2.0 * n * n * d, 2.0 * nd_bytes + score),
        // softmax: read S, write P.
        KernelLaunch {
            label: "std_softmax",
            grid: eltwise_grid.max(1),
            block: eltwise_block,
            matmul_flops: 0.0,
            nonmatmul_flops: bh * n * n * (1.0 + EXP + 1.0 + DIV),
            hbm_bytes: 2.0 * score,
            smem_bytes: 0.0,
            mm_eff: 1.0,
        },
        // O = PV: read P,V; write O.
        gemm("std_pv", bh * 2.0 * n * n * d, score + 2.0 * nd_bytes),
    ];
    if p.causal {
        // masked_fill: read S + mask, write S — a separate eltwise pass.
        kernels.insert(1, KernelLaunch {
            label: "std_mask",
            grid: eltwise_grid.max(1),
            block: eltwise_block,
            matmul_flops: 0.0,
            nonmatmul_flops: bh * n * n,
            hbm_bytes: 2.0 * score + score / 4.0, // mask is 1 byte/element
            smem_bytes: 0.0,
            mm_eff: 1.0,
        });
    }

    if pass != Pass::Fwd {
        // Autograd backward: each GEMM touching an N x N operand also pays a
        // transpose/.contiguous() materialization pass (PyTorch autograd
        // does not fuse these), hence the extra `score` per GEMM.
        let bwd = vec![
            gemm("std_dv", bh * 2.0 * n * n * d, 2.0 * score + 2.0 * nd_bytes),
            gemm("std_dp", bh * 2.0 * n * n * d, 2.0 * nd_bytes + 2.0 * score),
            KernelLaunch {
                label: "std_dsoftmax",
                grid: eltwise_grid.max(1),
                block: eltwise_block,
                matmul_flops: 0.0,
                nonmatmul_flops: bh * n * n * 4.0,
                hbm_bytes: 3.0 * score,
                smem_bytes: 0.0,
                mm_eff: 1.0,
            },
            gemm("std_dq", bh * 2.0 * n * n * d, 2.0 * score + 2.0 * nd_bytes),
            gemm("std_dk", bh * 2.0 * n * n * d, 2.0 * score + 2.0 * nd_bytes),
        ];
        if pass == Pass::Bwd {
            return bwd;
        }
        kernels.extend(bwd);
    }
    kernels
}

/// Build the kernels for any method/pass.
pub fn kernels_for(p: &AttnProblem, method: Method, pass: Pass) -> Vec<KernelLaunch> {
    if method == Method::Standard {
        return standard_kernels(p, pass);
    }
    let spec = ScheduleSpec::for_method(method, p.head_dim);
    match pass {
        Pass::Fwd => fwd_kernels(p, &spec),
        Pass::Bwd => bwd_kernels(p, &spec),
        Pass::FwdBwd => {
            let mut ks = fwd_kernels(p, &spec);
            ks.extend(bwd_kernels(p, &spec));
            ks
        }
    }
}

/// Simulated wall-clock time for (problem, method, pass) on a device.
pub fn simulate_time(dev: &Device, p: &AttnProblem, method: Method, pass: Pass) -> f64 {
    simulate_pipeline(dev, &kernels_for(p, method, pass))
}

/// Reported throughput in FLOP/s (paper's accounting, section 4.1).
pub fn simulate_tflops(dev: &Device, p: &AttnProblem, method: Method, pass: Pass) -> f64 {
    p.reported_flops(pass) / simulate_time(dev, p, method, pass)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn visited_pairs_counts() {
        let full = AttnProblem { batch: 1, heads: 1, seqlen: 1024, head_dim: 64, causal: false, dtype_bytes: 2 };
        assert_eq!(visited_pairs(&full, 128, 128), 8 * 8);
        let causal = AttnProblem { causal: true, ..full };
        // sum_{i=0..7} (i+1) = 36 of 64 pairs
        assert_eq!(visited_pairs(&causal, 128, 128), 36);
        // causal block skipping approaches 1/2 for large N (paper: ~1.7-1.8x
        // speedup because the ratio is (Tc+1)/2Tc, not exactly 1/2)
        let big = AttnProblem { seqlen: 16384, causal: true, ..full };
        let frac = visited_pairs(&big, 128, 128) as f64 / (128.0 * 128.0);
        assert!(frac < 0.51 && frac > 0.49, "{frac}");
    }

    #[test]
    fn diagonal_pairs_is_about_one_per_row_block() {
        let p = AttnProblem { batch: 1, heads: 1, seqlen: 2048, head_dim: 64, causal: true, dtype_bytes: 2 };
        assert_eq!(diagonal_pairs(&p, 128, 128), 16); // exactly 1 per row block
        // block_k smaller than block_q straddles 2 per row block
        assert_eq!(diagonal_pairs(&p, 128, 64), 32);
    }

    #[test]
    fn fa2_has_fewer_nonmatmul_flops_than_fa1() {
        // Section 3.1: the tweaks strictly reduce non-matmul work.
        let p = AttnProblem::paper_setting(4096, 128, false);
        let fa1 = &fwd_kernels(&p, &ScheduleSpec::for_method(Method::Flash1, 128))[0];
        let fa2 = &fwd_kernels(&p, &ScheduleSpec::for_method(Method::Flash2, 128))[0];
        assert!(fa2.nonmatmul_flops < fa1.nonmatmul_flops);
        // and identical matmul FLOPs per visited pair (same math!)
        assert!((fa2.matmul_flops - fa1.matmul_flops).abs() / fa1.matmul_flops < 0.02);
    }

    #[test]
    fn fa2_grid_scales_with_seqlen_fa1_does_not() {
        let p = AttnProblem::paper_setting(16384, 128, false);
        let fa1 = &fwd_kernels(&p, &ScheduleSpec::for_method(Method::Flash1, 128))[0];
        let fa2 = &fwd_kernels(&p, &ScheduleSpec::for_method(Method::Flash2, 128))[0];
        assert_eq!(fa1.grid, p.batch * p.heads);
        assert_eq!(fa2.grid, p.batch * p.heads * (16384 / 128));
    }

    #[test]
    fn splitk_smem_exchange_is_visible() {
        let p = AttnProblem::paper_setting(4096, 64, false);
        let fa1 = &fwd_kernels(&p, &ScheduleSpec::for_method(Method::Flash1, 64))[0];
        let fa2 = &fwd_kernels(&p, &ScheduleSpec::for_method(Method::Flash2, 64))[0];
        assert!(fa1.smem_bytes > 1.5 * fa2.smem_bytes);
    }

    #[test]
    fn standard_materializes_the_square() {
        let p = AttnProblem::paper_setting(4096, 64, false);
        let ks = standard_kernels(&p, Pass::Fwd);
        assert_eq!(ks.len(), 3);
        let total_hbm: f64 = ks.iter().map(|k| k.hbm_bytes).sum();
        // at least 4 full N^2 matrices of traffic
        assert!(total_hbm > 4.0 * p.score_matrix_bytes());
        let ks_bwd = standard_kernels(&p, Pass::FwdBwd);
        assert_eq!(ks_bwd.len(), 8);
    }

    #[test]
    fn causal_halves_flash_matmul_but_not_standard() {
        let full = AttnProblem::paper_setting(8192, 128, false);
        let causal = AttnProblem::paper_setting(8192, 128, true);
        let f2f = &kernels_for(&full, Method::Flash2, Pass::Fwd)[0];
        let f2c = &kernels_for(&causal, Method::Flash2, Pass::Fwd)[0];
        let ratio = f2c.matmul_flops / f2f.matmul_flops;
        assert!(ratio > 0.45 && ratio < 0.55, "{ratio}");
        let sf: f64 = kernels_for(&full, Method::Standard, Pass::Fwd).iter().map(|k| k.matmul_flops).sum();
        let sc: f64 = kernels_for(&causal, Method::Standard, Pass::Fwd).iter().map(|k| k.matmul_flops).sum();
        assert_eq!(sf, sc); // standard computes the whole square regardless
    }
}
