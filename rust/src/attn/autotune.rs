//! Block-size autotuner — the paper's stated future work (§3.3: "we
//! manually tune for each head dimension ... this could benefit from
//! auto-tuning to avoid this manual labor. We leave this to future work.")
//!
//! The tuner searches {64,128}² tiles x {4,8} warps against the gpusim
//! cost model for a concrete (problem, device, pass) and returns the best
//! schedule.  Because the cost model prices occupancy, smem footprint and
//! the non-matmul mix, the tuner independently rediscovers the paper's
//! hand-tuned choices (asserted in the tests below).

use crate::gpusim::comm::RingLink;
use crate::gpusim::device::Device;
use crate::gpusim::kernel::simulate_pipeline;
use crate::util::pool;

use super::exec::seqpar::{SeqParParams, SeqParPlan};
use super::problem::{AttnProblem, Pass};
use super::spec::AttnSpec;
use super::schedule::{bwd_kernels, fwd_kernels, Method, ScheduleSpec};

/// Candidate tile/warp grid searched by the tuner.
pub const TILE_CANDIDATES: [u64; 2] = [64, 128];
pub const WARP_CANDIDATES: [u32; 2] = [4, 8];

/// One evaluated configuration.
#[derive(Debug, Clone, Copy)]
pub struct TunedSchedule {
    pub block_q: u64,
    pub block_k: u64,
    pub warps: u32,
    pub time: f64,
}

/// Exhaustively search tiles x warps for the given problem.  Returns every
/// candidate (sorted fastest-first) so callers can inspect the landscape;
/// `[0]` is the winner.  Configurations whose shared-memory footprint makes
/// the kernel unlaunchable price as infinite and sort last — exactly the
/// paper's "the kernel cannot run at all" case.
pub fn tune(
    dev: &Device,
    p: &AttnProblem,
    method: Method,
    pass: Pass,
) -> Vec<TunedSchedule> {
    let base = ScheduleSpec::for_method(method, p.head_dim);
    let mut jobs = Vec::new();
    for &bq in &TILE_CANDIDATES {
        for &bk in &TILE_CANDIDATES {
            for &warps in &WARP_CANDIDATES {
                jobs.push((bq, bk, warps));
            }
        }
    }
    // The candidate grid points are independent cost-model evaluations:
    // fan them across the work-stealing pool.  par_map preserves candidate
    // order and the sort below is stable, so the returned ranking is
    // identical to the serial search.
    let mut out = pool::par_map(jobs, |(bq, bk, warps)| {
        let spec = ScheduleSpec { block_q: bq, block_k: bk, warps, ..base };
        let mut kernels = Vec::new();
        if pass != Pass::Bwd {
            kernels.extend(fwd_kernels(p, &spec));
        }
        if pass != Pass::Fwd {
            kernels.extend(bwd_kernels(p, &spec));
        }
        TunedSchedule {
            block_q: bq,
            block_k: bk,
            warps,
            time: simulate_pipeline(dev, &kernels),
        }
    });
    out.sort_by(|a, b| a.time.partial_cmp(&b.time).unwrap());
    out
}

/// The winning schedule for a problem.
pub fn best(dev: &Device, p: &AttnProblem, method: Method, pass: Pass) -> TunedSchedule {
    tune(dev, p, method, pass)[0]
}

/// The tile the tuner would hand the *executing* engine for this problem:
/// the cost-model winner's (block_q, block_k), as `attn::exec` tile sizes.
/// This is the one seam through which exec call sites pick FlashParams —
/// they used to hardcode the 64×64 default, so the executing engine and
/// the cost model disagreed on tiling (ISSUE 5 bugfix).
pub fn exec_params(p: &AttnProblem, pass: Pass) -> crate::attn::exec::FlashParams {
    let t = best(&Device::a100(), p, Method::Flash2, pass);
    crate::attn::exec::FlashParams {
        block_q: t.block_q as usize,
        block_k: t.block_k as usize,
    }
}

/// Simulated cost of one sequence-parallel configuration: the flash
/// pipeline's cost-model time split across the ring (ideal §3.2 split —
/// striping makes the executing layer approach it) plus the
/// [`RingLink`] exchange term on [`SeqParPlan::fwd_comm_bytes`], the
/// exact byte count the executing transport meters.  Sharing that
/// currency is what makes the simulated and executing layers rank shard
/// counts the same way.
pub fn seqpar_cost(
    dev: &Device,
    link: &RingLink,
    spec: &AttnSpec,
    prm: &SeqParParams,
    pass: Pass,
) -> f64 {
    let plan = SeqParPlan::build(spec, prm);
    let p = spec.q_dims().problem();
    let sched = ScheduleSpec::for_method(Method::Flash2, p.head_dim);
    let mut kernels = Vec::new();
    if pass != Pass::Bwd {
        kernels.extend(fwd_kernels(&p, &sched));
    }
    if pass != Pass::Fwd {
        kernels.extend(bwd_kernels(&p, &sched));
    }
    let compute = simulate_pipeline(dev, &kernels) / plan.workers as f64;
    // The backward ring re-ships the KV shards and returns dK/dV tiles of
    // the same shape — model gradient passes as twice the forward
    // exchange.
    let comm_mult = if pass == Pass::Fwd { 1.0 } else { 2.0 };
    let comm =
        link.exchange_time(plan.fwd_comm_msgs(), plan.fwd_comm_bytes(spec) as f64);
    compute + comm_mult * comm
}

/// Rank candidate worker counts for a seqpar execution, fastest first,
/// on the simulated cost — the shard-count search the executing layer's
/// benches validate against.
pub fn seqpar_rank(
    dev: &Device,
    link: &RingLink,
    spec: &AttnSpec,
    chunk: usize,
    candidates: &[usize],
    pass: Pass,
) -> Vec<(usize, f64)> {
    let mut out: Vec<(usize, f64)> = candidates
        .iter()
        .map(|&workers| {
            let prm = SeqParParams { workers, chunk, striped: true };
            (workers, seqpar_cost(dev, link, spec, &prm, pass))
        })
        .collect();
    out.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rediscovers_paper_hand_tuning_d64() {
        // Paper §3.3 picks 128x128 tiles for head_dim 64 on A100.
        let p = AttnProblem::paper_setting(4096, 64, false);
        let b = best(&Device::a100(), &p, Method::Flash2, Pass::Fwd);
        assert_eq!((b.block_q, b.block_k), (128, 128), "{b:?}");
    }

    #[test]
    fn d128_prefers_smaller_kv_tile() {
        // At head_dim 128 the 128x128 working set pressures smem; the tuner
        // must not pick a configuration worse than the hand choice 128x64.
        let p = AttnProblem::paper_setting(4096, 128, false);
        let all = tune(&Device::a100(), &p, Method::Flash2, Pass::Fwd);
        let hand = all
            .iter()
            .find(|t| t.block_q == 128 && t.block_k == 64 && t.warps == 4)
            .unwrap();
        assert!(all[0].time <= hand.time);
        // and the winner is within 10% of (or equal to) the hand tuning —
        // i.e. the manual labor was near-optimal, as the paper implies.
        assert!(hand.time / all[0].time < 1.10, "{:?} vs hand {:?}", all[0], hand);
    }

    #[test]
    fn all_candidates_evaluated_and_sorted() {
        let p = AttnProblem::paper_setting(2048, 64, true);
        let all = tune(&Device::a100(), &p, Method::Flash2, Pass::FwdBwd);
        assert_eq!(all.len(), TILE_CANDIDATES.len().pow(2) * WARP_CANDIDATES.len());
        for w in all.windows(2) {
            assert!(w[0].time <= w[1].time);
        }
        assert!(all[0].time.is_finite());
    }

    #[test]
    fn tuned_never_slower_than_default() {
        for d in [64, 128] {
            for causal in [false, true] {
                let p = AttnProblem::paper_setting(8192, d, causal);
                let spec = ScheduleSpec::for_method(Method::Flash2, d);
                let default_t = simulate_pipeline(
                    &Device::a100(),
                    &fwd_kernels(&p, &spec),
                );
                let tuned = best(&Device::a100(), &p, Method::Flash2, Pass::Fwd);
                assert!(
                    tuned.time <= default_t * 1.0001,
                    "tuner regressed d={d} causal={causal}"
                );
            }
        }
    }

    #[test]
    fn exec_params_mirror_the_cost_model_winner() {
        let p = AttnProblem::paper_setting(4096, 64, false);
        let fp = exec_params(&p, Pass::Fwd);
        let b = best(&Device::a100(), &p, Method::Flash2, Pass::Fwd);
        assert_eq!((fp.block_q as u64, fp.block_k as u64), (b.block_q, b.block_k));
        assert!(fp.block_q > 0 && fp.block_k > 0);
    }

    #[test]
    fn seqpar_ranking_follows_the_compute_comm_tradeoff() {
        use crate::attn::spec::{HeadMap, Mask};
        let spec = AttnSpec {
            batch: 1,
            heads: HeadMap::mha(8),
            seq: 8192,
            head_dim: 64,
            mask: Mask::Full,
        };
        let dev = Device::a100();
        // a free link: more shards always win (pure 1/W compute split)
        let free = RingLink { bandwidth: f64::INFINITY, latency: 0.0 };
        let r = seqpar_rank(&dev, &free, &spec, 64, &[1, 2, 4, 8], Pass::Fwd);
        assert_eq!(r[0].0, 8, "{r:?}");
        // an absurdly slow link: sharding can never pay for itself
        let slow = RingLink { bandwidth: 1e3, latency: 1.0 };
        let r = seqpar_rank(&dev, &slow, &spec, 64, &[1, 2, 4, 8], Pass::Fwd);
        assert_eq!(r[0].0, 1, "{r:?}");
        // realistic link: every candidate priced finite, returned sorted
        let r =
            seqpar_rank(&dev, &RingLink::nvlink(), &spec, 64, &[1, 2, 4, 8], Pass::FwdBwd);
        assert_eq!(r.len(), 4);
        for w in r.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
        assert!(r.iter().all(|(_, t)| t.is_finite()));
    }

    #[test]
    fn h100_tuning_also_finite() {
        let p = AttnProblem::paper_setting(16384, 128, true);
        let b = best(&Device::h100(), &p, Method::Flash2, Pass::FwdBwd);
        assert!(b.time.is_finite());
    }
}
