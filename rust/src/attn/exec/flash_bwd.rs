//! Tiled backward — the paper's Algorithm 2, the 5-matmul pass.
//!
//! P is *recomputed* from the saved logsumexp (`Pᵢⱼ = exp(scale·qᵢ·kⱼ −
//! LSEᵢ)`), never stored: the five tile matmuls are S = QKᵀ, dV = PᵀdO,
//! dP = dOVᵀ, dK = dSᵀQ and dQ = dSK, with `dSᵢⱼ = Pᵢⱼ(dPᵢⱼ − Dᵢ)·scale`
//! and `Dᵢ = Σₜ dOᵢₜOᵢₜ` precomputed once per tensor.
//!
//! Work partitioning mirrors the paper's backward: one task per
//! (b, h, K-block) owns that block's dK/dV exclusively and emits a partial
//! dQ covering the rows it touched; [`super::parallel::backward_with`]
//! sums those partials in task order, so the reduction is deterministic at
//! any worker count (no atomics — the host-side stand-in for the paper's
//! atomic-add on dQ).

use super::TensorView;

/// One (b, h, K-block) backward tile over columns `j0..j1`.
///
/// Returns `(dk_tile, dv_tile, q_start, dq_partial)`: dK/dV rows for
/// `j0..j1`, and a dQ contribution for rows `q_start..seq` (rows below
/// `q_start` provably receive nothing from this block under the mask).
pub(crate) fn backward_tile(
    q: TensorView,
    k: TensorView,
    v: TensorView,
    lse: &[f32],
    dout: TensorView,
    dvec: &[f32],
    b: usize,
    h: usize,
    j0: usize,
    j1: usize,
) -> (Vec<f32>, Vec<f32>, usize, Vec<f32>) {
    let dims = q.dims;
    let (n, d) = (dims.seq, dims.head_dim);
    let scale = dims.scale();
    let w = j1 - j0;

    let mut dk = vec![0.0f32; w * d];
    let mut dv = vec![0.0f32; w * d];
    let q_start = if dims.causal { j0 } else { 0 };
    let mut dq = vec![0.0f32; (n - q_start) * d];

    for i in q_start..n {
        // columns of this block row i attends to (j ≤ i when causal)
        let cols = if dims.causal { (i - j0 + 1).min(w) } else { w };
        let qi = q.row(b, h, i);
        let doi = dout.row(b, h, i);
        let lse_i = lse[dims.lse_offset(b, h, i)];
        let d_i = dvec[dims.lse_offset(b, h, i)];
        let dqrow = &mut dq[(i - q_start) * d..(i - q_start + 1) * d];
        for cj in 0..cols {
            let j = j0 + cj;
            let kj = k.row(b, h, j);
            let vj = v.row(b, h, j);
            // S then P from the saved LSE (recomputation, not storage)
            let mut s = 0.0f32;
            for t in 0..d {
                s += qi[t] * kj[t];
            }
            let pij = (s * scale - lse_i).exp();
            // dP = dO·Vⱼ ;  dS = P(dP − D)·scale
            let mut dp = 0.0f32;
            for t in 0..d {
                dp += doi[t] * vj[t];
            }
            let ds = pij * (dp - d_i) * scale;
            let dkrow = &mut dk[cj * d..(cj + 1) * d];
            let dvrow = &mut dv[cj * d..(cj + 1) * d];
            for t in 0..d {
                dkrow[t] += ds * qi[t];
                dvrow[t] += pij * doi[t];
                dqrow[t] += ds * kj[t];
            }
        }
    }
    (dk, dv, q_start, dq)
}

#[cfg(test)]
mod tests {
    use super::super::{parallel, reference, AttnDims, FlashParams};
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn max_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn matches_reference_gradients() {
        let mut rng = Rng::seed_from(31);
        for &(seq, causal) in &[(9usize, false), (16, true), (21, true)] {
            let dims = AttnDims { batch: 1, heads: 2, seq, head_dim: 8, causal };
            let n = dims.elems();
            let (q, k, v, dout) = (
                rand_vec(&mut rng, n),
                rand_vec(&mut rng, n),
                rand_vec(&mut rng, n),
                rand_vec(&mut rng, n),
            );
            let p = FlashParams { block_q: 8, block_k: 8 };
            let fwd = parallel::forward_with(1, &q, &k, &v, dims, p);
            let g = parallel::backward_with(1, &q, &k, &v, &fwd, &dout, dims, p);
            let r = reference::backward(&q, &k, &v, &dout, dims);
            assert!(max_diff(&g.dq, &r.dq) < 1e-4, "dQ seq={seq} causal={causal}");
            assert!(max_diff(&g.dk, &r.dk) < 1e-4, "dK seq={seq} causal={causal}");
            assert!(max_diff(&g.dv, &r.dv) < 1e-4, "dV seq={seq} causal={causal}");
        }
    }
}
