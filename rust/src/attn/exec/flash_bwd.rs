//! Tiled backward — the paper's Algorithm 2, the 5-matmul pass,
//! dispatched on [`AttnSpec`].
//!
//! P is *recomputed* from the saved logsumexp (`Pᵢⱼ = exp(scale·qᵢ·kⱼ −
//! LSEᵢ)`), never stored: the five tile matmuls are S = QKᵀ, dV = PᵀdO,
//! dP = dOVᵀ, dK = dSᵀQ and dQ = dSK, with `dSᵢⱼ = Pᵢⱼ(dPᵢⱼ − Dᵢ)·scale`
//! and `Dᵢ = Σₜ dOᵢₜOᵢₜ` precomputed once per tensor.
//!
//! Work partitioning mirrors the paper's backward: one task per
//! (b, KV-head, K-block) owns that block's dK/dV exclusively — under GQA
//! it accumulates every query head of its group, so no two tasks ever
//! write the same dK/dV rows — and emits per-group dQ partials covering
//! only the rows the mask lets this block touch (below `j0`, and past the
//! sliding window's reach `j1 − 1 + w`, rows provably receive nothing);
//! [`super::parallel::backward_spec_with`] sums those partials in task
//! order, so the reduction is deterministic at any worker count (no
//! atomics — the host-side stand-in for the paper's atomic-add on dQ).

use crate::attn::spec::{AttnSpec, Mask};

use super::TensorView;

/// One (b, kv-head, K-block) backward tile over columns `j0..j1`.
///
/// Returns `(dk_tile, dv_tile, q_start, dq_partials)`: dK/dV rows for
/// `j0..j1` (summed over the query-head group), and one dQ contribution
/// per query head of the group, each covering rows `q_start..q_end(j1)`
/// (`dq_partials.len() == group_size * (q_end - q_start) * d`, head-major).
#[allow(clippy::too_many_arguments)]
pub(crate) fn backward_tile(
    q: TensorView,
    k: TensorView,
    v: TensorView,
    lse: &[f32],
    dout: TensorView,
    dvec: &[f32],
    spec: AttnSpec,
    b: usize,
    kvh: usize,
    j0: usize,
    j1: usize,
) -> (Vec<f32>, Vec<f32>, usize, Vec<f32>) {
    let (n, d) = (spec.seq, spec.head_dim);
    let qd = spec.q_dims();
    let scale = spec.scale();
    let w = j1 - j0;

    let mut dk = vec![0.0f32; w * d];
    let mut dv = vec![0.0f32; w * d];
    let (q_start, q_end) = q_row_span(spec.mask, n, j0, j1);
    let span = q_end - q_start;
    let group = spec.heads.group_size();
    let mut dq = vec![0.0f32; group * span * d];

    for (gi, h) in spec.heads.q_heads_of(kvh).enumerate() {
        for i in q_start..q_end {
            // columns of this block row i attends to under the mask
            let (lo, hi) = spec.mask.row_bounds(i, n);
            let (start, end) = (lo.max(j0), hi.min(j1));
            if start >= end {
                continue;
            }
            let qi = q.row(b, h, i);
            let doi = dout.row(b, h, i);
            let lse_i = lse[qd.lse_offset(b, h, i)];
            let d_i = dvec[qd.lse_offset(b, h, i)];
            let dqrow_at = (gi * span + (i - q_start)) * d;
            let dqrow = &mut dq[dqrow_at..dqrow_at + d];
            for j in start..end {
                let cj = j - j0;
                let kj = k.row(b, kvh, j);
                let vj = v.row(b, kvh, j);
                // S then P from the saved LSE (recomputation, not storage)
                let mut s = 0.0f32;
                for t in 0..d {
                    s += qi[t] * kj[t];
                }
                let pij = (s * scale - lse_i).exp();
                // dP = dO·Vⱼ ;  dS = P(dP − D)·scale
                let mut dp = 0.0f32;
                for t in 0..d {
                    dp += doi[t] * vj[t];
                }
                let ds = pij * (dp - d_i) * scale;
                let dkrow = &mut dk[cj * d..(cj + 1) * d];
                let dvrow = &mut dv[cj * d..(cj + 1) * d];
                for t in 0..d {
                    dkrow[t] += ds * qi[t];
                    dvrow[t] += pij * doi[t];
                    dqrow[t] += ds * kj[t];
                }
            }
        }
    }
    (dk, dv, q_start, dq)
}

/// The Q rows the K-block `[j0, j1)` can contribute to under `mask`:
/// `Full` touches every row; causal-like masks touch nothing above `j0`;
/// a sliding window additionally touches nothing past `j1 − 1 + w`.
pub(crate) fn q_row_span(mask: Mask, n: usize, j0: usize, j1: usize) -> (usize, usize) {
    match mask {
        Mask::Full => (0, n),
        Mask::Causal => (j0, n),
        Mask::SlidingWindow(w) => (j0, n.min(j1 - 1 + w)),
    }
}

#[cfg(test)]
mod tests {
    use super::super::{parallel, reference, AttnDims, FlashParams};
    use super::*;
    use crate::attn::spec::HeadMap;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn max_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn q_row_span_is_tight() {
        // brute force: the span must contain exactly the rows with any
        // live column in the block
        let n = 24;
        for mask in [Mask::Full, Mask::Causal, Mask::SlidingWindow(3), Mask::SlidingWindow(9)]
        {
            for j0 in (0..n).step_by(5) {
                let j1 = (j0 + 5).min(n);
                let (s, e) = q_row_span(mask, n, j0, j1);
                for i in 0..n {
                    let live = (j0..j1).any(|j| mask.allows(i, j));
                    assert!(
                        !live || (s..e).contains(&i),
                        "{mask:?} block [{j0},{j1}): live row {i} outside span [{s},{e})"
                    );
                }
            }
        }
    }

    #[test]
    fn matches_reference_gradients() {
        let mut rng = Rng::seed_from(31);
        for &(seq, causal) in &[(9usize, false), (16, true), (21, true)] {
            let dims = AttnDims { batch: 1, heads: 2, seq, head_dim: 8, causal };
            let n = dims.elems();
            let (q, k, v, dout) = (
                rand_vec(&mut rng, n),
                rand_vec(&mut rng, n),
                rand_vec(&mut rng, n),
                rand_vec(&mut rng, n),
            );
            let p = FlashParams { block_q: 8, block_k: 8 };
            let fwd = parallel::forward_with(1, &q, &k, &v, dims, p);
            let g = parallel::backward_with(1, &q, &k, &v, &fwd, &dout, dims, p);
            let r = reference::backward(&q, &k, &v, &dout, dims);
            assert!(max_diff(&g.dq, &r.dq) < 1e-4, "dQ seq={seq} causal={causal}");
            assert!(max_diff(&g.dk, &r.dk) < 1e-4, "dK seq={seq} causal={causal}");
            assert!(max_diff(&g.dv, &r.dv) < 1e-4, "dV seq={seq} causal={causal}");
        }
    }

    #[test]
    fn matches_reference_gradients_gqa_and_window() {
        let mut rng = Rng::seed_from(32);
        for (heads, mask) in [
            (HeadMap { n_q_heads: 4, n_kv_heads: 2 }, Mask::Causal),
            (HeadMap { n_q_heads: 4, n_kv_heads: 1 }, Mask::SlidingWindow(5)),
            (HeadMap::mha(2), Mask::SlidingWindow(3)),
            (HeadMap { n_q_heads: 6, n_kv_heads: 2 }, Mask::Full),
        ] {
            let spec = AttnSpec { batch: 1, heads, seq: 19, head_dim: 6, mask };
            let q = rand_vec(&mut rng, spec.q_elems());
            let k = rand_vec(&mut rng, spec.kv_elems());
            let v = rand_vec(&mut rng, spec.kv_elems());
            let dout = rand_vec(&mut rng, spec.q_elems());
            let p = FlashParams { block_q: 8, block_k: 4 };
            let fwd = parallel::forward_spec_with(1, &q, &k, &v, spec, p);
            let g = parallel::backward_spec_with(1, &q, &k, &v, &fwd, &dout, spec, p);
            let r = reference::backward_spec(&q, &k, &v, &dout, spec);
            assert!(max_diff(&g.dq, &r.dq) < 1e-4, "dQ {heads:?} {mask:?}");
            assert!(max_diff(&g.dk, &r.dk) < 1e-4, "dK {heads:?} {mask:?}");
            assert!(max_diff(&g.dv, &r.dv) < 1e-4, "dV {heads:?} {mask:?}");
        }
    }
}
