//! Tiled online-softmax forward — the paper's Algorithm 1.
//!
//! One call to [`forward_tile`] computes a (b, h, Q-block) tile: it streams
//! K/V blocks through a running (m, l, õ) state, rescales the accumulator
//! once per block instead of once per iteration (§3.1), skips K blocks that
//! are entirely above the causal diagonal, and masks only the blocks the
//! diagonal actually crosses.  Only the logsumexp is saved for the backward
//! pass — not m and l separately, and never the N×N score matrix.
//!
//! The whole-tensor entry point lives in [`super::parallel`]; `forward`
//! here is the serial spelling (worker count 1 through the same fan-out),
//! so serial and parallel runs are byte-identical by construction.

use super::{AttnDims, FlashOut, FlashParams, TensorView};

/// Compute rows `q0..q1` of head (b, h).  Returns the tile's output rows
/// (`(q1-q0)·head_dim` values) and logsumexps (`q1-q0` values).
pub(crate) fn forward_tile(
    q: TensorView,
    k: TensorView,
    v: TensorView,
    p: FlashParams,
    b: usize,
    h: usize,
    q0: usize,
    q1: usize,
) -> (Vec<f32>, Vec<f32>) {
    let dims = q.dims;
    let (n, d) = (dims.seq, dims.head_dim);
    let scale = dims.scale();
    let rows = q1 - q0;
    let bk = p.block_k.max(1);

    let mut o = vec![0.0f32; rows * d];
    let mut m = vec![f32::NEG_INFINITY; rows];
    let mut l = vec![0.0f32; rows];
    let mut s = vec![0.0f32; rows * bk]; // score tile scratch

    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + bk).min(n);
        if dims.causal && j0 > q1 - 1 {
            break; // this and all later K blocks are fully masked
        }
        let w = j1 - j0;
        // A block is "full" when the causal diagonal does not cross it;
        // then no per-row masking is needed (§3.1: mask only where needed).
        let full = !dims.causal || j1 - 1 <= q0;
        for (ri, i) in (q0..q1).enumerate() {
            // columns of this block row i may attend to (j ≤ i when
            // causal); masked columns are never computed, not computed
            // then discarded
            let lim = if full {
                w
            } else if i < j0 {
                0
            } else {
                (i - j0 + 1).min(w)
            };
            if lim == 0 {
                continue;
            }
            // S[ri, ..lim] = scale · qᵢ Kᵀ
            let qi = q.row(b, h, i);
            {
                let srow = &mut s[ri * bk..ri * bk + lim];
                for (cj, sv) in srow.iter_mut().enumerate() {
                    let kj = k.row(b, h, j0 + cj);
                    let mut acc = 0.0f32;
                    for t in 0..d {
                        acc += qi[t] * kj[t];
                    }
                    *sv = acc * scale;
                }
            }
            let srow = &s[ri * bk..ri * bk + lim];
            let mut mb = f32::NEG_INFINITY;
            for &x in srow {
                mb = mb.max(x);
            }
            let mnew = m[ri].max(mb);
            // one rescale of the existing accumulator per block (not per
            // iteration — the §3.1 non-matmul-FLOP reduction)
            let alpha = (m[ri] - mnew).exp(); // exp(-inf)=0 on the first block
            let orow = &mut o[ri * d..(ri + 1) * d];
            if alpha != 1.0 {
                for x in orow.iter_mut() {
                    *x *= alpha;
                }
                l[ri] *= alpha;
            }
            for (cj, &sj) in srow.iter().enumerate() {
                let pij = (sj - mnew).exp();
                l[ri] += pij;
                let vj = v.row(b, h, j0 + cj);
                for t in 0..d {
                    orow[t] += pij * vj[t];
                }
            }
            m[ri] = mnew;
        }
        j0 = j1;
    }

    // finalize: O = õ / l, LSE = m + ln l (the single statistic saved)
    let mut lse = vec![0.0f32; rows];
    for ri in 0..rows {
        if l[ri] > 0.0 {
            let inv = 1.0 / l[ri];
            for x in &mut o[ri * d..(ri + 1) * d] {
                *x *= inv;
            }
            lse[ri] = m[ri] + l[ri].ln();
        } else {
            // a row that attended to nothing (cannot happen for square
            // causal/full attention, but keep the contract total)
            lse[ri] = f32::NEG_INFINITY;
        }
    }
    (o, lse)
}

/// Algorithm 1 over the whole tensor, serially (worker count 1 through the
/// same order-preserving fan-out `parallel::forward` uses).
pub fn forward(q: &[f32], k: &[f32], v: &[f32], dims: AttnDims, p: FlashParams) -> FlashOut {
    super::parallel::forward_with(1, q, k, v, dims, p)
}

#[cfg(test)]
mod tests {
    use super::super::reference;
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn max_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn matches_reference_on_block_boundaries_and_remainders() {
        let mut rng = Rng::seed_from(42);
        for &(seq, bq, bkc) in &[(16usize, 8usize, 8usize), (17, 8, 8), (5, 2, 3), (33, 16, 8)] {
            for &causal in &[false, true] {
                let dims = AttnDims { batch: 1, heads: 2, seq, head_dim: 16, causal };
                let n = dims.elems();
                let (q, k, v) =
                    (rand_vec(&mut rng, n), rand_vec(&mut rng, n), rand_vec(&mut rng, n));
                let p = FlashParams { block_q: bq, block_k: bkc };
                let fl = forward(&q, &k, &v, dims, p);
                let rf = reference::forward(&q, &k, &v, dims);
                assert!(
                    max_diff(&fl.o, &rf.o) < 1e-4,
                    "O mismatch seq={seq} bq={bq} bk={bkc} causal={causal}"
                );
                assert!(
                    max_diff(&fl.lse, &rf.lse) < 1e-4,
                    "LSE mismatch seq={seq} causal={causal}"
                );
            }
        }
    }

    #[test]
    fn block_size_does_not_change_results_beyond_roundoff() {
        let mut rng = Rng::seed_from(7);
        let dims = AttnDims { batch: 1, heads: 1, seq: 29, head_dim: 8, causal: true };
        let n = dims.elems();
        let (q, k, v) = (rand_vec(&mut rng, n), rand_vec(&mut rng, n), rand_vec(&mut rng, n));
        let a = forward(&q, &k, &v, dims, FlashParams { block_q: 4, block_k: 4 });
        let b = forward(&q, &k, &v, dims, FlashParams { block_q: 64, block_k: 64 });
        assert!(max_diff(&a.o, &b.o) < 1e-5);
        assert!(max_diff(&a.lse, &b.lse) < 1e-5);
    }

    #[test]
    fn causal_block_skipping_still_covers_the_diagonal() {
        // seq smaller than one block AND seq spanning many blocks
        let mut rng = Rng::seed_from(8);
        for seq in [1usize, 2, 3, 64, 70] {
            let dims = AttnDims { batch: 1, heads: 1, seq, head_dim: 4, causal: true };
            let n = dims.elems();
            let (q, k, v) =
                (rand_vec(&mut rng, n), rand_vec(&mut rng, n), rand_vec(&mut rng, n));
            let fl = forward(&q, &k, &v, dims, FlashParams { block_q: 16, block_k: 16 });
            let rf = reference::forward(&q, &k, &v, dims);
            assert!(max_diff(&fl.o, &rf.o) < 1e-4, "seq={seq}");
        }
    }
}
