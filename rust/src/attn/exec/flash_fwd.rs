//! Tiled online-softmax forward — the paper's Algorithm 1, dispatched on
//! [`AttnSpec`].
//!
//! One call to [`forward_tile`] computes a (b, q-head, Q-block) tile: it
//! streams K/V blocks of the spec's KV head (grouped-query broadcast)
//! through a running (m, l, õ) state, rescales the accumulator once per
//! block instead of once per iteration (§3.1), and classifies every
//! K block against the spec's mask ([`Mask::cover`]): `Skip` blocks —
//! above the causal diagonal *or* left of the sliding window — are never
//! read, `Full` blocks need no per-row masking, and only the blocks the
//! mask boundary actually crosses pay per-row column bounds.  Only the
//! logsumexp is saved for the backward pass — not m and l separately, and
//! never the N×N score matrix.
//!
//! The whole-tensor entry point lives in [`super::parallel`]; `forward`
//! here is the serial spelling (worker count 1 through the same fan-out),
//! so serial and parallel runs are byte-identical by construction.
//!
//! [`Mask::cover`]: crate::attn::spec::Mask::cover

use crate::attn::spec::{AttnSpec, Cover};

use super::{AttnDims, FlashOut, FlashParams, TensorView};

/// Compute rows `q0..q1` of query head (b, h).  Returns the tile's output
/// rows (`(q1-q0)·head_dim` values) and logsumexps (`q1-q0` values).
#[allow(clippy::too_many_arguments)]
pub(crate) fn forward_tile(
    q: TensorView,
    k: TensorView,
    v: TensorView,
    spec: AttnSpec,
    p: FlashParams,
    b: usize,
    h: usize,
    q0: usize,
    q1: usize,
) -> (Vec<f32>, Vec<f32>) {
    let (n, d) = (spec.seq, spec.head_dim);
    let g = spec.heads.kv_head(h);
    let scale = spec.scale();
    let rows = q1 - q0;
    let bk = p.block_k.max(1);

    let mut o = vec![0.0f32; rows * d];
    let mut m = vec![f32::NEG_INFINITY; rows];
    let mut l = vec![0.0f32; rows];
    let mut s = vec![0.0f32; rows * bk]; // score tile scratch

    // Start at the first block any row of this tile can see (left-edge
    // block skipping for sliding windows; 0 for full/causal), and stop
    // after the diagonal for causal-like masks.
    let first_col = spec.mask.row_bounds(q0, n).0;
    let mut j0 = (first_col / bk) * bk;
    // tiles visited vs skipped (§3 work partitioning made observable):
    // blocks before first_col and after the causal break never iterate,
    // so skipped = ceil(n/bk) − full − partial at the end
    let (mut tiles_full, mut tiles_partial) = (0u64, 0u64);
    while j0 < n {
        let j1 = (j0 + bk).min(n);
        let cover = spec.mask.cover(q0, q1, j0, j1);
        if cover == Cover::Skip {
            if spec.mask.is_causal_like() && j0 > q1 - 1 {
                break; // this and all later K blocks are above the diagonal
            }
            j0 = j1;
            continue; // left of the window: never read, move right
        }
        if cover == Cover::Full {
            tiles_full += 1;
        } else {
            tiles_partial += 1;
        }
        for (ri, i) in (q0..q1).enumerate() {
            // columns of this block row i may attend to; masked columns
            // are never computed, not computed then discarded
            let (start, end) = if cover == Cover::Full {
                (j0, j1)
            } else {
                let (lo, hi) = spec.mask.row_bounds(i, n);
                (lo.max(j0), hi.min(j1))
            };
            if start >= end {
                continue;
            }
            let w = end - start;
            // S[ri, ..w] = scale · qᵢ Kᵀ
            let qi = q.row(b, h, i);
            {
                let srow = &mut s[ri * bk..ri * bk + w];
                for (cj, sv) in srow.iter_mut().enumerate() {
                    let kj = k.row(b, g, start + cj);
                    let mut acc = 0.0f32;
                    for t in 0..d {
                        acc += qi[t] * kj[t];
                    }
                    *sv = acc * scale;
                }
            }
            let srow = &s[ri * bk..ri * bk + w];
            let mut mb = f32::NEG_INFINITY;
            for &x in srow {
                mb = mb.max(x);
            }
            let mnew = m[ri].max(mb);
            // one rescale of the existing accumulator per block (not per
            // iteration — the §3.1 non-matmul-FLOP reduction)
            let alpha = (m[ri] - mnew).exp(); // exp(-inf)=0 on the first block
            let orow = &mut o[ri * d..(ri + 1) * d];
            // fa2lint: allow(no-float-eq) -- exp(0)==1.0 exactly; skipping the rescale is the §3.1 non-matmul-FLOP saving
            if alpha != 1.0 {
                for x in orow.iter_mut() {
                    *x *= alpha;
                }
                l[ri] *= alpha;
            }
            for (cj, &sj) in srow.iter().enumerate() {
                let pij = (sj - mnew).exp();
                l[ri] += pij;
                let vj = v.row(b, g, start + cj);
                for t in 0..d {
                    orow[t] += pij * vj[t];
                }
            }
            m[ri] = mnew;
        }
        j0 = j1;
    }
    crate::obs_count!("attn_tiles_full_total", tiles_full);
    crate::obs_count!("attn_tiles_partial_total", tiles_partial);
    crate::obs_count!(
        "attn_tiles_skipped_total",
        n.div_ceil(bk) as u64 - tiles_full - tiles_partial
    );

    // finalize: O = õ / l, LSE = m + ln l (the single statistic saved)
    let mut lse = vec![0.0f32; rows];
    for ri in 0..rows {
        if l[ri] > 0.0 {
            let inv = 1.0 / l[ri];
            for x in &mut o[ri * d..(ri + 1) * d] {
                *x *= inv;
            }
            lse[ri] = m[ri] + l[ri].ln();
        } else {
            // a row that attended to nothing (cannot happen for square
            // full/causal/window attention, but keep the contract total)
            lse[ri] = f32::NEG_INFINITY;
        }
    }
    (o, lse)
}

/// Algorithm 1 over the whole tensor under a full [`AttnSpec`], serially
/// (worker count 1 through the same order-preserving fan-out
/// `parallel::forward_spec` uses).
pub fn forward_spec(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    spec: AttnSpec,
    p: FlashParams,
) -> FlashOut {
    super::parallel::forward_spec_with(1, q, k, v, spec, p)
}

/// Algorithm 1 in the seed-era equal-heads API (wrapper over
/// [`forward_spec`] with `AttnSpec::from_dims`).
pub fn forward(q: &[f32], k: &[f32], v: &[f32], dims: AttnDims, p: FlashParams) -> FlashOut {
    forward_spec(q, k, v, crate::attn::spec::AttnSpec::from_dims(dims), p)
}

#[cfg(test)]
mod tests {
    use super::super::reference;
    use super::*;
    use crate::attn::spec::{HeadMap, Mask};
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn max_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn matches_reference_on_block_boundaries_and_remainders() {
        let mut rng = Rng::seed_from(42);
        for &(seq, bq, bkc) in &[(16usize, 8usize, 8usize), (17, 8, 8), (5, 2, 3), (33, 16, 8)] {
            for &causal in &[false, true] {
                let dims = AttnDims { batch: 1, heads: 2, seq, head_dim: 16, causal };
                let n = dims.elems();
                let (q, k, v) =
                    (rand_vec(&mut rng, n), rand_vec(&mut rng, n), rand_vec(&mut rng, n));
                let p = FlashParams { block_q: bq, block_k: bkc };
                let fl = forward(&q, &k, &v, dims, p);
                let rf = reference::forward(&q, &k, &v, dims);
                assert!(
                    max_diff(&fl.o, &rf.o) < 1e-4,
                    "O mismatch seq={seq} bq={bq} bk={bkc} causal={causal}"
                );
                assert!(
                    max_diff(&fl.lse, &rf.lse) < 1e-4,
                    "LSE mismatch seq={seq} causal={causal}"
                );
            }
        }
    }

    #[test]
    fn block_size_does_not_change_results_beyond_roundoff() {
        let mut rng = Rng::seed_from(7);
        let dims = AttnDims { batch: 1, heads: 1, seq: 29, head_dim: 8, causal: true };
        let n = dims.elems();
        let (q, k, v) = (rand_vec(&mut rng, n), rand_vec(&mut rng, n), rand_vec(&mut rng, n));
        let a = forward(&q, &k, &v, dims, FlashParams { block_q: 4, block_k: 4 });
        let b = forward(&q, &k, &v, dims, FlashParams { block_q: 64, block_k: 64 });
        assert!(max_diff(&a.o, &b.o) < 1e-5);
        assert!(max_diff(&a.lse, &b.lse) < 1e-5);
    }

    #[test]
    fn causal_block_skipping_still_covers_the_diagonal() {
        // seq smaller than one block AND seq spanning many blocks
        let mut rng = Rng::seed_from(8);
        for seq in [1usize, 2, 3, 64, 70] {
            let dims = AttnDims { batch: 1, heads: 1, seq, head_dim: 4, causal: true };
            let n = dims.elems();
            let (q, k, v) =
                (rand_vec(&mut rng, n), rand_vec(&mut rng, n), rand_vec(&mut rng, n));
            let fl = forward(&q, &k, &v, dims, FlashParams { block_q: 16, block_k: 16 });
            let rf = reference::forward(&q, &k, &v, dims);
            assert!(max_diff(&fl.o, &rf.o) < 1e-4, "seq={seq}");
        }
    }

    #[test]
    fn sliding_window_matches_reference_across_block_geometries() {
        // windows smaller than / equal to / larger than the K block, with
        // seqlens that leave remainders, and GQA/MQA head maps
        let mut rng = Rng::seed_from(9);
        for &(seq, w, bq, bk) in &[
            (33usize, 5usize, 8usize, 8usize),
            (64, 16, 16, 16),
            (40, 1, 8, 8),
            (21, 100, 4, 8), // window wider than seq == causal
            (48, 7, 16, 4),
        ] {
            for heads in [HeadMap::mha(2), HeadMap { n_q_heads: 4, n_kv_heads: 2 }] {
                let spec = AttnSpec {
                    batch: 1,
                    heads,
                    seq,
                    head_dim: 8,
                    mask: Mask::SlidingWindow(w),
                };
                let q = rand_vec(&mut rng, spec.q_elems());
                let k = rand_vec(&mut rng, spec.kv_elems());
                let v = rand_vec(&mut rng, spec.kv_elems());
                let p = FlashParams { block_q: bq, block_k: bk };
                let fl = forward_spec(&q, &k, &v, spec, p);
                let rf = reference::forward_spec(&q, &k, &v, spec);
                assert!(
                    max_diff(&fl.o, &rf.o) < 1e-4,
                    "O mismatch seq={seq} w={w} bq={bq} bk={bk} {heads:?}"
                );
                assert!(max_diff(&fl.lse, &rf.lse) < 1e-4, "LSE mismatch w={w}");
            }
        }
    }

    #[test]
    fn window_wider_than_seq_is_bitwise_causal() {
        // SlidingWindow(w >= seq) visits exactly the blocks Causal visits,
        // in the same order — the outputs must be bit-identical.
        let mut rng = Rng::seed_from(10);
        let dims = AttnDims { batch: 1, heads: 2, seq: 37, head_dim: 8, causal: true };
        let n = dims.elems();
        let (q, k, v) = (rand_vec(&mut rng, n), rand_vec(&mut rng, n), rand_vec(&mut rng, n));
        let p = FlashParams { block_q: 8, block_k: 8 };
        let causal = forward(&q, &k, &v, dims, p);
        let spec = AttnSpec {
            mask: Mask::SlidingWindow(64),
            ..AttnSpec::from_dims(dims)
        };
        let windowed = forward_spec(&q, &k, &v, spec, p);
        assert_eq!(causal.o, windowed.o);
        assert_eq!(causal.lse, windowed.lse);
    }
}
