//! Sequence-parallel ring attention over the combine algebra (DESIGN.md
//! §16) — the long-context execution mode.
//!
//! The single-slab kernels hold one sequence's whole KV in every worker's
//! reach; book-length contexts want the opposite: W workers each *own* a
//! contiguous KV shard plus a set of Q chunks, and KV shards rotate around
//! an in-process ring ([`super::comm`]) for W steps, Sequence
//! Parallelism / DISTFLASHATTN style.  What makes this correct is exactly
//! FlashAttention-2's combine algebra: every (Q row × K chunk) produces a
//! [`Partial`], and partials merge associatively via `merge_from`.
//!
//! **Deterministic-merge invariant.**  Outputs and LSE are byte-identical
//! at ANY worker count, striping mode, or ring timing.  Two structural
//! rules buy this:
//!
//! 1. Partials are computed at a fixed absolute K-chunk granularity
//!    ([`SeqParParams::chunk`]) with chunk boundaries at absolute
//!    positions, never at shard boundaries (shards are unions of whole
//!    chunks, and shard extents change with W).
//! 2. Per Q row, partials merge in ascending absolute K-chunk index —
//!    keyed by chunk index, not ring-arrival order.  Likewise dK/dV
//!    contributions sum per owner in ascending Q-chunk order and dQ
//!    contributions in ascending K-chunk order, in f64.
//!
//! `FA2_SEQPAR_INJECT_SKEW=1` disables rule 2 (arrival-order merging) so
//! CI can prove the worker-count-identity test actually guards the
//! invariant.
//!
//! **Causal load balancing.**  With a causal mask and naive contiguous Q
//! shards, the owner of the earliest rows attends only its own diagonal
//! shard and idles for the other W−1 steps.  [`SeqParParams::striped`]
//! assigns Q chunks round-robin (`qc % W`) instead, so every worker holds
//! a mix of early and late rows and per-step work evens out — the
//! DISTFLASHATTN rebalancing, visible directly in
//! [`SeqParStats::idle_ns`].
//!
//! **Shard skipping.**  [`SeqParPlan`] classifies every (worker × shard)
//! pair with [`Mask::cover`]; a shard travels only as many hops as its
//! farthest attending worker ([`SeqParPlan::fwd_hops`]), so causal
//! above-diagonal and out-of-window shards are never shipped at all.  The
//! plan also *predicts* the exact bytes the transport will move
//! ([`SeqParPlan::fwd_comm_bytes`]) — `gpusim::comm` prices that same
//! number, which is what keeps the simulated and executing layers ranking
//! shard counts the same way.
//!
//! The backward pass rotates (KV + accumulated dK/dV contributions)
//! around the full ring: visiting workers append per-(Q-chunk × K-chunk)
//! contribution tiles to the traveling payload, the K/V rows are dropped
//! from the payload after the last attending worker, and the shard's
//! exclusive owner performs the final deterministic accumulation when the
//! payload comes home.
//!
//! [`Partial`]: crate::attn::combine::Partial
//! [`Mask::cover`]: crate::attn::spec::Mask::cover

use std::time::Instant;

use crate::attn::combine::Partial;
use crate::attn::spec::{AttnSpec, Cover};
use crate::bail;
use crate::util::error::Result;
use crate::util::pool;

use super::comm::{self, LinkStats, RingEndpoint};
use super::{parallel, FlashGrads, FlashOut, TensorView};

/// Knobs of one sequence-parallel execution.
#[derive(Debug, Clone, Copy)]
pub struct SeqParParams {
    /// Ring size W (clamped to the chunk count; `1` runs serially).
    pub workers: usize,
    /// Absolute K/Q chunk granularity in tokens — the unit partials are
    /// computed and merged at.  Identical results require an identical
    /// chunk, NOT an identical worker count.
    pub chunk: usize,
    /// Round-robin (striped) Q-chunk ownership for causal load balance;
    /// `false` is the naive contiguous baseline the benches compare
    /// against.
    pub striped: bool,
}

impl Default for SeqParParams {
    fn default() -> Self {
        SeqParParams { workers: pool::threads(), chunk: 64, striped: true }
    }
}

/// The static ring schedule: shard and Q-chunk ownership, per-shard hop
/// counts, and the (worker × shard) attendance matrix — everything both
/// the executing workers and the `gpusim` comm model need to agree on.
#[derive(Debug, Clone)]
pub struct SeqParPlan {
    /// Ring size after clamping to the chunk count.
    pub workers: usize,
    /// Chunk granularity in tokens.
    pub chunk: usize,
    /// Number of absolute chunks covering the sequence.
    pub n_chunks: usize,
    /// Sequence length the plan was built for.
    pub seq: usize,
    /// KV shard `s` owns chunks `shard_start[s]..shard_start[s+1]`.
    pub shard_start: Vec<usize>,
    /// Worker owning each Q chunk (striped or contiguous).
    pub q_owner: Vec<usize>,
    /// Forward hops shard `s` travels (0 = never leaves its owner).
    pub fwd_hops: Vec<usize>,
    /// Whether shard `s`'s backward payload makes the full W-hop loop
    /// (true iff any non-owner attends it).
    pub bwd_loop: Vec<bool>,
    /// `needs[w * workers + s]`: worker `w` attends shard `s`.
    needs: Vec<bool>,
}

impl SeqParPlan {
    /// Build the schedule for `spec` under `prm`.
    pub fn build(spec: &AttnSpec, prm: &SeqParParams) -> SeqParPlan {
        let chunk = prm.chunk.max(1);
        let n_chunks = (spec.seq + chunk - 1) / chunk;
        let workers = prm.workers.max(1).min(n_chunks.max(1));
        let shard_start: Vec<usize> =
            (0..=workers).map(|s| s * n_chunks / workers).collect();
        let q_owner: Vec<usize> = (0..n_chunks)
            .map(|qc| {
                if prm.striped {
                    qc % workers
                } else {
                    let mut owner = workers - 1;
                    for s in 0..workers {
                        if qc < shard_start[s + 1] {
                            owner = s;
                            break;
                        }
                    }
                    owner
                }
            })
            .collect();
        let mut plan = SeqParPlan {
            workers,
            chunk,
            n_chunks,
            seq: spec.seq,
            shard_start,
            q_owner,
            fwd_hops: vec![0; workers],
            bwd_loop: vec![false; workers],
            needs: vec![false; workers * workers],
        };
        for w in 0..workers {
            for s in 0..workers {
                plan.needs[w * workers + s] = plan.worker_attends(w, s, spec);
            }
        }
        for s in 0..workers {
            let mut hops = 0;
            let mut looped = false;
            for w in 0..workers {
                if plan.needs[w * workers + s] {
                    hops = hops.max((w + workers - s) % workers);
                    if w != s {
                        looped = true;
                    }
                }
            }
            plan.fwd_hops[s] = hops;
            plan.bwd_loop[s] = looped;
        }
        plan
    }

    /// Token rows `[lo, hi)` of absolute chunk `c`.
    pub fn chunk_rows(&self, c: usize) -> (usize, usize) {
        (c * self.chunk, ((c + 1) * self.chunk).min(self.seq))
    }

    /// The chunk indices shard `s` owns.
    pub fn shard_chunks(&self, s: usize) -> std::ops::Range<usize> {
        self.shard_start[s]..self.shard_start[s + 1]
    }

    /// Token rows `[lo, hi)` of shard `s` (`lo == hi` for an empty shard).
    pub fn shard_rows(&self, s: usize) -> (usize, usize) {
        let (c0, c1) = (self.shard_start[s], self.shard_start[s + 1]);
        if c0 == c1 {
            return (0, 0);
        }
        (self.chunk_rows(c0).0, self.chunk_rows(c1 - 1).1)
    }

    /// Whether worker `w` attends any row of shard `s` under the mask.
    pub fn needs(&self, w: usize, s: usize) -> bool {
        self.needs[w * self.workers + s]
    }

    fn worker_attends(&self, w: usize, s: usize, spec: &AttnSpec) -> bool {
        let (sr0, sr1) = self.shard_rows(s);
        if sr0 == sr1 {
            return false;
        }
        (0..self.n_chunks).any(|qc| {
            if self.q_owner[qc] != w {
                return false;
            }
            let (q0, q1) = self.chunk_rows(qc);
            spec.mask.cover(q0, q1, sr0, sr1) != Cover::Skip
        })
    }

    /// Whether shard `s` is live at forward ring position `pos` (hops
    /// from its owner; 0 = at the owner).
    pub fn fwd_alive(&self, s: usize, pos: usize) -> bool {
        let (r0, r1) = self.shard_rows(s);
        if r0 == r1 {
            return false;
        }
        if pos == 0 {
            self.needs(s, s) || self.fwd_hops[s] > 0
        } else {
            pos <= self.fwd_hops[s]
        }
    }

    /// Whether shard `s`'s backward payload exists at ring position
    /// `pos` (0 = owner start, `workers` = homecoming).
    pub fn bwd_alive(&self, s: usize, pos: usize) -> bool {
        let (r0, r1) = self.shard_rows(s);
        if r0 == r1 {
            return false;
        }
        if pos == 0 {
            return self.needs(s, s) || self.bwd_loop[s];
        }
        self.bwd_loop[s] && pos <= self.workers
    }

    /// Ring steps one pass executes.
    pub fn steps(&self) -> usize {
        self.workers
    }

    /// Exact payload bytes the executing *forward* transport ships: each
    /// live hop of shard `s` moves its compact K+V f32 copy.  The
    /// `gpusim::comm` model prices exactly this number, and the
    /// `seqpar_comm_bytes_total` counter measures exactly this number —
    /// the calibration tests pin all three equal.
    pub fn fwd_comm_bytes(&self, spec: &AttnSpec) -> u64 {
        (0..self.workers)
            .map(|s| {
                let (t0, t1) = self.shard_rows(s);
                let elems = spec.batch * spec.heads.n_kv_heads * (t1 - t0) * spec.head_dim;
                self.fwd_hops[s] as u64 * (2 * elems * 4) as u64
            })
            .sum()
    }

    /// Forward messages the transport will send (one per live hop).
    pub fn fwd_comm_msgs(&self) -> u64 {
        self.fwd_hops.iter().map(|&h| h as u64).sum()
    }
}

/// Transport + load metering of one seqpar pass, aggregated over workers.
#[derive(Debug, Clone, Copy, Default)]
pub struct SeqParStats {
    /// Ring size the pass actually ran with (after clamping).
    pub workers: usize,
    /// Ring steps executed (== workers).
    pub steps: usize,
    /// Payload bytes shipped over ring links.
    pub comm_bytes: u64,
    /// Ring messages sent.
    pub comm_msgs: u64,
    /// Shards the mask proved no remote worker attends (never shipped).
    pub shards_unshipped: u64,
    /// Σ over workers of nanoseconds inside compute sections.
    pub compute_ns: u64,
    /// Σ over workers of (wall − compute): time not spent computing —
    /// the load-imbalance signal striping exists to shrink.
    pub idle_ns: u64,
    /// Wall nanoseconds of the whole pass.
    pub wall_ns: u64,
}

/// Arrival-order-merge injection (`FA2_SEQPAR_INJECT_SKEW=1`): the
/// established honesty hook — CI asserts the worker-count-identity test
/// FAILS under it, proving the deterministic-merge invariant is
/// load-bearing rather than vacuously tested.
fn inject_skew() -> bool {
    matches!(std::env::var("FA2_SEQPAR_INJECT_SKEW"), Ok(v) if v == "1")
}

/// Compact copy of one KV shard: token rows `t0..t0+rows` of every
/// (batch, kv-head) plane, `(batch, n_kv_heads, rows, d)` row-major —
/// the bytes that actually travel the ring.
struct KvShardData {
    t0: usize,
    rows: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvShardData {
    fn wire_bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }

    /// K/V rows `[lo, hi)` (absolute token indices) of plane (b, kvh).
    fn rows(&self, spec: &AttnSpec, b: usize, kvh: usize, lo: usize, hi: usize) -> (&[f32], &[f32]) {
        let d = spec.head_dim;
        debug_assert!(lo >= self.t0 && hi <= self.t0 + self.rows && lo <= hi);
        let base = ((b * spec.heads.n_kv_heads + kvh) * self.rows + (lo - self.t0)) * d;
        let len = (hi - lo) * d;
        (&self.k[base..base + len], &self.v[base..base + len])
    }
}

fn extract_shard(
    plan: &SeqParPlan,
    s: usize,
    kvv: TensorView,
    vvv: TensorView,
    spec: &AttnSpec,
) -> KvShardData {
    let (t0, t1) = plan.shard_rows(s);
    let d = spec.head_dim;
    let rows = t1 - t0;
    let mut k = Vec::with_capacity(spec.batch * spec.heads.n_kv_heads * rows * d);
    let mut v = Vec::with_capacity(k.capacity());
    for b in 0..spec.batch {
        for h in 0..spec.heads.n_kv_heads {
            k.extend_from_slice(&kvv.head(b, h)[t0 * d..t1 * d]);
            v.extend_from_slice(&vvv.head(b, h)[t0 * d..t1 * d]);
        }
    }
    KvShardData { t0, rows, k, v }
}

/// Forward ring message: a KV shard in flight.
struct FwdMsg {
    shard: usize,
    data: KvShardData,
}

/// One owned Q chunk's finalized forward outputs,
/// `(batch, n_q_heads, rows, d)` / `(batch, n_q_heads, rows)` compact.
struct QcTile {
    qc: usize,
    o: Vec<f32>,
    lse: Vec<f32>,
}

struct FwdWorkerOut {
    tiles: Vec<QcTile>,
    compute_ns: u64,
    link: LinkStats,
}

/// Sequence-parallel forward: output + LSE byte-identical at any worker
/// count (see the module docs for the invariant), plus transport stats.
pub fn forward_spec(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    spec: AttnSpec,
    prm: SeqParParams,
) -> Result<(FlashOut, SeqParStats)> {
    let _sp = crate::obs_span!("attn_seqpar_fwd");
    spec.validate()?;
    if q.len() != spec.q_elems() || k.len() != spec.kv_elems() || v.len() != spec.kv_elems() {
        bail!("seqpar forward: tensor lengths do not match {spec:?}");
    }
    let qd = spec.q_dims();
    let kd = spec.kv_dims();
    let qv = TensorView::new(qd, q);
    let kvv = TensorView::new(kd, k);
    let vvv = TensorView::new(kd, v);
    let plan = SeqParPlan::build(&spec, &prm);
    let w = plan.workers;
    let skew = inject_skew();
    let (nq, d) = (spec.heads.n_q_heads, spec.head_dim);

    let t_wall = Instant::now();
    let eps = comm::ring::<FwdMsg>(w);
    let outs = pool::par_map_with(w, eps, |ep| {
        fwd_worker(ep, &plan, &spec, qv, kvv, vvv, skew)
    });
    let wall_ns = t_wall.elapsed().as_nanos() as u64;

    let mut out = FlashOut { o: vec![0.0; spec.q_elems()], lse: vec![0.0; spec.q_rows()] };
    let mut stats = SeqParStats {
        workers: w,
        steps: plan.steps(),
        shards_unshipped: plan.fwd_hops.iter().filter(|&&h| h == 0).count() as u64,
        wall_ns,
        ..SeqParStats::default()
    };
    for r in outs {
        let wo = r?;
        stats.comm_bytes += wo.link.sent_bytes;
        stats.comm_msgs += wo.link.sends;
        stats.compute_ns += wo.compute_ns;
        stats.idle_ns += wall_ns.saturating_sub(wo.compute_ns);
        for tile in wo.tiles {
            let (q0, q1) = plan.chunk_rows(tile.qc);
            let rl = q1 - q0;
            for b in 0..spec.batch {
                for h in 0..nq {
                    let src = (b * nq + h) * rl;
                    let ro = qd.row_offset(b, h, q0);
                    out.o[ro..ro + rl * d].copy_from_slice(&tile.o[src * d..(src + rl) * d]);
                    let lo = qd.lse_offset(b, h, q0);
                    out.lse[lo..lo + rl].copy_from_slice(&tile.lse[src..src + rl]);
                }
            }
        }
    }
    record_stats(&stats);
    Ok((out, stats))
}

fn record_stats(stats: &SeqParStats) {
    crate::obs_count!("seqpar_comm_bytes_total", stats.comm_bytes);
    crate::obs_count!("seqpar_comm_msgs_total", stats.comm_msgs);
    crate::obs_count!("seqpar_steps_total", stats.steps);
    crate::obs_count!("seqpar_idle_ns_total", stats.idle_ns);
    crate::obs_count!("seqpar_shards_unshipped_total", stats.shards_unshipped);
}

fn fwd_worker(
    mut ep: RingEndpoint<FwdMsg>,
    plan: &SeqParPlan,
    spec: &AttnSpec,
    qv: TensorView,
    kvv: TensorView,
    vvv: TensorView,
    skew: bool,
) -> Result<FwdWorkerOut> {
    let rank = ep.rank();
    let w = plan.workers;
    let (nq, d) = (spec.heads.n_q_heads, spec.head_dim);
    let my_qcs: Vec<usize> =
        (0..plan.n_chunks).filter(|&qc| plan.q_owner[qc] == rank).collect();
    // per owned qc, per (b, h, local row): the (chunk, Partial) pairs seen
    let mut acc: Vec<Vec<Vec<(usize, Partial)>>> = my_qcs
        .iter()
        .map(|&qc| {
            let (r0, r1) = plan.chunk_rows(qc);
            vec![Vec::new(); spec.batch * nq * (r1 - r0)]
        })
        .collect();
    let mut compute_ns = 0u64;

    for t in 0..w {
        let s = (rank + w - t) % w;
        let mut payload: Option<FwdMsg> = if !plan.fwd_alive(s, t) {
            None
        } else if t == 0 {
            Some(FwdMsg { shard: s, data: extract_shard(plan, s, kvv, vvv, spec) })
        } else {
            let msg = ep.recv()?;
            if msg.shard != s {
                bail!("ring skew: fwd worker {rank} step {t} expected shard {s}, got {}", msg.shard);
            }
            Some(msg)
        };
        if let Some(msg) = &payload {
            if plan.needs(rank, s) {
                let c0 = Instant::now();
                accumulate_shard(&mut acc, &my_qcs, &msg.data, s, plan, spec, qv);
                compute_ns += c0.elapsed().as_nanos() as u64;
            }
        }
        if plan.fwd_alive(s, t + 1) {
            match payload.take() {
                Some(msg) => {
                    let bytes = msg.data.wire_bytes();
                    ep.send_next(msg, bytes)?;
                }
                None => bail!("ring skew: fwd worker {rank} step {t} must forward shard {s} it never held"),
            }
        }
    }

    let mut tiles = Vec::with_capacity(my_qcs.len());
    for (qi, &qc) in my_qcs.iter().enumerate() {
        let (q0, q1) = plan.chunk_rows(qc);
        let rl = q1 - q0;
        let mut o = vec![0.0f32; spec.batch * nq * rl * d];
        let mut lse = vec![0.0f32; spec.batch * nq * rl];
        for (ri, parts) in acc[qi].iter_mut().enumerate() {
            if !skew {
                // the invariant: merge keyed by absolute K-chunk index, not
                // ring-arrival order
                parts.sort_unstable_by_key(|&(c, _)| c);
            }
            let mut m = Partial::empty(d);
            for (_, p) in parts.iter() {
                m.merge_from(p);
            }
            let (orow, l) = m.finalize();
            for (t2, x) in orow.iter().enumerate() {
                o[ri * d + t2] = *x as f32;
            }
            lse[ri] = l as f32;
        }
        tiles.push(QcTile { qc, o, lse });
    }
    Ok(FwdWorkerOut { tiles, compute_ns, link: ep.stats() })
}

/// Merge-inputs for every (owned Q row × chunk of shard `s`) pair: one
/// f64 [`Partial`] per pair, computed from the *payload* copy (the bytes
/// that actually traveled), with per-row mask bounds intersected per
/// chunk.  The stored set of (row, chunk) partials depends only on the
/// mask and chunk grid — never on W.
fn accumulate_shard(
    acc: &mut [Vec<Vec<(usize, Partial)>>],
    my_qcs: &[usize],
    data: &KvShardData,
    s: usize,
    plan: &SeqParPlan,
    spec: &AttnSpec,
    qv: TensorView,
) {
    let nq = spec.heads.n_q_heads;
    let d = spec.head_dim;
    let (sr0, sr1) = plan.shard_rows(s);
    let scale = spec.scale();
    for (qi, &qc) in my_qcs.iter().enumerate() {
        let (q0, q1) = plan.chunk_rows(qc);
        if spec.mask.cover(q0, q1, sr0, sr1) == Cover::Skip {
            continue;
        }
        let rl = q1 - q0;
        for b in 0..spec.batch {
            for h in 0..nq {
                let kvh = spec.heads.kv_head(h);
                for i in q0..q1 {
                    let (lo, hi) = spec.mask.row_bounds(i, spec.seq);
                    let row_acc = &mut acc[qi][(b * nq + h) * rl + (i - q0)];
                    for c in plan.shard_chunks(s) {
                        let (c0, c1) = plan.chunk_rows(c);
                        let (st, en) = (lo.max(c0), hi.min(c1));
                        if st >= en {
                            continue;
                        }
                        let (kc, vc) = data.rows(spec, b, kvh, st, en);
                        let mut part = Partial::empty(d);
                        parallel::partial_from_chunk(&mut part, qv.row(b, h, i), kc, vc, scale);
                        row_acc.push((c, part));
                    }
                }
            }
        }
    }
}

/// One dK/dV contribution tile: what Q-chunk `qc` (computed wherever its
/// owner sat on the ring) adds to K-chunk `kc` of some shard, for plane
/// (b, kvh).  Tiles travel with the shard and are summed by the shard's
/// exclusive owner in ascending `qc` order.
struct Contrib {
    b: u32,
    kvh: u32,
    qc: u32,
    kc: u32,
    dk: Vec<f32>,
    dv: Vec<f32>,
}

/// Backward ring message: the KV shard (dropped after its last attending
/// worker) plus the accumulated contribution tiles riding home.
struct BwdMsg {
    shard: usize,
    data: Option<KvShardData>,
    contribs: Vec<Contrib>,
}

impl BwdMsg {
    fn wire_bytes(&self) -> usize {
        let kv = self.data.as_ref().map_or(0, KvShardData::wire_bytes);
        kv + self.contribs.iter().map(|c| (c.dk.len() + c.dv.len()) * 4).sum::<usize>()
    }
}

struct BwdWorkerOut {
    /// dK/dV of this worker's own shard, `(batch, n_kv_heads, rows, d)`.
    dk: Vec<f32>,
    dv: Vec<f32>,
    /// Per owned Q chunk: `(qc, dQ tile (batch, n_q_heads, rows, d))`.
    dq_tiles: Vec<(usize, Vec<f32>)>,
    compute_ns: u64,
    link: LinkStats,
}

/// Sequence-parallel backward: ring-shuttles dK/dV contribution tiles
/// with the rotating KV shard; each shard's owner accumulates its dK/dV
/// exclusively, in deterministic ascending-Q-chunk order, and dQ sums
/// locally in ascending K-chunk order — byte-identical at any worker
/// count, matching the forward's invariant.
#[allow(clippy::too_many_arguments)]
pub fn backward_spec(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    fwd: &FlashOut,
    dout: &[f32],
    spec: AttnSpec,
    prm: SeqParParams,
) -> Result<(FlashGrads, SeqParStats)> {
    let _sp = crate::obs_span!("attn_seqpar_bwd");
    spec.validate()?;
    if q.len() != spec.q_elems() || k.len() != spec.kv_elems() || v.len() != spec.kv_elems() {
        bail!("seqpar backward: tensor lengths do not match {spec:?}");
    }
    if dout.len() != spec.q_elems() || fwd.o.len() != spec.q_elems() || fwd.lse.len() != spec.q_rows()
    {
        bail!("seqpar backward: forward-output lengths do not match {spec:?}");
    }
    let qd = spec.q_dims();
    let kd = spec.kv_dims();
    let qv = TensorView::new(qd, q);
    let kvv = TensorView::new(kd, k);
    let vvv = TensorView::new(kd, v);
    let dov = TensorView::new(qd, dout);
    let (nq, nkv, d) = (spec.heads.n_q_heads, spec.heads.n_kv_heads, spec.head_dim);

    // D_i = Σ_t dO_it · O_it, once per tensor (Algorithm 2 line 1)
    let mut dvec = vec![0.0f32; spec.q_rows()];
    for (r, dvi) in dvec.iter_mut().enumerate() {
        let (orow, dorow) = (&fwd.o[r * d..(r + 1) * d], &dout[r * d..(r + 1) * d]);
        let mut a = 0.0f32;
        for t in 0..d {
            a += orow[t] * dorow[t];
        }
        *dvi = a;
    }

    let plan = SeqParPlan::build(&spec, &prm);
    let w = plan.workers;
    let skew = inject_skew();
    let lse = &fwd.lse;
    let dvec_ref = &dvec;

    let t_wall = Instant::now();
    let eps = comm::ring::<BwdMsg>(w);
    let outs = pool::par_map_with(w, eps, |ep| {
        bwd_worker(ep, &plan, &spec, qv, kvv, vvv, dov, lse, dvec_ref, skew)
    });
    let wall_ns = t_wall.elapsed().as_nanos() as u64;

    let mut g = FlashGrads {
        dq: vec![0.0; spec.q_elems()],
        dk: vec![0.0; spec.kv_elems()],
        dv: vec![0.0; spec.kv_elems()],
    };
    let mut stats = SeqParStats {
        workers: w,
        steps: plan.steps(),
        shards_unshipped: plan.bwd_loop.iter().filter(|&&l| !l).count() as u64,
        wall_ns,
        ..SeqParStats::default()
    };
    for (rank, r) in outs.into_iter().enumerate() {
        let wo = r?;
        stats.comm_bytes += wo.link.sent_bytes;
        stats.comm_msgs += wo.link.sends;
        stats.compute_ns += wo.compute_ns;
        stats.idle_ns += wall_ns.saturating_sub(wo.compute_ns);
        let (t0s, t1s) = plan.shard_rows(rank);
        let rows = t1s - t0s;
        for b in 0..spec.batch {
            for kvh in 0..nkv {
                let src = (b * nkv + kvh) * rows * d;
                let dst = kd.row_offset(b, kvh, t0s);
                g.dk[dst..dst + rows * d].copy_from_slice(&wo.dk[src..src + rows * d]);
                g.dv[dst..dst + rows * d].copy_from_slice(&wo.dv[src..src + rows * d]);
            }
        }
        for (qc, tile) in wo.dq_tiles {
            let (i0, i1) = plan.chunk_rows(qc);
            let il = i1 - i0;
            for b in 0..spec.batch {
                for h in 0..nq {
                    let src = (b * nq + h) * il * d;
                    let dst = qd.row_offset(b, h, i0);
                    g.dq[dst..dst + il * d].copy_from_slice(&tile[src..src + il * d]);
                }
            }
        }
    }
    record_stats(&stats);
    Ok((g, stats))
}

#[allow(clippy::too_many_arguments)]
fn bwd_worker(
    mut ep: RingEndpoint<BwdMsg>,
    plan: &SeqParPlan,
    spec: &AttnSpec,
    qv: TensorView,
    kvv: TensorView,
    vvv: TensorView,
    dov: TensorView,
    lse: &[f32],
    dvec: &[f32],
    skew: bool,
) -> Result<BwdWorkerOut> {
    let rank = ep.rank();
    let w = plan.workers;
    let (nq, nkv, d) = (spec.heads.n_q_heads, spec.heads.n_kv_heads, spec.head_dim);
    let my_qcs: Vec<usize> =
        (0..plan.n_chunks).filter(|&qc| plan.q_owner[qc] == rank).collect();
    // per owned qc: dQ contribution tiles keyed (kc, b, kvh)
    let mut dq_parts: Vec<Vec<(usize, usize, usize, Vec<f32>)>> =
        vec![Vec::new(); my_qcs.len()];
    // contribution tiles for our own shard, local + homecoming
    let mut home: Vec<Contrib> = Vec::new();
    let mut compute_ns = 0u64;

    for t in 0..w {
        let s = (rank + w - t) % w;
        let mut payload: Option<BwdMsg> = if !plan.bwd_alive(s, t) {
            None
        } else if t == 0 {
            Some(BwdMsg {
                shard: s,
                data: Some(extract_shard(plan, s, kvv, vvv, spec)),
                contribs: Vec::new(),
            })
        } else {
            let msg = ep.recv()?;
            if msg.shard != s {
                bail!("ring skew: bwd worker {rank} step {t} expected shard {s}, got {}", msg.shard);
            }
            Some(msg)
        };
        if let Some(msg) = &mut payload {
            if plan.needs(rank, s) {
                let Some(data) = msg.data.as_ref() else {
                    bail!("ring skew: bwd worker {rank} step {t} attends shard {s} whose K/V was already dropped");
                };
                let c0 = Instant::now();
                bwd_shard_contribs(
                    &my_qcs,
                    data,
                    s,
                    plan,
                    spec,
                    qv,
                    dov,
                    lse,
                    dvec,
                    &mut msg.contribs,
                    &mut dq_parts,
                );
                compute_ns += c0.elapsed().as_nanos() as u64;
            }
            // K/V rows ride only as far as the last attending worker; the
            // contribution tiles continue the loop home without them
            if plan.fwd_hops[s] < t + 1 {
                msg.data = None;
            }
        }
        if plan.bwd_alive(s, t + 1) {
            match payload.take() {
                Some(msg) => {
                    let bytes = msg.wire_bytes();
                    ep.send_next(msg, bytes)?;
                }
                None => bail!("ring skew: bwd worker {rank} step {t} must forward shard {s} it never held"),
            }
        }
        if let Some(msg) = payload.take() {
            // not forwarded: only our own never-looped shard ends here
            if msg.shard != rank {
                bail!("ring skew: bwd worker {rank} stranded shard {}", msg.shard);
            }
            home.extend(msg.contribs);
        }
    }
    if plan.bwd_alive(rank, w) {
        let msg = ep.recv()?;
        if msg.shard != rank {
            bail!("ring skew: bwd worker {rank} homecoming got shard {}", msg.shard);
        }
        home.extend(msg.contribs);
    }

    // exclusive-owner accumulation: ascending (b, kvh, kc, qc) — per dK/dV
    // element that is ascending absolute Q-chunk order, independent of W
    let (t0s, t1s) = plan.shard_rows(rank);
    let rows = t1s - t0s;
    let mut dk_acc = vec![0.0f64; spec.batch * nkv * rows * d];
    let mut dv_acc = vec![0.0f64; spec.batch * nkv * rows * d];
    if !skew {
        home.sort_unstable_by_key(|c| (c.b, c.kvh, c.kc, c.qc));
    }
    for c in &home {
        let (j0, j1) = plan.chunk_rows(c.kc as usize);
        let base = ((c.b as usize * nkv + c.kvh as usize) * rows + (j0 - t0s)) * d;
        let len = (j1 - j0) * d;
        for (x, a) in c.dk.iter().zip(&mut dk_acc[base..base + len]) {
            *a += *x as f64;
        }
        for (x, a) in c.dv.iter().zip(&mut dv_acc[base..base + len]) {
            *a += *x as f64;
        }
    }
    let dk: Vec<f32> = dk_acc.iter().map(|x| *x as f32).collect();
    let dv: Vec<f32> = dv_acc.iter().map(|x| *x as f32).collect();

    // dQ: per owned chunk, ascending absolute K-chunk order
    let mut dq_tiles = Vec::with_capacity(my_qcs.len());
    for (qi, &qc) in my_qcs.iter().enumerate() {
        let (i0, i1) = plan.chunk_rows(qc);
        let il = i1 - i0;
        let mut acc = vec![0.0f64; spec.batch * nq * il * d];
        let parts = &mut dq_parts[qi];
        if !skew {
            parts.sort_unstable_by_key(|p| (p.0, p.1, p.2));
        }
        for (_kc, b, kvh, tile) in parts.iter() {
            for (gi, h) in spec.heads.q_heads_of(*kvh).enumerate() {
                for li in 0..il {
                    let src = (gi * il + li) * d;
                    let dst = ((*b * nq + h) * il + li) * d;
                    for t2 in 0..d {
                        acc[dst + t2] += tile[src + t2] as f64;
                    }
                }
            }
        }
        dq_tiles.push((qc, acc.iter().map(|x| *x as f32).collect()));
    }
    Ok(BwdWorkerOut { dk, dv, dq_tiles, compute_ns, link: ep.stats() })
}

/// Contribution tiles of every owned (Q chunk × K chunk of shard `s`)
/// pair: dK/dV tiles appended to the traveling payload, dQ tiles kept
/// locally.  Tile values are pure f32 functions of the fixed chunk grid
/// and the tensor values — identical at any worker count; only *where*
/// they are computed moves with W.
#[allow(clippy::too_many_arguments)]
fn bwd_shard_contribs(
    my_qcs: &[usize],
    data: &KvShardData,
    s: usize,
    plan: &SeqParPlan,
    spec: &AttnSpec,
    qv: TensorView,
    dov: TensorView,
    lse: &[f32],
    dvec: &[f32],
    contribs: &mut Vec<Contrib>,
    dq_parts: &mut [Vec<(usize, usize, usize, Vec<f32>)>],
) {
    let qd = spec.q_dims();
    let d = spec.head_dim;
    let n = spec.seq;
    let scale = spec.scale();
    let group = spec.heads.group_size();
    for (qi, &qc) in my_qcs.iter().enumerate() {
        let (i0, i1) = plan.chunk_rows(qc);
        let il = i1 - i0;
        for kc in plan.shard_chunks(s) {
            let (j0, j1) = plan.chunk_rows(kc);
            if spec.mask.cover(i0, i1, j0, j1) == Cover::Skip {
                continue;
            }
            let jl = j1 - j0;
            for b in 0..spec.batch {
                for kvh in 0..spec.heads.n_kv_heads {
                    let mut dk_t = vec![0.0f32; jl * d];
                    let mut dv_t = vec![0.0f32; jl * d];
                    let mut dq_t = vec![0.0f32; group * il * d];
                    for (gi, h) in spec.heads.q_heads_of(kvh).enumerate() {
                        for i in i0..i1 {
                            let (lo, hi) = spec.mask.row_bounds(i, n);
                            let (st, en) = (lo.max(j0), hi.min(j1));
                            if st >= en {
                                continue;
                            }
                            let qrow = qv.row(b, h, i);
                            let doi = dov.row(b, h, i);
                            let lse_i = lse[qd.lse_offset(b, h, i)];
                            let d_i = dvec[qd.lse_offset(b, h, i)];
                            let (krows, vrows) = data.rows(spec, b, kvh, st, en);
                            let dq_at = (gi * il + (i - i0)) * d;
                            for j in st..en {
                                let kj = &krows[(j - st) * d..(j - st + 1) * d];
                                let vj = &vrows[(j - st) * d..(j - st + 1) * d];
                                let mut sdot = 0.0f32;
                                for t2 in 0..d {
                                    sdot += qrow[t2] * kj[t2];
                                }
                                let pij = (sdot * scale - lse_i).exp();
                                let mut dp = 0.0f32;
                                for t2 in 0..d {
                                    dp += doi[t2] * vj[t2];
                                }
                                let ds = pij * (dp - d_i) * scale;
                                let cj = (j - j0) * d;
                                for t2 in 0..d {
                                    dk_t[cj + t2] += ds * qrow[t2];
                                    dv_t[cj + t2] += pij * doi[t2];
                                    dq_t[dq_at + t2] += ds * kj[t2];
                                }
                            }
                        }
                    }
                    contribs.push(Contrib {
                        b: b as u32,
                        kvh: kvh as u32,
                        qc: qc as u32,
                        kc: kc as u32,
                        dk: dk_t,
                        dv: dv_t,
                    });
                    dq_parts[qi].push((kc, b, kvh, dq_t));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::exec::reference;
    use crate::attn::spec::{HeadMap, Mask};
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn max_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    fn spec(seq: usize, heads: HeadMap, mask: Mask) -> AttnSpec {
        AttnSpec { batch: 1, heads, seq, head_dim: 8, mask }
    }

    #[test]
    fn plan_partitions_and_clamps() {
        let sp = spec(100, HeadMap::mha(2), Mask::Causal);
        let plan =
            SeqParPlan::build(&sp, &SeqParParams { workers: 3, chunk: 16, striped: true });
        assert_eq!(plan.n_chunks, 7);
        assert_eq!(plan.workers, 3);
        assert_eq!(plan.shard_start, vec![0, 2, 4, 7]);
        // striped ownership round-robins chunks
        assert_eq!(plan.q_owner, vec![0, 1, 2, 0, 1, 2, 0]);
        assert_eq!(plan.chunk_rows(6), (96, 100));
        assert_eq!(plan.shard_rows(2), (64, 100));
        // more workers than chunks clamps
        let tiny =
            SeqParPlan::build(&sp, &SeqParParams { workers: 64, chunk: 64, striped: false });
        assert_eq!(tiny.workers, 2);
        // contiguous ownership matches the shard split
        assert_eq!(tiny.q_owner, vec![0, 1]);
    }

    #[test]
    fn causal_contiguous_plan_skips_above_diagonal_shards() {
        let sp = spec(128, HeadMap::mha(2), Mask::Causal);
        let plan =
            SeqParPlan::build(&sp, &SeqParParams { workers: 4, chunk: 16, striped: false });
        // contiguous causal: shard s is attended only by workers >= s, so
        // hops shrink toward the last shard and shard 3 never ships
        assert_eq!(plan.fwd_hops, vec![3, 2, 1, 0]);
        assert!(!plan.bwd_loop[3]);
        assert!(plan.bwd_loop[0]);
        // striping makes every shard needed ring-wide (late rows everywhere)
        let striped =
            SeqParPlan::build(&sp, &SeqParParams { workers: 4, chunk: 16, striped: true });
        assert_eq!(striped.fwd_hops, vec![3, 3, 3, 3]);
    }

    #[test]
    fn sliding_window_plan_expires_shards_early() {
        let sp = spec(256, HeadMap::mha(2), Mask::SlidingWindow(8));
        let plan =
            SeqParPlan::build(&sp, &SeqParParams { workers: 8, chunk: 16, striped: false });
        // a 8-token window never reaches more than one chunk back, so no
        // shard travels more than one hop under contiguous ownership
        assert!(plan.fwd_hops.iter().all(|&h| h <= 1), "{:?}", plan.fwd_hops);
        assert!(plan.fwd_hops.iter().any(|&h| h == 1), "adjacent shards do ship");
    }

    #[test]
    fn forward_matches_oracle_and_counts_bytes() {
        let mut rng = Rng::seed_from(0x5EA1);
        for (sp, workers) in [
            (spec(96, HeadMap::mha(2), Mask::Causal), 3),
            (spec(96, HeadMap { n_q_heads: 4, n_kv_heads: 2 }, Mask::Full), 4),
            (spec(96, HeadMap::mha(2), Mask::SlidingWindow(13)), 4),
        ] {
            let q = rand_vec(&mut rng, sp.q_elems());
            let k = rand_vec(&mut rng, sp.kv_elems());
            let v = rand_vec(&mut rng, sp.kv_elems());
            let prm = SeqParParams { workers, chunk: 16, striped: true };
            let (out, stats) = forward_spec(&q, &k, &v, sp, prm).expect("seqpar fwd");
            let want = reference::forward_spec(&q, &k, &v, sp);
            assert!(max_diff(&out.o, &want.o) < 1e-4, "O diverged ({sp:?})");
            assert!(max_diff(&out.lse, &want.lse) < 1e-4, "LSE diverged ({sp:?})");
            // measured transport bytes equal the plan's static prediction
            let plan = SeqParPlan::build(&sp, &prm);
            assert_eq!(stats.comm_bytes, plan.fwd_comm_bytes(&sp), "{sp:?}");
            assert_eq!(stats.comm_msgs, plan.fwd_comm_msgs(), "{sp:?}");
            assert_eq!(stats.workers, plan.workers);
        }
    }

    #[test]
    fn backward_matches_oracle() {
        let mut rng = Rng::seed_from(0x5EA2);
        let sp = spec(64, HeadMap { n_q_heads: 4, n_kv_heads: 2 }, Mask::Causal);
        let q = rand_vec(&mut rng, sp.q_elems());
        let k = rand_vec(&mut rng, sp.kv_elems());
        let v = rand_vec(&mut rng, sp.kv_elems());
        let dout = rand_vec(&mut rng, sp.q_elems());
        let prm = SeqParParams { workers: 4, chunk: 8, striped: true };
        let (fwd, _) = forward_spec(&q, &k, &v, sp, prm).expect("seqpar fwd");
        let (g, stats) = backward_spec(&q, &k, &v, &fwd, &dout, sp, prm).expect("seqpar bwd");
        let r = reference::backward_spec(&q, &k, &v, &dout, sp);
        assert!(max_diff(&g.dq, &r.dq) < 1e-4, "dQ diverged");
        assert!(max_diff(&g.dk, &r.dk) < 1e-4, "dK diverged");
        assert!(max_diff(&g.dv, &r.dv) < 1e-4, "dV diverged");
        assert!(stats.comm_bytes > 0, "backward ring shipped nothing");
    }

    #[test]
    fn worker_count_and_striping_do_not_change_bytes_out() {
        let mut rng = Rng::seed_from(0x5EA3);
        let sp = spec(70, HeadMap::mha(2), Mask::Causal);
        let q = rand_vec(&mut rng, sp.q_elems());
        let k = rand_vec(&mut rng, sp.kv_elems());
        let v = rand_vec(&mut rng, sp.kv_elems());
        let dout = rand_vec(&mut rng, sp.q_elems());
        let base_prm = SeqParParams { workers: 1, chunk: 16, striped: true };
        let (base, _) = forward_spec(&q, &k, &v, sp, base_prm).expect("base fwd");
        let (bg, _) =
            backward_spec(&q, &k, &v, &base, &dout, sp, base_prm).expect("base bwd");
        for workers in [2usize, 3, 4] {
            for striped in [true, false] {
                let prm = SeqParParams { workers, chunk: 16, striped };
                let (out, _) = forward_spec(&q, &k, &v, sp, prm).expect("fwd");
                assert_eq!(out.o, base.o, "O not byte-identical (W={workers} striped={striped})");
                assert_eq!(out.lse, base.lse, "LSE not byte-identical (W={workers})");
                let (g, _) =
                    backward_spec(&q, &k, &v, &out, &dout, sp, prm).expect("bwd");
                assert_eq!(g.dq, bg.dq, "dQ not byte-identical (W={workers} striped={striped})");
                assert_eq!(g.dk, bg.dk, "dK not byte-identical (W={workers})");
                assert_eq!(g.dv, bg.dv, "dV not byte-identical (W={workers})");
            }
        }
    }

    #[test]
    fn never_attended_shards_are_never_shipped() {
        let mut rng = Rng::seed_from(0x5EA4);
        // narrow window, contiguous shards: distant shards must not travel
        let sp = spec(128, HeadMap::mha(2), Mask::SlidingWindow(9));
        let q = rand_vec(&mut rng, sp.q_elems());
        let k = rand_vec(&mut rng, sp.kv_elems());
        let v = rand_vec(&mut rng, sp.kv_elems());
        let win = SeqParParams { workers: 4, chunk: 16, striped: false };
        let (_, stats) = forward_spec(&q, &k, &v, sp, win).expect("windowed fwd");
        let full_spec = AttnSpec { mask: Mask::Full, ..sp };
        let (_, full) = forward_spec(&q, &k, &v, full_spec, win).expect("full fwd");
        assert!(
            stats.comm_bytes < full.comm_bytes,
            "window must ship fewer bytes than full attention ({} vs {})",
            stats.comm_bytes,
            full.comm_bytes
        );
        // under a full mask every shard makes the whole loop
        let plan = SeqParPlan::build(&full_spec, &win);
        assert!(plan.fwd_hops.iter().all(|&h| h == 3));
        assert_eq!(full.shards_unshipped, 0);
    }
}
