//! `attn::exec` — the native *executing* FlashAttention-2 engine (CPU, f32).
//!
//! Everything else under `attn` prices schedules on the gpusim cost model;
//! this subsystem actually computes attention, so a fresh checkout runs
//! `serve`/`verify` end-to-end with no AOT artifacts (see
//! `runtime::native`).  DESIGN.md §7 is the architecture note.
//!
//! Every kernel dispatches on one [`AttnSpec`](crate::attn::spec::AttnSpec)
//! (DESIGN.md §11): grouped-query head maps, full/causal/sliding-window
//! masks, and contiguous-vs-paged KV layouts all flow through the same
//! entry points — the seed-era `AttnDims` functions survive as thin
//! equal-heads wrappers.
//!
//! Layout contract: every tensor is a flat `Vec<f32>`/`&[f32]` in row-major
//! `(batch, heads, seq, head_dim)` order with the last dim contiguous,
//! wrapped in a [`TensorView`]; under GQA the Q-shaped tensors carry
//! `n_q_heads` and the KV-shaped tensors `n_kv_heads`.  Modules:
//!
//! - [`reference`]: naive O(N²) forward + backward, the correctness oracle
//!   (f64 accumulation, f32 in/out).
//! - [`flash_fwd`]: the tiled online-softmax forward (paper Algorithm 1)
//!   with causal block skipping; saves only the per-row logsumexp.
//! - [`flash_bwd`]: the 5-matmul backward (Algorithm 2), recomputing P
//!   from the saved LSE instead of storing the N×N matrix.
//! - [`parallel`]: §3.2 work partitioning — (batch, head, Q-block) /
//!   (batch, head, K-block) tasks fanned across `util::pool`, plus the
//!   split-KV decode path reduced through `attn::combine`.
//! - [`seqpar`] + [`comm`]: sequence-parallel ring execution (§16) — W
//!   workers own KV shards and rotate them over an in-process ring,
//!   merging per-Q-block partials in deterministic absolute-chunk order;
//!   the long-context mode [`ExecMode::SeqParallel`] dispatches to.

pub mod comm;
pub mod flash_bwd;
pub mod flash_fwd;
pub mod parallel;
pub mod reference;
pub mod seqpar;

use crate::attn::spec::AttnSpec;
use crate::util::error::Result;

use super::Pass;

/// Which execution subsystem runs an attention call: the single-slab
/// pool fan-out ([`parallel`]) or the sequence-parallel ring
/// ([`seqpar`]).  Both produce byte-identical outputs for the math they
/// share; they differ in how work and KV residency are partitioned.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// §3.2 block fan-out over the pool — every worker sees all of KV.
    Fanned { workers: usize },
    /// §16 ring KV-exchange — each worker owns a KV shard; shards
    /// rotate.  The long-context mode.
    SeqParallel { workers: usize },
}

/// Forward under `mode`.  `Fanned` uses `p` as tile sizes; `SeqParallel`
/// reuses `p.block_k` as the absolute chunk granularity (striped causal
/// balancing on).  Returns seqpar transport stats when the ring ran.
pub fn forward_spec_mode(
    mode: ExecMode,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    spec: AttnSpec,
    p: FlashParams,
) -> Result<(FlashOut, Option<seqpar::SeqParStats>)> {
    match mode {
        ExecMode::Fanned { workers } => {
            Ok((parallel::forward_spec_with(workers, q, k, v, spec, p), None))
        }
        ExecMode::SeqParallel { workers } => {
            let prm =
                seqpar::SeqParParams { workers, chunk: p.block_k, striped: true };
            let (out, stats) = seqpar::forward_spec(q, k, v, spec, prm)?;
            Ok((out, Some(stats)))
        }
    }
}

/// Backward under `mode` — same dispatch contract as
/// [`forward_spec_mode`].
#[allow(clippy::too_many_arguments)]
pub fn backward_spec_mode(
    mode: ExecMode,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    fwd: &FlashOut,
    dout: &[f32],
    spec: AttnSpec,
    p: FlashParams,
) -> Result<(FlashGrads, Option<seqpar::SeqParStats>)> {
    match mode {
        ExecMode::Fanned { workers } => {
            Ok((parallel::backward_spec_with(workers, q, k, v, fwd, dout, spec, p), None))
        }
        ExecMode::SeqParallel { workers } => {
            let prm =
                seqpar::SeqParParams { workers, chunk: p.block_k, striped: true };
            let (g, stats) = seqpar::backward_spec(q, k, v, fwd, dout, spec, prm)?;
            Ok((g, Some(stats)))
        }
    }
}

/// Dimensions + masking of one executing attention problem.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AttnDims {
    pub batch: usize,
    pub heads: usize,
    pub seq: usize,
    pub head_dim: usize,
    pub causal: bool,
}

impl AttnDims {
    /// Element count of one (batch, heads, seq, head_dim) tensor.
    pub fn elems(&self) -> usize {
        self.batch * self.heads * self.seq * self.head_dim
    }

    /// Row count — the size of per-row tensors like the LSE.
    pub fn rows(&self) -> usize {
        self.batch * self.heads * self.seq
    }

    /// Softmax scale 1/sqrt(d).
    pub fn scale(&self) -> f32 {
        1.0 / (self.head_dim as f32).sqrt()
    }

    /// Flat offset of row `i` of head (b, h).
    pub fn row_offset(&self, b: usize, h: usize, i: usize) -> usize {
        ((b * self.heads + h) * self.seq + i) * self.head_dim
    }

    /// Flat index into a per-row (batch, heads, seq) tensor (the LSE).
    pub fn lse_offset(&self, b: usize, h: usize, i: usize) -> usize {
        (b * self.heads + h) * self.seq + i
    }

    /// The cost-model form of this problem (f32 dtype) — what the
    /// autotuner prices when choosing tiles for the executing kernels.
    pub fn problem(&self) -> crate::attn::AttnProblem {
        crate::attn::AttnProblem {
            batch: self.batch as u64,
            heads: self.heads as u64,
            seqlen: self.seq as u64,
            head_dim: self.head_dim as u64,
            causal: self.causal,
            dtype_bytes: 4, // f32 (irrelevant to the FLOP count)
        }
    }

    /// Executed FLOPs under the paper's §4.1 accounting — delegates to
    /// [`AttnProblem::reported_flops`] so the formula lives in one place.
    ///
    /// [`AttnProblem::reported_flops`]: crate::attn::AttnProblem::reported_flops
    pub fn flops(&self, pass: Pass) -> f64 {
        self.problem().reported_flops(pass)
    }
}

/// Borrowed row-major (batch, heads, seq, head_dim) view over a flat f32
/// buffer — the layout shared by every kernel in this subsystem.
#[derive(Clone, Copy)]
pub struct TensorView<'a> {
    pub dims: AttnDims,
    data: &'a [f32],
}

impl<'a> TensorView<'a> {
    pub fn new(dims: AttnDims, data: &'a [f32]) -> TensorView<'a> {
        // fa2lint: allow(kernel-release-assert) -- once-per-view API-boundary shape check, not an inner-loop invariant
        assert_eq!(
            data.len(),
            dims.elems(),
            "TensorView: buffer length does not match {dims:?}"
        );
        TensorView { dims, data }
    }

    /// Row `i` of head (b, h): a contiguous `head_dim` slice.
    pub fn row(&self, b: usize, h: usize, i: usize) -> &'a [f32] {
        let o = self.dims.row_offset(b, h, i);
        &self.data[o..o + self.dims.head_dim]
    }

    /// The contiguous (seq, head_dim) block of head (b, h).
    pub fn head(&self, b: usize, h: usize) -> &'a [f32] {
        let o = self.dims.row_offset(b, h, 0);
        &self.data[o..o + self.dims.seq * self.dims.head_dim]
    }

    pub fn data(&self) -> &'a [f32] {
        self.data
    }
}

/// Tile sizes for the flash kernels (B_r × B_c in the paper's notation).
/// Any positive sizes are correct — seqlens need not divide them.
#[derive(Debug, Clone, Copy)]
pub struct FlashParams {
    pub block_q: usize,
    pub block_k: usize,
}

impl Default for FlashParams {
    fn default() -> Self {
        FlashParams { block_q: 64, block_k: 64 }
    }
}

impl FlashParams {
    /// The tile `attn::autotune` picks for this problem on the cost model
    /// — the executing engine and the cost model agree on tiling instead
    /// of the exec call sites hardcoding the 64×64 default.
    pub fn tuned(dims: AttnDims, pass: Pass) -> FlashParams {
        crate::attn::autotune::exec_params(&dims.problem(), pass)
    }
}

/// Forward products: O shaped like Q, plus the per-row logsumexp — the
/// only softmax statistic the backward pass needs (§3.1).
#[derive(Debug, Clone, PartialEq)]
pub struct FlashOut {
    pub o: Vec<f32>,
    pub lse: Vec<f32>,
}

/// Backward products, each shaped like the corresponding input.
#[derive(Debug, Clone, PartialEq)]
pub struct FlashGrads {
    pub dq: Vec<f32>,
    pub dk: Vec<f32>,
    pub dv: Vec<f32>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_offsets_and_flops() {
        let d = AttnDims { batch: 2, heads: 3, seq: 5, head_dim: 4, causal: false };
        assert_eq!(d.elems(), 2 * 3 * 5 * 4);
        assert_eq!(d.rows(), 2 * 3 * 5);
        assert_eq!(d.row_offset(0, 0, 0), 0);
        assert_eq!(d.row_offset(1, 2, 4), ((1 * 3 + 2) * 5 + 4) * 4);
        assert_eq!(d.lse_offset(1, 0, 3), (1 * 3) * 5 + 3);
        let f = d.flops(Pass::Fwd);
        assert_eq!(f, 4.0 * 25.0 * 4.0 * 6.0);
        assert_eq!(d.flops(Pass::Bwd), 2.5 * f);
        let dc = AttnDims { causal: true, ..d };
        assert_eq!(dc.flops(Pass::Fwd), f / 2.0);
    }

    #[test]
    fn exec_modes_agree_and_report_stats() {
        use crate::attn::spec::{HeadMap, Mask};
        let spec = AttnSpec {
            batch: 1,
            heads: HeadMap::mha(2),
            seq: 48,
            head_dim: 8,
            mask: Mask::Causal,
        };
        let mut rng = crate::util::rng::Rng::seed_from(42);
        let mut gen = |n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.normal() as f32).collect()
        };
        let q = gen(spec.q_elems());
        let k = gen(spec.kv_elems());
        let v = gen(spec.kv_elems());
        let dout = gen(spec.q_elems());
        let p = FlashParams { block_q: 16, block_k: 16 };
        let (fan, none) =
            forward_spec_mode(ExecMode::Fanned { workers: 2 }, &q, &k, &v, spec, p)
                .expect("fanned fwd");
        assert!(none.is_none(), "fanned mode has no ring stats");
        let (ring, stats) =
            forward_spec_mode(ExecMode::SeqParallel { workers: 3 }, &q, &k, &v, spec, p)
                .expect("seqpar fwd");
        let stats = stats.expect("seqpar mode reports ring stats");
        assert_eq!(stats.workers, 3);
        for (a, b) in fan.o.iter().zip(&ring.o) {
            assert!((a - b).abs() < 1e-4, "modes disagree on O");
        }
        for (a, b) in fan.lse.iter().zip(&ring.lse) {
            assert!((a - b).abs() < 1e-4, "modes disagree on LSE");
        }
        let (gf, _) = backward_spec_mode(
            ExecMode::Fanned { workers: 2 }, &q, &k, &v, &fan, &dout, spec, p,
        )
        .expect("fanned bwd");
        let (gr, _) = backward_spec_mode(
            ExecMode::SeqParallel { workers: 3 }, &q, &k, &v, &ring, &dout, spec, p,
        )
        .expect("seqpar bwd");
        for (a, b) in gf.dq.iter().zip(&gr.dq) {
            assert!((a - b).abs() < 1e-4, "modes disagree on dQ");
        }
        for (a, b) in gf.dk.iter().zip(&gr.dk) {
            assert!((a - b).abs() < 1e-4, "modes disagree on dK");
        }
        for (a, b) in gf.dv.iter().zip(&gr.dv) {
            assert!((a - b).abs() < 1e-4, "modes disagree on dV");
        }
    }

    #[test]
    fn view_rows_are_contiguous_slices() {
        let d = AttnDims { batch: 1, heads: 2, seq: 3, head_dim: 2, causal: false };
        let data: Vec<f32> = (0..d.elems()).map(|x| x as f32).collect();
        let v = TensorView::new(d, &data);
        assert_eq!(v.row(0, 0, 0), &[0.0, 1.0]);
        assert_eq!(v.row(0, 1, 2), &[10.0, 11.0]);
        assert_eq!(v.head(0, 1).len(), 6);
        assert_eq!(v.head(0, 1)[0], 6.0);
    }
}
