//! In-process ring transport for sequence-parallel attention (DESIGN.md
//! §16).
//!
//! A ring of W endpoints, one per `util::pool` worker: endpoint `w` sends
//! to its right neighbor `(w + 1) % W` and receives from its left neighbor
//! `(w − 1) % W` — the classic ring-collective wiring, realized as W
//! `std::sync::mpsc` channels (std-only; no sockets, no shared-memory
//! tricks).  Channels are unbounded, so sends never block; receives block
//! until the left neighbor forwards, which is exactly the per-step
//! synchronization a KV-rotation schedule needs — no extra barrier.
//!
//! Every endpoint meters itself: messages and payload bytes sent, and
//! nanoseconds spent blocked in `recv` (the transport-visible share of
//! worker idle time).  `seqpar` aggregates these [`LinkStats`] into the
//! `seqpar_*` observability counters, and the same byte accounting is what
//! the `gpusim::comm` cost model is calibrated against.
//!
//! Failure model: a ring neighbor can only disappear if its worker task
//! died, so `send_next`/`recv` surface disconnections as `Result` errors
//! instead of panicking (this module is inside the `no-hotpath-panic` lint
//! scope).  A healthy schedule never sees them: the seqpar plan computes,
//! per shard, exactly how many hops it travels, and every endpoint runs
//! the same plan.

use std::sync::mpsc;
use std::time::Instant;

use crate::util::error::{Error, Result};

/// Per-endpoint transport meters, readable after the worker loop ends.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LinkStats {
    /// Messages sent to the right neighbor.
    pub sends: u64,
    /// Payload bytes sent (as declared by the caller per send).
    pub sent_bytes: u64,
    /// Messages received from the left neighbor.
    pub recvs: u64,
    /// Nanoseconds spent blocked inside `recv` waiting for the neighbor.
    pub recv_idle_ns: u64,
}

/// One worker's pair of ring links: a sender to the right neighbor and a
/// receiver from the left one, plus the meters.
pub struct RingEndpoint<T> {
    rank: usize,
    workers: usize,
    tx: mpsc::Sender<T>,
    rx: mpsc::Receiver<T>,
    stats: LinkStats,
}

impl<T: Send> RingEndpoint<T> {
    /// This endpoint's position on the ring.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Ring size W.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Send `msg` to the right neighbor, accounting `bytes` payload bytes.
    /// Errors only if the neighbor's endpoint was dropped (its worker
    /// died) — never blocks.
    pub fn send_next(&mut self, msg: T, bytes: usize) -> Result<()> {
        self.tx.send(msg).map_err(|_| {
            Error::msg(format!("ring worker {}: right neighbor hung up", self.rank))
        })?;
        self.stats.sends += 1;
        self.stats.sent_bytes += bytes as u64;
        Ok(())
    }

    /// Block until the left neighbor sends, metering the wait as idle
    /// time.  Errors if the neighbor's endpoint was dropped mid-schedule.
    pub fn recv(&mut self) -> Result<T> {
        let t0 = Instant::now();
        let msg = self.rx.recv().map_err(|_| {
            Error::msg(format!("ring worker {}: left neighbor hung up", self.rank))
        })?;
        self.stats.recv_idle_ns += t0.elapsed().as_nanos() as u64;
        self.stats.recvs += 1;
        Ok(msg)
    }

    /// The meters accumulated so far.
    pub fn stats(&self) -> LinkStats {
        self.stats
    }
}

/// Build a ring of `workers` endpoints (`workers == 0` yields an empty
/// vec; `workers == 1` is a self-loop that a correct schedule never
/// sends on).  Endpoint `w` must be moved to pool worker `w`.
pub fn ring<T: Send>(workers: usize) -> Vec<RingEndpoint<T>> {
    // chans[w] delivers TO worker w; endpoint w keeps chans[w]'s receiver
    // and a sender for chans[(w + 1) % W].
    let chans: Vec<(mpsc::Sender<T>, mpsc::Receiver<T>)> =
        (0..workers).map(|_| mpsc::channel()).collect();
    let txs: Vec<mpsc::Sender<T>> = chans.iter().map(|c| c.0.clone()).collect();
    chans
        .into_iter()
        .enumerate()
        .map(|(w, (_tx, rx))| RingEndpoint {
            rank: w,
            workers,
            tx: txs[(w + 1) % workers].clone(),
            rx,
            stats: LinkStats::default(),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pool;

    #[test]
    fn tokens_complete_a_full_rotation() {
        // Each worker injects its rank and forwards whatever arrives for
        // W-1 steps; after the loop every worker has seen every token and
        // holds its own again.
        let w = 4;
        let eps = ring::<usize>(w);
        let seen = pool::par_map_with(
            w,
            eps.into_iter().collect::<Vec<_>>(),
            |mut ep| -> Result<(Vec<usize>, LinkStats)> {
                let mut held = ep.rank();
                let mut seen = vec![held];
                for _ in 0..ep.workers() - 1 {
                    ep.send_next(held, 8)?;
                    held = ep.recv()?;
                    seen.push(held);
                }
                // one more hop brings the original token home
                ep.send_next(held, 8)?;
                held = ep.recv()?;
                assert_eq!(held, ep.rank(), "token failed to come home");
                Ok((seen, ep.stats()))
            },
        );
        for (rank, r) in seen.into_iter().enumerate() {
            let (seen, stats) = r.expect("ring worker failed");
            // worker w sees w, w-1, w-2, ... (tokens rotate rightward)
            let want: Vec<usize> = (0..w).map(|t| (rank + w - t) % w).collect();
            assert_eq!(seen, want, "worker {rank} saw tokens out of order");
            assert_eq!(stats.sends, w as u64);
            assert_eq!(stats.recvs, w as u64);
            assert_eq!(stats.sent_bytes, 8 * w as u64);
        }
    }

    #[test]
    fn disconnection_is_an_error_not_a_hang() {
        let mut eps = ring::<u8>(2);
        let b = eps.pop().expect("two endpoints");
        let mut a = eps.pop().expect("two endpoints");
        drop(b); // worker 1 "dies": its receiver and sender both drop
        assert!(a.send_next(1, 1).is_err(), "send to a dead neighbor must error");
        assert!(a.recv().is_err(), "recv from a dead neighbor must error");
    }

    #[test]
    fn empty_and_self_rings_construct() {
        assert!(ring::<u8>(0).is_empty());
        let mut solo = ring::<u8>(1);
        assert_eq!(solo.len(), 1);
        // a self-loop is wired but unused by any correct 1-worker schedule
        let ep = &mut solo[0];
        assert_eq!(ep.rank(), 0);
        assert_eq!(ep.workers(), 1);
        assert_eq!(ep.stats(), LinkStats::default());
    }
}
