//! Naive O(N²) attention — the correctness oracle for the flash kernels.
//!
//! Materializes each score row, computes the softmax the straightforward
//! way, and accumulates in f64 so the flash kernels' f32 results can be
//! held to a tight tolerance (DESIGN.md §7: parity within 1e-4).  Inputs
//! and outputs are f32 in the shared (batch, heads, seq, head_dim) layout;
//! the softmax scale is the same f32 `1/sqrt(d)` the flash kernels use so
//! the two paths compute the *same* math, not merely similar math.

use super::{AttnDims, FlashGrads, FlashOut, TensorView};

fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// Standard attention forward: O = softmax(scale·QKᵀ + mask)·V, plus the
/// per-row logsumexp (what the flash forward saves for the backward).
pub fn forward(q: &[f32], k: &[f32], v: &[f32], dims: AttnDims) -> FlashOut {
    let (qv, kv, vv) = (
        TensorView::new(dims, q),
        TensorView::new(dims, k),
        TensorView::new(dims, v),
    );
    let (n, d) = (dims.seq, dims.head_dim);
    let scale = dims.scale() as f64;
    let mut out = FlashOut {
        o: vec![0.0; dims.elems()],
        lse: vec![0.0; dims.rows()],
    };
    let mut scores = vec![0.0f64; n];
    for b in 0..dims.batch {
        for h in 0..dims.heads {
            for i in 0..n {
                let qi = qv.row(b, h, i);
                let lim = if dims.causal { i + 1 } else { n };
                let mut m = f64::NEG_INFINITY;
                for (j, s) in scores[..lim].iter_mut().enumerate() {
                    *s = scale * dot_f64(qi, kv.row(b, h, j));
                    m = m.max(*s);
                }
                let mut l = 0.0f64;
                let mut acc = vec![0.0f64; d];
                for j in 0..lim {
                    let w = (scores[j] - m).exp();
                    l += w;
                    for (a, &x) in acc.iter_mut().zip(vv.row(b, h, j)) {
                        *a += w * x as f64;
                    }
                }
                let orow = dims.row_offset(b, h, i);
                for (t, a) in acc.iter().enumerate() {
                    out.o[orow + t] = (a / l) as f32;
                }
                out.lse[dims.lse_offset(b, h, i)] = (m + l.ln()) as f32;
            }
        }
    }
    out
}

/// Standard attention backward: recomputes P row by row and applies the
/// softmax chain rule.  `dout` is dL/dO shaped like Q.
pub fn backward(q: &[f32], k: &[f32], v: &[f32], dout: &[f32], dims: AttnDims) -> FlashGrads {
    let (qv, kv, vv, dov) = (
        TensorView::new(dims, q),
        TensorView::new(dims, k),
        TensorView::new(dims, v),
        TensorView::new(dims, dout),
    );
    let (n, d) = (dims.seq, dims.head_dim);
    let scale = dims.scale() as f64;
    let elems = dims.elems();
    let mut dq = vec![0.0f64; elems];
    let mut dk = vec![0.0f64; elems];
    let mut dv = vec![0.0f64; elems];
    let mut p = vec![0.0f64; n];
    let mut dp = vec![0.0f64; n];
    for b in 0..dims.batch {
        for h in 0..dims.heads {
            for i in 0..n {
                let qi = qv.row(b, h, i);
                let doi = dov.row(b, h, i);
                let lim = if dims.causal { i + 1 } else { n };
                let mut m = f64::NEG_INFINITY;
                for (j, s) in p[..lim].iter_mut().enumerate() {
                    *s = scale * dot_f64(qi, kv.row(b, h, j));
                    m = m.max(*s);
                }
                let mut l = 0.0f64;
                for s in p[..lim].iter_mut() {
                    *s = (*s - m).exp();
                    l += *s;
                }
                for s in p[..lim].iter_mut() {
                    *s /= l;
                }
                // dP_j = dO·V_j ;  D = Σ_j P_j dP_j ;  dS_j = P_j (dP_j − D)
                let mut dsum = 0.0f64;
                for j in 0..lim {
                    dp[j] = dot_f64(doi, vv.row(b, h, j));
                    dsum += p[j] * dp[j];
                }
                for j in 0..lim {
                    let ds = p[j] * (dp[j] - dsum) * scale;
                    let kj = kv.row(b, h, j);
                    let qrow = dims.row_offset(b, h, i);
                    let krow = dims.row_offset(b, h, j);
                    for t in 0..d {
                        dq[qrow + t] += ds * kj[t] as f64;
                        dk[krow + t] += ds * qi[t] as f64;
                        dv[krow + t] += p[j] * doi[t] as f64;
                    }
                }
            }
        }
    }
    FlashGrads {
        dq: dq.into_iter().map(|x| x as f32).collect(),
        dk: dk.into_iter().map(|x| x as f32).collect(),
        dv: dv.into_iter().map(|x| x as f32).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_scores_average_values() {
        // Q = 0 ⇒ all scores 0 ⇒ O is the plain mean of V rows.
        let dims = AttnDims { batch: 1, heads: 1, seq: 3, head_dim: 2, causal: false };
        let q = vec![0.0; dims.elems()];
        let k = vec![1.0; dims.elems()];
        let v = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let out = forward(&q, &k, &v, dims);
        assert!((out.o[0] - 3.0).abs() < 1e-6);
        assert!((out.o[1] - 4.0).abs() < 1e-6);
        // lse = ln(3) for three zero scores
        assert!((out.lse[0] - 3.0f32.ln()).abs() < 1e-6);
    }

    #[test]
    fn causal_first_row_copies_v0() {
        let dims = AttnDims { batch: 1, heads: 1, seq: 3, head_dim: 2, causal: true };
        let q: Vec<f32> = (0..dims.elems()).map(|x| x as f32 * 0.1).collect();
        let k = q.clone();
        let v = vec![7.0, -2.0, 1.0, 1.0, 1.0, 1.0];
        let out = forward(&q, &k, &v, dims);
        assert!((out.o[0] - 7.0).abs() < 1e-6);
        assert!((out.o[1] + 2.0).abs() < 1e-6);
    }

    #[test]
    fn backward_shapes_and_finiteness() {
        let dims = AttnDims { batch: 1, heads: 2, seq: 4, head_dim: 3, causal: true };
        let mut rng = crate::util::rng::Rng::seed_from(9);
        let n = dims.elems();
        let gen = |rng: &mut crate::util::rng::Rng| -> Vec<f32> {
            (0..n).map(|_| rng.normal() as f32).collect()
        };
        let (q, k, v, dout) = (gen(&mut rng), gen(&mut rng), gen(&mut rng), gen(&mut rng));
        let g = backward(&q, &k, &v, &dout, dims);
        assert_eq!(g.dq.len(), n);
        assert_eq!(g.dk.len(), n);
        assert_eq!(g.dv.len(), n);
        assert!(g.dq.iter().chain(&g.dk).chain(&g.dv).all(|x| x.is_finite()));
    }
}
