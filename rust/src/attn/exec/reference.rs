//! Naive O(N²) attention — the correctness oracle for the flash kernels.
//!
//! Materializes each score row, computes the softmax the straightforward
//! way, and accumulates in f64 so the flash kernels' f32 results can be
//! held to a tight tolerance (DESIGN.md §7: parity within 1e-4).  Inputs
//! and outputs are f32; the softmax scale is the same f32 `1/sqrt(d)` the
//! flash kernels use so the two paths compute the *same* math, not merely
//! similar math.
//!
//! The oracle is extended FIRST for every axis of [`AttnSpec`]
//! (DESIGN.md §11): grouped-query head broadcast and the full/causal/
//! sliding-window masks are all spelled out here in the obvious row-wise
//! form, and the flash paths are verified against it.

use crate::attn::spec::AttnSpec;

use super::{AttnDims, FlashGrads, FlashOut, TensorView};

fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// Standard attention forward under the spec: O = softmax(scale·QKᵀ +
/// mask)·V with grouped-query broadcast, plus the per-Q-row logsumexp.
/// `q` is `(B, n_q_heads, N, d)`; `k`/`v` are `(B, n_kv_heads, N, d)`.
pub fn forward_spec(q: &[f32], k: &[f32], v: &[f32], spec: AttnSpec) -> FlashOut {
    let qd = spec.q_dims();
    let kd = spec.kv_dims();
    let (qv, kv, vv) = (
        TensorView::new(qd, q),
        TensorView::new(kd, k),
        TensorView::new(kd, v),
    );
    let (n, d) = (spec.seq, spec.head_dim);
    let scale = spec.scale() as f64;
    let mut out = FlashOut {
        o: vec![0.0; spec.q_elems()],
        lse: vec![0.0; spec.q_rows()],
    };
    let mut scores = vec![0.0f64; n];
    for b in 0..spec.batch {
        for h in 0..spec.heads.n_q_heads {
            let g = spec.heads.kv_head(h);
            for i in 0..n {
                let qi = qv.row(b, h, i);
                let (lo, hi) = spec.mask.row_bounds(i, n);
                let mut m = f64::NEG_INFINITY;
                for j in lo..hi {
                    scores[j] = scale * dot_f64(qi, kv.row(b, g, j));
                    m = m.max(scores[j]);
                }
                let mut l = 0.0f64;
                let mut acc = vec![0.0f64; d];
                for j in lo..hi {
                    let w = (scores[j] - m).exp();
                    l += w;
                    for (a, &x) in acc.iter_mut().zip(vv.row(b, g, j)) {
                        *a += w * x as f64;
                    }
                }
                let orow = qd.row_offset(b, h, i);
                for (t, a) in acc.iter().enumerate() {
                    out.o[orow + t] = (a / l) as f32;
                }
                out.lse[qd.lse_offset(b, h, i)] = (m + l.ln()) as f32;
            }
        }
    }
    out
}

/// Standard attention backward under the spec: recomputes P row by row
/// and applies the softmax chain rule.  `dout` is dL/dO shaped like Q;
/// `dq` is Q-shaped, `dk`/`dv` are KV-shaped (each KV head accumulates
/// the gradients of every query head in its group).
pub fn backward_spec(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dout: &[f32],
    spec: AttnSpec,
) -> FlashGrads {
    let qd = spec.q_dims();
    let kd = spec.kv_dims();
    let (qv, kv, vv, dov) = (
        TensorView::new(qd, q),
        TensorView::new(kd, k),
        TensorView::new(kd, v),
        TensorView::new(qd, dout),
    );
    let (n, d) = (spec.seq, spec.head_dim);
    let scale = spec.scale() as f64;
    let mut dq = vec![0.0f64; spec.q_elems()];
    let mut dk = vec![0.0f64; spec.kv_elems()];
    let mut dv = vec![0.0f64; spec.kv_elems()];
    let mut p = vec![0.0f64; n];
    let mut dp = vec![0.0f64; n];
    for b in 0..spec.batch {
        for h in 0..spec.heads.n_q_heads {
            let g = spec.heads.kv_head(h);
            for i in 0..n {
                let qi = qv.row(b, h, i);
                let doi = dov.row(b, h, i);
                let (lo, hi) = spec.mask.row_bounds(i, n);
                let cols = hi - lo;
                let mut m = f64::NEG_INFINITY;
                for (j, s) in p[..cols].iter_mut().enumerate() {
                    *s = scale * dot_f64(qi, kv.row(b, g, lo + j));
                    m = m.max(*s);
                }
                let mut l = 0.0f64;
                for s in p[..cols].iter_mut() {
                    *s = (*s - m).exp();
                    l += *s;
                }
                for s in p[..cols].iter_mut() {
                    *s /= l;
                }
                // dP_j = dO·V_j ;  D = Σ_j P_j dP_j ;  dS_j = P_j (dP_j − D)
                let mut dsum = 0.0f64;
                for c in 0..cols {
                    dp[c] = dot_f64(doi, vv.row(b, g, lo + c));
                    dsum += p[c] * dp[c];
                }
                for c in 0..cols {
                    let j = lo + c;
                    let ds = p[c] * (dp[c] - dsum) * scale;
                    let kj = kv.row(b, g, j);
                    let qrow = qd.row_offset(b, h, i);
                    let krow = kd.row_offset(b, g, j);
                    for t in 0..d {
                        dq[qrow + t] += ds * kj[t] as f64;
                        dk[krow + t] += ds * qi[t] as f64;
                        dv[krow + t] += p[c] * doi[t] as f64;
                    }
                }
            }
        }
    }
    FlashGrads {
        dq: dq.into_iter().map(|x| x as f32).collect(),
        dk: dk.into_iter().map(|x| x as f32).collect(),
        dv: dv.into_iter().map(|x| x as f32).collect(),
    }
}

/// Standard attention forward in the seed-era equal-heads API (wrapper
/// over [`forward_spec`] with `AttnSpec::from_dims`).
pub fn forward(q: &[f32], k: &[f32], v: &[f32], dims: AttnDims) -> FlashOut {
    forward_spec(q, k, v, AttnSpec::from_dims(dims))
}

/// Standard attention backward in the seed-era equal-heads API.
pub fn backward(q: &[f32], k: &[f32], v: &[f32], dout: &[f32], dims: AttnDims) -> FlashGrads {
    backward_spec(q, k, v, dout, AttnSpec::from_dims(dims))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::spec::{HeadMap, Mask};

    #[test]
    fn uniform_scores_average_values() {
        // Q = 0 ⇒ all scores 0 ⇒ O is the plain mean of V rows.
        let dims = AttnDims { batch: 1, heads: 1, seq: 3, head_dim: 2, causal: false };
        let q = vec![0.0; dims.elems()];
        let k = vec![1.0; dims.elems()];
        let v = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let out = forward(&q, &k, &v, dims);
        assert!((out.o[0] - 3.0).abs() < 1e-6);
        assert!((out.o[1] - 4.0).abs() < 1e-6);
        // lse = ln(3) for three zero scores
        assert!((out.lse[0] - 3.0f32.ln()).abs() < 1e-6);
    }

    #[test]
    fn causal_first_row_copies_v0() {
        let dims = AttnDims { batch: 1, heads: 1, seq: 3, head_dim: 2, causal: true };
        let q: Vec<f32> = (0..dims.elems()).map(|x| x as f32 * 0.1).collect();
        let k = q.clone();
        let v = vec![7.0, -2.0, 1.0, 1.0, 1.0, 1.0];
        let out = forward(&q, &k, &v, dims);
        assert!((out.o[0] - 7.0).abs() < 1e-6);
        assert!((out.o[1] + 2.0).abs() < 1e-6);
    }

    #[test]
    fn window_one_copies_own_value_row() {
        // w = 1: every row attends only to itself, so O = V exactly.
        let spec = AttnSpec {
            batch: 1,
            heads: HeadMap::mha(2),
            seq: 4,
            head_dim: 3,
            mask: Mask::SlidingWindow(1),
        };
        let mut rng = crate::util::rng::Rng::seed_from(5);
        let n = spec.q_elems();
        let gen = |rng: &mut crate::util::rng::Rng| -> Vec<f32> {
            (0..n).map(|_| rng.normal() as f32).collect()
        };
        let (q, k, v) = (gen(&mut rng), gen(&mut rng), gen(&mut rng));
        let out = forward_spec(&q, &k, &v, spec);
        for (o, x) in out.o.iter().zip(&v) {
            assert!((o - x).abs() < 1e-6, "window-1 must copy V");
        }
    }

    #[test]
    fn gqa_broadcast_equals_replicated_kv_heads() {
        // GQA with n_kv = 1 must equal MHA where the single KV head is
        // replicated across all query heads.
        let spec = AttnSpec {
            batch: 1,
            heads: HeadMap { n_q_heads: 4, n_kv_heads: 1 },
            seq: 6,
            head_dim: 4,
            mask: Mask::Causal,
        };
        let mut rng = crate::util::rng::Rng::seed_from(6);
        let gen = |rng: &mut crate::util::rng::Rng, n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.normal() as f32).collect()
        };
        let q = gen(&mut rng, spec.q_elems());
        let k1 = gen(&mut rng, spec.kv_elems());
        let v1 = gen(&mut rng, spec.kv_elems());
        let gqa = forward_spec(&q, &k1, &v1, spec);
        // replicate the KV head 4× and run equal-heads
        let rep = |x: &[f32]| -> Vec<f32> { x.repeat(4) };
        let dense = AttnSpec { heads: HeadMap::mha(4), ..spec };
        let mha = forward_spec(&q, &rep(&k1), &rep(&v1), dense);
        assert_eq!(gqa.o, mha.o, "GQA broadcast must equal replicated KV");
        assert_eq!(gqa.lse, mha.lse);
    }

    #[test]
    fn gqa_backward_accumulates_the_group() {
        // dK/dV of the shared KV head must equal the SUM over the
        // replicated-head gradients.
        let spec = AttnSpec {
            batch: 1,
            heads: HeadMap { n_q_heads: 2, n_kv_heads: 1 },
            seq: 4,
            head_dim: 3,
            mask: Mask::SlidingWindow(2),
        };
        let mut rng = crate::util::rng::Rng::seed_from(7);
        let gen = |rng: &mut crate::util::rng::Rng, n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.normal() as f32).collect()
        };
        let q = gen(&mut rng, spec.q_elems());
        let k1 = gen(&mut rng, spec.kv_elems());
        let v1 = gen(&mut rng, spec.kv_elems());
        let dout = gen(&mut rng, spec.q_elems());
        let g = backward_spec(&q, &k1, &v1, &dout, spec);
        let dense = AttnSpec { heads: HeadMap::mha(2), ..spec };
        let gm = backward_spec(&q, &k1.repeat(2), &v1.repeat(2), &dout, dense);
        assert_eq!(g.dq, gm.dq);
        let per = spec.kv_elems();
        for t in 0..per {
            let want = gm.dk[t] + gm.dk[per + t];
            assert!((g.dk[t] - want).abs() < 1e-5, "dK[{t}]");
            let want = gm.dv[t] + gm.dv[per + t];
            assert!((g.dv[t] - want).abs() < 1e-5, "dV[{t}]");
        }
    }

    #[test]
    fn backward_shapes_and_finiteness() {
        let dims = AttnDims { batch: 1, heads: 2, seq: 4, head_dim: 3, causal: true };
        let mut rng = crate::util::rng::Rng::seed_from(9);
        let n = dims.elems();
        let gen = |rng: &mut crate::util::rng::Rng| -> Vec<f32> {
            (0..n).map(|_| rng.normal() as f32).collect()
        };
        let (q, k, v, dout) = (gen(&mut rng), gen(&mut rng), gen(&mut rng), gen(&mut rng));
        let g = backward(&q, &k, &v, &dout, dims);
        assert_eq!(g.dq.len(), n);
        assert_eq!(g.dk.len(), n);
        assert_eq!(g.dv.len(), n);
        assert!(g.dq.iter().chain(&g.dk).chain(&g.dv).all(|x| x.is_finite()));
    }
}
