//! §3.2 work partitioning for the native kernels, on `util::pool`,
//! dispatched on [`AttnSpec`].
//!
//! Forward fans one task per (batch, Q-head, Q-block); backward fans one
//! per (batch, KV-head, K-block) — exactly the grid dimensions the paper
//! adds over FlashAttention-1 to fill the machine when batch·heads alone
//! is too small, and under GQA the backward grid naturally owns each KV
//! head's dK/dV exclusively (every query head of the group accumulates
//! inside one task).  `par_map` returns results in input order, and dQ
//! partials are summed in fixed task order, so any worker count produces
//! byte-identical outputs (`FA2_POOL_THREADS=1` is the serial A/B switch,
//! as for the sweeps).
//!
//! The split-KV decode path is the flash-decoding shape: one query row
//! against a long KV history, cut into chunks whose partial softmax states
//! reduce through `attn::combine` — the same associative merge the warp
//! split-K exchange (§3.3) relies on.  [`decode_splitkv_spec`] is the
//! layout-polymorphic core: it streams a [`KvLayout`] (contiguous run or
//! paged block table) over an absolute row range with chunk boundaries
//! aligned to absolute multiples of the chunk size, so paged and
//! contiguous decode of the same history are **bit-identical** whenever
//! their chunk sizes agree, and a sliding window's out-of-range blocks
//! are never touched.  The streaming variants reuse two `Partial`s and
//! never allocate per chunk; [`decode_splitkv_fanned`] computes chunk
//! partials on the pool and reduces them with `merge_all`.

use crate::attn::combine::{merge_all, Partial};
use crate::attn::spec::{AttnSpec, KvLayout};
use crate::util::pool;

use super::{flash_bwd, flash_fwd, AttnDims, FlashGrads, FlashOut, FlashParams, TensorView};

/// One task per (b, h, block) where `h` counts `heads` and `block` tiles
/// `0..seq` by `step`.
fn block_tasks(
    batch: usize,
    heads: usize,
    seq: usize,
    step: usize,
) -> Vec<(usize, usize, usize, usize)> {
    let step = step.max(1);
    let mut tasks = Vec::new();
    for b in 0..batch {
        for h in 0..heads {
            let mut lo = 0;
            while lo < seq {
                let hi = (lo + step).min(seq);
                tasks.push((b, h, lo, hi));
                lo = hi;
            }
        }
    }
    tasks
}

/// Flash forward over the whole tensor under the spec, fanned across the
/// pool.
pub fn forward_spec(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    spec: AttnSpec,
    p: FlashParams,
) -> FlashOut {
    forward_spec_with(pool::threads(), q, k, v, spec, p)
}

/// [`forward_spec`] with an explicit worker count (1 = serial; benches
/// and the byte-identical A/B tests pin this).
pub fn forward_spec_with(
    workers: usize,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    spec: AttnSpec,
    p: FlashParams,
) -> FlashOut {
    let _sp = crate::obs_span!("attn_flash_fwd");
    let t0 = std::time::Instant::now();
    let qd = spec.q_dims();
    let kd = spec.kv_dims();
    let qv = TensorView::new(qd, q);
    let kv = TensorView::new(kd, k);
    let vv = TensorView::new(kd, v);
    let tasks = block_tasks(spec.batch, spec.heads.n_q_heads, spec.seq, p.block_q);
    let tiles = pool::par_map_with(workers, tasks.clone(), |(b, h, q0, q1)| {
        flash_fwd::forward_tile(qv, kv, vv, spec, p, b, h, q0, q1)
    });
    let d = spec.head_dim;
    let mut out = FlashOut { o: vec![0.0; spec.q_elems()], lse: vec![0.0; spec.q_rows()] };
    for ((b, h, q0, q1), (ot, lt)) in tasks.into_iter().zip(tiles) {
        let ro = qd.row_offset(b, h, q0);
        out.o[ro..ro + (q1 - q0) * d].copy_from_slice(&ot);
        let lo = qd.lse_offset(b, h, q0);
        out.lse[lo..lo + (q1 - q0)].copy_from_slice(&lt);
    }
    crate::obs_count!("flash_fwd_flops_total", qd.flops(crate::attn::Pass::Fwd));
    crate::obs_count!("flash_fwd_ns_total", t0.elapsed().as_nanos());
    out
}

/// Flash forward in the seed-era equal-heads API.
pub fn forward(q: &[f32], k: &[f32], v: &[f32], dims: AttnDims, p: FlashParams) -> FlashOut {
    forward_with(pool::threads(), q, k, v, dims, p)
}

/// [`forward`] with an explicit worker count.
pub fn forward_with(
    workers: usize,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dims: AttnDims,
    p: FlashParams,
) -> FlashOut {
    forward_spec_with(workers, q, k, v, AttnSpec::from_dims(dims), p)
}

/// Flash backward over the whole tensor under the spec, fanned across the
/// pool.  `fwd` is the forward's output (O for the D vector, LSE to
/// recompute P).  `dq` is Q-shaped; `dk`/`dv` are KV-shaped.
pub fn backward_spec(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    fwd: &FlashOut,
    dout: &[f32],
    spec: AttnSpec,
    p: FlashParams,
) -> FlashGrads {
    backward_spec_with(pool::threads(), q, k, v, fwd, dout, spec, p)
}

/// [`backward_spec`] with an explicit worker count.
#[allow(clippy::too_many_arguments)]
pub fn backward_spec_with(
    workers: usize,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    fwd: &FlashOut,
    dout: &[f32],
    spec: AttnSpec,
    p: FlashParams,
) -> FlashGrads {
    let _sp = crate::obs_span!("attn_flash_bwd");
    let t0 = std::time::Instant::now();
    let qd = spec.q_dims();
    let kd = spec.kv_dims();
    let qv = TensorView::new(qd, q);
    let kv = TensorView::new(kd, k);
    let vv = TensorView::new(kd, v);
    let dov = TensorView::new(qd, dout);
    // fa2lint: allow(kernel-release-assert) -- once-per-call boundary check on caller-supplied forward outputs
    assert_eq!(fwd.o.len(), spec.q_elems(), "forward O length mismatch");
    // fa2lint: allow(kernel-release-assert) -- same boundary check, LSE side
    assert_eq!(fwd.lse.len(), spec.q_rows(), "forward LSE length mismatch");

    // D_i = Σ_t dO_it · O_it, once per tensor (Algorithm 2 line 1)
    let d = spec.head_dim;
    let mut dvec = vec![0.0f32; spec.q_rows()];
    for (r, dv) in dvec.iter_mut().enumerate() {
        let (orow, dorow) = (&fwd.o[r * d..(r + 1) * d], &dout[r * d..(r + 1) * d]);
        let mut acc = 0.0f32;
        for t in 0..d {
            acc += orow[t] * dorow[t];
        }
        *dv = acc;
    }

    let tasks = block_tasks(spec.batch, spec.heads.n_kv_heads, spec.seq, p.block_k);
    let lse = &fwd.lse;
    let dvec_ref = &dvec;

    let mut g = FlashGrads {
        dq: vec![0.0; spec.q_elems()],
        dk: vec![0.0; spec.kv_elems()],
        dv: vec![0.0; spec.kv_elems()],
    };
    // Fan tasks in bounded waves: each task's dQ partial spans up to the
    // whole seqlen per group head, so holding every tile at once would
    // cost O(group·seq²·d/block_k) transient memory on long sequences.
    // dK/dV rows are owned by exactly one task; dQ partials are summed in
    // ascending task order — the order is the same for ANY worker or wave
    // size, so outputs stay byte-identical to serial.
    let wave = workers.max(1) * 4;
    for wave_tasks in tasks.chunks(wave) {
        let tiles = pool::par_map_with(workers, wave_tasks.to_vec(), |(b, kvh, j0, j1)| {
            flash_bwd::backward_tile(qv, kv, vv, lse, dov, dvec_ref, spec, b, kvh, j0, j1)
        });
        for (&(b, kvh, j0, j1), (dk_t, dv_t, q_start, dq_t)) in wave_tasks.iter().zip(tiles) {
            let ro = kd.row_offset(b, kvh, j0);
            g.dk[ro..ro + (j1 - j0) * d].copy_from_slice(&dk_t);
            g.dv[ro..ro + (j1 - j0) * d].copy_from_slice(&dv_t);
            let group = spec.heads.group_size();
            let span = dq_t.len() / (group * d);
            for (gi, h) in spec.heads.q_heads_of(kvh).enumerate() {
                let base = qd.row_offset(b, h, q_start);
                let part = &dq_t[gi * span * d..(gi + 1) * span * d];
                for (x, acc) in part.iter().zip(&mut g.dq[base..base + part.len()]) {
                    *acc += *x;
                }
            }
        }
    }
    crate::obs_count!("flash_bwd_flops_total", qd.flops(crate::attn::Pass::Bwd));
    crate::obs_count!("flash_bwd_ns_total", t0.elapsed().as_nanos());
    g
}

/// Flash backward in the seed-era equal-heads API.
pub fn backward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    fwd: &FlashOut,
    dout: &[f32],
    dims: AttnDims,
    p: FlashParams,
) -> FlashGrads {
    backward_with(pool::threads(), q, k, v, fwd, dout, dims, p)
}

/// [`backward`] with an explicit worker count.
#[allow(clippy::too_many_arguments)]
pub fn backward_with(
    workers: usize,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    fwd: &FlashOut,
    dout: &[f32],
    dims: AttnDims,
    p: FlashParams,
) -> FlashGrads {
    backward_spec_with(workers, q, k, v, fwd, dout, AttnSpec::from_dims(dims), p)
}

/// Fill `out` with the partial softmax state of one KV chunk (`rows`
/// key/value rows of width `d = qrow.len()`), in f64 like `combine`.
/// Allocation-free once `out.o` has capacity `d`.
pub(crate) fn partial_from_chunk(
    out: &mut Partial,
    qrow: &[f32],
    kc: &[f32],
    vc: &[f32],
    scale: f32,
) {
    let d = qrow.len();
    out.o.clear();
    out.o.resize(d, 0.0);
    out.m = f64::NEG_INFINITY;
    out.l = 0.0;
    let rows = kc.len() / d;
    for r in 0..rows {
        let (kr, vr) = (&kc[r * d..(r + 1) * d], &vc[r * d..(r + 1) * d]);
        let mut s = 0.0f64;
        for t in 0..d {
            s += qrow[t] as f64 * kr[t] as f64;
        }
        s *= scale as f64;
        if s > out.m {
            // raise the running max; rescale what we have so far
            let alpha = (out.m - s).exp(); // 0 on the first row
            out.l *= alpha;
            for o in out.o.iter_mut() {
                *o *= alpha;
            }
            out.m = s;
        }
        let w = (s - out.m).exp();
        out.l += w;
        for (o, &x) in out.o.iter_mut().zip(vr) {
            *o += w * x as f64;
        }
    }
}

/// Layout-polymorphic streaming split-KV decode: one query row against
/// the history rows `[lo, hi)` of `kv`, reduced chunk by chunk with
/// `Partial::merge_from` — zero allocations per chunk (the serving decode
/// hot loop).  Chunk boundaries sit at absolute multiples of `chunk`, so
/// a paged layout (chunk = block size) and a contiguous layout chunked
/// the same way produce **bit-identical** results, and rows left of `lo`
/// (a sliding window's expired history) are never read.  Returns
/// (O row, LSE).
pub fn decode_splitkv_spec(
    qrow: &[f32],
    kv: &KvLayout<'_>,
    lo: usize,
    hi: usize,
    scale: f32,
    chunk: usize,
) -> (Vec<f32>, f32) {
    let d = qrow.len();
    let chunk = kv.chunk_tokens(chunk);
    let mut acc = Partial::empty(d);
    let mut tmp = Partial::empty(d);
    let mut t0 = lo;
    while t0 < hi {
        let t1 = hi.min((t0 / chunk + 1) * chunk);
        let (kc, vc) = kv.rows(t0, t1, d);
        partial_from_chunk(&mut tmp, qrow, kc, vc, scale);
        acc.merge_from(&tmp);
        t0 = t1;
    }
    let (o, lse) = acc.finalize();
    (o.into_iter().map(|x| x as f32).collect(), lse as f32)
}

/// Streaming split-KV decode over a contiguous history: one query row
/// against `n` cached KV rows ([`decode_splitkv_spec`] over
/// `KvLayout::Contiguous`, full range).  Returns (O row, LSE).
pub fn decode_splitkv(
    qrow: &[f32],
    k_hist: &[f32],
    v_hist: &[f32],
    n: usize,
    scale: f32,
    chunk: usize,
) -> (Vec<f32>, f32) {
    let d = qrow.len();
    // fa2lint: allow(kernel-release-assert) -- once-per-decode boundary check before slicing the history
    assert!(k_hist.len() >= n * d && v_hist.len() >= n * d, "history too short");
    let kv = KvLayout::Contiguous { k: &k_hist[..n * d], v: &v_hist[..n * d] };
    decode_splitkv_spec(qrow, &kv, 0, n, scale, chunk)
}

/// Fanned split-KV decode: chunk partials computed on the pool, reduced
/// with `merge_all` — the flash-decoding shape, exercising the same
/// merge associativity the §3.3 warp split-K models.
pub fn decode_splitkv_fanned(
    workers: usize,
    qrow: &[f32],
    k_hist: &[f32],
    v_hist: &[f32],
    n: usize,
    scale: f32,
    chunk: usize,
) -> (Vec<f32>, f32) {
    let d = qrow.len();
    // fa2lint: allow(kernel-release-assert) -- once-per-decode boundary check before chunking the history
    assert!(k_hist.len() >= n * d && v_hist.len() >= n * d, "history too short");
    let chunk = chunk.max(1);
    let mut ranges = Vec::new();
    let mut c0 = 0;
    while c0 < n {
        let c1 = (c0 + chunk).min(n);
        ranges.push((c0, c1));
        c0 = c1;
    }
    let parts = pool::par_map_with(workers, ranges, |(c0, c1)| {
        let mut p = Partial::empty(d);
        partial_from_chunk(&mut p, qrow, &k_hist[c0 * d..c1 * d], &v_hist[c0 * d..c1 * d], scale);
        p
    });
    let (o, lse) = merge_all(&parts).finalize();
    (o.into_iter().map(|x| x as f32).collect(), lse as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::spec::{HeadMap, Mask};
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn parallel_forward_is_bitwise_equal_to_serial() {
        let mut rng = Rng::seed_from(77);
        let dims = AttnDims { batch: 2, heads: 3, seq: 37, head_dim: 16, causal: true };
        let n = dims.elems();
        let (q, k, v) = (rand_vec(&mut rng, n), rand_vec(&mut rng, n), rand_vec(&mut rng, n));
        let p = FlashParams { block_q: 8, block_k: 8 };
        let serial = forward_with(1, &q, &k, &v, dims, p);
        let par = forward_with(4, &q, &k, &v, dims, p);
        assert_eq!(serial.o, par.o, "parallel forward diverged from serial");
        assert_eq!(serial.lse, par.lse);
    }

    #[test]
    fn parallel_backward_is_bitwise_equal_to_serial() {
        let mut rng = Rng::seed_from(78);
        let dims = AttnDims { batch: 1, heads: 4, seq: 26, head_dim: 8, causal: false };
        let n = dims.elems();
        let (q, k, v, dout) = (
            rand_vec(&mut rng, n),
            rand_vec(&mut rng, n),
            rand_vec(&mut rng, n),
            rand_vec(&mut rng, n),
        );
        let p = FlashParams { block_q: 8, block_k: 8 };
        let fwd = forward_with(1, &q, &k, &v, dims, p);
        let serial = backward_with(1, &q, &k, &v, &fwd, &dout, dims, p);
        let par = backward_with(4, &q, &k, &v, &fwd, &dout, dims, p);
        assert_eq!(serial.dq, par.dq, "parallel dQ diverged from serial");
        assert_eq!(serial.dk, par.dk);
        assert_eq!(serial.dv, par.dv);
    }

    #[test]
    fn parallel_spec_paths_are_bitwise_equal_to_serial() {
        // GQA + sliding window through the fan-out: the §3.2 partitioning
        // must stay deterministic on the new axes too.
        let mut rng = Rng::seed_from(79);
        let spec = AttnSpec {
            batch: 2,
            heads: HeadMap { n_q_heads: 4, n_kv_heads: 2 },
            seq: 29,
            head_dim: 8,
            mask: Mask::SlidingWindow(7),
        };
        let q = rand_vec(&mut rng, spec.q_elems());
        let k = rand_vec(&mut rng, spec.kv_elems());
        let v = rand_vec(&mut rng, spec.kv_elems());
        let dout = rand_vec(&mut rng, spec.q_elems());
        let p = FlashParams { block_q: 8, block_k: 8 };
        let serial = forward_spec_with(1, &q, &k, &v, spec, p);
        let par = forward_spec_with(4, &q, &k, &v, spec, p);
        assert_eq!(serial.o, par.o);
        assert_eq!(serial.lse, par.lse);
        let gs = backward_spec_with(1, &q, &k, &v, &serial, &dout, spec, p);
        let gp = backward_spec_with(4, &q, &k, &v, &serial, &dout, spec, p);
        assert_eq!(gs.dq, gp.dq);
        assert_eq!(gs.dk, gp.dk);
        assert_eq!(gs.dv, gp.dv);
        assert_eq!(gs.dk.len(), spec.kv_elems(), "dK is KV-shaped");
    }

    #[test]
    fn decode_chunking_is_split_invariant() {
        let mut rng = Rng::seed_from(79);
        let (n, d) = (130usize, 16usize);
        let q = rand_vec(&mut rng, d);
        let k = rand_vec(&mut rng, n * d);
        let v = rand_vec(&mut rng, n * d);
        let scale = 1.0 / (d as f32).sqrt();
        let mono = decode_splitkv(&q, &k, &v, n, scale, n);
        for chunk in [1usize, 3, 32, 64, 127] {
            let split = decode_splitkv(&q, &k, &v, n, scale, chunk);
            let fanned = decode_splitkv_fanned(4, &q, &k, &v, n, scale, chunk);
            for (a, b) in mono.0.iter().zip(&split.0) {
                assert!((a - b).abs() < 1e-5, "chunk={chunk}: {a} vs {b}");
            }
            assert!((mono.1 - split.1).abs() < 1e-5);
            for (a, b) in split.0.iter().zip(&fanned.0) {
                assert!((a - b).abs() < 1e-5, "fanned chunk={chunk}");
            }
            assert!((split.1 - fanned.1).abs() < 1e-5);
        }
    }

    #[test]
    fn decode_matches_single_row_softmax() {
        let mut rng = Rng::seed_from(80);
        let (n, d) = (23usize, 8usize);
        let q = rand_vec(&mut rng, d);
        let k = rand_vec(&mut rng, n * d);
        let v = rand_vec(&mut rng, n * d);
        let scale = 0.5f32;
        let (o, lse) = decode_splitkv(&q, &k, &v, n, scale, 5);
        // direct f64 softmax over the row
        let scores: Vec<f64> = (0..n)
            .map(|j| {
                scale as f64
                    * (0..d).map(|t| q[t] as f64 * k[j * d + t] as f64).sum::<f64>()
            })
            .collect();
        let m = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let l: f64 = scores.iter().map(|s| (s - m).exp()).sum();
        for t in 0..d {
            let want: f64 = (0..n)
                .map(|j| (scores[j] - m).exp() * v[j * d + t] as f64)
                .sum::<f64>()
                / l;
            assert!((o[t] as f64 - want).abs() < 1e-6, "dim {t}");
        }
        assert!((lse as f64 - (m + l.ln())).abs() < 1e-6);
    }

    #[test]
    fn windowed_decode_matches_reference_tail_softmax() {
        // decode over [lo, hi) must equal the plain softmax over exactly
        // the window's rows — expired history never contributes
        let mut rng = Rng::seed_from(81);
        let (n, d, w) = (40usize, 8usize, 11usize);
        let q = rand_vec(&mut rng, d);
        let k = rand_vec(&mut rng, n * d);
        let v = rand_vec(&mut rng, n * d);
        let scale = 1.0 / (d as f32).sqrt();
        let lo = n - w;
        let kv = KvLayout::Contiguous { k: &k, v: &v };
        let (o, lse) = decode_splitkv_spec(&q, &kv, lo, n, scale, 16);
        let (o_tail, lse_tail) =
            decode_splitkv(&q, &k[lo * d..], &v[lo * d..], w, scale, w);
        // same math, different chunk boundaries — close, not bitwise
        for (a, b) in o.iter().zip(&o_tail) {
            assert!((a - b).abs() < 1e-5, "windowed decode diverged");
        }
        assert!((lse - lse_tail).abs() < 1e-5);
    }
}
