//! §3.2 work partitioning for the native kernels, on `util::pool`.
//!
//! Forward fans one task per (batch, head, Q-block); backward fans one per
//! (batch, head, K-block) — exactly the grid dimensions the paper adds over
//! FlashAttention-1 to fill the machine when batch·heads alone is too
//! small.  `par_map` returns results in input order, and dQ partials are
//! summed in fixed task order, so any worker count produces byte-identical
//! outputs (`FA2_POOL_THREADS=1` is the serial A/B switch, as for the
//! sweeps).
//!
//! The split-KV decode path is the flash-decoding shape: one query row
//! against a long KV history, cut into chunks whose partial softmax states
//! reduce through `attn::combine` — the same associative merge the warp
//! split-K exchange (§3.3) relies on.  The streaming variant
//! ([`decode_splitkv`]) reuses two `Partial`s and never allocates per
//! chunk; the fanned variant ([`decode_splitkv_fanned`]) computes chunk
//! partials on the pool and reduces them with `merge_all`.

use crate::attn::combine::{merge_all, Partial};
use crate::util::pool;

use super::{flash_bwd, flash_fwd, AttnDims, FlashGrads, FlashOut, FlashParams, TensorView};

/// One task per (b, h, block) where `block` tiles `0..seq` by `step`.
fn block_tasks(dims: AttnDims, step: usize) -> Vec<(usize, usize, usize, usize)> {
    let step = step.max(1);
    let mut tasks = Vec::new();
    for b in 0..dims.batch {
        for h in 0..dims.heads {
            let mut lo = 0;
            while lo < dims.seq {
                let hi = (lo + step).min(dims.seq);
                tasks.push((b, h, lo, hi));
                lo = hi;
            }
        }
    }
    tasks
}

/// Flash forward over the whole tensor, fanned across the pool.
pub fn forward(q: &[f32], k: &[f32], v: &[f32], dims: AttnDims, p: FlashParams) -> FlashOut {
    forward_with(pool::threads(), q, k, v, dims, p)
}

/// [`forward`] with an explicit worker count (1 = serial; benches and the
/// byte-identical A/B tests pin this).
pub fn forward_with(
    workers: usize,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dims: AttnDims,
    p: FlashParams,
) -> FlashOut {
    let qv = TensorView::new(dims, q);
    let kv = TensorView::new(dims, k);
    let vv = TensorView::new(dims, v);
    let tasks = block_tasks(dims, p.block_q);
    let tiles = pool::par_map_with(workers, tasks.clone(), |(b, h, q0, q1)| {
        flash_fwd::forward_tile(qv, kv, vv, p, b, h, q0, q1)
    });
    let d = dims.head_dim;
    let mut out = FlashOut { o: vec![0.0; dims.elems()], lse: vec![0.0; dims.rows()] };
    for ((b, h, q0, q1), (ot, lt)) in tasks.into_iter().zip(tiles) {
        let ro = dims.row_offset(b, h, q0);
        out.o[ro..ro + (q1 - q0) * d].copy_from_slice(&ot);
        let lo = dims.lse_offset(b, h, q0);
        out.lse[lo..lo + (q1 - q0)].copy_from_slice(&lt);
    }
    out
}

/// Flash backward over the whole tensor, fanned across the pool.
/// `fwd` is the forward's output (O for the D vector, LSE to recompute P).
pub fn backward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    fwd: &FlashOut,
    dout: &[f32],
    dims: AttnDims,
    p: FlashParams,
) -> FlashGrads {
    backward_with(pool::threads(), q, k, v, fwd, dout, dims, p)
}

/// [`backward`] with an explicit worker count.
#[allow(clippy::too_many_arguments)]
pub fn backward_with(
    workers: usize,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    fwd: &FlashOut,
    dout: &[f32],
    dims: AttnDims,
    p: FlashParams,
) -> FlashGrads {
    let qv = TensorView::new(dims, q);
    let kv = TensorView::new(dims, k);
    let vv = TensorView::new(dims, v);
    let dov = TensorView::new(dims, dout);
    assert_eq!(fwd.o.len(), dims.elems(), "forward O length mismatch");
    assert_eq!(fwd.lse.len(), dims.rows(), "forward LSE length mismatch");

    // D_i = Σ_t dO_it · O_it, once per tensor (Algorithm 2 line 1)
    let d = dims.head_dim;
    let mut dvec = vec![0.0f32; dims.rows()];
    for (r, dv) in dvec.iter_mut().enumerate() {
        let (orow, dorow) = (&fwd.o[r * d..(r + 1) * d], &dout[r * d..(r + 1) * d]);
        let mut acc = 0.0f32;
        for t in 0..d {
            acc += orow[t] * dorow[t];
        }
        *dv = acc;
    }

    let tasks = block_tasks(dims, p.block_k);
    let lse = &fwd.lse;
    let dvec_ref = &dvec;

    let mut g = FlashGrads {
        dq: vec![0.0; dims.elems()],
        dk: vec![0.0; dims.elems()],
        dv: vec![0.0; dims.elems()],
    };
    // Fan tasks in bounded waves: each task's dQ partial spans up to the
    // whole seqlen, so holding every tile at once would cost
    // O(seq²·d/block_k) transient memory on long sequences.  dK/dV rows
    // are owned by exactly one task; dQ partials are summed in ascending
    // task order — the order is the same for ANY worker or wave size, so
    // outputs stay byte-identical to serial.
    let wave = workers.max(1) * 4;
    for wave_tasks in tasks.chunks(wave) {
        let tiles = pool::par_map_with(workers, wave_tasks.to_vec(), |(b, h, j0, j1)| {
            flash_bwd::backward_tile(qv, kv, vv, lse, dov, dvec_ref, b, h, j0, j1)
        });
        for (&(b, h, j0, j1), (dk_t, dv_t, q_start, dq_t)) in
            wave_tasks.iter().zip(tiles)
        {
            let ro = dims.row_offset(b, h, j0);
            g.dk[ro..ro + (j1 - j0) * d].copy_from_slice(&dk_t);
            g.dv[ro..ro + (j1 - j0) * d].copy_from_slice(&dv_t);
            let base = dims.row_offset(b, h, q_start);
            for (x, acc) in dq_t.iter().zip(&mut g.dq[base..base + dq_t.len()]) {
                *acc += *x;
            }
        }
    }
    g
}

/// Fill `out` with the partial softmax state of one KV chunk (`rows`
/// key/value rows of width `d = qrow.len()`), in f64 like `combine`.
/// Allocation-free once `out.o` has capacity `d`.
fn partial_from_chunk(out: &mut Partial, qrow: &[f32], kc: &[f32], vc: &[f32], scale: f32) {
    let d = qrow.len();
    out.o.clear();
    out.o.resize(d, 0.0);
    out.m = f64::NEG_INFINITY;
    out.l = 0.0;
    let rows = kc.len() / d;
    for r in 0..rows {
        let (kr, vr) = (&kc[r * d..(r + 1) * d], &vc[r * d..(r + 1) * d]);
        let mut s = 0.0f64;
        for t in 0..d {
            s += qrow[t] as f64 * kr[t] as f64;
        }
        s *= scale as f64;
        if s > out.m {
            // raise the running max; rescale what we have so far
            let alpha = (out.m - s).exp(); // 0 on the first row
            out.l *= alpha;
            for o in out.o.iter_mut() {
                *o *= alpha;
            }
            out.m = s;
        }
        let w = (s - out.m).exp();
        out.l += w;
        for (o, &x) in out.o.iter_mut().zip(vr) {
            *o += w * x as f64;
        }
    }
}

/// Streaming split-KV decode: one query row against `n` cached KV rows,
/// reduced chunk by chunk with `Partial::merge_from` — zero allocations
/// per chunk (the serving decode hot loop).  Returns (O row, LSE).
pub fn decode_splitkv(
    qrow: &[f32],
    k_hist: &[f32],
    v_hist: &[f32],
    n: usize,
    scale: f32,
    chunk: usize,
) -> (Vec<f32>, f32) {
    let d = qrow.len();
    assert!(k_hist.len() >= n * d && v_hist.len() >= n * d, "history too short");
    let chunk = chunk.max(1);
    let mut acc = Partial::empty(d);
    let mut tmp = Partial::empty(d);
    let mut c0 = 0;
    while c0 < n {
        let c1 = (c0 + chunk).min(n);
        partial_from_chunk(&mut tmp, qrow, &k_hist[c0 * d..c1 * d], &v_hist[c0 * d..c1 * d], scale);
        acc.merge_from(&tmp);
        c0 = c1;
    }
    let (o, lse) = acc.finalize();
    (o.into_iter().map(|x| x as f32).collect(), lse as f32)
}

/// Fanned split-KV decode: chunk partials computed on the pool, reduced
/// with `merge_all` — the flash-decoding shape, exercising the same
/// merge associativity the §3.3 warp split-K models.
pub fn decode_splitkv_fanned(
    workers: usize,
    qrow: &[f32],
    k_hist: &[f32],
    v_hist: &[f32],
    n: usize,
    scale: f32,
    chunk: usize,
) -> (Vec<f32>, f32) {
    let d = qrow.len();
    assert!(k_hist.len() >= n * d && v_hist.len() >= n * d, "history too short");
    let chunk = chunk.max(1);
    let mut ranges = Vec::new();
    let mut c0 = 0;
    while c0 < n {
        let c1 = (c0 + chunk).min(n);
        ranges.push((c0, c1));
        c0 = c1;
    }
    let parts = pool::par_map_with(workers, ranges, |(c0, c1)| {
        let mut p = Partial::empty(d);
        partial_from_chunk(&mut p, qrow, &k_hist[c0 * d..c1 * d], &v_hist[c0 * d..c1 * d], scale);
        p
    });
    let (o, lse) = merge_all(&parts).finalize();
    (o.into_iter().map(|x| x as f32).collect(), lse as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn parallel_forward_is_bitwise_equal_to_serial() {
        let mut rng = Rng::seed_from(77);
        let dims = AttnDims { batch: 2, heads: 3, seq: 37, head_dim: 16, causal: true };
        let n = dims.elems();
        let (q, k, v) = (rand_vec(&mut rng, n), rand_vec(&mut rng, n), rand_vec(&mut rng, n));
        let p = FlashParams { block_q: 8, block_k: 8 };
        let serial = forward_with(1, &q, &k, &v, dims, p);
        let par = forward_with(4, &q, &k, &v, dims, p);
        assert_eq!(serial.o, par.o, "parallel forward diverged from serial");
        assert_eq!(serial.lse, par.lse);
    }

    #[test]
    fn parallel_backward_is_bitwise_equal_to_serial() {
        let mut rng = Rng::seed_from(78);
        let dims = AttnDims { batch: 1, heads: 4, seq: 26, head_dim: 8, causal: false };
        let n = dims.elems();
        let (q, k, v, dout) = (
            rand_vec(&mut rng, n),
            rand_vec(&mut rng, n),
            rand_vec(&mut rng, n),
            rand_vec(&mut rng, n),
        );
        let p = FlashParams { block_q: 8, block_k: 8 };
        let fwd = forward_with(1, &q, &k, &v, dims, p);
        let serial = backward_with(1, &q, &k, &v, &fwd, &dout, dims, p);
        let par = backward_with(4, &q, &k, &v, &fwd, &dout, dims, p);
        assert_eq!(serial.dq, par.dq, "parallel dQ diverged from serial");
        assert_eq!(serial.dk, par.dk);
        assert_eq!(serial.dv, par.dv);
    }

    #[test]
    fn decode_chunking_is_split_invariant() {
        let mut rng = Rng::seed_from(79);
        let (n, d) = (130usize, 16usize);
        let q = rand_vec(&mut rng, d);
        let k = rand_vec(&mut rng, n * d);
        let v = rand_vec(&mut rng, n * d);
        let scale = 1.0 / (d as f32).sqrt();
        let mono = decode_splitkv(&q, &k, &v, n, scale, n);
        for chunk in [1usize, 3, 32, 64, 127] {
            let split = decode_splitkv(&q, &k, &v, n, scale, chunk);
            let fanned = decode_splitkv_fanned(4, &q, &k, &v, n, scale, chunk);
            for (a, b) in mono.0.iter().zip(&split.0) {
                assert!((a - b).abs() < 1e-5, "chunk={chunk}: {a} vs {b}");
            }
            assert!((mono.1 - split.1).abs() < 1e-5);
            for (a, b) in split.0.iter().zip(&fanned.0) {
                assert!((a - b).abs() < 1e-5, "fanned chunk={chunk}");
            }
            assert!((split.1 - fanned.1).abs() < 1e-5);
        }
    }

    #[test]
    fn decode_matches_single_row_softmax() {
        let mut rng = Rng::seed_from(80);
        let (n, d) = (23usize, 8usize);
        let q = rand_vec(&mut rng, d);
        let k = rand_vec(&mut rng, n * d);
        let v = rand_vec(&mut rng, n * d);
        let scale = 0.5f32;
        let (o, lse) = decode_splitkv(&q, &k, &v, n, scale, 5);
        // direct f64 softmax over the row
        let scores: Vec<f64> = (0..n)
            .map(|j| {
                scale as f64
                    * (0..d).map(|t| q[t] as f64 * k[j * d + t] as f64).sum::<f64>()
            })
            .collect();
        let m = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let l: f64 = scores.iter().map(|s| (s - m).exp()).sum();
        for t in 0..d {
            let want: f64 = (0..n)
                .map(|j| (scores[j] - m).exp() * v[j * d + t] as f64)
                .sum::<f64>()
                / l;
            assert!((o[t] as f64 - want).abs() < 1e-6, "dim {t}");
        }
        assert!((lse as f64 - (m + l.ln())).abs() < 1e-6);
    }
}
