//! `AttnSpec` — the one attention-problem descriptor every executing
//! kernel dispatches on (DESIGN.md §11).
//!
//! FlashAttention-2 (§4) treats MQA/GQA head sharing and non-trivial
//! masking (causal, local) as first-class kernel variants.  The seed-era
//! `attn::exec` API hardcoded the opposite: equal Q/KV heads, a bare
//! causal flag, and one contiguous KV slab per sequence.  This module
//! moves those three axes into the *type* the kernels take:
//!
//! - [`HeadMap`] — grouped-query head sharing: `n_q_heads` query heads
//!   read `n_kv_heads` K/V heads (`n_kv_heads == n_q_heads` is classic
//!   MHA, `n_kv_heads == 1` is MQA, anything dividing in between is GQA).
//! - [`Mask`] — `Full`, `Causal`, or `SlidingWindow(w)` (causal local
//!   attention: row *i* sees columns `j ≤ i` with `i − j < w`).  The mask
//!   classifies whole tiles ([`Mask::cover`]) so the flash kernels skip
//!   out-of-window K blocks exactly like they already skip above-diagonal
//!   causal blocks — skipped blocks are never read.
//! - [`KvLayout`] — where the K/V rows live: one `Contiguous` run, or
//!   `Paged` behind a [`BlockTable`] into the serving arena's block pool
//!   (`runtime::kv`).  The split-KV decode kernel consumes either through
//!   the same chunk iterator, so paged and contiguous decode are
//!   *bit-identical* when their chunk boundaries agree.
//!
//! Every executing entry point — reference oracle, tiled forward/backward,
//! the parallel fan-outs, and split-KV decode — takes the spec; serving,
//! verification and the CLI all describe their scenario here instead of
//! growing per-scenario entry points.

use crate::bail;
use crate::util::error::Result;

use super::exec::AttnDims;

/// Grouped-query head mapping: `n_q_heads` query heads share `n_kv_heads`
/// K/V heads in contiguous groups of `n_q_heads / n_kv_heads`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HeadMap {
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
}

impl HeadMap {
    /// Equal Q/KV heads (classic multi-head attention).
    pub fn mha(heads: usize) -> HeadMap {
        HeadMap { n_q_heads: heads, n_kv_heads: heads }
    }

    /// Query heads per KV head.
    pub fn group_size(&self) -> usize {
        debug_assert!(self.n_kv_heads > 0 && self.n_q_heads % self.n_kv_heads == 0);
        self.n_q_heads / self.n_kv_heads
    }

    /// The KV head query head `q` reads (grouped broadcast).
    pub fn kv_head(&self, q: usize) -> usize {
        q / self.group_size()
    }

    /// The query heads of KV head `kv`: `kv * g .. (kv + 1) * g`.
    pub fn q_heads_of(&self, kv: usize) -> std::ops::Range<usize> {
        let g = self.group_size();
        kv * g..(kv + 1) * g
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_kv_heads == 0 || self.n_q_heads == 0 {
            bail!("head map needs at least one head: {self:?}");
        }
        if self.n_q_heads % self.n_kv_heads != 0 {
            bail!(
                "GQA needs n_kv_heads ({}) to divide n_q_heads ({})",
                self.n_kv_heads,
                self.n_q_heads
            );
        }
        Ok(())
    }
}

/// How a tile of the score matrix relates to the mask.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cover {
    /// Every (row, col) in the tile is masked — skip, never read K/V.
    Skip,
    /// The mask boundary crosses the tile — per-row column bounds apply.
    Partial,
    /// Every (row, col) in the tile is live — no per-row masking needed.
    Full,
}

/// The mask axis: full (bidirectional), causal, or causal sliding-window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mask {
    /// Every row attends to every column.
    Full,
    /// Row `i` attends to columns `j ≤ i`.
    Causal,
    /// Row `i` attends to columns `j ≤ i` with `i − j < w` (so `w = 1`
    /// is attend-to-self only; `w ≥ seq` degenerates to `Causal`).
    SlidingWindow(usize),
}

impl Mask {
    pub fn validate(&self) -> Result<()> {
        if let Mask::SlidingWindow(0) = self {
            bail!("sliding window must be at least 1 token");
        }
        Ok(())
    }

    /// Whether row `i` may attend to column `j`.
    pub fn allows(&self, i: usize, j: usize) -> bool {
        match *self {
            Mask::Full => true,
            Mask::Causal => j <= i,
            Mask::SlidingWindow(w) => j <= i && i - j < w,
        }
    }

    /// The half-open column range `[lo, hi)` row `i` attends to, clipped
    /// to a history of `kv_len` columns.
    pub fn row_bounds(&self, i: usize, kv_len: usize) -> (usize, usize) {
        match *self {
            Mask::Full => (0, kv_len),
            Mask::Causal => (0, (i + 1).min(kv_len)),
            Mask::SlidingWindow(w) => ((i + 1).saturating_sub(w), (i + 1).min(kv_len)),
        }
    }

    /// Classify the tile rows `[q0, q1) ×` cols `[j0, j1)` (both
    /// non-empty).  `Skip` tiles are provably all-masked: the kernels
    /// never touch their K/V blocks — the same block-skipping treatment
    /// causal attention already gets, extended to the window's left edge.
    pub fn cover(&self, q0: usize, q1: usize, j0: usize, j1: usize) -> Cover {
        debug_assert!(q0 < q1 && j0 < j1);
        match *self {
            Mask::Full => Cover::Full,
            Mask::Causal => {
                if j0 > q1 - 1 {
                    Cover::Skip // entirely above the diagonal
                } else if j1 - 1 <= q0 {
                    Cover::Full // entirely at-or-below for every row
                } else {
                    Cover::Partial
                }
            }
            Mask::SlidingWindow(w) => {
                if j0 > q1 - 1 {
                    Cover::Skip // above the diagonal
                } else if j1 <= (q0 + 1).saturating_sub(w) {
                    Cover::Skip // left of every row's window
                } else if j1 - 1 <= q0 && j0 + w >= q1 {
                    // top row covers the right edge, bottom row's window
                    // reaches the left edge
                    Cover::Full
                } else {
                    Cover::Partial
                }
            }
        }
    }

    /// True for masks where later K blocks can be skipped once the
    /// diagonal is passed (everything but `Full`).
    pub fn is_causal_like(&self) -> bool {
        !matches!(self, Mask::Full)
    }
}

/// One executing attention problem: batch/shape, head sharing, and mask.
/// Q is `(batch, n_q_heads, seq, head_dim)`; K/V are
/// `(batch, n_kv_heads, seq, head_dim)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AttnSpec {
    pub batch: usize,
    pub heads: HeadMap,
    pub seq: usize,
    pub head_dim: usize,
    pub mask: Mask,
}

impl AttnSpec {
    /// The spec the seed-era `AttnDims` API described: equal heads, full
    /// or causal mask.
    pub fn from_dims(dims: AttnDims) -> AttnSpec {
        AttnSpec {
            batch: dims.batch,
            heads: HeadMap::mha(dims.heads),
            seq: dims.seq,
            head_dim: dims.head_dim,
            mask: if dims.causal { Mask::Causal } else { Mask::Full },
        }
    }

    pub fn validate(&self) -> Result<()> {
        self.heads.validate()?;
        self.mask.validate()?;
        if self.batch == 0 || self.seq == 0 || self.head_dim == 0 {
            bail!("degenerate attention spec {self:?}");
        }
        Ok(())
    }

    /// Layout of the Q-shaped tensors (Q, O, dO, dQ).  The `causal` flag
    /// is only FLOP-accounting metadata here; kernels consult `mask`.
    pub fn q_dims(&self) -> AttnDims {
        AttnDims {
            batch: self.batch,
            heads: self.heads.n_q_heads,
            seq: self.seq,
            head_dim: self.head_dim,
            causal: self.mask.is_causal_like(),
        }
    }

    /// Layout of the KV-shaped tensors (K, V, dK, dV).
    pub fn kv_dims(&self) -> AttnDims {
        AttnDims { heads: self.heads.n_kv_heads, ..self.q_dims() }
    }

    /// Softmax scale 1/sqrt(d).
    pub fn scale(&self) -> f32 {
        1.0 / (self.head_dim as f32).sqrt()
    }

    /// Element count of a Q-shaped tensor.
    pub fn q_elems(&self) -> usize {
        self.q_dims().elems()
    }

    /// Element count of a KV-shaped tensor.
    pub fn kv_elems(&self) -> usize {
        self.kv_dims().elems()
    }

    /// Rows of per-Q-row statistics (the LSE).
    pub fn q_rows(&self) -> usize {
        self.q_dims().rows()
    }
}

/// Block-table view of one `(layer, head)` plane of a paged KV cache
/// (`runtime::kv`): logical token block `b` lives in physical pool block
/// `blocks[b]`; within a block, this plane's rows sit at `plane` and are
/// contiguous — which is exactly what the split-KV decode kernel streams.
#[derive(Clone, Copy)]
pub struct BlockTable<'a> {
    pub k_pool: &'a [f32],
    pub v_pool: &'a [f32],
    /// Physical pool block index per logical token block.
    pub blocks: &'a [u32],
    /// Elements per physical block (all planes).
    pub block_elems: usize,
    /// Element offset of this plane's rows inside a block.
    pub plane: usize,
    /// Token rows per block.
    pub block_tokens: usize,
}

impl BlockTable<'_> {
    /// The contiguous K/V rows `[t0, t1)` of width `d`.  The range must
    /// not cross a block boundary (the decode kernel chunks at block
    /// boundaries, so it never asks for one that does).
    pub fn rows(&self, t0: usize, t1: usize, d: usize) -> (&[f32], &[f32]) {
        debug_assert!(t0 < t1);
        debug_assert_eq!(
            t0 / self.block_tokens,
            (t1 - 1) / self.block_tokens,
            "paged row range [{t0}, {t1}) crosses a block boundary"
        );
        let blk = self.blocks[t0 / self.block_tokens] as usize;
        let start =
            blk * self.block_elems + self.plane + (t0 % self.block_tokens) * d;
        let len = (t1 - t0) * d;
        (&self.k_pool[start..start + len], &self.v_pool[start..start + len])
    }
}

/// Where one sequence's K/V history lives — the layout axis of the spec.
#[derive(Clone, Copy)]
pub enum KvLayout<'a> {
    /// Rows `0..n` stored contiguously (`n * d` elements each).
    Contiguous { k: &'a [f32], v: &'a [f32] },
    /// Rows scattered across fixed-size token blocks via a block table.
    Paged(BlockTable<'a>),
}

impl KvLayout<'_> {
    /// The K/V rows `[t0, t1)` of width `d`; for `Paged` the range must
    /// stay within one token block.
    pub fn rows(&self, t0: usize, t1: usize, d: usize) -> (&[f32], &[f32]) {
        match self {
            KvLayout::Contiguous { k, v } => (&k[t0 * d..t1 * d], &v[t0 * d..t1 * d]),
            KvLayout::Paged(table) => table.rows(t0, t1, d),
        }
    }

    /// Natural chunk size for split-KV streaming: the block size for
    /// `Paged` (chunks must not cross blocks), or `fallback` rows for
    /// `Contiguous`.  Using a paged layout's block size for the matching
    /// contiguous run makes the two decodes bit-identical.
    pub fn chunk_tokens(&self, fallback: usize) -> usize {
        match self {
            KvLayout::Contiguous { .. } => fallback.max(1),
            KvLayout::Paged(t) => t.block_tokens.max(1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_map_groups_and_validates() {
        let m = HeadMap { n_q_heads: 8, n_kv_heads: 2 };
        assert!(m.validate().is_ok());
        assert_eq!(m.group_size(), 4);
        assert_eq!(m.kv_head(0), 0);
        assert_eq!(m.kv_head(3), 0);
        assert_eq!(m.kv_head(4), 1);
        assert_eq!(m.q_heads_of(1), 4..8);
        let mqa = HeadMap { n_q_heads: 8, n_kv_heads: 1 };
        assert_eq!(mqa.kv_head(7), 0);
        assert!(HeadMap { n_q_heads: 8, n_kv_heads: 3 }.validate().is_err());
        assert!(HeadMap { n_q_heads: 0, n_kv_heads: 0 }.validate().is_err());
        assert_eq!(HeadMap::mha(4), HeadMap { n_q_heads: 4, n_kv_heads: 4 });
    }

    #[test]
    fn mask_row_bounds_match_allows() {
        let n = 12;
        for mask in [Mask::Full, Mask::Causal, Mask::SlidingWindow(1), Mask::SlidingWindow(4)]
        {
            for i in 0..n {
                let (lo, hi) = mask.row_bounds(i, n);
                for j in 0..n {
                    assert_eq!(
                        mask.allows(i, j),
                        (lo..hi).contains(&j),
                        "{mask:?} row {i} col {j}"
                    );
                }
            }
        }
        assert!(Mask::SlidingWindow(0).validate().is_err());
        assert!(Mask::SlidingWindow(1).validate().is_ok());
    }

    #[test]
    fn cover_classification_is_exact() {
        // brute-force: a tile's cover must equal the element-wise truth
        let n = 20;
        for mask in [Mask::Full, Mask::Causal, Mask::SlidingWindow(3), Mask::SlidingWindow(7)]
        {
            for q0 in (0..n).step_by(4) {
                let q1 = (q0 + 4).min(n);
                for j0 in (0..n).step_by(5) {
                    let j1 = (j0 + 5).min(n);
                    let mut any = false;
                    let mut all = true;
                    for i in q0..q1 {
                        for j in j0..j1 {
                            if mask.allows(i, j) {
                                any = true;
                            } else {
                                all = false;
                            }
                        }
                    }
                    let want = if !any {
                        Cover::Skip
                    } else if all {
                        Cover::Full
                    } else {
                        Cover::Partial
                    };
                    assert_eq!(
                        mask.cover(q0, q1, j0, j1),
                        want,
                        "{mask:?} tile ({q0},{q1})x({j0},{j1})"
                    );
                }
            }
        }
    }

    #[test]
    fn spec_dims_split_q_and_kv_heads() {
        let spec = AttnSpec {
            batch: 2,
            heads: HeadMap { n_q_heads: 4, n_kv_heads: 2 },
            seq: 8,
            head_dim: 16,
            mask: Mask::SlidingWindow(4),
        };
        assert!(spec.validate().is_ok());
        assert_eq!(spec.q_dims().heads, 4);
        assert_eq!(spec.kv_dims().heads, 2);
        assert_eq!(spec.q_elems(), 2 * 4 * 8 * 16);
        assert_eq!(spec.kv_elems(), 2 * 2 * 8 * 16);
        assert_eq!(spec.q_rows(), 2 * 4 * 8);
        assert!(spec.q_dims().causal, "window masks account as causal-like");
        let dense = AttnSpec::from_dims(AttnDims {
            batch: 1,
            heads: 3,
            seq: 5,
            head_dim: 4,
            causal: true,
        });
        assert_eq!(dense.heads, HeadMap::mha(3));
        assert_eq!(dense.mask, Mask::Causal);
    }

    #[test]
    fn paged_and_contiguous_layouts_serve_the_same_rows() {
        // two planes (l=0 h=0/1), block_tokens 2, 2 logical blocks in
        // REVERSED physical order to prove the table indirection
        let d = 2;
        let block_tokens = 2;
        let planes = 2;
        let block_elems = planes * block_tokens * d;
        // pool: phys block 0 holds logical block 1, phys 1 holds logical 0
        let mut k_pool = vec![0.0f32; 2 * block_elems];
        let mut v_pool = vec![0.0f32; 2 * block_elems];
        let flat: Vec<f32> = (0..8).map(|x| x as f32).collect(); // plane 1, rows 0..4
        for t in 0..4 {
            let (phys, tin) = (if t < 2 { 1 } else { 0 }, t % 2);
            let off = phys * block_elems + 1 * block_tokens * d + tin * d;
            k_pool[off..off + d].copy_from_slice(&flat[t * d..(t + 1) * d]);
            v_pool[off..off + d].copy_from_slice(&flat[t * d..(t + 1) * d]);
        }
        let table = BlockTable {
            k_pool: &k_pool,
            v_pool: &v_pool,
            blocks: &[1, 0],
            block_elems,
            plane: 1 * block_tokens * d,
            block_tokens,
        };
        let paged = KvLayout::Paged(table);
        let contig = KvLayout::Contiguous { k: &flat, v: &flat };
        for (t0, t1) in [(0usize, 2usize), (2, 4), (1, 2), (3, 4)] {
            let (pk, pv) = paged.rows(t0, t1, d);
            let (ck, cv) = contig.rows(t0, t1, d);
            assert_eq!(pk, ck, "k rows [{t0},{t1})");
            assert_eq!(pv, cv, "v rows [{t0},{t1})");
        }
        assert_eq!(paged.chunk_tokens(64), 2);
        assert_eq!(contig.chunk_tokens(64), 64);
    }
}
