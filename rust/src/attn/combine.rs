//! Split-K combine algebra — the rust mirror of
//! `python/compile/kernels/splitk.py::combine_partials`.
//!
//! A partial is the triple (o_tilde, m, l) a KV-chunk worker produces:
//! o_tilde = sum_j exp(s_j - m) v_j (unscaled), m = local max, l = local
//! sum of exponentials.  Merging two partials is the online-softmax update;
//! it is associative and commutative, which is what makes both the warp
//! split-K exchange (section 3.3) and flash-decoding correct under any
//! reduction order.  That property is property-tested in
//! `rust/tests/prop_combine.rs` and mirrored by the hypothesis test on the
//! python side.

/// One row's partial softmax state over `d` output dims.
#[derive(Debug, Clone, PartialEq)]
pub struct Partial {
    pub o: Vec<f64>,
    pub m: f64,
    pub l: f64,
}

impl Partial {
    /// The identity element: an empty chunk (no keys seen).
    pub fn empty(d: usize) -> Partial {
        Partial { o: vec![0.0; d], m: f64::NEG_INFINITY, l: 0.0 }
    }

    /// A partial from explicit scores + values (reference construction).
    pub fn from_scores(scores: &[f64], values: &[Vec<f64>]) -> Partial {
        assert_eq!(scores.len(), values.len());
        let d = values.first().map_or(0, |v| v.len());
        if scores.is_empty() {
            return Partial::empty(d);
        }
        let m = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut o = vec![0.0; d];
        let mut l = 0.0;
        for (s, v) in scores.iter().zip(values) {
            let w = (s - m).exp();
            l += w;
            for (oi, vi) in o.iter_mut().zip(v) {
                *oi += w * vi;
            }
        }
        Partial { o, m, l }
    }

    /// Merge `other` into `self` in place (the smem exchange / combine
    /// pass).  Allocation-free when both partials share `d` — this is the
    /// flash-decoding hot loop, which must not allocate per KV chunk.
    pub fn merge_from(&mut self, other: &Partial) {
        // fa2lint: allow(no-float-eq) -- (l=0.0, m=-inf) is the exact empty-partial sentinel set by Partial::empty
        if other.l == 0.0 && other.m == f64::NEG_INFINITY {
            return;
        }
        // fa2lint: allow(no-float-eq) -- same empty-partial sentinel, receiver side
        if self.l == 0.0 && self.m == f64::NEG_INFINITY {
            // clone_from reuses self.o's buffer when capacities allow.
            self.o.clone_from(&other.o);
            self.m = other.m;
            self.l = other.l;
            return;
        }
        let m = self.m.max(other.m);
        let wa = (self.m - m).exp();
        let wb = (other.m - m).exp();
        self.l = wa * self.l + wb * other.l;
        for (a, b) in self.o.iter_mut().zip(&other.o) {
            *a = wa * *a + wb * b;
        }
        self.m = m;
    }

    /// Merge two partials, returning the result ([`merge_from`] wrapper).
    ///
    /// [`merge_from`]: Partial::merge_from
    pub fn merge(&self, other: &Partial) -> Partial {
        let mut out = self.clone();
        out.merge_from(other);
        out
    }

    /// Finalize: O = o_tilde / l, LSE = m + ln(l).
    pub fn finalize(&self) -> (Vec<f64>, f64) {
        // fa2lint: allow(no-float-eq) -- l==0.0 only for the exact empty sentinel; avoids 0/0 in the division below
        let l = if self.l == 0.0 { 1.0 } else { self.l };
        (self.o.iter().map(|x| x / l).collect(), self.m + l.ln())
    }
}

/// Merge a slice of partials (any order is valid; in-place left fold so
/// the reduction allocates once, not per element).
pub fn merge_all(parts: &[Partial]) -> Partial {
    let d = parts.first().map_or(0, |p| p.o.len());
    let mut acc = Partial::empty(d);
    for p in parts {
        acc.merge_from(p);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() <= 1e-9 * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn split_equals_monolithic() {
        let scores = vec![0.3, -1.2, 2.0, 0.7, -0.5, 1.1];
        let values: Vec<Vec<f64>> =
            (0..6).map(|i| vec![i as f64, 1.0 - i as f64]).collect();
        let whole = Partial::from_scores(&scores, &values).finalize();
        let a = Partial::from_scores(&scores[..2], &values[..2]);
        let b = Partial::from_scores(&scores[2..5], &values[2..5]);
        let c = Partial::from_scores(&scores[5..], &values[5..]);
        let merged = merge_all(&[a, b, c]).finalize();
        for (x, y) in whole.0.iter().zip(&merged.0) {
            assert!(close(*x, *y), "{x} vs {y}");
        }
        assert!(close(whole.1, merged.1));
    }

    #[test]
    fn empty_is_identity() {
        let p = Partial::from_scores(&[1.0, 2.0], &[vec![3.0], vec![4.0]]);
        let e = Partial::empty(1);
        assert_eq!(p.merge(&e), p);
        assert_eq!(e.merge(&p), p);
    }

    #[test]
    fn merge_commutes() {
        let a = Partial::from_scores(&[5.0, -3.0], &[vec![1.0, 0.0], vec![0.0, 1.0]]);
        let b = Partial::from_scores(&[0.1], &[vec![2.0, 2.0]]);
        let ab = a.merge(&b).finalize();
        let ba = b.merge(&a).finalize();
        for (x, y) in ab.0.iter().zip(&ba.0) {
            assert!(close(*x, *y));
        }
        assert!(close(ab.1, ba.1));
    }

    #[test]
    fn merge_from_matches_merge_and_reuses_buffer() {
        let a = Partial::from_scores(&[0.5, -2.0], &[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Partial::from_scores(&[1.5], &[vec![-1.0, 0.5]]);
        let via_merge = a.merge(&b);
        let mut via_from = a.clone();
        let ptr_before = via_from.o.as_ptr();
        via_from.merge_from(&b);
        assert_eq!(via_from, via_merge);
        // in-place path: the output buffer is the input buffer
        assert_eq!(via_from.o.as_ptr(), ptr_before);
        // identity cases mirror merge()
        let mut e = Partial::empty(2);
        e.merge_from(&a);
        assert_eq!(e, a);
        let mut a2 = a.clone();
        a2.merge_from(&Partial::empty(2));
        assert_eq!(a2, a);
    }

    #[test]
    fn numerically_stable_with_huge_scores() {
        let a = Partial::from_scores(&[800.0], &[vec![1.0]]);
        let b = Partial::from_scores(&[-800.0], &[vec![5.0]]);
        let (o, lse) = a.merge(&b).finalize();
        assert!(o[0].is_finite() && (o[0] - 1.0).abs() < 1e-12);
        assert!(lse.is_finite() && (lse - 800.0).abs() < 1e-9);
    }
}
