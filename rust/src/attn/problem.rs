//! Attention problem description + the paper's FLOP accounting formulas
//! (section 4.1).

/// One attention benchmark point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AttnProblem {
    pub batch: u64,
    pub heads: u64,
    pub seqlen: u64,
    pub head_dim: u64,
    pub causal: bool,
    /// Bytes per element of Q/K/V/O (2 = fp16/bf16, the paper's setting).
    pub dtype_bytes: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pass {
    Fwd,
    Bwd,
    FwdBwd,
}

impl AttnProblem {
    /// The paper's benchmark grid: total tokens fixed (16k on A100), hidden
    /// dim 2048 split into heads of `head_dim`.
    pub fn paper_setting(seqlen: u64, head_dim: u64, causal: bool) -> AttnProblem {
        let total_tokens = 16 * 1024;
        let hidden = 2048;
        AttnProblem {
            batch: (total_tokens / seqlen).max(1),
            heads: hidden / head_dim,
            seqlen,
            head_dim,
            causal,
            dtype_bytes: 2,
        }
    }

    /// Section 4.1: `4 * seqlen^2 * head_dim * heads` per batch element,
    /// halved for causal, x2.5 for backward, x3.5 for fwd+bwd.  This is the
    /// *reported* FLOP count used for TFLOPs/s figures (not the executed
    /// count — standard attention executes the full square even with a
    /// causal mask but is still charged the halved count).
    pub fn reported_flops(&self, pass: Pass) -> f64 {
        let n = self.seqlen as f64;
        let mut f = 4.0 * n * n * self.head_dim as f64
            * (self.heads * self.batch) as f64;
        if self.causal {
            f /= 2.0;
        }
        match pass {
            Pass::Fwd => f,
            Pass::Bwd => 2.5 * f,
            Pass::FwdBwd => 3.5 * f,
        }
    }

    /// Bytes of Q+K+V (inputs) for one full pass over the problem.
    pub fn qkv_bytes(&self) -> f64 {
        (3 * self.batch * self.heads * self.seqlen * self.head_dim * self.dtype_bytes)
            as f64
    }

    /// Bytes of the output O.
    pub fn o_bytes(&self) -> f64 {
        (self.batch * self.heads * self.seqlen * self.head_dim * self.dtype_bytes)
            as f64
    }

    /// Bytes of one full N x N score/probability matrix (what standard
    /// attention materializes and FlashAttention exists to avoid).
    pub fn score_matrix_bytes(&self) -> f64 {
        (self.batch * self.heads * self.seqlen * self.seqlen * self.dtype_bytes) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_setting_fixes_token_count() {
        for n in [512, 1024, 2048, 4096, 8192, 16384] {
            let p = AttnProblem::paper_setting(n, 64, false);
            assert_eq!(p.batch * p.seqlen, 16 * 1024);
            assert_eq!(p.heads, 32); // hidden 2048 / 64
        }
        assert_eq!(AttnProblem::paper_setting(2048, 128, false).heads, 16);
    }

    #[test]
    fn flops_formula_matches_paper() {
        let p = AttnProblem::paper_setting(2048, 64, false);
        // 4 * N^2 * d * heads * batch
        let expect = 4.0 * 2048.0f64 * 2048.0 * 64.0 * 32.0 * 8.0;
        assert_eq!(p.reported_flops(Pass::Fwd), expect);
        assert_eq!(p.reported_flops(Pass::Bwd), 2.5 * expect);
        assert_eq!(p.reported_flops(Pass::FwdBwd), 3.5 * expect);
        let pc = AttnProblem { causal: true, ..p };
        assert_eq!(pc.reported_flops(Pass::Fwd), expect / 2.0);
    }

    #[test]
    fn traffic_helpers() {
        let p = AttnProblem { batch: 2, heads: 4, seqlen: 1024, head_dim: 64, causal: false, dtype_bytes: 2 };
        assert_eq!(p.qkv_bytes(), (3 * 2 * 4 * 1024 * 64 * 2) as f64);
        assert_eq!(p.o_bytes(), (2 * 4 * 1024 * 64 * 2) as f64);
        assert_eq!(p.score_matrix_bytes(), (2u64 * 4 * 1024 * 1024 * 2) as f64);
    }
}
