//! Attention schedules + FLOP accounting + split-K combine algebra: the
//! executable form of the paper's sections 3.1-3.3 differences between
//! standard attention, FlashAttention-1, Triton, and FlashAttention-2.

pub mod autotune;
pub mod combine;
pub mod exec;
pub mod problem;
pub mod schedule;
pub mod spec;

pub use autotune::{best as autotune_best, tune as autotune_tune, TunedSchedule};
pub use problem::{AttnProblem, Pass};
pub use schedule::{kernels_for, simulate_tflops, simulate_time, Method, ScheduleSpec};
pub use spec::{AttnSpec, BlockTable, Cover, HeadMap, KvLayout, Mask};
