//! The single source of truth for every observable name in the tree
//! (DESIGN.md §13).
//!
//! Every span, event, counter, and gauge name used through the `obs_*!`
//! macros must be declared here **exactly once** — the `obs-name-registry`
//! lint rule cross-checks the macro call sites in the whole workspace
//! against this table, so a typo'd name fails CI instead of silently
//! forking a metric series.  Declarations are one `NameDef` per line on
//! purpose: the lint rule extracts the `name: "..."` field line-by-line.
//!
//! Naming convention: `snake_case`, `<subsystem>_<what>[_total]` —
//! `_total` marks monotonic counters (Prometheus convention); gauges are
//! instantaneous levels.  The subsystem prefix (`engine`, `sched`, `kv`,
//! `attn`/`flash`/`decode`, `serve`, `http`, `trace`, `bench`, `test`)
//! doubles as the Chrome trace category.

/// What kind of observable a registry entry names — decides which
/// exposition surface (trace stream vs. metrics snapshot) it appears on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NameKind {
    /// A duration: recorded as a Chrome `"X"` (complete) trace event.
    Span,
    /// A point-in-time fact: recorded as a Chrome `"i"` (instant) event.
    Event,
    /// A monotonically increasing count (Prometheus `counter`).
    Counter,
    /// An instantaneous level (Prometheus `gauge`).
    Gauge,
}

/// One declared observable name.
#[derive(Debug)]
pub struct NameDef {
    pub kind: NameKind,
    pub name: &'static str,
    /// One-line help text, emitted as the Prometheus `# HELP` line.
    pub help: &'static str,
}

use NameKind::{Counter, Event, Gauge, Span};

/// The registry, in exposition order.  Keep each entry on one line.
pub const REGISTRY: &[NameDef] = &[
    // --- spans (trace only) ---
    NameDef { kind: Span, name: "serve_run", help: "one whole repro serve workload" },
    NameDef { kind: Span, name: "engine_step", help: "one engine worker scheduling+decode step" },
    NameDef { kind: Span, name: "sched_plan", help: "one Scheduler::plan admission/preemption decision" },
    NameDef { kind: Span, name: "attn_flash_fwd", help: "one flash forward kernel invocation (whole tensor)" },
    NameDef { kind: Span, name: "attn_flash_bwd", help: "one flash backward kernel invocation (whole tensor)" },
    NameDef { kind: Span, name: "attn_decode_step", help: "one in-place paged decode step over a batch of rows" },
    NameDef { kind: Span, name: "attn_seqpar_fwd", help: "one sequence-parallel ring forward pass (all workers)" },
    NameDef { kind: Span, name: "attn_seqpar_bwd", help: "one sequence-parallel ring backward pass (all workers)" },
    NameDef { kind: Span, name: "bench_overhead_span", help: "no-op span used by the tracing-overhead bench" },
    NameDef { kind: Span, name: "http_request", help: "one HTTP request, parse to last response byte" },
    NameDef { kind: Span, name: "test_span_outer", help: "golden-trace fixture: outer span" },
    NameDef { kind: Span, name: "test_span_inner", help: "golden-trace fixture: inner span" },
    // --- events (trace only; the scheduler rows form the audit log) ---
    NameDef { kind: Event, name: "sched_admit", help: "session admitted: args session, need (blocks)" },
    NameDef { kind: Event, name: "sched_preempt", help: "session preempted: args session, need, victim_of" },
    NameDef { kind: Event, name: "sched_saturate", help: "submit rejected by bounded queue: args need" },
    NameDef { kind: Event, name: "engine_rows", help: "per sub-step row mix: args decode, prefill" },
    NameDef { kind: Event, name: "kv_alloc", help: "arena block grant: args slot, blocks" },
    NameDef { kind: Event, name: "kv_free", help: "arena block release: args slot, blocks" },
    NameDef { kind: Event, name: "http_shed", help: "request shed with 429: args status" },
    NameDef { kind: Event, name: "test_event", help: "golden-trace fixture: instant event" },
    // --- counters (metrics snapshot) ---
    NameDef { kind: Counter, name: "engine_steps_total", help: "engine worker steps that did scheduling or decode work" },
    NameDef { kind: Counter, name: "engine_tokens_total", help: "tokens generated across completed sessions" },
    NameDef { kind: Counter, name: "engine_decode_steps_total", help: "decode sub-steps executed" },
    NameDef { kind: Counter, name: "engine_decode_rows_total", help: "decode rows summed over sub-steps" },
    NameDef { kind: Counter, name: "engine_prefill_rows_total", help: "chunked-prefill rows ridden through the decode seam" },
    NameDef { kind: Counter, name: "engine_cancelled_total", help: "sessions cancelled by the client" },
    NameDef { kind: Counter, name: "engine_prompt_tokens_total", help: "true prompt tokens admitted" },
    NameDef { kind: Counter, name: "engine_prompt_pad_tokens_total", help: "prompt tokens after bucket padding" },
    NameDef { kind: Counter, name: "sched_admissions_total", help: "scheduler admissions granted (incl. resume after preemption)" },
    NameDef { kind: Counter, name: "sched_preemptions_total", help: "sessions preempted by the anti-starvation policy" },
    NameDef { kind: Counter, name: "sched_saturations_total", help: "submits rejected with EngineError::Saturated" },
    NameDef { kind: Counter, name: "attn_tiles_full_total", help: "K-block tiles visited with a Full mask cover" },
    NameDef { kind: Counter, name: "attn_tiles_partial_total", help: "K-block tiles visited with a Partial mask cover" },
    NameDef { kind: Counter, name: "attn_tiles_skipped_total", help: "K-block tiles skipped outright by Mask::cover" },
    NameDef { kind: Counter, name: "flash_fwd_flops_total", help: "FLOPs reported by flash forward invocations" },
    NameDef { kind: Counter, name: "flash_fwd_ns_total", help: "wall nanoseconds inside flash forward invocations" },
    NameDef { kind: Counter, name: "flash_bwd_flops_total", help: "FLOPs reported by flash backward invocations" },
    NameDef { kind: Counter, name: "flash_bwd_ns_total", help: "wall nanoseconds inside flash backward invocations" },
    NameDef { kind: Counter, name: "decode_flops_total", help: "FLOPs of split-KV decode attention (4*ctx*d_head per head)" },
    NameDef { kind: Counter, name: "decode_ns_total", help: "wall nanoseconds inside paged decode steps" },
    NameDef { kind: Counter, name: "kv_block_allocs_total", help: "arena blocks granted" },
    NameDef { kind: Counter, name: "kv_block_frees_total", help: "arena blocks released" },
    NameDef { kind: Counter, name: "trace_events_dropped_total", help: "trace events dropped at the sink capacity ceiling" },
    NameDef { kind: Counter, name: "http_conns_total", help: "TCP connections accepted by the HTTP listener" },
    NameDef { kind: Counter, name: "http_requests_total", help: "HTTP requests parsed (all routes)" },
    NameDef { kind: Counter, name: "http_generate_requests_total", help: "POST /generate requests" },
    NameDef { kind: Counter, name: "http_stream_requests_total", help: "POST /generate_stream requests" },
    NameDef { kind: Counter, name: "http_health_requests_total", help: "GET /health requests" },
    NameDef { kind: Counter, name: "http_metrics_requests_total", help: "GET /metrics scrapes" },
    NameDef { kind: Counter, name: "http_validation_rejects_total", help: "requests rejected 4xx before touching the scheduler" },
    NameDef { kind: Counter, name: "http_shed_total", help: "requests shed with 429 (budget, queue ratio, or engine saturation)" },
    NameDef { kind: Counter, name: "http_5xx_total", help: "responses served with a 5xx status" },
    NameDef { kind: Counter, name: "http_sse_events_total", help: "SSE events written on /generate_stream" },
    NameDef { kind: Counter, name: "http_accept_rejects_total", help: "connections refused 503 at the bounded accept queue" },
    NameDef { kind: Counter, name: "kv_prefix_hits_total", help: "prompt blocks adopted from the prefix cache instead of re-prefilled" },
    NameDef { kind: Counter, name: "kv_prefix_misses_total", help: "cacheable prompt blocks not found in the prefix cache" },
    NameDef { kind: Counter, name: "kv_prefix_evictions_total", help: "zero-ref cached blocks reclaimed (LRU or retained-cap)" },
    NameDef { kind: Counter, name: "kv_prefix_cow_total", help: "copy-on-write block copies triggered by a divergent write" },
    NameDef { kind: Counter, name: "kv_prefix_cached_tokens_total", help: "prompt tokens whose prefill was skipped via cache adoption" },
    NameDef { kind: Counter, name: "seqpar_comm_bytes_total", help: "payload bytes shipped over seqpar ring links" },
    NameDef { kind: Counter, name: "seqpar_comm_msgs_total", help: "messages sent over seqpar ring links" },
    NameDef { kind: Counter, name: "seqpar_steps_total", help: "seqpar ring steps executed (workers per pass)" },
    NameDef { kind: Counter, name: "seqpar_idle_ns_total", help: "per-worker non-compute nanoseconds summed over seqpar passes" },
    NameDef { kind: Counter, name: "seqpar_shards_unshipped_total", help: "KV shards the mask proved never-attended remotely (skipped shipping)" },
    // --- gauges (metrics snapshot) ---
    NameDef { kind: Gauge, name: "kv_blocks_in_use", help: "arena blocks currently granted" },
    NameDef { kind: Gauge, name: "kv_blocks_high_water", help: "max arena blocks ever simultaneously granted" },
    NameDef { kind: Gauge, name: "kv_pool_blocks", help: "arena capacity in blocks" },
    NameDef { kind: Gauge, name: "kv_free_blocks", help: "arena blocks on the free list" },
    NameDef { kind: Gauge, name: "http_inflight_requests", help: "HTTP requests currently being handled" },
    NameDef { kind: Gauge, name: "http_budget_prefill_tokens", help: "prompt tokens currently reserved by router admission" },
    NameDef { kind: Gauge, name: "http_budget_total_tokens", help: "prompt+max_tokens currently reserved by router admission" },
    NameDef { kind: Gauge, name: "http_budget_total_tokens_peak", help: "max total tokens ever simultaneously reserved" },
    NameDef { kind: Gauge, name: "http_generate_latency_p50_us", help: "/generate latency p50 (µs, sampled)" },
    NameDef { kind: Gauge, name: "http_generate_latency_p95_us", help: "/generate latency p95 (µs, sampled)" },
    NameDef { kind: Gauge, name: "http_generate_ttft_p50_us", help: "/generate time-to-first-token p50 (µs, sampled)" },
    NameDef { kind: Gauge, name: "http_generate_ttft_p95_us", help: "/generate time-to-first-token p95 (µs, sampled)" },
    NameDef { kind: Gauge, name: "http_generate_tpot_p50_us", help: "/generate time-per-output-token p50 (µs, sampled)" },
    NameDef { kind: Gauge, name: "http_stream_latency_p50_us", help: "/generate_stream latency p50 (µs, sampled)" },
    NameDef { kind: Gauge, name: "http_stream_latency_p95_us", help: "/generate_stream latency p95 (µs, sampled)" },
    NameDef { kind: Gauge, name: "http_stream_ttft_p50_us", help: "/generate_stream time-to-first-token p50 (µs, sampled)" },
    NameDef { kind: Gauge, name: "http_stream_ttft_p95_us", help: "/generate_stream time-to-first-token p95 (µs, sampled)" },
    NameDef { kind: Gauge, name: "http_stream_tpot_p50_us", help: "/generate_stream time-per-output-token p50 (µs, sampled)" },
    NameDef { kind: Gauge, name: "kv_prefix_cached_blocks", help: "blocks currently registered in the prefix cache index" },
];

/// Index of `name` in [`REGISTRY`], if declared.
pub fn lookup(name: &str) -> Option<usize> {
    REGISTRY.iter().position(|d| d.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_are_unique_and_snake_case() {
        let mut seen = std::collections::HashSet::new();
        for def in REGISTRY {
            assert!(seen.insert(def.name), "duplicate registry name {}", def.name);
            assert!(
                def.name
                    .chars()
                    .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_'),
                "{} is not snake_case",
                def.name
            );
            assert!(!def.help.is_empty(), "{} has no help text", def.name);
        }
    }

    #[test]
    fn lookup_finds_declared_names_only() {
        // 12 spans + 8 events precede the first counter
        assert_eq!(lookup("engine_steps_total"), Some(20));
        assert!(lookup("engine_steps_totall").is_none());
        for (i, def) in REGISTRY.iter().enumerate() {
            assert_eq!(lookup(def.name), Some(i));
        }
    }

    #[test]
    fn counters_end_in_total_and_gauges_do_not() {
        for def in REGISTRY {
            match def.kind {
                NameKind::Counter => assert!(
                    def.name.ends_with("_total"),
                    "counter {} must end in _total",
                    def.name
                ),
                NameKind::Gauge => assert!(
                    !def.name.ends_with("_total"),
                    "gauge {} must not end in _total",
                    def.name
                ),
                _ => {}
            }
        }
    }
}
