//! The counter/gauge registry instance (DESIGN.md §13): one `AtomicU64`
//! cell per declared name, addressed by the [`super::registry`] table.
//!
//! Counters are **always on** — a relaxed `fetch_add` per increment is
//! cheap enough to leave in the hot path unconditionally (the expensive
//! machinery, the trace stream, is what hides behind the enable gate).
//! Two instances matter:
//!
//! - the process-wide [`global()`] instance, which the kernels, the KV
//!   arena, and the scheduler write into and the exposition layer
//!   (`obs::expo`) snapshots;
//! - per-[`crate::coordinator::metrics::Metrics`] **local** instances, so
//!   concurrent engines in one test binary keep independent books (each
//!   `Metrics` mirrors its increments into the global instance).
//!
//! Writes against names missing from the registry are silently dropped —
//! the `obs-name-registry` lint rule makes that unreachable for committed
//! code, and a lint gate beats a runtime panic in a serving hot path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use super::registry::{self, NameDef, REGISTRY};

/// A full set of cells, one per registry entry (spans/events included so
/// indices line up; only Counter/Gauge entries are ever written).
#[derive(Debug)]
pub struct Counters {
    cells: Vec<AtomicU64>,
}

impl Counters {
    pub fn new() -> Counters {
        Counters { cells: (0..REGISTRY.len()).map(|_| AtomicU64::new(0)).collect() }
    }

    fn cell(&self, name: &str) -> Option<&AtomicU64> {
        registry::lookup(name).and_then(|i| self.cells.get(i))
    }

    /// Monotonic increment (counter semantics).
    pub fn add(&self, name: &str, v: u64) {
        if let Some(c) = self.cell(name) {
            c.fetch_add(v, Ordering::Relaxed);
        }
    }

    /// Overwrite (gauge semantics).
    pub fn set(&self, name: &str, v: u64) {
        if let Some(c) = self.cell(name) {
            c.store(v, Ordering::Relaxed);
        }
    }

    /// Raise-only overwrite (high-water gauge semantics).
    pub fn set_max(&self, name: &str, v: u64) {
        if let Some(c) = self.cell(name) {
            c.fetch_max(v, Ordering::Relaxed);
        }
    }

    pub fn get(&self, name: &str) -> u64 {
        self.cell(name).map_or(0, |c| c.load(Ordering::Relaxed))
    }

    /// Every Counter/Gauge entry with its current value, in registry
    /// (= deterministic exposition) order.
    pub fn snapshot(&self) -> Vec<(&'static NameDef, u64)> {
        REGISTRY
            .iter()
            .enumerate()
            .filter(|(_, d)| {
                matches!(d.kind, registry::NameKind::Counter | registry::NameKind::Gauge)
            })
            .map(|(i, d)| (d, self.cells[i].load(Ordering::Relaxed)))
            .collect()
    }

    /// Zero every cell — test isolation for the global instance.
    pub fn reset(&self) {
        for c in &self.cells {
            c.store(0, Ordering::Relaxed);
        }
    }
}

impl Default for Counters {
    fn default() -> Counters {
        Counters::new()
    }
}

/// The process-wide instance the `obs_count!`/`obs_gauge!` macros target.
pub fn global() -> &'static Counters {
    static GLOBAL: OnceLock<Counters> = OnceLock::new();
    GLOBAL.get_or_init(Counters::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_set_and_max_on_a_local_instance() {
        let c = Counters::new();
        c.add("engine_tokens_total", 3);
        c.add("engine_tokens_total", 4);
        assert_eq!(c.get("engine_tokens_total"), 7);
        c.set("kv_blocks_in_use", 5);
        c.set("kv_blocks_in_use", 2);
        assert_eq!(c.get("kv_blocks_in_use"), 2);
        c.set_max("kv_blocks_high_water", 9);
        c.set_max("kv_blocks_high_water", 4);
        assert_eq!(c.get("kv_blocks_high_water"), 9);
        c.reset();
        assert_eq!(c.get("engine_tokens_total"), 0);
    }

    #[test]
    fn unknown_names_are_dropped_not_panicked() {
        let c = Counters::new();
        c.add("no_such_metric_total", 1);
        assert_eq!(c.get("no_such_metric_total"), 0);
    }

    #[test]
    fn snapshot_is_registry_ordered_and_skips_trace_names() {
        let c = Counters::new();
        c.add("sched_admissions_total", 2);
        let snap = c.snapshot();
        assert!(snap.iter().all(|(d, _)| matches!(
            d.kind,
            registry::NameKind::Counter | registry::NameKind::Gauge
        )));
        let names: Vec<&str> = snap.iter().map(|(d, _)| d.name).collect();
        let mut sorted_by_registry = names.clone();
        sorted_by_registry.sort_by_key(|n| registry::lookup(n));
        assert_eq!(names, sorted_by_registry);
        assert!(snap.iter().any(|(d, v)| d.name == "sched_admissions_total" && *v == 2));
    }
}
