//! Metrics exposition (DESIGN.md §13): render a [`Counters`] snapshot as
//! Prometheus text format or a JSON object, in deterministic registry
//! order, so a future HTTP front-end (ROADMAP item 1) serves `/metrics`
//! by calling [`prometheus`] on the global instance — no new bookkeeping.
//!
//! After the declared counters/gauges, [`prometheus`] appends a small set
//! of **derived** gauges (achieved GFLOP/s per kernel, tile skip rate)
//! computed from the raw counters — the FlashAttention-2 headline
//! numbers, precomputed so scrapers need no PromQL.

use std::path::Path;

use super::counters::Counters;
use super::registry::NameKind;
use crate::util::error::{Context, Result};
use crate::util::json::Json;

/// All names carry this prefix on the wire, leaving the in-tree registry
/// names short.
const PREFIX: &str = "fa2";

fn fmt_value(v: f64) -> String {
    // fa2lint: allow(no-float-eq) -- exact integrality test picks the integer rendering
    if v.fract() == 0.0 && v.abs() < 9e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// GFLOP/s from FLOP and nanosecond totals (identical units cancel).
fn gflops(flops: u64, ns: u64) -> Option<f64> {
    (ns > 0).then(|| flops as f64 / ns as f64)
}

/// The derived gauges appended after the registry entries:
/// (name, help, value) in fixed order.  Also consumed by
/// `bench::summary::record_attn_obs` so benches and the exposition
/// layer can never disagree on how GFLOP/s is computed.
pub(crate) fn derived(c: &Counters) -> Vec<(&'static str, &'static str, f64)> {
    let mut out = Vec::new();
    if let Some(g) = gflops(c.get("flash_fwd_flops_total"), c.get("flash_fwd_ns_total")) {
        out.push(("flash_fwd_gflops", "achieved flash forward GFLOP/s (derived)", g));
    }
    if let Some(g) = gflops(c.get("flash_bwd_flops_total"), c.get("flash_bwd_ns_total")) {
        out.push(("flash_bwd_gflops", "achieved flash backward GFLOP/s (derived)", g));
    }
    if let Some(g) = gflops(c.get("decode_flops_total"), c.get("decode_ns_total")) {
        out.push(("decode_gflops", "achieved split-KV decode GFLOP/s (derived)", g));
    }
    let visited = c.get("attn_tiles_full_total") + c.get("attn_tiles_partial_total");
    let skipped = c.get("attn_tiles_skipped_total");
    if visited + skipped > 0 {
        out.push((
            "attn_tile_skip_rate",
            "fraction of K-block tiles Mask::cover skipped (derived)",
            skipped as f64 / (visited + skipped) as f64,
        ));
    }
    out
}

/// Prometheus text exposition format, deterministically ordered.
pub fn prometheus(c: &Counters) -> String {
    let mut out = String::new();
    for (def, v) in c.snapshot() {
        let ty = match def.kind {
            NameKind::Counter => "counter",
            NameKind::Gauge => "gauge",
            // snapshot() never yields these
            NameKind::Span | NameKind::Event => continue,
        };
        out.push_str(&format!(
            "# HELP {p}_{n} {h}\n# TYPE {p}_{n} {t}\n{p}_{n} {v}\n",
            p = PREFIX,
            n = def.name,
            h = def.help,
            t = ty,
            v = v,
        ));
    }
    for (name, help, v) in derived(c) {
        out.push_str(&format!(
            "# HELP {p}_{n} {h}\n# TYPE {p}_{n} gauge\n{p}_{n} {v}\n",
            p = PREFIX,
            n = name,
            h = help,
            v = fmt_value(v),
        ));
    }
    out
}

/// The same snapshot as a JSON object (registry order, derived gauges
/// last) — the shape a `/metrics?format=json` endpoint would serve.
pub fn json_snapshot(c: &Counters) -> Json {
    let mut fields: Vec<(String, Json)> = c
        .snapshot()
        .into_iter()
        .map(|(def, v)| (format!("{PREFIX}_{}", def.name), Json::Num(v as f64)))
        .collect();
    for (name, _, v) in derived(c) {
        fields.push((format!("{PREFIX}_{name}"), Json::Num(v)));
    }
    Json::Obj(fields)
}

/// Write the Prometheus rendering to `path` (parents created).
pub fn write_prometheus(path: &Path, c: &Counters) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
    }
    std::fs::write(path, prometheus(c))
        .with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prometheus_rendering_is_deterministic_and_prefixed() {
        let c = Counters::new();
        c.add("sched_admissions_total", 3);
        c.set("kv_blocks_in_use", 5);
        let a = prometheus(&c);
        let b = prometheus(&c);
        assert_eq!(a, b, "same snapshot must render byte-identically");
        assert!(a.contains("# TYPE fa2_sched_admissions_total counter\n"));
        assert!(a.contains("\nfa2_sched_admissions_total 3\n"));
        assert!(a.contains("# TYPE fa2_kv_blocks_in_use gauge\n"));
        assert!(a.contains("\nfa2_kv_blocks_in_use 5\n"));
        // no derived gauges without kernel activity
        assert!(!a.contains("gflops"));
    }

    #[test]
    fn derived_gauges_appear_with_kernel_activity() {
        let c = Counters::new();
        c.add("flash_fwd_flops_total", 200);
        c.add("flash_fwd_ns_total", 100);
        c.add("attn_tiles_full_total", 3);
        c.add("attn_tiles_skipped_total", 1);
        let p = prometheus(&c);
        assert!(p.contains("\nfa2_flash_fwd_gflops 2\n"));
        assert!(p.contains("\nfa2_attn_tile_skip_rate 0.25\n"));
        let j = json_snapshot(&c);
        let skip = j.get("fa2_attn_tile_skip_rate").and_then(Json::as_f64);
        assert!(skip.is_some_and(|v| (v - 0.25).abs() < 1e-12));
    }

    #[test]
    fn json_snapshot_matches_prometheus_values() {
        let c = Counters::new();
        c.add("engine_tokens_total", 42);
        let j = json_snapshot(&c);
        assert_eq!(j.get("fa2_engine_tokens_total").and_then(Json::as_i64), Some(42));
        // every non-comment prometheus line appears in the json object
        for line in prometheus(&c).lines().filter(|l| !l.starts_with('#')) {
            let mut it = line.split_whitespace();
            let (name, val) = (it.next().unwrap(), it.next().unwrap());
            let got = j.get(name).and_then(Json::as_f64).unwrap();
            let want: f64 = val.parse().unwrap();
            assert!((got - want).abs() < 1e-9, "{name}: {got} != {want}");
        }
    }
}
