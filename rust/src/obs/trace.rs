//! Low-overhead span/event recorder with a Chrome trace-event JSON
//! exporter (DESIGN.md §13).
//!
//! Disabled (the default), the entire cost of an `obs_span!` /
//! `obs_event!` call site is **one relaxed atomic load** — no clock read,
//! no allocation, no branch into recording code.  Enabled, events land in
//! a thread-local buffer and spill to a global sink under a mutex only
//! when the buffer fills (or the thread exits), so the serving hot path
//! never takes a lock per event.
//!
//! Two clocks: the default monotonic clock stamps microseconds since the
//! first enable (what Perfetto expects); **logical-clock mode**
//! ([`set_logical`]) stamps a global tick per timestamp and pins every
//! thread id to 0, making a single-threaded recording byte-deterministic —
//! the golden-trace tests run on it.
//!
//! The exporter doubles as a validator: every span guard must have
//! dropped before [`export_json`] — a nonzero open-span count fails the
//! export (and `ci.sh --verify-trace` proves that failure path fires, via
//! [`inject_unclosed`]).

use std::cell::RefCell;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Instant;

use crate::bail;
use crate::util::error::{Context, Result};
use crate::util::json::Json;

/// Thread-local buffer capacity before spilling to the global sink.
const RING_CAP: usize = 4096;
/// Hard ceiling on retained events; beyond it new events are counted in
/// `trace_events_dropped_total` and discarded (bounded memory beats an
/// unbounded trace of a long serve).
const SINK_CAP: usize = 1 << 20;

static ENABLED: AtomicBool = AtomicBool::new(false);
static LOGICAL: AtomicBool = AtomicBool::new(false);
/// Logical-mode tick source.
static TICK: AtomicU64 = AtomicU64::new(0);
/// Span guards created minus span guards dropped — the unclosed-span
/// validator the exporter checks.
static OPEN_SPANS: AtomicI64 = AtomicI64::new(0);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

/// The one load every disabled-path call site pays.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

pub fn set_enabled(on: bool) {
    if on {
        // pin the epoch before the first event so ts=0 is the enable point
        let _ = epoch();
    }
    ENABLED.store(on, Ordering::SeqCst);
}

/// Deterministic mode: timestamps become global ticks, thread ids 0.
pub fn set_logical(on: bool) {
    LOGICAL.store(on, Ordering::SeqCst);
}

/// Chrome trace phases this recorder emits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ph {
    /// `"X"`: a complete event with a duration (a closed span).
    Complete,
    /// `"i"`: an instant event.
    Instant,
}

/// One recorded trace event (µs or logical ticks in `ts`/`dur`).
#[derive(Debug, Clone)]
pub struct Event {
    pub name: &'static str,
    pub ph: Ph,
    pub ts: u64,
    pub dur: u64,
    pub tid: u64,
    pub args: Vec<(&'static str, u64)>,
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn sink() -> MutexGuard<'static, Vec<Event>> {
    static SINK: OnceLock<Mutex<Vec<Event>>> = OnceLock::new();
    match SINK.get_or_init(|| Mutex::new(Vec::new())).lock() {
        Ok(g) => g,
        // a panicking recorder thread must not wedge every later export
        Err(poisoned) => poisoned.into_inner(),
    }
}

fn now_ts() -> u64 {
    if LOGICAL.load(Ordering::Relaxed) {
        TICK.fetch_add(1, Ordering::Relaxed)
    } else {
        epoch().elapsed().as_micros() as u64
    }
}

fn this_tid() -> u64 {
    if LOGICAL.load(Ordering::Relaxed) {
        return 0;
    }
    thread_local! {
        static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    }
    TID.with(|t| *t)
}

/// Thread-local event buffer; spills on fill and on thread exit.
struct Ring {
    buf: Vec<Event>,
}

impl Drop for Ring {
    fn drop(&mut self) {
        spill(&mut self.buf);
    }
}

thread_local! {
    static RING: RefCell<Ring> = RefCell::new(Ring { buf: Vec::new() });
}

fn spill(buf: &mut Vec<Event>) {
    if buf.is_empty() {
        return;
    }
    let mut g = sink();
    let room = SINK_CAP.saturating_sub(g.len());
    let take = room.min(buf.len());
    let dropped = (buf.len() - take) as u64;
    g.extend(buf.drain(..take));
    buf.clear();
    drop(g);
    if dropped > 0 {
        super::counters::global().add("trace_events_dropped_total", dropped);
    }
}

fn push(ev: Event) {
    RING.with(|r| {
        let mut r = r.borrow_mut();
        if r.buf.len() >= RING_CAP {
            spill(&mut r.buf);
        }
        r.buf.push(ev);
    });
}

/// Move this thread's buffered events into the global sink.
pub fn flush() {
    RING.with(|r| spill(&mut r.borrow_mut().buf));
}

/// Drop every recorded event and re-arm the validator/clock — test
/// isolation between recordings in one process.
pub fn reset() {
    flush();
    sink().clear();
    TICK.store(0, Ordering::SeqCst);
    OPEN_SPANS.store(0, Ordering::SeqCst);
}

/// An open span: records a Complete event over its lifetime.  Inert (no
/// clock read, nothing recorded) when tracing was disabled at creation.
pub struct SpanGuard(Option<OpenSpan>);

struct OpenSpan {
    name: &'static str,
    ts: u64,
    tid: u64,
}

/// Open a span.  Prefer the [`crate::obs_span!`] macro, which the
/// `obs-name-registry` lint rule can see.
pub fn span(name: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard(None);
    }
    OPEN_SPANS.fetch_add(1, Ordering::Relaxed);
    SpanGuard(Some(OpenSpan { name, ts: now_ts(), tid: this_tid() }))
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(open) = self.0.take() {
            OPEN_SPANS.fetch_sub(1, Ordering::Relaxed);
            let end = now_ts();
            push(Event {
                name: open.name,
                ph: Ph::Complete,
                ts: open.ts,
                dur: end.saturating_sub(open.ts),
                tid: open.tid,
                args: Vec::new(),
            });
        }
    }
}

/// Record an instant event.  Prefer the [`crate::obs_event!`] macro.
pub fn event(name: &'static str, args: &[(&'static str, u64)]) {
    if !enabled() {
        return;
    }
    push(Event {
        name,
        ph: Ph::Instant,
        ts: now_ts(),
        dur: 0,
        tid: this_tid(),
        args: args.to_vec(),
    });
}

/// Current open-span count (the validator's input).
pub fn open_spans() -> i64 {
    OPEN_SPANS.load(Ordering::SeqCst)
}

/// The `--verify-trace` fixture: leak one span guard so the export
/// validator must fail.  No-op while tracing is disabled.
pub fn inject_unclosed() {
    std::mem::forget(span("engine_step"));
}

/// The `"cat"` field: the subsystem prefix of the name.
fn category(name: &str) -> &str {
    name.split('_').next().unwrap_or(name)
}

/// Render everything recorded so far as a Chrome trace-event JSON
/// document (the `{"traceEvents": [...]}` object form), validating that
/// every span closed.  Deterministic: events are sorted by
/// (ts, tid, name) and the serializer is the in-tree compact writer.
pub fn export_json() -> Result<String> {
    flush();
    let open = open_spans();
    if open != 0 {
        bail!(
            "trace validator: {open} span(s) never closed — every obs_span! \
             guard must drop before export"
        );
    }
    let mut evs: Vec<Event> = sink().clone();
    evs.sort_by(|a, b| {
        (a.ts, a.tid, a.name).cmp(&(b.ts, b.tid, b.name))
    });
    let mut arr = Vec::with_capacity(evs.len());
    for e in &evs {
        let mut obj = vec![
            ("name".to_string(), Json::Str(e.name.to_string())),
            ("cat".to_string(), Json::Str(category(e.name).to_string())),
            ("ph".to_string(), Json::Str(match e.ph {
                Ph::Complete => "X".to_string(),
                Ph::Instant => "i".to_string(),
            })),
            ("pid".to_string(), Json::Num(1.0)),
            ("tid".to_string(), Json::Num(e.tid as f64)),
            ("ts".to_string(), Json::Num(e.ts as f64)),
        ];
        match e.ph {
            Ph::Complete => obj.push(("dur".to_string(), Json::Num(e.dur as f64))),
            Ph::Instant => obj.push(("s".to_string(), Json::Str("t".to_string()))),
        }
        if !e.args.is_empty() {
            let args = e
                .args
                .iter()
                .map(|(k, v)| (k.to_string(), Json::Num(*v as f64)))
                .collect();
            obj.push(("args".to_string(), Json::Obj(args)));
        }
        arr.push(Json::Obj(obj));
    }
    let root = Json::Obj(vec![
        ("traceEvents".to_string(), Json::Arr(arr)),
        ("displayTimeUnit".to_string(), Json::Str("ms".to_string())),
    ]);
    Ok(root.to_string())
}

/// Export to `path` (parent directories created); returns the event
/// count.  Fails — nonzero exit from the CLI — on an unclosed span.
pub fn export_to(path: &Path) -> Result<usize> {
    let doc = export_json()?;
    let n = sink().len();
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
    }
    std::fs::write(path, &doc).with_context(|| format!("writing {}", path.display()))?;
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: tests that flip the global enable gate live in the dedicated
    // integration binary rust/tests/obs_trace.rs (their own process,
    // serialized there); the unit tests here only exercise the
    // disabled path and pure helpers, so they cannot pollute parallel
    // lib tests.

    #[test]
    fn disabled_span_and_event_record_nothing() {
        assert!(!enabled());
        let g = span("engine_step");
        event("sched_admit", &[("session", 1)]);
        drop(g);
        assert_eq!(open_spans(), 0);
        flush();
        assert!(sink().is_empty());
    }

    #[test]
    fn disabled_inject_is_a_noop() {
        assert!(!enabled());
        inject_unclosed();
        assert_eq!(open_spans(), 0);
    }

    #[test]
    fn categories_come_from_the_name_prefix() {
        assert_eq!(category("engine_step"), "engine");
        assert_eq!(category("sched_admit"), "sched");
        assert_eq!(category("kv_alloc"), "kv");
    }
}
