//! End-to-end observability (DESIGN.md §13): structured tracing, a named
//! counter/gauge registry, and a metrics exposition layer — all in-tree,
//! zero dependencies.
//!
//! Three layers:
//!
//! - [`trace`] — span/event recorder with a Chrome trace-event JSON
//!   exporter (`repro serve --trace reports/trace.json`, load the file in
//!   Perfetto or `chrome://tracing`).  Disabled, a call site costs one
//!   relaxed atomic load.
//! - [`counters`] — one `AtomicU64` per name declared in [`registry`];
//!   always on.  `coordinator::metrics::Metrics` reads its books from a
//!   local instance of this registry and mirrors into the global one.
//! - [`expo`] — Prometheus-text / JSON snapshot rendering
//!   (`--metrics-out reports/metrics.prom`), deterministic ordering.
//!
//! Every name must be declared in [`registry::REGISTRY`]; the
//! `obs-name-registry` lint rule (DESIGN.md §12) cross-checks all
//! `obs_*!` call sites against it, which is why instrumentation goes
//! through these macros rather than the module functions: the macro call
//! shape `obs_xxx!("name"` is what the rule greps for.

pub mod counters;
pub mod expo;
pub mod registry;
pub mod trace;

/// Open a span; returns a guard recording a trace event on drop.
/// `let _sp = obs_span!("engine_step");` — name must be registered.
#[macro_export]
macro_rules! obs_span {
    ($name:literal) => {
        $crate::obs::trace::span($name)
    };
}

/// Record an instant event with numeric args:
/// `obs_event!("sched_admit", "session" => id, "need" => n);`
/// Args are not evaluated while tracing is disabled.
#[macro_export]
macro_rules! obs_event {
    ($name:literal $(, $k:literal => $v:expr)* $(,)?) => {
        if $crate::obs::trace::enabled() {
            $crate::obs::trace::event($name, &[$(($k, ($v) as u64)),*]);
        }
    };
}

/// Increment a registered counter on the global registry instance.
#[macro_export]
macro_rules! obs_count {
    ($name:literal, $v:expr) => {
        $crate::obs::counters::global().add($name, ($v) as u64)
    };
}

/// Set a registered gauge on the global registry instance.
#[macro_export]
macro_rules! obs_gauge {
    ($name:literal, $v:expr) => {
        $crate::obs::counters::global().set($name, ($v) as u64)
    };
}

/// Raise-only gauge update (high-water marks).
#[macro_export]
macro_rules! obs_gauge_max {
    ($name:literal, $v:expr) => {
        $crate::obs::counters::global().set_max($name, ($v) as u64)
    };
}
