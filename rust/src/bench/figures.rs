//! Regenerate the paper's figures 4-7: attention throughput (TFLOPs/s) vs
//! sequence length for standard / FlashAttention / Triton / FlashAttention-2,
//! across {causal, non-causal} x {head_dim 64, 128}, on A100 (figs 4-6) and
//! H100 (fig 7).
//!
//! Output: CSV rows + an ASCII chart per sub-figure + shape assertions (the
//! reproduction bands from DESIGN.md section 4: who wins, by what factor).

use std::fmt::Write as _;

use crate::attn::{simulate_tflops, AttnProblem, Method, Pass};
use crate::gpusim::Device;
use crate::util::pool;

pub const SEQLENS: [u64; 6] = [512, 1024, 2048, 4096, 8192, 16384];

/// One sub-figure (a panel in the paper's figure grid).
#[derive(Debug, Clone)]
pub struct Panel {
    pub device: Device,
    pub pass: Pass,
    pub head_dim: u64,
    pub causal: bool,
}

impl Panel {
    pub fn title(&self) -> String {
        format!(
            "{}, {} head_dim={} {}",
            self.device.name,
            match self.pass {
                Pass::Fwd => "fwd",
                Pass::Bwd => "bwd",
                Pass::FwdBwd => "fwd+bwd",
            },
            self.head_dim,
            if self.causal { "causal" } else { "no-mask" },
        )
    }
}

/// A measured/simulated series: TFLOPs/s per seqlen for one method.
#[derive(Debug, Clone)]
pub struct Series {
    pub method: Method,
    pub tflops: Vec<f64>,
}

pub struct PanelResult {
    pub panel: Panel,
    pub series: Vec<Series>,
}

/// One grid point of the sweep: a full seqlen series for (panel, method).
fn series_for(panel: &Panel, method: Method) -> Series {
    Series {
        method,
        tflops: SEQLENS
            .iter()
            .map(|&n| {
                let p = AttnProblem::paper_setting(n, panel.head_dim, panel.causal);
                simulate_tflops(&panel.device, &p, method, panel.pass) / 1e12
            })
            .collect(),
    }
}

pub fn run_panel(panel: &Panel) -> PanelResult {
    let series = Method::all()
        .into_iter()
        .map(|method| series_for(panel, method))
        .collect();
    PanelResult { panel: panel.clone(), series }
}

/// The panels of one paper figure.
pub fn figure_panels(fig: u32) -> Vec<Panel> {
    let (device, pass) = match fig {
        4 => (Device::a100(), Pass::FwdBwd),
        5 => (Device::a100(), Pass::Fwd),
        6 => (Device::a100(), Pass::Bwd),
        7 => (Device::h100(), Pass::FwdBwd),
        _ => panic!("unknown figure {fig} (paper has figures 4-7)"),
    };
    let mut panels = Vec::new();
    for causal in [false, true] {
        for head_dim in [64, 128] {
            panels.push(Panel { device: device.clone(), pass, head_dim, causal });
        }
    }
    panels
}

/// Regenerate one figure, fanning the independent (panel × method) grid
/// points across the work-stealing pool.  `par_map` preserves input order,
/// so the assembled panels — and therefore `to_csv` — are byte-identical to
/// a serial run (`FA2_POOL_THREADS=1`).
pub fn run_figure(fig: u32) -> Vec<PanelResult> {
    let panels = figure_panels(fig);
    let jobs: Vec<(usize, Method)> = panels
        .iter()
        .enumerate()
        .flat_map(|(i, _)| Method::all().into_iter().map(move |m| (i, m)))
        .collect();
    let series = pool::par_map(jobs, |(i, m)| series_for(&panels[i], m));
    let per_panel = Method::all().len();
    let mut it = series.into_iter();
    panels
        .iter()
        .map(|panel| PanelResult {
            panel: panel.clone(),
            series: it.by_ref().take(per_panel).collect(),
        })
        .collect()
}

/// CSV for all panels of a figure (matches the paper's plotted series).
pub fn to_csv(results: &[PanelResult]) -> String {
    let mut out = String::from("figure_panel,device,pass,head_dim,causal,method,seqlen,tflops\n");
    for r in results {
        for s in &r.series {
            for (i, &n) in SEQLENS.iter().enumerate() {
                let _ = writeln!(
                    out,
                    "{},{},{:?},{},{},{},{},{:.1}",
                    r.panel.title(),
                    r.panel.device.name,
                    r.panel.pass,
                    r.panel.head_dim,
                    r.panel.causal,
                    s.method.name(),
                    n,
                    s.tflops[i]
                );
            }
        }
    }
    out
}

/// ASCII rendering of one panel (the terminal stand-in for the paper plot).
pub fn render_ascii(r: &PanelResult) -> String {
    let mut out = String::new();
    let peak = r.panel.device.matmul_flops / 1e12;
    let _ = writeln!(out, "── {} (peak {peak:.0} TFLOPs/s) ──", r.panel.title());
    let _ = writeln!(
        out,
        "{:<18} {}",
        "method",
        SEQLENS.iter().map(|n| format!("{n:>7}")).collect::<String>()
    );
    let max = r
        .series
        .iter()
        .flat_map(|s| s.tflops.iter())
        .cloned()
        .fold(1.0f64, f64::max);
    for s in &r.series {
        let _ = write!(out, "{:<18}", s.method.name());
        for &t in &s.tflops {
            let _ = write!(out, "{t:>7.0}");
        }
        let _ = writeln!(out);
        // bar chart line
        let _ = write!(out, "{:<18}", "");
        for &t in &s.tflops {
            let w = ((t / max) * 6.0).round() as usize;
            let _ = write!(out, "{:>7}", "▇".repeat(w.max(1)));
        }
        let _ = writeln!(out);
    }
    out
}

/// Shape assertions: the reproduction bands.  Returns a list of human-
/// readable check results; `ok == false` on any row fails the bench.
#[derive(Debug)]
pub struct BandCheck {
    pub name: String,
    pub value: f64,
    pub lo: f64,
    pub hi: f64,
    pub ok: bool,
}

fn check(name: String, value: f64, lo: f64, hi: f64) -> BandCheck {
    BandCheck { name, value, lo, hi, ok: value >= lo && value <= hi }
}

fn series<'a>(r: &'a PanelResult, m: Method) -> &'a [f64] {
    &r.series.iter().find(|s| s.method == m).unwrap().tflops
}

/// Bands for the A100 figures, from the paper's section 4.1 claims.
pub fn check_bands(results: &[PanelResult], pass: Pass) -> Vec<BandCheck> {
    let mut checks = Vec::new();
    for r in results {
        let title = r.panel.title();
        let peak = r.panel.device.matmul_flops / 1e12;
        let fa2 = series(r, Method::Flash2);
        let fa1 = series(r, Method::Flash1);
        let tri = series(r, Method::Triton);
        let std_ = series(r, Method::Standard);
        // "FlashAttention-2 is 1.7-3.0x faster than FlashAttention": checked
        // as a geometric mean over the sweep, plus loose pointwise rails
        // (the ratio legitimately explodes at 16k where FA1's grid is 16-32
        // blocks on 108 SMs — that IS the paper's occupancy argument).
        let geomean = ((0..SEQLENS.len())
            .map(|i| (fa2[i] / fa1[i]).ln())
            .sum::<f64>()
            / SEQLENS.len() as f64)
            .exp();
        checks.push(check(format!("{title}: FA2/FA1 geomean"), geomean, 1.5, 3.6));
        for i in 0..SEQLENS.len() {
            checks.push(check(
                format!("{title}: FA2/FA1 @n={}", SEQLENS[i]),
                fa2[i] / fa1[i],
                1.2,
                16.0,
            ));
        }
        // "1.3-2.5x faster than FlashAttention in Triton" (fwd; ~2x bwd)
        let mid = 2;
        checks.push(check(
            format!("{title}: FA2/Triton @n={}", SEQLENS[mid]),
            fa2[mid] / tri[mid],
            1.2,
            2.8,
        ));
        // "3-10x faster than a standard attention implementation" (the
        // causal panels exceed 10x because standard is charged the halved
        // FLOP count while executing the full square — same accounting as
        // the paper's figures)
        for i in 2..SEQLENS.len() {
            checks.push(check(
                format!("{title}: FA2/standard @n={}", SEQLENS[i]),
                fa2[i] / std_[i],
                2.5,
                22.0,
            ));
        }
        // Peak efficiency: fwd "up to 73%", bwd "up to 63%" of max.
        let best = fa2.iter().cloned().fold(0.0f64, f64::max) / peak;
        match pass {
            Pass::Fwd => checks.push(check(
                format!("{title}: FA2 peak fraction (fwd)"),
                best,
                0.55,
                0.80,
            )),
            Pass::Bwd => checks.push(check(
                format!("{title}: FA2 peak fraction (bwd)"),
                best,
                0.45,
                0.70,
            )),
            Pass::FwdBwd => checks.push(check(
                format!("{title}: FA2 peak fraction (fwd+bwd)"),
                best,
                0.45,
                0.75,
            )),
        }
        // FA2 should hold throughput flat (or rising) with seqlen — that is
        // the whole point of seqlen parallelism. Allow 15% sag.
        let sag = fa2[SEQLENS.len() - 1] / fa2.iter().cloned().fold(0.0f64, f64::max);
        checks.push(check(format!("{title}: FA2 long-seq retention"), sag, 0.85, 1.01));
        // FA1 must DROP with seqlen in the fixed-token setting (occupancy).
        let fa1_drop = fa1[SEQLENS.len() - 1] / fa1[0];
        checks.push(check(format!("{title}: FA1 long-seq decline"), fa1_drop, 0.05, 0.9));
    }
    checks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure4_panels_cover_the_grid() {
        let panels = figure_panels(4);
        assert_eq!(panels.len(), 4);
        assert!(panels.iter().any(|p| p.causal && p.head_dim == 128));
    }

    #[test]
    fn csv_has_all_rows() {
        let results = run_figure(5);
        let csv = to_csv(&results);
        // 4 panels x 4 methods x 6 seqlens + header
        assert_eq!(csv.lines().count(), 1 + 4 * 4 * 6);
    }

    #[test]
    fn h100_beats_a100_for_fa2() {
        let a = run_panel(&Panel { device: Device::a100(), pass: Pass::FwdBwd, head_dim: 128, causal: false });
        let h = run_panel(&Panel { device: Device::h100(), pass: Pass::FwdBwd, head_dim: 128, causal: false });
        let fa2_a = series(&a, Method::Flash2);
        let fa2_h = series(&h, Method::Flash2);
        for i in 0..SEQLENS.len() {
            assert!(fa2_h[i] > fa2_a[i]);
        }
        // paper fig 7: up to ~335 TFLOPs/s on H100 with the same kernels
        let peak_h = fa2_h.iter().cloned().fold(0.0f64, f64::max);
        assert!(peak_h > 280.0 && peak_h < 390.0, "H100 peak {peak_h}");
    }
}
