//! Benchmark harness: one module per paper artifact (figures 4-7, table 1),
//! plus runtime microbenches.  `cargo bench` targets and the `repro figures`
//! CLI both call into here.

pub mod figures;
pub mod summary;
pub mod table1;
