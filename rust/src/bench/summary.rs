//! Machine-readable bench summaries and the CI regression gate.
//!
//! Every bench target under `benches/` records its headline numbers
//! (GFLOP/s, tokens/s, µs/step, ...) into one unified
//! `reports/bench_summary.json` via [`merge_into`] — each bench replaces
//! its *own* entries and preserves everyone else's, so running the suite
//! piecewise still converges on a complete summary.  `repro bench-gate`
//! (main.rs) then compares the summary against the checked-in
//! `benches/baseline.json` and fails CI when any metric regressed by more
//! than the tolerance (default 15%); `./ci.sh --update-baseline` re-pins.
//!
//! `FA2_BENCH_INJECT_SLOWDOWN=<factor>` worsens every recorded value by
//! `factor` (divides higher-is-better metrics, multiplies lower-is-better
//! ones).  It exists so the gate itself can be exercised end to end:
//! `FA2_BENCH_INJECT_SLOWDOWN=1.2 ./ci.sh` must fail the bench-gate step.

use std::path::{Path, PathBuf};

use crate::util::error::{Context, Result};
use crate::util::json::Json;

/// Where benches accumulate the current run's summary (workspace-root
/// relative; resolve with [`summary_path`]).
pub const SUMMARY_PATH: &str = "reports/bench_summary.json";
/// The checked-in reference the gate compares against (workspace-root
/// relative; resolve with [`baseline_path`]).
pub const BASELINE_PATH: &str = "benches/baseline.json";

/// The workspace root, independent of who is running: cargo sets the cwd
/// of bench/test binaries to the *package* root (rust/), while `cargo
/// run`/ci.sh inherit the invoker's cwd (the workspace root).  Anchor on
/// ci.sh so both sides read and write the SAME summary/baseline files.
pub fn workspace_root() -> PathBuf {
    if Path::new("ci.sh").exists() {
        PathBuf::from(".")
    } else if Path::new("../ci.sh").exists() {
        PathBuf::from("..")
    } else {
        PathBuf::from(".")
    }
}

/// `SUMMARY_PATH` anchored to the workspace root.
pub fn summary_path() -> PathBuf {
    workspace_root().join(SUMMARY_PATH)
}

/// `BASELINE_PATH` anchored to the workspace root.
pub fn baseline_path() -> PathBuf {
    workspace_root().join(BASELINE_PATH)
}

/// One (bench, config, metric) measurement.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchRecord {
    /// Bench target, e.g. "coordinator_hotpath".
    pub bench: String,
    /// Case within the bench, e.g. "decode_b4" or "fwd_t4".
    pub config: String,
    /// Metric name, e.g. "gflops" or "tokens_per_sec".
    pub metric: String,
    pub value: f64,
    pub unit: String,
    /// Direction: true for throughput-like metrics, false for latencies.
    pub higher_is_better: bool,
}

impl BenchRecord {
    pub fn key(&self) -> String {
        format!("{}/{}/{}", self.bench, self.config, self.metric)
    }
}

/// The injected-slowdown test hook (1.0 = off).
pub fn slowdown_factor() -> f64 {
    std::env::var("FA2_BENCH_INJECT_SLOWDOWN")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .filter(|f| f.is_finite() && *f > 0.0)
        .unwrap_or(1.0)
}

fn apply_slowdown(value: f64, higher_is_better: bool, factor: f64) -> f64 {
    // fa2lint: allow(no-float-eq) -- 1.0 is the exact "injection hook off" default from slowdown_factor()
    if factor == 1.0 {
        value
    } else if higher_is_better {
        value / factor
    } else {
        value * factor
    }
}

/// Build a record, applying `FA2_BENCH_INJECT_SLOWDOWN` — benches must
/// construct their entries through here so the gate's failure path stays
/// testable end to end.
pub fn record(
    bench: &str,
    config: &str,
    metric: &str,
    value: f64,
    unit: &str,
    higher_is_better: bool,
) -> BenchRecord {
    BenchRecord {
        bench: bench.to_string(),
        config: config.to_string(),
        metric: metric.to_string(),
        value: apply_slowdown(value, higher_is_better, slowdown_factor()),
        unit: unit.to_string(),
        higher_is_better,
    }
}

fn to_json(records: &[BenchRecord]) -> Json {
    let mut sorted: Vec<&BenchRecord> = records.iter().collect();
    sorted.sort_by_key(|r| r.key());
    Json::Obj(vec![
        ("version".into(), Json::Num(1.0)),
        (
            "benches".into(),
            Json::Arr(
                sorted
                    .iter()
                    .map(|r| {
                        Json::Obj(vec![
                            ("bench".into(), Json::Str(r.bench.clone())),
                            ("config".into(), Json::Str(r.config.clone())),
                            ("metric".into(), Json::Str(r.metric.clone())),
                            ("value".into(), Json::Num(r.value)),
                            ("unit".into(), Json::Str(r.unit.clone())),
                            ("higher_is_better".into(), Json::Bool(r.higher_is_better)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

fn from_json(j: &Json) -> Result<Vec<BenchRecord>> {
    let arr = j
        .get("benches")
        .and_then(|b| b.as_arr())
        .context("bench summary: missing 'benches' array")?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, e) in arr.iter().enumerate() {
        let field = |k: &str| -> Result<&Json> {
            e.get(k).with_context(|| format!("bench summary entry {i}: missing '{k}'"))
        };
        out.push(BenchRecord {
            bench: field("bench")?.as_str().context("'bench' not a string")?.to_string(),
            config: field("config")?.as_str().context("'config' not a string")?.to_string(),
            metric: field("metric")?.as_str().context("'metric' not a string")?.to_string(),
            value: field("value")?.as_f64().context("'value' not a number")?,
            unit: field("unit")?.as_str().context("'unit' not a string")?.to_string(),
            higher_is_better: field("higher_is_better")?
                .as_bool()
                .context("'higher_is_better' not a bool")?,
        });
    }
    Ok(out)
}

/// Load a summary/baseline file; a missing file is an empty record set
/// (callers that care distinguish via `path.exists()`).
pub fn load(path: &Path) -> Result<Vec<BenchRecord>> {
    if !path.exists() {
        return Ok(Vec::new());
    }
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
    from_json(&j)
}

/// Write `records` (sorted by key, deterministic bytes).
pub fn save(path: &Path, records: &[BenchRecord]) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
    }
    std::fs::write(path, to_json(records).to_string() + "\n")
        .with_context(|| format!("writing {}", path.display()))
}

/// Merge this bench run into the unified summary: entries from the benches
/// named in `records` are replaced wholesale; other benches' entries are
/// preserved.
pub fn merge_into(path: &Path, records: &[BenchRecord]) -> Result<()> {
    let mut all = load(path)?;
    all.retain(|old| !records.iter().any(|r| r.bench == old.bench));
    all.extend(records.iter().cloned());
    save(path, &all)
}

/// Append observability-derived kernel metrics to a bench's record set:
/// per-kernel GFLOP/s and the `Mask::cover` tile-skip rate, read from the
/// global obs counter registry (populated passively whenever kernels run
/// in this process).  Counters that never moved contribute nothing, so a
/// bench that exercises only the forward kernel records only
/// `flash_fwd_gflops`.  Shares `obs::expo::derived` with the Prometheus
/// exposition so the two layers can never disagree on the arithmetic.
pub fn record_attn_obs(records: &mut Vec<BenchRecord>, bench: &str, config: &str) {
    for (name, _help, value) in crate::obs::expo::derived(crate::obs::counters::global()) {
        let unit = if name.ends_with("_rate") { "ratio" } else { "gflops" };
        records.push(record(bench, config, name, value, unit, true));
    }
}

/// The gate's verdict over one baseline/current comparison.
#[derive(Debug, Default)]
pub struct GateReport {
    pub compared: usize,
    pub improvements: usize,
    /// Human-readable regression lines — non-empty fails CI.
    pub regressions: Vec<String>,
    /// Metrics measured now but not pinned (warn: re-pin the baseline).
    pub missing_in_baseline: Vec<String>,
    /// Pinned metrics that did not run (warn: a bench silently dropped).
    pub missing_in_current: Vec<String>,
}

/// Compare `current` against `baseline`: a metric regresses when it is
/// worse by strictly more than `tolerance` (0.15 = 15%) in its own
/// direction.  The comparison is on the relative change with a tiny
/// epsilon, so a measurement at exactly the tolerance never flaps on
/// floating-point representation.
pub fn gate(baseline: &[BenchRecord], current: &[BenchRecord], tolerance: f64) -> GateReport {
    const EPS: f64 = 1e-9;
    let mut report = GateReport::default();
    for base in baseline {
        let Some(cur) = current.iter().find(|c| c.key() == base.key()) else {
            report.missing_in_current.push(base.key());
            continue;
        };
        report.compared += 1;
        let change = cur.value / base.value - 1.0;
        let worse = if cur.higher_is_better {
            change < -(tolerance + EPS)
        } else {
            change > tolerance + EPS
        };
        if worse {
            report.regressions.push(format!(
                "{}: {:.4} -> {:.4} {} ({:+.1}%, tolerance {:.0}%)",
                base.key(),
                base.value,
                cur.value,
                cur.unit,
                change * 100.0,
                tolerance * 100.0
            ));
        } else if (cur.higher_is_better && cur.value > base.value)
            || (!cur.higher_is_better && cur.value < base.value)
        {
            report.improvements += 1;
        }
    }
    for cur in current {
        if !baseline.iter().any(|b| b.key() == cur.key()) {
            report.missing_in_baseline.push(cur.key());
        }
    }
    report
}

/// Convenience for bench mains: merge into the workspace-root summary and
/// report where it went.  Benches must not fail the run over a
/// summary-file problem (the gate will complain about the hole instead),
/// so this only prints on error.
pub fn merge_and_announce(records: &[BenchRecord]) {
    let path = summary_path();
    match merge_into(&path, records) {
        Ok(()) => println!(
            "recorded {} bench summary entries -> {}",
            records.len(),
            path.display()
        ),
        Err(e) => eprintln!("WARNING: could not write {}: {e:#}", path.display()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(bench: &str, config: &str, metric: &str, value: f64, hib: bool) -> BenchRecord {
        BenchRecord {
            bench: bench.into(),
            config: config.into(),
            metric: metric.into(),
            value,
            unit: "u".into(),
            higher_is_better: hib,
        }
    }

    #[test]
    fn roundtrips_through_json_deterministically() {
        let dir = std::env::temp_dir().join("fa2_bench_summary_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.json");
        let records =
            vec![rec("b", "cfg2", "m", 2.5, false), rec("a", "cfg1", "gflops", 10.0, true)];
        save(&path, &records).unwrap();
        let loaded = load(&path).unwrap();
        // sorted by key on save
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded[0].key(), "a/cfg1/gflops");
        assert_eq!(loaded[1].key(), "b/cfg2/m");
        assert!(loaded[0].higher_is_better && !loaded[1].higher_is_better);
        let first = std::fs::read(&path).unwrap();
        save(&path, &loaded).unwrap();
        assert_eq!(first, std::fs::read(&path).unwrap(), "bytes must be deterministic");
        // missing file loads as empty; garbage is a typed error
        assert!(load(&dir.join("absent.json")).unwrap().is_empty());
        std::fs::write(&path, "not json").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn merge_replaces_own_bench_and_preserves_others() {
        let dir = std::env::temp_dir().join("fa2_bench_summary_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("merge.json");
        let _ = std::fs::remove_file(&path);
        merge_into(&path, &[rec("attn", "fwd", "gflops", 10.0, true)]).unwrap();
        merge_into(&path, &[rec("hotpath", "decode", "us", 5.0, false)]).unwrap();
        // re-running attn replaces its stale entry (old config dropped)
        merge_into(&path, &[rec("attn", "fwd_v2", "gflops", 12.0, true)]).unwrap();
        let all = load(&path).unwrap();
        let keys: Vec<String> = all.iter().map(|r| r.key()).collect();
        assert_eq!(keys, vec!["attn/fwd_v2/gflops", "hotpath/decode/us"]);
    }

    #[test]
    fn gate_flags_regressions_in_the_right_direction() {
        let baseline = vec![
            rec("a", "c", "thru", 100.0, true),
            rec("a", "c", "lat", 100.0, false),
        ];
        // exactly at tolerance: NOT a regression (strictly-worse rule)
        let r = gate(
            &baseline,
            &[rec("a", "c", "thru", 85.0, true), rec("a", "c", "lat", 115.0, false)],
            0.15,
        );
        assert_eq!(r.compared, 2);
        assert!(r.regressions.is_empty(), "{:?}", r.regressions);
        // past tolerance in each direction: both fail
        let r = gate(
            &baseline,
            &[rec("a", "c", "thru", 80.0, true), rec("a", "c", "lat", 120.0, false)],
            0.15,
        );
        assert_eq!(r.regressions.len(), 2, "{:?}", r.regressions);
        assert!(r.regressions[0].contains("a/c"), "{:?}", r.regressions);
        // improvements counted, never flagged
        let r = gate(
            &baseline,
            &[rec("a", "c", "thru", 130.0, true), rec("a", "c", "lat", 70.0, false)],
            0.15,
        );
        assert!(r.regressions.is_empty());
        assert_eq!(r.improvements, 2);
    }

    #[test]
    fn gate_reports_coverage_holes_both_ways() {
        let baseline = vec![rec("a", "c", "m", 1.0, true), rec("b", "c", "m", 1.0, true)];
        let current = vec![rec("a", "c", "m", 1.0, true), rec("n", "c", "m", 1.0, true)];
        let r = gate(&baseline, &current, 0.15);
        assert_eq!(r.compared, 1);
        assert_eq!(r.missing_in_current, vec!["b/c/m".to_string()]);
        assert_eq!(r.missing_in_baseline, vec!["n/c/m".to_string()]);
    }

    #[test]
    fn record_attn_obs_reads_the_global_registry() {
        // seed the global decode counters; other tests may add on top
        // concurrently, which only moves the ratio — never removes it
        let c = crate::obs::counters::global();
        c.add("decode_flops_total", 2_000);
        c.add("decode_ns_total", 1_000);
        let mut recs = Vec::new();
        record_attn_obs(&mut recs, "hotpath", "obs");
        let g = recs
            .iter()
            .find(|r| r.metric == "decode_gflops")
            .expect("decode_gflops recorded once the counters moved");
        assert!(g.value > 0.0, "ratio of positive totals: {}", g.value);
        assert!(g.higher_is_better && g.unit == "gflops");
        assert!(recs.iter().all(|r| r.bench == "hotpath" && r.config == "obs"));
    }

    #[test]
    fn injected_slowdown_worsens_both_directions() {
        // the pure core of the FA2_BENCH_INJECT_SLOWDOWN hook
        assert_eq!(apply_slowdown(100.0, true, 1.25), 80.0, "throughput divided");
        assert_eq!(apply_slowdown(100.0, false, 1.25), 125.0, "latency multiplied");
        assert_eq!(apply_slowdown(100.0, true, 1.0), 100.0);
    }
}
