//! Regenerate the paper's Table 1: end-to-end GPT-3 training throughput
//! (TFLOPs/s per A100) for {no FlashAttention, FlashAttention, and
//! FlashAttention-2} on GPT3-1.3B and GPT3-2.7B at 2k and 8k context.
//!
//! Model: step time = non-attention GEMM time (Megatron-style, at a
//! calibrated GEMM MFU) + 24/32 layers of attention time from the gpusim
//! schedule models.  Reported TFLOPs/s uses the paper's exact formula
//! (section 4.2): `6 * seqlen * n_params + 12 * n_layer * hidden * seqlen^2`
//! per sequence — attention term NOT halved for causal, "for consistency
//! with the literature".

use std::fmt::Write as _;

use crate::attn::{simulate_time, AttnProblem, Method, Pass};
use crate::gpusim::Device;
use crate::util::pool;

/// Non-attention GEMM MFU for the Megatron-style trainer (calibrated so the
/// FA2 2k rows land on the paper's ~196 TFLOPs/s; see EXPERIMENTS.md).
const GEMM_MFU: f64 = 0.553;

/// A GPT-3 model row of Table 1.
#[derive(Debug, Clone, Copy)]
pub struct GptModel {
    pub name: &'static str,
    pub n_params: f64,
    pub n_layer: u64,
    pub hidden: u64,
    pub n_head: u64,
}

impl GptModel {
    pub fn gpt3_1p3b() -> GptModel {
        GptModel { name: "GPT3-1.3B", n_params: 1.3e9, n_layer: 24, hidden: 2048, n_head: 16 }
    }

    pub fn gpt3_2p7b() -> GptModel {
        GptModel { name: "GPT3-2.7B", n_params: 2.7e9, n_layer: 32, hidden: 2560, n_head: 20 }
    }

    pub fn head_dim(&self) -> u64 {
        self.hidden / self.n_head
    }

    /// Paper section 4.2 FLOPs formula, per sequence.
    pub fn flops_per_seq(&self, seqlen: u64) -> f64 {
        6.0 * seqlen as f64 * self.n_params
            + 12.0 * self.n_layer as f64 * self.hidden as f64 * (seqlen as f64).powi(2)
    }
}

/// One cell of Table 1.
#[derive(Debug, Clone)]
pub struct Cell {
    pub model: &'static str,
    pub seqlen: u64,
    pub method: Method,
    pub tflops_per_gpu: f64,
    pub attn_fraction: f64,
}

/// Simulate one (model, context, method) configuration.
pub fn simulate_cell(
    dev: &Device,
    model: &GptModel,
    seqlen: u64,
    method: Method,
    batch_per_gpu: u64,
) -> Cell {
    let step_flops = model.flops_per_seq(seqlen) * batch_per_gpu as f64;
    // attention share of the formula (the 12*L*h*s^2 term)
    let attn_formula = 12.0 * model.n_layer as f64 * model.hidden as f64
        * (seqlen as f64).powi(2)
        * batch_per_gpu as f64;
    let nonattn_flops = step_flops - attn_formula;
    let t_nonattn = nonattn_flops / (dev.matmul_flops * GEMM_MFU);

    let p = AttnProblem {
        batch: batch_per_gpu,
        heads: model.n_head,
        seqlen,
        head_dim: model.head_dim(),
        causal: true,
        dtype_bytes: 2,
    };
    let t_attn_layer = simulate_time(dev, &p, method, Pass::FwdBwd);
    let t_attn = t_attn_layer * model.n_layer as f64;

    let t = t_nonattn + t_attn;
    Cell {
        model: model.name,
        seqlen,
        method,
        tflops_per_gpu: step_flops / t / 1e12,
        attn_fraction: t_attn / t,
    }
}

/// The paper's Table 1 methods, in column order.
pub fn methods() -> [Method; 3] {
    [Method::Standard, Method::Flash1, Method::Flash2]
}

/// Batch size per GPU: paper trains with tokens-per-GPU roughly constant
/// (16k tokens fits 80GB at these sizes).
pub fn batch_for(seqlen: u64) -> u64 {
    (16 * 1024 / seqlen).max(1)
}

/// Price every (model × context × method) cell; the cells are independent,
/// so they fan out across the work-stealing pool.  `par_map` preserves
/// input order, keeping `render`/`to_csv` byte-identical to a serial run.
pub fn run_table1(dev: &Device) -> Vec<Cell> {
    let mut jobs = Vec::new();
    for model in [GptModel::gpt3_1p3b(), GptModel::gpt3_2p7b()] {
        for seqlen in [2048u64, 8192] {
            for method in methods() {
                jobs.push((model, seqlen, method));
            }
        }
    }
    pool::par_map(jobs, |(model, seqlen, method)| {
        simulate_cell(dev, &model, seqlen, method, batch_for(seqlen))
    })
}

/// Paper's measured values for band checking: (model, seqlen, method) -> TFLOPs/s.
pub fn paper_value(model: &str, seqlen: u64, method: Method) -> f64 {
    match (model, seqlen, method) {
        ("GPT3-1.3B", 2048, Method::Standard) => 142.0,
        ("GPT3-1.3B", 2048, Method::Flash1) => 189.0,
        ("GPT3-1.3B", 2048, Method::Flash2) => 196.0,
        ("GPT3-1.3B", 8192, Method::Standard) => 72.0,
        ("GPT3-1.3B", 8192, Method::Flash1) => 170.0,
        ("GPT3-1.3B", 8192, Method::Flash2) => 220.0,
        ("GPT3-2.7B", 2048, Method::Standard) => 149.0,
        ("GPT3-2.7B", 2048, Method::Flash1) => 189.0,
        ("GPT3-2.7B", 2048, Method::Flash2) => 205.0,
        ("GPT3-2.7B", 8192, Method::Standard) => 80.0,
        ("GPT3-2.7B", 8192, Method::Flash1) => 175.0,
        ("GPT3-2.7B", 8192, Method::Flash2) => 225.0,
        _ => f64::NAN,
    }
}

pub fn render(cells: &[Cell]) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:<12} {:>8} | {:>12} {:>16} {:>18} | attn% (FA2)",
        "Model", "context", "no-FA", "FlashAttention", "FlashAttention-2"
    );
    for model in ["GPT3-1.3B", "GPT3-2.7B"] {
        for seqlen in [2048u64, 8192] {
            let get = |m: Method| {
                cells
                    .iter()
                    .find(|c| c.model == model && c.seqlen == seqlen && c.method == m)
                    .unwrap()
            };
            let _ = writeln!(
                out,
                "{:<12} {:>8} | {:>8.0} TF/s {:>12.0} TF/s {:>14.0} TF/s | {:>5.1}%",
                model,
                seqlen,
                get(Method::Standard).tflops_per_gpu,
                get(Method::Flash1).tflops_per_gpu,
                get(Method::Flash2).tflops_per_gpu,
                get(Method::Flash2).attn_fraction * 100.0,
            );
        }
    }
    out
}

pub fn to_csv(cells: &[Cell]) -> String {
    let mut out = String::from("model,seqlen,method,tflops_per_gpu,paper_tflops,attn_fraction\n");
    for c in cells {
        let _ = writeln!(
            out,
            "{},{},{},{:.1},{:.0},{:.3}",
            c.model,
            c.seqlen,
            c.method.name(),
            c.tflops_per_gpu,
            paper_value(c.model, c.seqlen, c.method),
            c.attn_fraction
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cells() -> Vec<Cell> {
        run_table1(&Device::a100())
    }

    fn get(cells: &[Cell], model: &str, seqlen: u64, m: Method) -> f64 {
        cells
            .iter()
            .find(|c| c.model == model && c.seqlen == seqlen && c.method == m)
            .unwrap()
            .tflops_per_gpu
    }

    #[test]
    fn orderings_match_paper() {
        let cs = cells();
        for model in ["GPT3-1.3B", "GPT3-2.7B"] {
            for seqlen in [2048, 8192] {
                let s = get(&cs, model, seqlen, Method::Standard);
                let f1 = get(&cs, model, seqlen, Method::Flash1);
                let f2 = get(&cs, model, seqlen, Method::Flash2);
                assert!(f2 > f1 && f1 > s, "{model}@{seqlen}: {s} {f1} {f2}");
            }
        }
    }

    #[test]
    fn key_ratios_in_band() {
        let cs = cells();
        // "2.8x speedup compared to a baseline without FlashAttention" (8k)
        let r = get(&cs, "GPT3-1.3B", 8192, Method::Flash2)
            / get(&cs, "GPT3-1.3B", 8192, Method::Standard);
        assert!(r > 2.2 && r < 3.8, "FA2/no-FA @8k = {r}");
        // "1.3x speedup compared to FlashAttention" (8k)
        let r = get(&cs, "GPT3-1.3B", 8192, Method::Flash2)
            / get(&cs, "GPT3-1.3B", 8192, Method::Flash1);
        assert!(r > 1.15 && r < 2.3, "FA2/FA1 @8k = {r}");
        // At 2k attention is a small fraction: methods within 40%.
        let r = get(&cs, "GPT3-1.3B", 2048, Method::Flash2)
            / get(&cs, "GPT3-1.3B", 2048, Method::Standard);
        assert!(r > 1.0 && r < 1.6, "FA2/no-FA @2k = {r}");
    }

    #[test]
    fn absolute_values_within_35_percent_of_paper() {
        for c in cells() {
            let paper = paper_value(c.model, c.seqlen, c.method);
            let rel = (c.tflops_per_gpu - paper).abs() / paper;
            assert!(
                rel < 0.35,
                "{} {} {:?}: {:.0} vs paper {:.0} ({:.0}% off)",
                c.model, c.seqlen, c.method, c.tflops_per_gpu, paper, rel * 100.0
            );
        }
    }

    #[test]
    fn fa2_reaches_paper_headline_mfu() {
        // "up to 225 TFLOPs/s (72% model FLOPs utilization)"
        let cs = cells();
        let best = cs
            .iter()
            .filter(|c| c.method == Method::Flash2)
            .map(|c| c.tflops_per_gpu)
            .fold(0.0f64, f64::max);
        assert!(best > 190.0 && best < 260.0, "best FA2 = {best}");
    }

    #[test]
    fn longer_context_hurts_standard_most() {
        let cs = cells();
        let drop_std = get(&cs, "GPT3-1.3B", 8192, Method::Standard)
            / get(&cs, "GPT3-1.3B", 2048, Method::Standard);
        let drop_fa2 = get(&cs, "GPT3-1.3B", 8192, Method::Flash2)
            / get(&cs, "GPT3-1.3B", 2048, Method::Flash2);
        assert!(drop_std < 0.7, "standard should crater at 8k: {drop_std}");
        assert!(drop_fa2 > 0.85, "FA2 should hold at 8k: {drop_fa2}");
    }

    #[test]
    fn flops_formula_matches_paper_definition() {
        let m = GptModel::gpt3_1p3b();
        let f = m.flops_per_seq(2048);
        let expect = 6.0 * 2048.0 * 1.3e9 + 12.0 * 24.0 * 2048.0 * 2048.0 * 2048.0;
        assert_eq!(f, expect);
    }
}
