//! Run configuration: TOML files under `configs/` parsed with the in-tree
//! `toml_lite` codec into typed structs used by the CLI, trainer and server.

use std::path::Path;

use crate::util::error::{Context, Result};

use crate::train::trainer::TrainConfig;
use crate::util::toml_lite::TomlDoc;

/// Top-level config file (see configs/train_tiny.toml for the schema).
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub artifact_dir: String,
    pub train: TrainConfig,
    pub serve: ServeConfig,
    pub model: ModelConfig,
    pub attn: AttnConfig,
    pub bench: BenchConfig,
}

/// Attention-execution knobs (`[attn]` section) — how `attn-exec` and
/// long-context callers drive the sequence-parallel ring (DESIGN.md §16).
#[derive(Debug, Clone)]
pub struct AttnConfig {
    /// Ring workers for `ExecMode::SeqParallel`; 0 = one per pool thread.
    pub seqpar_workers: usize,
    /// Absolute K/Q chunk granularity in tokens — the unit seqpar
    /// partials merge at (byte identity requires equal chunk, not equal
    /// workers).
    pub seqpar_chunk: usize,
    /// Striped (round-robin) Q-chunk ownership for causal load balance;
    /// false = naive contiguous shards (the bench baseline).
    pub seqpar_stripe: bool,
}

impl Default for AttnConfig {
    fn default() -> Self {
        AttnConfig { seqpar_workers: 0, seqpar_chunk: 64, seqpar_stripe: true }
    }
}

/// Model-shape overrides for the native backend (`[model]` section).
/// Compiled-artifact backends ignore these — their shapes are baked into
/// the manifest.
#[derive(Debug, Clone, Default)]
pub struct ModelConfig {
    /// KV heads for grouped-query attention (None = equal to n_head;
    /// 1 = MQA; must divide n_head).
    pub n_kv_heads: Option<usize>,
    /// Sliding attention window in tokens (None = full causal).
    pub window: Option<usize>,
}

#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub model: String,
    /// Execution backend: "auto" | "native" | "xla" | "stub" (the
    /// `--backend` CLI flag overrides this).
    pub backend: String,
    pub num_requests: usize,
    pub tokens_per_request: usize,
    /// Poisson arrival rate (requests/second); 0 = closed-loop.
    pub arrival_rate: f64,
    pub seed: u64,
    /// Sampling temperature for the workload's sessions; 0 = greedy.
    pub temperature: f32,
    /// Top-k sampling cutoff; 0 = full vocabulary.
    pub top_k: usize,
    /// Stream the first session's `TokenEvent`s to stdout (`--stream`).
    pub stream: bool,
    /// Scheduler mode: "continuous" (default) | "gang" (wave baseline).
    pub sched: String,
    /// Concurrently admitted sessions.
    pub max_in_flight: usize,
    /// Prompt tokens a prefilling session advances per scheduler step.
    pub prefill_chunk: usize,
    /// KV paging granularity in tokens (`--kv-block`); admission reserves
    /// blocks of this size against real arena availability.
    pub kv_block: usize,
    /// Total KV blocks the arena holds (`--kv-blocks`); 0 = enough for
    /// `max_in_flight` full windows.
    pub kv_blocks: usize,
    /// Enable copy-on-write prefix caching over the KV arena
    /// (`--prefix-cache`, DESIGN.md §15): sessions adopt the full KV
    /// blocks their prompt shares with a cached prefix instead of
    /// re-prefilling them.
    pub prefix_cache: bool,
    /// Max cached blocks retained after their publisher retires
    /// (`--prefix-cache-blocks`; 0 = unbounded, evict only under arena
    /// pressure).
    pub prefix_cache_blocks: usize,
    /// HTTP listen address (`--http ADDR`); "" = no HTTP front-end, run
    /// the synthetic in-process workload instead.
    pub http: String,
    /// Router admission: max in-flight prompt tokens (0 = unlimited).
    pub max_batch_prefill_tokens: usize,
    /// Router admission: max in-flight prompt+max_tokens (0 = unlimited).
    pub max_batch_total_tokens: usize,
    /// Router admission: admit while queue depth < ratio * max_in_flight
    /// (0.0 = no queue-depth gate).
    pub waiting_served_ratio: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let sched = crate::coordinator::scheduler::SchedulerConfig::default();
        let admission = crate::srv::admission::AdmissionConfig::default();
        ServeConfig {
            model: "tiny".into(),
            backend: "auto".into(),
            num_requests: 16,
            tokens_per_request: 8,
            arrival_rate: 0.0,
            seed: 0,
            temperature: 0.0,
            top_k: 0,
            stream: false,
            sched: "continuous".into(),
            max_in_flight: sched.max_in_flight,
            prefill_chunk: sched.prefill_chunk,
            kv_block: sched.kv_block,
            kv_blocks: 0,
            prefix_cache: sched.prefix_cache,
            prefix_cache_blocks: sched.prefix_cache_blocks,
            http: String::new(),
            max_batch_prefill_tokens: admission.max_batch_prefill_tokens,
            max_batch_total_tokens: admission.max_batch_total_tokens,
            waiting_served_ratio: admission.waiting_served_ratio,
        }
    }
}

#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub out_dir: String,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { out_dir: "reports".into() }
    }
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifact_dir: "artifacts".into(),
            train: TrainConfig::default(),
            serve: ServeConfig::default(),
            model: ModelConfig::default(),
            attn: AttnConfig::default(),
            bench: BenchConfig::default(),
        }
    }
}

impl RunConfig {
    pub fn load(path: &Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let doc = TomlDoc::parse(&text)
            .with_context(|| format!("parsing config {}", path.display()))?;
        Ok(Self::from_doc(&doc))
    }

    pub fn from_doc(doc: &TomlDoc) -> RunConfig {
        let d = RunConfig::default();
        let dt = TrainConfig::default();
        RunConfig {
            artifact_dir: doc.str_or("artifact_dir", &d.artifact_dir).to_string(),
            train: TrainConfig {
                model: doc.str_or("train.model", &dt.model).to_string(),
                variant: doc.str_or("train.variant", &dt.variant).to_string(),
                steps: doc.i64_or("train.steps", dt.steps as i64) as usize,
                seed: doc.i64_or("train.seed", dt.seed as i64) as u64,
                log_every: doc.i64_or("train.log_every", dt.log_every as i64) as usize,
                checkpoint: doc
                    .get("train.checkpoint")
                    .and_then(|v| v.as_str())
                    .map(String::from),
            },
            serve: ServeConfig {
                model: doc.str_or("serve.model", &d.serve.model).to_string(),
                backend: doc.str_or("serve.backend", &d.serve.backend).to_string(),
                num_requests: doc.i64_or("serve.num_requests", d.serve.num_requests as i64)
                    as usize,
                tokens_per_request: doc
                    .i64_or("serve.tokens_per_request", d.serve.tokens_per_request as i64)
                    as usize,
                arrival_rate: doc.f64_or("serve.arrival_rate", d.serve.arrival_rate),
                seed: doc.i64_or("serve.seed", d.serve.seed as i64) as u64,
                temperature: doc.f64_or("serve.temperature", d.serve.temperature as f64)
                    as f32,
                top_k: doc.i64_or("serve.top_k", d.serve.top_k as i64) as usize,
                stream: doc.bool_or("serve.stream", d.serve.stream),
                sched: doc.str_or("serve.sched", &d.serve.sched).to_string(),
                max_in_flight: doc
                    .i64_or("serve.max_in_flight", d.serve.max_in_flight as i64)
                    as usize,
                prefill_chunk: doc
                    .i64_or("serve.prefill_chunk", d.serve.prefill_chunk as i64)
                    as usize,
                kv_block: doc.i64_or("serve.kv_block", d.serve.kv_block as i64) as usize,
                kv_blocks: doc.i64_or("serve.kv_blocks", d.serve.kv_blocks as i64) as usize,
                prefix_cache: doc.bool_or("serve.prefix_cache", d.serve.prefix_cache),
                prefix_cache_blocks: doc
                    .i64_or("serve.prefix_cache_blocks", d.serve.prefix_cache_blocks as i64)
                    as usize,
                http: doc.str_or("serve.http", &d.serve.http).to_string(),
                max_batch_prefill_tokens: doc
                    .i64_or(
                        "serve.max_batch_prefill_tokens",
                        d.serve.max_batch_prefill_tokens as i64,
                    ) as usize,
                max_batch_total_tokens: doc
                    .i64_or(
                        "serve.max_batch_total_tokens",
                        d.serve.max_batch_total_tokens as i64,
                    ) as usize,
                waiting_served_ratio: doc
                    .f64_or("serve.waiting_served_ratio", d.serve.waiting_served_ratio),
            },
            model: ModelConfig {
                n_kv_heads: doc
                    .get("model.n_kv_heads")
                    .and_then(|v| v.as_i64())
                    .map(|n| n as usize),
                window: doc.get("model.window").and_then(|v| v.as_i64()).map(|n| n as usize),
            },
            attn: AttnConfig {
                seqpar_workers: doc
                    .i64_or("attn.seqpar_workers", d.attn.seqpar_workers as i64)
                    as usize,
                seqpar_chunk: doc
                    .i64_or("attn.seqpar_chunk", d.attn.seqpar_chunk as i64)
                    as usize,
                seqpar_stripe: doc.bool_or("attn.seqpar_stripe", d.attn.seqpar_stripe),
            },
            bench: BenchConfig {
                out_dir: doc.str_or("bench.out_dir", &d.bench.out_dir).to_string(),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_when_empty() {
        let doc = TomlDoc::parse("").unwrap();
        let c = RunConfig::from_doc(&doc);
        assert_eq!(c.train.model, "tiny");
        assert_eq!(c.serve.num_requests, 16);
    }

    #[test]
    fn overrides_applied() {
        let doc = TomlDoc::parse(
            "artifact_dir = \"a\"\n[train]\nmodel = \"small\"\nsteps = 7\n\
             checkpoint = \"ckpt.fat1\"\n[serve]\narrival_rate = 3.5\n\
             backend = \"native\"\ntemperature = 0.8\ntop_k = 40\n\
             stream = true\nsched = \"gang\"\nmax_in_flight = 3\n\
             prefill_chunk = 2\nkv_block = 8\nkv_blocks = 24\n\
             prefix_cache = true\nprefix_cache_blocks = 12\n\
             http = \"127.0.0.1:8080\"\nmax_batch_prefill_tokens = 512\n\
             max_batch_total_tokens = 2048\nwaiting_served_ratio = 1.5\n\
             [model]\nn_kv_heads = 2\nwindow = 48\n\
             [attn]\nseqpar_workers = 4\nseqpar_chunk = 32\n\
             seqpar_stripe = false\n",
        )
        .unwrap();
        let c = RunConfig::from_doc(&doc);
        assert_eq!(c.artifact_dir, "a");
        assert_eq!(c.train.model, "small");
        assert_eq!(c.train.steps, 7);
        assert_eq!(c.train.checkpoint.as_deref(), Some("ckpt.fat1"));
        assert!((c.serve.arrival_rate - 3.5).abs() < 1e-12);
        assert_eq!(c.serve.backend, "native");
        assert!((c.serve.temperature - 0.8).abs() < 1e-6);
        assert_eq!(c.serve.top_k, 40);
        assert!(c.serve.stream);
        assert_eq!(c.serve.sched, "gang");
        assert_eq!(c.serve.max_in_flight, 3);
        assert_eq!(c.serve.prefill_chunk, 2);
        assert_eq!(c.serve.kv_block, 8);
        assert_eq!(c.serve.kv_blocks, 24);
        assert!(c.serve.prefix_cache);
        assert_eq!(c.serve.prefix_cache_blocks, 12);
        assert_eq!(c.serve.http, "127.0.0.1:8080");
        assert_eq!(c.serve.max_batch_prefill_tokens, 512);
        assert_eq!(c.serve.max_batch_total_tokens, 2048);
        assert!((c.serve.waiting_served_ratio - 1.5).abs() < 1e-12);
        assert_eq!(c.model.n_kv_heads, Some(2));
        assert_eq!(c.model.window, Some(48));
        assert_eq!(c.attn.seqpar_workers, 4);
        assert_eq!(c.attn.seqpar_chunk, 32);
        assert!(!c.attn.seqpar_stripe);
    }

    #[test]
    fn serve_sampling_defaults_are_greedy() {
        let c = RunConfig::from_doc(&TomlDoc::parse("").unwrap());
        assert_eq!(c.serve.temperature, 0.0);
        assert_eq!(c.serve.top_k, 0);
        assert!(!c.serve.stream);
        // scheduler defaults mirror SchedulerConfig::default()
        let s = crate::coordinator::scheduler::SchedulerConfig::default();
        assert_eq!(c.serve.sched, "continuous");
        assert_eq!(c.serve.max_in_flight, s.max_in_flight);
        assert_eq!(c.serve.prefill_chunk, s.prefill_chunk);
        assert_eq!(c.serve.kv_block, s.kv_block);
        assert_eq!(c.serve.kv_blocks, 0, "0 = derive from max_in_flight");
        assert!(!c.serve.prefix_cache, "prefix caching is opt-in");
        assert_eq!(c.serve.prefix_cache_blocks, 0, "0 = unbounded retention");
        // HTTP is off by default; admission knobs mirror AdmissionConfig
        let a = crate::srv::admission::AdmissionConfig::default();
        assert!(c.serve.http.is_empty());
        assert_eq!(c.serve.max_batch_prefill_tokens, a.max_batch_prefill_tokens);
        assert_eq!(c.serve.max_batch_total_tokens, a.max_batch_total_tokens);
        assert!((c.serve.waiting_served_ratio - a.waiting_served_ratio).abs() < 1e-12);
        assert_eq!(c.model.n_kv_heads, None);
        assert_eq!(c.model.window, None);
        // seqpar defaults: auto workers, 64-token chunks, striping on
        assert_eq!(c.attn.seqpar_workers, 0);
        assert_eq!(c.attn.seqpar_chunk, 64);
        assert!(c.attn.seqpar_stripe);
    }
}
