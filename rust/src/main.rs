//! `repro` — the FlashAttention-2 reproduction CLI (leader entry point).
//!
//! Subcommands (argument parsing is in-tree; clap is not vendored offline):
//!   figures   regenerate paper figures 4-7 from the gpusim cost model
//!   table1    regenerate paper Table 1 (end-to-end training TFLOPs/s)
//!   simulate  section 3.1/3.3 ablation reports (rescale, split-K, occupancy)
//!   verify    execute every artifact with golden vectors and compare
//!   train     run the AOT train_step loop on the synthetic corpus
//!   serve     run the session-based serving engine on a synthetic
//!             workload (--stream, --temperature, --top-k, --sched
//!             continuous|gang, --max-in-flight, --prefill-chunk), or —
//!             with --http ADDR — serve it over HTTP/1.1 + SSE
//!             (srv router: validation, token-budget admission, shedding)
//!   attn-exec run the native flash-attention kernels (GFLOP/s + parity)
//!   bench-gate compare reports/bench_summary.json against the pinned
//!             benches/baseline.json; nonzero exit on >tolerance regression
//!   lint      in-tree static analysis over the workspace (DESIGN.md §12);
//!             nonzero exit on any violation; --inject-violation seeds a
//!             synthetic hot-path unwrap so ci.sh --verify-lint can prove
//!             the gate fails when it should
//!   inspect   list artifacts in the manifest
//!
//! `verify`, `train`, `serve` and `inspect` take `--backend
//! auto|native|xla|stub`.  `native` executes on the in-tree `attn::exec`
//! CPU engine and needs no artifacts on disk for `serve`, `verify` and
//! `inspect`; `train` still requires the AOT train_step artifact (native
//! reports a clear error).

use std::path::Path;
use std::sync::Arc;

use fa2::bail;
use fa2::util::error::{Context, Result};

use fa2::attn::exec::{parallel, reference, seqpar, FlashParams};
use fa2::attn::spec::{AttnSpec, HeadMap, Mask};
use fa2::attn::{kernels_for, AttnProblem, Method, Pass};
use fa2::bench::{figures, table1};
use fa2::bench::summary;
use fa2::config::RunConfig;
use fa2::coordinator::engine::{Completion, Engine, SamplingParams, TokenEvent};
use fa2::coordinator::scheduler::{SchedMode, SchedulerConfig};
use fa2::gpusim::{simulate, Device};
use fa2::runtime::{BackendKind, Runtime, RuntimeOptions};
use fa2::srv::admission::AdmissionConfig;
use fa2::srv::{HttpServer, HttpServerConfig};
use fa2::train::corpus::Corpus;
use fa2::train::trainer::{TrainConfig, Trainer};
use fa2::util::rng::Rng;

fn usage() -> ! {
    eprintln!(
        "usage: repro <command> [options]\n\
         commands:\n  \
           figures   [--fig 4|5|6|7|all] [--out-dir DIR]\n  \
           table1    [--device a100|h100] [--out-dir DIR]\n  \
           simulate  [--ablation rescale|splitk|occupancy|blocks]\n  \
           verify    [--artifact NAME] [--artifact-dir DIR] [--backend B]\n  \
           train     [--config FILE] [--model tiny|small] [--steps N]\n            \
                     [--variant ''|_refattn] [--loss-csv FILE] [--backend B]\n  \
           serve     [--config FILE] [--requests N] [--tokens N] [--rate R]\n            \
                     [--backend B] [--stream] [--temperature T] [--top-k K]\n            \
                     [--sched continuous|gang] [--max-in-flight N]\n            \
                     [--prefill-chunk N] [--kv-block T] [--kv-blocks N]\n            \
                     [--prefix-cache] [--prefix-cache-blocks N]\n            \
                     [--kv-heads H] [--window W]\n            \
                     [--http ADDR] [--http-addr-file FILE]\n            \
                     [--max-batch-prefill-tokens N] [--max-batch-total-tokens N]\n            \
                     [--waiting-served-ratio R]\n            \
                     [--trace FILE] [--metrics-out FILE]  (env: FA2_TRACE=FILE)\n  \
           attn-exec [--batch B] [--heads H] [--kv-heads H] [--seqlen N]\n            \
                     [--head-dim D] [--causal 0|1] [--window W]\n            \
                     [--threads T] [--check 0|1] [--config FILE]\n            \
                     [--seqpar-workers N] [--seqpar-chunk N]\n            \
                     [--seqpar-stripe 0|1]\n  \
           bench-gate [--summary FILE] [--baseline FILE] [--tolerance F]\n            \
                     [--update-baseline]\n  \
           lint      [--root DIR] [--rules] [--inject-violation]\n  \
           inspect   [--artifact-dir DIR] [--backend B]\n\
         backends (B): auto (default) | native | xla | stub"
    );
    std::process::exit(2)
}

/// Tiny flag parser: --key value pairs after the subcommand; a flag
/// followed by another flag (or nothing) is valueless (e.g. `--stream`).
struct Args {
    pairs: Vec<(String, String)>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut pairs = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let k = argv[i]
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got {:?}", argv[i]))?;
            let (v, step) = match argv.get(i + 1) {
                Some(next) if !next.starts_with("--") => (next.clone(), 2),
                _ => (String::new(), 1),
            };
            pairs.push((k.to_string(), v));
            i += step;
        }
        Ok(Args { pairs })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        self.get(key)
            .map(|v| v.parse().with_context(|| format!("--{key} {v}: not a number")))
            .transpose()
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else { usage() };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "figures" => cmd_figures(&args),
        "table1" => cmd_table1(&args),
        "simulate" => cmd_simulate(&args),
        "verify" => cmd_verify(&args),
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "attn-exec" => cmd_attn_exec(&args),
        "bench-gate" => cmd_bench_gate(&args),
        "lint" => cmd_lint(&args),
        "inspect" => cmd_inspect(&args),
        _ => usage(),
    }
}

fn out_dir(args: &Args) -> Result<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(args.get("out-dir").unwrap_or("reports"));
    std::fs::create_dir_all(&dir)?;
    Ok(dir)
}

fn cmd_figures(args: &Args) -> Result<()> {
    let which = args.get("fig").unwrap_or("all");
    let figs: Vec<u32> = match which {
        "all" => vec![4, 5, 6, 7],
        f => vec![f.parse().context("--fig must be 4..7 or all")?],
    };
    let dir = out_dir(args)?;
    let mut any_fail = false;
    for fig in figs {
        let results = figures::run_figure(fig);
        println!("=== Figure {fig} ===");
        for r in &results {
            print!("{}", figures::render_ascii(r));
        }
        let csv_path = dir.join(format!("fig{fig}.csv"));
        std::fs::write(&csv_path, figures::to_csv(&results))?;
        println!("wrote {}", csv_path.display());
        if fig != 7 {
            let pass = match fig {
                5 => Pass::Fwd,
                6 => Pass::Bwd,
                _ => Pass::FwdBwd,
            };
            let checks = figures::check_bands(&results, pass);
            let failed: Vec<_> = checks.iter().filter(|c| !c.ok).collect();
            println!(
                "band checks: {}/{} ok",
                checks.len() - failed.len(),
                checks.len()
            );
            for c in failed {
                println!("  FAIL {}: {:.2} not in [{},{}]", c.name, c.value, c.lo, c.hi);
                any_fail = true;
            }
        }
    }
    if any_fail {
        bail!("figure band checks failed");
    }
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let dev = Device::by_name(args.get("device").unwrap_or("a100"))
        .context("--device must be a100 or h100")?;
    let cells = table1::run_table1(&dev);
    println!("=== Table 1 (simulated {}) ===", dev.name);
    print!("{}", table1::render(&cells));
    println!("\npaper-reported values for comparison:");
    println!(
        "GPT3-1.3B 2k: 142/189/196   GPT3-1.3B 8k: 72/170/220\n\
         GPT3-2.7B 2k: 149/189/205   GPT3-2.7B 8k: 80/175/225"
    );
    let dir = out_dir(args)?;
    let p = dir.join("table1.csv");
    std::fs::write(&p, table1::to_csv(&cells))?;
    println!("wrote {}", p.display());
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let dev = Device::a100();
    match args.get("ablation").unwrap_or("rescale") {
        "rescale" => {
            // Section 3.1 ablation: non-matmul FLOPs FA1 vs FA2.
            println!("non-matmul FLOPs ablation (fwd, B*N=16k tokens, d=128):");
            println!(
                "{:<8} {:>14} {:>14} {:>10} {:>12}",
                "seqlen", "FA1 nm-FLOPs", "FA2 nm-FLOPs", "saved", "time saved"
            );
            for n in figures::SEQLENS {
                let p = AttnProblem::paper_setting(n, 128, false);
                let f1 = &kernels_for(&p, Method::Flash1, Pass::Fwd)[0];
                let f2 = &kernels_for(&p, Method::Flash2, Pass::Fwd)[0];
                let saved = f1.nonmatmul_flops - f2.nonmatmul_flops;
                println!(
                    "{:<8} {:>14.3e} {:>14.3e} {:>9.1}% {:>10.3} ms",
                    n,
                    f1.nonmatmul_flops,
                    f2.nonmatmul_flops,
                    100.0 * saved / f1.nonmatmul_flops,
                    saved / dev.nonmatmul_flops * 1e3,
                );
            }
        }
        "splitk" => {
            // Section 3.3 ablation: smem exchange traffic split-K vs split-Q.
            println!("warp-partitioning ablation (fwd, d=64):");
            println!(
                "{:<8} {:>14} {:>14} {:>12}",
                "seqlen", "splitK smem", "splitQ smem", "extra time"
            );
            for n in figures::SEQLENS {
                let p = AttnProblem::paper_setting(n, 64, false);
                let f1 = &kernels_for(&p, Method::Flash1, Pass::Fwd)[0];
                let f2 = &kernels_for(&p, Method::Flash2, Pass::Fwd)[0];
                println!(
                    "{:<8} {:>11.2} GB {:>11.2} GB {:>10.3} ms",
                    n,
                    f1.smem_bytes / 1e9,
                    f2.smem_bytes / 1e9,
                    (f1.smem_bytes - f2.smem_bytes) / dev.smem_bw * 1e3,
                );
            }
        }
        "occupancy" => {
            // Section 3.2 ablation: grid size & SM fill vs seqlen.
            println!("occupancy ablation (fwd, d=128, B*N=16k tokens):");
            println!(
                "{:<8} {:>10} {:>10} {:>9} {:>9}",
                "seqlen", "FA1 grid", "FA2 grid", "FA1 fill", "FA2 fill"
            );
            for n in figures::SEQLENS {
                let p = AttnProblem::paper_setting(n, 128, false);
                let f1 = &kernels_for(&p, Method::Flash1, Pass::Fwd)[0];
                let f2 = &kernels_for(&p, Method::Flash2, Pass::Fwd)[0];
                let c1 = simulate(&dev, f1);
                let c2 = simulate(&dev, f2);
                println!(
                    "{:<8} {:>10} {:>10} {:>8.0}% {:>8.0}%",
                    n, f1.grid, f2.grid, c1.sm_fill * 100.0, c2.sm_fill * 100.0
                );
            }
        }
        "blocks" => {
            // Section 3.3 "tuning block sizes": sweep {64,128}^2.
            println!("block-size sweep (FA2 fwd, n=4096):");
            for d in [64u64, 128] {
                for bq in [64u64, 128] {
                    for bk in [64u64, 128] {
                        let p = AttnProblem::paper_setting(4096, d, false);
                        let mut spec =
                            fa2::attn::ScheduleSpec::for_method(Method::Flash2, d);
                        spec.block_q = bq;
                        spec.block_k = bk;
                        let ks = fa2::attn::schedule::fwd_kernels(&p, &spec);
                        let t = fa2::gpusim::simulate_pipeline(&dev, &ks);
                        println!(
                            "d={d:<4} Bq={bq:<4} Bk={bk:<4} -> {:>7.1} TFLOPs/s",
                            p.reported_flops(Pass::Fwd) / t / 1e12
                        );
                    }
                }
            }
        }
        other => bail!("unknown ablation {other}"),
    }
    Ok(())
}

fn backend_from(args: &Args) -> Result<BackendKind> {
    BackendKind::from_flag(args.get("backend").unwrap_or("auto"))
}

fn runtime_from(args: &Args) -> Result<Arc<Runtime>> {
    let dir = args.get("artifact-dir").unwrap_or("artifacts");
    Ok(Arc::new(Runtime::with_backend(Path::new(dir), backend_from(args)?)?))
}

fn cmd_verify(args: &Args) -> Result<()> {
    let rt = runtime_from(args)?;
    println!("backend: {}", rt.platform());
    let names: Vec<String> = match args.get("artifact") {
        Some(n) => vec![n.to_string()],
        None => rt.golden_names(),
    };
    let mut failures = 0;
    for name in names {
        match rt.verify_golden(&name) {
            Ok(diffs) => {
                let worst = diffs.iter().cloned().fold(0.0f32, f32::max);
                let ok = worst < 2e-4;
                if !ok {
                    failures += 1;
                }
                println!(
                    "{} {name}: max|Δ| = {worst:.2e} over {} outputs",
                    if ok { "PASS" } else { "FAIL" },
                    diffs.len()
                );
            }
            Err(e) => {
                failures += 1;
                println!("FAIL {name}: {e:#}");
            }
        }
    }
    if failures > 0 {
        bail!("{failures} artifact(s) failed golden verification");
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(p) => RunConfig::load(Path::new(p))?.train,
        None => TrainConfig::default(),
    };
    if let Some(m) = args.get("model") {
        cfg.model = m.to_string();
    }
    if let Some(v) = args.get("variant") {
        cfg.variant = v.to_string();
    }
    if let Some(s) = args.get_usize("steps")? {
        cfg.steps = s;
    }
    let rt = runtime_from(args)?;
    let report = Trainer::new(rt).run(&cfg)?;
    println!(
        "trained {} for {} steps: loss {:.4} -> {:.4}",
        cfg.model,
        cfg.steps,
        report.first_loss(),
        report.last_loss()
    );
    println!(
        "tokens/step {}  mean step {:.3}s  achieved {:.2} GFLOP/s (model-FLOPs accounting)",
        report.tokens_per_step,
        report.mean_step_secs,
        report.achieved_flops / 1e9
    );
    if let Some(path) = args.get("loss-csv") {
        std::fs::write(path, report.loss_csv())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let (mut cfg, mut model_cfg) = match args.get("config") {
        Some(p) => {
            let rc = RunConfig::load(Path::new(p))?;
            (rc.serve, rc.model)
        }
        None => (fa2::config::ServeConfig::default(), fa2::config::ModelConfig::default()),
    };
    if let Some(n) = args.get_usize("requests")? {
        cfg.num_requests = n;
    }
    if let Some(n) = args.get_usize("tokens")? {
        cfg.tokens_per_request = n;
    }
    if let Some(r) = args.get("rate") {
        cfg.arrival_rate = r.parse().context("--rate")?;
    }
    if let Some(t) = args.get("temperature") {
        cfg.temperature = t.parse().context("--temperature")?;
    }
    if let Some(k) = args.get_usize("top-k")? {
        cfg.top_k = k;
    }
    if args.get("stream").is_some() {
        cfg.stream = true;
    }
    if let Some(s) = args.get("sched") {
        cfg.sched = s.to_string();
    }
    if let Some(n) = args.get_usize("max-in-flight")? {
        cfg.max_in_flight = n;
    }
    if let Some(n) = args.get_usize("prefill-chunk")? {
        cfg.prefill_chunk = n;
    }
    if let Some(n) = args.get_usize("kv-block")? {
        cfg.kv_block = n;
    }
    if let Some(n) = args.get_usize("kv-blocks")? {
        cfg.kv_blocks = n;
    }
    if args.get("prefix-cache").is_some() {
        cfg.prefix_cache = true;
    }
    if let Some(n) = args.get_usize("prefix-cache-blocks")? {
        cfg.prefix_cache_blocks = n;
    }
    if let Some(n) = args.get_usize("kv-heads")? {
        model_cfg.n_kv_heads = Some(n);
    }
    if let Some(w) = args.get_usize("window")? {
        model_cfg.window = Some(w);
    }
    // Observability wiring (DESIGN.md §13): --trace (or FA2_TRACE) turns
    // the span/event recorder on for the whole run and exports Chrome
    // trace JSON at the end; --metrics-out snapshots the global counter
    // registry as Prometheus text.  Neither flag set: the recorder stays
    // at its one-atomic-load disabled path.
    let trace_path: Option<String> = args
        .get("trace")
        .map(str::to_string)
        .or_else(|| std::env::var("FA2_TRACE").ok().filter(|p| !p.is_empty()));
    if trace_path.is_some() {
        fa2::obs::trace::set_enabled(true);
        // ci.sh --verify-trace: leak one span so the export validator
        // must fail — proving the unclosed-span check can turn red.
        if std::env::var("FA2_TRACE_INJECT_UNCLOSED").is_ok() {
            fa2::obs::trace::inject_unclosed();
        }
    }
    let serve_span = fa2::obs_span!("serve_run");
    let mode = SchedMode::from_flag(&cfg.sched)
        .with_context(|| format!("--sched {}: expected continuous|gang", cfg.sched))?;
    let sched_cfg = SchedulerConfig {
        mode,
        max_in_flight: cfg.max_in_flight,
        prefill_chunk: cfg.prefill_chunk,
        kv_block: cfg.kv_block,
        kv_blocks: if cfg.kv_blocks == 0 { None } else { Some(cfg.kv_blocks) },
        prefix_cache: cfg.prefix_cache,
        prefix_cache_blocks: cfg.prefix_cache_blocks,
        // the CLI drives its own closed-loop workload: size the queue so
        // the synthetic burst is never rejected by its own backpressure
        max_queue: SchedulerConfig::default().max_queue.max(cfg.num_requests),
        ..SchedulerConfig::default()
    }
    .sanitized();
    let opts = RuntimeOptions { n_kv_heads: model_cfg.n_kv_heads, window: model_cfg.window };
    let backend = BackendKind::from_flag(args.get("backend").unwrap_or(&cfg.backend))?;
    let engine = Engine::start_full(
        std::path::PathBuf::from(args.get("artifact-dir").unwrap_or("artifacts")),
        &cfg.model,
        backend,
        sched_cfg,
        opts,
    )?;
    let shapes = engine.shapes();
    println!(
        "engine up: model {} (prompt window {}, max_seq {}, vocab {}, kv heads {}{})",
        cfg.model,
        shapes.prompt_len,
        shapes.max_seq,
        shapes.vocab,
        shapes.n_kv_head,
        match model_cfg.window {
            Some(w) => format!(", window {w}"),
            None => String::new(),
        }
    );
    // capacity as the ENGINE derived it, not re-computed here
    let total_blocks = engine.kv_capacity_blocks();
    let kv_block = engine.kv_block_tokens();
    println!(
        "scheduler: {:?}, max_in_flight {}, kv arena {} blocks x {} tokens \
         ({} KiB; a full window reserves {} blocks), prefill_chunk {}",
        sched_cfg.mode,
        sched_cfg.max_in_flight,
        total_blocks,
        kv_block,
        total_blocks * shapes.block_bytes(kv_block) / 1024,
        shapes.geometry(kv_block).blocks_per_seq(),
        sched_cfg.prefill_chunk
    );
    if sched_cfg.prefix_cache {
        println!(
            "prefix cache: on (copy-on-write block sharing, retained-block cap {})",
            match sched_cfg.prefix_cache_blocks {
                0 => "unbounded".to_string(),
                n => n.to_string(),
            }
        );
    }
    // --http ADDR (or serve.http in the config) puts the srv router in
    // front of the engine instead of running the synthetic workload; the
    // process then serves until a client POSTs /admin/shutdown.
    let http_addr: Option<String> = match args.get("http") {
        Some("") if cfg.http.is_empty() => Some("127.0.0.1:8080".to_string()),
        Some("") => Some(cfg.http.clone()),
        Some(a) => Some(a.to_string()),
        None if !cfg.http.is_empty() => Some(cfg.http.clone()),
        None => None,
    };
    if let Some(addr) = http_addr {
        if let Some(n) = args.get_usize("max-batch-prefill-tokens")? {
            cfg.max_batch_prefill_tokens = n;
        }
        if let Some(n) = args.get_usize("max-batch-total-tokens")? {
            cfg.max_batch_total_tokens = n;
        }
        if let Some(r) = args.get("waiting-served-ratio") {
            cfg.waiting_served_ratio = r.parse().context("--waiting-served-ratio")?;
        }
        let http_cfg = HttpServerConfig {
            admission: AdmissionConfig {
                max_batch_prefill_tokens: cfg.max_batch_prefill_tokens,
                max_batch_total_tokens: cfg.max_batch_total_tokens,
                waiting_served_ratio: cfg.waiting_served_ratio,
                max_in_flight: sched_cfg.max_in_flight,
            },
            inject_saturate: std::env::var("FA2_HTTP_INJECT_SATURATE").is_ok(),
            ..HttpServerConfig::default()
        };
        let server = HttpServer::start(&addr, engine.handle(), http_cfg)?;
        let bound = server.local_addr();
        println!(
            "http: listening on {bound} (POST /generate | POST /generate_stream | \
             GET /health | GET /metrics | POST /admin/shutdown)"
        );
        if let Some(p) = args.get("http-addr-file") {
            // ephemeral-port handshake for scripts (ci.sh --verify-http)
            std::fs::write(p, format!("{bound}\n"))
                .with_context(|| format!("writing --http-addr-file {p}"))?;
        }
        server.wait_shutdown_requested();
        println!("http: shutdown requested; draining in-flight sessions");
        server.shutdown();
    } else {
        let mut rng = Rng::seed_from(cfg.seed);
        let mut corpus = Corpus::new(512, cfg.seed);
        let mut sessions = Vec::new();
        for i in 0..cfg.num_requests {
            let prompt = corpus.next_batch(1, 16);
            let sampling = SamplingParams {
                max_tokens: cfg.tokens_per_request,
                temperature: cfg.temperature,
                top_k: cfg.top_k,
                seed: cfg.seed ^ (i as u64).wrapping_mul(0x9E37_79B9),
                stop_tokens: Vec::new(),
            };
            sessions.push(engine.submit(prompt, sampling)?);
            if cfg.arrival_rate > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(
                    rng.exponential(cfg.arrival_rate),
                ));
            }
        }
        for (i, session) in sessions.into_iter().enumerate() {
            let comp: Completion = if cfg.stream && i == 0 {
                // stream the first session's tokens as they are generated
                use std::io::Write;
                print!("session 0 stream:");
                loop {
                    match session.recv() {
                        Some(TokenEvent::First { token, ttft_secs }) => {
                            print!(" {token} (ttft {:.1} ms)", ttft_secs * 1e3);
                            std::io::stdout().flush().ok();
                        }
                        Some(TokenEvent::Delta { token, .. }) => {
                            print!(" {token}");
                            std::io::stdout().flush().ok();
                        }
                        Some(TokenEvent::Done {
                            finish,
                            tokens,
                            latency_secs,
                            ttft_secs,
                            cached_tokens,
                        }) => {
                            println!("  [{finish:?}]");
                            break Completion {
                                tokens,
                                finish,
                                latency: latency_secs,
                                ttft: ttft_secs,
                                cached_tokens,
                            };
                        }
                        None => bail!("engine closed mid-stream"),
                    }
                }
            } else {
                session.wait()?
            };
            if i < 3 {
                println!(
                    "req {i}: {} tokens, latency {:.1} ms, ttft {:.1} ms, {:?}: {:?}",
                    comp.tokens.len(),
                    comp.latency * 1e3,
                    comp.ttft * 1e3,
                    comp.finish,
                    &comp.tokens[..comp.tokens.len().min(8)]
                );
            }
        }
    }
    let metrics = engine.shutdown()?;
    println!("{}", metrics.report());
    // the run span must close before the exporter's unclosed-span check
    drop(serve_span);
    if let Some(p) = &trace_path {
        let n = fa2::obs::trace::export_to(Path::new(p))?;
        println!("trace: {n} events -> {p} (load in Perfetto or chrome://tracing)");
    }
    if let Some(p) = args.get("metrics-out") {
        fa2::obs::expo::write_prometheus(Path::new(p), fa2::obs::counters::global())?;
        println!("metrics -> {p}");
    }
    Ok(())
}

fn cmd_attn_exec(args: &Args) -> Result<()> {
    let n_q_heads = args.get_usize("heads")?.unwrap_or(8);
    let n_kv_heads = args.get_usize("kv-heads")?.unwrap_or(n_q_heads);
    let causal = matches!(args.get("causal"), Some("1") | Some("true"));
    let mask = match args.get_usize("window")? {
        Some(w) => Mask::SlidingWindow(w.max(1)),
        None if causal => Mask::Causal,
        None => Mask::Full,
    };
    let spec = AttnSpec {
        batch: args.get_usize("batch")?.unwrap_or(2),
        heads: HeadMap { n_q_heads, n_kv_heads },
        seq: args.get_usize("seqlen")?.unwrap_or(512),
        head_dim: args.get_usize("head-dim")?.unwrap_or(64),
        mask,
    };
    spec.validate()?;
    let dims = spec.q_dims();
    let threads = args
        .get_usize("threads")?
        .unwrap_or_else(fa2::util::pool::threads);
    let check = !matches!(args.get("check"), Some("0") | Some("false"));
    // tiles from the autotuner: the executing engine runs what the cost
    // model picked, instead of a hardcoded 64x64 default
    let p = FlashParams::tuned(dims, Pass::FwdBwd);
    println!(
        "native attn exec: B={} Hq={} Hkv={} N={} d={} mask={:?} threads={threads} \
         tile={}x{} (autotuned)",
        spec.batch,
        n_q_heads,
        n_kv_heads,
        spec.seq,
        spec.head_dim,
        spec.mask,
        p.block_q,
        p.block_k
    );

    let mut rng = Rng::seed_from(0xA77);
    let mut draw = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.normal() as f32).collect() };
    let q = draw(spec.q_elems());
    let k = draw(spec.kv_elems());
    let v = draw(spec.kv_elems());
    let dout = draw(spec.q_elems());

    let b = fa2::util::stats::Bencher::quick();
    let s = b.run("flash fwd", || parallel::forward_spec_with(threads, &q, &k, &v, spec, p));
    println!(
        "fwd:  {:>8.2} ms  {:>7.2} GFLOP/s",
        s.p50 * 1e3,
        dims.flops(Pass::Fwd) / s.p50 / 1e9
    );
    let fwd = parallel::forward_spec_with(threads, &q, &k, &v, spec, p);
    let s = b.run("flash bwd", || {
        parallel::backward_spec_with(threads, &q, &k, &v, &fwd, &dout, spec, p)
    });
    println!(
        "bwd:  {:>8.2} ms  {:>7.2} GFLOP/s",
        s.p50 * 1e3,
        dims.flops(Pass::Bwd) / s.p50 / 1e9
    );

    // split-KV decode over one head's history
    let dh = spec.head_dim;
    let scale = spec.scale();
    let hist = spec.seq;
    let s = b.run("split-KV decode", || {
        parallel::decode_splitkv(&q[..dh], &k[..hist * dh], &v[..hist * dh], hist, scale, 64)
    });
    println!(
        "decode: {:>6.1} µs/token over {hist} cached rows (chunk 64)",
        s.p50 * 1e6
    );

    // Sequence-parallel ring execution (DESIGN.md §16): opt in with
    // --seqpar-workers (0 = one worker per pool thread).  A `--config`
    // file's `[attn]` table supplies the defaults for all three knobs.
    if args.get("seqpar-workers").is_some() || args.get("config").is_some() {
        let acfg = match args.get("config") {
            Some(path) if !path.is_empty() => RunConfig::load(Path::new(path))?.attn,
            _ => fa2::config::AttnConfig::default(),
        };
        let workers = match args.get_usize("seqpar-workers")?.unwrap_or(acfg.seqpar_workers) {
            0 => fa2::util::pool::threads(),
            w => w,
        };
        let chunk = args
            .get_usize("seqpar-chunk")?
            .unwrap_or(acfg.seqpar_chunk)
            .max(1);
        let striped = match args.get("seqpar-stripe") {
            Some("0") | Some("false") => false,
            Some(_) => true,
            None => acfg.seqpar_stripe,
        };
        let prm = seqpar::SeqParParams { workers, chunk, striped };
        let (sp_out, st) = seqpar::forward_spec(&q, &k, &v, spec, prm)?;
        let wall_s = (st.wall_ns as f64 / 1e9).max(1e-12);
        println!(
            "seqpar fwd: W={} chunk={chunk} striped={striped} {:>8.2} ms  {:>7.2} GFLOP/s",
            st.workers,
            wall_s * 1e3,
            dims.flops(Pass::Fwd) / wall_s / 1e9
        );
        println!(
            "seqpar comm: {} B over {} msgs ({} B/step, {} steps), \
             {} shards unshipped, idle {:.2} ms",
            st.comm_bytes,
            st.comm_msgs,
            st.comm_bytes / st.steps.max(1) as u64,
            st.steps,
            st.shards_unshipped,
            st.idle_ns as f64 / 1e6
        );
        let (_, stb) = seqpar::backward_spec(&q, &k, &v, &sp_out, &dout, spec, prm)?;
        let bwall_s = (stb.wall_ns as f64 / 1e9).max(1e-12);
        println!(
            "seqpar bwd: {:>8.2} ms  {:>7.2} GFLOP/s  ({} B over {} msgs)",
            bwall_s * 1e3,
            dims.flops(Pass::Bwd) / bwall_s / 1e9,
            stb.comm_bytes,
            stb.comm_msgs
        );
        if check {
            // the ring's core invariant: bytes out are identical at any
            // worker count, so W workers must reproduce W=1 exactly
            let solo = seqpar::SeqParParams { workers: 1, ..prm };
            let (base, _) = seqpar::forward_spec(&q, &k, &v, spec, solo)?;
            if sp_out.o != base.o || sp_out.lse != base.lse {
                bail!("seqpar W={} output is not byte-identical to W=1", st.workers);
            }
            println!("seqpar parity: byte-identical to W=1 ✓");
        }
    }

    if check {
        let rf = reference::forward_spec(&q, &k, &v, spec);
        let worst = fwd
            .o
            .iter()
            .zip(&rf.o)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        // same 2e-4 gate as `verify`, relaxed mildly with seqlen (f32
        // accumulation error grows with the number of summed terms)
        let tol = 2e-4f32 * (1.0 + spec.seq as f32 / 1024.0);
        println!("parity vs O(N²) reference: max|Δ| = {worst:.2e} (tol {tol:.1e})");
        if worst >= tol {
            bail!("native flash forward diverged from reference ({worst:.2e} >= {tol:.1e})");
        }
    }
    Ok(())
}

/// The bench-regression CI gate (ci.sh step): compare the current
/// `reports/bench_summary.json` against the pinned `benches/baseline.json`
/// and fail on any metric worse by more than the tolerance.
/// `--update-baseline` re-pins instead of comparing.
fn cmd_bench_gate(args: &Args) -> Result<()> {
    // Defaults resolve against the workspace root (where ci.sh lives):
    // cargo runs bench binaries with cwd = rust/, so the summary they
    // write and the file read here must anchor the same way.
    let summary_path = args
        .get("summary")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(summary::summary_path);
    let baseline_path = args
        .get("baseline")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(summary::baseline_path);
    let (summary_path, baseline_path) = (summary_path.as_path(), baseline_path.as_path());
    let tolerance: f64 = match args.get("tolerance") {
        Some(t) => t.parse().context("--tolerance must be a fraction (0.15 = 15%)")?,
        None => 0.15,
    };
    let current = summary::load(summary_path)?;
    if args.get("update-baseline").is_some() {
        // fa2lint: allow(no-float-eq) -- 1.0 is the exact "hook off" sentinel, never computed
        if summary::slowdown_factor() != 1.0 {
            bail!(
                "refusing to pin a baseline while FA2_BENCH_INJECT_SLOWDOWN={} is set: \
                 the recorded values are synthetically worsened (unset it and re-run)",
                summary::slowdown_factor()
            );
        }
        if current.is_empty() {
            bail!(
                "refusing to pin an empty baseline: no entries in {} (run the benches \
                 first, e.g. ./ci.sh --update-baseline)",
                summary_path.display()
            );
        }
        summary::save(baseline_path, &current)?;
        println!(
            "pinned {} bench metrics from {} -> {}",
            current.len(),
            summary_path.display(),
            baseline_path.display()
        );
        return Ok(());
    }
    let baseline = summary::load(baseline_path)?;
    if baseline.is_empty() {
        println!(
            "bench-gate: baseline {} has no pinned metrics yet — gate is VACUOUS.\n\
             Pin the first real numbers on a quiet machine with `./ci.sh --update-baseline`.",
            baseline_path.display()
        );
        return Ok(());
    }
    let report = summary::gate(&baseline, &current, tolerance);
    println!(
        "bench-gate: {} metrics compared against {} (tolerance {:.0}%), {} improved",
        report.compared,
        baseline_path.display(),
        tolerance * 100.0,
        report.improvements
    );
    for k in &report.missing_in_baseline {
        println!("  WARN new metric not pinned (re-pin with --update-baseline): {k}");
    }
    for k in &report.missing_in_current {
        println!("  WARN pinned metric did not run this time: {k}");
    }
    for r in &report.regressions {
        println!("  REGRESSION {r}");
    }
    if !report.regressions.is_empty() {
        bail!(
            "{} bench metric(s) regressed past the {:.0}% tolerance",
            report.regressions.len(),
            tolerance * 100.0
        );
    }
    Ok(())
}

/// The in-tree static-analysis gate (DESIGN.md §12).  `ci.sh` runs this
/// before the tests; any violation is a nonzero exit.  `--inject-violation`
/// lints with a synthetic hot-path `unwrap()` fixture so `ci.sh
/// --verify-lint` can assert the gate actually fails on a violation.
fn cmd_lint(args: &Args) -> Result<()> {
    if args.get("rules").is_some() {
        println!("repro lint rule catalog:");
        for r in fa2::analysis::RULES {
            println!("  {:<24} {}", r.id, r.summary);
        }
        return Ok(());
    }
    let root = args
        .get("root")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(summary::workspace_root);
    let inject = args.get("inject-violation").is_some();
    let report = fa2::analysis::lint_workspace(&root, inject)?;
    for w in &report.warnings {
        println!("warning: {}", w.render());
    }
    for v in &report.violations {
        println!("{}", v.render());
    }
    println!(
        "repro lint: {} violation(s), {} warning(s), {} suppressed by fa2lint allows",
        report.violations.len(),
        report.warnings.len(),
        report.suppressed.len()
    );
    if !report.clean() {
        bail!("{} lint violation(s)", report.violations.len());
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let rt = runtime_from(args)?;
    println!("{} artifacts in {}:", rt.manifest.artifacts.len(), rt.manifest.dir.display());
    for a in rt.manifest.artifacts.values() {
        println!(
            "  {:<40} {:?} {:>2} in / {:>2} out {}",
            a.name,
            a.kind,
            a.inputs.len(),
            a.outputs.len(),
            if a.golden_path.is_some() { "[golden]" } else { "" }
        );
    }
    Ok(())
}
