//! FlashAttention-2 reproduction: Rust coordinator over JAX/Pallas AOT
//! artifacts, plus the GPU cost-model substrate that regenerates the paper's
//! figures.  See DESIGN.md for the system inventory.

pub mod analysis;
pub mod attn;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod gpusim;
pub mod obs;
pub mod runtime;
pub mod srv;
pub mod train;
pub mod util;
