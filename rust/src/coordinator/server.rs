//! DEPRECATED compatibility shim: the pre-engine `Server` API, kept for
//! one release as a thin layer over [`coordinator::engine::Engine`]
//! (DESIGN.md §8).  New code should use `Engine`/`Session` directly —
//! they add streamed `TokenEvent`s, typed sampling, cancellation, and the
//! zero-copy KV arena.
//!
//! Behavior changes versus the original `Server`:
//!
//! - `submit` now returns `Result`: a dead worker surfaces as a typed
//!   `EngineError::Closed` immediately instead of leaving the client
//!   blocked forever on a response channel that will never fire, and an
//!   over-long prompt is rejected (`EngineError::PromptTooLong`) instead
//!   of being silently truncated to the compiled window.
//! - the shim maps `GenRequest { prompt, n_new }` onto a greedy `Session`
//!   with `max_tokens = n_new`.  Since the continuous scheduler
//!   (DESIGN.md §9), prompts are prefilled at true positions instead of
//!   padded to the compiled window with token 0 — greedy output remains
//!   deterministic and batch-invariant, but differs numerically from the
//!   old padded worker (pad tokens used to attend as real context).

use std::path::PathBuf;

use crate::util::error::Result;

use crate::runtime::BackendKind;

// Back-compat re-export: `ServeShapes` moved to the runtime's typed
// bundle discovery (`runtime::bundle`).
pub use crate::runtime::bundle::ServeShapes;

use super::engine::{Engine, SamplingParams, Session};
use super::metrics::Metrics;

/// A generation request: prompt tokens + number of tokens to generate.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: Vec<i32>,
    pub n_new: usize,
}

/// The completed response.
#[derive(Debug, Clone)]
pub struct GenResponse {
    pub tokens: Vec<i32>,
    /// end-to-end latency (submit -> complete), seconds
    pub latency: f64,
    /// time to first token (prefill), seconds
    pub ttft: f64,
}

/// Blocking handle for one shimmed request (replaces the old raw
/// `Receiver<GenResponse>`).
pub struct GenHandle {
    session: Session,
}

impl GenHandle {
    /// Block until the request completes, draining the streamed events.
    pub fn recv(&self) -> Result<GenResponse> {
        let c = self.session.drain()?;
        Ok(GenResponse { tokens: c.tokens, latency: c.latency, ttft: c.ttft })
    }
}

#[deprecated(
    note = "superseded by coordinator::engine::Engine (typed sessions, streamed \
            tokens, sampling params, zero-copy KV arena); this shim will be \
            removed next release"
)]
pub struct Server {
    engine: Engine,
}

#[allow(deprecated)]
impl Server {
    /// Start the worker on the default backend.  `model` is the manifest
    /// model name ("tiny").
    pub fn start(artifact_dir: PathBuf, model: &str) -> Result<Server> {
        Self::start_with(artifact_dir, model, BackendKind::Auto)
    }

    /// Start the worker on an explicit backend (`BackendKind::Native`
    /// needs no artifacts on disk).
    pub fn start_with(
        artifact_dir: PathBuf,
        model: &str,
        backend: BackendKind,
    ) -> Result<Server> {
        Ok(Server { engine: Engine::start(artifact_dir, model, backend)? })
    }

    /// Submit a request; returns a blocking response handle, or a typed
    /// error if the prompt is invalid or the engine has closed.
    ///
    /// The session is detached so dropping the handle does NOT cancel the
    /// request — the old `Server` completed (and counted) fire-and-forget
    /// submissions, and the shim preserves that.
    pub fn submit(&self, req: GenRequest) -> Result<GenHandle> {
        let mut session = self
            .engine
            .submit(req.prompt, SamplingParams::greedy(req.n_new))?;
        session.detach();
        Ok(GenHandle { session })
    }

    /// Close the queue and wait for the worker; returns serving metrics.
    pub fn shutdown(self) -> Result<Metrics> {
        self.engine.shutdown()
    }
}
