//! The serving coordinator: a mini vLLM-style router that owns the AOT
//! prefill/decode executables and serves generate() requests over channels
//! with dynamic batching and per-sequence KV-cache state management.
//!
//! Topology: clients -> mpsc submit queue -> worker thread
//!   worker: admit (prefill, bucket 1) -> decode loop (bucket 1 or 4,
//!           padding with replicated rows when the active set is between
//!           bucket sizes) -> per-request response channels.
//!
//! Python never runs here: prefill/decode are compiled HLO artifacts.

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::util::error::{Context, Error, Result};

use crate::runtime::{BackendKind, Executable, Runtime};
use crate::util::tensorio::{DType, HostTensor};

use super::metrics::Metrics;

/// A generation request: prompt tokens + number of tokens to generate.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: Vec<i32>,
    pub n_new: usize,
}

/// The completed response.
#[derive(Debug, Clone)]
pub struct GenResponse {
    pub tokens: Vec<i32>,
    /// end-to-end latency (submit -> complete), seconds
    pub latency: f64,
    /// time to first token (prefill), seconds
    pub ttft: f64,
}

struct Inflight {
    req: GenRequest,
    resp_tx: Sender<GenResponse>,
    submitted: Instant,
}

/// One active sequence's server-side state.
struct SeqState {
    resp_tx: Sender<GenResponse>,
    submitted: Instant,
    ttft: f64,
    generated: Vec<i32>,
    n_new: usize,
    pos: i32,
    /// KV cache for this sequence: per (layer-major) f32 slab of shape
    /// (L, 1, Hkv, S, dh) flattened.
    k_cache: Vec<f32>,
    v_cache: Vec<f32>,
}

/// Shapes of the serving model, read from artifact metadata.
#[derive(Debug, Clone, Copy)]
pub struct ServeShapes {
    pub n_layer: usize,
    pub n_kv_head: usize,
    pub max_seq: usize,
    pub d_head: usize,
    pub vocab: usize,
    pub prompt_len: usize,
}

impl ServeShapes {
    pub fn cache_elems_per_seq(&self) -> usize {
        self.n_layer * self.n_kv_head * self.max_seq * self.d_head
    }
}

pub struct Server {
    tx: Sender<Inflight>,
    handle: Option<JoinHandle<Result<Metrics>>>,
}

impl Server {
    /// Start the worker on the default backend.  `model` is the artifact
    /// prefix ("tiny").
    pub fn start(artifact_dir: std::path::PathBuf, model: &str) -> Result<Server> {
        Self::start_with(artifact_dir, model, BackendKind::Auto)
    }

    /// Start the worker on an explicit backend (`BackendKind::Native` needs
    /// no artifacts on disk).
    ///
    /// The backend and executables are created INSIDE the worker thread:
    /// the `xla` crate's handles are `!Send` (Rc internals), so the worker
    /// owns the whole runtime and talks to clients only through channels —
    /// which is the right shape for a serving leader anyway.
    pub fn start_with(
        artifact_dir: std::path::PathBuf,
        model: &str,
        backend: BackendKind,
    ) -> Result<Server> {
        let model = model.to_string();
        let (tx, rx) = channel::<Inflight>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let handle = std::thread::spawn(move || {
            let setup = || -> Result<_> {
                let rt = Runtime::with_backend(&artifact_dir, backend)?;
                let prefill1 = rt.load(&format!("{model}_prefill_b1"))?;
                let decode1 = rt.load(&format!("{model}_decode_b1"))?;
                let decode4 = rt.load(&format!("{model}_decode_b4"))?;
                let init = rt.load(&format!("{model}_init"))?;
                let spec = &prefill1.spec;
                let shapes = ServeShapes {
                    n_layer: spec.meta_i64("n_layer").context("n_layer")? as usize,
                    n_kv_head: spec.meta_i64("n_kv_head").context("n_kv_head")? as usize,
                    max_seq: spec.meta_i64("max_seq").context("max_seq")? as usize,
                    d_head: (spec.meta_i64("d_model").context("d_model")?
                        / spec.meta_i64("n_head").context("n_head")?) as usize,
                    vocab: spec.meta_i64("vocab_size").context("vocab")? as usize,
                    prompt_len: spec.meta_i64("prompt_len").context("prompt_len")?
                        as usize,
                };
                // Materialize the weights once via the init artifact (seed
                // 0): the flat param list is shared by prefill and decode.
                let params = init.run(&[HostTensor::scalar_u32(0)])?;
                Ok((rt, prefill1, decode1, decode4, params, shapes))
            };
            match setup() {
                Ok((_rt, prefill1, decode1, decode4, params, shapes)) => {
                    let _ = ready_tx.send(Ok(()));
                    worker(rx, prefill1, decode1, decode4, params, shapes)
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    Ok(Metrics::new())
                }
            }
        });
        ready_rx
            .recv()
            .map_err(|_| Error::msg("server worker died during setup"))??;
        Ok(Server { tx, handle: Some(handle) })
    }

    /// Submit a request; returns the response channel.
    pub fn submit(&self, req: GenRequest) -> Receiver<GenResponse> {
        let (resp_tx, resp_rx) = channel();
        let _ = self.tx.send(Inflight { req, resp_tx, submitted: Instant::now() });
        resp_rx
    }

    /// Close the queue and wait for the worker; returns serving metrics.
    pub fn shutdown(mut self) -> Result<Metrics> {
        drop(self.tx);
        self.handle
            .take()
            .unwrap()
            .join()
            .map_err(|_| Error::msg("server worker panicked"))?
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[allow(clippy::too_many_arguments)]
fn worker(
    rx: Receiver<Inflight>,
    prefill1: std::sync::Arc<Executable>,
    decode1: std::sync::Arc<Executable>,
    decode4: std::sync::Arc<Executable>,
    params: Vec<HostTensor>,
    shapes: ServeShapes,
) -> Result<Metrics> {
    let mut metrics = Metrics::new();
    let mut active: BTreeMap<u64, SeqState> = BTreeMap::new();
    let mut next_id = 0u64;
    let mut closed = false;

    while !closed || !active.is_empty() {
        // Admission: drain the queue (block only when idle).
        loop {
            let msg = if active.is_empty() && !closed {
                match rx.recv() {
                    Ok(m) => Some(m),
                    Err(_) => {
                        closed = true;
                        None
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => Some(m),
                    Err(std::sync::mpsc::TryRecvError::Empty) => None,
                    Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                        closed = true;
                        None
                    }
                }
            };
            let Some(inflight) = msg else { break };
            let state = prefill(&prefill1, &params, &shapes, inflight)?;
            active.insert(next_id, state);
            next_id += 1;
        }
        if active.is_empty() {
            continue;
        }

        // Decode step for the whole active set, in bucket-sized groups.
        let ids: Vec<u64> = active.keys().cloned().collect();
        for group in ids.chunks(4) {
            let exe = if group.len() == 1 { &decode1 } else { &decode4 };
            decode_group(exe, &params, &shapes, group, &mut active)?;
        }

        // Retire finished sequences.
        let done: Vec<u64> = active
            .iter()
            .filter(|(_, s)| s.generated.len() >= s.n_new)
            .map(|(id, _)| *id)
            .collect();
        for id in done {
            let s = active.remove(&id).unwrap();
            let latency = s.submitted.elapsed().as_secs_f64();
            metrics.observe_request(latency, s.ttft, s.generated.len());
            let _ = s.resp_tx.send(GenResponse {
                tokens: s.generated,
                latency,
                ttft: s.ttft,
            });
        }
    }
    Ok(metrics)
}

fn prefill(
    exe: &Executable,
    params: &[HostTensor],
    shapes: &ServeShapes,
    inflight: Inflight,
) -> Result<SeqState> {
    // Pad/trim the prompt to the compiled prompt length.
    let mut prompt = inflight.req.prompt.clone();
    prompt.resize(shapes.prompt_len, 0);
    let tokens = HostTensor::from_i32(&[1, shapes.prompt_len], &prompt);
    let mut inputs: Vec<HostTensor> = params.to_vec();
    inputs.push(tokens);
    let out = exe.run(&inputs)?;
    let logits = out[0].to_f32_vec();
    let first = argmax(&logits) as i32;
    let ttft = inflight.submitted.elapsed().as_secs_f64();
    Ok(SeqState {
        resp_tx: inflight.resp_tx,
        submitted: inflight.submitted,
        ttft,
        generated: vec![first],
        n_new: inflight.req.n_new.max(1),
        pos: shapes.prompt_len as i32,
        k_cache: out[1].to_f32_vec(),
        v_cache: out[2].to_f32_vec(),
    })
}

/// Assemble a batch-`b` cache tensor from per-sequence slabs.
/// Layout: (L, B, H, S, dh); per-sequence slab is (L, 1, H, S, dh).
fn assemble_cache(
    seqs: &[&SeqState],
    pick: fn(&SeqState) -> &Vec<f32>,
    shapes: &ServeShapes,
    b: usize,
) -> HostTensor {
    let per_layer = shapes.n_kv_head * shapes.max_seq * shapes.d_head;
    let mut data = vec![0.0f32; shapes.n_layer * b * per_layer];
    for l in 0..shapes.n_layer {
        for (bi, s) in seqs.iter().enumerate() {
            let src = &pick(s)[l * per_layer..(l + 1) * per_layer];
            let dst = (l * b + bi) * per_layer;
            data[dst..dst + per_layer].copy_from_slice(src);
        }
        // padding rows replicate sequence 0 (results discarded)
        for bi in seqs.len()..b {
            let src = &pick(seqs[0])[l * per_layer..(l + 1) * per_layer];
            let dst = (l * b + bi) * per_layer;
            data[dst..dst + per_layer].copy_from_slice(src);
        }
    }
    HostTensor::from_f32(
        &[shapes.n_layer, b, shapes.n_kv_head, shapes.max_seq, shapes.d_head],
        &data,
    )
}

fn decode_group(
    exe: &Executable,
    params: &[HostTensor],
    shapes: &ServeShapes,
    group: &[u64],
    active: &mut BTreeMap<u64, SeqState>,
) -> Result<()> {
    let b = exe.spec.meta_i64("batch").unwrap_or(1) as usize;
    let seqs: Vec<&SeqState> = group.iter().map(|id| &active[id]).collect();
    let k = assemble_cache(&seqs, |s| &s.k_cache, shapes, b);
    let v = assemble_cache(&seqs, |s| &s.v_cache, shapes, b);
    let mut tok = vec![0i32; b];
    let mut pos = vec![0i32; b];
    for (i, s) in seqs.iter().enumerate() {
        tok[i] = *s.generated.last().unwrap();
        pos[i] = s.pos;
    }
    for i in seqs.len()..b {
        tok[i] = tok[0];
        pos[i] = pos[0];
    }
    let mut inputs: Vec<HostTensor> = params.to_vec();
    inputs.push(k);
    inputs.push(v);
    inputs.push(HostTensor::from_i32(&[b], &tok));
    inputs.push(HostTensor::from_i32(&[b], &pos));
    let out = exe.run(&inputs)?;

    let logits = out[0].to_f32_vec();
    let per_layer = shapes.n_kv_head * shapes.max_seq * shapes.d_head;
    let k_new = out[1].to_f32_vec();
    let v_new = out[2].to_f32_vec();
    for (bi, id) in group.iter().enumerate() {
        let s = active.get_mut(id).unwrap();
        let row = &logits[bi * shapes.vocab..(bi + 1) * shapes.vocab];
        s.generated.push(argmax(row) as i32);
        s.pos += 1;
        // scatter the updated cache rows back to the per-sequence slabs
        for l in 0..shapes.n_layer {
            let src = (l * b + bi) * per_layer;
            let dst = l * per_layer;
            s.k_cache[dst..dst + per_layer]
                .copy_from_slice(&k_new[src..src + per_layer]);
            s.v_cache[dst..dst + per_layer]
                .copy_from_slice(&v_new[src..src + per_layer]);
        }
        debug_assert_eq!(s.k_cache.len(), shapes.cache_elems_per_seq());
    }
    let _ = DType::F32; // (keep import used in all cfg combinations)
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax(&[0.1, 3.0, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
    }

    #[test]
    fn cache_assembly_roundtrip_layout() {
        let shapes = ServeShapes {
            n_layer: 2, n_kv_head: 1, max_seq: 2, d_head: 2,
            vocab: 4, prompt_len: 2,
        };
        let per_layer = 1 * 2 * 2;
        let mk = |base: f32| SeqState {
            resp_tx: channel().0,
            submitted: Instant::now(),
            ttft: 0.0,
            generated: vec![1],
            n_new: 1,
            pos: 0,
            k_cache: (0..2 * per_layer).map(|i| base + i as f32).collect(),
            v_cache: vec![0.0; 2 * per_layer],
        };
        let s0 = mk(0.0);
        let s1 = mk(100.0);
        let t = assemble_cache(&[&s0, &s1], |s| &s.k_cache, &shapes, 4);
        assert_eq!(t.dims, vec![2, 4, 1, 2, 2]);
        let data = t.to_f32_vec();
        // layer 0: [seq0 layer0][seq1 layer0][pad=seq0][pad=seq0]
        assert_eq!(&data[0..4], &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(&data[4..8], &[100.0, 101.0, 102.0, 103.0]);
        assert_eq!(&data[8..12], &[0.0, 1.0, 2.0, 3.0]);
        // layer 1 of seq1 starts at (1*4 + 1)*4
        assert_eq!(&data[20..24], &[104.0, 105.0, 106.0, 107.0]);
    }
}
