//! Serving metrics: request latency distribution, time-to-first-token,
//! token throughput.  Printed by `repro serve` and the serving example.

use std::time::Instant;

use crate::util::stats::{percentile, fmt_duration};

#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    latencies: Vec<f64>,
    ttfts: Vec<f64>,
    tokens: u64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics { started: Instant::now(), latencies: Vec::new(), ttfts: Vec::new(), tokens: 0 }
    }

    pub fn observe_request(&mut self, latency: f64, ttft: f64, n_tokens: usize) {
        self.latencies.push(latency);
        self.ttfts.push(ttft);
        self.tokens += n_tokens as u64;
    }

    pub fn requests(&self) -> usize {
        self.latencies.len()
    }

    pub fn tokens(&self) -> u64 {
        self.tokens
    }

    pub fn elapsed(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens as f64 / self.elapsed()
    }

    pub fn latency_percentile(&self, q: f64) -> f64 {
        let mut s = self.latencies.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if s.is_empty() { 0.0 } else { percentile(&s, q) }
    }

    pub fn ttft_percentile(&self, q: f64) -> f64 {
        let mut s = self.ttfts.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if s.is_empty() { 0.0 } else { percentile(&s, q) }
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} tokens={} throughput={:.1} tok/s  \
             latency p50={} p95={}  ttft p50={}",
            self.requests(),
            self.tokens(),
            self.tokens_per_sec(),
            fmt_duration(self.latency_percentile(0.5)),
            fmt_duration(self.latency_percentile(0.95)),
            fmt_duration(self.ttft_percentile(0.5)),
        )
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_and_counts() {
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.observe_request(i as f64 / 1000.0, i as f64 / 2000.0, 4);
        }
        assert_eq!(m.requests(), 100);
        assert_eq!(m.tokens(), 400);
        assert!((m.latency_percentile(0.5) - 0.0505).abs() < 1e-3);
        assert!(m.latency_percentile(0.95) > m.latency_percentile(0.5));
        assert!(m.report().contains("requests=100"));
    }
}
