//! Serving metrics: request latency distribution, time-to-first-token,
//! token throughput, and the engine's decode-step/KV-copy accounting
//! (`kv_*` must be zero on the native in-place path — DESIGN.md §8).
//! Printed by `repro serve` and the serving example.

use std::time::Instant;

use crate::runtime::CopyStats;
use crate::util::stats::{percentile, fmt_duration};

/// Percentile over an unsorted sample set (0.0 when empty).
fn sorted_percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile(&s, q)
}

#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    latencies: Vec<f64>,
    ttfts: Vec<f64>,
    queue_waits: Vec<f64>,
    tokens: u64,
    decode_steps: u64,
    decode_rows: u64,
    prefill_rows: u64,
    preemptions: u64,
    cancelled: u64,
    prompt_tokens: u64,
    prompt_pad_tokens: u64,
    kv: CopyStats,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            started: Instant::now(),
            latencies: Vec::new(),
            ttfts: Vec::new(),
            queue_waits: Vec::new(),
            tokens: 0,
            decode_steps: 0,
            decode_rows: 0,
            prefill_rows: 0,
            preemptions: 0,
            cancelled: 0,
            prompt_tokens: 0,
            prompt_pad_tokens: 0,
            kv: CopyStats::default(),
        }
    }

    pub fn observe_request(&mut self, latency: f64, ttft: f64, n_tokens: usize) {
        self.latencies.push(latency);
        self.ttfts.push(ttft);
        self.tokens += n_tokens as u64;
    }

    /// One batched decode step over `rows` real sequences.
    pub fn observe_decode_step(&mut self, rows: usize) {
        self.decode_steps += 1;
        self.decode_rows += rows as u64;
    }

    /// `rows` of the last decode step carried chunked-prefill (replay)
    /// tokens rather than sampled decode tokens.
    pub fn observe_prefill_rows(&mut self, rows: usize) {
        self.prefill_rows += rows as u64;
    }

    /// Scheduler admission: time a session waited in the pending queue
    /// before it got a KV slot (first admission only).
    pub fn observe_queue_wait(&mut self, secs: f64) {
        self.queue_waits.push(secs);
    }

    /// The anti-starvation policy evicted an active session (its cache is
    /// recomputed by replay on re-admission).
    pub fn observe_preemption(&mut self) {
        self.preemptions += 1;
    }

    /// Admission accounting: `true_len` is the client's prompt length,
    /// `padded_len` the compiled window it was padded to (satellite fix:
    /// true lengths are tracked, never silently truncated).
    pub fn observe_prompt(&mut self, true_len: usize, padded_len: usize) {
        self.prompt_tokens += true_len as u64;
        self.prompt_pad_tokens += (padded_len - true_len.min(padded_len)) as u64;
    }

    /// Total true prompt tokens admitted.
    pub fn prompt_tokens(&self) -> u64 {
        self.prompt_tokens
    }

    /// Pad tokens spent filling prompts to the compiled window.
    pub fn prompt_pad_tokens(&self) -> u64 {
        self.prompt_pad_tokens
    }

    pub fn observe_cancelled(&mut self) {
        self.cancelled += 1;
    }

    /// Install the arena's copy accounting at worker shutdown.
    pub fn set_kv_copies(&mut self, kv: CopyStats) {
        self.kv = kv;
    }

    pub fn decode_steps(&self) -> u64 {
        self.decode_steps
    }

    pub fn prefill_rows(&self) -> u64 {
        self.prefill_rows
    }

    pub fn preemptions(&self) -> u64 {
        self.preemptions
    }

    pub fn queue_wait_percentile(&self, q: f64) -> f64 {
        sorted_percentile(&self.queue_waits, q)
    }

    pub fn cancelled(&self) -> u64 {
        self.cancelled
    }

    /// Bytes assembled into batch cache tensors (compat path only).
    pub fn kv_gather_bytes(&self) -> u64 {
        self.kv.gather_bytes
    }

    /// Bytes scattered back to per-sequence slots (compat path only).
    pub fn kv_scatter_bytes(&self) -> u64 {
        self.kv.scatter_bytes
    }

    /// KV bytes moved per decode step — 0 on the native in-place path.
    pub fn kv_bytes_per_step(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.kv.total_bytes() as f64 / self.decode_steps as f64
        }
    }

    pub fn requests(&self) -> usize {
        self.latencies.len()
    }

    pub fn tokens(&self) -> u64 {
        self.tokens
    }

    pub fn elapsed(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens as f64 / self.elapsed()
    }

    pub fn latency_percentile(&self, q: f64) -> f64 {
        sorted_percentile(&self.latencies, q)
    }

    pub fn ttft_percentile(&self, q: f64) -> f64 {
        sorted_percentile(&self.ttfts, q)
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} tokens={} throughput={:.1} tok/s  \
             latency p50={} p95={}  ttft p50={}  queue wait p50={}\n\
             decode steps={} (rows/step {:.2}, {} prefill rows)  \
             preemptions={}  cancelled={}  \
             prompt tokens={} (+{} pad)  \
             kv moved/step={:.0} B (gather {} B, scatter {} B)",
            self.requests(),
            self.tokens(),
            self.tokens_per_sec(),
            fmt_duration(self.latency_percentile(0.5)),
            fmt_duration(self.latency_percentile(0.95)),
            fmt_duration(self.ttft_percentile(0.5)),
            fmt_duration(self.queue_wait_percentile(0.5)),
            self.decode_steps,
            if self.decode_steps == 0 {
                0.0
            } else {
                self.decode_rows as f64 / self.decode_steps as f64
            },
            self.prefill_rows,
            self.preemptions,
            self.cancelled,
            self.prompt_tokens,
            self.prompt_pad_tokens,
            self.kv_bytes_per_step(),
            self.kv.gather_bytes,
            self.kv.scatter_bytes,
        )
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_and_counts() {
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.observe_request(i as f64 / 1000.0, i as f64 / 2000.0, 4);
        }
        assert_eq!(m.requests(), 100);
        assert_eq!(m.tokens(), 400);
        assert!((m.latency_percentile(0.5) - 0.0505).abs() < 1e-3);
        assert!(m.latency_percentile(0.95) > m.latency_percentile(0.5));
        assert!(m.report().contains("requests=100"));
    }

    #[test]
    fn kv_copy_accounting_per_step() {
        let mut m = Metrics::new();
        assert_eq!(m.kv_bytes_per_step(), 0.0);
        for _ in 0..4 {
            m.observe_decode_step(3);
        }
        m.observe_prefill_rows(2);
        m.observe_prefill_rows(3);
        m.observe_preemption();
        m.observe_queue_wait(0.25);
        m.observe_queue_wait(0.75);
        m.observe_cancelled();
        m.observe_prompt(12, 16);
        m.observe_prompt(16, 16);
        assert_eq!(m.prompt_tokens(), 28);
        assert_eq!(m.prompt_pad_tokens(), 4);
        assert_eq!(m.prefill_rows(), 5);
        assert_eq!(m.preemptions(), 1);
        assert!((m.queue_wait_percentile(0.5) - 0.5).abs() < 1e-9);
        m.set_kv_copies(CopyStats {
            gathers: 4,
            scatters: 4,
            gather_bytes: 4000,
            scatter_bytes: 1000,
        });
        assert_eq!(m.decode_steps(), 4);
        assert_eq!(m.cancelled(), 1);
        assert_eq!(m.kv_gather_bytes(), 4000);
        assert_eq!(m.kv_scatter_bytes(), 1000);
        assert!((m.kv_bytes_per_step() - 1250.0).abs() < 1e-9);
        let r = m.report();
        assert!(r.contains("decode steps=4"), "{r}");
        assert!(r.contains("cancelled=1"), "{r}");
        assert!(r.contains("preemptions=1"), "{r}");
        assert!(r.contains("5 prefill rows"), "{r}");
    }
}
