//! Serving metrics: request latency distribution, time-to-first-token,
//! token throughput, and the engine's decode-step/KV-copy accounting
//! (`kv_*` must be zero on the native in-place path — DESIGN.md §8).
//! Printed by `repro serve` and the serving example.
//!
//! The scalar books live in a **local** [`Counters`] instance keyed by
//! the `obs::registry` names (DESIGN.md §13) — `Metrics` is a reader
//! over that registry rather than a bag of ad-hoc fields.  Every
//! increment is mirrored into `obs::counters::global()` so the
//! exposition layer (`repro serve --metrics-out`, future `/metrics`)
//! sees engine activity without holding a reference to any `Metrics`;
//! the local instance is what keeps concurrent engines in one test
//! binary from reading each other's counts.

use std::time::Instant;

use crate::obs::counters::{self, Counters};
use crate::runtime::CopyStats;
use crate::util::stats::{percentile, fmt_duration};

/// Percentile over an unsorted sample set (0.0 when empty).  `total_cmp`
/// gives NaN a fixed sort position (after +inf) instead of panicking the
/// metrics path on a single corrupt latency sample.
fn sorted_percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.total_cmp(b));
    percentile(&s, q)
}

#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    latencies: Vec<f64>,
    ttfts: Vec<f64>,
    queue_waits: Vec<f64>,
    counters: Counters,
    kv: CopyStats,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            started: Instant::now(),
            latencies: Vec::new(),
            ttfts: Vec::new(),
            queue_waits: Vec::new(),
            counters: Counters::new(),
            kv: CopyStats::default(),
        }
    }

    /// Count locally and mirror into the process-wide registry.
    fn bump(&self, name: &'static str, v: u64) {
        self.counters.add(name, v);
        counters::global().add(name, v);
    }

    pub fn observe_request(&mut self, latency: f64, ttft: f64, n_tokens: usize) {
        self.latencies.push(latency);
        self.ttfts.push(ttft);
        self.bump("engine_tokens_total", n_tokens as u64);
    }

    /// One batched decode step over `rows` real sequences.
    pub fn observe_decode_step(&mut self, rows: usize) {
        self.bump("engine_decode_steps_total", 1);
        self.bump("engine_decode_rows_total", rows as u64);
    }

    /// `rows` of the last decode step carried chunked-prefill (replay)
    /// tokens rather than sampled decode tokens.
    pub fn observe_prefill_rows(&mut self, rows: usize) {
        self.bump("engine_prefill_rows_total", rows as u64);
    }

    /// Scheduler admission: time a session waited in the pending queue
    /// before it got a KV slot (first admission only).
    pub fn observe_queue_wait(&mut self, secs: f64) {
        self.queue_waits.push(secs);
    }

    /// The scheduler granted a session KV blocks (initial admission or
    /// resume after preemption).
    pub fn observe_admission(&mut self) {
        self.bump("sched_admissions_total", 1);
    }

    /// The anti-starvation policy evicted an active session (its cache is
    /// recomputed by replay on re-admission).
    pub fn observe_preemption(&mut self) {
        self.bump("sched_preemptions_total", 1);
    }

    /// Admission accounting: `true_len` is the client's prompt length,
    /// `padded_len` the compiled window it was padded to (satellite fix:
    /// true lengths are tracked, never silently truncated).
    pub fn observe_prompt(&mut self, true_len: usize, padded_len: usize) {
        self.bump("engine_prompt_tokens_total", true_len as u64);
        self.bump(
            "engine_prompt_pad_tokens_total",
            (padded_len - true_len.min(padded_len)) as u64,
        );
    }

    /// Prompt tokens served from the prefix cache (first admission only).
    /// Local books only: the arena's `acquire_prefix` already mirrors
    /// `kv_prefix_cached_tokens_total` into the global registry at the
    /// moment of adoption, so bumping it globally here too would
    /// double-count.
    pub fn observe_prefix(&mut self, cached_tokens: usize) {
        self.counters.add("kv_prefix_cached_tokens_total", cached_tokens as u64);
    }

    /// Prompt tokens whose prefill was skipped via prefix-cache adoption.
    pub fn prefix_cached_tokens(&self) -> u64 {
        self.counters.get("kv_prefix_cached_tokens_total")
    }

    /// Total true prompt tokens admitted.
    pub fn prompt_tokens(&self) -> u64 {
        self.counters.get("engine_prompt_tokens_total")
    }

    /// Pad tokens spent filling prompts to the compiled window.
    pub fn prompt_pad_tokens(&self) -> u64 {
        self.counters.get("engine_prompt_pad_tokens_total")
    }

    pub fn observe_cancelled(&mut self) {
        self.bump("engine_cancelled_total", 1);
    }

    /// Install the arena's copy accounting at worker shutdown.
    pub fn set_kv_copies(&mut self, kv: CopyStats) {
        self.kv = kv;
    }

    pub fn decode_steps(&self) -> u64 {
        self.counters.get("engine_decode_steps_total")
    }

    pub fn prefill_rows(&self) -> u64 {
        self.counters.get("engine_prefill_rows_total")
    }

    pub fn admissions(&self) -> u64 {
        self.counters.get("sched_admissions_total")
    }

    pub fn preemptions(&self) -> u64 {
        self.counters.get("sched_preemptions_total")
    }

    pub fn queue_wait_percentile(&self, q: f64) -> f64 {
        sorted_percentile(&self.queue_waits, q)
    }

    pub fn cancelled(&self) -> u64 {
        self.counters.get("engine_cancelled_total")
    }

    /// Bytes assembled into batch cache tensors (compat path only).
    pub fn kv_gather_bytes(&self) -> u64 {
        self.kv.gather_bytes
    }

    /// Bytes scattered back to per-sequence slots (compat path only).
    pub fn kv_scatter_bytes(&self) -> u64 {
        self.kv.scatter_bytes
    }

    /// KV bytes moved per decode step — 0 on the native in-place path.
    pub fn kv_bytes_per_step(&self) -> f64 {
        let steps = self.decode_steps();
        if steps == 0 {
            0.0
        } else {
            self.kv.total_bytes() as f64 / steps as f64
        }
    }

    pub fn requests(&self) -> usize {
        self.latencies.len()
    }

    pub fn tokens(&self) -> u64 {
        self.counters.get("engine_tokens_total")
    }

    pub fn elapsed(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens() as f64 / self.elapsed()
    }

    pub fn latency_percentile(&self, q: f64) -> f64 {
        sorted_percentile(&self.latencies, q)
    }

    pub fn ttft_percentile(&self, q: f64) -> f64 {
        sorted_percentile(&self.ttfts, q)
    }

    pub fn report(&self) -> String {
        let steps = self.decode_steps();
        format!(
            "requests={} tokens={} throughput={:.1} tok/s  \
             latency p50={} p95={}  ttft p50={}  queue wait p50={}\n\
             decode steps={} (rows/step {:.2}, {} prefill rows)  \
             preemptions={}  cancelled={}  \
             prompt tokens={} (+{} pad, {} cached)  \
             kv moved/step={:.0} B (gather {} B, scatter {} B)",
            self.requests(),
            self.tokens(),
            self.tokens_per_sec(),
            fmt_duration(self.latency_percentile(0.5)),
            fmt_duration(self.latency_percentile(0.95)),
            fmt_duration(self.ttft_percentile(0.5)),
            fmt_duration(self.queue_wait_percentile(0.5)),
            steps,
            if steps == 0 {
                0.0
            } else {
                self.counters.get("engine_decode_rows_total") as f64 / steps as f64
            },
            self.prefill_rows(),
            self.preemptions(),
            self.cancelled(),
            self.prompt_tokens(),
            self.prompt_pad_tokens(),
            self.prefix_cached_tokens(),
            self.kv_bytes_per_step(),
            self.kv.gather_bytes,
            self.kv.scatter_bytes,
        )
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_and_counts() {
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.observe_request(i as f64 / 1000.0, i as f64 / 2000.0, 4);
        }
        assert_eq!(m.requests(), 100);
        assert_eq!(m.tokens(), 400);
        assert!((m.latency_percentile(0.5) - 0.0505).abs() < 1e-3);
        assert!(m.latency_percentile(0.95) > m.latency_percentile(0.5));
        assert!(m.report().contains("requests=100"));
    }

    #[test]
    fn kv_copy_accounting_per_step() {
        let mut m = Metrics::new();
        assert_eq!(m.kv_bytes_per_step(), 0.0);
        for _ in 0..4 {
            m.observe_decode_step(3);
        }
        m.observe_prefill_rows(2);
        m.observe_prefill_rows(3);
        m.observe_preemption();
        m.observe_admission();
        m.observe_admission();
        m.observe_queue_wait(0.25);
        m.observe_queue_wait(0.75);
        m.observe_cancelled();
        m.observe_prompt(12, 16);
        m.observe_prompt(16, 16);
        m.observe_prefix(8);
        assert_eq!(m.prompt_tokens(), 28);
        assert_eq!(m.prompt_pad_tokens(), 4);
        assert_eq!(m.prefix_cached_tokens(), 8);
        assert_eq!(m.prefill_rows(), 5);
        assert_eq!(m.preemptions(), 1);
        assert_eq!(m.admissions(), 2);
        assert!((m.queue_wait_percentile(0.5) - 0.5).abs() < 1e-9);
        m.set_kv_copies(CopyStats {
            gathers: 4,
            scatters: 4,
            gather_bytes: 4000,
            scatter_bytes: 1000,
        });
        assert_eq!(m.decode_steps(), 4);
        assert_eq!(m.cancelled(), 1);
        assert_eq!(m.kv_gather_bytes(), 4000);
        assert_eq!(m.kv_scatter_bytes(), 1000);
        assert!((m.kv_bytes_per_step() - 1250.0).abs() < 1e-9);
        let r = m.report();
        assert!(r.contains("decode steps=4"), "{r}");
        assert!(r.contains("cancelled=1"), "{r}");
        assert!(r.contains("preemptions=1"), "{r}");
        assert!(r.contains("5 prefill rows"), "{r}");
        assert!(r.contains("8 cached"), "{r}");
    }

    #[test]
    fn two_engines_keep_independent_books() {
        // the regression the per-Metrics local registry instance guards:
        // two live Metrics (concurrent engines in one test binary) must
        // not bleed counts into each other, whatever the global mirror
        // accumulates.
        let mut a = Metrics::new();
        let mut b = Metrics::new();
        a.observe_decode_step(2);
        a.observe_decode_step(2);
        b.observe_decode_step(7);
        assert_eq!(a.decode_steps(), 2);
        assert_eq!(b.decode_steps(), 1);
    }

    #[test]
    fn nan_latency_does_not_panic_the_percentiles() {
        // regression: sorted_percentile used partial_cmp().unwrap(), so a
        // single NaN sample panicked the shutdown report.
        let mut m = Metrics::new();
        m.observe_request(0.5, 0.1, 1);
        m.observe_request(f64::NAN, f64::NAN, 1);
        m.observe_request(0.25, 0.05, 1);
        m.observe_queue_wait(f64::NAN);
        let p50 = m.latency_percentile(0.5);
        assert!(p50.is_finite(), "median of {{0.25, 0.5, NaN}} picked {p50}");
        assert!((p50 - 0.5).abs() < 1e-9, "NaN sorts after +inf, median is 0.5");
        // the report renders without panicking even with NaN samples
        assert!(m.report().contains("requests=3"));
    }
}
