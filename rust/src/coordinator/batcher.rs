//! Dynamic batching policy: the pure, testable core of the serving
//! coordinator (vLLM-router-style max-batch / max-wait policy).
//!
//! The policy is deliberately separated from threads and channels so its
//! invariants can be property-tested exhaustively:
//!   * no request is lost or duplicated,
//!   * a batch never exceeds `max_batch`,
//!   * no admitted request waits longer than `max_wait` once the clock
//!     advances (modulo an in-flight batch),
//!   * FIFO order is preserved within a batch.

use std::collections::VecDeque;
use std::time::Duration;

/// Policy configuration.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Largest batch the executor accepts (a compiled bucket size).
    pub max_batch: usize,
    /// Max time the oldest queued request may wait before a partial batch
    /// is dispatched anyway.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) }
    }
}

/// A queued request with its enqueue timestamp (abstract clock, seconds).
#[derive(Debug, Clone, PartialEq)]
pub struct Queued<T> {
    pub item: T,
    pub enqueued_at: f64,
}

/// The batching queue.  Generic over the request payload.
#[derive(Debug)]
pub struct Batcher<T> {
    policy: BatchPolicy,
    queue: VecDeque<Queued<T>>,
    /// Monotonic counters for invariant checking / metrics.
    pub enqueued: u64,
    pub dispatched: u64,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { policy, queue: VecDeque::new(), enqueued: 0, dispatched: 0 }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn push(&mut self, item: T, now: f64) {
        self.queue.push_back(Queued { item, enqueued_at: now });
        self.enqueued += 1;
    }

    /// Should a batch be dispatched right now?
    pub fn ready(&self, now: f64) -> bool {
        if self.queue.len() >= self.policy.max_batch {
            return true;
        }
        match self.queue.front() {
            Some(front) => now - front.enqueued_at >= self.policy.max_wait.as_secs_f64(),
            None => false,
        }
    }

    /// Time until the oldest request hits its deadline (for worker sleeps).
    pub fn time_to_deadline(&self, now: f64) -> Option<Duration> {
        self.queue.front().map(|f| {
            let dl = f.enqueued_at + self.policy.max_wait.as_secs_f64();
            Duration::from_secs_f64((dl - now).max(0.0))
        })
    }

    /// Pop the next batch (up to max_batch, FIFO).  Call when `ready`.
    pub fn take_batch(&mut self) -> Vec<Queued<T>> {
        let n = self.queue.len().min(self.policy.max_batch);
        let batch: Vec<_> = self.queue.drain(..n).collect();
        self.dispatched += batch.len() as u64;
        batch
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, PropConfig};
    use crate::util::rng::Rng;

    fn policy(max_batch: usize, max_wait_ms: u64) -> BatchPolicy {
        BatchPolicy { max_batch, max_wait: Duration::from_millis(max_wait_ms) }
    }

    #[test]
    fn dispatches_full_batch_immediately() {
        let mut b = Batcher::new(policy(4, 100));
        for i in 0..4 {
            b.push(i, 0.0);
        }
        assert!(b.ready(0.0));
        let batch = b.take_batch();
        assert_eq!(batch.iter().map(|q| q.item).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn partial_batch_waits_for_deadline() {
        let mut b = Batcher::new(policy(4, 100));
        b.push(1u32, 0.0);
        assert!(!b.ready(0.05));
        assert!(b.ready(0.11));
        assert_eq!(b.take_batch().len(), 1);
    }

    #[test]
    fn batch_never_exceeds_max() {
        let mut b = Batcher::new(policy(3, 1));
        for i in 0..10 {
            b.push(i, 0.0);
        }
        assert_eq!(b.take_batch().len(), 3);
        assert_eq!(b.take_batch().len(), 3);
        assert_eq!(b.len(), 4);
    }

    #[test]
    fn deadline_exactly_at_now_dispatches() {
        // boundary: `now - enqueued_at >= max_wait` is inclusive, so a
        // request is ready at EXACTLY its deadline, not one tick later
        let mut b = Batcher::new(policy(4, 10));
        b.push(1u32, 1.0);
        assert!(!b.ready(1.009_999));
        assert!(b.ready(1.010), "deadline exactly at now must dispatch");
        assert_eq!(b.time_to_deadline(1.010).unwrap(), Duration::ZERO);
        assert_eq!(b.take_batch().len(), 1);
    }

    #[test]
    fn max_batch_one_degenerates_to_fifo_singletons() {
        let mut b = Batcher::new(policy(1, 100));
        for i in 0..3 {
            b.push(i, 0.0);
        }
        // every queued item makes a full batch of one, immediately
        for expect in 0..3 {
            assert!(b.ready(0.0));
            let batch = b.take_batch();
            assert_eq!(batch.len(), 1);
            assert_eq!(batch[0].item, expect, "FIFO order preserved at max_batch=1");
        }
        assert!(!b.ready(1000.0), "drained queue never becomes ready");
        assert_eq!((b.enqueued, b.dispatched), (3, 3));
    }

    #[test]
    fn empty_take_batch_is_a_harmless_noop() {
        let mut b = Batcher::new(policy(4, 10));
        assert!(b.take_batch().is_empty());
        assert_eq!(b.dispatched, 0);
        assert!(b.is_empty());
        // still works normally afterwards
        b.push(7u8, 0.0);
        assert_eq!(b.take_batch()[0].item, 7);
        assert!(b.take_batch().is_empty());
        assert_eq!(b.dispatched, 1);
    }

    #[test]
    fn deadline_countdown() {
        let mut b = Batcher::new(policy(8, 10));
        assert!(b.time_to_deadline(0.0).is_none());
        b.push(0u8, 1.0);
        let d = b.time_to_deadline(1.004).unwrap();
        assert!((d.as_secs_f64() - 0.006).abs() < 1e-9);
        assert_eq!(b.time_to_deadline(2.0).unwrap(), Duration::ZERO);
    }

    #[test]
    fn prop_no_loss_no_duplication_fifo() {
        check("batcher-conservation", PropConfig::default(), |rng: &mut Rng| {
            let max_batch = rng.range_usize(1, 9);
            let max_wait = rng.range_i64(1, 50) as u64;
            let mut b = Batcher::new(policy(max_batch, max_wait));
            let n = rng.range_usize(1, 200);
            let mut now = 0.0;
            let mut out: Vec<usize> = Vec::new();
            let mut pushed = 0usize;
            while out.len() < n {
                // interleave pushes and dispatches randomly
                if pushed < n && rng.next_f64() < 0.6 {
                    b.push(pushed, now);
                    pushed += 1;
                }
                now += rng.next_f64() * 0.01;
                while b.ready(now) {
                    out.extend(b.take_batch().into_iter().map(|q| q.item));
                }
                if pushed == n {
                    now += 1.0; // flush via deadline
                }
            }
            crate::prop_assert!(
                out == (0..n).collect::<Vec<_>>(),
                "requests lost/duplicated/reordered: {out:?}"
            );
            crate::prop_assert!(
                b.enqueued == b.dispatched && b.is_empty(),
                "counters diverge: {} vs {}", b.enqueued, b.dispatched
            );
            Ok(())
        });
    }

    #[test]
    fn prop_batches_bounded_and_deadline_respected() {
        check("batcher-bounds", PropConfig::default(), |rng: &mut Rng| {
            let max_batch = rng.range_usize(1, 6);
            let wait_ms = rng.range_i64(1, 20) as u64;
            let mut b = Batcher::new(policy(max_batch, wait_ms));
            let mut now = 0.0;
            for i in 0..100 {
                b.push(i, now);
                now += rng.next_f64() * 0.005;
                if b.ready(now) {
                    let batch = b.take_batch();
                    crate::prop_assert!(
                        batch.len() <= max_batch,
                        "batch too big: {}", batch.len()
                    );
                    // the oldest dispatched item must not have exceeded its
                    // deadline by more than the simulation step
                    let age = now - batch[0].enqueued_at;
                    crate::prop_assert!(
                        age <= wait_ms as f64 / 1000.0 + 0.005 + 1e-9
                            || batch.len() == max_batch,
                        "deadline violated: age {age}"
                    );
                }
            }
            Ok(())
        });
    }
}
