//! Serving coordinator: the session-based serving engine (typed
//! `Engine`/`Session` API with streamed tokens and a zero-copy KV arena —
//! DESIGN.md §8) driven by the continuous-batching scheduler (per-step
//! admission, chunked prefill, KV-pressure backpressure and anti-starvation
//! preemption — DESIGN.md §9), the dynamic batcher policy, serving
//! metrics, and the deprecated `Server` shim kept for one release.  The
//! paper's kernel slots into serving as the prefill/decode compute; the
//! coordinator proves the artifacts compose into a request-driven system
//! with Python off the request path.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod scheduler;
pub mod server;
