//! Serving coordinator: dynamic batcher policy, mini-vLLM decode server,
//! and serving metrics.  The paper's kernel slots into serving as the
//! prefill compute; the coordinator proves the artifacts compose into a
//! request-driven system with Python off the request path.

pub mod batcher;
pub mod metrics;
pub mod server;
