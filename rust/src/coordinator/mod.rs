//! Serving coordinator: the session-based serving engine (typed
//! `Engine`/`Session` API with streamed tokens and a zero-copy **paged**
//! KV arena — DESIGN.md §8/§11) driven by the continuous-batching
//! scheduler (per-step admission with block-level KV reservation, chunked
//! prefill, typed backpressure and anti-starvation preemption —
//! DESIGN.md §9), the dynamic batcher policy, and serving metrics.  (The
//! deprecated pre-engine `Server` shim shipped its one release of
//! back-compat in PR 3/4 and is now gone; `Engine::submit` +
//! `Session::wait` is the replacement.)  The paper's kernel slots into
//! serving as the prefill/decode compute; the coordinator proves the
//! artifacts compose into a request-driven system with Python off the
//! request path.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod scheduler;
