//! The session-based serving engine (DESIGN.md §8): a typed
//! [`Engine`]/[`Session`] API over the coordinator worker.
//!
//! Where the old `Server` took a `GenRequest` and answered with one final
//! `GenResponse`, the engine:
//!
//! - discovers a [`ModelBundle`] from the manifest by typed query
//!   (`ArtifactKind` + `meta.model`) instead of format-string name
//!   guessing, and drives decode grouping from the discovered
//!   [`DecodeBuckets`] rather than a hardcoded 1/4 pair;
//! - hands each request a [`Session`] carrying [`SamplingParams`] (greedy
//!   by default; temperature/top-k with the seeded in-tree RNG) and
//!   **streams** [`TokenEvent`]s — first token, per-token deltas, and a
//!   final finish reason — instead of buffering the whole generation;
//! - rejects over-long prompts ([`EngineError::PromptTooLong`] — the old
//!   server silently truncated and padded with token 0) and out-of-vocab
//!   tokens ([`EngineError::TokenOutOfVocab`] — one bad request must not
//!   poison the shared worker) *before* they reach the worker, and fails
//!   fast with [`EngineError::Closed`] when the worker is gone (the old
//!   server dropped the send error and left clients blocked forever);
//! - owns a [`KvArena`]: per-sequence cache slots decoded **in place**
//!   through the widened `Module::decode_step` seam — zero per-token
//!   assemble/scatter bytes on the native backend (metrics-asserted).
//!
//! Dropping a `Session` (or calling [`Session::cancel`]) cancels the
//! request; the worker retires it with [`FinishReason::Cancelled`] at the
//! next step boundary and frees its cache slot.

use std::collections::BTreeMap;
use std::fmt;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::util::error::{Error, Result};

use crate::runtime::{BackendKind, KvArena, KvSlot, ModelBundle, Runtime, ServeShapes};
use crate::util::rng::Rng;
use crate::util::tensorio::HostTensor;

use super::metrics::Metrics;

/// Per-session sampling configuration.  The default is greedy argmax
/// (temperature 0), which reproduces the old server's decoding exactly.
#[derive(Debug, Clone, PartialEq)]
pub struct SamplingParams {
    /// Stop after this many generated tokens (>= 1; the prefill token
    /// counts).
    pub max_tokens: usize,
    /// 0.0 = greedy argmax; > 0 samples from softmax(logits / temperature).
    pub temperature: f32,
    /// Restrict sampling to the k highest logits; 0 = no cutoff.
    pub top_k: usize,
    /// Seed for the per-session RNG (only consulted when temperature > 0).
    pub seed: u64,
    /// Generation finishes (reason `Stop`) when one of these is sampled;
    /// the stop token is included in the output.
    pub stop_tokens: Vec<i32>,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            max_tokens: 16,
            temperature: 0.0,
            top_k: 0,
            seed: 0,
            stop_tokens: Vec::new(),
        }
    }
}

impl SamplingParams {
    /// Greedy decoding for `max_tokens` tokens — the old `GenRequest`
    /// semantics.
    pub fn greedy(max_tokens: usize) -> SamplingParams {
        SamplingParams { max_tokens: max_tokens.max(1), ..Default::default() }
    }
}

/// Why a session finished.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Generated `max_tokens` tokens.
    MaxTokens,
    /// Sampled a token from `stop_tokens`.
    Stop,
    /// The KV cache reached the compiled `max_seq` window.
    ContextFull,
    /// The client cancelled (dropped the `Session` or called `cancel`).
    Cancelled,
}

/// One streamed event on a session's channel.  Events arrive strictly in
/// order: `First` (index 0), then `Delta`s with consecutive indices, then
/// exactly one `Done`.
#[derive(Debug, Clone, PartialEq)]
pub enum TokenEvent {
    /// The first generated token (produced by prefill), with
    /// time-to-first-token.
    First { token: i32, ttft_secs: f64 },
    /// A subsequent decode token; `index` counts all generated tokens, so
    /// deltas start at 1.
    Delta { index: usize, token: i32 },
    /// Terminal event: the finish reason plus the complete token list and
    /// latency accounting.
    Done { finish: FinishReason, tokens: Vec<i32>, latency_secs: f64, ttft_secs: f64 },
}

impl TokenEvent {
    /// The generation index this event carries, if any (`First` is 0).
    pub fn index(&self) -> Option<usize> {
        match self {
            TokenEvent::First { .. } => Some(0),
            TokenEvent::Delta { index, .. } => Some(*index),
            TokenEvent::Done { .. } => None,
        }
    }

    pub fn token(&self) -> Option<i32> {
        match self {
            TokenEvent::First { token, .. } | TokenEvent::Delta { token, .. } => Some(*token),
            TokenEvent::Done { .. } => None,
        }
    }
}

/// The drained result of a finished session.
#[derive(Debug, Clone, PartialEq)]
pub struct Completion {
    pub tokens: Vec<i32>,
    pub finish: FinishReason,
    /// end-to-end latency (submit -> done), seconds
    pub latency: f64,
    /// time to first token (prefill), seconds
    pub ttft: f64,
}

/// Typed submission errors — the conditions a client can act on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EngineError {
    /// The prompt exceeds the compiled prompt window.  The old server
    /// silently dropped the excess tokens and padded with token 0 (which
    /// attends as real context); the engine refuses instead.
    PromptTooLong { len: usize, max: usize },
    /// A prompt token is outside the model's vocabulary.  Rejected at
    /// submission so one bad request cannot poison the shared worker
    /// (backend modules treat out-of-range tokens as a fatal engine
    /// error).
    TokenOutOfVocab { token: i32, vocab: usize },
    /// The worker thread has shut down (or died); nothing submitted now
    /// can ever complete, so fail fast instead of blocking forever.
    Closed,
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::PromptTooLong { len, max } => write!(
                f,
                "prompt has {len} tokens but the model's compiled prompt window is {max}"
            ),
            EngineError::TokenOutOfVocab { token, vocab } => {
                write!(f, "prompt token {token} is outside the model vocabulary 0..{vocab}")
            }
            EngineError::Closed => write!(f, "engine is closed (worker thread has exited)"),
        }
    }
}

impl std::error::Error for EngineError {}

/// A live request handle: streamed events plus cancellation.
pub struct Session {
    events: Receiver<TokenEvent>,
    cancel: Arc<AtomicBool>,
    /// Dropping the handle cancels the request unless detached (the
    /// deprecated `Server` shim detaches to keep the old fire-and-forget
    /// submit semantics).
    cancel_on_drop: bool,
}

impl Session {
    /// Blocking receive of the next event; `None` once the stream ends
    /// (after `Done`, or if the engine died mid-generation).
    pub fn recv(&self) -> Option<TokenEvent> {
        self.events.recv().ok()
    }

    /// Non-blocking receive: `Ok(None)` means no event *yet*;
    /// `Err(Closed)` means the engine died and no event will ever arrive
    /// (so pollers don't spin forever on a dead stream).
    pub fn try_recv(&self) -> Result<Option<TokenEvent>, EngineError> {
        match self.events.try_recv() {
            Ok(ev) => Ok(Some(ev)),
            Err(std::sync::mpsc::TryRecvError::Empty) => Ok(None),
            Err(std::sync::mpsc::TryRecvError::Disconnected) => Err(EngineError::Closed),
        }
    }

    /// Request cancellation; the worker retires the session with
    /// `FinishReason::Cancelled` at the next step boundary.  (Dropping the
    /// session does the same.)
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }

    /// Disarm drop-cancellation: the request keeps generating (and is
    /// counted in metrics) even if this handle is dropped.
    pub fn detach(&mut self) {
        self.cancel_on_drop = false;
    }

    /// Drain events to completion and return the final result.
    pub fn wait(self) -> Result<Completion> {
        self.drain()
    }

    /// Shared drain loop behind [`wait`](Self::wait) and the deprecated
    /// shim's `GenHandle::recv`.
    pub(crate) fn drain(&self) -> Result<Completion> {
        loop {
            match self.events.recv() {
                Ok(TokenEvent::Done { finish, tokens, latency_secs, ttft_secs }) => {
                    return Ok(Completion {
                        tokens,
                        finish,
                        latency: latency_secs,
                        ttft: ttft_secs,
                    })
                }
                Ok(_) => continue,
                Err(_) => {
                    return Err(Error::msg(
                        "engine closed before the session finished (worker died)",
                    ))
                }
            }
        }
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        // dropping the handle cancels the request; harmless after Done
        if self.cancel_on_drop {
            self.cancel.store(true, Ordering::Relaxed);
        }
    }
}

struct Incoming {
    prompt: Vec<i32>,
    sampling: SamplingParams,
    events_tx: Sender<TokenEvent>,
    cancel: Arc<AtomicBool>,
    submitted: Instant,
}

/// The serving engine: typed submissions in, streamed sessions out.
///
/// The backend and executables are created INSIDE the worker thread: the
/// `xla` crate's handles are `!Send` (Rc internals), so the worker owns
/// the whole runtime and talks to clients only through channels.
pub struct Engine {
    tx: Sender<Incoming>,
    shapes: ServeShapes,
    handle: JoinHandle<Result<Metrics>>,
}

impl Engine {
    /// Start the worker on an explicit backend (`BackendKind::Native`
    /// needs no artifacts on disk).
    pub fn start(artifact_dir: PathBuf, model: &str, backend: BackendKind) -> Result<Engine> {
        let model = model.to_string();
        let (tx, rx) = channel::<Incoming>();
        let (ready_tx, ready_rx) = channel::<Result<ServeShapes>>();
        let handle = std::thread::spawn(move || {
            let setup = || -> Result<(ModelBundle, Vec<HostTensor>)> {
                let rt = Runtime::with_backend(&artifact_dir, backend)?;
                let bundle = ModelBundle::discover(&rt, &model)?;
                // Materialize the weights once via the init artifact (seed
                // 0): the flat param list is shared by prefill and decode.
                let params = bundle.init.run(&[HostTensor::scalar_u32(0)])?;
                Ok((bundle, params))
            };
            match setup() {
                Ok((bundle, params)) => {
                    let _ = ready_tx.send(Ok(bundle.shapes));
                    worker(rx, bundle, params)
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    Ok(Metrics::new())
                }
            }
        });
        let shapes = ready_rx
            .recv()
            .map_err(|_| Error::msg("engine worker died during setup"))??;
        Ok(Engine { tx, shapes, handle })
    }

    /// The serving model's compiled shapes (prompt window, vocab, ...).
    pub fn shapes(&self) -> ServeShapes {
        self.shapes
    }

    /// Open a session: validates the prompt against the compiled window
    /// and enqueues it.  Fails fast with a typed error instead of
    /// truncating prompts or blocking on a dead worker.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        sampling: SamplingParams,
    ) -> Result<Session, EngineError> {
        if prompt.len() > self.shapes.prompt_len {
            return Err(EngineError::PromptTooLong {
                len: prompt.len(),
                max: self.shapes.prompt_len,
            });
        }
        if let Some(&t) = prompt.iter().find(|&&t| t < 0 || t as usize >= self.shapes.vocab)
        {
            return Err(EngineError::TokenOutOfVocab { token: t, vocab: self.shapes.vocab });
        }
        let (events_tx, events) = channel();
        let cancel = Arc::new(AtomicBool::new(false));
        let incoming = Incoming {
            prompt,
            sampling,
            events_tx,
            cancel: cancel.clone(),
            submitted: Instant::now(),
        };
        self.tx.send(incoming).map_err(|_| EngineError::Closed)?;
        Ok(Session { events, cancel, cancel_on_drop: true })
    }

    /// Close the queue, wait for in-flight sessions to finish, and return
    /// the serving metrics.
    pub fn shutdown(self) -> Result<Metrics> {
        let Engine { tx, handle, .. } = self;
        drop(tx);
        handle.join().map_err(|_| Error::msg("engine worker panicked"))?
    }
}

// ---------------------------------------------------------------------------
// sampling

/// NaN-safe argmax: NaN entries never win; ties go to the first maximum.
/// (The old server's `x > xs[best]` got stuck on index 0 whenever
/// `xs[0]` was NaN.)
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut best_val = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > best_val {
            best = i;
            best_val = x;
        }
    }
    best
}

fn nan_to_neg_inf(x: f32) -> f32 {
    if x.is_nan() {
        f32::NEG_INFINITY
    } else {
        x
    }
}

/// Sample one token id from `logits` under `p` (greedy when temperature
/// is 0).  Deterministic given the RNG state.
fn sample_token(logits: &[f32], p: &SamplingParams, rng: &mut Rng) -> i32 {
    if p.temperature <= 0.0 {
        return argmax(logits) as i32;
    }
    let n = logits.len();
    let k = if p.top_k == 0 || p.top_k > n { n } else { p.top_k };
    let by_logit_desc = |a: &usize, b: &usize| {
        nan_to_neg_inf(logits[*b])
            .partial_cmp(&nan_to_neg_inf(logits[*a]))
            .expect("NaNs mapped to -inf")
            .then(a.cmp(b))
    };
    let cand: Vec<usize> = if k == n {
        (0..n).collect()
    } else {
        // hot path: select the top k in O(n), sort only the k survivors
        let mut idx: Vec<usize> = (0..n).collect();
        idx.select_nth_unstable_by(k - 1, by_logit_desc);
        idx.truncate(k);
        idx.sort_unstable_by(by_logit_desc);
        idx
    };
    let m = cand
        .iter()
        .map(|&i| nan_to_neg_inf(logits[i]))
        .fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return argmax(logits) as i32; // degenerate logits: fall back to greedy
    }
    let weights: Vec<f64> = cand
        .iter()
        .map(|&i| (((nan_to_neg_inf(logits[i]) - m) / p.temperature) as f64).exp())
        .collect();
    let total: f64 = weights.iter().sum();
    let u = rng.next_f64() * total;
    let mut acc = 0.0;
    for (&i, &w) in cand.iter().zip(&weights) {
        acc += w;
        if u < acc {
            return i as i32;
        }
    }
    *cand.last().expect("candidate set is non-empty") as i32
}

struct Sampler {
    params: SamplingParams,
    rng: Rng,
}

impl Sampler {
    fn new(params: SamplingParams) -> Sampler {
        let rng = Rng::seed_from(0x5E55_1014 ^ params.seed);
        Sampler { params, rng }
    }

    fn next(&mut self, logits: &[f32]) -> i32 {
        sample_token(logits, &self.params, &mut self.rng)
    }
}

// ---------------------------------------------------------------------------
// worker

/// One active sequence's server-side state.
struct SeqState {
    events_tx: Sender<TokenEvent>,
    cancel: Arc<AtomicBool>,
    submitted: Instant,
    ttft: f64,
    /// True (pre-padding) prompt length, tracked per satellite fix: the
    /// compiled prefill pads shorter prompts to `prompt_len` with token 0
    /// (part of the fixed-shape artifact contract); over-long prompts are
    /// rejected at `submit` instead of silently truncated.
    prompt_len: usize,
    generated: Vec<i32>,
    sampler: Sampler,
    /// Next KV write position (starts at the padded prompt window).
    pos: i32,
    slot: KvSlot,
}

fn finish_reason(s: &SeqState, shapes: &ServeShapes) -> Option<FinishReason> {
    if s.cancel.load(Ordering::Relaxed) {
        return Some(FinishReason::Cancelled);
    }
    let last = *s.generated.last().expect("admitted with >= 1 token");
    if s.sampler.params.stop_tokens.contains(&last) {
        return Some(FinishReason::Stop);
    }
    if s.generated.len() >= s.sampler.params.max_tokens {
        return Some(FinishReason::MaxTokens);
    }
    if s.pos as usize >= shapes.max_seq {
        return Some(FinishReason::ContextFull);
    }
    None
}

fn retire_finished(
    active: &mut BTreeMap<u64, SeqState>,
    arena: &mut KvArena,
    metrics: &mut Metrics,
    shapes: &ServeShapes,
) {
    let done: Vec<(u64, FinishReason)> = active
        .iter()
        .filter_map(|(id, s)| finish_reason(s, shapes).map(|r| (*id, r)))
        .collect();
    for (id, finish) in done {
        let s = active.remove(&id).expect("id came from the map");
        arena.free(s.slot);
        let latency = s.submitted.elapsed().as_secs_f64();
        // Cancelled sessions are counted separately — folding an aborted
        // generation into the latency/TTFT percentiles would skew the
        // numbers the serving report exists to measure.
        if finish == FinishReason::Cancelled {
            metrics.observe_cancelled();
        } else {
            metrics.observe_request(latency, s.ttft, s.generated.len());
        }
        let _ = s.events_tx.send(TokenEvent::Done {
            finish,
            tokens: s.generated,
            latency_secs: latency,
            ttft_secs: s.ttft,
        });
    }
}

/// Admit one request: prefill, adopt the cache pair into the arena, emit
/// the `First` event.
fn admit(
    bundle: &ModelBundle,
    params: &[HostTensor],
    arena: &mut KvArena,
    inc: Incoming,
) -> Result<SeqState> {
    let shapes = bundle.shapes;
    let true_len = inc.prompt.len();
    debug_assert!(true_len <= shapes.prompt_len, "submit() validates the prompt window");
    // Pad the prompt to the compiled window (token 0); see `prompt_len`.
    let mut prompt = inc.prompt;
    prompt.resize(shapes.prompt_len, 0);
    let tokens = HostTensor::from_i32(&[1, shapes.prompt_len], &prompt);
    let mut inputs: Vec<HostTensor> = params.to_vec();
    inputs.push(tokens);
    let out = bundle.prefill.run(&inputs)?;
    let mut sampler = Sampler::new(inc.sampling);
    let first = sampler.next(&out[0].to_f32_vec());
    let ttft = inc.submitted.elapsed().as_secs_f64();
    let slot = arena.adopt(out[1].to_f32_vec(), out[2].to_f32_vec())?;
    let _ = inc.events_tx.send(TokenEvent::First { token: first, ttft_secs: ttft });
    Ok(SeqState {
        events_tx: inc.events_tx,
        cancel: inc.cancel,
        submitted: inc.submitted,
        ttft,
        prompt_len: true_len,
        generated: vec![first],
        sampler,
        pos: shapes.prompt_len as i32,
        slot,
    })
}

fn worker(
    rx: Receiver<Incoming>,
    bundle: ModelBundle,
    params: Vec<HostTensor>,
) -> Result<Metrics> {
    let shapes = bundle.shapes;
    let mut arena = KvArena::new(shapes.geometry());
    let mut metrics = Metrics::new();
    let mut active: BTreeMap<u64, SeqState> = BTreeMap::new();
    let mut next_id = 0u64;
    let mut closed = false;

    while !closed || !active.is_empty() {
        // Admission: drain the queue (block only when idle).
        loop {
            let msg = if active.is_empty() && !closed {
                match rx.recv() {
                    Ok(m) => Some(m),
                    Err(_) => {
                        closed = true;
                        None
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => Some(m),
                    Err(TryRecvError::Empty) => None,
                    Err(TryRecvError::Disconnected) => {
                        closed = true;
                        None
                    }
                }
            };
            let Some(inc) = msg else { break };
            if inc.cancel.load(Ordering::Relaxed) {
                // cancelled before prefill: don't spend the compute
                metrics.observe_cancelled();
                let _ = inc.events_tx.send(TokenEvent::Done {
                    finish: FinishReason::Cancelled,
                    tokens: Vec::new(),
                    latency_secs: inc.submitted.elapsed().as_secs_f64(),
                    ttft_secs: 0.0,
                });
                continue;
            }
            // Backend/module failures here are deliberately engine-fatal
            // (matching the old worker): submit() has already validated
            // everything client-controllable (prompt window, token range),
            // so an error at prefill or decode means the backend itself is
            // broken and the engine should fail loudly, not limp on.
            let state = admit(&bundle, &params, &mut arena, inc)?;
            metrics.observe_prompt(state.prompt_len, shapes.prompt_len);
            active.insert(next_id, state);
            next_id += 1;
        }

        // Retire sessions that finished at prefill (max_tokens 1, stop on
        // the first token) or were cancelled — before spending decode
        // compute on them.
        retire_finished(&mut active, &mut arena, &mut metrics, &shapes);
        if active.is_empty() {
            continue;
        }

        // One decode step over the active set, grouped by the discovered
        // buckets: chunk by the largest bucket, pick the smallest bucket
        // that fits each chunk.
        let ids: Vec<u64> = active.keys().cloned().collect();
        for group in ids.chunks(bundle.buckets.max()) {
            let bucket = bundle.buckets.pick(group.len());
            let exe = bundle.decode_for(bucket)?;
            let slots: Vec<KvSlot> = group.iter().map(|id| active[id].slot).collect();
            let mut tok = Vec::with_capacity(group.len());
            let mut pos = Vec::with_capacity(group.len());
            for id in group {
                let s = &active[id];
                tok.push(*s.generated.last().expect("admitted with >= 1 token"));
                pos.push(s.pos);
            }
            let logits = {
                let mut view = arena.batch_view(&slots, bucket);
                exe.decode_step(&params, &mut view, &tok, &pos)?
            };
            metrics.observe_decode_step(group.len());
            for (bi, id) in group.iter().enumerate() {
                let s = active.get_mut(id).expect("id came from the map");
                let row = &logits[bi * shapes.vocab..(bi + 1) * shapes.vocab];
                let t = s.sampler.next(row);
                s.generated.push(t);
                s.pos += 1;
                let _ = s
                    .events_tx
                    .send(TokenEvent::Delta { index: s.generated.len() - 1, token: t });
            }
        }

        retire_finished(&mut active, &mut arena, &mut metrics, &shapes);
    }
    metrics.set_kv_copies(arena.stats());
    Ok(metrics)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_first_max_and_survives_nan() {
        assert_eq!(argmax(&[0.1, 3.0, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
        // NaN at the front no longer wedges the result at index 0
        assert_eq!(argmax(&[f32::NAN, 1.0, 2.0]), 2);
        assert_eq!(argmax(&[1.0, f32::NAN, 0.5]), 0);
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
    }

    #[test]
    fn greedy_sampling_is_argmax() {
        let logits = [0.5, 2.0, -1.0, 1.9];
        let mut rng = Rng::seed_from(1);
        let p = SamplingParams::greedy(4);
        assert_eq!(p.max_tokens, 4);
        for _ in 0..5 {
            assert_eq!(sample_token(&logits, &p, &mut rng), 1);
        }
    }

    #[test]
    fn temperature_sampling_is_seeded_and_in_top_k() {
        let logits: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin()).collect();
        let p = SamplingParams {
            max_tokens: 8,
            temperature: 0.9,
            top_k: 4,
            seed: 11,
            stop_tokens: vec![],
        };
        // top-4 indices by logit
        let mut idx: Vec<usize> = (0..32).collect();
        idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
        let top4 = &idx[..4];
        let draw = |seed: u64| -> Vec<i32> {
            let mut rng = Rng::seed_from(seed);
            (0..64).map(|_| sample_token(&logits, &p, &mut rng)).collect()
        };
        let a = draw(7);
        assert_eq!(a, draw(7), "same RNG seed must reproduce the draw sequence");
        assert!(a.iter().all(|t| top4.contains(&(*t as usize))), "draws escaped top-k");
        // with 64 draws at temperature 0.9 over 4 candidates, more than one
        // candidate appears (the distribution is not degenerate)
        assert!(a.iter().any(|&t| t != a[0]), "temperature sampling collapsed to one token");
    }

    #[test]
    fn degenerate_logits_fall_back_to_greedy() {
        let mut rng = Rng::seed_from(3);
        let p = SamplingParams { temperature: 0.7, ..Default::default() };
        let all_neg_inf = [f32::NEG_INFINITY; 4];
        assert_eq!(sample_token(&all_neg_inf, &p, &mut rng), 0);
        let with_nan = [f32::NAN, f32::NAN, 5.0, f32::NAN];
        assert_eq!(sample_token(&with_nan, &p, &mut rng), 2);
    }

    #[test]
    fn submit_fails_fast_when_worker_is_gone() {
        // Construct the dead-worker condition directly (private fields):
        // the queue receiver is dropped, so send must fail with Closed —
        // the old Server dropped this error and left clients blocked
        // forever on a response that could never arrive.
        let (tx, rx) = channel::<Incoming>();
        drop(rx);
        let shapes = ServeShapes {
            n_layer: 1,
            n_kv_head: 1,
            max_seq: 8,
            d_head: 2,
            vocab: 16,
            prompt_len: 4,
        };
        let handle = std::thread::spawn(|| -> Result<Metrics> { Ok(Metrics::new()) });
        let engine = Engine { tx, shapes, handle };
        let err = engine.submit(vec![1, 2], SamplingParams::greedy(1)).unwrap_err();
        assert_eq!(err, EngineError::Closed);
        // a session created against a dead engine reports Closed to
        // pollers instead of an indistinguishable "no event yet"
        let (events_tx, events) = channel();
        drop(events_tx);
        let session =
            Session { events, cancel: Arc::new(AtomicBool::new(false)), cancel_on_drop: true };
        assert_eq!(session.try_recv(), Err(EngineError::Closed));
        assert!(session.wait().is_err());
        engine.shutdown().unwrap();
    }

    #[test]
    fn engine_error_displays_actionable_messages() {
        let e = EngineError::PromptTooLong { len: 20, max: 16 };
        let s = format!("{e}");
        assert!(s.contains("20") && s.contains("16"), "{s}");
        assert!(format!("{}", EngineError::Closed).contains("closed"));
        // converts into the crate error for `?` at CLI level
        let ce: Error = e.into();
        assert!(format!("{ce}").contains("prompt"));
    }
}
