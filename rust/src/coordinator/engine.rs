//! The session-based serving engine (DESIGN.md §8/§9): a typed
//! [`Engine`]/[`Session`] API over a continuously-batched worker.
//!
//! The engine:
//!
//! - discovers a [`ModelBundle`] from the manifest by typed query
//!   (`ArtifactKind` + `meta.model`) and drives decode grouping from the
//!   discovered [`DecodeBuckets`];
//! - hands each request a [`Session`] carrying [`SamplingParams`] (greedy
//!   by default; temperature/top-k with the seeded in-tree RNG) and
//!   **streams** [`TokenEvent`]s — first token, per-token deltas, and a
//!   final finish reason;
//! - schedules work with a **continuous batching scheduler**
//!   (`coordinator::scheduler`, DESIGN.md §9): per-step FCFS admission
//!   into in-flight decode groups, prompt prefill *chunked through the
//!   same `decode_step` seam* (each prompt token is replayed in place on
//!   the session's KV slot, so prefill rows ride the same buckets as
//!   decode rows and a long prompt cannot stall the token cadence of
//!   running sessions), KV-pressure-aware admission against the bounded
//!   **paged** [`KvArena`] — a session reserves only the KV *blocks* its
//!   `prompt + max_tokens` can touch, so short sequences no longer pin
//!   window-sized slabs (DESIGN.md §11) — block refill as sessions
//!   retire, and recompute-style preemption under the anti-starvation
//!   bound.  The scheduler changes
//!   *when* work runs, never *what* it computes: per-session greedy
//!   output is byte-identical to solo decode (asserted in
//!   `tests/native_engine.rs`);
//! - rejects over-long prompts ([`EngineError::PromptTooLong`]),
//!   out-of-vocab tokens ([`EngineError::TokenOutOfVocab`]), and — new
//!   with the scheduler — applies typed backpressure
//!   ([`EngineError::Saturated`]) once `max_queue` submissions are
//!   waiting, instead of growing the channel without bound; a dead worker
//!   still fails fast with [`EngineError::Closed`].
//!
//! Dropping a `Session` (or calling [`Session::cancel`]) cancels the
//! request; the worker retires it with [`FinishReason::Cancelled`] at the
//! next step boundary and frees its cache slot.

use std::collections::BTreeMap;
use std::fmt;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::util::error::{Error, Result};

use crate::runtime::{
    BackendKind, KvArena, KvGeometry, KvSlot, ModelBundle, PrefixIndex, Runtime, RuntimeOptions,
    ServeShapes,
};
use crate::util::rng::Rng;
use crate::util::tensorio::HostTensor;

use super::metrics::Metrics;
use super::scheduler::{SchedMode, Scheduler, SchedulerConfig};

/// Per-session sampling configuration.  The default is greedy argmax
/// (temperature 0) — deterministic, and invariant to scheduling.
#[derive(Debug, Clone, PartialEq)]
pub struct SamplingParams {
    /// Stop after this many generated tokens (>= 1; the prefill token
    /// counts).
    pub max_tokens: usize,
    /// 0.0 = greedy argmax; > 0 samples from softmax(logits / temperature).
    pub temperature: f32,
    /// Restrict sampling to the k highest logits; 0 = no cutoff.
    pub top_k: usize,
    /// Seed for the per-session RNG (only consulted when temperature > 0).
    pub seed: u64,
    /// Generation finishes (reason `Stop`) when one of these is sampled;
    /// the stop token is included in the output.
    pub stop_tokens: Vec<i32>,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            max_tokens: 16,
            temperature: 0.0,
            top_k: 0,
            seed: 0,
            stop_tokens: Vec::new(),
        }
    }
}

impl SamplingParams {
    /// Greedy decoding for `max_tokens` tokens — the old `GenRequest`
    /// semantics.
    pub fn greedy(max_tokens: usize) -> SamplingParams {
        SamplingParams { max_tokens: max_tokens.max(1), ..Default::default() }
    }
}

/// Why a session finished.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Generated `max_tokens` tokens.
    MaxTokens,
    /// Sampled a token from `stop_tokens`.
    Stop,
    /// The KV cache reached the compiled `max_seq` window.
    ContextFull,
    /// The client cancelled (dropped the `Session` or called `cancel`).
    Cancelled,
}

/// One streamed event on a session's channel.  Events arrive strictly in
/// order: `First` (index 0), then `Delta`s with consecutive indices, then
/// exactly one `Done`.
#[derive(Debug, Clone, PartialEq)]
pub enum TokenEvent {
    /// The first generated token (produced by prefill), with
    /// time-to-first-token.
    First { token: i32, ttft_secs: f64 },
    /// A subsequent decode token; `index` counts all generated tokens, so
    /// deltas start at 1.
    Delta { index: usize, token: i32 },
    /// Terminal event: the finish reason plus the complete token list,
    /// latency accounting, and how many prompt tokens were adopted from
    /// the prefix cache (their prefill was skipped; 0 with caching off).
    Done {
        finish: FinishReason,
        tokens: Vec<i32>,
        latency_secs: f64,
        ttft_secs: f64,
        cached_tokens: usize,
    },
}

impl TokenEvent {
    /// The generation index this event carries, if any (`First` is 0).
    pub fn index(&self) -> Option<usize> {
        match self {
            TokenEvent::First { .. } => Some(0),
            TokenEvent::Delta { index, .. } => Some(*index),
            TokenEvent::Done { .. } => None,
        }
    }

    pub fn token(&self) -> Option<i32> {
        match self {
            TokenEvent::First { token, .. } | TokenEvent::Delta { token, .. } => Some(*token),
            TokenEvent::Done { .. } => None,
        }
    }
}

/// The drained result of a finished session.
#[derive(Debug, Clone, PartialEq)]
pub struct Completion {
    pub tokens: Vec<i32>,
    pub finish: FinishReason,
    /// end-to-end latency (submit -> done), seconds
    pub latency: f64,
    /// time to first token (prefill), seconds
    pub ttft: f64,
    /// prompt tokens whose prefill was skipped via prefix-cache adoption
    pub cached_tokens: usize,
}

/// Typed submission errors — the conditions a client can act on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EngineError {
    /// The prompt exceeds the compiled prompt window.  The old server
    /// silently dropped the excess tokens and padded with token 0 (which
    /// attends as real context); the engine refuses instead.
    PromptTooLong { len: usize, max: usize },
    /// A prompt token is outside the model's vocabulary.  Rejected at
    /// submission so one bad request cannot poison the shared worker
    /// (backend modules treat out-of-range tokens as a fatal engine
    /// error).
    TokenOutOfVocab { token: i32, vocab: usize },
    /// The session's `prompt + max_tokens` needs more KV blocks than the
    /// whole arena holds — it could never be admitted, so fail at submit
    /// instead of queueing it forever.
    ExceedsKvCapacity { need_blocks: usize, capacity_blocks: usize },
    /// `max_queue` submissions are already waiting for admission.  Typed
    /// backpressure: the client can retry/shed instead of the old
    /// behavior of growing the worker channel without bound.
    Saturated { max_queue: usize },
    /// The worker thread has shut down (or died); nothing submitted now
    /// can ever complete, so fail fast instead of blocking forever.
    Closed,
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::PromptTooLong { len, max } => write!(
                f,
                "prompt has {len} tokens but the model's compiled prompt window is {max}"
            ),
            EngineError::TokenOutOfVocab { token, vocab } => {
                write!(f, "prompt token {token} is outside the model vocabulary 0..{vocab}")
            }
            EngineError::ExceedsKvCapacity { need_blocks, capacity_blocks } => write!(
                f,
                "request needs {need_blocks} KV blocks but the arena only holds \
                 {capacity_blocks}; shorten the prompt/max_tokens or raise kv_blocks"
            ),
            EngineError::Saturated { max_queue } => write!(
                f,
                "engine is saturated ({max_queue} submissions already waiting for \
                 admission); retry later or raise max_queue/max_in_flight"
            ),
            EngineError::Closed => write!(f, "engine is closed (worker thread has exited)"),
        }
    }
}

impl std::error::Error for EngineError {}

/// A live request handle: streamed events plus cancellation.
pub struct Session {
    events: Receiver<TokenEvent>,
    cancel: Arc<AtomicBool>,
    /// Dropping the handle cancels the request unless detached
    /// ([`Session::detach`] keeps fire-and-forget submissions running).
    cancel_on_drop: bool,
}

impl Session {
    /// Blocking receive of the next event; `None` once the stream ends
    /// (after `Done`, or if the engine died mid-generation).
    pub fn recv(&self) -> Option<TokenEvent> {
        self.events.recv().ok()
    }

    /// Non-blocking receive: `Ok(None)` means no event *yet*;
    /// `Err(Closed)` means the engine died and no event will ever arrive
    /// (so pollers don't spin forever on a dead stream).
    pub fn try_recv(&self) -> Result<Option<TokenEvent>, EngineError> {
        match self.events.try_recv() {
            Ok(ev) => Ok(Some(ev)),
            Err(std::sync::mpsc::TryRecvError::Empty) => Ok(None),
            Err(std::sync::mpsc::TryRecvError::Disconnected) => Err(EngineError::Closed),
        }
    }

    /// Request cancellation; the worker retires the session with
    /// `FinishReason::Cancelled` at the next step boundary.  (Dropping the
    /// session does the same.)
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }

    /// Disarm drop-cancellation: the request keeps generating (and is
    /// counted in metrics) even if this handle is dropped.
    pub fn detach(&mut self) {
        self.cancel_on_drop = false;
    }

    /// Drain events to completion and return the final result.
    pub fn wait(self) -> Result<Completion> {
        self.drain()
    }

    /// Shared drain loop behind [`wait`](Self::wait).
    fn drain(&self) -> Result<Completion> {
        loop {
            match self.events.recv() {
                Ok(TokenEvent::Done { finish, tokens, latency_secs, ttft_secs, cached_tokens }) => {
                    return Ok(Completion {
                        tokens,
                        finish,
                        latency: latency_secs,
                        ttft: ttft_secs,
                        cached_tokens,
                    })
                }
                Ok(_) => continue,
                Err(_) => {
                    return Err(Error::msg(
                        "engine closed before the session finished (worker died)",
                    ))
                }
            }
        }
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        // dropping the handle cancels the request; harmless after Done
        if self.cancel_on_drop {
            self.cancel.store(true, Ordering::Relaxed);
        }
    }
}

struct Incoming {
    prompt: Vec<i32>,
    sampling: SamplingParams,
    events_tx: Sender<TokenEvent>,
    cancel: Arc<AtomicBool>,
    submitted: Instant,
}

/// The serving engine: typed submissions in, streamed sessions out.
///
/// The backend and executables are created INSIDE the worker thread: the
/// `xla` crate's handles are `!Send` (Rc internals), so the worker owns
/// the whole runtime and talks to clients only through channels.
pub struct Engine {
    shared: EngineHandle,
    handle: JoinHandle<Result<Metrics>>,
}

/// A cloneable, thread-safe submission handle onto a running engine
/// worker.  [`Engine`] owns one alongside the worker's `JoinHandle`; the
/// HTTP router (`crate::srv`) clones one per connection-handling thread.
/// All submit-side validation and queue accounting lives here, so every
/// caller — in-process or over the wire — goes through the same gates.
///
/// Outstanding clones keep the worker's queue open: [`Engine::shutdown`]
/// only drains once every `EngineHandle` has been dropped.
#[derive(Clone)]
pub struct EngineHandle {
    tx: Sender<Incoming>,
    shapes: ServeShapes,
    /// KV paging granularity (tokens per block).
    kv_block: usize,
    /// Total blocks the worker's arena holds — the submit-side feasibility
    /// bound behind [`EngineError::ExceedsKvCapacity`].
    kv_blocks: usize,
    /// Submissions not yet admitted to a KV reservation — the bounded
    /// queue depth behind [`EngineError::Saturated`].
    queued: Arc<AtomicUsize>,
    max_queue: usize,
    /// Shared view of the worker's prefix-cache index (None with caching
    /// off) — lets the submit side *probe* expected cache hits without a
    /// round-trip to the worker ([`cached_prefix_tokens`]).
    ///
    /// [`cached_prefix_tokens`]: Self::cached_prefix_tokens
    prefix: Option<Arc<Mutex<PrefixIndex>>>,
}

impl EngineHandle {
    /// The serving model's compiled shapes (prompt window, vocab, ...).
    pub fn shapes(&self) -> ServeShapes {
        self.shapes
    }

    /// Submissions currently waiting for admission.
    pub fn queue_depth(&self) -> usize {
        self.queued.load(Ordering::Relaxed)
    }

    /// Total KV blocks the worker's arena holds (the capacity behind
    /// [`EngineError::ExceedsKvCapacity`]).
    pub fn kv_capacity_blocks(&self) -> usize {
        self.kv_blocks
    }

    /// KV paging granularity (tokens per block).
    pub fn kv_block_tokens(&self) -> usize {
        self.kv_block
    }

    /// The bounded admission-queue depth behind [`EngineError::Saturated`].
    pub fn max_queue(&self) -> usize {
        self.max_queue
    }

    /// How many of `prompt`'s tokens the prefix cache would serve right
    /// now — an **advisory** count (DESIGN.md §15): the worker re-probes
    /// at intake, so the true per-request number is the `cached_tokens`
    /// field of [`TokenEvent::Done`] / [`Completion`].  The HTTP router
    /// uses this to charge the admission token budget only for *uncached*
    /// prefill work.  Always 0 when prefix caching is off.
    pub fn cached_prefix_tokens(&self, prompt: &[i32]) -> usize {
        let Some(ix) = &self.prefix else { return 0 };
        // Same cap as `KvArena::acquire_prefix`: never adopt the block
        // holding the last prompt token, so at least one replay row
        // remains to produce the first sampled token.
        let cap = prompt.len().saturating_sub(1) / self.kv_block;
        let g = match ix.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        g.probe(prompt, cap) * self.kv_block
    }

    /// Open a session: validates the prompt against the compiled window
    /// ([`EngineError::PromptTooLong`]), the model vocabulary
    /// ([`EngineError::TokenOutOfVocab`]), the arena's block capacity
    /// ([`EngineError::ExceedsKvCapacity`]), and the bounded queue
    /// ([`EngineError::Saturated`]), then enqueues it.  Fails fast with a
    /// typed error instead of truncating prompts, queueing unadmittable
    /// sessions, growing the queue without bound, or blocking on a dead
    /// worker ([`EngineError::Closed`]).
    ///
    /// The returned [`Session`] streams [`TokenEvent`]s in order (`First`,
    /// `Delta`..., `Done`); dropping it cancels the request unless
    /// [`Session::detach`] was called.  With prefix caching on, the worker
    /// adopts every full KV block the prompt shares with a cached prefix —
    /// the capacity gate here still charges the *full* reservation, since
    /// cache hits are not guaranteed to survive until admission.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        sampling: SamplingParams,
    ) -> Result<Session, EngineError> {
        if prompt.len() > self.shapes.prompt_len {
            return Err(EngineError::PromptTooLong {
                len: prompt.len(),
                max: self.shapes.prompt_len,
            });
        }
        if let Some(&t) = prompt.iter().find(|&&t| t < 0 || t as usize >= self.shapes.vocab)
        {
            return Err(EngineError::TokenOutOfVocab { token: t, vocab: self.shapes.vocab });
        }
        let need = blocks_needed(
            &self.shapes.geometry(self.kv_block),
            prompt.len(),
            sampling.max_tokens,
        );
        if need > self.kv_blocks {
            return Err(EngineError::ExceedsKvCapacity {
                need_blocks: need,
                capacity_blocks: self.kv_blocks,
            });
        }
        // Claim a queue slot (typed backpressure instead of unbounded
        // channel growth); the worker releases it at admission.
        let mut depth = self.queued.load(Ordering::Relaxed);
        loop {
            if depth >= self.max_queue {
                crate::obs_count!("sched_saturations_total", 1);
                crate::obs_event!("sched_saturate", "need" => need);
                return Err(EngineError::Saturated { max_queue: self.max_queue });
            }
            match self.queued.compare_exchange_weak(
                depth,
                depth + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(now) => depth = now,
            }
        }
        let (events_tx, events) = channel();
        let cancel = Arc::new(AtomicBool::new(false));
        let incoming = Incoming {
            prompt,
            sampling,
            events_tx,
            cancel: cancel.clone(),
            submitted: Instant::now(),
        };
        if self.tx.send(incoming).is_err() {
            self.queued.fetch_sub(1, Ordering::AcqRel);
            return Err(EngineError::Closed);
        }
        Ok(Session { events, cancel, cancel_on_drop: true })
    }
}

impl Engine {
    /// Start the worker on an explicit backend with the default
    /// (continuous) scheduler (`BackendKind::Native` needs no artifacts on
    /// disk).
    pub fn start(artifact_dir: PathBuf, model: &str, backend: BackendKind) -> Result<Engine> {
        Self::start_with(artifact_dir, model, backend, SchedulerConfig::default())
    }

    /// Start the worker with an explicit scheduler policy (`kv_block` /
    /// `kv_blocks` size the paged KV arena; `SchedMode::Gang` is the
    /// wave-scheduling baseline kept for benchmarks).
    pub fn start_with(
        artifact_dir: PathBuf,
        model: &str,
        backend: BackendKind,
        cfg: SchedulerConfig,
    ) -> Result<Engine> {
        Self::start_full(artifact_dir, model, backend, cfg, RuntimeOptions::default())
    }

    /// [`start_with`](Self::start_with) plus [`RuntimeOptions`] — the full
    /// spelling, with the native model's GQA/window configuration.
    pub fn start_full(
        artifact_dir: PathBuf,
        model: &str,
        backend: BackendKind,
        cfg: SchedulerConfig,
        opts: RuntimeOptions,
    ) -> Result<Engine> {
        let cfg = cfg.sanitized();
        let model = model.to_string();
        let (tx, rx) = channel::<Incoming>();
        let (ready_tx, ready_rx) = channel::<Result<ServeShapes>>();
        let queued = Arc::new(AtomicUsize::new(0));
        let worker_queued = queued.clone();
        // The prefix-cache index is shared between the worker (which owns
        // all mutation through the arena) and the handle (read-only
        // probes for admission accounting).
        let prefix = cfg.prefix_cache.then(|| {
            Arc::new(Mutex::new(PrefixIndex::new(cfg.kv_block, cfg.prefix_cache_blocks)))
        });
        let worker_prefix = prefix.clone();
        let handle = std::thread::spawn(move || {
            let setup = || -> Result<(ModelBundle, Vec<HostTensor>)> {
                let rt = Runtime::with_backend_opts(&artifact_dir, backend, opts)?;
                let bundle = ModelBundle::discover(&rt, &model)?;
                // Materialize the weights once via the init artifact (seed
                // 0): the flat param list is shared by prefill and decode.
                let params = bundle.init.run(&[HostTensor::scalar_u32(0)])?;
                Ok((bundle, params))
            };
            match setup() {
                Ok((bundle, params)) => {
                    let _ = ready_tx.send(Ok(bundle.shapes));
                    worker(rx, bundle, params, cfg, worker_queued, worker_prefix)
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    Ok(Metrics::new())
                }
            }
        });
        let shapes = ready_rx
            .recv()
            .map_err(|_| Error::msg("engine worker died during setup"))??;
        let kv_blocks = arena_blocks(&cfg, &shapes);
        Ok(Engine {
            shared: EngineHandle {
                tx,
                shapes,
                kv_block: cfg.kv_block,
                kv_blocks,
                queued,
                max_queue: cfg.max_queue,
                prefix,
            },
            handle,
        })
    }

    /// A cloneable submission handle for other threads (the HTTP router's
    /// workers).  Clones keep the worker's queue open — drop them all
    /// before [`shutdown`](Self::shutdown) is expected to return.
    pub fn handle(&self) -> EngineHandle {
        self.shared.clone()
    }

    /// The serving model's compiled shapes (prompt window, vocab, ...).
    pub fn shapes(&self) -> ServeShapes {
        self.shared.shapes()
    }

    /// Submissions currently waiting for admission.
    pub fn queue_depth(&self) -> usize {
        self.shared.queue_depth()
    }

    /// Total KV blocks the worker's arena holds (the capacity behind
    /// [`EngineError::ExceedsKvCapacity`]).
    pub fn kv_capacity_blocks(&self) -> usize {
        self.shared.kv_capacity_blocks()
    }

    /// KV paging granularity (tokens per block).
    pub fn kv_block_tokens(&self) -> usize {
        self.shared.kv_block_tokens()
    }

    /// See [`EngineHandle::submit`].
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        sampling: SamplingParams,
    ) -> Result<Session, EngineError> {
        self.shared.submit(prompt, sampling)
    }

    /// Close the queue, wait for in-flight sessions to finish, and return
    /// the serving metrics.  Blocks until every [`EngineHandle`] clone has
    /// been dropped too (the worker drains while any sender is live).
    pub fn shutdown(self) -> Result<Metrics> {
        let Engine { shared, handle } = self;
        drop(shared);
        handle.join().map_err(|_| Error::msg("engine worker panicked"))?
    }
}

/// Total KV blocks the worker's arena holds under `cfg`: the explicit
/// `kv_blocks` knob, or enough for `max_in_flight` full windows (the
/// pre-paging worst case, so default capacity is unchanged — the paging
/// win is that short sessions RESERVE less of it).
fn arena_blocks(cfg: &SchedulerConfig, shapes: &ServeShapes) -> usize {
    let per_seq = shapes.geometry(cfg.kv_block).blocks_per_seq();
    cfg.kv_blocks.unwrap_or(cfg.max_in_flight * per_seq).max(1)
}

/// KV blocks a session must reserve: one row for every token it can ever
/// feed (`prompt + max_tokens`, clamped to the window; an empty prompt is
/// normalized to one stand-in token).  The ONE formula both `submit`'s
/// feasibility gate and the worker's reservation use — they must agree,
/// or an accepted session could queue forever.
fn blocks_needed(geo: &KvGeometry, prompt_len: usize, max_tokens: usize) -> usize {
    geo.blocks_for(prompt_len.max(1) + max_tokens.max(1))
}

// ---------------------------------------------------------------------------
// sampling

/// NaN-safe argmax: NaN entries never win; ties go to the first maximum.
/// (The old server's `x > xs[best]` got stuck on index 0 whenever
/// `xs[0]` was NaN.)
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut best_val = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > best_val {
            best = i;
            best_val = x;
        }
    }
    best
}

fn nan_to_neg_inf(x: f32) -> f32 {
    if x.is_nan() {
        f32::NEG_INFINITY
    } else {
        x
    }
}

/// Sample one token id from `logits` under `p` (greedy when temperature
/// is 0).  Deterministic given the RNG state.
fn sample_token(logits: &[f32], p: &SamplingParams, rng: &mut Rng) -> i32 {
    if p.temperature <= 0.0 {
        return argmax(logits) as i32;
    }
    let n = logits.len();
    let k = if p.top_k == 0 || p.top_k > n { n } else { p.top_k };
    let by_logit_desc = |a: &usize, b: &usize| {
        nan_to_neg_inf(logits[*b])
            .partial_cmp(&nan_to_neg_inf(logits[*a]))
            .expect("NaNs mapped to -inf")
            .then(a.cmp(b))
    };
    let cand: Vec<usize> = if k == n {
        (0..n).collect()
    } else {
        // hot path: select the top k in O(n), sort only the k survivors
        let mut idx: Vec<usize> = (0..n).collect();
        idx.select_nth_unstable_by(k - 1, by_logit_desc);
        idx.truncate(k);
        idx.sort_unstable_by(by_logit_desc);
        idx
    };
    let m = cand
        .iter()
        .map(|&i| nan_to_neg_inf(logits[i]))
        .fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return argmax(logits) as i32; // degenerate logits: fall back to greedy
    }
    let weights: Vec<f64> = cand
        .iter()
        .map(|&i| (((nan_to_neg_inf(logits[i]) - m) / p.temperature) as f64).exp())
        .collect();
    let total: f64 = weights.iter().sum();
    let u = rng.next_f64() * total;
    let mut acc = 0.0;
    for (&i, &w) in cand.iter().zip(&weights) {
        acc += w;
        if u < acc {
            return i as i32;
        }
    }
    *cand.last().expect("candidate set is non-empty") as i32
}

struct Sampler {
    params: SamplingParams,
    rng: Rng,
}

impl Sampler {
    fn new(params: SamplingParams) -> Sampler {
        let rng = Rng::seed_from(0x5E55_1014 ^ params.seed);
        Sampler { params, rng }
    }

    fn next(&mut self, logits: &[f32]) -> i32 {
        sample_token(logits, &self.params, &mut self.rng)
    }
}

// ---------------------------------------------------------------------------
// worker

/// One session's server-side state (pending, active, or preempted).
///
/// The prompt is not prefilled by the fixed-shape prefill artifact any
/// more: it is **replayed** token by token through the same `decode_step`
/// seam as generation, writing the KV cache in place at true positions
/// (no window padding — pad tokens used to attend as real context).
/// `replay`/`cursor` drive that: while `cursor < replay.len()` the session
/// contributes its next replay token to a batch row and the resulting
/// logits are discarded, except for the *last* replay row of a
/// never-sampled session, which yields the first generated token.  A
/// preempted session rebuilds `replay` as `prompt ++ generated[..k-1]`
/// (everything it had fed) and recomputes its cache the same way — the
/// per-token math is deterministic and row-independent, so the resumed
/// stream is byte-identical to an uninterrupted run.
struct SeqState {
    events_tx: Sender<TokenEvent>,
    cancel: Arc<AtomicBool>,
    submitted: Instant,
    ttft: f64,
    /// True client prompt length (metrics; `prompt` itself is normalized
    /// so it is never empty).
    prompt_len: usize,
    /// Normalized prompt, kept verbatim for preemption replay.
    prompt: Vec<i32>,
    /// Tokens to feed before sampling (re)starts.
    replay: Vec<i32>,
    /// Next replay index; `cursor == replay.len()` means decoding.
    cursor: usize,
    generated: Vec<i32>,
    sampler: Sampler,
    /// Next KV write position == tokens fed so far.
    pos: i32,
    /// KV blocks this session reserves at (re-)admission — sized once at
    /// intake for `prompt + max_tokens` *minus* adopted cache blocks, so
    /// the reservation never grows mid-flight and preemption replay fits
    /// the same blocks (adopted blocks stay pinned across preemption).
    need_blocks: usize,
    /// Physical KV blocks adopted from the prefix cache at intake — the
    /// session's table starts with these, replay starts after them, and
    /// `free`/preemption return the pins instead of the blocks.
    adopted: Vec<u32>,
    /// `adopted.len() * block_tokens`: prompt tokens whose prefill is
    /// skipped (reported as `cached_tokens` on the Done event).
    cached_tokens: usize,
    /// Present iff the session is admitted (holds an arena reservation).
    slot: Option<KvSlot>,
    /// First admission already happened (queue-depth + metrics are
    /// observed once; preemption re-admissions skip them).
    admitted_once: bool,
}

impl SeqState {
    fn replaying(&self) -> bool {
        self.cursor < self.replay.len()
    }
}

fn finish_reason(s: &SeqState, shapes: &ServeShapes) -> Option<FinishReason> {
    if s.cancel.load(Ordering::Relaxed) {
        return Some(FinishReason::Cancelled);
    }
    if s.generated.is_empty() {
        return None; // still prefilling: nothing to judge yet
    }
    let last = *s.generated.last().expect("checked non-empty");
    if s.sampler.params.stop_tokens.contains(&last) {
        return Some(FinishReason::Stop);
    }
    if s.generated.len() >= s.sampler.params.max_tokens {
        return Some(FinishReason::MaxTokens);
    }
    if !s.replaying() && s.pos as usize >= shapes.max_seq {
        return Some(FinishReason::ContextFull);
    }
    None
}

fn send_done(s: SeqState, finish: FinishReason, metrics: &mut Metrics) {
    let latency = s.submitted.elapsed().as_secs_f64();
    // Cancelled sessions are counted separately — folding an aborted
    // generation into the latency/TTFT percentiles would skew the
    // numbers the serving report exists to measure.
    if finish == FinishReason::Cancelled {
        metrics.observe_cancelled();
    } else {
        metrics.observe_request(latency, s.ttft, s.generated.len());
    }
    let _ = s.events_tx.send(TokenEvent::Done {
        finish,
        tokens: s.generated,
        latency_secs: latency,
        ttft_secs: s.ttft,
        cached_tokens: s.cached_tokens,
    });
}

/// Retire every *admitted* session with a finish reason, freeing its slot
/// for the next refill.
fn retire_finished(
    sessions: &mut BTreeMap<u64, SeqState>,
    sched: &mut Scheduler,
    arena: &mut KvArena,
    metrics: &mut Metrics,
    shapes: &ServeShapes,
) {
    let done: Vec<(u64, FinishReason)> = sessions
        .iter()
        .filter(|(_, s)| s.slot.is_some())
        .filter_map(|(id, s)| finish_reason(s, shapes).map(|r| (*id, r)))
        .collect();
    for (id, finish) in done {
        let mut s = sessions.remove(&id).expect("id came from the map");
        sched.retire(id);
        arena.free(s.slot.take().expect("retiring an admitted session"));
        send_done(s, finish, metrics);
    }
}

fn worker(
    rx: Receiver<Incoming>,
    bundle: ModelBundle,
    params: Vec<HostTensor>,
    cfg: SchedulerConfig,
    queued: Arc<AtomicUsize>,
    prefix: Option<Arc<Mutex<PrefixIndex>>>,
) -> Result<Metrics> {
    let shapes = bundle.shapes;
    // The paged arena: capacity in BLOCKS, so admission decisions below
    // are made against real block availability (`arena.available()`) and
    // a short session reserves only the blocks its `prompt + max_tokens`
    // can touch instead of a full window.
    let geo = shapes.geometry(cfg.kv_block);
    let mut arena = KvArena::with_block_capacity(geo, arena_blocks(&cfg, &shapes));
    if let Some(ix) = prefix {
        arena.attach_prefix_index(ix);
    }
    let mut sched = Scheduler::new(cfg);
    let cfg = sched.config();
    let mut metrics = Metrics::new();
    let mut sessions: BTreeMap<u64, SeqState> = BTreeMap::new();
    let mut next_id = 0u64;
    let mut closed = false;

    while !closed || !sessions.is_empty() {
        // Intake: drain the channel into the scheduler's pending queue
        // (block only when completely idle).
        loop {
            let msg = if sessions.is_empty() && !closed {
                match rx.recv() {
                    Ok(m) => Some(m),
                    Err(_) => {
                        closed = true;
                        None
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => Some(m),
                    Err(TryRecvError::Empty) => None,
                    Err(TryRecvError::Disconnected) => {
                        closed = true;
                        None
                    }
                }
            };
            let Some(inc) = msg else { break };
            let prompt_len = inc.prompt.len();
            let mut prompt = inc.prompt;
            if prompt.is_empty() {
                // token 0 stands in for the empty prompt (the old engine
                // padded the whole window with zeros)
                prompt.push(0);
            }
            // Prefix-cache adoption (DESIGN.md §15): pin every full block
            // this prompt shares with a cached prefix NOW, at intake — the
            // pins survive queueing, admission, and preemption, so the
            // session's need never changes mid-flight.  `need_blocks`
            // counts only the MISSING blocks (a cache hit shrinks it), and
            // replay starts after the adopted positions.
            let (adopted, cached_tokens) = arena.acquire_prefix(&prompt);
            let need_blocks = blocks_needed(&geo, prompt.len(), inc.sampling.max_tokens)
                - adopted.len();
            let state = SeqState {
                events_tx: inc.events_tx,
                cancel: inc.cancel,
                submitted: inc.submitted,
                ttft: 0.0,
                prompt_len,
                replay: prompt.clone(),
                prompt,
                cursor: cached_tokens,
                generated: Vec::new(),
                sampler: Sampler::new(inc.sampling),
                pos: cached_tokens as i32,
                need_blocks,
                adopted,
                cached_tokens,
                slot: None,
                admitted_once: false,
            };
            sessions.insert(next_id, state);
            sched.enqueue(next_id, need_blocks);
            next_id += 1;
        }

        // Cancelled while waiting (pending or preempted): retire without
        // spending a slot or any compute.
        let waiting_cancelled: Vec<u64> = sessions
            .iter()
            .filter(|(_, s)| s.slot.is_none() && s.cancel.load(Ordering::Relaxed))
            .map(|(id, _)| *id)
            .collect();
        for id in waiting_cancelled {
            sched.remove_pending(id);
            let s = sessions.remove(&id).expect("id came from the map");
            if !s.admitted_once {
                queued.fetch_sub(1, Ordering::AcqRel);
            }
            // Waiting sessions hold no slot, but may hold cache pins from
            // intake adoption — return those before retiring.
            arena.release_prefix_blocks(&s.adopted);
            send_done(s, FinishReason::Cancelled, &mut metrics);
        }

        // Retire sessions that finished last step (stop token, max_tokens,
        // context, cancel) — their slots feed this step's refill.
        retire_finished(&mut sessions, &mut sched, &mut arena, &mut metrics, &shapes);
        if sessions.is_empty() {
            continue;
        }
        // One traced step: the plan decision and every sub-step batch
        // below ride under this span (DESIGN.md §13).  Idle loop
        // iterations above never reach it, so an enabled trace holds
        // only steps that did work.
        let _step = crate::obs_span!("engine_step");
        crate::obs_count!("engine_steps_total", 1);

        // Scheduler step: preemptions free blocks first, admissions then
        // reserve against real arena availability.
        let plan = sched.plan(arena.available());
        for &id in &plan.preempted {
            let s = sessions.get_mut(&id).expect("preempted id is live");
            // PIN BEFORE FREE: `free` releases the session's adoption pins
            // (and may run cache eviction); re-pinning first keeps the
            // adopted blocks' refcounts from ever touching zero, so the
            // KV they hold is still valid when the session resumes.
            arena.acquire_prefix_blocks(&s.adopted);
            arena.free(s.slot.take().expect("preempted session held a reservation"));
            // Rebuild the replay from everything it had fed: the prompt
            // plus all generated tokens except the last (which has been
            // sampled but not yet fed).  Adopted cache blocks survive
            // preemption, so replay restarts AFTER the cached positions.
            s.replay = s.prompt.clone();
            if s.generated.len() > 1 {
                s.replay.extend_from_slice(&s.generated[..s.generated.len() - 1]);
            }
            s.cursor = s.cached_tokens;
            s.pos = s.cached_tokens as i32;
            metrics.observe_preemption();
            // Audit-log row: who was evicted, how many blocks it gave
            // back, and which admission (the FCFS head) it made room for.
            crate::obs_event!(
                "sched_preempt",
                "session" => id,
                "need" => s.need_blocks,
                "victim_of" => plan.admitted.first().copied().unwrap_or(u64::MAX),
            );
        }
        for &id in &plan.admitted {
            let s = sessions.get_mut(&id).expect("admitted id is live");
            let slot = arena
                .try_alloc_seq_shared(&s.adopted, s.need_blocks)
                .expect("plan respects arena availability");
            s.slot = Some(slot);
            metrics.observe_admission();
            crate::obs_event!("sched_admit", "session" => id, "need" => s.need_blocks);
            if !s.admitted_once {
                s.admitted_once = true;
                queued.fetch_sub(1, Ordering::AcqRel);
                metrics.observe_queue_wait(s.submitted.elapsed().as_secs_f64());
                metrics.observe_prompt(s.prompt_len, s.prompt_len);
                metrics.observe_prefix(s.cached_tokens);
            }
        }
        // Block conservation, data-plane side (DESIGN.md §12): after the
        // plan is applied, the arena's live reservations and the policy's
        // accounting must agree block for block.
        debug_assert_eq!(
            arena.blocks_in_use(),
            sched.reserved_blocks(),
            "engine and scheduler disagree about reserved KV blocks"
        );

        // Sub-steps: sub-batch 0 carries one token for EVERY admitted
        // session (decode rows feed their last sampled token, prefill rows
        // their next replay token); sub-batches 1..prefill_chunk advance
        // only the still-replaying sessions.  Gang mode replays whole
        // prompts (unbounded chunk) — the wave baseline.
        let chunk = match cfg.mode {
            SchedMode::Gang => usize::MAX,
            SchedMode::Continuous => cfg.prefill_chunk,
        };
        let mut sub = 0usize;
        loop {
            let rows: Vec<u64> = sessions
                .iter()
                .filter(|(_, s)| s.slot.is_some() && (sub == 0 || s.replaying()))
                .map(|(id, _)| *id)
                .collect();
            if rows.is_empty() {
                break;
            }
            for group in rows.chunks(bundle.buckets.max()) {
                let bucket = bundle.buckets.pick(group.len());
                let exe = bundle.decode_for(bucket)?;
                let slots: Vec<KvSlot> = group
                    .iter()
                    .map(|id| sessions[id].slot.expect("row is admitted"))
                    .collect();
                let mut tok = Vec::with_capacity(group.len());
                let mut pos = Vec::with_capacity(group.len());
                let mut prefill_rows = 0usize;
                for id in group {
                    let s = &sessions[id];
                    if s.replaying() {
                        prefill_rows += 1;
                        tok.push(s.replay[s.cursor]);
                    } else {
                        tok.push(*s.generated.last().expect("decoding session has tokens"));
                    }
                    pos.push(s.pos);
                }
                // Defensive copy-on-write (DESIGN.md §15): serving-path
                // adoption is capped below the write cursor, so these
                // never trigger today — but any row about to write a
                // *shared* block must get a private copy first, or the
                // write would corrupt every other reader of that prefix.
                for (slot, &p) in slots.iter().zip(&pos) {
                    arena.ensure_writable(*slot, p as usize);
                }
                // Backend/module failures are deliberately engine-fatal:
                // submit() validated everything client-controllable, so an
                // error here means the backend itself is broken.
                let logits = {
                    let mut view = arena.batch_view(&slots, bucket);
                    exe.decode_step(&params, &mut view, &tok, &pos)?
                };
                metrics.observe_decode_step(group.len());
                metrics.observe_prefill_rows(prefill_rows);
                crate::obs_event!(
                    "engine_rows",
                    "decode" => group.len() - prefill_rows,
                    "prefill" => prefill_rows,
                );
                for (bi, id) in group.iter().enumerate() {
                    let s = sessions.get_mut(id).expect("id came from the map");
                    let row = &logits[bi * shapes.vocab..(bi + 1) * shapes.vocab];
                    s.pos += 1;
                    if s.replaying() {
                        s.cursor += 1;
                        // Mid-replay logits are discarded; so is the last
                        // replay row of a *resumed* session (its next token
                        // was sampled before preemption).  Only a session
                        // that has never sampled takes its first token
                        // here.
                        if s.cursor == s.replay.len() && s.generated.is_empty() {
                            let t = s.sampler.next(row);
                            s.generated.push(t);
                            s.ttft = s.submitted.elapsed().as_secs_f64();
                            let _ = s
                                .events_tx
                                .send(TokenEvent::First { token: t, ttft_secs: s.ttft });
                            sched.note_progress(*id);
                            // Prefill is complete exactly once, here:
                            // publish this prompt's full KV blocks into
                            // the prefix cache for followers to adopt.
                            arena.publish_prefix(
                                s.slot.expect("row is admitted"),
                                &s.prompt,
                            );
                        }
                    } else {
                        let t = s.sampler.next(row);
                        s.generated.push(t);
                        let _ = s
                            .events_tx
                            .send(TokenEvent::Delta { index: s.generated.len() - 1, token: t });
                        sched.note_progress(*id);
                    }
                }
            }
            sub += 1;
            if sub >= chunk {
                break;
            }
        }

        retire_finished(&mut sessions, &mut sched, &mut arena, &mut metrics, &shapes);
    }
    // Leak-at-retire check (DESIGN.md §12): every session path above —
    // finish, cancel, preempt, shutdown drain — must have returned its
    // blocks by the time the worker exits.
    #[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
    arena.check_quiescent();
    metrics.set_kv_copies(arena.stats());
    Ok(metrics)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_first_max_and_survives_nan() {
        assert_eq!(argmax(&[0.1, 3.0, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
        // NaN at the front no longer wedges the result at index 0
        assert_eq!(argmax(&[f32::NAN, 1.0, 2.0]), 2);
        assert_eq!(argmax(&[1.0, f32::NAN, 0.5]), 0);
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
    }

    #[test]
    fn greedy_sampling_is_argmax() {
        let logits = [0.5, 2.0, -1.0, 1.9];
        let mut rng = Rng::seed_from(1);
        let p = SamplingParams::greedy(4);
        assert_eq!(p.max_tokens, 4);
        for _ in 0..5 {
            assert_eq!(sample_token(&logits, &p, &mut rng), 1);
        }
    }

    #[test]
    fn temperature_sampling_is_seeded_and_in_top_k() {
        let logits: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin()).collect();
        let p = SamplingParams {
            max_tokens: 8,
            temperature: 0.9,
            top_k: 4,
            seed: 11,
            stop_tokens: vec![],
        };
        // top-4 indices by logit
        let mut idx: Vec<usize> = (0..32).collect();
        idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
        let top4 = &idx[..4];
        let draw = |seed: u64| -> Vec<i32> {
            let mut rng = Rng::seed_from(seed);
            (0..64).map(|_| sample_token(&logits, &p, &mut rng)).collect()
        };
        let a = draw(7);
        assert_eq!(a, draw(7), "same RNG seed must reproduce the draw sequence");
        assert!(a.iter().all(|t| top4.contains(&(*t as usize))), "draws escaped top-k");
        // with 64 draws at temperature 0.9 over 4 candidates, more than one
        // candidate appears (the distribution is not degenerate)
        assert!(a.iter().any(|&t| t != a[0]), "temperature sampling collapsed to one token");
    }

    #[test]
    fn degenerate_logits_fall_back_to_greedy() {
        let mut rng = Rng::seed_from(3);
        let p = SamplingParams { temperature: 0.7, ..Default::default() };
        let all_neg_inf = [f32::NEG_INFINITY; 4];
        assert_eq!(sample_token(&all_neg_inf, &p, &mut rng), 0);
        let with_nan = [f32::NAN, f32::NAN, 5.0, f32::NAN];
        assert_eq!(sample_token(&with_nan, &p, &mut rng), 2);
    }

    fn test_shapes() -> ServeShapes {
        ServeShapes {
            n_layer: 1,
            n_kv_head: 1,
            max_seq: 8,
            d_head: 2,
            vocab: 16,
            prompt_len: 4,
        }
    }

    fn dead_engine(max_queue: usize, queued: usize) -> (Engine, Receiver<Incoming>) {
        let (tx, rx) = channel::<Incoming>();
        let handle = std::thread::spawn(|| -> Result<Metrics> { Ok(Metrics::new()) });
        let engine = Engine {
            shared: EngineHandle {
                tx,
                shapes: test_shapes(),
                kv_block: 2,
                kv_blocks: 32,
                queued: Arc::new(AtomicUsize::new(queued)),
                max_queue,
                prefix: None,
            },
            handle,
        };
        (engine, rx)
    }

    #[test]
    fn submit_rejects_sessions_that_could_never_fit_the_arena() {
        // max_seq 8, kv_block 2 -> a full window is 4 blocks; an arena of
        // 2 blocks can never admit an 8-token reach
        let (engine, rx) = dead_engine(64, 0);
        drop(rx);
        let mut tight = engine;
        tight.shared.kv_blocks = 2;
        let err = tight
            .submit(vec![1; 4], SamplingParams::greedy(4))
            .unwrap_err();
        assert_eq!(
            err,
            EngineError::ExceedsKvCapacity { need_blocks: 4, capacity_blocks: 2 }
        );
        // a short request passes the capacity check: 1 prompt + 1 token
        // -> 1 block (the dead worker then surfaces as Closed, proving
        // validation got past the capacity gate)
        let err = tight.submit(vec![1], SamplingParams::greedy(1)).unwrap_err();
        assert_eq!(err, EngineError::Closed);
        tight.shutdown().unwrap();
    }

    #[test]
    fn submit_fails_fast_when_worker_is_gone() {
        // Construct the dead-worker condition directly (private fields):
        // the queue receiver is dropped, so send must fail with Closed —
        // the old Server dropped this error and left clients blocked
        // forever on a response that could never arrive.
        let (engine, rx) = dead_engine(64, 0);
        drop(rx);
        let err = engine.submit(vec![1, 2], SamplingParams::greedy(1)).unwrap_err();
        assert_eq!(err, EngineError::Closed);
        // the failed submit released its queue-depth claim
        assert_eq!(engine.queue_depth(), 0);
        // a session created against a dead engine reports Closed to
        // pollers instead of an indistinguishable "no event yet"
        let (events_tx, events) = channel();
        drop(events_tx);
        let session =
            Session { events, cancel: Arc::new(AtomicBool::new(false)), cancel_on_drop: true };
        assert_eq!(session.try_recv(), Err(EngineError::Closed));
        assert!(session.wait().is_err());
        engine.shutdown().unwrap();
    }

    #[test]
    fn submit_saturates_at_the_bounded_queue_depth() {
        // queue already at its bound -> typed backpressure, not unbounded
        // channel growth; the queue depth is not consumed further
        let (engine, _rx) = dead_engine(2, 2);
        let err = engine.submit(vec![1], SamplingParams::greedy(1)).unwrap_err();
        assert_eq!(err, EngineError::Saturated { max_queue: 2 });
        assert_eq!(engine.queue_depth(), 2);
        // prompt validation still runs first (it needs no queue slot)
        let err = engine.submit(vec![1; 99], SamplingParams::greedy(1)).unwrap_err();
        assert!(matches!(err, EngineError::PromptTooLong { .. }));
        engine.shutdown().unwrap();
    }

    #[test]
    fn engine_error_displays_actionable_messages() {
        let e = EngineError::PromptTooLong { len: 20, max: 16 };
        let s = format!("{e}");
        assert!(s.contains("20") && s.contains("16"), "{s}");
        assert!(format!("{}", EngineError::Closed).contains("closed"));
        let s = format!("{}", EngineError::Saturated { max_queue: 64 });
        assert!(s.contains("64") && s.contains("saturated"), "{s}");
        let s = format!(
            "{}",
            EngineError::ExceedsKvCapacity { need_blocks: 9, capacity_blocks: 8 }
        );
        assert!(s.contains('9') && s.contains('8') && s.contains("KV blocks"), "{s}");
        // converts into the crate error for `?` at CLI level
        let ce: Error = e.into();
        assert!(format!("{ce}").contains("prompt"));
    }
}
