//! Continuous-batching scheduler policy (DESIGN.md §9/§11): the pure,
//! property-testable admission/fairness core behind the engine worker.
//!
//! The pre-scheduler worker gang-scheduled: it prefilled whatever was
//! queued and then decoded that wave, so a request arriving mid-wave waited
//! for the slowest session and decode buckets ran under-filled as sessions
//! retired.  The scheduler replaces that with per-step decisions, in the
//! FA2 spirit of work partitioning — keep every slot busy by refilling
//! along whatever axis has slack.  Since the paged KV arena, capacity is
//! counted in **blocks, not slots**: each session declares at enqueue how
//! many KV blocks its `prompt + max_tokens` can touch, so a short chat
//! turn no longer pins a window-sized slab's worth of admission capacity.
//!
//! - **Admission** is FCFS from a bounded pending queue, gated on *real*
//!   capacity: the head is admitted only when the caller can grant its
//!   whole block reservation ([`Scheduler::plan`] is told `free_blocks`,
//!   the arena's live availability) and the in-flight cap has headroom.
//!   The queue never skips ahead — a big head blocks smaller followers,
//!   which is what keeps admission strictly arrival-ordered.
//! - **Anti-starvation preemption**: when the head of the pending queue has
//!   waited `starvation_bound` steps and admission is blocked, the
//!   youngest *progressed* active sessions are preempted — youngest first,
//!   as many as the head's reservation needs, and only if that is enough
//!   (otherwise nothing is evicted and the head keeps waiting) — and the
//!   starving head takes their blocks (recompute-style preemption: the
//!   engine frees the victims' blocks and replays their tokens later).
//!   Victims re-enter at the *front* of the queue in arrival order: FCFS
//!   admission means every active session arrived before every pending
//!   one.  Under sustained oversubscription this degrades gracefully into
//!   round-robin with quantum `starvation_bound`.
//! - **Refill**: retiring sessions free blocks that the next `plan` hands
//!   to the queue, so decode groups stay at the largest fitting bucket
//!   instead of draining with the wave.
//!
//! The scheduler is deliberately *only* policy: it tracks ids, arrival
//! order, block demands, waits and progress flags — never tokens, channels
//! or blocks themselves.  The engine owns the data plane (block tables,
//! chunked prefill cursors, sampling) and consumes [`StepPlan`]s.  That
//! split is what the property tests below exploit: random arrival/length
//! traces drive the policy with a simulated engine and check FCFS order,
//! the starvation bound and block conservation without touching a model.

use std::collections::VecDeque;

use crate::runtime::DEFAULT_KV_BLOCK;

/// How the worker schedules admissions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedMode {
    /// Per-step admission, chunked prefill, preemption — the default.
    Continuous,
    /// Wave scheduling: admit only when the active set is empty, prefill
    /// whole prompts at admission, decode the wave to completion.  Kept as
    /// the measurable baseline for `benches/coordinator_hotpath.rs`.
    Gang,
}

impl SchedMode {
    /// Parse a `--sched` flag / config value.
    pub fn from_flag(s: &str) -> Option<SchedMode> {
        match s {
            "continuous" | "" => Some(SchedMode::Continuous),
            "gang" => Some(SchedMode::Gang),
            _ => None,
        }
    }
}

/// Scheduler policy knobs (serve config: `max_in_flight`, `prefill_chunk`,
/// `kv_block`, `kv_blocks`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SchedulerConfig {
    pub mode: SchedMode,
    /// Cap on concurrently admitted sessions.
    pub max_in_flight: usize,
    /// Prompt tokens a prefilling session may advance per step.  Sub-step 0
    /// of every step carries one token for *every* active session (decode
    /// or prefill), so a long prompt can stall running sessions by at most
    /// `prefill_chunk - 1` extra sub-batches per step.
    pub prefill_chunk: usize,
    /// Bound on submitted-but-not-admitted depth; beyond it `submit` fails
    /// fast with `EngineError::Saturated` instead of growing the channel.
    pub max_queue: usize,
    /// Steps the pending head may starve before it preempts the youngest
    /// progressed active session(s).
    pub starvation_bound: usize,
    /// KV paging granularity in tokens — admission reserves blocks of this
    /// size against the arena.
    pub kv_block: usize,
    /// Total KV blocks the arena is sized to (None = enough for
    /// `max_in_flight` full windows, the pre-paging worst case).
    pub kv_blocks: Option<usize>,
    /// Enable copy-on-write prefix caching over the arena (DESIGN.md
    /// §15): sessions adopt cached prompt blocks, shrinking the `need`
    /// they enqueue with.  Off by default.
    pub prefix_cache: bool,
    /// Max cached blocks retained after their publisher retires
    /// (0 = unbounded); only meaningful with `prefix_cache`.
    pub prefix_cache_blocks: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            mode: SchedMode::Continuous,
            max_in_flight: 8,
            prefill_chunk: 4,
            max_queue: 64,
            starvation_bound: 64,
            kv_block: DEFAULT_KV_BLOCK,
            kv_blocks: None,
            prefix_cache: false,
            prefix_cache_blocks: 0,
        }
    }
}

impl SchedulerConfig {
    /// The gang-scheduling baseline with the same capacity knobs.
    pub fn gang() -> SchedulerConfig {
        SchedulerConfig { mode: SchedMode::Gang, ..Default::default() }
    }

    /// Clamp degenerate values (zero caps would deadlock the worker).
    pub fn sanitized(mut self) -> SchedulerConfig {
        self.max_in_flight = self.max_in_flight.max(1);
        self.prefill_chunk = self.prefill_chunk.max(1);
        self.max_queue = self.max_queue.max(1);
        self.starvation_bound = self.starvation_bound.max(1);
        self.kv_block = self.kv_block.max(1);
        if let Some(b) = self.kv_blocks {
            self.kv_blocks = Some(b.max(1));
        }
        self
    }
}

#[derive(Debug)]
struct Pending {
    id: u64,
    /// KV blocks this session's admission must reserve.
    need: usize,
    /// Steps spent waiting since (re-)enqueue; resets on preemption
    /// re-entry so a session that just ran cannot instantly starve-claim.
    waited: usize,
}

#[derive(Debug)]
struct Active {
    id: u64,
    /// The block reservation granted at admission (freed whole on
    /// retire/preempt — reservations are for the session's full
    /// `prompt + max_tokens` reach, so they never grow mid-flight).
    need: usize,
    /// Whether the session generated at least one token since this
    /// admission ([`Scheduler::note_progress`]).  Only progressed sessions
    /// are preemptible: a recompute victim whose replay outgrew the
    /// starvation quantum would otherwise be evicted before it produced
    /// anything, and the system would livelock replaying forever.
    progressed: bool,
}

/// One step's scheduling decisions.  The engine must process `preempted`
/// (free those blocks) *before* `admitted` (reserve blocks): a starvation
/// admission reuses the blocks its preemptions freed.
#[derive(Debug, Default, PartialEq, Eq)]
pub struct StepPlan {
    pub admitted: Vec<u64>,
    pub preempted: Vec<u64>,
}

/// The policy state: a bounded FCFS pending queue plus the active set in
/// admission order.
#[derive(Debug)]
pub struct Scheduler {
    cfg: SchedulerConfig,
    pending: VecDeque<Pending>,
    active: Vec<Active>,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Scheduler {
        Scheduler { cfg: cfg.sanitized(), pending: VecDeque::new(), active: Vec::new() }
    }

    pub fn config(&self) -> SchedulerConfig {
        self.cfg
    }

    /// Sessions waiting for admission.
    pub fn queue_len(&self) -> usize {
        self.pending.len()
    }

    /// Sessions currently holding a reservation.
    pub fn in_flight(&self) -> usize {
        self.active.len()
    }

    pub fn is_idle(&self) -> bool {
        self.pending.is_empty() && self.active.is_empty()
    }

    /// Enqueue a new arrival at the back (FCFS) with its block demand.
    pub fn enqueue(&mut self, id: u64, need_blocks: usize) {
        self.pending.push_back(Pending { id, need: need_blocks.max(1), waited: 0 });
    }

    /// Drop a not-yet-admitted session (client cancelled while queued).
    /// Returns false if the id is not pending.
    pub fn remove_pending(&mut self, id: u64) -> bool {
        match self.pending.iter().position(|p| p.id == id) {
            Some(i) => {
                self.pending.remove(i);
                true
            }
            None => false,
        }
    }

    /// An active session finished (or was cancelled); its blocks are free
    /// for the next `plan`.
    pub fn retire(&mut self, id: u64) {
        self.active.retain(|a| a.id != id);
    }

    /// The engine observed `id` generating a token this step.  Marks the
    /// session preemptible: eviction always costs a replay, so a session
    /// must get at least one token out of each admission before the
    /// anti-starvation policy may take its blocks back (this is what makes
    /// preemption ping-pong converge instead of livelocking on replays).
    pub fn note_progress(&mut self, id: u64) {
        if let Some(a) = self.active.iter_mut().find(|a| a.id == id) {
            a.progressed = true;
        }
    }

    /// Blocks currently reserved by the active set — the policy-side
    /// mirror of the arena's `blocks_in_use`, and one side of the
    /// conservation invariant checked at every [`plan`](Self::plan).
    pub fn reserved_blocks(&self) -> usize {
        self.active.iter().map(|a| a.need).sum()
    }

    /// One step of policy: FCFS admissions (and, in continuous mode, a
    /// starvation preemption batch) given `free_blocks` actually available
    /// in the KV arena.
    ///
    /// `free_blocks` is the arena's live
    /// [`available()`](crate::runtime::KvArena::available) count, so every
    /// admission the plan grants is backed by blocks the engine can
    /// really allocate.  With prefix caching on, a session's `need` (set
    /// at [`enqueue`](Self::enqueue)) counts only its *missing* blocks —
    /// adopted cache blocks are pinned outside this policy's ledger, so
    /// a cache hit directly widens what fits per step.
    pub fn plan(&mut self, free_blocks: usize) -> StepPlan {
        let _sp = crate::obs_span!("sched_plan");
        // Block conservation (DESIGN.md §12): with a bounded arena, the
        // caller's free count plus this policy's reservations must account
        // for every block at every step — drift above the total means the
        // engine and the policy disagree about who owns KV memory.  The
        // prefix cache may hold capacity *outside* both ledgers (pinned
        // blocks whose publisher retired), so the invariant is <=, with
        // equality whenever the cache holds no pinned owner-dead blocks.
        if let Some(total) = self.cfg.kv_blocks {
            debug_assert!(
                free_blocks + self.reserved_blocks() <= total,
                "kv block conservation violated: {free_blocks} free + {} reserved > {total} total",
                self.reserved_blocks(),
            );
        }
        for p in &mut self.pending {
            p.waited += 1;
        }
        let mut plan = StepPlan::default();
        let mut free = free_blocks;

        let gate_closed = self.cfg.mode == SchedMode::Gang && !self.active.is_empty();
        while !gate_closed && self.active.len() < self.cfg.max_in_flight {
            let head_fits = self.pending.front().map_or(false, |p| p.need <= free);
            if !head_fits {
                break;
            }
            let Some(p) = self.pending.pop_front() else { break };
            free -= p.need;
            self.active.push(Active { id: p.id, need: p.need, progressed: false });
            plan.admitted.push(p.id);
        }

        // Anti-starvation (continuous only): the head has waited out its
        // bound and admission is blocked -> evict the youngest progressed
        // actives, as many as the head's reservation needs — but only if
        // that is actually enough (eviction costs a replay; evicting
        // without unblocking the head would be pure waste).  A burst of
        // starvers drains one head per step instead of churning the whole
        // active set.
        if self.cfg.mode == SchedMode::Continuous {
            let (head_id, head_need, starving) = match self.pending.front() {
                Some(p) => (p.id, p.need, p.waited >= self.cfg.starvation_bound),
                None => (0, 0, false),
            };
            let blocked =
                self.active.len() >= self.cfg.max_in_flight || head_need > free;
            if starving && blocked {
                // youngest-first among sessions that yielded a token since
                // admission (none progressed -> wait, never livelock)
                let mut picked: Vec<usize> = Vec::new();
                let mut freed = free;
                for (i, a) in self.active.iter().enumerate().rev() {
                    let enough = freed >= head_need
                        && self.active.len() - picked.len() < self.cfg.max_in_flight;
                    if enough {
                        break;
                    }
                    if a.progressed {
                        picked.push(i);
                        freed += a.need;
                    }
                }
                let feasible = freed >= head_need
                    && self.active.len() - picked.len() < self.cfg.max_in_flight;
                if feasible && !picked.is_empty() {
                    // remove victims (indices collected descending), oldest
                    // last so push_front leaves arrival order intact
                    let mut victims: Vec<Active> = picked
                        .into_iter()
                        .map(|i| self.active.remove(i))
                        .collect();
                    if let Some(head) = self.pending.pop_front() {
                        debug_assert_eq!(head.id, head_id);
                        self.active.push(Active {
                            id: head.id,
                            need: head.need,
                            progressed: false,
                        });
                        plan.admitted.push(head.id);
                    }
                    // victims re-enter at the front: youngest pushed first
                    // so the oldest arrival ends up closest to the head
                    for v in victims.drain(..) {
                        plan.preempted.push(v.id);
                        self.pending.push_front(Pending { id: v.id, need: v.need, waited: 0 });
                    }
                }
            }
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, PropConfig};
    use crate::util::rng::Rng;

    fn cont(max_in_flight: usize, bound: usize) -> Scheduler {
        Scheduler::new(SchedulerConfig {
            mode: SchedMode::Continuous,
            max_in_flight,
            starvation_bound: bound,
            ..Default::default()
        })
    }

    #[test]
    fn admits_fcfs_up_to_capacity_and_refills_on_retire() {
        let mut s = cont(2, 8);
        for id in 0..4 {
            s.enqueue(id, 1);
        }
        let plan = s.plan(8);
        assert_eq!(plan.admitted, vec![0, 1], "FCFS admission up to max_in_flight");
        assert!(plan.preempted.is_empty());
        assert_eq!(s.in_flight(), 2);
        assert_eq!(s.queue_len(), 2);
        // no in-flight headroom -> no admission
        assert_eq!(s.plan(8), StepPlan::default());
        // retiring one refills from the queue head
        s.retire(0);
        assert_eq!(s.plan(8).admitted, vec![2]);
        // arena pressure gates admission even with in-flight headroom
        s.retire(1);
        assert_eq!(s.plan(0), StepPlan::default(), "no free blocks, no admission");
        assert_eq!(s.plan(1).admitted, vec![3]);
    }

    #[test]
    fn block_demand_gates_admission_without_skipping_fcfs() {
        // head needs 4 blocks, follower needs 1: with only 3 free the head
        // blocks and the follower must NOT overtake (strict FCFS)
        let mut s = cont(4, 1000);
        s.enqueue(0, 4);
        s.enqueue(1, 1);
        assert_eq!(s.plan(3), StepPlan::default(), "big head blocks, no skip-ahead");
        let plan = s.plan(5);
        assert_eq!(plan.admitted, vec![0, 1], "both fit once blocks free up");
        assert_eq!(s.in_flight(), 2);
        // short sessions pack: 3 one-block sessions fit where one window
        // (4 blocks) used to pin everything
        s.retire(0);
        s.retire(1);
        for id in 10..13 {
            s.enqueue(id, 1);
        }
        assert_eq!(s.plan(3).admitted, vec![10, 11, 12]);
    }

    #[test]
    fn starving_head_preempts_youngest_progressed_active() {
        let mut s = cont(2, 3);
        s.enqueue(10, 1);
        s.enqueue(11, 1);
        assert_eq!(s.plan(2).admitted, vec![10, 11]);
        s.note_progress(10);
        s.note_progress(11);
        s.enqueue(12, 1);
        // waited 1, 2 -> nothing; waited 3 == bound -> swap in
        assert_eq!(s.plan(0), StepPlan::default());
        assert_eq!(s.plan(0), StepPlan::default());
        let plan = s.plan(0);
        assert_eq!(plan.admitted, vec![12]);
        assert_eq!(plan.preempted, vec![11], "youngest progressed active is the victim");
        // the victim is back at the front, ahead of later arrivals
        s.enqueue(13, 1);
        s.retire(10);
        assert_eq!(s.plan(1).admitted, vec![11]);
        assert_eq!(s.queue_len(), 1);
    }

    #[test]
    fn starving_big_head_takes_as_many_victims_as_it_needs() {
        // head needs 3 blocks; two active 1-block + one active 2-block
        // sessions: evicting the youngest progressed two (2 + 1 blocks)
        // suffices, the oldest survives
        let mut s = cont(4, 2);
        s.enqueue(0, 1);
        s.enqueue(1, 1);
        s.enqueue(2, 2);
        assert_eq!(s.plan(4).admitted, vec![0, 1, 2]);
        for id in 0..3 {
            s.note_progress(id);
        }
        s.enqueue(3, 3);
        assert_eq!(s.plan(0), StepPlan::default(), "bound not reached");
        let plan = s.plan(0);
        assert_eq!(plan.admitted, vec![3]);
        assert_eq!(plan.preempted, vec![2, 1], "youngest evicted first, just enough");
        assert_eq!(s.in_flight(), 2, "session 0 survives");
        // victims resume in arrival order from the front: 1 then 2
        s.retire(0);
        s.retire(3);
        assert_eq!(s.plan(4).admitted, vec![1, 2]);
    }

    #[test]
    fn infeasible_starvation_evicts_nobody() {
        // the head wants more blocks than every progressed active holds
        // combined — evicting would be pure replay waste, so nothing moves
        let mut s = cont(4, 1);
        s.enqueue(0, 1);
        assert_eq!(s.plan(4).admitted, vec![0]);
        s.note_progress(0);
        s.enqueue(1, 4);
        for _ in 0..5 {
            let plan = s.plan(0);
            assert!(plan.preempted.is_empty(), "eviction cannot satisfy the head");
            assert!(plan.admitted.is_empty());
        }
        assert_eq!(s.in_flight(), 1);
        // once enough blocks free up elsewhere, the head admits normally
        assert_eq!(s.plan(4).admitted, vec![1]);
    }

    #[test]
    fn unprogressed_sessions_are_never_preempted() {
        // a session that has not produced a token since admission is
        // replaying — evicting it would livelock on recompute
        let mut s = cont(1, 2);
        s.enqueue(0, 1);
        assert_eq!(s.plan(1).admitted, vec![0]);
        s.enqueue(1, 1);
        for _ in 0..10 {
            assert_eq!(s.plan(0), StepPlan::default(), "victim has made no progress");
        }
        // first token out -> preemptible at the (long-passed) bound
        s.note_progress(0);
        let plan = s.plan(0);
        assert_eq!(plan.admitted, vec![1]);
        assert_eq!(plan.preempted, vec![0]);
    }

    #[test]
    fn gang_mode_admits_only_into_an_empty_active_set() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_in_flight: 4,
            ..SchedulerConfig::gang()
        });
        s.enqueue(0, 1);
        s.enqueue(1, 1);
        assert_eq!(s.plan(4).admitted, vec![0, 1]);
        // mid-wave arrivals wait, no matter how long (no preemption in gang)
        s.enqueue(2, 1);
        for _ in 0..200 {
            assert_eq!(s.plan(4), StepPlan::default());
        }
        s.retire(0);
        assert_eq!(s.plan(4), StepPlan::default(), "wave not yet drained");
        s.retire(1);
        assert_eq!(s.plan(4).admitted, vec![2], "next wave starts when empty");
    }

    #[test]
    fn reserved_blocks_mirror_admissions_and_conservation_holds() {
        // the caller must report free = total - reserved at every plan();
        // the debug_assert inside plan() is the conservation gate itself
        let mut s = Scheduler::new(SchedulerConfig {
            kv_blocks: Some(4),
            max_in_flight: 4,
            ..Default::default()
        });
        assert_eq!(s.reserved_blocks(), 0);
        s.enqueue(0, 2);
        s.enqueue(1, 1);
        assert_eq!(s.plan(4).admitted, vec![0, 1]);
        assert_eq!(s.reserved_blocks(), 3);
        assert_eq!(s.plan(1), StepPlan::default());
        s.retire(0);
        assert_eq!(s.reserved_blocks(), 1);
        s.enqueue(2, 3);
        assert_eq!(s.plan(3).admitted, vec![2]);
        assert_eq!(s.reserved_blocks(), 4, "fully subscribed");
        assert_eq!(s.plan(0), StepPlan::default());
    }

    #[test]
    fn sanitized_config_never_zero() {
        let c = SchedulerConfig {
            mode: SchedMode::Continuous,
            max_in_flight: 0,
            prefill_chunk: 0,
            max_queue: 0,
            starvation_bound: 0,
            kv_block: 0,
            kv_blocks: Some(0),
            prefix_cache: false,
            prefix_cache_blocks: 0,
        }
        .sanitized();
        assert_eq!(
            (c.max_in_flight, c.prefill_chunk, c.max_queue, c.starvation_bound),
            (1, 1, 1, 1)
        );
        assert_eq!(c.kv_block, 1);
        assert_eq!(c.kv_blocks, Some(1));
        assert_eq!(SchedMode::from_flag("gang"), Some(SchedMode::Gang));
        assert_eq!(SchedMode::from_flag("continuous"), Some(SchedMode::Continuous));
        assert_eq!(SchedMode::from_flag("wave"), None);
    }

    /// The tentpole property (ISSUE 4, extended for block demands in
    /// ISSUE 5): under random arrival/length/demand traces, whenever the
    /// queue is non-empty the scheduler makes progress (an admission or a
    /// preemption batch) within `starvation_bound + 1` steps; admissions
    /// are strictly FCFS by original arrival (preemption victims resume
    /// ahead of later arrivals); block capacity is never exceeded; and
    /// every session eventually retires.
    #[test]
    fn prop_fcfs_starvation_bound_and_conservation() {
        check("scheduler-continuous", PropConfig::default(), |rng: &mut Rng| {
            let cap = rng.range_usize(2, 7); // simulated arena blocks
            let cfg = SchedulerConfig {
                mode: SchedMode::Continuous,
                max_in_flight: rng.range_usize(1, 5),
                prefill_chunk: rng.range_usize(1, 5),
                max_queue: 64,
                starvation_bound: rng.range_usize(1, 10),
                kv_block: 16,
                kv_blocks: Some(cap),
                prefix_cache: false,
                prefix_cache_blocks: 0,
            };
            let bound = cfg.starvation_bound;
            let mut sched = Scheduler::new(cfg);

            let n = rng.range_usize(1, 24);
            // (arrival step, remaining work, block demand) per id
            let mut arrive_at: Vec<usize> =
                (0..n).map(|_| rng.range_usize(0, 30)).collect();
            arrive_at.sort_unstable();
            let mut remaining: Vec<usize> =
                (0..n).map(|_| rng.range_usize(1, 12)).collect();
            let need: Vec<usize> = (0..n).map(|_| rng.range_usize(1, cap + 1)).collect();

            let mut next_arrival = 0usize;
            let mut waiting: Vec<u64> = Vec::new(); // ids awaiting admission
            let mut running: Vec<u64> = Vec::new();
            let mut blocks_held = 0usize;
            let mut first_admission: Vec<Option<usize>> = vec![None; n];
            let mut admission_order: Vec<u64> = Vec::new();
            let mut retired = 0usize;
            let mut steps_since_progress = 0usize;

            let mut step = 0usize;
            while retired < n {
                crate::prop_assert!(
                    step < 50_000,
                    "liveness: {retired}/{n} retired after {step} steps"
                );
                while next_arrival < n && arrive_at[next_arrival] <= step {
                    sched.enqueue(next_arrival as u64, need[next_arrival]);
                    waiting.push(next_arrival as u64);
                    next_arrival += 1;
                }
                let free = cap - blocks_held;
                let had_waiters = !waiting.is_empty();
                let plan = sched.plan(free);

                for &id in &plan.preempted {
                    crate::prop_assert!(
                        running.contains(&id),
                        "preempted {id} was not running"
                    );
                    running.retain(|&r| r != id);
                    waiting.push(id);
                    blocks_held -= need[id as usize];
                }
                for &id in &plan.admitted {
                    // FCFS: the admitted id is the earliest original
                    // arrival among everyone still waiting — excluding this
                    // plan's own victims, which by construction arrived
                    // earlier than the starving head they just yielded to
                    // and resume at the queue front on the NEXT admission
                    let min_waiting = waiting
                        .iter()
                        .copied()
                        .filter(|w| !plan.preempted.contains(w))
                        .min()
                        .expect("admitted someone not waiting");
                    crate::prop_assert!(
                        id == min_waiting,
                        "admission {id} overtook waiting {min_waiting}"
                    );
                    crate::prop_assert!(
                        blocks_held + need[id as usize] <= cap,
                        "blocks over-allocated"
                    );
                    waiting.retain(|&w| w != id);
                    running.push(id);
                    blocks_held += need[id as usize];
                    if first_admission[id as usize].is_none() {
                        first_admission[id as usize] = Some(step);
                        admission_order.push(id);
                    }
                }
                crate::prop_assert!(
                    running.len() <= cfg.max_in_flight && blocks_held <= cap,
                    "capacity exceeded: {} in flight, {} blocks",
                    running.len(),
                    blocks_held
                );

                // anti-starvation: with waiters present, the scheduler may
                // stall (neither admit nor preempt) for at most the bound
                if had_waiters && plan.admitted.is_empty() && plan.preempted.is_empty() {
                    steps_since_progress += 1;
                    crate::prop_assert!(
                        steps_since_progress <= bound,
                        "queue stalled {steps_since_progress} steps (bound {bound})"
                    );
                } else {
                    steps_since_progress = 0;
                }

                // the simulated engine: every running session advances one
                // unit (and reports the progress, making it preemptible);
                // finished sessions retire and free their blocks
                let done: Vec<u64> = running
                    .iter()
                    .copied()
                    .filter(|&id| {
                        sched.note_progress(id);
                        remaining[id as usize] -= 1;
                        remaining[id as usize] == 0
                    })
                    .collect();
                for id in done {
                    running.retain(|&r| r != id);
                    sched.retire(id);
                    blocks_held -= need[id as usize];
                    retired += 1;
                }
                step += 1;
            }
            crate::prop_assert!(
                admission_order == (0..n as u64).collect::<Vec<_>>(),
                "first admissions out of arrival order: {admission_order:?}"
            );
            crate::prop_assert!(
                sched.is_idle(),
                "scheduler retained state after all sessions retired"
            );
            Ok(())
        });
    }
}
