//! Zero-copy KV-cache arena for the serving engine (DESIGN.md §8).
//!
//! The pre-engine coordinator kept one `Vec<f32>` K/V slab per sequence and
//! re-assembled the entire (L, B, H, S, dh) batch cache tensor on every
//! decode step, then scattered the updated rows back — an O(cache) memcpy
//! per generated token that dwarfs the attention math the paper optimizes.
//!
//! [`KvArena`] replaces that: a worker-owned pool of per-sequence slabs
//! ([`KvSlot`] handles) in the *single-sequence* cache layout (L, 1, H, S,
//! dh).  A decode step borrows a [`KvBatchView`] over the active slots and
//! hands it through the widened [`Module::decode_step`] seam
//! (`runtime::backend`):
//!
//! - the native backend mutates the slots **in place** — zero per-token
//!   assemble/scatter bytes (asserted by `benches/coordinator_hotpath.rs`
//!   and the tests below);
//! - compiled-artifact backends (PJRT/stub) fall back to the view's
//!   [`gather`](KvBatchView::gather)/[`scatter`](KvBatchView::scatter)
//!   compatibility pair, which reproduces the old batch-tensor exchange
//!   byte-for-byte and *accounts* every byte it moves in [`CopyStats`].

use crate::bail;
use crate::util::error::Result;
use crate::util::tensorio::HostTensor;

/// Per-sequence cache geometry: a slot holds (n_layer, 1, n_kv_head,
/// max_seq, d_head) f32 elements, layer-major.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvGeometry {
    pub n_layer: usize,
    pub n_kv_head: usize,
    pub max_seq: usize,
    pub d_head: usize,
}

impl KvGeometry {
    /// Elements in one layer of one sequence's cache: H * S * dh.
    pub fn per_layer(&self) -> usize {
        self.n_kv_head * self.max_seq * self.d_head
    }

    /// Elements in one sequence's full cache slab.
    pub fn slot_elems(&self) -> usize {
        self.n_layer * self.per_layer()
    }

    /// Dims of the batched cache tensor the compat path assembles.
    pub fn batch_dims(&self, batch: usize) -> Vec<usize> {
        vec![self.n_layer, batch, self.n_kv_head, self.max_seq, self.d_head]
    }
}

/// Bytes moved by the compatibility gather/scatter path.  The native
/// in-place path never touches these counters — "zero per-token KV copies"
/// is `gather_bytes == 0 && scatter_bytes == 0` after a serve run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CopyStats {
    pub gathers: u64,
    pub scatters: u64,
    pub gather_bytes: u64,
    pub scatter_bytes: u64,
}

impl CopyStats {
    pub fn total_bytes(&self) -> u64 {
        self.gather_bytes + self.scatter_bytes
    }
}

/// Handle to one sequence's slab in the arena.  Only meaningful for the
/// arena that issued it; freeing returns the slab to the pool for reuse.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvSlot(usize);

impl KvSlot {
    pub fn index(&self) -> usize {
        self.0
    }
}

/// The worker-owned slab pool: one pair of K/V slabs per live sequence,
/// optionally bounded so admission control can reserve against *real*
/// availability (DESIGN.md §9: the engine sizes the arena to
/// `max_in_flight` and admits only while [`try_alloc`](Self::try_alloc)
/// can succeed).
#[derive(Debug)]
pub struct KvArena {
    geo: KvGeometry,
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    free: Vec<usize>,
    /// Slot cap (`None` = unbounded legacy pool).
    cap: Option<usize>,
    stats: CopyStats,
}

impl KvArena {
    /// An unbounded pool (benches and the compat paths).
    pub fn new(geo: KvGeometry) -> KvArena {
        KvArena {
            geo,
            k: Vec::new(),
            v: Vec::new(),
            free: Vec::new(),
            cap: None,
            stats: CopyStats::default(),
        }
    }

    /// A pool bounded to `cap` live slots — the reservation substrate for
    /// KV-pressure-aware admission.
    pub fn with_capacity(geo: KvGeometry, cap: usize) -> KvArena {
        KvArena { cap: Some(cap.max(1)), ..KvArena::new(geo) }
    }

    pub fn geometry(&self) -> KvGeometry {
        self.geo
    }

    /// Slots currently live (allocated and not freed).
    pub fn live(&self) -> usize {
        self.k.len() - self.free.len()
    }

    /// Total slabs ever allocated (high-water mark of the pool).
    pub fn capacity(&self) -> usize {
        self.k.len()
    }

    /// The configured slot cap (`None` = unbounded).
    pub fn capacity_slots(&self) -> Option<usize> {
        self.cap
    }

    /// Slots an admission decision may still claim right now.  Unbounded
    /// arenas report `usize::MAX` (the scheduler clamps with its own
    /// in-flight cap).
    pub fn available(&self) -> usize {
        match self.cap {
            Some(cap) => cap.saturating_sub(self.live()),
            None => usize::MAX,
        }
    }

    pub fn stats(&self) -> CopyStats {
        self.stats
    }

    /// Allocate a zeroed slot (reuses a freed slab when available).
    /// Panics past the cap — bounded callers must reserve via
    /// [`try_alloc`](Self::try_alloc).
    pub fn alloc(&mut self) -> KvSlot {
        self.try_alloc().expect("kv arena exhausted (admission must check available())")
    }

    /// Reserve a zeroed slot, or `None` when the pool is at capacity —
    /// the admission-control primitive.
    pub fn try_alloc(&mut self) -> Option<KvSlot> {
        if self.available() == 0 {
            return None;
        }
        let n = self.geo.slot_elems();
        match self.free.pop() {
            Some(i) => {
                self.k[i].iter_mut().for_each(|x| *x = 0.0);
                self.v[i].iter_mut().for_each(|x| *x = 0.0);
                Some(KvSlot(i))
            }
            None => {
                self.k.push(vec![0.0; n]);
                self.v.push(vec![0.0; n]);
                Some(KvSlot(self.k.len() - 1))
            }
        }
    }

    /// Adopt a prefill-produced cache pair by *moving* the vectors in — the
    /// one-time admission cost; no per-token copies follow on the native
    /// path.
    pub fn adopt(&mut self, k: Vec<f32>, v: Vec<f32>) -> Result<KvSlot> {
        let n = self.geo.slot_elems();
        if k.len() != n || v.len() != n {
            bail!(
                "kv arena: adopted slab has {}/{} elements, geometry wants {n}",
                k.len(),
                v.len()
            );
        }
        if self.available() == 0 {
            bail!(
                "kv arena: at capacity ({} live slots); admission must reserve first",
                self.live()
            );
        }
        match self.free.pop() {
            Some(i) => {
                self.k[i] = k;
                self.v[i] = v;
                Ok(KvSlot(i))
            }
            None => {
                self.k.push(k);
                self.v.push(v);
                Ok(KvSlot(self.k.len() - 1))
            }
        }
    }

    /// Return a slot's slab to the pool.
    pub fn free(&mut self, slot: KvSlot) {
        debug_assert!(!self.free.contains(&slot.0), "double free of kv slot");
        self.free.push(slot.0);
    }

    /// This slot's (K, V) slabs, read-only.
    pub fn slot(&self, slot: KvSlot) -> (&[f32], &[f32]) {
        (&self.k[slot.0], &self.v[slot.0])
    }

    /// This slot's (K, V) slabs, mutable.
    pub fn slot_mut(&mut self, slot: KvSlot) -> (&mut [f32], &mut [f32]) {
        (&mut self.k[slot.0], &mut self.v[slot.0])
    }

    /// Borrow a decode-step view over `slots`, padded (virtually) to
    /// `batch` rows.  `batch` is the compiled bucket size; `slots.len()`
    /// may be smaller.
    pub fn batch_view<'a>(&'a mut self, slots: &[KvSlot], batch: usize) -> KvBatchView<'a> {
        assert!(!slots.is_empty() && slots.len() <= batch, "bad batch view shape");
        KvBatchView { arena: self, slots: slots.to_vec(), batch }
    }
}

/// A borrowed view of the active slots for one decode step, in batch-row
/// order.  Rows `slots.len()..batch` are padding (replicas of row 0 on the
/// compat path; simply absent on the native in-place path).
pub struct KvBatchView<'a> {
    arena: &'a mut KvArena,
    slots: Vec<KvSlot>,
    batch: usize,
}

impl KvBatchView<'_> {
    /// Real (non-padding) rows in this view.
    pub fn rows(&self) -> usize {
        self.slots.len()
    }

    /// Compiled bucket size the compat path pads to.
    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn geometry(&self) -> KvGeometry {
        self.arena.geo
    }

    /// Row `row`'s (K, V) slabs for in-place decode (native path).
    pub fn slot_mut(&mut self, row: usize) -> (&mut [f32], &mut [f32]) {
        self.arena.slot_mut(self.slots[row])
    }

    /// Compatibility path: assemble the (L, B, H, S, dh) batch cache pair
    /// the compiled decode artifacts expect.  Padding rows replicate row 0
    /// (their results are discarded).  Every byte is accounted in
    /// [`CopyStats`].
    pub fn gather(&mut self) -> (HostTensor, HostTensor) {
        let geo = self.arena.geo;
        let per_layer = geo.per_layer();
        let b = self.batch;
        let dims = geo.batch_dims(b);
        let mut kd = vec![0.0f32; geo.n_layer * b * per_layer];
        let mut vd = vec![0.0f32; geo.n_layer * b * per_layer];
        for l in 0..geo.n_layer {
            for bi in 0..b {
                // padding rows replicate sequence 0 (results discarded)
                let slot = if bi < self.slots.len() { self.slots[bi] } else { self.slots[0] };
                let (ks, vs) = self.arena.slot(slot);
                let src = l * per_layer..(l + 1) * per_layer;
                let dst = (l * b + bi) * per_layer;
                kd[dst..dst + per_layer].copy_from_slice(&ks[src.clone()]);
                vd[dst..dst + per_layer].copy_from_slice(&vs[src]);
            }
        }
        self.arena.stats.gathers += 1;
        self.arena.stats.gather_bytes += 2 * (kd.len() as u64) * 4;
        (HostTensor::from_f32(&dims, &kd), HostTensor::from_f32(&dims, &vd))
    }

    /// Compatibility path: scatter the updated batch cache pair back into
    /// the per-sequence slots (real rows only).
    pub fn scatter(&mut self, k_new: &HostTensor, v_new: &HostTensor) -> Result<()> {
        let geo = self.arena.geo;
        let per_layer = geo.per_layer();
        let b = self.batch;
        let want = geo.batch_dims(b);
        if k_new.dims != want || v_new.dims != want {
            bail!(
                "kv scatter: decode returned cache dims {:?}/{:?}, expected {want:?}",
                k_new.dims,
                v_new.dims
            );
        }
        let kd = k_new.to_f32_vec();
        let vd = v_new.to_f32_vec();
        let rows = self.slots.len();
        for bi in 0..rows {
            let (ks, vs) = self.arena.slot_mut(self.slots[bi]);
            for l in 0..geo.n_layer {
                let src = (l * b + bi) * per_layer;
                let dst = l * per_layer;
                ks[dst..dst + per_layer].copy_from_slice(&kd[src..src + per_layer]);
                vs[dst..dst + per_layer].copy_from_slice(&vd[src..src + per_layer]);
            }
        }
        self.arena.stats.scatters += 1;
        self.arena.stats.scatter_bytes += 2 * (geo.n_layer * rows * per_layer * 4) as u64;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geo() -> KvGeometry {
        KvGeometry { n_layer: 2, n_kv_head: 1, max_seq: 2, d_head: 2 }
    }

    fn ramp(base: f32, n: usize) -> Vec<f32> {
        (0..n).map(|i| base + i as f32).collect()
    }

    #[test]
    fn alloc_adopt_free_reuses_slabs() {
        let g = geo();
        let mut a = KvArena::new(g);
        let n = g.slot_elems();
        assert_eq!(n, 2 * 4);
        let s0 = a.adopt(ramp(0.0, n), vec![0.0; n]).unwrap();
        let s1 = a.alloc();
        assert_eq!(a.live(), 2);
        assert_eq!(a.capacity(), 2);
        a.free(s0);
        assert_eq!(a.live(), 1);
        // reuse: the freed slab index comes back, zeroed on alloc
        let s2 = a.alloc();
        assert_eq!(s2.index(), s0.index());
        assert!(a.slot(s2).0.iter().all(|&x| x == 0.0));
        assert_eq!(a.capacity(), 2);
        a.free(s1);
        a.free(s2);
        assert_eq!(a.live(), 0);
        // wrong-size adoption is a typed error, not a corrupted slab
        assert!(a.adopt(vec![0.0; n + 1], vec![0.0; n]).is_err());
    }

    #[test]
    fn bounded_arena_reserves_against_real_availability() {
        let g = geo();
        let n = g.slot_elems();
        let mut a = KvArena::with_capacity(g, 2);
        assert_eq!(a.capacity_slots(), Some(2));
        assert_eq!(a.available(), 2);
        let s0 = a.try_alloc().expect("slot 0");
        let s1 = a.try_alloc().expect("slot 1");
        assert_eq!(a.available(), 0);
        // at capacity: reservation fails, adoption is a typed error
        assert!(a.try_alloc().is_none());
        assert!(a.adopt(vec![0.0; n], vec![0.0; n]).is_err());
        // freeing restores availability; the recycled slab comes back zeroed
        {
            let (k, _) = a.slot_mut(s0);
            k[0] = 7.0;
        }
        a.free(s0);
        assert_eq!(a.available(), 1);
        let s2 = a.try_alloc().expect("recycled slot");
        assert_eq!(s2.index(), s0.index());
        assert!(a.slot(s2).0.iter().all(|&x| x == 0.0), "recycled slab not zeroed");
        a.free(s1);
        a.free(s2);
        assert_eq!(a.available(), 2);
        // the unbounded pool reports effectively infinite availability
        assert_eq!(KvArena::new(g).available(), usize::MAX);
    }

    #[test]
    fn gather_matches_legacy_assemble_layout() {
        // Port of the old coordinator `cache_assembly_roundtrip_layout`
        // test: same (L, B, H, S, dh) interleaving, same pad-row
        // replication of sequence 0.
        let g = geo();
        let n = g.slot_elems();
        let mut a = KvArena::new(g);
        let s0 = a.adopt(ramp(0.0, n), vec![0.0; n]).unwrap();
        let s1 = a.adopt(ramp(100.0, n), vec![0.0; n]).unwrap();
        let mut view = a.batch_view(&[s0, s1], 4);
        let (k, _v) = view.gather();
        assert_eq!(k.dims, vec![2, 4, 1, 2, 2]);
        let data = k.to_f32_vec();
        // layer 0: [seq0 layer0][seq1 layer0][pad=seq0][pad=seq0]
        assert_eq!(&data[0..4], &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(&data[4..8], &[100.0, 101.0, 102.0, 103.0]);
        assert_eq!(&data[8..12], &[0.0, 1.0, 2.0, 3.0]);
        // layer 1 of seq1 starts at (1*4 + 1)*4
        assert_eq!(&data[20..24], &[104.0, 105.0, 106.0, 107.0]);
        assert_eq!(a.stats().gathers, 1);
        assert_eq!(a.stats().gather_bytes, 2u64 * (2 * 4 * 4) * 4);
    }

    #[test]
    fn scatter_roundtrips_and_counts_real_rows_only() {
        let g = geo();
        let n = g.slot_elems();
        let mut a = KvArena::new(g);
        let s0 = a.adopt(ramp(0.0, n), ramp(50.0, n)).unwrap();
        let s1 = a.adopt(ramp(100.0, n), ramp(150.0, n)).unwrap();
        let mut view = a.batch_view(&[s0, s1], 4);
        let (k, v) = view.gather();
        // mutate one row of the batched tensor, write it back
        let mut kd = k.to_f32_vec();
        let per_layer = g.per_layer();
        // (l=1, b=1) block
        let off = (1 * 4 + 1) * per_layer;
        for x in &mut kd[off..off + per_layer] {
            *x += 1000.0;
        }
        let k2 = HostTensor::from_f32(&k.dims, &kd);
        view.scatter(&k2, &v).unwrap();
        let (ks1, vs1) = a.slot(s1);
        assert_eq!(&ks1[per_layer..2 * per_layer], &[1104.0, 1105.0, 1106.0, 1107.0]);
        assert_eq!(vs1, &ramp(150.0, n)[..]);
        // stats: one gather of the padded batch, one scatter of 2 real rows
        let st = a.stats();
        assert_eq!(st.scatters, 1);
        assert_eq!(st.scatter_bytes, 2 * (2 * 2 * per_layer as u64) * 4);
        assert_eq!(st.total_bytes(), st.gather_bytes + st.scatter_bytes);
        // dims mismatch is rejected
        let mut view = a.batch_view(&[s0], 1);
        assert!(view.scatter(&k2, &v).is_err());
    }

    #[test]
    fn in_place_slot_access_moves_zero_bytes() {
        let g = geo();
        let n = g.slot_elems();
        let mut a = KvArena::new(g);
        let s0 = a.adopt(ramp(0.0, n), ramp(1.0, n)).unwrap();
        {
            let mut view = a.batch_view(&[s0], 4);
            assert_eq!(view.rows(), 1);
            assert_eq!(view.batch(), 4);
            let (k, v) = view.slot_mut(0);
            k[0] = 42.0;
            v[0] = 43.0;
        }
        assert_eq!(a.slot(s0).0[0], 42.0);
        assert_eq!(a.slot(s0).1[0], 43.0);
        // the whole point: native in-place decode never bumps the counters
        assert_eq!(a.stats(), CopyStats::default());
        assert_eq!(a.stats().total_bytes(), 0);
    }
}
