//! Paged KV-cache arena for the serving engine (DESIGN.md §8/§11).
//!
//! The PR-3 arena kept one window-sized `(L, 1, H, S, dh)` slab per
//! sequence: a short chat turn pinned exactly as much cache memory as a
//! window-filling one, and admission control could only count *slabs*.
//! [`KvArena`] now stores K/V in fixed-size **token blocks**
//! (`KvGeometry::block_tokens` rows per block, all layers/heads
//! interleaved per block) behind per-sequence **block tables**:
//!
//! - allocation, free and admission reservation are all in blocks —
//!   [`try_alloc_seq`](KvArena::try_alloc_seq) reserves exactly the
//!   blocks a session's `prompt + max_tokens` can touch, so short
//!   sequences no longer pin window-sized slabs;
//! - the native decode path mutates blocks **in place** through
//!   [`PagedKvMut`], whose [`layout`](PagedKvMut::layout) hands the
//!   attention kernel a [`KvLayout::Paged`] block-table view — zero
//!   per-token assemble/scatter bytes, asserted by
//!   `benches/coordinator_hotpath.rs` and the tests below;
//! - compiled-artifact backends (PJRT/stub) fall back to the view's
//!   [`gather`](KvBatchView::gather)/[`scatter`](KvBatchView::scatter)
//!   compatibility pair, which materializes the legacy `(L, B, H, S, dh)`
//!   batch tensor from the blocks and *accounts* every byte it moves in
//!   [`CopyStats`].
//!
//! Within a physical block, rows are laid out `(layer, head, token,
//! d_head)` — one `(layer, head)` plane's rows are contiguous, which is
//! exactly the chunk shape the split-KV decode kernel streams.
//!
//! With a [`PrefixIndex`] attached (DESIGN.md §15), the arena also serves
//! as the **prefix cache**: fully-prefilled prompt blocks are published
//! into a refcounted hash→block index, later sessions adopt the shared
//! physical blocks instead of recomputing prefill
//! ([`acquire_prefix`](KvArena::acquire_prefix) /
//! [`try_alloc_seq_shared`](KvArena::try_alloc_seq_shared)), divergent
//! writes copy-on-write through
//! [`ensure_writable`](KvArena::ensure_writable), and zero-ref cached
//! blocks are reclaimed LRU-first when allocation runs dry.

use std::sync::{Arc, Mutex};

use crate::attn::spec::{BlockTable, KvLayout};
use crate::bail;
use crate::runtime::prefix::PrefixIndex;
use crate::util::error::Result;
use crate::util::tensorio::HostTensor;

/// Poison-safe lock on the shared prefix index: block accounting must
/// keep working even if an unrelated holder panicked mid-lock.
fn lock_prefix(ix: &Arc<Mutex<PrefixIndex>>) -> std::sync::MutexGuard<'_, PrefixIndex> {
    match ix.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Cache geometry: shapes from the model, block size from serving config.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvGeometry {
    pub n_layer: usize,
    pub n_kv_head: usize,
    pub max_seq: usize,
    pub d_head: usize,
    /// Token rows per KV block (the paging granularity).
    pub block_tokens: usize,
}

/// Default KV block size (tokens) — must match `runtime::native`'s legacy
/// decode chunk so paged and batch-tensor decode stay bit-identical.
pub const DEFAULT_KV_BLOCK: usize = 16;

impl KvGeometry {
    /// Elements in one layer of one sequence's *assembled* cache:
    /// H · S · dh (the compat gather/scatter shape).
    pub fn per_layer(&self) -> usize {
        self.n_kv_head * self.max_seq * self.d_head
    }

    /// Elements in one sequence's fully-assembled cache slab.
    pub fn slot_elems(&self) -> usize {
        self.n_layer * self.per_layer()
    }

    /// Dims of the batched cache tensor the compat path assembles.
    pub fn batch_dims(&self, batch: usize) -> Vec<usize> {
        vec![self.n_layer, batch, self.n_kv_head, self.max_seq, self.d_head]
    }

    /// Elements in one physical block (all layers and heads).
    pub fn block_elems(&self) -> usize {
        self.n_layer * self.n_kv_head * self.block_tokens * self.d_head
    }

    /// Element offset of the (layer, head) plane inside a block.
    pub fn plane_offset(&self, l: usize, h: usize) -> usize {
        (l * self.n_kv_head + h) * self.block_tokens * self.d_head
    }

    /// Blocks needed to back a full `max_seq` window.
    pub fn blocks_per_seq(&self) -> usize {
        self.max_seq.div_ceil(self.block_tokens).max(1)
    }

    /// Blocks a sequence that will touch at most `tokens` rows must
    /// reserve (clamped into `[1 block, full window]`).
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.clamp(1, self.max_seq).div_ceil(self.block_tokens)
    }

    /// The (physical block, first token, token rows) copy runs of a block
    /// table, clipped to the window — the one place the per-block run
    /// arithmetic lives (adopt/export/gather/scatter all iterate this).
    fn runs<'a>(
        &self,
        table: &'a [u32],
    ) -> impl Iterator<Item = (usize, usize, usize)> + 'a {
        let (bt, max_seq) = (self.block_tokens, self.max_seq);
        table.iter().enumerate().filter_map(move |(c, &pb)| {
            let t0 = c * bt;
            let rows = bt.min(max_seq.saturating_sub(t0));
            (rows > 0).then_some((pb as usize, t0, rows))
        })
    }
}

/// Bytes moved by the compatibility gather/scatter path.  The native
/// in-place path never touches these counters — "zero per-token KV copies"
/// is `gather_bytes == 0 && scatter_bytes == 0` after a serve run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CopyStats {
    pub gathers: u64,
    pub scatters: u64,
    pub gather_bytes: u64,
    pub scatter_bytes: u64,
}

impl CopyStats {
    pub fn total_bytes(&self) -> u64 {
        self.gather_bytes + self.scatter_bytes
    }
}

/// A block-accounting violation detected by the [`ShadowArena`] sanitizer.
///
/// The shadow is a pure state machine (every transition returns
/// `Result<(), ShadowViolation>`, so the detector itself is testable
/// without panics); the arena turns a violation into an abort through
/// [`enforce`], because continuing past corrupted block accounting would
/// silently serve one sequence's KV rows to another.
#[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ShadowViolation {
    /// A slot id was granted while the shadow still thinks it is live.
    SlotReused { slot: usize },
    /// An allocation handed out a block some live sequence already owns.
    AliasedGrant { block: u32, slot: usize, other: usize },
    /// `free` on a slot the shadow does not consider live.
    DoubleFree { slot: usize },
    /// A write through a slot that was never allocated or already freed.
    DeadSlotWrite { slot: usize },
    /// A write at a token position past the slot's block table.
    OutOfTable { slot: usize, pos: usize },
    /// A write would land in a physical block the shadow says this slot
    /// does not own at that table index — the cross-sequence aliasing bug
    /// class copy-on-write prefix sharing makes reachable.
    CrossSequenceAlias { slot: usize, pos: usize, block: u32, owner: Option<usize> },
    /// A write through a table entry that resolves to a *shared* (prefix
    /// cache registered) block — the writer must copy-on-write first.
    SharedBlockWrite { slot: usize, pos: usize, block: u32 },
    /// A shared block's refcount was decremented past zero, or a refcount
    /// operation named a block the shadow never saw published.
    RefcountUnderflow { block: u32 },
    /// A shared block was evicted while holders still pin it.
    PrematureEvict { block: u32, refs: usize },
    /// Blocks or slots still live when the arena should be quiescent.
    LeakAtRetire { live_slots: usize, owned_blocks: usize },
}

#[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
impl std::fmt::Display for ShadowViolation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShadowViolation::SlotReused { slot } => {
                write!(f, "slot {slot} re-granted while still live")
            }
            ShadowViolation::AliasedGrant { block, slot, other } => write!(
                f,
                "block {block} granted to slot {slot} but already owned by slot {other}"
            ),
            ShadowViolation::DoubleFree { slot } => {
                write!(f, "double free of slot {slot}")
            }
            ShadowViolation::DeadSlotWrite { slot } => {
                write!(f, "write through dead slot {slot}")
            }
            ShadowViolation::OutOfTable { slot, pos } => {
                write!(f, "slot {slot} write at token {pos} is out of its block table")
            }
            ShadowViolation::CrossSequenceAlias { slot, pos, block, owner } => write!(
                f,
                "slot {slot} write at token {pos} lands in block {block} owned by {}",
                match owner {
                    Some(o) => format!("slot {o}"),
                    None => "no live sequence".to_string(),
                }
            ),
            ShadowViolation::SharedBlockWrite { slot, pos, block } => write!(
                f,
                "slot {slot} write at token {pos} targets shared block {block} without copy-on-write"
            ),
            ShadowViolation::RefcountUnderflow { block } => {
                write!(f, "refcount underflow on shared block {block}")
            }
            ShadowViolation::PrematureEvict { block, refs } => write!(
                f,
                "premature evict of shared block {block} with {refs} live ref(s)"
            ),
            ShadowViolation::LeakAtRetire { live_slots, owned_blocks } => write!(
                f,
                "leak at retire: {live_slots} slot(s) still live holding {owned_blocks} block(s)"
            ),
        }
    }
}

/// Shadow block-accounting state, mirrored on every alloc/free/write
/// (DESIGN.md §12).  Compiled under `debug_assertions` or the
/// `kv-sanitizer` feature; release serving builds pay nothing.
#[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
#[derive(Debug, Default)]
pub struct ShadowArena {
    /// Mirror of the arena's slot table: block list per live slot.
    slots: Vec<Option<Vec<u32>>>,
    /// Physical block -> owning slot, for blocks owned *exclusively* by
    /// one live sequence (unpublished fresh blocks).
    owner: std::collections::HashMap<u32, usize>,
    /// Physical block -> refcount, for blocks published into the prefix
    /// cache.  The publishing sequence's pin counts as one ref while it
    /// lives; each adopter adds one.  refs == 0 means cached-evictable.
    shared: std::collections::HashMap<u32, usize>,
}

#[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
impl ShadowArena {
    /// Mirror a grant of `blocks` to `slot`.
    pub fn on_alloc(&mut self, slot: usize, blocks: &[u32]) -> Result<(), ShadowViolation> {
        self.on_alloc_shared(slot, &[], blocks)
    }

    /// Mirror a cache-aware grant: `adopted` blocks were pinned in the
    /// shared map earlier (at [`on_acquire`](Self::on_acquire) time);
    /// only the `fresh` tail is newly owned by `slot`.
    pub fn on_alloc_shared(
        &mut self,
        slot: usize,
        adopted: &[u32],
        fresh: &[u32],
    ) -> Result<(), ShadowViolation> {
        if self.slots.len() <= slot {
            self.slots.resize_with(slot + 1, || None);
        }
        if self.slots[slot].is_some() {
            return Err(ShadowViolation::SlotReused { slot });
        }
        for &b in adopted {
            if !self.shared.get(&b).is_some_and(|&r| r > 0) {
                return Err(ShadowViolation::RefcountUnderflow { block: b });
            }
        }
        for &b in fresh {
            if let Some(&other) = self.owner.get(&b) {
                return Err(ShadowViolation::AliasedGrant { block: b, slot, other });
            }
            if self.shared.contains_key(&b) {
                return Err(ShadowViolation::AliasedGrant { block: b, slot, other: slot });
            }
        }
        for &b in fresh {
            self.owner.insert(b, slot);
        }
        let mut table = adopted.to_vec();
        table.extend_from_slice(fresh);
        self.slots[slot] = Some(table);
        Ok(())
    }

    /// Mirror a free of `slot`: exclusive blocks lose their owner; every
    /// shared block in the table (adopted or self-published) drops the
    /// one pin this sequence held.
    pub fn on_free(&mut self, slot: usize) -> Result<(), ShadowViolation> {
        match self.slots.get_mut(slot).and_then(Option::take) {
            Some(blocks) => {
                for b in blocks {
                    if self.owner.get(&b) == Some(&slot) {
                        self.owner.remove(&b);
                    } else {
                        match self.shared.get_mut(&b) {
                            Some(r) if *r > 0 => *r -= 1,
                            _ => return Err(ShadowViolation::RefcountUnderflow { block: b }),
                        }
                    }
                }
                Ok(())
            }
            None => Err(ShadowViolation::DoubleFree { slot }),
        }
    }

    /// Mirror a publish: `blocks` move from exclusive ownership by
    /// `slot` into the shared map with one ref (the publisher's pin).
    pub fn on_publish(&mut self, slot: usize, blocks: &[u32]) -> Result<(), ShadowViolation> {
        for &b in blocks {
            match self.owner.get(&b) {
                Some(&o) if o == slot => {
                    self.owner.remove(&b);
                    self.shared.insert(b, 1);
                }
                Some(&other) => {
                    return Err(ShadowViolation::AliasedGrant { block: b, slot, other })
                }
                None => return Err(ShadowViolation::RefcountUnderflow { block: b }),
            }
        }
        Ok(())
    }

    /// Mirror a pin (cache adoption or preemption re-pin).
    pub fn on_acquire(&mut self, blocks: &[u32]) -> Result<(), ShadowViolation> {
        for &b in blocks {
            match self.shared.get_mut(&b) {
                Some(r) => *r += 1,
                None => return Err(ShadowViolation::RefcountUnderflow { block: b }),
            }
        }
        Ok(())
    }

    /// Mirror a pin release (cancel-before-admission or COW deref).
    pub fn on_release(&mut self, blocks: &[u32]) -> Result<(), ShadowViolation> {
        for &b in blocks {
            match self.shared.get_mut(&b) {
                Some(r) if *r > 0 => *r -= 1,
                _ => return Err(ShadowViolation::RefcountUnderflow { block: b }),
            }
        }
        Ok(())
    }

    /// Mirror an eviction: only zero-ref shared blocks may leave.
    pub fn on_evict(&mut self, blocks: &[u32]) -> Result<(), ShadowViolation> {
        for &b in blocks {
            match self.shared.get(&b) {
                Some(&0) => {
                    self.shared.remove(&b);
                }
                Some(&refs) => return Err(ShadowViolation::PrematureEvict { block: b, refs }),
                None => return Err(ShadowViolation::RefcountUnderflow { block: b }),
            }
        }
        Ok(())
    }

    /// Mirror a copy-on-write: `slot`'s table index `idx` swaps the
    /// shared block `old` for the freshly-owned copy `new`, dropping the
    /// pin on `old`.
    pub fn on_cow(
        &mut self,
        slot: usize,
        idx: usize,
        old: u32,
        new: u32,
    ) -> Result<(), ShadowViolation> {
        if let Some(&other) = self.owner.get(&new) {
            return Err(ShadowViolation::AliasedGrant { block: new, slot, other });
        }
        let Some(table) = self.slots.get_mut(slot).and_then(|s| s.as_mut()) else {
            return Err(ShadowViolation::DeadSlotWrite { slot });
        };
        match table.get_mut(idx) {
            Some(entry) if *entry == old => *entry = new,
            _ => {
                return Err(ShadowViolation::CrossSequenceAlias {
                    slot,
                    pos: idx,
                    block: old,
                    owner: self.owner.get(&old).copied(),
                })
            }
        }
        self.owner.insert(new, slot);
        match self.shared.get_mut(&old) {
            Some(r) if *r > 0 => {
                *r -= 1;
                Ok(())
            }
            _ => Err(ShadowViolation::RefcountUnderflow { block: old }),
        }
    }

    /// Validate a row write: `idx = pos / block_tokens` into the table,
    /// `block` what the *real* table resolved there (`None` = index past
    /// its end).  The write must stay inside the mirrored table and land
    /// in the exact block the shadow granted this slot at that index.
    pub fn check_write(
        &self,
        slot: usize,
        pos: usize,
        idx: usize,
        block: Option<u32>,
    ) -> Result<(), ShadowViolation> {
        let Some(mine) = self.slots.get(slot).and_then(|s| s.as_ref()) else {
            return Err(ShadowViolation::DeadSlotWrite { slot });
        };
        let Some(&granted) = mine.get(idx) else {
            return Err(ShadowViolation::OutOfTable { slot, pos });
        };
        match block {
            Some(b) if b == granted && self.owner.get(&b) == Some(&slot) => Ok(()),
            Some(b) if b == granted && self.shared.contains_key(&b) => {
                Err(ShadowViolation::SharedBlockWrite { slot, pos, block: b })
            }
            Some(b) => Err(ShadowViolation::CrossSequenceAlias {
                slot,
                pos,
                block: b,
                owner: self.owner.get(&b).copied(),
            }),
            None => Err(ShadowViolation::OutOfTable { slot, pos }),
        }
    }

    /// At retire, every sequence must have been freed and every block
    /// returned or parked zero-ref in the cache.  Zero-ref cached blocks
    /// are *not* a leak (they are the cache's working set); a shared
    /// block still pinned at quiescence is.
    pub fn check_quiescent(&self) -> Result<(), ShadowViolation> {
        let live = self.slots.iter().filter(|s| s.is_some()).count();
        let pinned = self.shared.values().filter(|&&r| r > 0).count();
        if live > 0 || !self.owner.is_empty() || pinned > 0 {
            return Err(ShadowViolation::LeakAtRetire {
                live_slots: live,
                owned_blocks: self.owner.len() + pinned,
            });
        }
        Ok(())
    }
}

/// Abort on a sanitizer violation.  The one deliberate panic in this
/// module: past this point block accounting is corrupt and any further
/// decode step could read another sequence's KV rows.
#[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
fn enforce(check: Result<(), ShadowViolation>) {
    if let Err(v) = check {
        // fa2lint: allow(no-hotpath-panic) -- sanitizer-only (debug/kv-sanitizer builds); aborting beats serving aliased KV rows
        panic!("kv-sanitizer: {v}");
    }
}

/// Handle to one sequence's block table in the arena.  Only meaningful
/// for the arena that issued it; freeing returns the blocks to the pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvSlot(usize);

impl KvSlot {
    pub fn index(&self) -> usize {
        self.0
    }
}

#[derive(Debug)]
struct Seq {
    /// Physical pool block per logical token block (eagerly reserved).
    blocks: Vec<u32>,
    /// The leading blocks adopted from the prefix cache (a subset of
    /// `blocks`): this sequence holds one index pin per entry and must
    /// never write through them — copy-on-write swaps a block out of
    /// this set.  Empty on the non-cached path.
    adopted: Vec<u32>,
}

impl Seq {
    /// Blocks granted fresh to this sequence (its own reservation, the
    /// unit both `in_use_blocks` and the scheduler count).
    fn fresh_blocks(&self) -> usize {
        self.blocks.len() - self.adopted.len()
    }
}

/// The worker-owned block pool + per-sequence block tables, optionally
/// bounded so admission control can reserve against *real* availability
/// (DESIGN.md §9/§11: the engine sizes the pool in blocks and admits a
/// session only while [`try_alloc_seq`](Self::try_alloc_seq) can grant
/// its whole reservation).
///
/// # Accounting model
///
/// Every physical block is in exactly one bucket:
///
/// - **free** — on `free_blocks`, grantable;
/// - **exclusive** — fresh-granted to one live sequence; the sum of
///   these is [`blocks_in_use`](Self::blocks_in_use), which the engine
///   asserts equal to the scheduler's reservation ledger;
/// - **cache** — published into the attached [`PrefixIndex`] and no
///   longer owned by a live sequence: pinned while adopters hold refs,
///   evictable (and counted by [`available`](Self::available) as
///   reclaimable) once refs drop to zero.
///
/// Adoption never moves a block between buckets — a cache hit shrinks
/// the *fresh* reservation a session needs, which is exactly how the
/// scheduler's `need` estimate sees the cache.
#[derive(Debug)]
pub struct KvArena {
    geo: KvGeometry,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Physical blocks currently materialized in `k`/`v`.
    pool_blocks: usize,
    free_blocks: Vec<u32>,
    /// Block cap (`None` = unbounded pool that grows on demand).
    cap_blocks: Option<usize>,
    in_use_blocks: usize,
    seqs: Vec<Option<Seq>>,
    free_slots: Vec<usize>,
    stats: CopyStats,
    /// The shared prefix-cache index (DESIGN.md §15); `None` = caching
    /// off, every path degenerates to the plain block-table arena.
    prefix: Option<Arc<Mutex<PrefixIndex>>>,
    /// Shadow accounting mirrored on every alloc/free/write (DESIGN.md
    /// §12); absent from release serving builds.
    #[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
    shadow: ShadowArena,
}

impl KvArena {
    /// An unbounded pool (benches and the compat paths).
    pub fn new(geo: KvGeometry) -> KvArena {
        assert!(geo.block_tokens > 0, "kv block size must be at least one token");
        KvArena {
            geo,
            k: Vec::new(),
            v: Vec::new(),
            pool_blocks: 0,
            free_blocks: Vec::new(),
            cap_blocks: None,
            in_use_blocks: 0,
            seqs: Vec::new(),
            free_slots: Vec::new(),
            stats: CopyStats::default(),
            prefix: None,
            #[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
            shadow: ShadowArena::default(),
        }
    }

    /// A pool bounded to `blocks` physical blocks — the reservation
    /// substrate for KV-pressure-aware admission.
    pub fn with_block_capacity(geo: KvGeometry, blocks: usize) -> KvArena {
        KvArena { cap_blocks: Some(blocks.max(1)), ..KvArena::new(geo) }
    }

    /// Attach a shared prefix-cache index: publishes, adoptions, COW and
    /// eviction all go through it from here on.  The index's block size
    /// must match this geometry's `block_tokens` (hashes are computed
    /// over that granularity).
    pub fn attach_prefix_index(&mut self, ix: Arc<Mutex<PrefixIndex>>) {
        debug_assert_eq!(
            lock_prefix(&ix).block_tokens(),
            self.geo.block_tokens,
            "prefix index block size must match the arena geometry"
        );
        self.prefix = Some(ix);
    }

    /// Whether a prefix-cache index is attached.
    pub fn prefix_cache_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    pub fn geometry(&self) -> KvGeometry {
        self.geo
    }

    /// Sequences currently live (allocated and not freed).
    pub fn live(&self) -> usize {
        self.seqs.iter().filter(|s| s.is_some()).count()
    }

    /// Blocks currently reserved by live sequences.
    pub fn blocks_in_use(&self) -> usize {
        self.in_use_blocks
    }

    /// Physical blocks ever materialized (pool high-water mark).
    pub fn pool_blocks(&self) -> usize {
        self.pool_blocks
    }

    /// The configured block cap (`None` = unbounded).
    pub fn capacity_blocks(&self) -> Option<usize> {
        self.cap_blocks
    }

    /// Blocks an admission decision may still claim right now.  Unbounded
    /// arenas report `usize::MAX` (the scheduler clamps with its own
    /// in-flight cap).  With a prefix cache attached, capacity held by
    /// *pinned* owner-dead cache blocks (adopters alive, publisher gone)
    /// is subtracted — zero-ref cached blocks still count as available
    /// because [`grab_block`](Self::try_alloc_seq) reclaims them LRU-first
    /// on demand.
    pub fn available(&self) -> usize {
        let Some(cap) = self.cap_blocks else { return usize::MAX };
        let pinned_dead = match &self.prefix {
            Some(ix) => lock_prefix(ix).pinned_dead(),
            None => 0,
        };
        cap.saturating_sub(self.in_use_blocks).saturating_sub(pinned_dead)
    }

    pub fn stats(&self) -> CopyStats {
        self.stats
    }

    /// Evict up to `max` zero-ref cached blocks back onto the free list
    /// (LRU-first), mirroring the shadow.  Returns how many were
    /// reclaimed.
    fn reclaim_cached(&mut self, max: usize) -> usize {
        let Some(ix) = self.prefix.clone() else { return 0 };
        let evicted = lock_prefix(&ix).evict_lru(max);
        if evicted.is_empty() {
            return 0;
        }
        #[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
        enforce(self.shadow.on_evict(&evicted));
        crate::obs_count!("kv_prefix_evictions_total", evicted.len());
        let n = evicted.len();
        self.free_blocks.extend(evicted);
        n
    }

    fn grab_block(&mut self) -> u32 {
        let elems = self.geo.block_elems();
        // under cache pressure the pool can be fully materialized while
        // zero-ref cached blocks hold the capacity — reclaim before
        // growing past the cap
        if self.free_blocks.is_empty()
            && self.cap_blocks.is_some_and(|cap| self.pool_blocks >= cap)
        {
            self.reclaim_cached(1);
        }
        match self.free_blocks.pop() {
            Some(b) => {
                let at = b as usize * elems;
                self.k[at..at + elems].iter_mut().for_each(|x| *x = 0.0);
                self.v[at..at + elems].iter_mut().for_each(|x| *x = 0.0);
                b
            }
            None => {
                self.k.resize((self.pool_blocks + 1) * elems, 0.0);
                self.v.resize((self.pool_blocks + 1) * elems, 0.0);
                self.pool_blocks += 1;
                (self.pool_blocks - 1) as u32
            }
        }
    }

    /// Park `seq` in a slot (recycling freed slot ids) and return it.
    fn install_seq(&mut self, seq: Seq) -> usize {
        match self.free_slots.pop() {
            Some(i) => {
                self.seqs[i] = Some(seq);
                i
            }
            None => {
                self.seqs.push(Some(seq));
                self.seqs.len() - 1
            }
        }
    }

    /// Reserve a sequence backed by `n_blocks` zeroed blocks, or `None`
    /// when the pool cannot grant the whole reservation — the
    /// block-level admission-control primitive.
    pub fn try_alloc_seq(&mut self, n_blocks: usize) -> Option<KvSlot> {
        self.try_alloc_seq_shared(&[], n_blocks)
    }

    /// Cache-aware [`try_alloc_seq`](Self::try_alloc_seq): the sequence's
    /// table opens with the already-pinned `adopted` cache blocks (from
    /// [`acquire_prefix`](Self::acquire_prefix)) followed by `n_fresh`
    /// zeroed fresh blocks.  Only the fresh tail counts against
    /// availability and `blocks_in_use` — the adopted blocks stay in the
    /// cache bucket, pinned by the refs taken at acquire time.
    pub fn try_alloc_seq_shared(&mut self, adopted: &[u32], n_fresh: usize) -> Option<KvSlot> {
        let n_fresh = n_fresh.max(1);
        if self.available() < n_fresh {
            return None;
        }
        let fresh: Vec<u32> = (0..n_fresh).map(|_| self.grab_block()).collect();
        self.in_use_blocks += n_fresh;
        let mut blocks = adopted.to_vec();
        blocks.extend_from_slice(&fresh);
        let id = self.install_seq(Seq { blocks, adopted: adopted.to_vec() });
        #[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
        enforce(self.shadow.on_alloc_shared(id, adopted, &fresh));
        crate::obs_count!("kv_block_allocs_total", n_fresh);
        crate::obs_event!("kv_alloc", "slot" => id, "blocks" => n_fresh);
        self.publish_gauges();
        Some(KvSlot(id))
    }

    /// Mirror the arena's occupancy into the global obs gauge registry
    /// (DESIGN.md §13) — called on every grant/release, so a metrics
    /// snapshot always sees the latest levels and high-water mark.
    fn publish_gauges(&self) {
        crate::obs_gauge!("kv_blocks_in_use", self.in_use_blocks);
        crate::obs_gauge_max!("kv_blocks_high_water", self.in_use_blocks);
        crate::obs_gauge!("kv_pool_blocks", self.cap_blocks.unwrap_or(self.pool_blocks));
        // unbounded arenas grow on demand: report the recycled free list
        let free = match self.cap_blocks {
            Some(cap) => cap.saturating_sub(self.in_use_blocks),
            None => self.free_blocks.len(),
        };
        crate::obs_gauge!("kv_free_blocks", free);
        if let Some(ix) = &self.prefix {
            crate::obs_gauge!("kv_prefix_cached_blocks", lock_prefix(ix).len());
        }
    }

    /// Adopt a legacy `(L, 1, H, S, dh)` cache slab pair by copying it
    /// into a full-window block reservation — the one-time admission cost
    /// for callers that prefill outside the arena (benches, tests); no
    /// per-token copies follow on the native path, and these bytes are
    /// NOT counted as gather/scatter traffic.
    pub fn adopt(&mut self, k: Vec<f32>, v: Vec<f32>) -> Result<KvSlot> {
        let n = self.geo.slot_elems();
        if k.len() != n || v.len() != n {
            bail!(
                "kv arena: adopted slab has {}/{} elements, geometry wants {n}",
                k.len(),
                v.len()
            );
        }
        let blocks = self.geo.blocks_per_seq();
        let Some(slot) = self.try_alloc_seq(blocks) else {
            bail!(
                "kv arena: {} blocks available, adoption needs {blocks}; \
                 admission must reserve first",
                self.available()
            );
        };
        // slab (l, h, s, dh) rows -> block planes, run by run (the table
        // read and the pool writes are disjoint fields)
        let geo = self.geo;
        let dh = geo.d_head;
        // fa2lint: allow(no-hotpath-panic) -- slot was allocated two lines up in this function; a miss is arena corruption
        let table = &self.seqs[slot.0].as_ref().expect("just allocated").blocks;
        for l in 0..geo.n_layer {
            for h in 0..geo.n_kv_head {
                let plane = geo.plane_offset(l, h);
                let src_base = (l * geo.n_kv_head + h) * geo.max_seq * dh;
                for (pb, t0, rows) in geo.runs(table) {
                    let src = src_base + t0 * dh..src_base + (t0 + rows) * dh;
                    let dst = pb * geo.block_elems() + plane;
                    self.k[dst..dst + rows * dh].copy_from_slice(&k[src.clone()]);
                    self.v[dst..dst + rows * dh].copy_from_slice(&v[src]);
                }
            }
        }
        Ok(slot)
    }

    /// Return a sequence's blocks to the pool.  Adopted blocks drop
    /// their cache pin instead of hitting the free list; fresh blocks
    /// that were published stay parked in the cache (owner now dead);
    /// everything else is recycled.  The owner-dead retention cap is
    /// enforced afterwards, so a bounded cache sheds its LRU overflow
    /// here.
    pub fn free(&mut self, slot: KvSlot) {
        #[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
        enforce(self.shadow.on_free(slot.0));
        // fa2lint: allow(no-hotpath-panic) -- double free is unrecoverable accounting corruption; the sanitizer reports it first in debug builds
        let seq = self.seqs[slot.0].take().expect("double free of kv slot");
        let fresh = seq.fresh_blocks();
        self.in_use_blocks -= fresh;
        crate::obs_count!("kv_block_frees_total", fresh);
        crate::obs_event!("kv_free", "slot" => slot.0, "blocks" => fresh);
        match self.prefix.clone() {
            Some(ix) => {
                let mut g = lock_prefix(&ix);
                for &b in &seq.blocks {
                    if seq.adopted.contains(&b) {
                        g.release_block(b);
                    } else if !g.owner_free(b) {
                        self.free_blocks.push(b);
                    }
                }
                let evicted = g.enforce_cap();
                drop(g);
                if !evicted.is_empty() {
                    #[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
                    enforce(self.shadow.on_evict(&evicted));
                    crate::obs_count!("kv_prefix_evictions_total", evicted.len());
                    self.free_blocks.extend(evicted);
                }
            }
            None => self.free_blocks.extend(seq.blocks),
        }
        self.free_slots.push(slot.0);
        self.publish_gauges();
    }

    /// Pin and adopt every leading full prompt block already in the
    /// cache, capped so at least the final prompt token is always
    /// replayed (the model needs it to produce first-token logits, and
    /// the cap guarantees the serving path never writes into an adopted
    /// shared block).  Returns `(adopted physical blocks, cached token
    /// count)` — pass the blocks to
    /// [`try_alloc_seq_shared`](Self::try_alloc_seq_shared), or return
    /// them through [`release_prefix_blocks`](Self::release_prefix_blocks)
    /// if the session dies before admission.
    pub fn acquire_prefix(&mut self, prompt: &[i32]) -> (Vec<u32>, usize) {
        let Some(ix) = self.prefix.clone() else { return (Vec::new(), 0) };
        let bt = self.geo.block_tokens;
        let cap = prompt.len().saturating_sub(1) / bt;
        let full = (prompt.len() / bt).min(cap);
        let adopted = lock_prefix(&ix).acquire(prompt, cap);
        #[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
        enforce(self.shadow.on_acquire(&adopted));
        crate::obs_count!("kv_prefix_hits_total", adopted.len());
        crate::obs_count!("kv_prefix_misses_total", full - adopted.len());
        crate::obs_count!("kv_prefix_cached_tokens_total", adopted.len() * bt);
        let cached_tokens = adopted.len() * bt;
        self.publish_gauges();
        (adopted, cached_tokens)
    }

    /// Re-pin already-adopted blocks by physical id — the preemption
    /// path: pin *before* freeing the slot so the refs never touch zero
    /// and the blocks cannot be evicted in between.
    pub fn acquire_prefix_blocks(&mut self, blocks: &[u32]) {
        if blocks.is_empty() {
            return;
        }
        let Some(ix) = self.prefix.clone() else { return };
        lock_prefix(&ix).acquire_blocks(blocks);
        #[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
        enforce(self.shadow.on_acquire(blocks));
    }

    /// Drop pins taken by [`acquire_prefix`](Self::acquire_prefix) for a
    /// session that never reached admission (cancelled while pending).
    pub fn release_prefix_blocks(&mut self, blocks: &[u32]) {
        if blocks.is_empty() {
            return;
        }
        let Some(ix) = self.prefix.clone() else { return };
        lock_prefix(&ix).release_blocks(blocks);
        #[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
        enforce(self.shadow.on_release(blocks));
        self.publish_gauges();
    }

    /// Publish this sequence's fully-prefilled prompt blocks into the
    /// cache (every complete `block_tokens` block of `prompt`).  Called
    /// once per sequence, after prefill wrote all prompt rows — from
    /// here on those blocks are immutable (decode writes start past the
    /// prompt).  Hashes already published by another sequence are
    /// skipped.  Returns how many blocks this call registered.
    pub fn publish_prefix(&mut self, slot: KvSlot, prompt: &[i32]) -> usize {
        let Some(ix) = self.prefix.clone() else { return 0 };
        let blocks = self.table(slot).to_vec();
        let registered = lock_prefix(&ix).publish(prompt, &blocks);
        #[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
        enforce(self.shadow.on_publish(slot.0, &registered));
        self.publish_gauges();
        registered.len()
    }

    /// Copy-on-write guard: if `pos` resolves to a shared (cache
    /// registered) block in `slot`'s table, copy it into a fresh
    /// exclusive block, swap the table entry, and drop the pin on the
    /// original.  Returns true when a copy was taken.  The adoption cap
    /// in [`acquire_prefix`](Self::acquire_prefix) keeps the serving
    /// path from ever needing this, but the engine calls it defensively
    /// before every row write, and divergent-write tests drive it
    /// directly.
    pub fn ensure_writable(&mut self, slot: KvSlot, pos: usize) -> bool {
        let Some(ix) = self.prefix.clone() else { return false };
        let idx = pos / self.geo.block_tokens;
        let old = match self.seqs[slot.0].as_ref().and_then(|s| s.blocks.get(idx)) {
            Some(&b) => b,
            None => return false,
        };
        if !lock_prefix(&ix).contains_block(old) {
            return false;
        }
        let fresh = self.grab_block();
        let elems = self.geo.block_elems();
        let (src, dst) = (old as usize * elems, fresh as usize * elems);
        self.k.copy_within(src..src + elems, dst);
        self.v.copy_within(src..src + elems, dst);
        if let Some(seq) = self.seqs[slot.0].as_mut() {
            seq.blocks[idx] = fresh;
            seq.adopted.retain(|&b| b != old);
        }
        self.in_use_blocks += 1;
        {
            let mut g = lock_prefix(&ix);
            g.release_block(old);
            g.note_cow();
        }
        #[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
        enforce(self.shadow.on_cow(slot.0, idx, old, fresh));
        crate::obs_count!("kv_prefix_cow_total", 1);
        self.publish_gauges();
        true
    }

    /// This sequence's block table (physical block per logical block).
    pub fn table(&self, slot: KvSlot) -> &[u32] {
        // fa2lint: allow(no-hotpath-panic) -- slot liveness is the KvSlot handle contract (slots are only freed through free())
        &self.seqs[slot.0].as_ref().expect("live slot").blocks
    }

    /// Blocks reserved by this sequence.
    pub fn reserved_blocks(&self, slot: KvSlot) -> usize {
        self.table(slot).len()
    }

    /// Token rows this sequence's reservation can hold.
    pub fn reserved_tokens(&self, slot: KvSlot) -> usize {
        (self.reserved_blocks(slot) * self.geo.block_tokens).min(self.geo.max_seq)
    }

    /// In-place paged access to one sequence (the native decode seam).
    pub fn paged_mut(&mut self, slot: KvSlot) -> PagedKvMut<'_> {
        // fa2lint: allow(no-hotpath-panic) -- slot liveness is the handle contract; the shadow reports a dead slot with a typed violation first
        let table = &self.seqs[slot.0].as_ref().expect("live slot").blocks;
        PagedKvMut {
            geo: self.geo,
            k: &mut self.k,
            v: &mut self.v,
            table,
            #[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
            shadow: &self.shadow,
            #[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
            slot: slot.0,
        }
    }

    /// Sanitizer: assert every sequence was freed and every block
    /// returned — the engine worker calls this when it retires, so a
    /// leaked reservation fails loudly instead of shrinking the pool
    /// forever (DESIGN.md §12).
    #[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
    pub fn check_quiescent(&self) {
        enforce(self.shadow.check_quiescent());
    }

    /// Test hook: corrupt `victim`'s block table to point at `donor`'s
    /// first block WITHOUT telling the shadow — the next write through
    /// `victim` must be caught as a cross-sequence alias.  Sanitizer
    /// builds only; exists so the aliasing detector is itself testable.
    #[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
    pub fn corrupt_alias_for_test(&mut self, victim: KvSlot, donor: KvSlot) {
        let donor_block = self.table(donor)[0];
        if let Some(seq) = self.seqs[victim.0].as_mut() {
            seq.blocks[0] = donor_block;
        }
    }

    /// Test hook: zero a shared block's refcount in the *real* index
    /// WITHOUT telling the shadow — a subsequent eviction pass must be
    /// caught as a premature evict of a still-pinned block.  Sanitizer
    /// builds only; exists so the refcount detector is itself testable.
    #[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
    pub fn corrupt_prefix_refs_for_test(&mut self, block: u32) -> bool {
        match self.prefix.clone() {
            Some(ix) => lock_prefix(&ix).corrupt_refs_for_test(block),
            None => false,
        }
    }

    /// Test hook: force up to `max` LRU evictions through the shadow
    /// mirror, as allocation pressure would.  Sanitizer builds only.
    #[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
    pub fn evict_cached_for_test(&mut self, max: usize) -> usize {
        self.reclaim_cached(max)
    }

    /// Assemble this sequence's legacy `(L, 1, H, S, dh)` slab pair
    /// (zeros beyond its reservation) — a test/bench convenience, not a
    /// serving path; the bytes are not counted as gather traffic.
    pub fn export_slab(&self, slot: KvSlot) -> (Vec<f32>, Vec<f32>) {
        let geo = self.geo;
        let dh = geo.d_head;
        let table = self.table(slot);
        let mut ks = vec![0.0f32; geo.slot_elems()];
        let mut vs = vec![0.0f32; geo.slot_elems()];
        for l in 0..geo.n_layer {
            for h in 0..geo.n_kv_head {
                let plane = geo.plane_offset(l, h);
                let dst_base = (l * geo.n_kv_head + h) * geo.max_seq * dh;
                for (pb, t0, rows) in geo.runs(table) {
                    let src = pb * geo.block_elems() + plane;
                    let dst = dst_base + t0 * dh..dst_base + (t0 + rows) * dh;
                    ks[dst.clone()].copy_from_slice(&self.k[src..src + rows * dh]);
                    vs[dst].copy_from_slice(&self.v[src..src + rows * dh]);
                }
            }
        }
        (ks, vs)
    }

    /// Borrow a decode-step view over `slots`, padded (virtually) to
    /// `batch` rows.  `batch` is the compiled bucket size; `slots.len()`
    /// may be smaller.
    pub fn batch_view<'a>(&'a mut self, slots: &[KvSlot], batch: usize) -> KvBatchView<'a> {
        assert!(!slots.is_empty() && slots.len() <= batch, "bad batch view shape");
        KvBatchView { arena: self, slots: slots.to_vec(), batch }
    }
}

/// Mutable paged access to one sequence: append rows in place, and hand
/// the attention kernel a [`KvLayout::Paged`] view of any (layer, head)
/// plane.  This is the zero-copy native decode seam.
///
/// With prefix caching on, a sequence's leading table entries may
/// resolve to *shared* cache blocks.  Reading them (through
/// [`layout`](Self::layout)) is always safe — that is the point of
/// adoption — but [`write_row`](Self::write_row) into one is a
/// [`ShadowViolation::SharedBlockWrite`]: callers must run
/// [`KvArena::ensure_writable`] (copy-on-write) on the position first.
/// The engine's adoption cap keeps serving writes out of shared blocks
/// by construction.
pub struct PagedKvMut<'a> {
    pub geo: KvGeometry,
    k: &'a mut [f32],
    v: &'a mut [f32],
    table: &'a [u32],
    #[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
    shadow: &'a ShadowArena,
    #[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
    slot: usize,
}

impl PagedKvMut<'_> {
    /// Token rows the reservation can hold (writes past this panic).
    pub fn reserved_tokens(&self) -> usize {
        (self.table.len() * self.geo.block_tokens).min(self.geo.max_seq)
    }

    /// Write the K/V row of (layer `l`, kv head `h`) at token position
    /// `pos`, in place.
    pub fn write_row(&mut self, l: usize, h: usize, pos: usize, krow: &[f32], vrow: &[f32]) {
        let geo = &self.geo;
        debug_assert_eq!(krow.len(), geo.d_head);
        debug_assert_eq!(vrow.len(), geo.d_head);
        let (bt, dh) = (geo.block_tokens, geo.d_head);
        #[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
        enforce(self.shadow.check_write(
            self.slot,
            pos,
            pos / bt,
            self.table.get(pos / bt).copied(),
        ));
        let blk = self.table[pos / bt] as usize;
        let at = blk * geo.block_elems() + geo.plane_offset(l, h) + (pos % bt) * dh;
        self.k[at..at + dh].copy_from_slice(krow);
        self.v[at..at + dh].copy_from_slice(vrow);
    }

    /// The (layer `l`, kv head `h`) plane as a paged attention layout.
    pub fn layout(&self, l: usize, h: usize) -> KvLayout<'_> {
        KvLayout::Paged(BlockTable {
            k_pool: self.k,
            v_pool: self.v,
            blocks: self.table,
            block_elems: self.geo.block_elems(),
            plane: self.geo.plane_offset(l, h),
            block_tokens: self.geo.block_tokens,
        })
    }
}

/// A borrowed view of the active slots for one decode step, in batch-row
/// order.  Rows `slots.len()..batch` are padding (replicas of row 0 on the
/// compat path; simply absent on the native in-place path).
pub struct KvBatchView<'a> {
    arena: &'a mut KvArena,
    slots: Vec<KvSlot>,
    batch: usize,
}

impl KvBatchView<'_> {
    /// Real (non-padding) rows in this view.
    pub fn rows(&self) -> usize {
        self.slots.len()
    }

    /// Compiled bucket size the compat path pads to.
    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn geometry(&self) -> KvGeometry {
        self.arena.geo
    }

    /// Row `row`'s sequence for in-place paged decode (native path).
    pub fn paged(&mut self, row: usize) -> PagedKvMut<'_> {
        self.arena.paged_mut(self.slots[row])
    }

    /// Compatibility path: assemble the (L, B, H, S, dh) batch cache pair
    /// the compiled decode artifacts expect, reading each row's blocks
    /// through its table (zeros beyond the reservation).  Padding rows
    /// replicate row 0 (their results are discarded).  Every byte is
    /// accounted in [`CopyStats`].
    pub fn gather(&mut self) -> (HostTensor, HostTensor) {
        let geo = self.arena.geo;
        let per_layer = geo.per_layer();
        let b = self.batch;
        let dims = geo.batch_dims(b);
        let mut kd = vec![0.0f32; geo.n_layer * b * per_layer];
        let mut vd = vec![0.0f32; geo.n_layer * b * per_layer];
        let dh = geo.d_head;
        for l in 0..geo.n_layer {
            for bi in 0..b {
                // padding rows replicate sequence 0 (results discarded)
                let slot = if bi < self.slots.len() { self.slots[bi] } else { self.slots[0] };
                let table = self.arena.table(slot);
                for h in 0..geo.n_kv_head {
                    let plane = geo.plane_offset(l, h);
                    let dst_base = (l * b + bi) * per_layer + h * geo.max_seq * dh;
                    for (pb, t0, rows) in geo.runs(table) {
                        let src = pb * geo.block_elems() + plane;
                        let dst = dst_base + t0 * dh;
                        kd[dst..dst + rows * dh]
                            .copy_from_slice(&self.arena.k[src..src + rows * dh]);
                        vd[dst..dst + rows * dh]
                            .copy_from_slice(&self.arena.v[src..src + rows * dh]);
                    }
                }
            }
        }
        self.arena.stats.gathers += 1;
        self.arena.stats.gather_bytes += 2 * (kd.len() as u64) * 4;
        (HostTensor::from_f32(&dims, &kd), HostTensor::from_f32(&dims, &vd))
    }

    /// Compatibility path: scatter the updated batch cache pair back into
    /// the per-sequence blocks (real rows only, each only up to its
    /// reservation — there is no storage past it).
    pub fn scatter(&mut self, k_new: &HostTensor, v_new: &HostTensor) -> Result<()> {
        let geo = self.arena.geo;
        let per_layer = geo.per_layer();
        let b = self.batch;
        let want = geo.batch_dims(b);
        if k_new.dims != want || v_new.dims != want {
            bail!(
                "kv scatter: decode returned cache dims {:?}/{:?}, expected {want:?}",
                k_new.dims,
                v_new.dims
            );
        }
        let kd = k_new.to_f32_vec();
        let vd = v_new.to_f32_vec();
        let dh = geo.d_head;
        let mut moved_elems = 0u64;
        for bi in 0..self.slots.len() {
            // split borrows: the table lives in arena.seqs, the writes go
            // to arena.k/arena.v — disjoint fields, no clone needed
            let arena = &mut *self.arena;
            let table = &arena.seqs[self.slots[bi].0]
                .as_ref()
                // fa2lint: allow(no-hotpath-panic) -- batch_view validated the slots when the view was built and holds the arena exclusively
                .expect("view slots are live")
                .blocks;
            for l in 0..geo.n_layer {
                for h in 0..geo.n_kv_head {
                    let plane = geo.plane_offset(l, h);
                    let src_base = (l * b + bi) * per_layer + h * geo.max_seq * dh;
                    for (pb, t0, rows) in geo.runs(table) {
                        let src = src_base + t0 * dh;
                        let dst = pb * geo.block_elems() + plane;
                        arena.k[dst..dst + rows * dh]
                            .copy_from_slice(&kd[src..src + rows * dh]);
                        arena.v[dst..dst + rows * dh]
                            .copy_from_slice(&vd[src..src + rows * dh]);
                        moved_elems += (rows * dh) as u64;
                    }
                }
            }
        }
        self.arena.stats.scatters += 1;
        self.arena.stats.scatter_bytes += 2 * moved_elems * 4;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geo() -> KvGeometry {
        KvGeometry { n_layer: 2, n_kv_head: 1, max_seq: 4, d_head: 2, block_tokens: 2 }
    }

    fn ramp(base: f32, n: usize) -> Vec<f32> {
        (0..n).map(|i| base + i as f32).collect()
    }

    #[test]
    fn geometry_block_arithmetic() {
        let g = geo();
        assert_eq!(g.slot_elems(), 2 * 1 * 4 * 2);
        assert_eq!(g.block_elems(), 2 * 1 * 2 * 2);
        assert_eq!(g.blocks_per_seq(), 2);
        assert_eq!(g.plane_offset(1, 0), 1 * 2 * 2);
        assert_eq!(g.blocks_for(1), 1);
        assert_eq!(g.blocks_for(2), 1);
        assert_eq!(g.blocks_for(3), 2);
        assert_eq!(g.blocks_for(100), 2, "clamped to the window");
        assert_eq!(g.blocks_for(0), 1, "at least one block");
        let odd = KvGeometry { max_seq: 5, ..g };
        assert_eq!(odd.blocks_per_seq(), 3, "tail block counts");
    }

    #[test]
    fn alloc_free_reuses_blocks_and_zeroes_them() {
        let g = geo();
        let mut a = KvArena::with_block_capacity(g, 3);
        assert_eq!(a.available(), 3);
        let s0 = a.try_alloc_seq(2).expect("2 blocks");
        assert_eq!(a.reserved_blocks(s0), 2);
        assert_eq!(a.reserved_tokens(s0), 4);
        assert_eq!(a.blocks_in_use(), 2);
        assert_eq!(a.available(), 1);
        // the remaining block serves a short sequence
        let s1 = a.try_alloc_seq(1).expect("1 block");
        assert_eq!(a.available(), 0);
        assert!(a.try_alloc_seq(1).is_none(), "pool exhausted");
        // dirty a block, free, realloc: recycled block comes back zeroed
        {
            let mut p = a.paged_mut(s1);
            p.write_row(0, 0, 0, &[7.0, 8.0], &[9.0, 10.0]);
        }
        a.free(s1);
        assert_eq!(a.available(), 1);
        let s2 = a.try_alloc_seq(1).expect("recycled");
        let (ks, vs) = a.export_slab(s2);
        assert!(ks.iter().chain(&vs).all(|&x| x == 0.0), "recycled block not zeroed");
        assert_eq!(a.live(), 2);
        a.free(s0);
        a.free(s2);
        assert_eq!(a.blocks_in_use(), 0);
        assert_eq!(a.live(), 0);
        // the unbounded pool reports effectively infinite availability
        assert_eq!(KvArena::new(g).available(), usize::MAX);
    }

    #[test]
    fn paged_writes_round_trip_through_the_table() {
        let g = geo();
        let mut a = KvArena::new(g);
        let s = a.try_alloc_seq(g.blocks_per_seq()).unwrap();
        {
            let mut p = a.paged_mut(s);
            assert_eq!(p.reserved_tokens(), 4);
            for pos in 0..4 {
                let base = 10.0 * pos as f32;
                for l in 0..2 {
                    p.write_row(l, 0, pos, &[base + l as f32, 1.0], &[base + 5.0, 2.0]);
                }
            }
            // the layout view sees the rows in token order across blocks
            let lay = p.layout(1, 0);
            let (k01, _) = lay.rows(0, 2, 2);
            assert_eq!(k01, &[1.0, 1.0, 11.0, 1.0]);
            let (k23, v23) = lay.rows(2, 4, 2);
            assert_eq!(k23, &[21.0, 1.0, 31.0, 1.0]);
            assert_eq!(v23, &[25.0, 2.0, 35.0, 2.0]);
        }
        // export assembles the legacy slab layout
        let (ks, _) = a.export_slab(s);
        // layer 1 plane starts at per_layer = 8; row 3 of that plane
        assert_eq!(&ks[8 + 3 * 2..8 + 4 * 2], &[31.0, 1.0]);
    }

    #[test]
    fn adopt_scatters_the_slab_into_blocks() {
        let g = geo();
        let n = g.slot_elems();
        let mut a = KvArena::new(g);
        let s = a.adopt(ramp(0.0, n), ramp(100.0, n)).unwrap();
        assert_eq!(a.reserved_blocks(s), g.blocks_per_seq());
        let (ks, vs) = a.export_slab(s);
        assert_eq!(ks, ramp(0.0, n), "adopt/export must round-trip the slab");
        assert_eq!(vs, ramp(100.0, n));
        // adoption is admission cost, not per-step gather/scatter traffic
        assert_eq!(a.stats(), CopyStats::default());
        // wrong-size adoption is a typed error, not a corrupted pool
        assert!(a.adopt(vec![0.0; n + 1], vec![0.0; n]).is_err());
        // bounded arena refuses adoption past its block budget
        let mut b = KvArena::with_block_capacity(g, 1);
        assert!(b.adopt(ramp(0.0, n), ramp(0.0, n)).is_err());
    }

    #[test]
    fn gather_matches_legacy_assemble_layout() {
        // Same (L, B, H, S, dh) interleaving and pad-row replication as
        // the PR-3 slab arena — the compat contract compiled artifacts
        // rely on — now read through the block tables.
        let g = geo();
        let n = g.slot_elems();
        let mut a = KvArena::new(g);
        let s0 = a.adopt(ramp(0.0, n), vec![0.0; n]).unwrap();
        let s1 = a.adopt(ramp(100.0, n), vec![0.0; n]).unwrap();
        let mut view = a.batch_view(&[s0, s1], 4);
        let (k, _v) = view.gather();
        assert_eq!(k.dims, vec![2, 4, 1, 4, 2]);
        let data = k.to_f32_vec();
        let per_layer = g.per_layer(); // 8
        // layer 0: [seq0 layer0][seq1 layer0][pad=seq0][pad=seq0]
        assert_eq!(&data[0..per_layer], &ramp(0.0, per_layer)[..]);
        assert_eq!(&data[per_layer..2 * per_layer], &ramp(100.0, per_layer)[..]);
        assert_eq!(&data[2 * per_layer..3 * per_layer], &ramp(0.0, per_layer)[..]);
        // layer 1 of seq1 starts at (1*4 + 1)*per_layer
        assert_eq!(
            &data[5 * per_layer..6 * per_layer],
            &ramp(100.0 + per_layer as f32, per_layer)[..]
        );
        assert_eq!(a.stats().gathers, 1);
        assert_eq!(a.stats().gather_bytes, 2u64 * (2 * 4 * per_layer as u64) * 4);
    }

    #[test]
    fn scatter_roundtrips_and_counts_real_rows_only() {
        let g = geo();
        let n = g.slot_elems();
        let mut a = KvArena::new(g);
        let s0 = a.adopt(ramp(0.0, n), ramp(50.0, n)).unwrap();
        let s1 = a.adopt(ramp(100.0, n), ramp(150.0, n)).unwrap();
        let mut view = a.batch_view(&[s0, s1], 4);
        let (k, v) = view.gather();
        // mutate one row of the batched tensor, write it back
        let mut kd = k.to_f32_vec();
        let per_layer = g.per_layer();
        // (l=1, b=1) block
        let off = (1 * 4 + 1) * per_layer;
        for x in &mut kd[off..off + per_layer] {
            *x += 1000.0;
        }
        let k2 = HostTensor::from_f32(&k.dims, &kd);
        view.scatter(&k2, &v).unwrap();
        let (ks1, vs1) = a.export_slab(s1);
        assert_eq!(
            &ks1[per_layer..2 * per_layer],
            &ramp(1000.0 + 100.0 + per_layer as f32, per_layer)[..]
        );
        assert_eq!(vs1, ramp(150.0, n));
        // stats: one gather of the padded batch, one scatter of 2 real
        // rows' reserved regions (full window here)
        let st = a.stats();
        assert_eq!(st.scatters, 1);
        assert_eq!(st.scatter_bytes, 2 * (2 * 2 * per_layer as u64) * 4);
        assert_eq!(st.total_bytes(), st.gather_bytes + st.scatter_bytes);
        // dims mismatch is rejected
        let mut view = a.batch_view(&[s0], 1);
        assert!(view.scatter(&k2, &v).is_err());
    }

    #[test]
    fn short_reservations_gather_zeros_past_their_blocks() {
        let g = geo();
        let mut a = KvArena::with_block_capacity(g, 2);
        // one block = 2 of the 4 window tokens
        let s = a.try_alloc_seq(1).unwrap();
        {
            let mut p = a.paged_mut(s);
            p.write_row(0, 0, 0, &[1.0, 2.0], &[3.0, 4.0]);
            p.write_row(0, 0, 1, &[5.0, 6.0], &[7.0, 8.0]);
        }
        let mut view = a.batch_view(&[s], 1);
        let (k, v) = view.gather();
        let kd = k.to_f32_vec();
        assert_eq!(&kd[0..4], &[1.0, 2.0, 5.0, 6.0]);
        assert_eq!(&kd[4..8], &[0.0; 4], "past the reservation is zeros");
        // scatter writes back (and counts) only the reserved rows
        let before = a.stats().scatter_bytes;
        let mut view = a.batch_view(&[s], 1);
        view.scatter(&k, &v).unwrap();
        let per_block_rows = 2u64; // one block of 2 tokens per (l, h)
        assert_eq!(
            a.stats().scatter_bytes - before,
            2 * (g.n_layer as u64 * per_block_rows * g.d_head as u64) * 4
        );
    }

    #[test]
    fn in_place_paged_access_moves_zero_bytes() {
        let g = geo();
        let n = g.slot_elems();
        let mut a = KvArena::new(g);
        let s0 = a.adopt(ramp(0.0, n), ramp(1.0, n)).unwrap();
        {
            let mut view = a.batch_view(&[s0], 4);
            assert_eq!(view.rows(), 1);
            assert_eq!(view.batch(), 4);
            let mut p = view.paged(0);
            p.write_row(0, 0, 0, &[42.0, 42.5], &[43.0, 43.5]);
        }
        let (ks, vs) = a.export_slab(s0);
        assert_eq!(ks[0], 42.0);
        assert_eq!(vs[0], 43.0);
        // the whole point: native in-place decode never bumps the counters
        assert_eq!(a.stats(), CopyStats::default());
        assert_eq!(a.stats().total_bytes(), 0);
    }

    // --- prefix cache over the arena (DESIGN.md §15) ---

    fn cached_arena(cap: usize) -> KvArena {
        let mut a = KvArena::with_block_capacity(geo(), cap);
        a.attach_prefix_index(Arc::new(Mutex::new(PrefixIndex::new(
            geo().block_tokens,
            0,
        ))));
        a
    }

    /// Prefill both blocks of `slot` with rows derived from `base`.
    fn fill_rows(a: &mut KvArena, slot: KvSlot, base: f32) {
        let mut p = a.paged_mut(slot);
        for pos in 0..4 {
            for l in 0..2 {
                let x = base + 10.0 * pos as f32 + l as f32;
                p.write_row(l, 0, pos, &[x, x + 1.0], &[x + 2.0, x + 3.0]);
            }
        }
    }

    #[test]
    fn adoption_shares_published_blocks_and_shrinks_fresh_need() {
        let mut a = cached_arena(8);
        let prompt = [1, 2, 3, 4];
        let s0 = a.try_alloc_seq(2).unwrap();
        fill_rows(&mut a, s0, 0.0);
        assert_eq!(a.publish_prefix(s0, &prompt), 2, "both full blocks published");
        let s0_table = a.table(s0).to_vec();
        a.free(s0);
        // publisher gone; the blocks are parked zero-ref in the cache
        assert_eq!(a.blocks_in_use(), 0);

        // same prompt + decode headroom: adoption is capped below the
        // last prompt token -> 1 of 2 blocks adopted
        let (adopted, cached_tokens) = a.acquire_prefix(&prompt);
        assert_eq!(adopted, vec![s0_table[0]], "adopts the first published block");
        assert_eq!(cached_tokens, 2);
        let before = a.blocks_in_use();
        let s1 = a.try_alloc_seq_shared(&adopted, 1).unwrap();
        // strictly fewer fresh blocks than a cold session would take
        assert_eq!(a.blocks_in_use() - before, 1);
        assert_eq!(a.table(s1).len(), 2, "adopted + fresh spans the window");
        // the adopted block really is s0's bytes: layer 1 rows 0..2
        {
            let p = a.paged_mut(s1);
            let lay = p.layout(1, 0);
            let (k01, _) = lay.rows(0, 2, 2);
            assert_eq!(k01, &[1.0, 2.0, 11.0, 12.0], "shared block holds s0's prefill");
        }
        a.free(s1);
    }

    #[test]
    fn cow_copies_shared_block_and_drops_the_pin() {
        let mut a = cached_arena(8);
        let prompt = [1, 2, 3, 4];
        let s0 = a.try_alloc_seq(2).unwrap();
        fill_rows(&mut a, s0, 0.0);
        a.publish_prefix(s0, &prompt);
        a.free(s0);
        let (adopted, _) = a.acquire_prefix(&prompt);
        assert_eq!(adopted.len(), 1);
        let s1 = a.try_alloc_seq_shared(&adopted, 1).unwrap();
        let shared_block = a.table(s1)[0];
        // divergence: the session wants to overwrite token 0
        assert!(a.ensure_writable(s1, 0), "write into a shared block must COW");
        let private = a.table(s1)[0];
        assert_ne!(private, shared_block, "table entry swapped to a private copy");
        // the copy carries the bytes, and is now writable without a trip
        {
            let mut p = a.paged_mut(s1);
            let (k01, _) = p.layout(1, 0).rows(0, 2, 2);
            assert_eq!(k01, &[1.0, 2.0, 11.0, 12.0], "COW preserved the contents");
            p.write_row(1, 0, 0, &[9.0, 9.0], &[9.0, 9.0]);
        }
        assert!(!a.ensure_writable(s1, 0), "already private: no second copy");
        // the COW grant is accounted: 1 adopted pin dropped, 2 fresh held
        assert_eq!(a.blocks_in_use(), 2);
        a.free(s1);
        assert_eq!(a.blocks_in_use(), 0);
    }

    #[test]
    fn allocation_pressure_evicts_only_unpinned_cache_blocks() {
        let mut a = cached_arena(4);
        let prompt = [1, 2, 3, 4];
        let s0 = a.try_alloc_seq(2).unwrap();
        fill_rows(&mut a, s0, 0.0);
        a.publish_prefix(s0, &prompt);
        a.free(s0);
        // adopt block 0 (pinning it); block 1 stays zero-ref cached
        let (adopted, _) = a.acquire_prefix(&prompt);
        let s1 = a.try_alloc_seq_shared(&adopted, 1).unwrap();
        // in_use = 1 fresh, 1 pinned cache block, 1 evictable, 1 free:
        // available counts the evictable block but not the pinned one
        assert_eq!(a.available(), 2);
        // demanding both remaining blocks forces the LRU eviction of the
        // unpinned cached block; the pinned one must survive
        let s2 = a.try_alloc_seq(2).expect("eviction reclaims the zero-ref block");
        assert_eq!(a.available(), 0);
        let pinned = a.table(s1)[0];
        assert!(
            !a.table(s2).contains(&pinned),
            "pinned shared block must never be re-granted"
        );
        // and the shared bytes are still intact
        {
            let p = a.paged_mut(s1);
            let (k01, _) = p.layout(0, 0).rows(0, 2, 2);
            assert_eq!(k01, &[0.0, 1.0, 10.0, 11.0]);
        }
        a.free(s1);
        a.free(s2);
    }

    #[test]
    fn cancel_before_admission_releases_pins() {
        let mut a = cached_arena(4);
        let prompt = [1, 2, 3, 4];
        let s0 = a.try_alloc_seq(2).unwrap();
        fill_rows(&mut a, s0, 0.0);
        a.publish_prefix(s0, &prompt);
        a.free(s0);
        let (adopted, _) = a.acquire_prefix(&prompt);
        assert_eq!(adopted.len(), 1);
        // the session dies before try_alloc_seq_shared
        a.release_prefix_blocks(&adopted);
        // both cached blocks are zero-ref again: a full-pool claim works
        assert_eq!(a.available(), 4);
        let s = a.try_alloc_seq(4).expect("released pins make the pool reclaimable");
        a.free(s);
    }
}

/// Sanitizer tests: drive the pure [`ShadowArena`] state machine, then
/// inject real corruption into a [`KvArena`] and assert the abort paths
/// fire with the right violation.  Gated exactly like the sanitizer so
/// `cargo check --release --all-targets` (no debug_assertions, feature
/// off) still compiles.
#[cfg(all(test, any(debug_assertions, feature = "kv-sanitizer")))]
mod sanitizer_tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    fn geo() -> KvGeometry {
        KvGeometry { n_layer: 1, n_kv_head: 1, max_seq: 4, d_head: 2, block_tokens: 2 }
    }

    /// Run `f`, assert it panics, and return the panic message.
    fn panic_message(f: impl FnOnce()) -> String {
        let err = catch_unwind(AssertUnwindSafe(f)).expect_err("expected a sanitizer abort");
        err.downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .expect("panic payload is a message")
    }

    // --- the pure state machine, violation by violation ---

    #[test]
    fn shadow_detects_double_free_and_slot_reuse() {
        let mut s = ShadowArena::default();
        s.on_alloc(0, &[3, 4]).unwrap();
        assert_eq!(
            s.on_alloc(0, &[5]),
            Err(ShadowViolation::SlotReused { slot: 0 })
        );
        s.on_free(0).unwrap();
        assert_eq!(s.on_free(0), Err(ShadowViolation::DoubleFree { slot: 0 }));
        assert_eq!(s.on_free(9), Err(ShadowViolation::DoubleFree { slot: 9 }));
    }

    #[test]
    fn shadow_detects_aliased_grant_and_leak() {
        let mut s = ShadowArena::default();
        s.on_alloc(0, &[1, 2]).unwrap();
        assert_eq!(
            s.on_alloc(1, &[2]),
            Err(ShadowViolation::AliasedGrant { block: 2, slot: 1, other: 0 })
        );
        assert_eq!(
            s.check_quiescent(),
            Err(ShadowViolation::LeakAtRetire { live_slots: 1, owned_blocks: 2 })
        );
        s.on_free(0).unwrap();
        s.check_quiescent().unwrap();
    }

    #[test]
    fn shadow_validates_writes() {
        let mut s = ShadowArena::default();
        s.on_alloc(0, &[7, 8]).unwrap();
        s.check_write(0, 3, 1, Some(8)).unwrap();
        assert_eq!(
            s.check_write(0, 4, 2, None),
            Err(ShadowViolation::OutOfTable { slot: 0, pos: 4 })
        );
        // the real table disagrees with the shadow grant: aliasing
        assert_eq!(
            s.check_write(0, 0, 0, Some(9)),
            Err(ShadowViolation::CrossSequenceAlias {
                slot: 0,
                pos: 0,
                block: 9,
                owner: None
            })
        );
        assert_eq!(
            s.check_write(5, 0, 0, Some(7)),
            Err(ShadowViolation::DeadSlotWrite { slot: 5 })
        );
    }

    // --- injected corruption through the real arena ---

    #[test]
    fn arena_double_free_aborts() {
        let mut a = KvArena::with_block_capacity(geo(), 2);
        let s = a.try_alloc_seq(1).unwrap();
        a.free(s);
        let msg = panic_message(move || a.free(s));
        assert!(msg.contains("kv-sanitizer"), "{msg}");
        assert!(msg.contains("double free"), "{msg}");
    }

    #[test]
    fn arena_leak_at_retire_aborts() {
        let mut a = KvArena::with_block_capacity(geo(), 2);
        let _leaked = a.try_alloc_seq(2).unwrap();
        let msg = panic_message(|| a.check_quiescent());
        assert!(msg.contains("leak at retire"), "{msg}");
        assert!(msg.contains("2 block"), "{msg}");
    }

    #[test]
    fn arena_cross_sequence_alias_write_aborts() {
        let mut a = KvArena::with_block_capacity(geo(), 2);
        let victim = a.try_alloc_seq(1).unwrap();
        let donor = a.try_alloc_seq(1).unwrap();
        a.corrupt_alias_for_test(victim, donor);
        let msg = panic_message(AssertUnwindSafe(|| {
            let mut p = a.paged_mut(victim);
            p.write_row(0, 0, 0, &[1.0, 2.0], &[3.0, 4.0]);
        }));
        assert!(msg.contains("kv-sanitizer"), "{msg}");
        assert!(msg.contains("lands in block"), "{msg}");
    }

    #[test]
    fn arena_out_of_table_write_aborts() {
        let mut a = KvArena::with_block_capacity(geo(), 2);
        // one block of 2 tokens reserved; token 2 is past the table
        let s = a.try_alloc_seq(1).unwrap();
        let msg = panic_message(AssertUnwindSafe(|| {
            let mut p = a.paged_mut(s);
            p.write_row(0, 0, 2, &[1.0, 2.0], &[3.0, 4.0]);
        }));
        assert!(msg.contains("out of its block table"), "{msg}");
    }

    #[test]
    fn clean_lifecycle_stays_silent() {
        let mut a = KvArena::with_block_capacity(geo(), 2);
        let s0 = a.try_alloc_seq(1).unwrap();
        let s1 = a.try_alloc_seq(1).unwrap();
        {
            let mut p = a.paged_mut(s0);
            p.write_row(0, 0, 0, &[1.0, 2.0], &[3.0, 4.0]);
            p.write_row(0, 0, 1, &[5.0, 6.0], &[7.0, 8.0]);
        }
        a.free(s0);
        // freed blocks may be re-granted immediately without tripping
        let s2 = a.try_alloc_seq(1).unwrap();
        a.free(s1);
        a.free(s2);
        a.check_quiescent();
    }

    // --- refcounted sharing: the generalized state machine ---

    #[test]
    fn shadow_detects_shared_block_write() {
        let mut s = ShadowArena::default();
        s.on_alloc(0, &[3, 4]).unwrap();
        s.on_publish(0, &[3]).unwrap();
        // slot 0's table still maps idx 0 -> block 3, but 3 is shared now
        assert_eq!(
            s.check_write(0, 0, 0, Some(3)),
            Err(ShadowViolation::SharedBlockWrite { slot: 0, pos: 0, block: 3 })
        );
        // the exclusive block stays writable
        s.check_write(0, 2, 1, Some(4)).unwrap();
    }

    #[test]
    fn shadow_detects_refcount_underflow_and_premature_evict() {
        let mut s = ShadowArena::default();
        s.on_alloc(0, &[3]).unwrap();
        s.on_publish(0, &[3]).unwrap();
        s.on_acquire(&[3]).unwrap(); // an adopter pins: refs = 2
        assert_eq!(
            s.on_evict(&[3]),
            Err(ShadowViolation::PrematureEvict { block: 3, refs: 2 })
        );
        s.on_release(&[3]).unwrap();
        s.on_free(0).unwrap(); // publisher's pin: refs = 0
        assert_eq!(
            s.on_release(&[3]),
            Err(ShadowViolation::RefcountUnderflow { block: 3 })
        );
        s.on_evict(&[3]).unwrap();
        assert_eq!(
            s.on_acquire(&[3]),
            Err(ShadowViolation::RefcountUnderflow { block: 3 })
        );
        s.check_quiescent().unwrap();
    }

    #[test]
    fn shadow_cow_transfers_ownership_and_drops_the_pin() {
        let mut s = ShadowArena::default();
        s.on_alloc(0, &[3]).unwrap();
        s.on_publish(0, &[3]).unwrap();
        s.on_acquire(&[3]).unwrap();
        s.on_alloc_shared(1, &[3], &[7]).unwrap();
        // slot 1 diverges at idx 0: block 3 -> private copy 9
        s.on_cow(1, 0, 3, 9).unwrap();
        s.check_write(1, 0, 0, Some(9)).unwrap();
        s.on_free(1).unwrap(); // releases 9 (owned) and 7; 3's pin went at COW
        s.on_free(0).unwrap();
        s.on_evict(&[3]).unwrap();
        s.check_quiescent().unwrap();
    }

    #[test]
    fn shadow_quiescence_tolerates_zero_ref_cache_but_not_pins() {
        let mut s = ShadowArena::default();
        s.on_alloc(0, &[3]).unwrap();
        s.on_publish(0, &[3]).unwrap();
        s.on_acquire(&[3]).unwrap();
        s.on_free(0).unwrap();
        // an adopter pin outlives every sequence: that is a leak
        assert_eq!(
            s.check_quiescent(),
            Err(ShadowViolation::LeakAtRetire { live_slots: 0, owned_blocks: 1 })
        );
        s.on_release(&[3]).unwrap();
        // zero-ref cached block: the cache's working set, not a leak
        s.check_quiescent().unwrap();
    }

    // --- injected refcount corruption through the real arena ---

    #[test]
    fn arena_premature_evict_of_pinned_block_aborts() {
        use std::sync::{Arc, Mutex};
        let mut a = KvArena::with_block_capacity(geo(), 4);
        a.attach_prefix_index(Arc::new(Mutex::new(PrefixIndex::new(
            geo().block_tokens,
            0,
        ))));
        let prompt = [1, 2, 3, 4];
        let s0 = a.try_alloc_seq(2).unwrap();
        {
            let mut p = a.paged_mut(s0);
            for pos in 0..4 {
                p.write_row(0, 0, pos, &[1.0, 2.0], &[3.0, 4.0]);
            }
        }
        a.publish_prefix(s0, &prompt);
        a.free(s0);
        let (adopted, _) = a.acquire_prefix(&prompt);
        assert_eq!(adopted.len(), 1, "one block pinned");
        // zero the real refcount behind the shadow's back: the pinned
        // block now looks evictable to the index
        assert!(a.corrupt_prefix_refs_for_test(adopted[0]));
        let msg = panic_message(AssertUnwindSafe(|| {
            a.evict_cached_for_test(4);
        }));
        assert!(msg.contains("kv-sanitizer"), "{msg}");
        assert!(msg.contains("premature evict"), "{msg}");
    }
}
