//! Execution-backend seam for the runtime.
//!
//! The real path (feature `xla`) drives the PJRT CPU client through the
//! `xla` bindings; those bindings are not part of the offline vendor set,
//! so the default build substitutes an in-tree stub with the same API.
//! Manifest-only workflows (`repro inspect`, spec validation, the
//! synthesized-fixture tests) work under both; compiling or executing an
//! artifact requires the real backend and reports a clear error otherwise.

/// Wall-clock split of one execution, feeding `runtime::ExecStats`.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecTiming {
    pub exec_secs: f64,
    pub transfer_secs: f64,
}

#[cfg(feature = "xla")]
pub use pjrt::{Client, LoadedModule};
#[cfg(not(feature = "xla"))]
pub use stub::{Client, LoadedModule};

#[cfg(feature = "xla")]
mod pjrt {
    use std::path::Path;
    use std::time::Instant;

    use super::ExecTiming;
    use crate::bail;
    use crate::util::error::{Context, Error, Result};
    use crate::util::tensorio::{DType, HostTensor};

    fn element_type(dt: DType) -> xla::ElementType {
        match dt {
            DType::F32 => xla::ElementType::F32,
            DType::I32 => xla::ElementType::S32,
            DType::U32 => xla::ElementType::U32,
            DType::F64 => xla::ElementType::F64,
            DType::I64 => xla::ElementType::S64,
        }
    }

    fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
        xla::Literal::create_from_shape_and_untyped_data(
            element_type(t.dtype),
            &t.dims,
            &t.data,
        )
        .map_err(|e| Error::msg(format!("literal create failed: {e:?}")))
    }

    fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit
            .array_shape()
            .map_err(|e| Error::msg(format!("literal shape: {e:?}")))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let dtype = match shape.primitive_type() {
            xla::PrimitiveType::F32 => DType::F32,
            xla::PrimitiveType::S32 => DType::I32,
            xla::PrimitiveType::U32 => DType::U32,
            xla::PrimitiveType::F64 => DType::F64,
            xla::PrimitiveType::S64 => DType::I64,
            other => bail!("unsupported output primitive type {other:?}"),
        };
        let n = lit.element_count();
        let data;
        // Bulk path: one copy_raw_to into a typed buffer, then a single
        // memcpy reinterpreting to bytes (host is little-endian, matching
        // FAT1).  (Perf: the original per-element to_le_bytes loop was ~40%
        // of transfer time on large outputs.)
        macro_rules! copy_as {
            ($t:ty) => {{
                let mut buf = vec![<$t>::default(); n];
                lit.copy_raw_to::<$t>(&mut buf)
                    .map_err(|e| Error::msg(format!("copy_raw_to: {e:?}")))?;
                // SAFETY: buf is a live, initialized slice of plain-old-data
                // numeric values; reinterpreting as bytes is always valid.
                let bytes = unsafe {
                    std::slice::from_raw_parts(
                        buf.as_ptr() as *const u8,
                        n * std::mem::size_of::<$t>(),
                    )
                };
                data = bytes.to_vec();
            }};
        }
        match dtype {
            DType::F32 => copy_as!(f32),
            DType::I32 => copy_as!(i32),
            DType::U32 => copy_as!(u32),
            DType::F64 => copy_as!(f64),
            DType::I64 => copy_as!(i64),
        }
        Ok(HostTensor { dtype, dims, data })
    }

    /// The PJRT CPU client.
    pub struct Client {
        inner: xla::PjRtClient,
    }

    impl Client {
        pub fn cpu() -> Result<Client> {
            let inner = xla::PjRtClient::cpu()
                .map_err(|e| Error::msg(format!("PjRtClient::cpu: {e:?}")))?;
            Ok(Client { inner })
        }

        pub fn platform_name(&self) -> String {
            self.inner.platform_name()
        }

        /// Parse + compile an HLO *text* module (text, not serialized proto:
        /// xla_extension 0.5.1 rejects jax>=0.5's 64-bit instruction ids;
        /// the text parser reassigns them).
        pub fn compile_hlo_text(&self, name: &str, path: &Path) -> Result<LoadedModule> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .map_err(|e| Error::msg(format!("{name}: parse hlo: {e:?}")))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .inner
                .compile(&comp)
                .map_err(|e| Error::msg(format!("{name}: compile: {e:?}")))?;
            Ok(LoadedModule { exe, name: name.to_string() })
        }
    }

    /// A compiled HLO module ready to run.
    pub struct LoadedModule {
        exe: xla::PjRtLoadedExecutable,
        name: String,
    }

    impl LoadedModule {
        pub fn execute(&self, inputs: &[HostTensor]) -> Result<(Vec<HostTensor>, ExecTiming)> {
            let t0 = Instant::now();
            let literals = inputs
                .iter()
                .map(to_literal)
                .collect::<Result<Vec<_>>>()?;
            let transfer_in = t0.elapsed().as_secs_f64();

            let t1 = Instant::now();
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| Error::msg(format!("{}: execute: {e:?}", self.name)))?;
            let exec_secs = t1.elapsed().as_secs_f64();

            let t2 = Instant::now();
            let buffer = &result[0][0];
            let lit = buffer
                .to_literal_sync()
                .map_err(|e| Error::msg(format!("to_literal_sync: {e:?}")))?;
            // aot.py lowers with return_tuple=True: the single output is a
            // tuple.
            let parts = lit
                .to_tuple()
                .map_err(|e| Error::msg(format!("to_tuple: {e:?}")))?;
            let outputs = parts
                .iter()
                .map(from_literal)
                .collect::<Result<Vec<_>>>()?;
            let transfer_secs = transfer_in + t2.elapsed().as_secs_f64();
            Ok((outputs, ExecTiming { exec_secs, transfer_secs }))
        }
    }
}

#[cfg(not(feature = "xla"))]
mod stub {
    use std::path::Path;

    use super::ExecTiming;
    use crate::util::error::{Error, Result};
    use crate::util::tensorio::HostTensor;

    const HINT: &str =
        "this build has no execution backend (enable the `xla` feature and \
         add the xla bindings as a path dependency in rust/Cargo.toml)";

    /// No-op PJRT stand-in so the crate builds fully offline.
    pub struct Client;

    impl Client {
        pub fn cpu() -> Result<Client> {
            Ok(Client)
        }

        pub fn platform_name(&self) -> String {
            "stub (built without the `xla` feature)".to_string()
        }

        pub fn compile_hlo_text(&self, name: &str, _path: &Path) -> Result<LoadedModule> {
            Err(Error::msg(format!("{name}: cannot compile HLO artifact: {HINT}")))
        }
    }

    pub struct LoadedModule;

    impl LoadedModule {
        pub fn execute(&self, _inputs: &[HostTensor]) -> Result<(Vec<HostTensor>, ExecTiming)> {
            Err(Error::msg(HINT))
        }
    }
}
