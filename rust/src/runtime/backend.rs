//! Execution-backend seam for the runtime: the [`Backend`]/[`Module`]
//! traits and the three implementations behind them.
//!
//! - `pjrt` (feature `xla`): the real PJRT CPU client over compiled HLO
//!   artifacts.  The bindings are not part of the offline vendor set, so
//!   default builds omit it.
//! - `stub`: always available; manifest-only workflows work, executing an
//!   artifact reports a clear error.
//! - `native` (`runtime::native`): the in-tree `attn::exec` CPU engine
//!   with a synthesized manifest — `serve`/`verify` run end-to-end on a
//!   fresh checkout with no AOT artifacts and no `xla` feature.

use crate::bail;
use crate::runtime::artifact::ArtifactSpec;
use crate::runtime::kv::KvBatchView;
use crate::util::error::Result;
use crate::util::tensorio::HostTensor;

/// Wall-clock split of one execution, feeding `runtime::ExecStats`.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecTiming {
    pub exec_secs: f64,
    pub transfer_secs: f64,
}

/// Which execution backend `Runtime::with_backend` constructs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// PJRT when built with the `xla` feature, the stub otherwise.
    Auto,
    /// In-tree `attn::exec` CPU engine + synthesized manifest.
    Native,
    /// PJRT CPU client (requires the `xla` feature).
    Pjrt,
    /// No-op backend: inspection works, execution errors.
    Stub,
}

impl BackendKind {
    /// Parse a `--backend` flag / config value.
    pub fn from_flag(s: &str) -> Result<BackendKind> {
        Ok(match s {
            "auto" | "" => BackendKind::Auto,
            "native" => BackendKind::Native,
            "xla" | "pjrt" => BackendKind::Pjrt,
            "stub" => BackendKind::Stub,
            other => bail!("unknown backend '{other}' (expected auto|native|xla|stub)"),
        })
    }
}

/// One loaded executable.
pub trait Module {
    fn execute(&self, inputs: &[HostTensor]) -> Result<(Vec<HostTensor>, ExecTiming)>;

    /// One batched decode step over the KV arena (serving hot path).
    ///
    /// `tok`/`pos` carry one entry per *real* row (`view.rows()`); the
    /// returned logits hold at least `view.rows() * vocab` values with row
    /// `i` at `i * vocab`.
    ///
    /// The default is the compatibility path for compiled-artifact
    /// backends: gather the slots into the (L, B, H, S, dh) batch cache
    /// pair the artifact signature expects (padding rows replicate row 0),
    /// execute, scatter the updated rows back.  Every byte it moves is
    /// accounted in the arena's `CopyStats`.  Backends that can mutate the
    /// cache in place (native) override this and move zero bytes.
    fn decode_step(
        &self,
        params: &[HostTensor],
        view: &mut KvBatchView<'_>,
        tok: &[i32],
        pos: &[i32],
    ) -> Result<(Vec<f32>, ExecTiming)> {
        let b = view.batch();
        let (k, v) = view.gather();
        let mut tok_p = tok.to_vec();
        let mut pos_p = pos.to_vec();
        tok_p.resize(b, tok[0]);
        pos_p.resize(b, pos[0]);
        let mut inputs: Vec<HostTensor> = params.to_vec();
        inputs.push(k);
        inputs.push(v);
        inputs.push(HostTensor::from_i32(&[b], &tok_p));
        inputs.push(HostTensor::from_i32(&[b], &pos_p));
        let (out, timing) = self.execute(&inputs)?;
        if out.len() < 3 {
            bail!("decode_step: executable returned {} outputs, need logits+k+v", out.len());
        }
        view.scatter(&out[1], &out[2])?;
        Ok((out[0].to_f32_vec(), timing))
    }
}

/// Synthesized golden vectors: run the module on `inputs`, expect
/// `outputs` (the native backend derives these from `attn::exec::reference`).
pub struct GoldenCase {
    pub inputs: Vec<HostTensor>,
    pub outputs: Vec<HostTensor>,
}

/// A pluggable execution backend behind `runtime::Runtime`.
pub trait Backend {
    fn platform_name(&self) -> String;

    /// Load (compile) one artifact into an executable module.
    fn load(&self, spec: &ArtifactSpec) -> Result<Box<dyn Module>>;

    /// Whether this backend can synthesize golden vectors for `spec`
    /// (file-based goldens still work when this is false).
    fn provides_golden(&self, spec: &ArtifactSpec) -> bool {
        let _ = spec;
        false
    }

    /// Synthesize the golden case for `spec`, or `None` to fall back to
    /// golden files on disk.
    fn golden(&self, spec: &ArtifactSpec) -> Result<Option<GoldenCase>> {
        let _ = spec;
        Ok(None)
    }
}

/// Construct the backend for `kind`.
pub fn make(kind: BackendKind) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Auto => auto_backend(),
        BackendKind::Native => Ok(Box::new(crate::runtime::native::NativeBackend::new())),
        BackendKind::Pjrt => pjrt_backend(),
        BackendKind::Stub => Ok(Box::new(stub::StubBackend)),
    }
}

#[cfg(feature = "xla")]
fn auto_backend() -> Result<Box<dyn Backend>> {
    pjrt_backend()
}

#[cfg(not(feature = "xla"))]
fn auto_backend() -> Result<Box<dyn Backend>> {
    Ok(Box::new(stub::StubBackend))
}

#[cfg(feature = "xla")]
fn pjrt_backend() -> Result<Box<dyn Backend>> {
    Ok(Box::new(pjrt::PjrtBackend::cpu()?))
}

#[cfg(not(feature = "xla"))]
fn pjrt_backend() -> Result<Box<dyn Backend>> {
    Err(crate::util::error::Error::msg(
        "this build has no PJRT backend (enable the `xla` feature and add the \
         xla bindings as a path dependency in rust/Cargo.toml); `--backend \
         native` runs the in-tree CPU engine instead",
    ))
}

#[cfg(feature = "xla")]
mod pjrt {
    use std::time::Instant;

    use super::ExecTiming;
    use crate::bail;
    use crate::runtime::artifact::ArtifactSpec;
    use crate::util::error::{Context, Error, Result};
    use crate::util::tensorio::{DType, HostTensor};

    fn element_type(dt: DType) -> xla::ElementType {
        match dt {
            DType::F32 => xla::ElementType::F32,
            DType::I32 => xla::ElementType::S32,
            DType::U32 => xla::ElementType::U32,
            DType::F64 => xla::ElementType::F64,
            DType::I64 => xla::ElementType::S64,
        }
    }

    fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
        xla::Literal::create_from_shape_and_untyped_data(
            element_type(t.dtype),
            &t.dims,
            &t.data,
        )
        .map_err(|e| Error::msg(format!("literal create failed: {e:?}")))
    }

    fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit
            .array_shape()
            .map_err(|e| Error::msg(format!("literal shape: {e:?}")))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let dtype = match shape.primitive_type() {
            xla::PrimitiveType::F32 => DType::F32,
            xla::PrimitiveType::S32 => DType::I32,
            xla::PrimitiveType::U32 => DType::U32,
            xla::PrimitiveType::F64 => DType::F64,
            xla::PrimitiveType::S64 => DType::I64,
            other => bail!("unsupported output primitive type {other:?}"),
        };
        let n = lit.element_count();
        let data;
        // Bulk path: one copy_raw_to into a typed buffer, then a single
        // memcpy reinterpreting to bytes (host is little-endian, matching
        // FAT1).  (Perf: the original per-element to_le_bytes loop was ~40%
        // of transfer time on large outputs.)
        macro_rules! copy_as {
            ($t:ty) => {{
                let mut buf = vec![<$t>::default(); n];
                lit.copy_raw_to::<$t>(&mut buf)
                    .map_err(|e| Error::msg(format!("copy_raw_to: {e:?}")))?;
                // SAFETY: buf is a live, initialized slice of plain-old-data
                // numeric values; reinterpreting as bytes is always valid.
                let bytes = unsafe {
                    std::slice::from_raw_parts(
                        buf.as_ptr() as *const u8,
                        n * std::mem::size_of::<$t>(),
                    )
                };
                data = bytes.to_vec();
            }};
        }
        match dtype {
            DType::F32 => copy_as!(f32),
            DType::I32 => copy_as!(i32),
            DType::U32 => copy_as!(u32),
            DType::F64 => copy_as!(f64),
            DType::I64 => copy_as!(i64),
        }
        Ok(HostTensor { dtype, dims, data })
    }

    /// The PJRT CPU client.
    pub struct PjrtBackend {
        inner: xla::PjRtClient,
    }

    impl PjrtBackend {
        pub fn cpu() -> Result<PjrtBackend> {
            let inner = xla::PjRtClient::cpu()
                .map_err(|e| Error::msg(format!("PjRtClient::cpu: {e:?}")))?;
            Ok(PjrtBackend { inner })
        }
    }

    impl super::Backend for PjrtBackend {
        fn platform_name(&self) -> String {
            self.inner.platform_name()
        }

        /// Parse + compile an HLO *text* module (text, not serialized proto:
        /// xla_extension 0.5.1 rejects jax>=0.5's 64-bit instruction ids;
        /// the text parser reassigns them).
        fn load(&self, spec: &ArtifactSpec) -> Result<Box<dyn super::Module>> {
            let name = spec.name.as_str();
            let proto = xla::HloModuleProto::from_text_file(
                spec.hlo_path.to_str().context("non-utf8 artifact path")?,
            )
            .map_err(|e| Error::msg(format!("{name}: parse hlo: {e:?}")))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .inner
                .compile(&comp)
                .map_err(|e| Error::msg(format!("{name}: compile: {e:?}")))?;
            Ok(Box::new(LoadedModule { exe, name: name.to_string() }))
        }
    }

    /// A compiled HLO module ready to run.
    struct LoadedModule {
        exe: xla::PjRtLoadedExecutable,
        name: String,
    }

    impl super::Module for LoadedModule {
        fn execute(&self, inputs: &[HostTensor]) -> Result<(Vec<HostTensor>, ExecTiming)> {
            let t0 = Instant::now();
            let literals = inputs
                .iter()
                .map(to_literal)
                .collect::<Result<Vec<_>>>()?;
            let transfer_in = t0.elapsed().as_secs_f64();

            let t1 = Instant::now();
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| Error::msg(format!("{}: execute: {e:?}", self.name)))?;
            let exec_secs = t1.elapsed().as_secs_f64();

            let t2 = Instant::now();
            let buffer = &result[0][0];
            let lit = buffer
                .to_literal_sync()
                .map_err(|e| Error::msg(format!("to_literal_sync: {e:?}")))?;
            // aot.py lowers with return_tuple=True: the single output is a
            // tuple.
            let parts = lit
                .to_tuple()
                .map_err(|e| Error::msg(format!("to_tuple: {e:?}")))?;
            let outputs = parts
                .iter()
                .map(from_literal)
                .collect::<Result<Vec<_>>>()?;
            let transfer_secs = transfer_in + t2.elapsed().as_secs_f64();
            Ok((outputs, ExecTiming { exec_secs, transfer_secs }))
        }
    }
}

mod stub {
    use crate::runtime::artifact::ArtifactSpec;
    use crate::util::error::{Error, Result};

    const HINT: &str =
        "this build has no compiled-artifact execution backend (enable the \
         `xla` feature, or run with `--backend native` for the in-tree CPU \
         engine)";

    /// No-op stand-in so the crate builds and inspects manifests fully
    /// offline; loading any artifact reports a clear error.
    pub struct StubBackend;

    impl super::Backend for StubBackend {
        fn platform_name(&self) -> String {
            "stub (no execution backend)".to_string()
        }

        fn load(&self, spec: &ArtifactSpec) -> Result<Box<dyn super::Module>> {
            Err(Error::msg(format!(
                "{}: cannot compile HLO artifact: {HINT}",
                spec.name
            )))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses_flags() {
        assert_eq!(BackendKind::from_flag("auto").unwrap(), BackendKind::Auto);
        assert_eq!(BackendKind::from_flag("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::from_flag("xla").unwrap(), BackendKind::Pjrt);
        assert_eq!(BackendKind::from_flag("pjrt").unwrap(), BackendKind::Pjrt);
        assert_eq!(BackendKind::from_flag("stub").unwrap(), BackendKind::Stub);
        assert!(BackendKind::from_flag("gpu").is_err());
    }

    #[test]
    fn stub_backend_errors_on_load_not_panic() {
        let b = make(BackendKind::Stub).unwrap();
        assert!(b.platform_name().contains("stub"));
        let spec = ArtifactSpec {
            name: "toy".into(),
            kind: crate::runtime::artifact::ArtifactKind::Other,
            hlo_path: "nonexistent.hlo.txt".into(),
            golden_path: None,
            inputs: vec![],
            outputs: vec![],
            meta: crate::util::json::Json::Obj(vec![]),
        };
        let err = b.load(&spec).unwrap_err();
        assert!(format!("{err}").contains("toy"));
        assert!(!b.provides_golden(&spec));
        assert!(b.golden(&spec).unwrap().is_none());
    }
}
