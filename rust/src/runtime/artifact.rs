//! Artifact manifest model: the typed view of `artifacts/manifest.json`
//! produced by `python/compile/aot.py`.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use crate::bail;
use crate::util::error::{Context, Result};

use crate::util::json::Json;
use crate::util::tensorio::DType;

/// Shape + dtype of one executable input/output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dims: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn byte_size(&self) -> usize {
        self.element_count() * self.dtype.size()
    }

    fn from_json(j: &Json) -> Result<Self> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .context("spec missing name")?
            .to_string();
        let dtype_s = j
            .get("dtype")
            .and_then(Json::as_str)
            .context("spec missing dtype")?;
        let dtype = DType::from_name(dtype_s)
            .with_context(|| format!("unknown dtype {dtype_s}"))?;
        let dims = j
            .get("shape")
            .and_then(Json::as_arr)
            .context("spec missing shape")?
            .iter()
            .map(|d| d.as_i64().map(|v| v as usize).context("bad dim"))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec { name, dims, dtype })
    }
}

/// What kind of executable an artifact is (drives which subsystem uses it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    AttnFwd,
    AttnGrad,
    Init,
    TrainStep,
    Prefill,
    Decode,
    Other,
}

impl ArtifactKind {
    fn from_str(s: &str) -> Self {
        match s {
            "attn_fwd" => Self::AttnFwd,
            "attn_grad" => Self::AttnGrad,
            "init" => Self::Init,
            "train_step" => Self::TrainStep,
            "prefill" => Self::Prefill,
            "decode" => Self::Decode,
            _ => Self::Other,
        }
    }
}

/// One manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub kind: ArtifactKind,
    pub hlo_path: PathBuf,
    pub golden_path: Option<PathBuf>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: Json,
}

impl ArtifactSpec {
    /// Integer metadata accessor (`meta.seqlen`, `meta.batch`, ...).
    pub fn meta_i64(&self, key: &str) -> Option<i64> {
        self.meta.get(key).and_then(Json::as_i64)
    }

    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).and_then(Json::as_str)
    }

    pub fn meta_bool(&self, key: &str) -> Option<bool> {
        self.meta.get(key).and_then(Json::as_bool)
    }
}

/// The whole manifest.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`?)", path.display()))?;
        let json = Json::parse(&text).context("parsing manifest.json")?;
        let version = json.get("version").and_then(Json::as_i64).unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let mut artifacts = BTreeMap::new();
        for entry in json
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest missing artifacts")?
        {
            let name = entry
                .get("name")
                .and_then(Json::as_str)
                .context("artifact missing name")?
                .to_string();
            let hlo = entry
                .get("hlo")
                .and_then(Json::as_str)
                .context("artifact missing hlo")?;
            let golden_path = entry
                .get("golden")
                .and_then(Json::as_str)
                .map(|g| dir.join(g));
            let inputs = entry
                .get("inputs")
                .and_then(Json::as_arr)
                .context("artifact missing inputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = entry
                .get("outputs")
                .and_then(Json::as_arr)
                .context("artifact missing outputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let kind = ArtifactKind::from_str(
                entry.get("kind").and_then(Json::as_str).unwrap_or(""),
            );
            let meta = entry.get("meta").cloned().unwrap_or(Json::Obj(vec![]));
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name,
                    kind,
                    hlo_path: dir.join(hlo),
                    golden_path,
                    inputs,
                    outputs,
                    meta,
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }

    pub fn by_kind(&self, kind: ArtifactKind) -> Vec<&ArtifactSpec> {
        self.artifacts.values().filter(|a| a.kind == kind).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        fs::create_dir_all(dir).unwrap();
        fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parse_minimal_manifest() {
        let dir = std::env::temp_dir().join("fa2_manifest_test");
        write_manifest(
            &dir,
            r#"{"version": 1, "artifacts": [
                {"name": "a", "kind": "attn_fwd", "hlo": "a.hlo.txt",
                 "golden": "a.golden.fat1",
                 "inputs": [{"name": "q", "shape": [1, 2, 64, 32], "dtype": "f32"}],
                 "outputs": [{"name": "out0", "shape": [1, 2, 64, 32], "dtype": "f32"}],
                 "meta": {"seqlen": 64, "causal": true, "impl": "fa2"}}
            ]}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        let a = m.get("a").unwrap();
        assert_eq!(a.kind, ArtifactKind::AttnFwd);
        assert_eq!(a.inputs[0].dims, vec![1, 2, 64, 32]);
        assert_eq!(a.inputs[0].byte_size(), 1 * 2 * 64 * 32 * 4);
        assert_eq!(a.meta_i64("seqlen"), Some(64));
        assert_eq!(a.meta_bool("causal"), Some(true));
        assert_eq!(m.by_kind(ArtifactKind::AttnFwd).len(), 1);
        assert!(m.get("missing").is_err());
    }

    #[test]
    fn rejects_bad_version() {
        let dir = std::env::temp_dir().join("fa2_manifest_test_v2");
        write_manifest(&dir, r#"{"version": 9, "artifacts": []}"#);
        assert!(Manifest::load(&dir).is_err());
    }
}
