//! The `native` execution backend: a fully in-tree CPU implementation of
//! the serving artifact set, with attention computed by `attn::exec`.
//!
//! Where `pjrt` compiles AOT HLO artifacts, this backend *synthesizes* its
//! manifest ([`synth_manifest`]) and implements each artifact as Rust:
//!
//! - `tiny_init` — seeded parameter initialization for a tiny GPT
//!   (tied-embedding, RMS-norm, GELU MLP; heads sized for `attn::exec`).
//!   The model is **GQA-configurable**: [`GptConfig::n_kv_head`] may be
//!   any divisor of `n_head` (MQA at 1), and [`GptConfig::window`] turns
//!   every layer into sliding-window attention — both flow into the
//!   kernels as one [`AttnSpec`], never as special-cased entry points.
//! - `tiny_prefill_b1` — full prompt forward; attention runs through
//!   `attn::exec::parallel::forward_spec` (Algorithm 1 on the pool) under
//!   the model's head map + mask, with tiles chosen by `attn::autotune`
//!   (the exec engine and the cost model agree on tiling), and the
//!   per-layer K/V land in the serving cache layout.
//! - `tiny_decode_b1` / `tiny_decode_b4` — one-token steps over the KV
//!   cache via the split-KV decode path
//!   (`parallel::decode_splitkv_spec`, the flash-decoding reduction
//!   through `attn::combine`), reading either the legacy batch cache
//!   tensor or — on the serving hot path — the paged arena **in place**
//!   through the same [`KvLayout`] seam, with identical chunk boundaries
//!   so the two are bit-identical.
//! - `native_attn_*` — bare attention kernels (equal-head, GQA, MQA and
//!   sliding-window variants) whose golden vectors are synthesized from
//!   `attn::exec::reference`, so `repro verify --backend native` checks
//!   flash-vs-reference parity on every spec axis end to end through the
//!   runtime with no files on disk.
//!
//! Input/output specs match what the engine already exchanges with the
//! AOT artifacts, so the serving path is backend-agnostic.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use crate::bail;
use crate::util::error::Result;

use crate::attn::exec::{parallel, reference, FlashParams};
use crate::attn::spec::{AttnSpec, HeadMap, KvLayout, Mask};
use crate::attn::Pass;
use crate::runtime::artifact::{ArtifactKind, ArtifactSpec, Manifest, TensorSpec};
use crate::runtime::backend::{Backend, ExecTiming, GoldenCase, Module};
use crate::runtime::kv::{KvBatchView, PagedKvMut, DEFAULT_KV_BLOCK};
use crate::runtime::RuntimeOptions;
use crate::util::json::Json;
use crate::util::pool;
use crate::util::rng::Rng;
use crate::util::tensorio::{DType, HostTensor};

/// KV rows per split-KV chunk when decoding over the legacy batch cache
/// tensor.  MUST equal [`DEFAULT_KV_BLOCK`]: the paged path chunks at
/// block boundaries, and equal chunk boundaries are what make paged and
/// batch-tensor decode bit-identical.
const DECODE_CHUNK: usize = DEFAULT_KV_BLOCK;

/// Shape of the tiny native serving model.
#[derive(Debug, Clone, Copy)]
pub struct GptConfig {
    pub n_layer: usize,
    pub n_head: usize,
    /// KV heads (GQA when < `n_head`, MQA at 1; must divide `n_head`).
    pub n_kv_head: usize,
    pub d_model: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub prompt_len: usize,
    /// Sliding attention window (None = full causal).
    pub window: Option<usize>,
}

impl GptConfig {
    pub fn tiny() -> GptConfig {
        GptConfig {
            n_layer: 2,
            n_head: 4,
            n_kv_head: 4,
            d_model: 64,
            vocab: 512,
            max_seq: 128,
            prompt_len: 16,
            window: None,
        }
    }

    /// The tiny model with the runtime's GQA/window overrides applied.
    pub fn tiny_with(opts: RuntimeOptions) -> Result<GptConfig> {
        let mut cfg = GptConfig::tiny();
        if let Some(kv) = opts.n_kv_heads {
            cfg.n_kv_head = kv;
        }
        cfg.window = opts.window;
        cfg.heads().validate()?;
        cfg.mask().validate()?;
        Ok(cfg)
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.n_head
    }

    /// The model's head map (grouped-query broadcast).
    pub fn heads(&self) -> HeadMap {
        HeadMap { n_q_heads: self.n_head, n_kv_heads: self.n_kv_head }
    }

    /// The model's mask: sliding window when configured, else causal.
    pub fn mask(&self) -> Mask {
        match self.window {
            Some(w) => Mask::SlidingWindow(w),
            None => Mask::Causal,
        }
    }

    /// Columns of the fused QKV projection: d (Q) + 2 · n_kv_head · dh.
    fn qkv_cols(&self) -> usize {
        self.d_model + 2 * self.n_kv_head * self.d_head()
    }

    fn n_params(&self) -> usize {
        2 + 4 * self.n_layer
    }

    /// Serving cache dims (L, B, H_kv, S, dh) — the layout the compat
    /// path assembles and scatters.
    fn cache_dims(&self, batch: usize) -> Vec<usize> {
        vec![self.n_layer, batch, self.n_kv_head, self.max_seq, self.d_head()]
    }

    /// Flat offset of cache row (l, b, h, s) under batch size `batch`.
    fn cache_offset(&self, batch: usize, l: usize, b: usize, h: usize, s: usize) -> usize {
        (((l * batch + b) * self.n_kv_head + h) * self.max_seq + s) * self.d_head()
    }
}

/// Flat parameter list: wte, wpe, then per layer (wqkv, wo, wmlp1, wmlp2).
fn param_specs(cfg: &GptConfig) -> Vec<TensorSpec> {
    let d = cfg.d_model;
    let f32_spec = |name: String, dims: Vec<usize>| TensorSpec { name, dims, dtype: DType::F32 };
    let mut specs = vec![
        f32_spec("wte".into(), vec![cfg.vocab, d]),
        f32_spec("wpe".into(), vec![cfg.max_seq, d]),
    ];
    for l in 0..cfg.n_layer {
        specs.push(f32_spec(format!("l{l}_wqkv"), vec![d, cfg.qkv_cols()]));
        specs.push(f32_spec(format!("l{l}_wo"), vec![d, d]));
        specs.push(f32_spec(format!("l{l}_wmlp1"), vec![d, 4 * d]));
        specs.push(f32_spec(format!("l{l}_wmlp2"), vec![4 * d, d]));
    }
    specs
}

// ---------------------------------------------------------------------------
// small dense math (f32, row-major)

/// y[m,n] = x[m,k] @ w[k,n]
fn matmul(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    let mut y = vec![0.0f32; m * n];
    for i in 0..m {
        let xr = &x[i * k..(i + 1) * k];
        let yr = &mut y[i * n..(i + 1) * n];
        for (t, &xv) in xr.iter().enumerate() {
            let wr = &w[t * n..(t + 1) * n];
            for (yv, &wv) in yr.iter_mut().zip(wr) {
                *yv += xv * wv;
            }
        }
    }
    y
}

/// Parameter-free RMS norm applied row-wise.
fn rmsnorm(x: &[f32], d: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; x.len()];
    for (yr, xr) in y.chunks_mut(d).zip(x.chunks(d)) {
        let ms: f32 = xr.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + 1e-6).sqrt();
        for (yv, &xv) in yr.iter_mut().zip(xr) {
            *yv = xv * inv;
        }
    }
    y
}

fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (0.797_884_6 * (x + 0.044_715 * x * x * x)).tanh())
}

fn add_inplace(x: &mut [f32], y: &[f32]) {
    for (a, &b) in x.iter_mut().zip(y) {
        *a += b;
    }
}

fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}

struct Params {
    tensors: Vec<Vec<f32>>,
}

impl Params {
    fn parse(cfg: &GptConfig, inputs: &[HostTensor]) -> Params {
        Params {
            tensors: inputs[..cfg.n_params()].iter().map(|t| t.to_f32_vec()).collect(),
        }
    }

    fn wte(&self) -> &[f32] {
        &self.tensors[0]
    }

    fn wpe(&self) -> &[f32] {
        &self.tensors[1]
    }

    fn wqkv(&self, l: usize) -> &[f32] {
        &self.tensors[2 + 4 * l]
    }

    fn wo(&self, l: usize) -> &[f32] {
        &self.tensors[3 + 4 * l]
    }

    fn wmlp1(&self, l: usize) -> &[f32] {
        &self.tensors[4 + 4 * l]
    }

    fn wmlp2(&self, l: usize) -> &[f32] {
        &self.tensors[5 + 4 * l]
    }
}

/// Pre-norm GELU MLP with residual, applied to all `rows` of `x`.
fn layer_ffn(cfg: &GptConfig, params: &Params, l: usize, x: &mut [f32], rows: usize) {
    let d = cfg.d_model;
    let xn = rmsnorm(x, d);
    let mut h = matmul(&xn, params.wmlp1(l), rows, d, 4 * d);
    for v in h.iter_mut() {
        *v = gelu(*v);
    }
    let y = matmul(&h, params.wmlp2(l), rows, 4 * d, d);
    add_inplace(x, &y);
}

/// Logits for one d_model row against the tied embedding.
fn lm_head(cfg: &GptConfig, params: &Params, xrow: &[f32]) -> Vec<f32> {
    let d = cfg.d_model;
    let xn = rmsnorm(xrow, d);
    let wte = params.wte();
    (0..cfg.vocab).map(|t| dot(&xn, &wte[t * d..(t + 1) * d])).collect()
}

fn embed(cfg: &GptConfig, params: &Params, tok: usize, pos: usize) -> Vec<f32> {
    let d = cfg.d_model;
    let mut x = vec![0.0f32; d];
    let (wte, wpe) = (params.wte(), params.wpe());
    for c in 0..d {
        x[c] = wte[tok * d + c] + wpe[pos * d + c];
    }
    x
}

fn check_token(cfg: &GptConfig, t: i32) -> Result<usize> {
    if t < 0 || t as usize >= cfg.vocab {
        bail!("token {t} out of vocab range 0..{}", cfg.vocab);
    }
    Ok(t as usize)
}

// ---------------------------------------------------------------------------
// modules

struct InitModule {
    cfg: GptConfig,
}

impl Module for InitModule {
    fn execute(&self, inputs: &[HostTensor]) -> Result<(Vec<HostTensor>, ExecTiming)> {
        let t0 = Instant::now();
        let seed_bytes = inputs
            .first()
            .and_then(|t| t.data.get(..4))
            .and_then(|b| <[u8; 4]>::try_from(b).ok());
        let Some(seed_bytes) = seed_bytes else {
            bail!("init module expects a 4-byte scalar seed tensor as input 0");
        };
        let seed = u32::from_le_bytes(seed_bytes);
        let mut rng = Rng::seed_from(0xFA2_0002 ^ seed as u64);
        let outputs = param_specs(&self.cfg)
            .iter()
            .map(|spec| {
                let vals: Vec<f32> = (0..spec.element_count())
                    .map(|_| (rng.normal() * 0.02) as f32)
                    .collect();
                HostTensor::from_f32(&spec.dims, &vals)
            })
            .collect();
        Ok((outputs, ExecTiming { exec_secs: t0.elapsed().as_secs_f64(), transfer_secs: 0.0 }))
    }
}

struct PrefillModule {
    cfg: GptConfig,
    /// Tile sizes from `attn::autotune` for the prompt-sized problem.
    tile: FlashParams,
}

impl Module for PrefillModule {
    fn execute(&self, inputs: &[HostTensor]) -> Result<(Vec<HostTensor>, ExecTiming)> {
        let t0 = Instant::now();
        let cfg = &self.cfg;
        let params = Params::parse(cfg, inputs);
        let tokens = inputs[cfg.n_params()].to_i32_vec();
        let (d, dh, hn, kvn, p_len) =
            (cfg.d_model, cfg.d_head(), cfg.n_head, cfg.n_kv_head, cfg.prompt_len);

        // embed the prompt
        let mut x = vec![0.0f32; p_len * d];
        for (i, &t) in tokens.iter().enumerate() {
            let tok = check_token(cfg, t)?;
            x[i * d..(i + 1) * d].copy_from_slice(&embed(cfg, &params, tok, i));
        }

        let cache_len: usize = cfg.cache_dims(1).iter().product();
        let mut kc = vec![0.0f32; cache_len];
        let mut vc = vec![0.0f32; cache_len];
        let spec = AttnSpec {
            batch: 1,
            heads: cfg.heads(),
            seq: p_len,
            head_dim: dh,
            mask: cfg.mask(),
        };
        let qd = spec.q_dims();
        let kd = spec.kv_dims();

        for l in 0..cfg.n_layer {
            let xn = rmsnorm(&x, d);
            let qkv = matmul(&xn, params.wqkv(l), p_len, d, cfg.qkv_cols());
            // repack (row, qkv_cols) into (1, Hq, P, dh) Q and
            // (1, Hkv, P, dh) K/V tensors
            let mut qb = vec![0.0f32; spec.q_elems()];
            let mut kb = vec![0.0f32; spec.kv_elems()];
            let mut vb = vec![0.0f32; spec.kv_elems()];
            for i in 0..p_len {
                let src = i * cfg.qkv_cols();
                for h in 0..hn {
                    let ro = qd.row_offset(0, h, i);
                    qb[ro..ro + dh].copy_from_slice(&qkv[src + h * dh..src + (h + 1) * dh]);
                }
                for g in 0..kvn {
                    let ro = kd.row_offset(0, g, i);
                    let ks = src + d + g * dh;
                    let vs = src + d + kvn * dh + g * dh;
                    kb[ro..ro + dh].copy_from_slice(&qkv[ks..ks + dh]);
                    vb[ro..ro + dh].copy_from_slice(&qkv[vs..vs + dh]);
                }
            }
            // Algorithm 1 on the pool (prompt rows fan as Q-blocks),
            // tiles from the autotuner
            let out = parallel::forward_spec(&qb, &kb, &vb, spec, self.tile);
            // K/V into the serving cache layout (l, 0, g, s, ·)
            for g in 0..kvn {
                for s in 0..p_len {
                    let dst = cfg.cache_offset(1, l, 0, g, s);
                    let src = kd.row_offset(0, g, s);
                    kc[dst..dst + dh].copy_from_slice(&kb[src..src + dh]);
                    vc[dst..dst + dh].copy_from_slice(&vb[src..src + dh]);
                }
            }
            // concat heads, project, residual, MLP
            let mut y = vec![0.0f32; p_len * d];
            for i in 0..p_len {
                for h in 0..hn {
                    let src = qd.row_offset(0, h, i);
                    y[i * d + h * dh..i * d + (h + 1) * dh]
                        .copy_from_slice(&out.o[src..src + dh]);
                }
            }
            let proj = matmul(&y, params.wo(l), p_len, d, d);
            add_inplace(&mut x, &proj);
            layer_ffn(cfg, &params, l, &mut x, p_len);
        }

        let logits = lm_head(cfg, &params, &x[(p_len - 1) * d..p_len * d]);
        let outputs = vec![
            HostTensor::from_f32(&[1, cfg.vocab], &logits),
            HostTensor::from_f32(&cfg.cache_dims(1), &kc),
            HostTensor::from_f32(&cfg.cache_dims(1), &vc),
        ];
        Ok((outputs, ExecTiming { exec_secs: t0.elapsed().as_secs_f64(), transfer_secs: 0.0 }))
    }
}

struct DecodeModule {
    cfg: GptConfig,
    batch: usize,
}

/// One sequence's K/V cache behind the decode kernel: write the new row
/// in place, then hand attention a [`KvLayout`] over any (layer, kv-head)
/// plane.  Implemented over the legacy (L, B, H, S, dh) batch tensor
/// *and* over a paged arena sequence so [`decode_row`] is the single
/// decode kernel for both paths; both chunk split-KV at the same
/// boundaries, which is what keeps the in-place paged path byte-identical
/// to the batch-tensor path.
trait CacheRows {
    fn write(&mut self, l: usize, h: usize, pos: usize, krow: &[f32], vrow: &[f32]);
    fn layout(&self, l: usize, h: usize) -> KvLayout<'_>;
    /// Split-KV chunk size (token rows) — equal across impls for
    /// bit-identical decode.
    fn chunk_tokens(&self) -> usize;
}

/// Row `b` of a (L, B, H, S, dh) batch cache tensor pair.
struct BatchRows<'a> {
    cfg: &'a GptConfig,
    batch: usize,
    b: usize,
    kc: &'a mut [f32],
    vc: &'a mut [f32],
}

impl CacheRows for BatchRows<'_> {
    fn write(&mut self, l: usize, h: usize, pos: usize, krow: &[f32], vrow: &[f32]) {
        let dh = self.cfg.d_head();
        let at = self.cfg.cache_offset(self.batch, l, self.b, h, pos);
        self.kc[at..at + dh].copy_from_slice(krow);
        self.vc[at..at + dh].copy_from_slice(vrow);
    }

    fn layout(&self, l: usize, h: usize) -> KvLayout<'_> {
        let sdh = self.cfg.max_seq * self.cfg.d_head();
        let off = self.cfg.cache_offset(self.batch, l, self.b, h, 0);
        KvLayout::Contiguous { k: &self.kc[off..off + sdh], v: &self.vc[off..off + sdh] }
    }

    fn chunk_tokens(&self) -> usize {
        DECODE_CHUNK
    }
}

/// One paged arena sequence (the serving hot path).
struct PagedRows<'a> {
    inner: PagedKvMut<'a>,
}

impl CacheRows for PagedRows<'_> {
    fn write(&mut self, l: usize, h: usize, pos: usize, krow: &[f32], vrow: &[f32]) {
        self.inner.write_row(l, h, pos, krow, vrow);
    }

    fn layout(&self, l: usize, h: usize) -> KvLayout<'_> {
        self.inner.layout(l, h)
    }

    fn chunk_tokens(&self) -> usize {
        self.inner.geo.block_tokens
    }
}

/// One-token forward for one sequence, reading and extending its cache.
fn decode_row(
    cfg: &GptConfig,
    params: &Params,
    tok: i32,
    pos: usize,
    cache: &mut dyn CacheRows,
) -> Result<Vec<f32>> {
    let (d, dh, hn, kvn) = (cfg.d_model, cfg.d_head(), cfg.n_head, cfg.n_kv_head);
    if pos >= cfg.max_seq {
        bail!("decode position {pos} exceeds max_seq {}", cfg.max_seq);
    }
    let tok = check_token(cfg, tok)?;
    let scale = 1.0 / (dh as f32).sqrt();
    let group = hn / kvn;
    // the history rows this token attends to: causal up to pos, clipped
    // to the sliding window — out-of-window blocks are never read
    let hi = pos + 1;
    let lo = match cfg.window {
        Some(w) => hi.saturating_sub(w),
        None => 0,
    };
    let mut x = embed(cfg, params, tok, pos);
    for l in 0..cfg.n_layer {
        let xn = rmsnorm(&x, d);
        let qkv = matmul(&xn, params.wqkv(l), 1, d, cfg.qkv_cols());
        // append this token's K/V per KV head, then split-KV attention
        // per query head over its group's plane (each plane is written
        // before any head reads it, so the order matches the old
        // write-then-attend loop bit for bit)
        for g in 0..kvn {
            let ks = d + g * dh;
            let vs = d + kvn * dh + g * dh;
            cache.write(l, g, pos, &qkv[ks..ks + dh], &qkv[vs..vs + dh]);
        }
        let mut y = vec![0.0f32; d];
        let chunk = cache.chunk_tokens();
        for h in 0..hn {
            let lay = cache.layout(l, h / group);
            let qh = &qkv[h * dh..(h + 1) * dh];
            let (oh, _lse) = parallel::decode_splitkv_spec(qh, &lay, lo, hi, scale, chunk);
            y[h * dh..(h + 1) * dh].copy_from_slice(&oh);
        }
        let proj = matmul(&y, params.wo(l), 1, d, d);
        add_inplace(&mut x, &proj);
        layer_ffn(cfg, params, l, &mut x, 1);
    }
    // attention FLOPs of this token: 2 matmuls (QKᵀ, PV) of ctx×dh per
    // query head per layer — one relaxed add per decoded token-row
    crate::obs_count!("decode_flops_total", 4 * (hi - lo) * dh * hn * cfg.n_layer);
    Ok(lm_head(cfg, params, &x))
}

impl Module for DecodeModule {
    fn execute(&self, inputs: &[HostTensor]) -> Result<(Vec<HostTensor>, ExecTiming)> {
        let t0 = Instant::now();
        let cfg = &self.cfg;
        let np = cfg.n_params();
        let params = Params::parse(cfg, inputs);
        let mut kc = inputs[np].to_f32_vec();
        let mut vc = inputs[np + 1].to_f32_vec();
        let tok = inputs[np + 2].to_i32_vec();
        let pos = inputs[np + 3].to_i32_vec();

        let mut logits = vec![0.0f32; self.batch * cfg.vocab];
        for b in 0..self.batch {
            if pos[b] < 0 {
                bail!("negative decode position {}", pos[b]);
            }
            let mut rows =
                BatchRows { cfg, batch: self.batch, b, kc: &mut kc, vc: &mut vc };
            let row = decode_row(cfg, &params, tok[b], pos[b] as usize, &mut rows)?;
            logits[b * cfg.vocab..(b + 1) * cfg.vocab].copy_from_slice(&row);
        }
        let outputs = vec![
            HostTensor::from_f32(&[self.batch, cfg.vocab], &logits),
            HostTensor::from_f32(&cfg.cache_dims(self.batch), &kc),
            HostTensor::from_f32(&cfg.cache_dims(self.batch), &vc),
        ];
        Ok((outputs, ExecTiming { exec_secs: t0.elapsed().as_secs_f64(), transfer_secs: 0.0 }))
    }

    /// Serving hot path: decode every real row **in place** on its paged
    /// KV-arena sequence — no batch-tensor assemble, no scatter, zero
    /// bytes through the arena's `CopyStats`.  Padding rows simply do not
    /// exist here, so bucket padding costs nothing either.
    fn decode_step(
        &self,
        params_t: &[HostTensor],
        view: &mut KvBatchView<'_>,
        tok: &[i32],
        pos: &[i32],
    ) -> Result<(Vec<f32>, ExecTiming)> {
        let _sp = crate::obs_span!("attn_decode_step");
        let t0 = Instant::now();
        let cfg = &self.cfg;
        if params_t.len() < cfg.n_params() {
            bail!(
                "native decode_step: got {} params, model wants {}",
                params_t.len(),
                cfg.n_params()
            );
        }
        let geo = view.geometry();
        if geo.n_layer != cfg.n_layer
            || geo.n_kv_head != cfg.n_kv_head
            || geo.max_seq != cfg.max_seq
            || geo.d_head != cfg.d_head()
        {
            bail!(
                "native decode_step: arena geometry {geo:?} does not match model \
                 cache dims {:?}",
                cfg.cache_dims(1)
            );
        }
        let params = Params::parse(cfg, params_t);
        let mut logits = vec![0.0f32; view.rows() * cfg.vocab];
        for bi in 0..view.rows() {
            if pos[bi] < 0 {
                bail!("negative decode position {}", pos[bi]);
            }
            let paged = view.paged(bi);
            if pos[bi] as usize >= paged.reserved_tokens() {
                bail!(
                    "native decode_step: position {} is beyond the sequence's \
                     block reservation of {} tokens (admission under-reserved)",
                    pos[bi],
                    paged.reserved_tokens()
                );
            }
            let mut rows = PagedRows { inner: paged };
            let row = decode_row(cfg, &params, tok[bi], pos[bi] as usize, &mut rows)?;
            logits[bi * cfg.vocab..(bi + 1) * cfg.vocab].copy_from_slice(&row);
        }
        crate::obs_count!("decode_ns_total", t0.elapsed().as_nanos());
        Ok((logits, ExecTiming { exec_secs: t0.elapsed().as_secs_f64(), transfer_secs: 0.0 }))
    }
}

/// Bare flash attention forward (q, k, v) → (o, lse).
struct AttnFwdModule {
    spec: AttnSpec,
    tile: FlashParams,
}

impl Module for AttnFwdModule {
    fn execute(&self, inputs: &[HostTensor]) -> Result<(Vec<HostTensor>, ExecTiming)> {
        let t0 = Instant::now();
        let (q, k, v) = (inputs[0].to_f32_vec(), inputs[1].to_f32_vec(), inputs[2].to_f32_vec());
        let out = parallel::forward_spec(&q, &k, &v, self.spec, self.tile);
        let s = self.spec;
        let outputs = vec![
            HostTensor::from_f32(&[s.batch, s.heads.n_q_heads, s.seq, s.head_dim], &out.o),
            HostTensor::from_f32(&[s.batch, s.heads.n_q_heads, s.seq], &out.lse),
        ];
        Ok((outputs, ExecTiming { exec_secs: t0.elapsed().as_secs_f64(), transfer_secs: 0.0 }))
    }
}

/// Bare flash attention backward (q, k, v, do) → (dq, dk, dv).
struct AttnBwdModule {
    spec: AttnSpec,
    tile: FlashParams,
}

impl Module for AttnBwdModule {
    fn execute(&self, inputs: &[HostTensor]) -> Result<(Vec<HostTensor>, ExecTiming)> {
        let t0 = Instant::now();
        let (q, k, v, dout) = (
            inputs[0].to_f32_vec(),
            inputs[1].to_f32_vec(),
            inputs[2].to_f32_vec(),
            inputs[3].to_f32_vec(),
        );
        let fwd = parallel::forward_spec(&q, &k, &v, self.spec, self.tile);
        let g = parallel::backward_spec(&q, &k, &v, &fwd, &dout, self.spec, self.tile);
        let s = self.spec;
        let qdims = [s.batch, s.heads.n_q_heads, s.seq, s.head_dim];
        let kdims = [s.batch, s.heads.n_kv_heads, s.seq, s.head_dim];
        let outputs = vec![
            HostTensor::from_f32(&qdims, &g.dq),
            HostTensor::from_f32(&kdims, &g.dk),
            HostTensor::from_f32(&kdims, &g.dv),
        ];
        Ok((outputs, ExecTiming { exec_secs: t0.elapsed().as_secs_f64(), transfer_secs: 0.0 }))
    }
}

// ---------------------------------------------------------------------------
// backend + synthesized manifest

/// The native backend: `attn::exec` CPU engine, no artifacts needed.
pub struct NativeBackend {
    cfg: GptConfig,
}

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend { cfg: GptConfig::tiny() }
    }

    /// A backend serving an explicit (GQA/window-configured) tiny model —
    /// pair it with `synth_manifest` over the same config.
    pub fn with_cfg(cfg: GptConfig) -> NativeBackend {
        NativeBackend { cfg }
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

/// Parse a bare-attention artifact's spec: Q is `inputs[0]`
/// (b, n_q, n, d), K is `inputs[1]` (b, n_kv, n, d); the mask comes from
/// `meta.window` / `meta.causal`.
fn attn_spec_from(spec: &ArtifactSpec) -> Result<AttnSpec> {
    let Some(q) = spec.inputs.first() else {
        bail!("{}: attention artifact has no inputs", spec.name);
    };
    let Some(k) = spec.inputs.get(1) else {
        bail!("{}: attention artifact has no K input", spec.name);
    };
    if q.dims.len() != 4 || k.dims.len() != 4 {
        bail!(
            "{}: expected rank-4 (b, h, n, d) q/k inputs, got {:?} / {:?}",
            spec.name,
            q.dims,
            k.dims
        );
    }
    if q.dims[0] != k.dims[0] || q.dims[2] != k.dims[2] || q.dims[3] != k.dims[3] {
        bail!("{}: q/k shapes disagree beyond heads: {:?} vs {:?}", spec.name, q.dims, k.dims);
    }
    let mask = match spec.meta_i64("window") {
        Some(w) if w > 0 => Mask::SlidingWindow(w as usize),
        _ => {
            if spec.meta_bool("causal").unwrap_or(false) {
                Mask::Causal
            } else {
                Mask::Full
            }
        }
    };
    let out = AttnSpec {
        batch: q.dims[0],
        heads: HeadMap { n_q_heads: q.dims[1], n_kv_heads: k.dims[1] },
        seq: q.dims[2],
        head_dim: q.dims[3],
        mask,
    };
    out.validate()?;
    Ok(out)
}

impl Backend for NativeBackend {
    fn platform_name(&self) -> String {
        format!("native (attn::exec cpu f32, {} pool threads)", pool::threads())
    }

    fn load(&self, spec: &ArtifactSpec) -> Result<Box<dyn Module>> {
        match spec.kind {
            ArtifactKind::Init => Ok(Box::new(InitModule { cfg: self.cfg })),
            ArtifactKind::Prefill => {
                let cfg = self.cfg;
                let dims = AttnSpec {
                    batch: 1,
                    heads: cfg.heads(),
                    seq: cfg.prompt_len,
                    head_dim: cfg.d_head(),
                    mask: cfg.mask(),
                }
                .q_dims();
                let tile = FlashParams::tuned(dims, Pass::Fwd);
                Ok(Box::new(PrefillModule { cfg, tile }))
            }
            ArtifactKind::Decode => {
                let batch = spec.meta_i64("batch").unwrap_or(1) as usize;
                Ok(Box::new(DecodeModule { cfg: self.cfg, batch }))
            }
            ArtifactKind::AttnFwd => {
                let aspec = attn_spec_from(spec)?;
                let tile = FlashParams::tuned(aspec.q_dims(), Pass::Fwd);
                Ok(Box::new(AttnFwdModule { spec: aspec, tile }))
            }
            ArtifactKind::AttnGrad => {
                let aspec = attn_spec_from(spec)?;
                let tile = FlashParams::tuned(aspec.q_dims(), Pass::FwdBwd);
                Ok(Box::new(AttnBwdModule { spec: aspec, tile }))
            }
            ArtifactKind::TrainStep | ArtifactKind::Other => bail!(
                "{}: the native backend does not implement artifact kind {:?}",
                spec.name,
                spec.kind
            ),
        }
    }

    fn provides_golden(&self, spec: &ArtifactSpec) -> bool {
        matches!(spec.kind, ArtifactKind::AttnFwd | ArtifactKind::AttnGrad)
    }

    fn golden(&self, spec: &ArtifactSpec) -> Result<Option<GoldenCase>> {
        if !self.provides_golden(spec) {
            return Ok(None);
        }
        let aspec = attn_spec_from(spec)?;
        let seed = spec.meta_i64("seed").unwrap_or(1) as u64;
        let mut rng = Rng::seed_from(seed);
        let mut draw = |n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.normal() as f32).collect()
        };
        let qdims = [aspec.batch, aspec.heads.n_q_heads, aspec.seq, aspec.head_dim];
        let kdims = [aspec.batch, aspec.heads.n_kv_heads, aspec.seq, aspec.head_dim];
        let case = match spec.kind {
            ArtifactKind::AttnFwd => {
                let q = draw(aspec.q_elems());
                let k = draw(aspec.kv_elems());
                let v = draw(aspec.kv_elems());
                let r = reference::forward_spec(&q, &k, &v, aspec);
                GoldenCase {
                    inputs: vec![
                        HostTensor::from_f32(&qdims, &q),
                        HostTensor::from_f32(&kdims, &k),
                        HostTensor::from_f32(&kdims, &v),
                    ],
                    outputs: vec![
                        HostTensor::from_f32(&qdims, &r.o),
                        HostTensor::from_f32(
                            &[aspec.batch, aspec.heads.n_q_heads, aspec.seq],
                            &r.lse,
                        ),
                    ],
                }
            }
            ArtifactKind::AttnGrad => {
                let q = draw(aspec.q_elems());
                let k = draw(aspec.kv_elems());
                let v = draw(aspec.kv_elems());
                let dout = draw(aspec.q_elems());
                let r = reference::backward_spec(&q, &k, &v, &dout, aspec);
                GoldenCase {
                    inputs: vec![
                        HostTensor::from_f32(&qdims, &q),
                        HostTensor::from_f32(&kdims, &k),
                        HostTensor::from_f32(&kdims, &v),
                        HostTensor::from_f32(&qdims, &dout),
                    ],
                    outputs: vec![
                        HostTensor::from_f32(&qdims, &r.dq),
                        HostTensor::from_f32(&kdims, &r.dk),
                        HostTensor::from_f32(&kdims, &r.dv),
                    ],
                }
            }
            other => bail!("no golden generator for artifact kind {other:?}"),
        };
        Ok(Some(case))
    }
}

fn meta_obj(pairs: &[(&str, Json)]) -> Json {
    Json::Obj(pairs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect())
}

fn num(n: usize) -> Json {
    Json::Num(n as f64)
}

/// The in-memory manifest the native backend serves for `cfg`: the tiny
/// GPT artifact set plus self-verifying attention kernels covering every
/// `AttnSpec` axis.  `dir` is only recorded for display — nothing is read
/// from disk.
pub fn synth_manifest(dir: &Path, cfg: &GptConfig) -> Manifest {
    let params = param_specs(cfg);
    let f32_spec = |name: &str, dims: Vec<usize>| TensorSpec {
        name: name.to_string(),
        dims,
        dtype: DType::F32,
    };
    let mut model_pairs = vec![
        ("model", Json::Str("tiny".into())),
        ("n_layer", num(cfg.n_layer)),
        ("n_head", num(cfg.n_head)),
        ("n_kv_head", num(cfg.n_kv_head)),
        ("d_model", num(cfg.d_model)),
        ("max_seq", num(cfg.max_seq)),
        ("vocab_size", num(cfg.vocab)),
        ("prompt_len", num(cfg.prompt_len)),
    ];
    if let Some(w) = cfg.window {
        model_pairs.push(("window", num(w)));
    }
    let model_meta = meta_obj(&model_pairs);
    let mut specs: Vec<ArtifactSpec> = Vec::new();

    specs.push(ArtifactSpec {
        name: "tiny_init".into(),
        kind: ArtifactKind::Init,
        hlo_path: dir.join("tiny_init.native"),
        golden_path: None,
        inputs: vec![TensorSpec { name: "seed".into(), dims: vec![], dtype: DType::U32 }],
        outputs: params.clone(),
        meta: model_meta.clone(),
    });

    let mut prefill_inputs = params.clone();
    prefill_inputs.push(TensorSpec {
        name: "tokens".into(),
        dims: vec![1, cfg.prompt_len],
        dtype: DType::I32,
    });
    specs.push(ArtifactSpec {
        name: "tiny_prefill_b1".into(),
        kind: ArtifactKind::Prefill,
        hlo_path: dir.join("tiny_prefill_b1.native"),
        golden_path: None,
        inputs: prefill_inputs,
        outputs: vec![
            f32_spec("logits", vec![1, cfg.vocab]),
            f32_spec("k_cache", cfg.cache_dims(1)),
            f32_spec("v_cache", cfg.cache_dims(1)),
        ],
        meta: model_meta.clone(),
    });

    for batch in [1usize, 4] {
        let mut decode_inputs = params.clone();
        decode_inputs.push(f32_spec("k_cache", cfg.cache_dims(batch)));
        decode_inputs.push(f32_spec("v_cache", cfg.cache_dims(batch)));
        decode_inputs.push(TensorSpec {
            name: "tok".into(),
            dims: vec![batch],
            dtype: DType::I32,
        });
        decode_inputs.push(TensorSpec {
            name: "pos".into(),
            dims: vec![batch],
            dtype: DType::I32,
        });
        let mut meta = model_meta.clone();
        if let Json::Obj(kvs) = &mut meta {
            kvs.push(("batch".to_string(), num(batch)));
        }
        specs.push(ArtifactSpec {
            name: format!("tiny_decode_b{batch}"),
            kind: ArtifactKind::Decode,
            hlo_path: dir.join(format!("tiny_decode_b{batch}.native")),
            golden_path: None,
            inputs: decode_inputs,
            outputs: vec![
                f32_spec("logits", vec![batch, cfg.vocab]),
                f32_spec("k_cache", cfg.cache_dims(batch)),
                f32_spec("v_cache", cfg.cache_dims(batch)),
            ],
            meta,
        });
    }

    // Placeholder so `train --backend native` reaches NativeBackend::load's
    // clear "does not implement artifact kind TrainStep" error instead of a
    // misleading "not in manifest" (the trainer resolves
    // "{model}_train_step{variant}" before loading).
    specs.push(ArtifactSpec {
        name: "tiny_train_step".into(),
        kind: ArtifactKind::TrainStep,
        hlo_path: dir.join("tiny_train_step.native"),
        golden_path: None,
        inputs: vec![TensorSpec { name: "seed".into(), dims: vec![], dtype: DType::U32 }],
        outputs: vec![f32_spec("loss", vec![1])],
        meta: meta_obj(&[(
            "note",
            Json::Str("not implemented by the native backend".into()),
        )]),
    });

    // self-verifying attention kernels (golden = attn::exec::reference)
    // covering every spec axis: equal heads, GQA, MQA; full, causal,
    // sliding-window.  (name, kind, b, n_q, n_kv, n, d, causal, window, seed)
    type AttnCase = (&'static str, ArtifactKind, usize, usize, usize, usize, usize, bool, usize, usize);
    let attn_cases: [AttnCase; 6] = [
        ("native_attn_fwd_full_b2h2n48d32", ArtifactKind::AttnFwd, 2, 2, 2, 48, 32, false, 0, 11),
        ("native_attn_fwd_causal_b2h2n40d32", ArtifactKind::AttnFwd, 2, 2, 2, 40, 32, true, 0, 12),
        ("native_attn_grad_causal_b1h2n24d16", ArtifactKind::AttnGrad, 1, 2, 2, 24, 16, true, 0, 13),
        ("native_attn_fwd_gqa4x2_causal_b2n48d32", ArtifactKind::AttnFwd, 2, 4, 2, 48, 32, true, 0, 14),
        ("native_attn_fwd_swa_w16_b2h2n40d32", ArtifactKind::AttnFwd, 2, 2, 2, 40, 32, true, 16, 15),
        ("native_attn_grad_mqa_swa_w8_b1n24d16", ArtifactKind::AttnGrad, 1, 4, 1, 24, 16, true, 8, 16),
    ];
    for (name, kind, b, nq, nkv, n, d, causal, window, seed) in attn_cases {
        let qdims = vec![b, nq, n, d];
        let kdims = vec![b, nkv, n, d];
        let mut inputs = vec![
            f32_spec("q", qdims.clone()),
            f32_spec("k", kdims.clone()),
            f32_spec("v", kdims.clone()),
        ];
        let outputs = if kind == ArtifactKind::AttnFwd {
            vec![f32_spec("o", qdims.clone()), f32_spec("lse", vec![b, nq, n])]
        } else {
            inputs.push(f32_spec("do", qdims.clone()));
            vec![
                f32_spec("dq", qdims.clone()),
                f32_spec("dk", kdims.clone()),
                f32_spec("dv", kdims.clone()),
            ]
        };
        let mut meta_pairs = vec![
            ("seqlen", num(n)),
            ("head_dim", num(d)),
            ("n_kv_head", num(nkv)),
            ("causal", Json::Bool(causal)),
            ("seed", num(seed)),
            ("impl", Json::Str("attn_exec".into())),
        ];
        if window > 0 {
            meta_pairs.push(("window", num(window)));
        }
        specs.push(ArtifactSpec {
            name: name.to_string(),
            kind,
            hlo_path: dir.join(format!("{name}.native")),
            golden_path: None,
            inputs,
            outputs,
            meta: meta_obj(&meta_pairs),
        });
    }

    let mut artifacts = BTreeMap::new();
    for spec in specs {
        artifacts.insert(spec.name.clone(), spec);
    }
    Manifest { dir: dir.to_path_buf(), artifacts }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::kv::{KvArena, KvGeometry, KvSlot};

    fn manifest() -> Manifest {
        synth_manifest(Path::new("unused"), &GptConfig::tiny())
    }

    fn tiny_geo(cfg: &GptConfig) -> KvGeometry {
        KvGeometry {
            n_layer: cfg.n_layer,
            n_kv_head: cfg.n_kv_head,
            max_seq: cfg.max_seq,
            d_head: cfg.d_head(),
            block_tokens: DECODE_CHUNK,
        }
    }

    #[test]
    fn synth_manifest_has_the_serving_set() {
        let m = manifest();
        for name in ["tiny_init", "tiny_prefill_b1", "tiny_decode_b1", "tiny_decode_b4"] {
            assert!(m.artifacts.contains_key(name), "missing {name}");
        }
        assert_eq!(m.by_kind(ArtifactKind::AttnFwd).len(), 4);
        assert_eq!(m.by_kind(ArtifactKind::AttnGrad).len(), 2);
        let pre = m.get("tiny_prefill_b1").unwrap();
        for key in
            ["n_layer", "n_kv_head", "max_seq", "d_model", "n_head", "vocab_size", "prompt_len"]
        {
            assert!(pre.meta_i64(key).is_some(), "prefill meta missing {key}");
        }
        assert_eq!(m.get("tiny_decode_b4").unwrap().meta_i64("batch"), Some(4));
        // train_step resolves in the manifest but loads with the clear
        // "not implemented" error (never the misleading "not in manifest")
        let err = NativeBackend::new()
            .load(m.get("tiny_train_step").unwrap())
            .unwrap_err();
        assert!(format!("{err}").contains("does not implement"), "{err}");
    }

    #[test]
    fn gqa_window_config_flows_into_manifest_and_specs() {
        let cfg = GptConfig::tiny_with(RuntimeOptions {
            n_kv_heads: Some(2),
            window: Some(32),
        })
        .unwrap();
        assert_eq!(cfg.heads(), HeadMap { n_q_heads: 4, n_kv_heads: 2 });
        assert_eq!(cfg.mask(), Mask::SlidingWindow(32));
        assert_eq!(cfg.qkv_cols(), 64 + 2 * 2 * 16);
        let m = synth_manifest(Path::new("unused"), &cfg);
        let pre = m.get("tiny_prefill_b1").unwrap();
        assert_eq!(pre.meta_i64("n_kv_head"), Some(2));
        assert_eq!(pre.meta_i64("window"), Some(32));
        // cache tensors shrink with the KV head count
        assert_eq!(pre.outputs[1].dims, vec![2, 1, 2, 128, 16]);
        // invalid head maps are typed errors
        assert!(GptConfig::tiny_with(RuntimeOptions {
            n_kv_heads: Some(3),
            window: None,
        })
        .is_err());
        assert!(GptConfig::tiny_with(RuntimeOptions {
            n_kv_heads: None,
            window: Some(0),
        })
        .is_err());
    }

    #[test]
    fn attn_spec_from_reads_heads_and_masks() {
        let m = manifest();
        let s = attn_spec_from(m.get("native_attn_fwd_gqa4x2_causal_b2n48d32").unwrap())
            .unwrap();
        assert_eq!(s.heads, HeadMap { n_q_heads: 4, n_kv_heads: 2 });
        assert_eq!(s.mask, Mask::Causal);
        let s = attn_spec_from(m.get("native_attn_fwd_swa_w16_b2h2n40d32").unwrap()).unwrap();
        assert_eq!(s.mask, Mask::SlidingWindow(16));
        let s = attn_spec_from(m.get("native_attn_grad_mqa_swa_w8_b1n24d16").unwrap()).unwrap();
        assert_eq!(s.heads, HeadMap { n_q_heads: 4, n_kv_heads: 1 });
        assert_eq!(s.mask, Mask::SlidingWindow(8));
        let s = attn_spec_from(m.get("native_attn_fwd_full_b2h2n48d32").unwrap()).unwrap();
        assert_eq!(s.mask, Mask::Full);
    }

    #[test]
    fn init_prefill_decode_roundtrip_shapes_and_determinism() {
        let be = NativeBackend::new();
        let m = manifest();
        let init = be.load(m.get("tiny_init").unwrap()).unwrap();
        let prefill = be.load(m.get("tiny_prefill_b1").unwrap()).unwrap();
        let decode = be.load(m.get("tiny_decode_b1").unwrap()).unwrap();
        let cfg = GptConfig::tiny();

        let (params, _) = init.execute(&[HostTensor::scalar_u32(0)]).unwrap();
        assert_eq!(params.len(), cfg.n_params());
        let (params2, _) = init.execute(&[HostTensor::scalar_u32(0)]).unwrap();
        assert_eq!(params, params2, "init must be deterministic");

        let tokens: Vec<i32> = (0..cfg.prompt_len as i32).collect();
        let mut inputs = params.clone();
        inputs.push(HostTensor::from_i32(&[1, cfg.prompt_len], &tokens));
        let (pre, _) = prefill.execute(&inputs).unwrap();
        assert_eq!(pre[0].dims, vec![1, cfg.vocab]);
        assert_eq!(pre[1].dims, cfg.cache_dims(1));
        assert!(pre[0].to_f32_vec().iter().all(|x| x.is_finite()));

        let mut dec_inputs = params.clone();
        dec_inputs.push(pre[1].clone());
        dec_inputs.push(pre[2].clone());
        dec_inputs.push(HostTensor::from_i32(&[1], &[7]));
        dec_inputs.push(HostTensor::from_i32(&[1], &[cfg.prompt_len as i32]));
        let (dec, _) = decode.execute(&dec_inputs).unwrap();
        assert_eq!(dec[0].dims, vec![1, cfg.vocab]);
        let (dec2, _) = decode.execute(&dec_inputs).unwrap();
        assert_eq!(dec[0], dec2[0], "decode must be deterministic");
        // the new K/V row landed at prompt_len
        let kc = dec[1].to_f32_vec();
        let at = cfg.cache_offset(1, 0, 0, 0, cfg.prompt_len);
        assert!(kc[at..at + cfg.d_head()].iter().any(|&x| x != 0.0));
    }

    #[test]
    fn decode_is_batch_invariant_across_bucket_sizes() {
        let be = NativeBackend::new();
        let m = manifest();
        let cfg = GptConfig::tiny();
        let init = be.load(m.get("tiny_init").unwrap()).unwrap();
        let prefill = be.load(m.get("tiny_prefill_b1").unwrap()).unwrap();
        let d1 = be.load(m.get("tiny_decode_b1").unwrap()).unwrap();
        let d4 = be.load(m.get("tiny_decode_b4").unwrap()).unwrap();

        let (params, _) = init.execute(&[HostTensor::scalar_u32(0)]).unwrap();
        let tokens: Vec<i32> = (1..=cfg.prompt_len as i32).collect();
        let mut inputs = params.clone();
        inputs.push(HostTensor::from_i32(&[1, cfg.prompt_len], &tokens));
        let (pre, _) = prefill.execute(&inputs).unwrap();
        let (kc1, vc1) = (pre[1].to_f32_vec(), pre[2].to_f32_vec());

        let mut in1 = params.clone();
        in1.push(pre[1].clone());
        in1.push(pre[2].clone());
        in1.push(HostTensor::from_i32(&[1], &[3]));
        in1.push(HostTensor::from_i32(&[1], &[cfg.prompt_len as i32]));
        let (solo, _) = d1.execute(&in1).unwrap();

        // replicate the row 4× (what the compat padding does)
        let per = kc1.len();
        let mut kc4 = vec![0.0f32; 0];
        let mut vc4 = vec![0.0f32; 0];
        let per_layer = per / cfg.n_layer;
        for l in 0..cfg.n_layer {
            for _ in 0..4 {
                kc4.extend_from_slice(&kc1[l * per_layer..(l + 1) * per_layer]);
                vc4.extend_from_slice(&vc1[l * per_layer..(l + 1) * per_layer]);
            }
        }
        let mut in4 = params.clone();
        in4.push(HostTensor::from_f32(&cfg.cache_dims(4), &kc4));
        in4.push(HostTensor::from_f32(&cfg.cache_dims(4), &vc4));
        in4.push(HostTensor::from_i32(&[4], &[3, 3, 3, 3]));
        in4.push(HostTensor::from_i32(&[4], &[cfg.prompt_len as i32; 4]));
        let (batched, _) = d4.execute(&in4).unwrap();

        let solo_logits = solo[0].to_f32_vec();
        let batch_logits = batched[0].to_f32_vec();
        assert_eq!(
            &batch_logits[..cfg.vocab],
            &solo_logits[..],
            "batched decode row 0 diverged from solo decode"
        );
    }

    #[test]
    fn in_place_decode_step_is_byte_identical_to_batch_tensor_path() {
        // The serving acceptance bar, on BOTH the classic MHA model and a
        // GQA + sliding-window one: for 1, 2 and 3 active sequences the
        // paged in-place decode must produce bitwise-identical logits AND
        // cache contents to the legacy assemble/execute/scatter path,
        // while moving zero assemble/scatter bytes.
        let configs = [
            GptConfig::tiny(),
            GptConfig::tiny_with(RuntimeOptions { n_kv_heads: Some(2), window: Some(24) })
                .unwrap(),
        ];
        for cfg in configs {
            let be = NativeBackend::with_cfg(cfg);
            let m = synth_manifest(Path::new("unused"), &cfg);
            let init = be.load(m.get("tiny_init").unwrap()).unwrap();
            let prefill = be.load(m.get("tiny_prefill_b1").unwrap()).unwrap();
            let (params, _) = init.execute(&[HostTensor::scalar_u32(0)]).unwrap();

            // three distinct sequences' caches via prefill
            let mut slabs = Vec::new();
            for j in 0..3 {
                let tokens: Vec<i32> =
                    (0..cfg.prompt_len as i32).map(|t| t + 1 + j).collect();
                let mut inputs = params.clone();
                inputs.push(HostTensor::from_i32(&[1, cfg.prompt_len], &tokens));
                let (pre, _) = prefill.execute(&inputs).unwrap();
                slabs.push((pre[1].to_f32_vec(), pre[2].to_f32_vec()));
            }

            let geo = tiny_geo(&cfg);
            for rows in [1usize, 2, 3] {
                let bucket = if rows == 1 { 1 } else { 4 };
                let decode = be
                    .load(m.get(&format!("tiny_decode_b{bucket}")).unwrap())
                    .unwrap();
                let tok: Vec<i32> = (0..rows as i32).map(|t| 7 + t).collect();
                let pos = vec![cfg.prompt_len as i32; rows];

                // path A: legacy batch-tensor exchange through the DEFAULT
                // seam impl (gather -> execute -> scatter)
                let mut arena_a = KvArena::new(geo);
                let slots_a: Vec<KvSlot> = slabs[..rows]
                    .iter()
                    .map(|(k, v)| arena_a.adopt(k.clone(), v.clone()).unwrap())
                    .collect();
                let mut view = arena_a.batch_view(&slots_a, bucket);
                // call the compat path explicitly (gather/execute/scatter),
                // sidestepping the native override
                let (kt, vt) = view.gather();
                let mut inputs = params.clone();
                inputs.push(kt);
                inputs.push(vt);
                let mut tok_p = tok.clone();
                let mut pos_p = pos.clone();
                tok_p.resize(bucket, tok[0]);
                pos_p.resize(bucket, pos[0]);
                inputs.push(HostTensor::from_i32(&[bucket], &tok_p));
                inputs.push(HostTensor::from_i32(&[bucket], &pos_p));
                let (out, _) = decode.execute(&inputs).unwrap();
                view.scatter(&out[1], &out[2]).unwrap();
                let logits_a = out[0].to_f32_vec();
                assert!(
                    arena_a.stats().total_bytes() > 0,
                    "compat path must account copies"
                );

                // path B: in-place paged decode_step on the arena
                let mut arena_b = KvArena::new(geo);
                let slots_b: Vec<KvSlot> = slabs[..rows]
                    .iter()
                    .map(|(k, v)| arena_b.adopt(k.clone(), v.clone()).unwrap())
                    .collect();
                let mut view = arena_b.batch_view(&slots_b, bucket);
                let (logits_b, _) = decode
                    .decode_step(&params, &mut view, &tok, &pos)
                    .unwrap();
                assert_eq!(
                    arena_b.stats().total_bytes(),
                    0,
                    "native decode_step must move zero assemble/scatter bytes"
                );

                for bi in 0..rows {
                    assert_eq!(
                        &logits_a[bi * cfg.vocab..(bi + 1) * cfg.vocab],
                        &logits_b[bi * cfg.vocab..(bi + 1) * cfg.vocab],
                        "rows={rows} row {bi}: logits diverged (n_kv={} window={:?})",
                        cfg.n_kv_head,
                        cfg.window
                    );
                }
                for (sa, sb) in slots_a.iter().zip(&slots_b) {
                    let (ka, va) = arena_a.export_slab(*sa);
                    let (kb, vb) = arena_b.export_slab(*sb);
                    assert_eq!(ka, kb, "k cache diverged");
                    assert_eq!(va, vb, "v cache diverged");
                }
            }
        }
    }

    #[test]
    fn golden_cases_pass_their_own_modules() {
        let be = NativeBackend::new();
        let m = manifest();
        for name in [
            "native_attn_fwd_full_b2h2n48d32",
            "native_attn_fwd_causal_b2h2n40d32",
            "native_attn_grad_causal_b1h2n24d16",
            "native_attn_fwd_gqa4x2_causal_b2n48d32",
            "native_attn_fwd_swa_w16_b2h2n40d32",
            "native_attn_grad_mqa_swa_w8_b1n24d16",
        ] {
            let spec = m.get(name).unwrap();
            assert!(be.provides_golden(spec));
            let case = be.golden(spec).unwrap().expect("golden case");
            let module = be.load(spec).unwrap();
            let (outs, _) = module.execute(&case.inputs).unwrap();
            assert_eq!(outs.len(), case.outputs.len());
            for (got, want) in outs.iter().zip(&case.outputs) {
                let diff = got.max_abs_diff(want);
                assert!(diff < 2e-4, "{name}: flash vs reference max|Δ| = {diff}");
            }
        }
    }
}
