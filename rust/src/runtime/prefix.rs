//! Prefix-cache index over the paged KV arena (DESIGN.md §15).
//!
//! Maps *chain hashes* of fixed-size prompt token blocks to the physical
//! KV blocks that already hold their keys/values, so a new session can
//! adopt every full block it shares with a live or recently-retired
//! prefix instead of recomputing prefill.  The index is pure bookkeeping:
//! it never touches KV bytes and never allocates or frees pool blocks
//! itself — [`KvArena`](super::kv::KvArena) drives it and moves the
//! physical blocks between its free list and the cache.
//!
//! Entry lifecycle (the §15 refcount state machine):
//!
//! ```text
//! free ──publish──▶ cached(owner live, refs=1)
//!                      │ acquire            ▲ release
//!                      ▼                    │
//!                   shared(refs>1) ─────────┘
//!                      │ owner_free (refs-=1, owner dead)
//!                      ▼
//!                   cached(owner dead) ──refs=0──▶ evictable ──evict──▶ free
//!                      │ cow (divergent write on an adopter's copy)
//!                      ▼
//!                   release_block on the shared original
//! ```
//!
//! Invariants the [`ShadowArena`](super::kv::ShadowArena) sanitizer
//! cross-checks: a registered block is never written through a serving
//! sequence's table (adoption is capped below the last prompt token, and
//! copy-on-write swaps in a private copy first), refcounts never
//! underflow, and eviction only ever takes `refs == 0` entries.

use std::collections::HashMap;

/// FNV-1a 64-bit offset basis.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Chain hash of one prompt block: FNV-1a over the parent block's hash
/// followed by this block's token bytes.  Folding the parent in makes a
/// block's identity its content *and* its position in the prompt — the
/// same 16 tokens after a different prefix hash differently, so a hash
/// hit implies the whole leading prompt matches byte-for-byte.
pub fn hash_block(parent: u64, tokens: &[i32]) -> u64 {
    let mut h = FNV_OFFSET;
    for b in parent.to_le_bytes() {
        h = (h ^ u64::from(b)).wrapping_mul(FNV_PRIME);
    }
    for t in tokens {
        for b in t.to_le_bytes() {
            h = (h ^ u64::from(b)).wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// Chain hashes for every *complete* `block_tokens`-sized block of
/// `tokens`, in order.  The tail partial block (if any) has no hash — it
/// is never cacheable.
pub fn chain_hashes(tokens: &[i32], block_tokens: usize) -> Vec<u64> {
    if block_tokens == 0 {
        return Vec::new();
    }
    let mut hashes = Vec::with_capacity(tokens.len() / block_tokens);
    let mut parent = 0u64;
    for block in tokens.chunks_exact(block_tokens) {
        parent = hash_block(parent, block);
        hashes.push(parent);
    }
    hashes
}

/// One cached block: the physical pool block holding its KV rows, how
/// many holders pin it (the publishing sequence counts as one while it
/// lives), and its LRU position once evictable.
#[derive(Debug)]
struct Entry {
    block: u32,
    refs: usize,
    owner_live: bool,
    lru_tick: u64,
}

/// Monotonic cache traffic counts, for benches and the metrics report.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PrefixStats {
    /// Full prompt blocks adopted from the cache (prefill skipped).
    pub hit_blocks: u64,
    /// Full prompt blocks that had to be prefilled (no cache entry).
    pub miss_blocks: u64,
    /// Zero-ref cached blocks evicted back to the arena free list.
    pub evictions: u64,
    /// Copy-on-write block copies taken on a divergent write.
    pub cows: u64,
}

/// The refcounted hash→block index.  All methods are O(blocks touched)
/// and panic-free (this module is on the repro-lint hot-path list).
#[derive(Debug)]
pub struct PrefixIndex {
    block_tokens: usize,
    /// Max *owner-dead* (cache-held) entries retained; 0 = unbounded.
    cap_blocks: usize,
    entries: HashMap<u64, Entry>,
    by_block: HashMap<u32, u64>,
    tick: u64,
    stats: PrefixStats,
}

impl PrefixIndex {
    /// New empty index for `block_tokens`-sized blocks.  `cap_blocks`
    /// bounds how many blocks the cache may keep alive after their
    /// publishing sequence retired (0 = unbounded; live-referenced
    /// entries are pinned and never count against eviction).
    pub fn new(block_tokens: usize, cap_blocks: usize) -> PrefixIndex {
        PrefixIndex {
            block_tokens: block_tokens.max(1),
            cap_blocks,
            entries: HashMap::new(),
            by_block: HashMap::new(),
            tick: 0,
            stats: PrefixStats::default(),
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Number of leading full blocks of `tokens` present in the index,
    /// capped at `max_blocks`.  Read-only: no ref bump, no stats — the
    /// advisory probe used by router admission.
    pub fn probe(&self, tokens: &[i32], max_blocks: usize) -> usize {
        let mut n = 0;
        for h in chain_hashes(tokens, self.block_tokens).iter().take(max_blocks) {
            if self.entries.contains_key(h) {
                n += 1;
            } else {
                break;
            }
        }
        n
    }

    /// Walk the chain of `tokens` and pin (ref-bump) every leading block
    /// already cached, up to `max_blocks`; stops at the first miss.
    /// Returns the physical blocks adopted, in table order.  Pinned
    /// entries cannot be evicted until [`release_blocks`](Self::release_blocks).
    pub fn acquire(&mut self, tokens: &[i32], max_blocks: usize) -> Vec<u32> {
        let hashes = chain_hashes(tokens, self.block_tokens);
        let full = hashes.len().min(max_blocks);
        let mut adopted = Vec::new();
        for h in hashes.iter().take(max_blocks) {
            match self.entries.get_mut(h) {
                Some(e) => {
                    e.refs += 1;
                    adopted.push(e.block);
                }
                None => break,
            }
        }
        self.stats.hit_blocks += adopted.len() as u64;
        self.stats.miss_blocks += (full - adopted.len()) as u64;
        adopted
    }

    /// Re-pin already-known physical blocks (the preemption path, which
    /// must not re-walk the chain: the blocks are pinned *before* the
    /// sequence's table is freed, so their entries are guaranteed
    /// present).  Returns false if any block was not registered.
    pub fn acquire_blocks(&mut self, blocks: &[u32]) -> bool {
        let mut all = true;
        for b in blocks {
            match self.by_block.get(b).and_then(|h| self.entries.get_mut(h)) {
                Some(e) => e.refs += 1,
                None => all = false,
            }
        }
        all
    }

    /// Drop one pin from each block (adopter retiring or cancelling).
    /// Entries whose refs reach 0 stay cached but become evictable;
    /// their LRU position is the moment of last release.
    pub fn release_blocks(&mut self, blocks: &[u32]) {
        for &b in blocks {
            self.release_block(b);
        }
    }

    /// Drop one pin from a single block (also the COW path, which
    /// dereferences the shared original after copying it).
    pub fn release_block(&mut self, block: u32) {
        self.tick += 1;
        if let Some(e) = self.by_block.get(&block).and_then(|h| self.entries.get_mut(h)) {
            e.refs = e.refs.saturating_sub(1);
            if e.refs == 0 {
                e.lru_tick = self.tick;
            }
        }
    }

    /// Register the leading full blocks of a fully-prefilled prompt.
    /// `blocks` is the owning sequence's table; block `i` holds prompt
    /// positions `[i*block_tokens, (i+1)*block_tokens)`.  Hashes already
    /// present are skipped (first publisher wins — a concurrent session
    /// with the same prompt keeps its copies private), and the chain
    /// stops at the first skip so every registered entry's full prefix
    /// is also registered.  Returns the physical blocks registered (the
    /// arena mirrors exactly these into its sanitizer shadow).
    pub fn publish(&mut self, tokens: &[i32], blocks: &[u32]) -> Vec<u32> {
        let hashes = chain_hashes(tokens, self.block_tokens);
        let mut registered = Vec::new();
        for (h, &b) in hashes.iter().zip(blocks) {
            if self.entries.contains_key(h) {
                continue;
            }
            if self.by_block.contains_key(&b) {
                // this physical block already backs another hash — the
                // table is inconsistent with the index; refuse quietly
                break;
            }
            self.tick += 1;
            self.entries.insert(
                *h,
                Entry { block: b, refs: 1, owner_live: true, lru_tick: self.tick },
            );
            self.by_block.insert(b, *h);
            registered.push(b);
        }
        registered
    }

    /// The publishing sequence is retiring this block: drop its pin and
    /// mark the owner dead.  Returns true if the block is registered (the
    /// arena must then *keep it out of the free list* — the cache owns it
    /// until eviction); false means the block was never published.
    pub fn owner_free(&mut self, block: u32) -> bool {
        self.tick += 1;
        match self.by_block.get(&block).and_then(|h| self.entries.get_mut(h)) {
            Some(e) => {
                e.refs = e.refs.saturating_sub(1);
                e.owner_live = false;
                if e.refs == 0 {
                    e.lru_tick = self.tick;
                }
                true
            }
            None => false,
        }
    }

    /// Whether `block` is registered (shared KV — writes must COW).
    pub fn contains_block(&self, block: u32) -> bool {
        self.by_block.contains_key(&block)
    }

    /// Registered entries, total.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Blocks whose publisher retired but which adopters still pin —
    /// physically occupied, yet part of no sequence's fresh reservation.
    pub fn pinned_dead(&self) -> usize {
        self.entries.values().filter(|e| !e.owner_live && e.refs > 0).count()
    }

    /// Zero-ref cached blocks: reclaimable by [`evict_lru`](Self::evict_lru).
    pub fn evictable(&self) -> usize {
        self.entries.values().filter(|e| e.refs == 0).count()
    }

    /// Evict up to `max` zero-ref entries, least recently released
    /// first, and return their physical blocks for the arena's free
    /// list.  Entries with live refs are never taken.
    pub fn evict_lru(&mut self, max: usize) -> Vec<u32> {
        let mut victims: Vec<(u64, u64)> = self
            .entries
            .iter()
            .filter(|(_, e)| e.refs == 0)
            .map(|(h, e)| (e.lru_tick, *h))
            .collect();
        victims.sort_unstable();
        let mut freed = Vec::new();
        for (_, h) in victims.into_iter().take(max) {
            if let Some(e) = self.entries.remove(&h) {
                self.by_block.remove(&e.block);
                freed.push(e.block);
            }
        }
        self.stats.evictions += freed.len() as u64;
        freed
    }

    /// Enforce the owner-dead retention cap: evict zero-ref LRU entries
    /// while more than `cap_blocks` owner-dead entries remain.  Returns
    /// the reclaimed physical blocks (empty when unbounded or within
    /// cap).  Pinned owner-dead entries can keep the count above cap —
    /// they are never evicted.
    pub fn enforce_cap(&mut self) -> Vec<u32> {
        if self.cap_blocks == 0 {
            return Vec::new();
        }
        let dead = self.entries.values().filter(|e| !e.owner_live).count();
        let over = dead.saturating_sub(self.cap_blocks);
        self.evict_lru(over)
    }

    /// Record one copy-on-write (the arena performs the copy).
    pub fn note_cow(&mut self) {
        self.stats.cows += 1;
    }

    pub fn stats(&self) -> PrefixStats {
        self.stats
    }

    /// Test hook for the sanitizer suite: forcibly zero a block's
    /// refcount so a subsequent eviction contradicts the ShadowArena's
    /// mirror — the kv-sanitizer must catch the premature evict.
    #[cfg(any(debug_assertions, feature = "kv-sanitizer"))]
    pub fn corrupt_refs_for_test(&mut self, block: u32) -> bool {
        self.tick += 1;
        match self.by_block.get(&block).and_then(|h| self.entries.get_mut(h)) {
            Some(e) => {
                e.refs = 0;
                e.owner_live = false;
                e.lru_tick = self.tick;
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_hash_depends_on_content_and_position() {
        let a = chain_hashes(&[1, 2, 3, 4], 2);
        assert_eq!(a.len(), 2, "two full blocks of 2");
        // same second block after a different first block: different hash
        let b = chain_hashes(&[9, 9, 3, 4], 2);
        assert_ne!(a[0], b[0]);
        assert_ne!(a[1], b[1], "chain hash must fold in the parent");
        // identical prompts hash identically
        assert_eq!(a, chain_hashes(&[1, 2, 3, 4], 2));
        // partial tail block is not hashed
        assert_eq!(chain_hashes(&[1, 2, 3], 2).len(), 1);
    }

    #[test]
    fn acquire_pins_longest_prefix_and_publish_is_idempotent() {
        let mut ix = PrefixIndex::new(2, 0);
        let prompt = [1, 2, 3, 4, 5];
        assert_eq!(ix.publish(&prompt, &[10, 11]), vec![10, 11]);
        // re-publish (another session, same prompt) registers nothing
        assert_eq!(ix.publish(&prompt, &[20, 21]), Vec::<u32>::new());
        assert_eq!(ix.len(), 2);

        // shares block 0 only
        assert_eq!(ix.probe(&[1, 2, 9, 9], 8), 1);
        let adopted = ix.acquire(&[1, 2, 9, 9], 8);
        assert_eq!(adopted, vec![10]);
        // full match, capped at 1 block
        assert_eq!(ix.acquire(&[1, 2, 3, 4], 1), vec![10]);
        let st = ix.stats();
        assert_eq!(st.hit_blocks, 2);
        assert_eq!(st.miss_blocks, 1, "block [9,9] missed");
    }

    #[test]
    fn eviction_takes_only_zero_ref_entries_in_lru_order() {
        let mut ix = PrefixIndex::new(2, 0);
        ix.publish(&[1, 2, 3, 4], &[10, 11]);
        let pinned = ix.acquire(&[1, 2], 8); // pins block 10
        assert_eq!(pinned, vec![10]);
        // owner retires both blocks
        assert!(ix.owner_free(10));
        assert!(ix.owner_free(11));
        assert_eq!(ix.pinned_dead(), 1, "10 still pinned by the adopter");
        assert_eq!(ix.evictable(), 1);
        // only 11 can go, no matter how many we ask for
        assert_eq!(ix.evict_lru(8), vec![11]);
        assert_eq!(ix.evict_lru(8), Vec::<u32>::new());
        // adopter releases; now 10 is evictable
        ix.release_blocks(&pinned);
        assert_eq!(ix.evict_lru(8), vec![10]);
        assert!(ix.is_empty());
        assert_eq!(ix.stats().evictions, 2);
    }

    #[test]
    fn enforce_cap_bounds_owner_dead_entries() {
        let mut ix = PrefixIndex::new(1, 2);
        ix.publish(&[1, 2, 3, 4], &[10, 11, 12, 13]);
        assert!(ix.enforce_cap().is_empty(), "owner-live entries are exempt");
        for b in [10, 11, 12, 13] {
            assert!(ix.owner_free(b));
        }
        let mut evicted = ix.enforce_cap();
        evicted.sort_unstable();
        assert_eq!(evicted, vec![10, 11], "oldest-released evicted down to cap 2");
        assert_eq!(ix.len(), 2);
    }

    #[test]
    fn preemption_repin_keeps_blocks_alive() {
        let mut ix = PrefixIndex::new(2, 0);
        ix.publish(&[1, 2, 3, 4], &[10, 11]);
        let adopted = ix.acquire(&[1, 2, 3, 4], 8);
        // preempt: pin first, then the table free releases the old pins
        assert!(ix.acquire_blocks(&adopted));
        ix.release_blocks(&adopted);
        // owner retires; the preempted session's pins must still hold
        assert!(ix.owner_free(10));
        assert!(ix.owner_free(11));
        assert_eq!(ix.evict_lru(8), Vec::<u32>::new(), "pinned entries survive");
        ix.release_blocks(&adopted);
        assert_eq!(ix.evict_lru(8).len(), 2);
    }
}
