//! L3 runtime: loads artifacts and executes them through the pluggable
//! [`Backend`] seam (see backend.rs):
//!
//! - `pjrt` (feature `xla`): compiles AOT HLO text (`artifacts/*.hlo.txt`)
//!   on the PJRT CPU client; Python never runs on this path.
//! - `stub`: default offline build; manifest inspection only.
//! - `native`: the in-tree `attn::exec` CPU engine with a synthesized
//!   manifest — executes with no artifacts on disk at all.
//!
//! The runtime loads each module once, caches the executable, and
//! exchanges host tensors with the backend.  Serving additions (DESIGN.md
//! §8): `bundle` discovers a model's serving set from the manifest by
//! typed query, `kv` provides the zero-copy KV arena behind the widened
//! `Module::decode_step` seam, and `prefix` adds the refcounted
//! prefix-cache index the arena shares KV blocks through (DESIGN.md §15).

pub mod artifact;
pub mod backend;
pub mod bundle;
pub mod kv;
pub mod native;
pub mod prefix;

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use crate::bail;
use crate::util::error::{Context, Result};

pub use artifact::{ArtifactKind, ArtifactSpec, Manifest, TensorSpec};
pub use backend::{Backend, BackendKind, ExecTiming, GoldenCase, Module};
pub use bundle::{DecodeBuckets, ModelBundle, ServeShapes};
pub use kv::{CopyStats, KvArena, KvBatchView, KvGeometry, KvSlot, PagedKvMut, DEFAULT_KV_BLOCK};
pub use native::NativeBackend;
pub use prefix::{PrefixIndex, PrefixStats};

/// Backend construction knobs that are not artifact-derivable — today the
/// native backend's GQA/window model configuration (`model.n_kv_heads`,
/// `--window`).  Compiled-artifact backends ignore them (their shapes are
/// baked into the manifest).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RuntimeOptions {
    /// Native tiny GPT: KV heads (None = equal to n_head; 1 = MQA).
    pub n_kv_heads: Option<usize>,
    /// Native tiny GPT: sliding attention window (None = full causal).
    pub window: Option<usize>,
}

use crate::util::tensorio::{DType, HostTensor};

/// Execution statistics for one executable (perf accounting).
#[derive(Debug, Default, Clone, Copy)]
pub struct ExecStats {
    pub executions: u64,
    pub total_exec_secs: f64,
    pub total_transfer_secs: f64,
}

/// A compiled artifact ready to run.
pub struct Executable {
    pub spec: ArtifactSpec,
    module: Box<dyn backend::Module>,
    stats: Mutex<ExecStats>,
}

impl Executable {
    /// Execute with host tensors; validates shapes against the manifest spec.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&self.spec.inputs).enumerate() {
            if t.dims != s.dims || t.dtype != s.dtype {
                bail!(
                    "{}: input {i} ('{}') expects {:?}/{}, got {:?}/{}",
                    self.spec.name, s.name, s.dims, s.dtype.name(),
                    t.dims, t.dtype.name()
                );
            }
        }
        let (outputs, timing) = self.module.execute(inputs)?;
        if outputs.len() != self.spec.outputs.len() {
            bail!(
                "{}: manifest promises {} outputs, executable returned {}",
                self.spec.name,
                self.spec.outputs.len(),
                outputs.len()
            );
        }
        let mut st = self.stats.lock().unwrap();
        st.executions += 1;
        st.total_exec_secs += timing.exec_secs;
        st.total_transfer_secs += timing.transfer_secs;
        Ok(outputs)
    }

    pub fn stats(&self) -> ExecStats {
        *self.stats.lock().unwrap()
    }

    /// One batched decode step through the widened backend seam (see
    /// `backend::Module::decode_step`).  `tok`/`pos` are per *real* row;
    /// returns row-major logits with row `i` at `i * vocab`.
    pub fn decode_step(
        &self,
        params: &[HostTensor],
        view: &mut kv::KvBatchView<'_>,
        tok: &[i32],
        pos: &[i32],
    ) -> Result<Vec<f32>> {
        if tok.len() != view.rows() || pos.len() != view.rows() {
            bail!(
                "{}: decode_step wants {} tok/pos entries, got {}/{}",
                self.spec.name,
                view.rows(),
                tok.len(),
                pos.len()
            );
        }
        let (logits, timing) = self.module.decode_step(params, view, tok, pos)?;
        let mut st = self.stats.lock().unwrap();
        st.executions += 1;
        st.total_exec_secs += timing.exec_secs;
        st.total_transfer_secs += timing.transfer_secs;
        Ok(logits)
    }
}

/// Backend + manifest + executable cache.
pub struct Runtime {
    pub manifest: Manifest,
    backend: Box<dyn backend::Backend>,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Runtime {
    /// The default backend: PJRT under the `xla` feature, stub otherwise.
    pub fn new(artifact_dir: &Path) -> Result<Runtime> {
        Self::with_backend(artifact_dir, BackendKind::Auto)
    }

    /// Build a runtime on an explicit backend.  `Native` synthesizes its
    /// manifest in memory, so nothing needs to exist at `artifact_dir`.
    pub fn with_backend(artifact_dir: &Path, kind: BackendKind) -> Result<Runtime> {
        Self::with_backend_opts(artifact_dir, kind, RuntimeOptions::default())
    }

    /// [`with_backend`](Self::with_backend) plus [`RuntimeOptions`]: for
    /// the native backend, the GQA/window overrides shape the synthesized
    /// model + manifest together so they can never disagree.
    pub fn with_backend_opts(
        artifact_dir: &Path,
        kind: BackendKind,
        opts: RuntimeOptions,
    ) -> Result<Runtime> {
        let (manifest, backend): (Manifest, Box<dyn backend::Backend>) = match kind {
            BackendKind::Native => {
                let cfg = native::GptConfig::tiny_with(opts)?;
                (
                    native::synth_manifest(artifact_dir, &cfg),
                    Box::new(native::NativeBackend::with_cfg(cfg)),
                )
            }
            _ => (Manifest::load(artifact_dir)?, backend::make(kind)?),
        };
        Ok(Runtime { manifest, backend, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.backend.platform_name()
    }

    /// Load (compile) an artifact; compiled executables are cached by name.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.get(name)?.clone();
        let t0 = Instant::now();
        let module = self.backend.load(&spec)?;
        let compile_secs = t0.elapsed().as_secs_f64();
        if std::env::var_os("FA2_LOG_COMPILE").is_some() {
            eprintln!("[runtime] compiled {name} in {compile_secs:.2}s");
        }
        let exec = std::sync::Arc::new(Executable {
            spec,
            module,
            stats: Mutex::new(ExecStats::default()),
        });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exec.clone());
        Ok(exec)
    }

    /// Artifacts that can be golden-verified under this backend: those with
    /// golden files on disk, plus those the backend self-verifies (native).
    pub fn golden_names(&self) -> Vec<String> {
        self.manifest
            .artifacts
            .values()
            .filter(|a| a.golden_path.is_some() || self.backend.provides_golden(a))
            .map(|a| a.name.clone())
            .collect()
    }

    /// Run an artifact's golden vectors: returns max_abs_diff per output.
    /// Goldens come from the backend when it synthesizes them (native:
    /// `attn::exec::reference`), else from the artifact's golden file.
    pub fn verify_golden(&self, name: &str) -> Result<Vec<f32>> {
        let exe = self.load(name)?;
        let (inputs, expected) = match self.backend.golden(&exe.spec)? {
            Some(case) => (case.inputs, case.outputs),
            None => {
                let golden_path = exe
                    .spec
                    .golden_path
                    .as_ref()
                    .with_context(|| format!("{name} has no golden file"))?;
                let tensors = crate::util::tensorio::read_tensors(golden_path)?;
                let inputs: Vec<HostTensor> = (0..exe.spec.inputs.len())
                    .map(|i| {
                        tensors
                            .get(&format!("in{i}"))
                            .cloned()
                            .with_context(|| format!("{name}: golden missing in{i}"))
                    })
                    .collect::<Result<Vec<_>>>()?;
                let expected: Vec<HostTensor> = (0..exe.spec.outputs.len())
                    .map(|i| {
                        tensors
                            .get(&format!("out{i}"))
                            .cloned()
                            .with_context(|| format!("{name}: golden missing out{i}"))
                    })
                    .collect::<Result<Vec<_>>>()?;
                (inputs, expected)
            }
        };
        if expected.len() != exe.spec.outputs.len() {
            bail!(
                "{name}: golden provides {} outputs, spec promises {}",
                expected.len(),
                exe.spec.outputs.len()
            );
        }
        let outputs = exe.run(&inputs)?;
        let mut diffs = Vec::new();
        for (out, want) in outputs.iter().zip(&expected) {
            let diff = match out.dtype {
                DType::F32 => out.max_abs_diff(want),
                _ => {
                    // integer outputs must match exactly
                    if out.data == want.data { 0.0 } else { f32::INFINITY }
                }
            };
            diffs.push(diff);
        }
        Ok(diffs)
    }
}
