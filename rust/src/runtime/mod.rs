//! L3 runtime: loads AOT artifacts (`artifacts/*.hlo.txt`) and executes them
//! on the PJRT CPU client via the `backend` seam (real `xla` bindings under
//! the `xla` feature, an in-tree stub otherwise — see backend.rs).
//!
//! Python never runs on this path: `aot.py` lowered every entry point to HLO
//! text at build time.  The runtime compiles each module once, caches the
//! executable, and exchanges host tensors with the backend.

pub mod artifact;
pub mod backend;

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use crate::bail;
use crate::util::error::{Context, Result};

pub use artifact::{ArtifactKind, ArtifactSpec, Manifest, TensorSpec};
pub use backend::ExecTiming;

use crate::util::tensorio::{DType, HostTensor};

/// Execution statistics for one executable (perf accounting).
#[derive(Debug, Default, Clone, Copy)]
pub struct ExecStats {
    pub executions: u64,
    pub total_exec_secs: f64,
    pub total_transfer_secs: f64,
}

/// A compiled artifact ready to run.
pub struct Executable {
    pub spec: ArtifactSpec,
    module: backend::LoadedModule,
    stats: Mutex<ExecStats>,
}

impl Executable {
    /// Execute with host tensors; validates shapes against the manifest spec.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&self.spec.inputs).enumerate() {
            if t.dims != s.dims || t.dtype != s.dtype {
                bail!(
                    "{}: input {i} ('{}') expects {:?}/{}, got {:?}/{}",
                    self.spec.name, s.name, s.dims, s.dtype.name(),
                    t.dims, t.dtype.name()
                );
            }
        }
        let (outputs, timing) = self.module.execute(inputs)?;
        if outputs.len() != self.spec.outputs.len() {
            bail!(
                "{}: manifest promises {} outputs, executable returned {}",
                self.spec.name,
                self.spec.outputs.len(),
                outputs.len()
            );
        }
        let mut st = self.stats.lock().unwrap();
        st.executions += 1;
        st.total_exec_secs += timing.exec_secs;
        st.total_transfer_secs += timing.transfer_secs;
        Ok(outputs)
    }

    pub fn stats(&self) -> ExecStats {
        *self.stats.lock().unwrap()
    }
}

/// Backend client + manifest + executable cache.
pub struct Runtime {
    pub manifest: Manifest,
    client: backend::Client,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Runtime {
    pub fn new(artifact_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = backend::Client::cpu()?;
        Ok(Runtime { manifest, client, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (compile) an artifact; compiled executables are cached by name.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.get(name)?.clone();
        let t0 = Instant::now();
        let module = self.client.compile_hlo_text(name, &spec.hlo_path)?;
        let compile_secs = t0.elapsed().as_secs_f64();
        if std::env::var_os("FA2_LOG_COMPILE").is_some() {
            eprintln!("[runtime] compiled {name} in {compile_secs:.2}s");
        }
        let exec = std::sync::Arc::new(Executable {
            spec,
            module,
            stats: Mutex::new(ExecStats::default()),
        });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exec.clone());
        Ok(exec)
    }

    /// Run an artifact's golden vectors: returns (max_abs_diff per output).
    pub fn verify_golden(&self, name: &str) -> Result<Vec<f32>> {
        let exe = self.load(name)?;
        let golden_path = exe
            .spec
            .golden_path
            .as_ref()
            .with_context(|| format!("{name} has no golden file"))?;
        let tensors = crate::util::tensorio::read_tensors(golden_path)?;
        let inputs: Vec<HostTensor> = (0..exe.spec.inputs.len())
            .map(|i| {
                tensors
                    .get(&format!("in{i}"))
                    .cloned()
                    .with_context(|| format!("{name}: golden missing in{i}"))
            })
            .collect::<Result<Vec<_>>>()?;
        let outputs = exe.run(&inputs)?;
        let mut diffs = Vec::new();
        for (i, out) in outputs.iter().enumerate() {
            let want = tensors
                .get(&format!("out{i}"))
                .with_context(|| format!("{name}: golden missing out{i}"))?;
            let diff = match out.dtype {
                DType::F32 => out.max_abs_diff(want),
                _ => {
                    // integer outputs must match exactly
                    if out.data == want.data { 0.0 } else { f32::INFINITY }
                }
            };
            diffs.push(diff);
        }
        Ok(diffs)
    }
}
