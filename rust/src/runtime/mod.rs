//! L3 runtime: loads AOT artifacts (`artifacts/*.hlo.txt`) and executes them
//! on the PJRT CPU client via the `xla` crate.
//!
//! Python never runs on this path: `aot.py` lowered every entry point to HLO
//! *text* at build time (text, not serialized proto — xla_extension 0.5.1
//! rejects jax>=0.5's 64-bit instruction ids; the text parser reassigns
//! them).  The runtime compiles each module once, caches the executable, and
//! exchanges host tensors as XLA literals.

pub mod artifact;

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Context, Result};

pub use artifact::{ArtifactKind, ArtifactSpec, Manifest, TensorSpec};

use crate::util::tensorio::{DType, HostTensor};

fn element_type(dt: DType) -> xla::ElementType {
    match dt {
        DType::F32 => xla::ElementType::F32,
        DType::I32 => xla::ElementType::S32,
        DType::U32 => xla::ElementType::U32,
        DType::F64 => xla::ElementType::F64,
        DType::I64 => xla::ElementType::S64,
    }
}

fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
    xla::Literal::create_from_shape_and_untyped_data(
        element_type(t.dtype),
        &t.dims,
        &t.data,
    )
    .map_err(|e| anyhow::anyhow!("literal create failed: {e:?}"))
}

fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow::anyhow!("literal shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let dtype = match shape.primitive_type() {
        xla::PrimitiveType::F32 => DType::F32,
        xla::PrimitiveType::S32 => DType::I32,
        xla::PrimitiveType::U32 => DType::U32,
        xla::PrimitiveType::F64 => DType::F64,
        xla::PrimitiveType::S64 => DType::I64,
        other => bail!("unsupported output primitive type {other:?}"),
    };
    let n = lit.element_count();
    let data;
    // Bulk path: one copy_raw_to into a typed buffer, then a single memcpy
    // reinterpreting to bytes (host is little-endian, matching FAT1).
    // (Perf: the original per-element to_le_bytes loop was ~40% of transfer
    // time on large outputs — see EXPERIMENTS.md §Perf.)
    macro_rules! copy_as {
        ($t:ty) => {{
            let mut buf = vec![<$t>::default(); n];
            lit.copy_raw_to::<$t>(&mut buf)
                .map_err(|e| anyhow::anyhow!("copy_raw_to: {e:?}"))?;
            // SAFETY: buf is a live, initialized slice of plain-old-data
            // numeric values; reinterpreting as bytes is always valid.
            let bytes = unsafe {
                std::slice::from_raw_parts(
                    buf.as_ptr() as *const u8,
                    n * std::mem::size_of::<$t>(),
                )
            };
            data = bytes.to_vec();
        }};
    }
    match dtype {
        DType::F32 => copy_as!(f32),
        DType::I32 => copy_as!(i32),
        DType::U32 => copy_as!(u32),
        DType::F64 => copy_as!(f64),
        DType::I64 => copy_as!(i64),
    }
    Ok(HostTensor { dtype, dims, data })
}

/// Execution statistics for one executable (perf accounting, EXPERIMENTS §Perf).
#[derive(Debug, Default, Clone, Copy)]
pub struct ExecStats {
    pub executions: u64,
    pub total_exec_secs: f64,
    pub total_transfer_secs: f64,
}

/// A compiled artifact ready to run.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    stats: Mutex<ExecStats>,
}

impl Executable {
    /// Execute with host tensors; validates shapes against the manifest spec.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&self.spec.inputs).enumerate() {
            if t.dims != s.dims || t.dtype != s.dtype {
                bail!(
                    "{}: input {i} ('{}') expects {:?}/{}, got {:?}/{}",
                    self.spec.name, s.name, s.dims, s.dtype.name(),
                    t.dims, t.dtype.name()
                );
            }
        }
        let t0 = Instant::now();
        let literals = inputs
            .iter()
            .map(to_literal)
            .collect::<Result<Vec<_>>>()?;
        let t_transfer_in = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("{}: execute: {e:?}", self.spec.name))?;
        let exec_secs = t1.elapsed().as_secs_f64();

        let t2 = Instant::now();
        let buffer = &result[0][0];
        let lit = buffer
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal_sync: {e:?}"))?;
        // aot.py lowers with return_tuple=True: the single output is a tuple.
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("to_tuple: {e:?}"))?;
        let outputs = parts
            .iter()
            .map(from_literal)
            .collect::<Result<Vec<_>>>()?;
        let transfer_secs = t_transfer_in + t2.elapsed().as_secs_f64();

        if outputs.len() != self.spec.outputs.len() {
            bail!(
                "{}: manifest promises {} outputs, executable returned {}",
                self.spec.name,
                self.spec.outputs.len(),
                outputs.len()
            );
        }
        let mut st = self.stats.lock().unwrap();
        st.executions += 1;
        st.total_exec_secs += exec_secs;
        st.total_transfer_secs += transfer_secs;
        Ok(outputs)
    }

    pub fn stats(&self) -> ExecStats {
        *self.stats.lock().unwrap()
    }
}

/// PJRT client + manifest + executable cache.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Runtime {
    pub fn new(artifact_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PjRtClient::cpu: {e:?}"))?;
        Ok(Runtime { manifest, client, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (compile) an artifact; compiled executables are cached by name.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.get(name)?.clone();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            spec.hlo_path
                .to_str()
                .context("non-utf8 artifact path")?,
        )
        .map_err(|e| anyhow::anyhow!("{}: parse hlo: {e:?}", name))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("{}: compile: {e:?}", name))?;
        let compile_secs = t0.elapsed().as_secs_f64();
        if std::env::var_os("FA2_LOG_COMPILE").is_some() {
            eprintln!("[runtime] compiled {name} in {compile_secs:.2}s");
        }
        let exec = std::sync::Arc::new(Executable {
            spec,
            exe,
            stats: Mutex::new(ExecStats::default()),
        });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exec.clone());
        Ok(exec)
    }

    /// Run an artifact's golden vectors: returns (max_abs_diff per output).
    pub fn verify_golden(&self, name: &str) -> Result<Vec<f32>> {
        let exe = self.load(name)?;
        let golden_path = exe
            .spec
            .golden_path
            .as_ref()
            .with_context(|| format!("{name} has no golden file"))?;
        let tensors = crate::util::tensorio::read_tensors(golden_path)?;
        let inputs: Vec<HostTensor> = (0..exe.spec.inputs.len())
            .map(|i| {
                tensors
                    .get(&format!("in{i}"))
                    .cloned()
                    .with_context(|| format!("{name}: golden missing in{i}"))
            })
            .collect::<Result<Vec<_>>>()?;
        let outputs = exe.run(&inputs)?;
        let mut diffs = Vec::new();
        for (i, out) in outputs.iter().enumerate() {
            let want = tensors
                .get(&format!("out{i}"))
                .with_context(|| format!("{name}: golden missing out{i}"))?;
            let diff = match out.dtype {
                DType::F32 => out.max_abs_diff(want),
                _ => {
                    // integer outputs must match exactly
                    if out.data == want.data { 0.0 } else { f32::INFINITY }
                }
            };
            diffs.push(diff);
        }
        Ok(diffs)
    }
}
