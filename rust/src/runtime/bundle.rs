//! Typed serving-model discovery (DESIGN.md §8): [`ModelBundle`] resolves a
//! model's init/prefill/decode executables from the manifest by
//! [`ArtifactKind`] + `meta.model`, replacing the old coordinator habit of
//! guessing format-string names (`{model}_prefill_b1`, `{model}_decode_b4`).
//!
//! The decode bucket set ([`DecodeBuckets`]) is likewise *discovered* from
//! the manifest's decode artifacts (`meta.batch`) instead of hardcoding the
//! 1/4 pair, so adding a compiled `_decode_b8` artifact widens the serving
//! batch ceiling with no coordinator change.

use std::sync::Arc;

use crate::bail;
use crate::util::error::{Context, Result};

use crate::runtime::artifact::{ArtifactKind, ArtifactSpec};
use crate::runtime::kv::KvGeometry;
use crate::runtime::{Executable, Runtime};

/// Shapes of the serving model, read from artifact metadata.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeShapes {
    pub n_layer: usize,
    pub n_kv_head: usize,
    pub max_seq: usize,
    pub d_head: usize,
    pub vocab: usize,
    pub prompt_len: usize,
}

impl ServeShapes {
    pub fn from_spec(spec: &ArtifactSpec) -> Result<ServeShapes> {
        Ok(ServeShapes {
            n_layer: spec.meta_i64("n_layer").context("n_layer")? as usize,
            n_kv_head: spec.meta_i64("n_kv_head").context("n_kv_head")? as usize,
            max_seq: spec.meta_i64("max_seq").context("max_seq")? as usize,
            d_head: (spec.meta_i64("d_model").context("d_model")?
                / spec.meta_i64("n_head").context("n_head")?) as usize,
            vocab: spec.meta_i64("vocab_size").context("vocab")? as usize,
            prompt_len: spec.meta_i64("prompt_len").context("prompt_len")? as usize,
        })
    }

    pub fn cache_elems_per_seq(&self) -> usize {
        self.n_layer * self.n_kv_head * self.max_seq * self.d_head
    }

    /// Bytes one KV block pins (K + V, f32) under `block_tokens`-token
    /// paging — what a block-level admission decision actually reserves,
    /// surfaced by `repro serve` so operators can size the arena against
    /// memory.
    pub fn block_bytes(&self, block_tokens: usize) -> usize {
        2 * self.n_layer * self.n_kv_head * block_tokens.max(1) * self.d_head
            * std::mem::size_of::<f32>()
    }

    /// Bytes a full-window sequence pins (K + V, f32) — the worst case a
    /// single session can reserve.
    pub fn slot_bytes(&self) -> usize {
        2 * self.cache_elems_per_seq() * std::mem::size_of::<f32>()
    }

    /// The paged KV-arena geometry this model serves with, under
    /// `block_tokens`-token blocks.
    pub fn geometry(&self, block_tokens: usize) -> KvGeometry {
        KvGeometry {
            n_layer: self.n_layer,
            n_kv_head: self.n_kv_head,
            max_seq: self.max_seq,
            d_head: self.d_head,
            block_tokens: block_tokens.max(1),
        }
    }
}

/// The compiled decode batch sizes, sorted ascending.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DecodeBuckets {
    sizes: Vec<usize>,
}

impl DecodeBuckets {
    pub fn new(mut sizes: Vec<usize>) -> Result<DecodeBuckets> {
        if sizes.is_empty() {
            bail!("no decode buckets discovered");
        }
        sizes.sort_unstable();
        if sizes[0] == 0 {
            bail!("decode bucket of size 0");
        }
        if sizes.windows(2).any(|w| w[0] == w[1]) {
            bail!("duplicate decode bucket in {sizes:?}");
        }
        Ok(DecodeBuckets { sizes })
    }

    pub fn sizes(&self) -> &[usize] {
        &self.sizes
    }

    /// Largest bucket — the decode group chunk size.
    pub fn max(&self) -> usize {
        *self.sizes.last().expect("buckets are non-empty")
    }

    /// Smallest bucket that fits `n` active rows (callers chunk by
    /// [`max`](Self::max) first, so `n <= max` always holds in the worker).
    pub fn pick(&self, n: usize) -> usize {
        self.sizes.iter().copied().find(|&b| b >= n).unwrap_or_else(|| self.max())
    }
}

/// A model's serving executables, discovered and loaded once.
pub struct ModelBundle {
    pub model: String,
    pub init: Arc<Executable>,
    pub prefill: Arc<Executable>,
    /// (bucket, executable), ascending by bucket.
    decodes: Vec<(usize, Arc<Executable>)>,
    pub buckets: DecodeBuckets,
    pub shapes: ServeShapes,
}

impl ModelBundle {
    /// Typed manifest query: find `model`'s init, batch-1 prefill and every
    /// decode bucket by `ArtifactKind` + `meta.model` and load them.
    pub fn discover(rt: &Runtime, model: &str) -> Result<ModelBundle> {
        let of_kind = |kind: ArtifactKind| -> Vec<&ArtifactSpec> {
            rt.manifest
                .by_kind(kind)
                .into_iter()
                .filter(|a| a.meta_str("model") == Some(model))
                .collect()
        };

        let inits = of_kind(ArtifactKind::Init);
        let [init_spec] = inits.as_slice() else {
            bail!(
                "model '{model}': expected exactly one init artifact, found {} \
                 (manifest has {} artifacts)",
                inits.len(),
                rt.manifest.artifacts.len()
            );
        };

        let prefill_spec = of_kind(ArtifactKind::Prefill)
            .into_iter()
            .find(|a| a.meta_i64("batch").unwrap_or(1) == 1)
            .with_context(|| format!("model '{model}': no batch-1 prefill artifact"))?;
        let shapes = ServeShapes::from_spec(prefill_spec)
            .with_context(|| format!("{}: serving metadata", prefill_spec.name))?;

        let mut decodes = Vec::new();
        for spec in of_kind(ArtifactKind::Decode) {
            let bucket = spec
                .meta_i64("batch")
                .with_context(|| format!("{}: decode artifact missing meta.batch", spec.name))?
                as usize;
            decodes.push((bucket, rt.load(&spec.name)?));
        }
        decodes.sort_by_key(|(b, _)| *b);
        let buckets = DecodeBuckets::new(decodes.iter().map(|(b, _)| *b).collect())
            .with_context(|| format!("model '{model}'"))?;

        Ok(ModelBundle {
            model: model.to_string(),
            init: rt.load(&init_spec.name)?,
            prefill: rt.load(&prefill_spec.name)?,
            decodes,
            buckets,
            shapes,
        })
    }

    /// The decode executable compiled for exactly `bucket` rows.
    pub fn decode_for(&self, bucket: usize) -> Result<&Arc<Executable>> {
        self.decodes
            .iter()
            .find(|(b, _)| *b == bucket)
            .map(|(_, e)| e)
            .with_context(|| {
                format!(
                    "model '{}': no decode artifact for bucket {bucket} (have {:?})",
                    self.model,
                    self.buckets.sizes()
                )
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::BackendKind;
    use std::path::Path;

    #[test]
    fn buckets_pick_smallest_fitting() {
        let b = DecodeBuckets::new(vec![4, 1]).unwrap();
        assert_eq!(b.sizes(), &[1, 4]);
        assert_eq!(b.max(), 4);
        assert_eq!(b.pick(1), 1);
        assert_eq!(b.pick(2), 4);
        assert_eq!(b.pick(3), 4);
        assert_eq!(b.pick(4), 4);
        // callers chunk by max() first; past-max falls back to max
        assert_eq!(b.pick(9), 4);
        assert!(DecodeBuckets::new(vec![]).is_err());
        assert!(DecodeBuckets::new(vec![2, 2]).is_err());
        assert!(DecodeBuckets::new(vec![0, 1]).is_err());
    }

    #[test]
    fn discovers_native_tiny_bundle_by_typed_query() {
        let rt = Runtime::with_backend(Path::new("unused"), BackendKind::Native).unwrap();
        let bundle = ModelBundle::discover(&rt, "tiny").unwrap();
        assert_eq!(bundle.buckets.sizes(), &[1, 4]);
        assert_eq!(bundle.shapes.n_layer, 2);
        assert_eq!(bundle.shapes.vocab, 512);
        assert_eq!(bundle.shapes.prompt_len, 16);
        let geo = bundle.shapes.geometry(16);
        assert_eq!(geo.slot_elems(), bundle.shapes.cache_elems_per_seq());
        assert_eq!(geo.block_tokens, 16);
        assert_eq!(geo.blocks_per_seq(), 128 / 16);
        // slot_bytes = K + V slabs in f32: 2 * L*H*S*dh * 4
        assert_eq!(bundle.shapes.slot_bytes(), 2 * 4 * bundle.shapes.cache_elems_per_seq());
        // a block pins 1/blocks_per_seq of that
        assert_eq!(
            bundle.shapes.block_bytes(16) * geo.blocks_per_seq(),
            bundle.shapes.slot_bytes()
        );
        assert!(bundle.decode_for(4).is_ok());
        assert!(bundle.decode_for(1).is_ok());
        assert!(bundle.decode_for(2).is_err());
        // unknown model is a typed discovery error, not a name-format guess
        let err = ModelBundle::discover(&rt, "nonexistent").unwrap_err();
        assert!(format!("{err:#}").contains("nonexistent"));
    }
}
