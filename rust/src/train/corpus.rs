//! Synthetic training corpus: a deterministic Zipf-weighted order-1 Markov
//! token stream.  It has enough learnable structure (bigram statistics) that
//! the cross-entropy of a trained model drops well below the unigram
//! entropy — which is what the e2e loss-curve experiment checks — without
//! needing any external dataset.

use crate::util::rng::{zipf_cdf, Rng};

pub struct Corpus {
    vocab: usize,
    /// Per-state successor tables: each token has `branch` likely successors
    /// drawn by a seeded permutation; transitions follow them with prob
    /// `locality`, otherwise sample the Zipf unigram.
    successors: Vec<[u32; 4]>,
    cdf: Vec<f64>,
    locality: f64,
    rng: Rng,
    state: u32,
}

impl Corpus {
    pub fn new(vocab: usize, seed: u64) -> Corpus {
        assert!(vocab >= 8);
        let mut rng = Rng::seed_from(seed);
        let successors = (0..vocab)
            .map(|_| {
                [
                    rng.below(vocab as u64) as u32,
                    rng.below(vocab as u64) as u32,
                    rng.below(vocab as u64) as u32,
                    rng.below(vocab as u64) as u32,
                ]
            })
            .collect();
        Corpus {
            vocab,
            successors,
            cdf: zipf_cdf(vocab, 1.1),
            locality: 0.75,
            rng,
            state: 0,
        }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    pub fn next_token(&mut self) -> i32 {
        let t = if self.rng.next_f64() < self.locality {
            self.successors[self.state as usize]
                [self.rng.below(4) as usize]
        } else {
            self.rng.zipf(&self.cdf) as u32
        };
        self.state = t;
        t as i32
    }

    /// Fill a (batch, seqlen) token matrix, row-major.
    pub fn next_batch(&mut self, batch: usize, seqlen: usize) -> Vec<i32> {
        (0..batch * seqlen).map(|_| self.next_token()).collect()
    }

    /// Empirical unigram entropy of the stream (nats) over `n` samples —
    /// the ceiling an order-0 model could reach; a trained transformer must
    /// beat this by exploiting the Markov structure.
    pub fn unigram_entropy(&mut self, n: usize) -> f64 {
        let mut counts = vec![0usize; self.vocab];
        for _ in 0..n {
            counts[self.next_token() as usize] += 1;
        }
        let total = n as f64;
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / total;
                -p * p.ln()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let a: Vec<i32> = Corpus::new(512, 9).next_batch(2, 32);
        let b: Vec<i32> = Corpus::new(512, 9).next_batch(2, 32);
        assert_eq!(a, b);
        let c: Vec<i32> = Corpus::new(512, 10).next_batch(2, 32);
        assert_ne!(a, c);
    }

    #[test]
    fn tokens_in_range() {
        let mut c = Corpus::new(128, 1);
        for t in c.next_batch(4, 256) {
            assert!((0..128).contains(&t));
        }
    }

    #[test]
    fn stream_has_learnable_structure() {
        // Markov locality means bigram entropy << unigram entropy.
        let mut c = Corpus::new(256, 2);
        let h1 = c.unigram_entropy(50_000);
        // conditional entropy given predecessor: estimate from bigrams
        let mut c = Corpus::new(256, 2);
        let mut prev = c.next_token();
        let mut big = std::collections::HashMap::new();
        let mut ctx = vec![0usize; 256];
        for _ in 0..50_000 {
            let t = c.next_token();
            *big.entry((prev, t)).or_insert(0usize) += 1;
            ctx[prev as usize] += 1;
            prev = t;
        }
        let h2: f64 = big
            .iter()
            .map(|(&(p, _), &n)| {
                let pj = n as f64 / 50_000.0;
                let pc = n as f64 / ctx[p as usize] as f64;
                -pj * pc.ln()
            })
            .sum();
        assert!(
            h2 < h1 - 0.5,
            "bigram entropy {h2:.2} should be well below unigram {h1:.2}"
        );
    }
}
