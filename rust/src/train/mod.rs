//! Training driver: synthetic corpus + AOT train_step loop + MFU accounting.

pub mod corpus;
pub mod trainer;
