//! Training driver: runs the AOT `*_train_step` executable in a loop over
//! the synthetic corpus, tracking loss, step time, and the paper's MFU
//! accounting (section 4.2 formula, applied to the measured wall-clock).
//!
//! State (flat params + Adam moments) lives host-side as `HostTensor`s and
//! round-trips through the executable each step — the whole fwd+bwd+Adam
//! update is a single compiled HLO module, so Python is never involved.

use std::path::Path;
use std::time::Instant;

use crate::bail;
use crate::util::error::{Context, Result};

use crate::runtime::{ArtifactKind, Runtime};
use crate::util::tensorio::{write_tensors, HostTensor};

use super::corpus::Corpus;

/// Configuration for a training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Artifact model prefix: "tiny" or "small".
    pub model: String,
    /// "" for the FA2 kernel path, "_refattn" for the XLA-fused reference
    /// attention (the no-FlashAttention baseline of Table 1).
    pub variant: String,
    pub steps: usize,
    pub seed: u64,
    pub log_every: usize,
    /// Optional checkpoint output (FAT1 of final params).
    pub checkpoint: Option<String>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "tiny".into(),
            variant: String::new(),
            steps: 50,
            seed: 0,
            log_every: 10,
            checkpoint: None,
        }
    }
}

/// One logged step.
#[derive(Debug, Clone, Copy)]
pub struct StepLog {
    pub step: usize,
    pub loss: f32,
    pub step_secs: f64,
}

/// Results of a run.
#[derive(Debug)]
pub struct TrainReport {
    pub logs: Vec<StepLog>,
    pub tokens_per_step: usize,
    pub model_flops_per_step: f64,
    pub mean_step_secs: f64,
    pub achieved_flops: f64,
}

impl TrainReport {
    pub fn first_loss(&self) -> f32 {
        self.logs.first().map(|l| l.loss).unwrap_or(f32::NAN)
    }

    pub fn last_loss(&self) -> f32 {
        self.logs.last().map(|l| l.loss).unwrap_or(f32::NAN)
    }

    pub fn loss_csv(&self) -> String {
        let mut out = String::from("step,loss,step_secs\n");
        for l in &self.logs {
            out.push_str(&format!("{},{:.4},{:.4}\n", l.step, l.loss, l.step_secs));
        }
        out
    }
}

pub struct Trainer {
    rt: std::sync::Arc<Runtime>,
}

impl Trainer {
    pub fn new(rt: std::sync::Arc<Runtime>) -> Trainer {
        Trainer { rt }
    }

    pub fn run(&self, cfg: &TrainConfig) -> Result<TrainReport> {
        let step_name = format!("{}_train_step{}", cfg.model, cfg.variant);
        let step_exe = self.rt.load(&step_name)?;
        if step_exe.spec.kind != ArtifactKind::TrainStep {
            bail!("{step_name} is not a train_step artifact");
        }
        let init_exe = self.rt.load(&format!("{}_init", cfg.model))?;
        let meta = &step_exe.spec;
        let vocab = meta.meta_i64("vocab_size").context("vocab_size")? as usize;
        let batch = meta.meta_i64("train_batch").context("train_batch")? as usize;
        let seqlen = meta.meta_i64("max_seq").context("max_seq")? as usize;
        let n_params = meta.meta_i64("n_params").context("n_params")? as f64;
        let n_layer = meta.meta_i64("n_layer").context("n_layer")? as f64;
        let d_model = meta.meta_i64("d_model").context("d_model")? as f64;

        // params from the init artifact; Adam state zero-initialized to the
        // manifest's declared shapes.
        let params = init_exe.run(&[HostTensor::scalar_u32(cfg.seed as u32)])?;
        let n_p = params.len();
        let n_inputs = step_exe.spec.inputs.len();
        let n_opt = n_inputs - n_p - 1;
        let mut state: Vec<HostTensor> = params;
        for spec in &step_exe.spec.inputs[n_p..n_p + n_opt] {
            state.push(HostTensor::zeros(spec.dtype, &spec.dims));
        }

        // Megatron FLOPs formula per step (paper section 4.2).
        let flops_per_seq = 6.0 * seqlen as f64 * n_params
            + 12.0 * n_layer * d_model * (seqlen as f64).powi(2);
        let model_flops_per_step = flops_per_seq * batch as f64;

        let mut corpus = Corpus::new(vocab, cfg.seed ^ 0xC0FFEE);
        let mut logs = Vec::with_capacity(cfg.steps);
        let mut total_secs = 0.0;
        for step in 0..cfg.steps {
            let tokens = corpus.next_batch(batch, seqlen);
            let mut inputs = state;
            inputs.push(HostTensor::from_i32(&[batch, seqlen], &tokens));
            let t0 = Instant::now();
            let mut outputs = step_exe.run(&inputs)?;
            let dt = t0.elapsed().as_secs_f64();
            total_secs += dt;
            let loss_t = outputs.pop().context("train_step returned no loss")?;
            let loss = loss_t.to_f32_vec()[0];
            if !loss.is_finite() {
                bail!("loss diverged (non-finite) at step {step}");
            }
            state = outputs;
            logs.push(StepLog { step, loss, step_secs: dt });
            if cfg.log_every > 0 && step % cfg.log_every == 0 {
                eprintln!(
                    "[train {}] step {step:>4} loss {loss:.4} ({:.2}s/step, {:.1} MFLOP/s)",
                    cfg.model,
                    dt,
                    model_flops_per_step / dt / 1e6
                );
            }
        }

        if let Some(path) = &cfg.checkpoint {
            let named: std::collections::BTreeMap<String, HostTensor> = state
                .iter()
                .take(n_p)
                .enumerate()
                .map(|(i, t)| (step_exe.spec.inputs[i].name.clone(), t.clone()))
                .collect();
            write_tensors(Path::new(path), &named)?;
        }

        let mean = total_secs / cfg.steps.max(1) as f64;
        Ok(TrainReport {
            logs,
            tokens_per_step: batch * seqlen,
            model_flops_per_step,
            mean_step_secs: mean,
            achieved_flops: model_flops_per_step / mean,
        })
    }
}

#[cfg(test)]
mod tests {
    // Runtime-dependent tests live in rust/tests/integration_train.rs; here
    // we only test the report plumbing.
    use super::*;

    #[test]
    fn report_accessors() {
        let r = TrainReport {
            logs: vec![
                StepLog { step: 0, loss: 6.0, step_secs: 0.1 },
                StepLog { step: 1, loss: 5.0, step_secs: 0.1 },
            ],
            tokens_per_step: 256,
            model_flops_per_step: 1e9,
            mean_step_secs: 0.1,
            achieved_flops: 1e10,
        };
        assert_eq!(r.first_loss(), 6.0);
        assert_eq!(r.last_loss(), 5.0);
        assert_eq!(r.loss_csv().lines().count(), 3);
    }
}
