//! In-tree static analysis: the `repro lint` pass (DESIGN.md §12).
//!
//! A zero-dependency lint layer the same spirit as the in-tree json/toml
//! parsers: [`scan`] hand-rolls a Rust token scanner (no syn), [`rules`]
//! is the registry of repo-invariant checks, [`report`] applies the
//! `fa2lint: allow(...)` directives and renders `file:line: [rule-id]`
//! diagnostics.  `ci.sh` runs the pass as a hard gate before the tests;
//! `./ci.sh --verify-lint` proves the gate can actually fail by linting
//! with an injected violation ([`lint_workspace`] with
//! `inject_violation = true`).
//!
//! The pass scans the *workspace* (`rust/src`, `rust/tests`, `benches`,
//! `examples`, the `Cargo.toml`s), not the compiler's view of the crate:
//! it reads files off disk, so it also sees code behind disabled features.

pub mod report;
pub mod rules;
pub mod scan;

use std::path::Path;

use crate::util::error::{Context, Result};

pub use report::{Diagnostic, LintReport};
pub use rules::RULES;
use scan::{FileKind, ScannedFile};

/// Run the full lint pass over the workspace at `root` (the directory
/// holding `ci.sh`).  `inject_violation` adds a synthetic in-memory
/// hot-path file containing an `unwrap()` — the `--verify-lint` fixture
/// proving the gate fails when it should (the same pattern as
/// `FA2_BENCH_INJECT_SLOWDOWN` for the bench gate).
pub fn lint_workspace(root: &Path, inject_violation: bool) -> Result<LintReport> {
    let mut files = collect_files(root)?;
    if inject_violation {
        files.push(injected_fixture());
    }
    let raw = rules::run_all(&files);
    Ok(report::finish(&files, raw))
}

/// The synthetic violation used by `--verify-lint`.
fn injected_fixture() -> ScannedFile {
    scan::scan(
        "rust/src/attn/exec/__lint_inject_fixture.rs",
        FileKind::Src,
        "pub fn poisoned(x: Option<u32>) -> u32 { x.unwrap() }\n",
    )
}

/// Enumerate and scan the lintable files, sorted by path for
/// deterministic reports.
pub fn collect_files(root: &Path) -> Result<Vec<ScannedFile>> {
    let mut files = Vec::new();
    walk_rs(root, "rust/src", FileKind::Src, &mut files)?;
    walk_rs(root, "rust/tests", FileKind::TestFile, &mut files)?;
    walk_rs(root, "benches", FileKind::Bench, &mut files)?;
    walk_rs(root, "examples", FileKind::Example, &mut files)?;
    for manifest in ["Cargo.toml", "rust/Cargo.toml"] {
        let p = root.join(manifest);
        if p.exists() {
            let text = std::fs::read_to_string(&p)
                .with_context(|| format!("reading {}", p.display()))?;
            files.push(scan::scan(manifest, FileKind::Manifest, &text));
        }
    }
    files.sort_by(|a, b| a.path.cmp(&b.path));
    Ok(files)
}

/// Recursively scan `root/rel` for `.rs` files (sorted traversal).
fn walk_rs(
    root: &Path,
    rel: &str,
    kind: FileKind,
    out: &mut Vec<ScannedFile>,
) -> Result<()> {
    let dir = root.join(rel);
    if !dir.is_dir() {
        return Ok(());
    }
    let mut entries: Vec<(String, bool)> = std::fs::read_dir(&dir)
        .with_context(|| format!("listing {}", dir.display()))?
        .filter_map(|e| e.ok())
        .map(|e| {
            let is_dir = e.file_type().map(|t| t.is_dir()).unwrap_or(false);
            (e.file_name().to_string_lossy().into_owned(), is_dir)
        })
        .collect();
    entries.sort();
    for (name, is_dir) in entries {
        let child_rel = format!("{rel}/{name}");
        if is_dir {
            walk_rs(root, &child_rel, kind, out)?;
        } else if name.ends_with(".rs") {
            let p = root.join(&child_rel);
            let text = std::fs::read_to_string(&p)
                .with_context(|| format!("reading {}", p.display()))?;
            out.push(scan::scan(&child_rel, kind, &text));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::summary;

    #[test]
    fn injected_fixture_trips_the_hotpath_rule() {
        let f = injected_fixture();
        let raw = rules::run_all(std::slice::from_ref(&f));
        let r = report::finish(std::slice::from_ref(&f), raw);
        assert!(!r.clean());
        assert!(r.violations.iter().any(|d| d.rule == "no-hotpath-panic"
            && d.path.contains("__lint_inject_fixture")));
    }

    #[test]
    fn workspace_collection_sees_all_file_kinds() {
        let root = summary::workspace_root();
        let files = collect_files(&root).expect("workspace is readable");
        let has = |k: FileKind| files.iter().any(|f| f.kind == k);
        assert!(has(FileKind::Src));
        assert!(has(FileKind::TestFile));
        assert!(has(FileKind::Bench));
        assert!(has(FileKind::Example));
        assert!(has(FileKind::Manifest));
        assert!(files.iter().any(|f| f.path == "rust/src/analysis/mod.rs"));
    }
}
