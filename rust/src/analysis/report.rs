//! Diagnostics, allowlist application, and rendering for `repro lint`.
//!
//! Rules emit raw [`Diagnostic`]s; [`finish`] then applies the per-file
//! `fa2lint: allow(...)` directives and folds the scanner's malformed
//! directives into `allow-syntax` violations.  Suppression is exact: the
//! directive must sit on (or directly above) the flagged line and name the
//! flagged rule id.  An allow that suppresses nothing is reported as a
//! warning so stale suppressions get cleaned up rather than rotting.

use super::rules::known_rule;
use super::scan::ScannedFile;

#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub path: String,
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

impl Diagnostic {
    pub fn new(path: &str, line: u32, rule: &'static str, msg: String) -> Diagnostic {
        Diagnostic { path: path.to_string(), line, rule, msg }
    }

    pub fn render(&self) -> String {
        format!("{}:{}: [{}] {}", self.path, self.line, self.rule, self.msg)
    }
}

/// The outcome of a lint pass: `violations` non-empty fails the gate;
/// `warnings` (unused allows) never do.
#[derive(Debug, Default)]
pub struct LintReport {
    pub violations: Vec<Diagnostic>,
    pub warnings: Vec<Diagnostic>,
    /// Diagnostics suppressed by a directive (kept for `--verbose`-style
    /// introspection and for tests asserting suppression really happened).
    pub suppressed: Vec<Diagnostic>,
}

impl LintReport {
    pub fn clean(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Apply allowlists and directive hygiene to the raw rule output.
pub fn finish(files: &[ScannedFile], raw: Vec<Diagnostic>) -> LintReport {
    let mut report = LintReport::default();
    // (file path, allow index) -> did it suppress anything
    let mut used: Vec<Vec<bool>> =
        files.iter().map(|f| vec![false; f.allows.len()]).collect();

    for d in raw {
        let suppressing = files.iter().enumerate().find_map(|(fi, f)| {
            if f.path != d.path {
                return None;
            }
            f.allows
                .iter()
                .position(|a| {
                    a.applies_to == d.line && a.rules.iter().any(|r| r == d.rule)
                })
                .map(|ai| (fi, ai))
        });
        match suppressing {
            Some((fi, ai)) => {
                used[fi][ai] = true;
                report.suppressed.push(d);
            }
            None => report.violations.push(d),
        }
    }

    for (fi, f) in files.iter().enumerate() {
        for (line, why) in &f.malformed_allows {
            report.violations.push(Diagnostic::new(
                &f.path,
                *line,
                "allow-syntax",
                why.clone(),
            ));
        }
        for (ai, a) in f.allows.iter().enumerate() {
            for r in &a.rules {
                if !known_rule(r) {
                    report.violations.push(Diagnostic::new(
                        &f.path,
                        a.line,
                        "allow-syntax",
                        format!("allow names unknown rule id `{r}`"),
                    ));
                }
            }
            if !used[fi][ai] && a.rules.iter().all(|r| known_rule(r)) {
                report.warnings.push(Diagnostic::new(
                    &f.path,
                    a.line,
                    "allow-syntax",
                    format!(
                        "unused allow({}) — nothing on line {} trips that rule; \
                         remove the stale directive",
                        a.rules.join(", "),
                        a.applies_to
                    ),
                ));
            }
        }
    }

    sort(&mut report.violations);
    sort(&mut report.warnings);
    sort(&mut report.suppressed);
    report
}

fn sort(diags: &mut [Diagnostic]) {
    diags.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.rule).cmp(&(b.path.as_str(), b.line, b.rule))
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::rules;
    use crate::analysis::scan::{scan, FileKind};

    fn lint_one(path: &str, kind: FileKind, src: &str) -> LintReport {
        let f = scan(path, kind, src);
        let raw = rules::run_all(std::slice::from_ref(&f));
        finish(std::slice::from_ref(&f), raw)
    }

    #[test]
    fn allow_suppresses_exactly_its_rule_and_line() {
        let src = "fn hot(x: Option<u32>) {\n\
                       // fa2lint: allow(no-hotpath-panic) -- slot liveness proven by caller\n\
                       let _a = x.unwrap();\n\
                       let _b = x.unwrap();\n\
                   }\n";
        let r = lint_one("rust/src/runtime/kv.rs", FileKind::Src, src);
        assert_eq!(r.suppressed.len(), 1);
        assert_eq!(r.violations.len(), 1, "{:?}", r.violations);
        assert_eq!(r.violations[0].line, 4, "only the un-allowed line fails");
        assert!(r.warnings.is_empty());
    }

    #[test]
    fn trailing_allow_and_multi_rule_list() {
        let src = "fn f(x: f32) -> bool {\n\
                       x == 1.0 // fa2lint: allow(no-float-eq, no-hotpath-panic) -- exact no-op sentinel\n\
                   }\n";
        let r = lint_one("rust/src/attn/combine.rs", FileKind::Src, src);
        assert!(r.clean(), "{:?}", r.violations);
        assert_eq!(r.suppressed.len(), 1);
    }

    #[test]
    fn unused_allow_warns_unknown_rule_fails() {
        let src = "// fa2lint: allow(no-float-eq) -- nothing here actually\n\
                   fn f() {}\n\
                   // fa2lint: allow(no-such-rule) -- typo\n\
                   fn g() {}\n";
        let r = lint_one("rust/src/util/x.rs", FileKind::Src, src);
        assert_eq!(r.warnings.len(), 1, "{:?}", r.warnings);
        assert!(r.warnings[0].msg.contains("unused"));
        assert_eq!(r.violations.len(), 1, "{:?}", r.violations);
        assert!(r.violations[0].msg.contains("unknown rule id"));
    }

    #[test]
    fn malformed_directive_is_a_violation() {
        let src = "fn f(x: Option<u32>) { // fa2lint: allow(no-hotpath-panic)\n\
                       let _ = x;\n\
                   }\n";
        let r = lint_one("rust/src/util/x.rs", FileKind::Src, src);
        assert_eq!(r.violations.len(), 1);
        assert_eq!(r.violations[0].rule, "allow-syntax");
    }

    #[test]
    fn manifest_allow_suppresses_dep_policy() {
        let toml = "[dev-dependencies]\n\
                    libc = \"0.2\" # fa2lint: allow(dep-policy) -- hypothetical escape hatch\n";
        let r = lint_one("rust/Cargo.toml", FileKind::Manifest, toml);
        assert!(r.clean(), "{:?}", r.violations);
        assert_eq!(r.suppressed.len(), 1);
    }

    #[test]
    fn render_is_file_line_rule_message() {
        let d = Diagnostic::new("rust/src/x.rs", 7, "no-float-eq", "msg".into());
        assert_eq!(d.render(), "rust/src/x.rs:7: [no-float-eq] msg");
    }
}
